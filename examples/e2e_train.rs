//! End-to-end driver (DESIGN.md / EXPERIMENTS.md §E2E): trains the
//! ~570k-parameter mini_res model through the FULL three-layer stack —
//! rust coordinator → PJRT CPU client → AOT HLO containing the Pallas
//! matmul/SGD kernels — for a few hundred FEEL periods on the synthetic
//! 10-class image corpus, logging the loss curve to results/e2e/.
//!
//! Requires `make artifacts`. Run:
//!   cargo run --release --example e2e_train [periods]

#![allow(clippy::field_reassign_with_default)]

use feel::config::Experiment;
use feel::coordinator::{Scheme, Trainer};
use feel::exp::common::{make_backend, make_data, BackendKind};
use feel::metrics::Recorder;
use feel::util::rng::Pcg;

fn main() -> anyhow::Result<()> {
    let periods: usize = std::env::args()
        .nth(1)
        .map(|s| s.parse())
        .transpose()?
        .unwrap_or(300);

    let mut exp = Experiment::default();
    exp.model = "mini_res".into();
    exp.k = 6;
    exp.train_n = 6000;
    exp.test_n = 1024;
    exp.trainer.eval_every = 10;

    let backend = make_backend(&exp, BackendKind::Pjrt)?;
    let (train, test) = make_data(&exp);
    let mut rng = Pcg::seeded(1);
    let fleet = exp.fleet(&mut rng);

    let t0 = std::time::Instant::now();
    let mut tr = Trainer::new(
        { let mut c = exp.trainer.clone(); c.scheme = Scheme::Proposed; c },
        fleet,
        &train,
        &test,
        exp.partition,
        backend.as_ref(),
    )?;
    println!("e2e: mini_res (570k params) x K=6 CPUs, {periods} FEEL periods via PJRT...");
    tr.run(periods)?;
    let wall = t0.elapsed().as_secs_f64();

    let rec = Recorder::new(std::path::Path::new("results"), "e2e")?;
    rec.csv("loss_curve", &tr.log.to_csv())?;

    let log = &tr.log;
    let first = &log.records[0];
    let last = log.records.last().unwrap();
    println!(
        "\nloss {:.4} -> {:.4} over {} periods ({:.0} simulated s, {:.0} host s)",
        first.train_loss,
        last.train_loss,
        log.records.len(),
        log.total_time(),
        wall
    );
    println!(
        "final test accuracy: {}",
        log.final_acc().map(|a| format!("{:.3}", a)).unwrap_or("n/a".into())
    );
    println!("loss curve -> {}", rec.dir().join("loss_curve.csv").display());

    // a few milestones for EXPERIMENTS.md
    for frac in [0.25, 0.5, 0.75, 1.0] {
        let i = ((log.records.len() - 1) as f64 * frac) as usize;
        let r = &log.records[i];
        println!(
            "  period {:>4}  sim {:>7.1}s  loss {:.4}  B {:>4}  acc {}",
            r.period,
            r.sim_time,
            r.train_loss,
            r.b_total,
            r.test_acc.map(|a| format!("{a:.3}")).unwrap_or_default()
        );
    }
    Ok(())
}
