fn main() {
    use std::time::Instant;
    use feel::util::linalg::gemm;
    use feel::util::rng::Pcg;
    let mut r = Pcg::seeded(1);
    let (m, k, n) = (128, 768, 256);
    let a: Vec<f32> = (0..m*k).map(|_| r.normal() as f32).collect();
    let b: Vec<f32> = (0..k*n).map(|_| r.normal() as f32).collect();
    let mut c = vec![0f32; m*n];
    let t = Instant::now();
    for _ in 0..50 { c.iter_mut().for_each(|x| *x = 0.0); gemm(m, k, n, &a, &b, &mut c); }
    let dt = t.elapsed().as_secs_f64() / 50.0;
    println!("gemm {m}x{k}x{n}: {:.3} ms, {:.2} GFLOP/s", dt*1e3, 2.0*(m*k*n) as f64/dt/1e9);
}
