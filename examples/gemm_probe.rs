//! Quick serial-kernel probe: packed-tile gemm vs the frozen pre-packing
//! kernel on a host-model-shaped call (see benches/bench_gemm.rs for the
//! full sweep + JSON baseline).

fn main() {
    use std::time::Instant;
    use feel::util::linalg::{gemm, gemm_ref};
    use feel::util::rng::Pcg;
    use feel::util::threads;

    let mut r = Pcg::seeded(1);
    let (m, k, n) = (128, 768, 256);
    let a: Vec<f32> = (0..m * k).map(|_| r.normal() as f32).collect();
    let b: Vec<f32> = (0..k * n).map(|_| r.normal() as f32).collect();
    let mut c = vec![0f32; m * n];
    let flops = 2.0 * (m * k * n) as f64;

    let t = Instant::now();
    for _ in 0..50 {
        c.iter_mut().for_each(|x| *x = 0.0);
        gemm_ref(m, k, n, &a, &b, &mut c);
    }
    let dt_ref = t.elapsed().as_secs_f64() / 50.0;

    let t = Instant::now();
    for _ in 0..50 {
        c.iter_mut().for_each(|x| *x = 0.0);
        threads::with_budget(1, || gemm(m, k, n, &a, &b, &mut c));
    }
    let dt = t.elapsed().as_secs_f64() / 50.0;

    println!(
        "gemm {m}x{k}x{n}: ref {:.3} ms ({:.2} GFLOP/s) -> packed {:.3} ms ({:.2} GFLOP/s), {:.2}x",
        dt_ref * 1e3,
        flops / dt_ref / 1e9,
        dt * 1e3,
        flops / dt / 1e9,
        dt_ref / dt
    );
}
