//! Domain scenario: a heterogeneous fleet (mixed CPU tiers + GPUs, mixed
//! channel quality) showing how the optimal batchsize adapts per device —
//! the paper's Remark 2 (batch scales with local training speed, grows with
//! rate) demonstrated as a table across channel conditions.
//!
//! Run: `cargo run --release --example heterogeneous_fleet`

use feel::device::{Compute, CpuModule, Device, GpuModule};
use feel::opt;
use feel::opt::types::Instance;
use feel::util::rng::Pcg;
use feel::wireless::{CellConfig, DeviceLink};

fn main() -> anyhow::Result<()> {
    let cell = CellConfig::default();
    let mut rng = Pcg::seeded(11);

    // 2 slow CPUs, 2 fast CPUs, 2 GPUs, at close/far positions
    let mk_cpu = |id: usize, ghz: f64, dist: f64, rng: &mut Pcg| Device {
        id,
        compute: Compute::Cpu(CpuModule::new(ghz * 1e9, 7e7, 1e8)),
        link: DeviceLink::at_distance(cell, dist, 0.0, 0.0, rng),
    };
    let mk_gpu = |id: usize, dist: f64, rng: &mut Pcg| Device {
        id,
        compute: Compute::Gpu(GpuModule::new(0.11, 2.4e-3, 24.0, 2e9, 1e13)),
        link: DeviceLink::at_distance(cell, dist, 0.0, 0.0, rng),
    };
    let mut fleet = vec![
        mk_cpu(0, 0.7, 60.0, &mut rng),
        mk_cpu(1, 0.7, 180.0, &mut rng),
        mk_cpu(2, 2.1, 60.0, &mut rng),
        mk_cpu(3, 2.1, 180.0, &mut rng),
        mk_gpu(4, 60.0, &mut rng),
        mk_gpu(5, 180.0, &mut rng),
    ];

    println!("heterogeneous fleet — optimal allocation across channel states\n");
    println!(
        "{:<28} {:>8} {:>8} {:>8} {:>8} {:>8} {:>8}   {:>8} {:>8}",
        "", "cpu0.7/n", "cpu0.7/f", "cpu2.1/n", "cpu2.1/f", "gpu/near", "gpu/far", "B*", "T (s)"
    );

    for (label, rate_scale) in
        [("good channels (x4 rate)", 4.0), ("nominal channels", 1.0), ("poor channels (/4 rate)", 0.25)]
    {
        let rates: Vec<_> = fleet
            .iter_mut()
            .map(|d| {
                let mut r = d.link.step(&mut rng);
                r.ul_bps *= rate_scale;
                r.dl_bps *= rate_scale;
                r
            })
            .collect();
        let s_bits = 0.005 * 64.0 * 570_000.0;
        let inst = Instance::from_fleet(&fleet, &rates, 128.0, s_bits, 0.01, 0.01, 0.05)?;
        let sol = opt::solve(&inst, 1e-9)?;
        let b: Vec<String> = sol.solution.batches.iter().map(|x| format!("{x:>8.1}")).collect();
        println!(
            "{:<28} {}   {:>8.0} {:>8.2}",
            label,
            b.join(" "),
            sol.solution.b_total,
            sol.solution.period_latency()
        );
    }

    println!(
        "\nReading the table (paper Remark 2): faster devices get larger batches\n\
         (GPUs >> 2.1 GHz CPUs >> 0.7 GHz CPUs); GPUs sit above their\n\
         compute-bound knee (B_th = 24, Lemma 2); as channels degrade, far\n\
         devices shed batch relative to near ones, and the optimizer grows the\n\
         global batch B* to amortize the now-costlier fixed communication\n\
         phase over more loss decay per period (E = xi*sqrt(B)/T)."
    );
    Ok(())
}
