//! Quickstart: the smallest end-to-end use of the public API.
//!
//! Loads the AOT artifacts (falls back to the pure-rust host model if
//! `make artifacts` hasn't run), builds the paper's K=6 CPU fleet, and runs
//! 20 FEEL training periods with the proposed joint batchsize + slot
//! policy, printing the per-period allocation and loss.
//!
//! Run: `cargo run --release --example quickstart`

#![allow(clippy::field_reassign_with_default)]

use feel::config::Experiment;
use feel::coordinator::{Scheme, Trainer};
use feel::exp::common::{make_backend, make_data, BackendKind};
use feel::util::rng::Pcg;

fn main() -> anyhow::Result<()> {
    let mut exp = Experiment::default();
    exp.k = 6;
    exp.train_n = 3000;
    exp.trainer.eval_every = 5;

    // prefer the production PJRT path when artifacts exist
    let kind = if std::path::Path::new("artifacts/manifest.json").exists() {
        BackendKind::Pjrt
    } else {
        eprintln!("note: no artifacts/ — using the pure-rust host backend");
        exp.synth.dim = 96; // keep the host model snappy
        BackendKind::Host
    };

    let backend = make_backend(&exp, kind)?;
    let (train, test) = make_data(&exp);
    let mut rng = Pcg::seeded(7);
    let fleet = exp.fleet(&mut rng);
    println!("fleet:");
    for d in &fleet {
        println!("  device {} at {:.0} m, {:?}", d.id, d.link.dist_m, d.compute.affine());
    }

    let mut tr = Trainer::new(
        { let mut c = exp.trainer.clone(); c.scheme = Scheme::Proposed; c },
        fleet,
        &train,
        &test,
        exp.partition,
        backend.as_ref(),
    )?;
    tr.run(20)?;

    println!("\nperiod  sim_time  T_period  B_total  train_loss  test_acc");
    for r in &tr.log.records {
        println!(
            "{:>6}  {:>8.2}  {:>8.3}  {:>7}  {:>10.4}  {}",
            r.period,
            r.sim_time,
            r.t_period,
            r.b_total,
            r.train_loss,
            r.test_acc.map(|a| format!("{a:.3}")).unwrap_or_default()
        );
    }
    println!(
        "\n20 periods in {:.1} simulated seconds; final loss {:.4}",
        tr.log.total_time(),
        tr.log.final_loss().unwrap()
    );
    Ok(())
}
