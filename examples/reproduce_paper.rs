//! Regenerate every paper table/figure in one run (moderate scale).
//!
//!   cargo run --release --example reproduce_paper [quick|full]
//!
//! quick (default): host backend, reduced periods — minutes.
//! full: paper-scale periods — long; use the CLI (`feel experiment ...`)
//! to run individual artifacts at custom scales.

#![allow(clippy::field_reassign_with_default)]

use feel::config::Experiment;
use feel::exp::common::BackendKind;
use feel::exp::{fig2, fig3, fig45, table2};
use feel::metrics::Recorder;

fn main() -> anyhow::Result<()> {
    let full = std::env::args().nth(1).as_deref() == Some("full");
    let kind = BackendKind::Host;
    let root = std::path::Path::new("results");
    let (t2_periods, t2_warm, fig3_periods, f45_budget, f45_periods, train_n, dim) = if full {
        (300, 400, 300, 1200.0, 4000, 6000, 768)
    } else {
        (60, 120, 50, 250.0, 500, 1800, 128)
    };
    let mut base = Experiment::default();
    base.train_n = train_n;
    base.test_n = 512;
    base.synth.dim = dim;

    println!("=== Fig. 2 ===");
    fig2::drive(&Recorder::new(root, "fig2")?)?;

    println!("\n=== Table II (K=6) ===");
    table2::drive(&Recorder::new(root, "table2_k6")?, &base, 6, t2_periods, t2_warm, kind)?;

    println!("\n=== Table II (K=12) ===");
    table2::drive(&Recorder::new(root, "table2_k12")?, &base, 12, t2_periods, t2_warm, kind)?;

    println!("\n=== Fig. 3 ===");
    fig3::drive(&Recorder::new(root, "fig3")?, &base, fig3_periods, kind)?;

    println!("\n=== Fig. 4 (IID) ===");
    fig45::drive(&Recorder::new(root, "fig4")?, &base, 4, f45_budget, f45_periods, kind)?;

    println!("\n=== Fig. 5 (non-IID) ===");
    fig45::drive(&Recorder::new(root, "fig5")?, &base, 5, f45_budget, f45_periods, kind)?;

    println!("\nall artifacts under results/");
    Ok(())
}
