// probe table2 ordering at different warm levels
fn main() {
    use feel::exp::table2::run_cell;
    use feel::exp::common::BackendKind;
    use feel::data::Partition;
    use feel::config::Experiment;
    let mut base = Experiment::default();
    base.synth.dim = 24;
    base.train_n = 800;
    base.test_n = 200;
    for warm in [30usize, 150, 400] {
        let rows = run_cell(&base, 4, Partition::Iid, 25, warm, BackendKind::Host).unwrap();
        println!("warm={warm}:");
        for r in &rows {
            println!("  {:<12} acc {:.3} spd {:.2} reached={} t={:.0}", r.scheme, r.test_acc, r.speedup, r.reached_target, r.sim_time);
        }
    }
}
