"""L1 correctness gate: Pallas kernels vs pure-jnp oracles.

Hypothesis sweeps shapes and dtypes; assert_allclose against ref.py is the
only thing that makes the kernels trustworthy (interpret=True means no
hardware compiler checked them either).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.matmul import matmul, matmul_bias_act
from compile.kernels.sgd import sgd_momentum_update, sgd_update

jax.config.update("jax_enable_x64", False)


def rand(rng, *shape, dtype=np.float32):
    return jnp.asarray(rng.standard_normal(shape), dtype)


dims = st.integers(min_value=1, max_value=200)


class TestMatmul:
    @settings(max_examples=25, deadline=None)
    @given(m=dims, k=dims, n=dims, seed=st.integers(0, 2**31 - 1))
    def test_matches_ref_random_shapes(self, m, k, n, seed):
        rng = np.random.default_rng(seed)
        a, b = rand(rng, m, k), rand(rng, k, n)
        np.testing.assert_allclose(
            matmul(a, b), ref.matmul_ref(a, b), rtol=2e-5, atol=2e-5
        )

    @pytest.mark.parametrize(
        "m,k,n",
        [(1, 1, 1), (128, 128, 128), (1, 768, 10), (129, 257, 3), (128, 1, 128)],
    )
    def test_edge_shapes(self, m, k, n):
        rng = np.random.default_rng(0)
        a, b = rand(rng, m, k), rand(rng, k, n)
        np.testing.assert_allclose(
            matmul(a, b), ref.matmul_ref(a, b), rtol=2e-5, atol=2e-5
        )

    def test_bfloat16_inputs_accumulate_f32(self):
        rng = np.random.default_rng(1)
        a = jnp.asarray(rng.standard_normal((32, 64)), jnp.bfloat16)
        b = jnp.asarray(rng.standard_normal((64, 16)), jnp.bfloat16)
        got = matmul(a, b)
        assert got.dtype == jnp.float32
        np.testing.assert_allclose(
            got, ref.matmul_ref(a, b), rtol=1e-5, atol=1e-5
        )

    def test_explicit_blocks(self):
        rng = np.random.default_rng(2)
        a, b = rand(rng, 100, 70), rand(rng, 70, 40)
        got = matmul(a, b, bm=32, bn=16, bk=8)
        np.testing.assert_allclose(got, ref.matmul_ref(a, b), rtol=2e-5, atol=2e-5)

    def test_rejects_mismatch(self):
        rng = np.random.default_rng(3)
        with pytest.raises(ValueError):
            matmul(rand(rng, 3, 4), rand(rng, 5, 6))

    def test_zero_blocks_contribute_nothing(self):
        # padded region must not leak into the result
        a = jnp.ones((3, 3), jnp.float32)
        b = jnp.ones((3, 3), jnp.float32)
        np.testing.assert_allclose(matmul(a, b), 3.0 * jnp.ones((3, 3)))


class TestFusedDense:
    @settings(max_examples=15, deadline=None)
    @given(m=dims, k=dims, n=dims, seed=st.integers(0, 2**31 - 1),
           act=st.sampled_from(["relu", "none"]))
    def test_matches_ref(self, m, k, n, seed, act):
        rng = np.random.default_rng(seed)
        a, b, bias = rand(rng, m, k), rand(rng, k, n), rand(rng, n)
        np.testing.assert_allclose(
            matmul_bias_act(a, b, bias, act),
            ref.matmul_bias_act_ref(a, b, bias, act),
            rtol=2e-5,
            atol=2e-5,
        )

    def test_relu_clamps(self):
        a = -jnp.ones((4, 4), jnp.float32)
        b = jnp.ones((4, 2), jnp.float32)
        bias = jnp.zeros((2,), jnp.float32)
        assert float(jnp.max(matmul_bias_act(a, b, bias, "relu"))) == 0.0


class TestSgd:
    @settings(max_examples=25, deadline=None)
    @given(n=st.integers(1, 300_000), seed=st.integers(0, 2**31 - 1),
           lr=st.floats(1e-5, 1.0))
    def test_matches_ref(self, n, seed, lr):
        rng = np.random.default_rng(seed)
        p, g = rand(rng, n), rand(rng, n)
        np.testing.assert_allclose(
            sgd_update(p, g, lr),
            ref.sgd_ref(p, g, jnp.float32(lr)),
            rtol=1e-6,
            atol=1e-6,
        )

    def test_zero_lr_identity(self):
        rng = np.random.default_rng(5)
        p, g = rand(rng, 1000), rand(rng, 1000)
        np.testing.assert_allclose(sgd_update(p, g, 0.0), p)

    def test_rejects_mismatched(self):
        rng = np.random.default_rng(6)
        with pytest.raises(ValueError):
            sgd_update(rand(rng, 3), rand(rng, 4), 0.1)

    @settings(max_examples=10, deadline=None)
    @given(n=st.integers(1, 100_000), seed=st.integers(0, 2**31 - 1),
           beta=st.floats(0.0, 0.999))
    def test_momentum_matches_ref(self, n, seed, beta):
        rng = np.random.default_rng(seed)
        p, g, m = rand(rng, n), rand(rng, n), rand(rng, n)
        po, mo = sgd_momentum_update(p, g, m, 0.01, beta)
        pr, mr = ref.sgd_momentum_ref(p, g, m, jnp.float32(0.01), beta)
        np.testing.assert_allclose(po, pr, rtol=1e-6, atol=1e-6)
        np.testing.assert_allclose(mo, mr, rtol=1e-6, atol=1e-6)


class TestMaskedLoss:
    @settings(max_examples=20, deadline=None)
    @given(b=st.integers(1, 64), c=st.integers(2, 20),
           seed=st.integers(0, 2**31 - 1))
    def test_mask_invariance(self, b, c, seed):
        """Zero-mask rows must not change loss regardless of their content."""
        rng = np.random.default_rng(seed)
        logits = rand(rng, b, c)
        y = jnp.asarray(rng.integers(0, c, b), jnp.int32)
        mask = jnp.asarray(rng.integers(0, 2, b), jnp.float32)
        mask = mask.at[0].set(1.0)  # keep >= 1 live row
        l1, c1 = ref.masked_softmax_xent_ref(logits, y, mask)
        corrupted = logits + 1000.0 * (1.0 - mask)[:, None]
        l2, c2 = ref.masked_softmax_xent_ref(corrupted, y, mask)
        np.testing.assert_allclose(l1, l2, rtol=1e-5)
        np.testing.assert_allclose(c1, c2)

    def test_uniform_logits_loss_is_log_c(self):
        c = 10
        logits = jnp.zeros((8, c), jnp.float32)
        y = jnp.zeros((8,), jnp.int32)
        w = jnp.ones((8,), jnp.float32)
        loss, _ = ref.masked_softmax_xent_ref(logits, y, w)
        np.testing.assert_allclose(loss, np.log(c), rtol=1e-6)
