"""L2 correctness: model zoo shapes, gradients and the AOT entry points."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import model as M
from compile.kernels.ref import masked_softmax_xent_ref


def batch(spec, b, seed=0):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((b, spec.input_dim)), jnp.float32)
    y = jnp.asarray(rng.integers(0, spec.classes, b), jnp.int32)
    w = jnp.ones((b,), jnp.float32)
    return x, y, w


@pytest.fixture(scope="module", params=sorted(M.MODELS))
def spec(request):
    return M.get_model(request.param, input_dim=48, classes=5)


class TestParamSpec:
    def test_total_matches_unflatten(self, spec):
        flat = M.init_params(spec, 0)
        assert flat.shape == (spec.params.total,)
        parts = spec.params.unflatten(flat)
        assert sum(int(np.prod(v.shape)) for v in parts.values()) == spec.params.total

    def test_unflatten_roundtrip_values(self, spec):
        flat = jnp.arange(spec.params.total, dtype=jnp.float32)
        parts = spec.params.unflatten(flat)
        rebuilt = jnp.concatenate([parts[n].ravel() for n, _ in spec.params.entries])
        np.testing.assert_array_equal(rebuilt, flat)

    def test_init_deterministic(self, spec):
        a = M.init_params(spec, 3)
        b = M.init_params(spec, 3)
        np.testing.assert_array_equal(a, b)
        c = M.init_params(spec, 4)
        assert not np.array_equal(np.asarray(a), np.asarray(c))


class TestForward:
    @settings(max_examples=8, deadline=None)
    @given(b=st.integers(1, 16))
    def test_logit_shape(self, spec, b):
        flat = M.init_params(spec, 0)
        x, _, _ = batch(spec, b)
        logits = spec.forward(spec.params.unflatten(flat), x)
        assert logits.shape == (b, spec.classes)
        assert bool(jnp.all(jnp.isfinite(logits)))

    def test_batch_rows_independent(self, spec):
        """Row i's logits must not depend on other rows."""
        flat = M.init_params(spec, 1)
        x, _, _ = batch(spec, 4, seed=2)
        full = spec.forward(spec.params.unflatten(flat), x)
        row0 = spec.forward(spec.params.unflatten(flat), x[:1])
        np.testing.assert_allclose(full[:1], row0, rtol=1e-5, atol=1e-6)


class TestTrainStep:
    def test_grad_matches_pure_jnp(self, spec):
        """Pallas-kernel path vs jnp.matmul path — same gradients."""
        flat = M.init_params(spec, 0)
        x, y, w = batch(spec, 6, seed=3)

        def jnp_loss(f):
            p = spec.params.unflatten(f)
            # re-run forward with plain matmul by monkeypatching pdot
            h = _forward_plain(spec, p, x)
            return masked_softmax_xent_ref(h, y, w)[0]

        g_plain = jax.grad(jnp_loss)(flat)
        g_kernel, loss, correct = M.train_step(spec, flat, x, y, w)
        np.testing.assert_allclose(
            np.asarray(g_kernel), np.asarray(g_plain), rtol=5e-4, atol=5e-5
        )
        assert float(loss) > 0
        assert 0 <= float(correct) <= 6

    def test_masked_rows_do_not_contribute(self, spec):
        flat = M.init_params(spec, 1)
        x, y, _ = batch(spec, 4, seed=4)
        w = jnp.asarray([1, 1, 0, 0], jnp.float32)
        g1, l1, _ = M.train_step(spec, flat, x, y, w)
        x2 = x.at[2:].set(123.0)
        g2, l2, _ = M.train_step(spec, flat, x2, y, w)
        np.testing.assert_allclose(l1, l2, rtol=1e-6)
        np.testing.assert_allclose(g1, g2, rtol=1e-5, atol=1e-6)

    def test_apply_update_is_sgd(self, spec):
        flat = M.init_params(spec, 0)
        g = jnp.ones_like(flat) * 0.5
        (out,) = M.apply_update(flat, g, jnp.float32(0.2))
        np.testing.assert_allclose(out, flat - 0.1, rtol=1e-6, atol=1e-7)

    def test_sgd_loop_learns(self, spec):
        flat = M.init_params(spec, 0)
        x, y, w = batch(spec, 16, seed=5)
        _, l0, _ = M.train_step(spec, flat, x, y, w)
        step = jax.jit(lambda f: M.train_step(spec, f, x, y, w))
        for _ in range(30):
            g, _, _ = step(flat)
            (flat,) = M.apply_update(flat, g, jnp.float32(0.5))
        _, l1, _ = M.train_step(spec, flat, x, y, w)
        assert float(l1) < 0.5 * float(l0), f"{l0} -> {l1}"


def _forward_plain(spec, p, x):
    """Forward with jnp.matmul instead of the Pallas kernel (oracle path)."""
    name = spec.name
    if name == "mini_dense":
        feats = [x]
        i = 0
        while f"blk{i}_w" in p:
            h = jnp.concatenate(feats, axis=1) @ p[f"blk{i}_w"] + p[f"blk{i}_b"]
            feats.append(jnp.maximum(h, 0.0))
            i += 1
        return jnp.concatenate(feats, axis=1) @ p["head_w"] + p["head_b"]
    if name == "mini_res":
        h = jnp.maximum(x @ p["stem_w"] + p["stem_b"], 0.0)
        i = 0
        while f"res{i}a_w" in p:
            inner = jnp.maximum(h @ p[f"res{i}a_w"] + p[f"res{i}a_b"], 0.0)
            inner = inner @ p[f"res{i}b_w"] + p[f"res{i}b_b"]
            h = jnp.maximum(h + inner, 0.0)
            i += 1
        return h @ p["head_w"] + p["head_b"]
    if name == "mini_mobile":
        h = jnp.maximum(x @ p["stem_w"] + p["stem_b"], 0.0)
        i = 0
        while f"sep{i}_w" in p:
            dw = jnp.maximum(h * p[f"sep{i}_dw"], 0.0)
            h = jnp.maximum(dw @ p[f"sep{i}_w"] + p[f"sep{i}_b"], 0.0)
            i += 1
        return h @ p["head_w"] + p["head_b"]
    raise KeyError(name)


class TestEvaluate:
    def test_eval_equals_trainstep_loss(self, spec):
        flat = M.init_params(spec, 0)
        x, y, w = batch(spec, 8, seed=6)
        loss_e, correct_e = M.evaluate(spec, flat, x, y)
        _, loss_t, correct_t = M.train_step(spec, flat, x, y, w)
        np.testing.assert_allclose(loss_e, loss_t, rtol=1e-6)
        np.testing.assert_allclose(correct_e, correct_t)


class TestRegistry:
    def test_get_model_unknown(self):
        with pytest.raises(KeyError):
            M.get_model("resnet50")

    def test_all_models_distinct_layouts(self):
        names = sorted(M.MODELS)
        totals = {n: M.get_model(n).params.total for n in names}
        assert len(set(totals.values())) == len(names), totals
