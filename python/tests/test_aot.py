"""AOT pipeline tests: HLO text is parseable, manifest is consistent, and
the emitted entry points have the contracted signatures."""

import json
import os

import jax
import numpy as np
import pytest

from compile import aot
from compile import model as M


@pytest.fixture(scope="module")
def outdir(tmp_path_factory):
    d = tmp_path_factory.mktemp("artifacts")
    aot.main([
        "--out", str(d), "--models", "mini_res", "--buckets", "1,4",
        "--input-dim", "24", "--classes", "3", "--eval-batch", "8",
    ])
    return str(d)


def test_manifest_structure(outdir):
    man = json.load(open(os.path.join(outdir, "manifest.json")))
    assert man["input_dim"] == 24
    assert man["buckets"] == [1, 4]
    assert "mini_res" in man["models"]
    kinds = {}
    for a in man["artifacts"]:
        kinds.setdefault(a["kind"], []).append(a)
        assert os.path.exists(os.path.join(outdir, a["path"])), a["path"]
    assert len(kinds["train_step"]) == 2
    assert len(kinds["apply_update"]) == 1
    assert len(kinds["eval"]) == 1
    assert len(kinds["init"]) == 1


def test_layout_sums_to_params(outdir):
    man = json.load(open(os.path.join(outdir, "manifest.json")))
    for name, meta in man["models"].items():
        total = sum(int(np.prod(s)) for _, s in meta["layout"])
        assert total == meta["params"], name


def test_init_bin_size_and_values(outdir):
    man = json.load(open(os.path.join(outdir, "manifest.json")))
    p = man["models"]["mini_res"]["params"]
    raw = np.fromfile(os.path.join(outdir, "init_mini_res.f32.bin"), dtype="<f4")
    assert raw.size == p
    spec = M.get_model("mini_res", input_dim=24, classes=3)
    np.testing.assert_array_equal(raw, np.asarray(M.init_params(spec, 0)))


def test_hlo_text_is_hlo(outdir):
    text = open(os.path.join(outdir, "train_step_mini_res_b4.hlo.txt")).read()
    assert text.startswith("HloModule"), text[:80]
    assert "ENTRY" in text
    # the contract: 4 params in, 3-tuple out
    assert text.count("parameter(0)") >= 1
    assert text.count("parameter(3)") >= 1
    assert "parameter(4)" not in text


def test_hlo_executes_via_python_client(outdir):
    """Round-trip sanity inside python: parse+run the HLO with jax's own
    CPU client and compare against directly calling train_step."""
    from jax._src.lib import xla_client as xc

    spec = M.get_model("mini_res", input_dim=24, classes=3)
    flat = M.init_params(spec, 0)
    rng = np.random.default_rng(0)
    x = np.asarray(rng.standard_normal((4, 24)), np.float32)
    y = np.asarray(rng.integers(0, 3, 4), np.int32)
    w = np.ones((4,), np.float32)

    direct = M.train_step(spec, flat, x, y, w)
    backend = jax.extend.backend.get_backend("cpu")
    text = open(os.path.join(outdir, "train_step_mini_res_b4.hlo.txt")).read()
    comp = xc._xla.mlir.hlo_text_to_xla_computation if False else None
    # Execute the same computation through jax.jit instead (the rust-side
    # execution path is covered by rust/tests/integration_runtime.rs).
    del backend, comp, text
    g, loss, correct = direct
    assert g.shape == flat.shape
    assert float(loss) > 0
    assert 0 <= float(correct) <= 4


def test_rerun_is_deterministic(outdir, tmp_path):
    d2 = tmp_path / "again"
    aot.main([
        "--out", str(d2), "--models", "mini_res", "--buckets", "1,4",
        "--input-dim", "24", "--classes", "3", "--eval-batch", "8",
    ])
    a = open(os.path.join(outdir, "train_step_mini_res_b1.hlo.txt")).read()
    b = open(d2 / "train_step_mini_res_b1.hlo.txt").read()
    assert a == b
