"""L1 Pallas kernel: fused SGD parameter update over the flat f32[P] vector.

The update `p <- p - lr * g` is memory-bound; on TPU the win is streaming
both vectors through VMEM once in VPU-aligned 1-D blocks (multiples of
8*128 lanes) instead of materializing `lr * g`. Block size 65536 f32 =
256 KiB/operand keeps three operands (< 1 MiB) comfortably in VMEM with
double-buffering headroom.

Interpret=True for CPU-PJRT execution, as everywhere in this repo.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK = 65536  # f32 elems per grid step; 8*128-lane aligned (65536 = 64*1024)


def _sgd_kernel(lr_ref, p_ref, g_ref, o_ref):
    o_ref[...] = p_ref[...] - lr_ref[0] * g_ref[...]


@jax.jit
def sgd_update(p: jnp.ndarray, g: jnp.ndarray, lr: jnp.ndarray) -> jnp.ndarray:
    """p - lr * g over 1-D f32 vectors of any length (zero-padded to BLOCK)."""
    if p.shape != g.shape or p.ndim != 1:
        raise ValueError(f"sgd_update wants matching 1-D shapes, got {p.shape} {g.shape}")
    n = p.shape[0]
    block = min(BLOCK, max(256, 1 << (n - 1).bit_length())) if n > 0 else 256
    npad = pl.cdiv(n, block) * block
    p_p = jnp.pad(p.astype(jnp.float32), (0, npad - n))
    g_p = jnp.pad(g.astype(jnp.float32), (0, npad - n))
    lr_arr = jnp.asarray(lr, dtype=jnp.float32).reshape((1,))

    out = pl.pallas_call(
        _sgd_kernel,
        grid=(npad // block,),
        in_specs=[
            pl.BlockSpec((1,), lambda i: (0,)),  # lr broadcast to every block
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((npad,), jnp.float32),
        interpret=True,
    )(lr_arr, p_p, g_p)
    return out[:n]


def _sgd_momentum_kernel(lrb_ref, p_ref, g_ref, m_ref, po_ref, mo_ref):
    lr = lrb_ref[0]
    beta = lrb_ref[1]
    m_new = beta * m_ref[...] + g_ref[...]
    mo_ref[...] = m_new
    po_ref[...] = p_ref[...] - lr * m_new


@functools.partial(jax.jit, static_argnames=("beta",))
def sgd_momentum_update(
    p: jnp.ndarray, g: jnp.ndarray, m: jnp.ndarray, lr: jnp.ndarray, beta: float
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Momentum SGD: returns (p', m') with m' = beta*m + g, p' = p - lr*m'."""
    if not (p.shape == g.shape == m.shape) or p.ndim != 1:
        raise ValueError("sgd_momentum_update wants matching 1-D shapes")
    n = p.shape[0]
    block = min(BLOCK, max(256, 1 << (n - 1).bit_length())) if n > 0 else 256
    npad = pl.cdiv(n, block) * block
    pad = lambda x: jnp.pad(x.astype(jnp.float32), (0, npad - n))
    lrb = jnp.stack(
        [jnp.asarray(lr, jnp.float32), jnp.asarray(beta, jnp.float32)]
    ).reshape((2,))

    p_o, m_o = pl.pallas_call(
        _sgd_momentum_kernel,
        grid=(npad // block,),
        in_specs=[
            pl.BlockSpec((2,), lambda i: (0,)),
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((npad,), jnp.float32),
            jax.ShapeDtypeStruct((npad,), jnp.float32),
        ],
        interpret=True,
    )(lrb, pad(p), pad(g), pad(m))
    return p_o[:n], m_o[:n]
