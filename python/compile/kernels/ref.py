"""Pure-jnp reference oracles for the Pallas kernels (L1 correctness signal).

Every kernel in this package has a counterpart here with identical
signature and semantics. pytest (python/tests/test_kernel.py) sweeps
shapes/dtypes with hypothesis and asserts allclose between kernel and
oracle; the kernels are only trusted through that gate.
"""

from __future__ import annotations

import jax.numpy as jnp


def matmul_ref(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """C = A @ B in f32 accumulation regardless of input dtype."""
    return jnp.matmul(a.astype(jnp.float32), b.astype(jnp.float32))


def matmul_bias_act_ref(
    a: jnp.ndarray, b: jnp.ndarray, bias: jnp.ndarray, act: str = "relu"
) -> jnp.ndarray:
    """Fused dense layer: act(A @ B + bias)."""
    c = matmul_ref(a, b) + bias[None, :]
    if act == "relu":
        return jnp.maximum(c, 0.0)
    if act == "none":
        return c
    raise ValueError(f"unknown act {act!r}")


def sgd_ref(p: jnp.ndarray, g: jnp.ndarray, lr: jnp.ndarray) -> jnp.ndarray:
    """Plain SGD step p - lr * g (lr is a scalar array)."""
    return p - lr * g


def sgd_momentum_ref(
    p: jnp.ndarray, g: jnp.ndarray, m: jnp.ndarray, lr: jnp.ndarray, beta: float
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Momentum SGD: m' = beta*m + g; p' = p - lr*m'."""
    m_new = beta * m + g
    return p - lr * m_new, m_new


def masked_softmax_xent_ref(
    logits: jnp.ndarray, labels: jnp.ndarray, mask: jnp.ndarray
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Mean masked softmax cross-entropy and correct-count.

    logits: f32[B, C]; labels: i32[B]; mask: f32[B] of 0/1.
    Returns (scalar mean loss over mask, scalar correct count over mask).
    Rows with mask 0 contribute nothing; the mean divides by sum(mask)
    clamped to >= 1 (callers guarantee at least one live row).
    """
    logits = logits.astype(jnp.float32)
    zmax = jnp.max(logits, axis=-1, keepdims=True)
    z = logits - zmax
    logsumexp = jnp.log(jnp.sum(jnp.exp(z), axis=-1))
    ll = jnp.take_along_axis(z, labels[:, None].astype(jnp.int32), axis=-1)[:, 0]
    per_row = logsumexp - ll
    denom = jnp.maximum(jnp.sum(mask), 1.0)
    loss = jnp.sum(per_row * mask) / denom
    pred = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    correct = jnp.sum((pred == labels.astype(jnp.int32)).astype(jnp.float32) * mask)
    return loss, correct
