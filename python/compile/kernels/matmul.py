"""L1 Pallas kernel: tiled matmul — the dense-layer compute hot-spot.

TPU mapping (DESIGN.md §8): the grid walks (M/bm, N/bn, K/bk); each step
stages an (bm, bk) tile of A and a (bk, bn) tile of B from HBM into VMEM
via BlockSpec and accumulates the partial product into the (bm, bn) output
tile, which Pallas keeps resident in VMEM across the K-loop (the innermost
grid axis revisits the same output block). Block sizes default to the
MXU-native 128 and shrink to the largest power of two dividing the padded
dimension for small models.

Runs under interpret=True everywhere in this repo: the CPU PJRT plugin
cannot execute Mosaic custom-calls, and correctness is the build-time
contract (pytest vs ref.py). Real-TPU efficiency is *estimated* in
DESIGN.md from the VMEM footprint and tile alignment, never from
interpret-mode wallclock.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _pick_block(dim: int, target: int = 128) -> int:
    """Largest power of two <= target that keeps the grid sane for `dim`.

    For dims >= target return target (MXU-native). For smaller dims return
    the next power of two >= dim so the whole dim fits in one block.
    """
    if dim >= target:
        return target
    b = 1
    while b < dim:
        b *= 2
    return b


def _matmul_kernel(a_ref, b_ref, o_ref):
    """One grid step: o += A_tile @ B_tile.

    The output BlockSpec's index map ignores the K grid axis, so Pallas
    keeps the same (bm, bn) output tile resident in VMEM across the whole
    K loop — the accumulator lives in the output block itself.
    """
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        a_ref[...], b_ref[...], preferred_element_type=jnp.float32
    )


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk"))
def matmul(
    a: jnp.ndarray,
    b: jnp.ndarray,
    *,
    bm: int | None = None,
    bn: int | None = None,
    bk: int | None = None,
) -> jnp.ndarray:
    """C[M, N] = A[M, K] @ B[K, N] with f32 accumulation.

    Shapes need not be multiples of the block sizes: inputs are
    zero-padded up to the block grid (zero rows/cols contribute nothing
    to the product) and the result is sliced back.
    """
    if a.ndim != 2 or b.ndim != 2 or a.shape[1] != b.shape[0]:
        raise ValueError(f"matmul shape mismatch: {a.shape} @ {b.shape}")
    m, k = a.shape
    _, n = b.shape
    bm = bm or _pick_block(m)
    bn = bn or _pick_block(n)
    bk = bk or _pick_block(k)

    mp = pl.cdiv(m, bm) * bm
    np_ = pl.cdiv(n, bn) * bn
    kp = pl.cdiv(k, bk) * bk
    a_p = jnp.pad(a.astype(jnp.float32), ((0, mp - m), (0, kp - k)))
    b_p = jnp.pad(b.astype(jnp.float32), ((0, kp - k), (0, np_ - n)))
    n_k = kp // bk

    out = pl.pallas_call(
        _matmul_kernel,
        grid=(mp // bm, np_ // bn, n_k),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=True,
    )(a_p, b_p)
    return out[:m, :n]


def matmul_bias_act(
    a: jnp.ndarray, b: jnp.ndarray, bias: jnp.ndarray, act: str = "relu"
) -> jnp.ndarray:
    """Fused dense layer act(A @ B + bias) built on the Pallas matmul.

    The bias-add + activation epilogue stays in XLA (it fuses into the
    matmul output in the lowered HLO); the MXU-shaped contraction is the
    Pallas kernel.
    """
    c = matmul(a, b) + bias[None, :].astype(jnp.float32)
    if act == "relu":
        return jnp.maximum(c, 0.0)
    if act == "none":
        return c
    raise ValueError(f"unknown act {act!r}")
