"""AOT pipeline: lower every (model, batch bucket) entry point to HLO TEXT.

HLO *text* (never ``lowered.compile().serialize()``) is the interchange
format: jax >= 0.5 emits HloModuleProtos with 64-bit instruction ids which
the xla crate's xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``);
the text parser reassigns ids and round-trips cleanly. Lowered with
``return_tuple=True``; the rust side unwraps with ``to_tuple()``.

Outputs (artifacts/):
  train_step_{model}_b{bucket}.hlo.txt   (params, x[b,D], y[b] i32, w[b]) ->
                                         (grads[P], loss[], correct[])
  apply_update_{model}.hlo.txt           (params, grads, lr[]) -> (params,)
  eval_{model}.hlo.txt                   (params, x[E,D], y[E] i32) -> (loss, correct)
  init_{model}.f32.bin                   raw little-endian f32[P] initial params
  manifest.json                          registry the rust runtime reads

Run via ``make artifacts`` (no-op when inputs are unchanged).
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os
import sys

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model as M

DEFAULT_BUCKETS = (1, 2, 4, 8, 16, 32, 64, 128)
DEFAULT_EVAL_BATCH = 256


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (see module docstring)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def lower_model(spec: M.ModelSpec, buckets, eval_batch, outdir, verbose=True):
    """Lower all entry points for one model; return manifest entries."""
    p_total = spec.params.total
    d = spec.input_dim
    entries = []

    def emit(name, lowered, extra):
        text = to_hlo_text(lowered)
        path = f"{name}.hlo.txt"
        with open(os.path.join(outdir, path), "w") as f:
            f.write(text)
        ent = {
            "name": name,
            "path": path,
            "model": spec.name,
            "params": p_total,
            "input_dim": d,
            "classes": spec.classes,
            "sha256": hashlib.sha256(text.encode()).hexdigest()[:16],
            **extra,
        }
        entries.append(ent)
        if verbose:
            print(f"  {path}  ({len(text)} chars)", flush=True)

    for b in buckets:
        fn = lambda flat, x, y, w: M.train_step(spec, flat, x, y, w)
        lowered = jax.jit(fn).lower(
            _spec((p_total,)), _spec((b, d)), _spec((b,), jnp.int32), _spec((b,))
        )
        emit(f"train_step_{spec.name}_b{b}", lowered,
             {"kind": "train_step", "bucket": b})

    lowered = jax.jit(M.apply_update).lower(
        _spec((p_total,)), _spec((p_total,)), _spec((), jnp.float32)
    )
    emit(f"apply_update_{spec.name}", lowered, {"kind": "apply_update"})

    fn = lambda flat, x, y: M.evaluate(spec, flat, x, y)
    lowered = jax.jit(fn).lower(
        _spec((p_total,)), _spec((eval_batch, d)), _spec((eval_batch,), jnp.int32)
    )
    emit(f"eval_{spec.name}", lowered, {"kind": "eval", "bucket": eval_batch})

    # Deterministic initial parameters as raw f32 (little-endian) binary.
    flat = np.asarray(M.init_params(spec, seed=0), dtype="<f4")
    init_path = f"init_{spec.name}.f32.bin"
    flat.tofile(os.path.join(outdir, init_path))
    entries.append({
        "name": f"init_{spec.name}", "path": init_path, "model": spec.name,
        "kind": "init", "params": p_total, "input_dim": d,
        "classes": spec.classes,
    })
    if verbose:
        print(f"  {init_path}  ({flat.size} f32)", flush=True)
    return entries


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="output directory")
    ap.add_argument("--models", default="mini_dense,mini_res,mini_mobile")
    ap.add_argument("--buckets", default=",".join(map(str, DEFAULT_BUCKETS)))
    ap.add_argument("--input-dim", type=int, default=768)
    ap.add_argument("--classes", type=int, default=10)
    ap.add_argument("--eval-batch", type=int, default=DEFAULT_EVAL_BATCH)
    args = ap.parse_args(argv)

    outdir = args.out
    os.makedirs(outdir, exist_ok=True)
    buckets = tuple(int(b) for b in args.buckets.split(","))
    models = args.models.split(",")

    manifest = {
        "version": 1,
        "input_dim": args.input_dim,
        "classes": args.classes,
        "eval_batch": args.eval_batch,
        "buckets": list(buckets),
        "models": {},
        "artifacts": [],
    }
    for name in models:
        spec = M.get_model(name, input_dim=args.input_dim, classes=args.classes)
        print(f"lowering {name} (P={spec.params.total}) ...", flush=True)
        entries = lower_model(spec, buckets, args.eval_batch, outdir)
        manifest["models"][name] = {
            "params": spec.params.total,
            "layout": [[n, list(s)] for n, s in spec.params.entries],
        }
        manifest["artifacts"].extend(entries)

    with open(os.path.join(outdir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"wrote {len(manifest['artifacts'])} artifacts + manifest.json "
          f"to {outdir}", flush=True)


if __name__ == "__main__":
    sys.exit(main())
