"""L2: JAX model zoo for the FEEL reproduction (build-time only).

The paper trains DenseNet121 / ResNet18 / MobileNetV2 on CIFAR-10. We build
three stand-ins of the same architectural *families* (DESIGN.md §3), sized
so CPU-PJRT sustains hundreds of federated training periods:

  mini_dense  — DenseNet-style: each block consumes the concatenation of
                all previous feature maps (dense connectivity).
  mini_res    — ResNet-style: identity-skip two-layer residual blocks.
  mini_mobile — MobileNet-style: depthwise (per-feature scale) followed by
                pointwise dense, i.e. a separable linear layer.

Interchange contract with the rust runtime (DESIGN.md §2):
  * parameters are ONE flat f32[P] vector (ParamSpec defines the layout);
  * train_step(params, x[b,D], y[b] i32, w[b]) -> (grads[P], loss, correct)
    where w is a 0/1 mask enabling padded pow-2 batch buckets;
  * apply_update(params, grads, lr) -> (params,) via the L1 sgd kernel;
  * evaluate(params, x[E,D], y[E]) -> (loss, correct).

All dense contractions route through the L1 Pallas matmul kernel wrapped in
a custom_vjp so the backward pass also runs on the kernel.
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp

from .kernels.matmul import matmul
from .kernels.ref import masked_softmax_xent_ref
from .kernels.sgd import sgd_update

# ---------------------------------------------------------------------------
# Pallas-backed dense primitive with a custom VJP (grad through pallas_call
# is undefined; fwd AND bwd both execute on the L1 kernel).
# ---------------------------------------------------------------------------


@jax.custom_vjp
def pdot(x: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    return matmul(x, w)


def _pdot_fwd(x, w):
    return matmul(x, w), (x, w)


def _pdot_bwd(res, dy):
    x, w = res
    dx = matmul(dy, w.T)
    dw = matmul(x.T, dy)
    return dx, dw


pdot.defvjp(_pdot_fwd, _pdot_bwd)

# ---------------------------------------------------------------------------
# Flat-parameter plumbing
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    """Layout of the flat f32[P] parameter vector: ordered (name, shape)."""

    entries: tuple[tuple[str, tuple[int, ...]], ...]

    @property
    def total(self) -> int:
        n = 0
        for _, shape in self.entries:
            size = 1
            for d in shape:
                size *= d
            n += size
        return n

    def unflatten(self, flat: jnp.ndarray) -> dict[str, jnp.ndarray]:
        out = {}
        off = 0
        for name, shape in self.entries:
            size = 1
            for d in shape:
                size *= d
            out[name] = flat[off : off + size].reshape(shape)
            off += size
        return out


@dataclasses.dataclass(frozen=True)
class ModelSpec:
    """A model variant: its parameter layout and its forward function."""

    name: str
    input_dim: int
    classes: int
    params: ParamSpec
    forward: Callable[[dict[str, jnp.ndarray], jnp.ndarray], jnp.ndarray]


def _glorot(key, shape):
    fan_in, fan_out = shape[0], shape[-1]
    scale = jnp.sqrt(2.0 / (fan_in + fan_out))
    return scale * jax.random.normal(key, shape, dtype=jnp.float32)


def init_params(spec: ModelSpec, seed: int) -> jnp.ndarray:
    """Deterministic flat initialization (glorot weights, zero biases,
    unit depthwise scales)."""
    key = jax.random.PRNGKey(seed)
    chunks = []
    for name, shape in spec.params.entries:
        key, sub = jax.random.split(key)
        if name.endswith("_b"):
            chunks.append(jnp.zeros(shape, jnp.float32).ravel())
        elif name.endswith("_dw"):
            chunks.append(jnp.ones(shape, jnp.float32).ravel())
        else:
            chunks.append(_glorot(sub, shape).ravel())
    return jnp.concatenate(chunks)


# ---------------------------------------------------------------------------
# Model family definitions
# ---------------------------------------------------------------------------


def _dense_layer(p, name, x, act="relu"):
    h = pdot(x, p[f"{name}_w"]) + p[f"{name}_b"][None, :]
    return jnp.maximum(h, 0.0) if act == "relu" else h


def mini_dense(input_dim: int = 768, classes: int = 10, growth: int = 192,
               blocks: int = 3) -> ModelSpec:
    """DenseNet-style: block i maps concat(x, h_1..h_{i-1}) -> growth feats."""
    entries = []
    width = input_dim
    for i in range(blocks):
        entries.append((f"blk{i}_w", (width, growth)))
        entries.append((f"blk{i}_b", (growth,)))
        width += growth
    entries.append(("head_w", (width, classes)))
    entries.append(("head_b", (classes,)))
    spec = ParamSpec(tuple(entries))

    def forward(p, x):
        feats = [x]
        for i in range(blocks):
            h = _dense_layer(p, f"blk{i}", jnp.concatenate(feats, axis=1))
            feats.append(h)
        return _dense_layer(p, "head", jnp.concatenate(feats, axis=1), act="none")

    return ModelSpec("mini_dense", input_dim, classes, spec, forward)


def mini_res(input_dim: int = 768, classes: int = 10, width: int = 256,
             blocks: int = 3) -> ModelSpec:
    """ResNet-style: stem then identity-skip two-layer residual blocks."""
    entries = [("stem_w", (input_dim, width)), ("stem_b", (width,))]
    for i in range(blocks):
        entries.append((f"res{i}a_w", (width, width)))
        entries.append((f"res{i}a_b", (width,)))
        entries.append((f"res{i}b_w", (width, width)))
        entries.append((f"res{i}b_b", (width,)))
    entries.append(("head_w", (width, classes)))
    entries.append(("head_b", (classes,)))
    spec = ParamSpec(tuple(entries))

    def forward(p, x):
        h = _dense_layer(p, "stem", x)
        for i in range(blocks):
            inner = _dense_layer(p, f"res{i}a", h)
            inner = _dense_layer(p, f"res{i}b", inner, act="none")
            h = jnp.maximum(h + inner, 0.0)
        return _dense_layer(p, "head", h, act="none")

    return ModelSpec("mini_res", input_dim, classes, spec, forward)


def mini_mobile(input_dim: int = 768, classes: int = 10, width: int = 384,
                blocks: int = 3) -> ModelSpec:
    """MobileNet-style: separable layers = depthwise scale + pointwise dense."""
    entries = [("stem_w", (input_dim, width)), ("stem_b", (width,))]
    for i in range(blocks):
        entries.append((f"sep{i}_dw", (width,)))  # depthwise per-feature scale
        entries.append((f"sep{i}_w", (width, width)))  # pointwise
        entries.append((f"sep{i}_b", (width,)))
    entries.append(("head_w", (width, classes)))
    entries.append(("head_b", (classes,)))
    spec = ParamSpec(tuple(entries))

    def forward(p, x):
        h = _dense_layer(p, "stem", x)
        for i in range(blocks):
            dw = jnp.maximum(h * p[f"sep{i}_dw"][None, :], 0.0)
            h = _dense_layer(p, f"sep{i}", dw)
        return _dense_layer(p, "head", h, act="none")

    return ModelSpec("mini_mobile", input_dim, classes, spec, forward)


MODELS: dict[str, Callable[..., ModelSpec]] = {
    "mini_dense": mini_dense,
    "mini_res": mini_res,
    "mini_mobile": mini_mobile,
}


def get_model(name: str, input_dim: int = 768, classes: int = 10) -> ModelSpec:
    if name not in MODELS:
        raise KeyError(f"unknown model {name!r}; have {sorted(MODELS)}")
    return MODELS[name](input_dim=input_dim, classes=classes)


# ---------------------------------------------------------------------------
# The three AOT entry points (lowered per model / batch bucket by aot.py)
# ---------------------------------------------------------------------------


def loss_fn(spec: ModelSpec, flat: jnp.ndarray, x: jnp.ndarray,
            y: jnp.ndarray, w: jnp.ndarray):
    """Masked mean CE loss + correct count over one (padded) batch."""
    p = spec.params.unflatten(flat)
    logits = spec.forward(p, x)
    return masked_softmax_xent_ref(logits, y, w)


def train_step(spec: ModelSpec, flat: jnp.ndarray, x: jnp.ndarray,
               y: jnp.ndarray, w: jnp.ndarray):
    """(grads[P], loss[], correct[]) for one masked mini-batch."""

    def scalar_loss(f):
        loss, correct = loss_fn(spec, f, x, y, w)
        return loss, correct

    (loss, correct), grads = jax.value_and_grad(scalar_loss, has_aux=True)(flat)
    return grads, loss, correct


def apply_update(flat: jnp.ndarray, grads: jnp.ndarray, lr: jnp.ndarray):
    """One SGD step on the flat parameter vector, via the L1 sgd kernel."""
    return (sgd_update(flat, grads, lr),)


def evaluate(spec: ModelSpec, flat: jnp.ndarray, x: jnp.ndarray, y: jnp.ndarray):
    """(mean loss, correct count) over a fixed eval batch (no mask)."""
    w = jnp.ones((x.shape[0],), jnp.float32)
    loss, correct = loss_fn(spec, flat, x, y, w)
    return loss, correct
