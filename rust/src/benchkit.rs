//! Criterion-lite bench harness (criterion is unavailable offline).
//!
//! `cargo bench` runs `[[bench]] harness = false` binaries; each calls
//! `Bench::new(...)` and registers closures with `bench()`. We do warmup,
//! adaptive iteration counts targeting a fixed measurement window, and
//! report mean / p50 / p95 / throughput — enough to drive the §Perf loop
//! and regenerate the paper-table harnesses.

use std::time::{Duration, Instant};

use crate::util::stats::quantile;

/// One benchmark's measurements.
#[derive(Clone, Debug)]
pub struct Measurement {
    pub name: String,
    pub iters: usize,
    pub mean_ns: f64,
    pub p50_ns: f64,
    pub p95_ns: f64,
    pub min_ns: f64,
}

impl Measurement {
    pub fn per_sec(&self) -> f64 {
        1e9 / self.mean_ns
    }
}

/// Bench runner.
pub struct Bench {
    pub suite: String,
    /// target measurement window per bench
    pub window: Duration,
    pub warmup: Duration,
    pub results: Vec<Measurement>,
    filter: Option<String>,
}

impl Bench {
    pub fn new(suite: &str) -> Self {
        // honor `cargo bench -- <filter>`
        let filter = std::env::args().skip(1).find(|a| !a.starts_with('-'));
        let quick = std::env::var("FEEL_BENCH_QUICK").is_ok();
        Bench {
            suite: suite.to_string(),
            window: if quick { Duration::from_millis(150) } else { Duration::from_millis(800) },
            warmup: if quick { Duration::from_millis(30) } else { Duration::from_millis(150) },
            results: Vec::new(),
            filter,
        }
    }

    /// Run one benchmark; `f` is a single iteration returning a value to
    /// keep the optimizer honest (use `std::hint::black_box` inside too).
    pub fn bench<F: FnMut()>(&mut self, name: &str, mut f: F) {
        if let Some(filt) = &self.filter {
            if !name.contains(filt.as_str()) {
                return;
            }
        }
        // warmup + calibrate
        let t0 = Instant::now();
        let mut calib_iters = 0usize;
        while t0.elapsed() < self.warmup {
            f();
            calib_iters += 1;
        }
        let per_iter = self.warmup.as_secs_f64() / calib_iters.max(1) as f64;
        let samples = ((self.window.as_secs_f64() / per_iter) as usize).clamp(5, 10_000);

        let mut times = Vec::with_capacity(samples);
        for _ in 0..samples {
            let t = Instant::now();
            f();
            times.push(t.elapsed().as_nanos() as f64);
        }
        let mean = times.iter().sum::<f64>() / times.len() as f64;
        let m = Measurement {
            name: name.to_string(),
            iters: samples,
            mean_ns: mean,
            p50_ns: quantile(&times, 0.5),
            p95_ns: quantile(&times, 0.95),
            min_ns: times.iter().copied().fold(f64::INFINITY, f64::min),
        };
        println!(
            "{:<48} {:>12} {:>12} {:>12} {:>10}",
            format!("{}::{}", self.suite, m.name),
            fmt_ns(m.mean_ns),
            fmt_ns(m.p50_ns),
            fmt_ns(m.p95_ns),
            format!("n={}", m.iters),
        );
        self.results.push(m);
    }

    /// Print the suite header (call once before benches).
    pub fn header(&self) {
        println!(
            "\n== {} ==\n{:<48} {:>12} {:>12} {:>12} {:>10}",
            self.suite, "benchmark", "mean", "p50", "p95", "samples"
        );
    }
}

fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.0} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something() {
        std::env::set_var("FEEL_BENCH_QUICK", "1");
        let mut b = Bench::new("test");
        b.window = Duration::from_millis(20);
        b.warmup = Duration::from_millis(5);
        b.filter = None;
        let mut acc = 0u64;
        b.bench("spin", || {
            for i in 0..1000u64 {
                acc = acc.wrapping_add(std::hint::black_box(i));
            }
        });
        assert_eq!(b.results.len(), 1);
        assert!(b.results[0].mean_ns > 0.0);
        assert!(b.results[0].p95_ns >= b.results[0].p50_ns);
    }

    #[test]
    fn fmt_ranges() {
        assert!(fmt_ns(500.0).ends_with("ns"));
        assert!(fmt_ns(5e4).ends_with("µs"));
        assert!(fmt_ns(5e7).ends_with("ms"));
        assert!(fmt_ns(5e9).ends_with("s"));
    }
}
