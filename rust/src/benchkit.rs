//! Criterion-lite bench harness (criterion is unavailable offline).
//!
//! `cargo bench` runs `[[bench]] harness = false` binaries; each calls
//! `Bench::new(...)` and registers closures with `bench()`. We do warmup,
//! adaptive iteration counts targeting a fixed measurement window, and
//! report mean / p50 / p95 / throughput — enough to drive the §Perf loop
//! and regenerate the paper-table harnesses.

use std::collections::BTreeMap;
use std::time::{Duration, Instant};

use crate::util::json::Json;
use crate::util::stats::quantile;

/// One benchmark's measurements.
#[derive(Clone, Debug)]
pub struct Measurement {
    pub name: String,
    pub iters: usize,
    pub mean_ns: f64,
    pub p50_ns: f64,
    pub p95_ns: f64,
    pub min_ns: f64,
}

impl Measurement {
    pub fn per_sec(&self) -> f64 {
        1e9 / self.mean_ns
    }
}

/// Bench runner.
pub struct Bench {
    pub suite: String,
    /// target measurement window per bench
    pub window: Duration,
    pub warmup: Duration,
    pub results: Vec<Measurement>,
    filter: Option<String>,
}

impl Bench {
    pub fn new(suite: &str) -> Self {
        // honor `cargo bench -- <filter>`
        let filter = std::env::args().skip(1).find(|a| !a.starts_with('-'));
        let quick = std::env::var("FEEL_BENCH_QUICK").is_ok();
        Bench {
            suite: suite.to_string(),
            window: if quick { Duration::from_millis(150) } else { Duration::from_millis(800) },
            warmup: if quick { Duration::from_millis(30) } else { Duration::from_millis(150) },
            results: Vec::new(),
            filter,
        }
    }

    /// Run one benchmark; `f` is a single iteration returning a value to
    /// keep the optimizer honest (use `std::hint::black_box` inside too).
    pub fn bench<F: FnMut()>(&mut self, name: &str, mut f: F) {
        if let Some(filt) = &self.filter {
            if !name.contains(filt.as_str()) {
                return;
            }
        }
        // warmup + calibrate
        let t0 = Instant::now();
        let mut calib_iters = 0usize;
        while t0.elapsed() < self.warmup {
            f();
            calib_iters += 1;
        }
        let per_iter = self.warmup.as_secs_f64() / calib_iters.max(1) as f64;
        let samples = ((self.window.as_secs_f64() / per_iter) as usize).clamp(5, 10_000);

        let mut times = Vec::with_capacity(samples);
        for _ in 0..samples {
            let t = Instant::now();
            f();
            times.push(t.elapsed().as_nanos() as f64);
        }
        let mean = times.iter().sum::<f64>() / times.len() as f64;
        let m = Measurement {
            name: name.to_string(),
            iters: samples,
            mean_ns: mean,
            p50_ns: quantile(&times, 0.5),
            p95_ns: quantile(&times, 0.95),
            min_ns: times.iter().copied().fold(f64::INFINITY, f64::min),
        };
        println!(
            "{:<48} {:>12} {:>12} {:>12} {:>10}",
            format!("{}::{}", self.suite, m.name),
            fmt_ns(m.mean_ns),
            fmt_ns(m.p50_ns),
            fmt_ns(m.p95_ns),
            format!("n={}", m.iters),
        );
        self.results.push(m);
    }

    /// Print the suite header (call once before benches).
    pub fn header(&self) {
        println!(
            "\n== {} ==\n{:<48} {:>12} {:>12} {:>12} {:>10}",
            self.suite, "benchmark", "mean", "p50", "p95", "samples"
        );
    }
}

fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.0} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

// ---------------------------------------------------------------------------
// Perf-trajectory folding (`feel bench-merge`)
// ---------------------------------------------------------------------------

/// Classify a bench-JSON key as a score: `Some(true)` if higher is better
/// (speedups, throughput), `Some(false)` if lower is better (timings), `None`
/// for configuration fields that must never gate CI.
fn metric_direction(key: &str) -> Option<bool> {
    if key.starts_with("ms_per")
        || key.starts_with("sim_secs")
        || key.ends_with("_ms")
        || key.ends_with("_ns")
        || key.ends_with("_secs")
    {
        return Some(false);
    }
    if key.contains("speedup") || key.contains("gflops") || key.contains("per_sec") {
        return Some(true);
    }
    None
}

/// One headline metric extracted from a `BENCH_*.json` document.
#[derive(Clone, Debug, PartialEq)]
pub struct Headline {
    pub name: String,
    pub value: f64,
    pub higher_is_better: bool,
}

/// Extract headline metrics from one bench document: every top-level score
/// key, plus the best value of each score key across the `results` rows
/// (best = min for timings, max for speedups/throughput). Names are
/// `"{bench}.{key}"` and `"{bench}.best.{key}"`.
pub fn headline_metrics(doc: &Json) -> Vec<Headline> {
    let bench = doc.get("bench").and_then(Json::as_str).unwrap_or("unknown");
    let mut out = Vec::new();
    if let Some(map) = doc.as_obj() {
        for (k, v) in map {
            if let (Some(higher), Some(x)) = (metric_direction(k), v.as_f64()) {
                out.push(Headline {
                    name: format!("{bench}.{k}"),
                    value: x,
                    higher_is_better: higher,
                });
            }
        }
    }
    let mut best: BTreeMap<&str, (f64, bool)> = BTreeMap::new();
    for row in doc.get("results").and_then(Json::as_arr).unwrap_or(&[]) {
        let Some(map) = row.as_obj() else { continue };
        for (k, v) in map {
            if let (Some(higher), Some(x)) = (metric_direction(k), v.as_f64()) {
                let e = best.entry(k.as_str()).or_insert((x, higher));
                e.0 = if higher { e.0.max(x) } else { e.0.min(x) };
            }
        }
    }
    for (k, (x, higher)) in best {
        out.push(Headline {
            name: format!("{bench}.best.{k}"),
            value: x,
            higher_is_better: higher,
        });
    }
    out
}

/// Fold parsed per-bench documents into one `BENCH_trajectory.json` value.
/// `run` is a caller-supplied stamp (commit hash, CI run id) — never wall
/// clock — so the same inputs always fold to the same bytes.
pub fn merge_bench_artifacts(parts: &[Json], run: &str) -> Json {
    let mut benches = BTreeMap::new();
    let mut headline = BTreeMap::new();
    for doc in parts {
        let name = doc.get("bench").and_then(Json::as_str).unwrap_or("unknown");
        for h in headline_metrics(doc) {
            headline.insert(h.name, Json::Num(h.value));
        }
        benches.insert(name.to_string(), doc.clone());
    }
    let mut top = BTreeMap::new();
    top.insert("run".to_string(), Json::Str(run.to_string()));
    top.insert("benches".to_string(), Json::Obj(benches));
    top.insert("headline".to_string(), Json::Obj(headline));
    Json::Obj(top)
}

/// Outcome of comparing a trajectory against a committed baseline.
#[derive(Clone, Debug, Default)]
pub struct RegressionReport {
    /// >tolerance regressions — these should fail CI.
    pub failures: Vec<String>,
    /// Metrics present on only one side, or not comparable — informational.
    pub notes: Vec<String>,
}

/// Compare the `headline` maps of two trajectory documents. A metric
/// regresses when it moves more than `tolerance` (fraction, e.g. 0.25) in
/// its bad direction. Metrics missing from either side only produce notes —
/// the committed baseline may lag newly added benches.
pub fn check_regressions(baseline: &Json, current: &Json, tolerance: f64) -> RegressionReport {
    let empty = BTreeMap::new();
    let base = baseline.get("headline").and_then(Json::as_obj).unwrap_or(&empty);
    let cur = current.get("headline").and_then(Json::as_obj).unwrap_or(&empty);
    let mut rep = RegressionReport::default();
    for (name, bv) in base {
        let Some(b) = bv.as_f64() else { continue };
        let Some(c) = cur.get(name).and_then(Json::as_f64) else {
            rep.notes.push(format!("note: baseline metric {name} missing from current run"));
            continue;
        };
        let key = name.rsplit('.').next().unwrap_or(name);
        let Some(higher) = metric_direction(key) else { continue };
        if b <= 0.0 || !b.is_finite() || !c.is_finite() {
            rep.notes.push(format!("note: {name} not comparable (baseline {b}, current {c})"));
            continue;
        }
        let regressed = if higher { c < b * (1.0 - tolerance) } else { c > b * (1.0 + tolerance) };
        if regressed {
            rep.failures.push(format!(
                "regression: {name} = {c:.4} vs baseline {b:.4} ({} is better, tolerance {:.0}%)",
                if higher { "higher" } else { "lower" },
                tolerance * 100.0,
            ));
        }
    }
    for name in cur.keys() {
        if !base.contains_key(name) {
            rep.notes.push(format!("note: new metric {name} not in baseline"));
        }
    }
    rep
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something() {
        std::env::set_var("FEEL_BENCH_QUICK", "1");
        let mut b = Bench::new("test");
        b.window = Duration::from_millis(20);
        b.warmup = Duration::from_millis(5);
        b.filter = None;
        let mut acc = 0u64;
        b.bench("spin", || {
            for i in 0..1000u64 {
                acc = acc.wrapping_add(std::hint::black_box(i));
            }
        });
        assert_eq!(b.results.len(), 1);
        assert!(b.results[0].mean_ns > 0.0);
        assert!(b.results[0].p95_ns >= b.results[0].p50_ns);
    }

    #[test]
    fn fmt_ranges() {
        assert!(fmt_ns(500.0).ends_with("ns"));
        assert!(fmt_ns(5e4).ends_with("µs"));
        assert!(fmt_ns(5e7).ends_with("ms"));
        assert!(fmt_ns(5e9).ends_with("s"));
    }

    fn doc(src: &str) -> Json {
        Json::parse(src).unwrap()
    }

    #[test]
    fn headline_extraction_picks_scores_not_config() {
        let d = doc(
            r#"{"bench":"gemm","cores":8,"speedup_256_vs_ref":3.5,
                "results":[{"op":"a","k":256,"packed_ms":4.0,"gflops_serial":9.0},
                           {"op":"b","k":512,"packed_ms":2.0,"gflops_serial":7.0}]}"#,
        );
        let hs = headline_metrics(&d);
        let get = |n: &str| hs.iter().find(|h| h.name == n).cloned();
        let top = get("gemm.speedup_256_vs_ref").unwrap();
        assert!(top.higher_is_better);
        assert_eq!(top.value, 3.5);
        // best across rows: min for timings, max for throughput
        assert_eq!(get("gemm.best.packed_ms").unwrap().value, 2.0);
        assert_eq!(get("gemm.best.gflops_serial").unwrap().value, 9.0);
        // config fields (cores, k) never become headlines
        assert!(get("gemm.cores").is_none());
        assert!(get("gemm.best.k").is_none());
    }

    #[test]
    fn merge_is_deterministic_and_run_stamped() {
        let a = doc(r#"{"bench":"gemm","speedup_256_vs_ref":3.5,"results":[]}"#);
        let b = doc(r#"{"bench":"scale","results":[{"ms_per_round":5.0}]}"#);
        let t1 = merge_bench_artifacts(&[a.clone(), b.clone()], "run-1");
        let t2 = merge_bench_artifacts(&[a, b], "run-1");
        assert_eq!(t1.to_string(), t2.to_string());
        assert_eq!(t1.get("run").and_then(Json::as_str), Some("run-1"));
        let head = t1.get("headline").and_then(Json::as_obj).unwrap();
        assert!(head.contains_key("gemm.speedup_256_vs_ref"));
        assert!(head.contains_key("scale.best.ms_per_round"));
        assert!(t1.get("benches").and_then(|b| b.get("gemm")).is_some());
    }

    #[test]
    fn regression_check_respects_direction_and_tolerance() {
        let base = doc(
            r#"{"headline":{"gemm.best.packed_ms":4.0,"gemm.speedup_256_vs_ref":4.0,
                            "old.best.ms_per_round":1.0}}"#,
        );
        // 24% slower timing + 24% lower speedup: both inside 25% tolerance
        let ok = doc(
            r#"{"headline":{"gemm.best.packed_ms":4.96,"gemm.speedup_256_vs_ref":3.04,
                            "fresh.best.ms_per_round":2.0}}"#,
        );
        let rep = check_regressions(&base, &ok, 0.25);
        assert!(rep.failures.is_empty(), "{:?}", rep.failures);
        // missing + new metrics are notes, not failures
        assert_eq!(rep.notes.len(), 2, "{:?}", rep.notes);
        // 30% worse in each bad direction: both fail
        let bad = doc(
            r#"{"headline":{"gemm.best.packed_ms":5.2,"gemm.speedup_256_vs_ref":2.8,
                            "old.best.ms_per_round":1.0}}"#,
        );
        let rep = check_regressions(&base, &bad, 0.25);
        assert_eq!(rep.failures.len(), 2, "{:?}", rep.failures);
        assert!(rep.failures[0].contains("packed_ms"), "{:?}", rep.failures);
        // improvements never fail
        let better = doc(
            r#"{"headline":{"gemm.best.packed_ms":1.0,"gemm.speedup_256_vs_ref":9.0,
                            "old.best.ms_per_round":0.5}}"#,
        );
        assert!(check_regressions(&base, &better, 0.25).failures.is_empty());
    }
}
