//! Artifact manifest: the contract file `artifacts/manifest.json` written by
//! `python/compile/aot.py` and consumed here (DESIGN.md §2).

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::util::json::Json;

/// Kind of AOT artifact.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Kind {
    /// `(params, x[b,D], y[b], w[b]) -> (grads, loss, correct)`
    TrainStep,
    /// `(params, grads, lr) -> (params,)`
    ApplyUpdate,
    /// `(params, x[E,D], y[E]) -> (loss, correct)`
    Eval,
    /// raw f32 initial parameter vector (binary, not HLO)
    Init,
}

impl Kind {
    fn parse(s: &str) -> Result<Kind> {
        Ok(match s {
            "train_step" => Kind::TrainStep,
            "apply_update" => Kind::ApplyUpdate,
            "eval" => Kind::Eval,
            "init" => Kind::Init,
            other => bail!("unknown artifact kind {other:?}"),
        })
    }
}

/// One artifact entry.
#[derive(Clone, Debug)]
pub struct Artifact {
    pub name: String,
    pub path: PathBuf,
    pub model: String,
    pub kind: Kind,
    /// batch bucket for TrainStep, eval batch for Eval, 0 otherwise.
    pub bucket: usize,
    pub params: usize,
}

/// Per-model metadata.
#[derive(Clone, Debug)]
pub struct ModelMeta {
    pub name: String,
    pub params: usize,
    /// flat layout [(tensor name, shape)] — used by compression/telemetry.
    pub layout: Vec<(String, Vec<usize>)>,
}

/// Parsed manifest.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub input_dim: usize,
    pub classes: usize,
    pub eval_batch: usize,
    /// ascending train-step batch buckets (e.g. 1,2,4,...,128)
    pub buckets: Vec<usize>,
    pub models: BTreeMap<String, ModelMeta>,
    pub artifacts: Vec<Artifact>,
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        Self::parse(&text, dir)
    }

    pub fn parse(text: &str, dir: &Path) -> Result<Manifest> {
        let v = Json::parse(text).context("parsing manifest.json")?;
        let input_dim = req_usize(&v, "input_dim")?;
        let classes = req_usize(&v, "classes")?;
        let eval_batch = req_usize(&v, "eval_batch")?;
        let mut buckets: Vec<usize> = v
            .req("buckets")?
            .as_arr()
            .context("buckets not an array")?
            .iter()
            .map(|b| b.as_usize().context("bucket not an int"))
            .collect::<Result<_>>()?;
        buckets.sort_unstable();
        if buckets.is_empty() {
            bail!("manifest has no batch buckets");
        }

        let mut models = BTreeMap::new();
        for (name, m) in v.req("models")?.as_obj().context("models not an object")? {
            let params = req_usize(m, "params")?;
            let mut layout = Vec::new();
            for e in m.req("layout")?.as_arr().context("layout not an array")? {
                let pair = e.as_arr().context("layout entry")?;
                let tname = pair[0].as_str().context("layout name")?.to_string();
                let shape = pair[1]
                    .as_arr()
                    .context("layout shape")?
                    .iter()
                    .map(|d| d.as_usize().context("layout dim"))
                    .collect::<Result<Vec<_>>>()?;
                layout.push((tname, shape));
            }
            // sanity: layout sizes must add up to the flat param count
            let sum: usize = layout
                .iter()
                .map(|(_, s)| s.iter().product::<usize>())
                .sum();
            if sum != params {
                bail!("model {name}: layout sums to {sum}, params = {params}");
            }
            models.insert(
                name.clone(),
                ModelMeta { name: name.clone(), params, layout },
            );
        }

        let mut artifacts = Vec::new();
        for a in v.req("artifacts")?.as_arr().context("artifacts")? {
            let kind = Kind::parse(a.req("kind")?.as_str().context("kind")?)?;
            artifacts.push(Artifact {
                name: a.req("name")?.as_str().context("name")?.to_string(),
                path: dir.join(a.req("path")?.as_str().context("path")?),
                model: a.req("model")?.as_str().context("model")?.to_string(),
                kind,
                bucket: a.get("bucket").and_then(|b| b.as_usize()).unwrap_or(0),
                params: req_usize(a, "params")?,
            });
        }
        let man = Manifest {
            dir: dir.to_path_buf(),
            input_dim,
            classes,
            eval_batch,
            buckets,
            models,
            artifacts,
        };
        man.validate()?;
        Ok(man)
    }

    fn validate(&self) -> Result<()> {
        for model in self.models.keys() {
            for &b in &self.buckets {
                if self.find(model, Kind::TrainStep, b).is_none() {
                    bail!("model {model}: missing train_step bucket {b}");
                }
            }
            for kind in [Kind::ApplyUpdate, Kind::Eval, Kind::Init] {
                if !self
                    .artifacts
                    .iter()
                    .any(|a| a.model == *model && a.kind == kind)
                {
                    bail!("model {model}: missing {kind:?} artifact");
                }
            }
        }
        Ok(())
    }

    /// Find the artifact for (model, kind, bucket); bucket ignored unless
    /// TrainStep.
    pub fn find(&self, model: &str, kind: Kind, bucket: usize) -> Option<&Artifact> {
        self.artifacts.iter().find(|a| {
            a.model == model
                && a.kind == kind
                && (kind != Kind::TrainStep || a.bucket == bucket)
        })
    }

    /// Smallest bucket >= n (batch padding target). None if n exceeds max.
    pub fn bucket_for(&self, n: usize) -> Option<usize> {
        self.buckets.iter().copied().find(|&b| b >= n)
    }

    /// Largest configured bucket (the runtime's B_max).
    pub fn max_bucket(&self) -> usize {
        // lint: allow(panic-path): parse() rejects a manifest with an empty bucket list
        *self.buckets.last().expect("manifest buckets validated non-empty")
    }

    pub fn model(&self, name: &str) -> Result<&ModelMeta> {
        self.models
            .get(name)
            .with_context(|| format!("model {name:?} not in manifest"))
    }
}

fn req_usize(v: &Json, key: &str) -> Result<usize> {
    v.req(key)?
        .as_usize()
        .with_context(|| format!("{key} not a non-negative integer"))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mini_manifest() -> String {
        r#"{
          "version": 1, "input_dim": 4, "classes": 2, "eval_batch": 8,
          "buckets": [1, 2],
          "models": {"m": {"params": 10, "layout": [["w", [4, 2]], ["b", [2]]]}},
          "artifacts": [
            {"name": "train_step_m_b1", "path": "t1.hlo.txt", "model": "m",
             "kind": "train_step", "bucket": 1, "params": 10},
            {"name": "train_step_m_b2", "path": "t2.hlo.txt", "model": "m",
             "kind": "train_step", "bucket": 2, "params": 10},
            {"name": "apply_update_m", "path": "u.hlo.txt", "model": "m",
             "kind": "apply_update", "params": 10},
            {"name": "eval_m", "path": "e.hlo.txt", "model": "m",
             "kind": "eval", "bucket": 8, "params": 10},
            {"name": "init_m", "path": "i.bin", "model": "m",
             "kind": "init", "params": 10}
          ]
        }"#
        .to_string()
    }

    #[test]
    fn parses_and_validates() {
        let m = Manifest::parse(&mini_manifest(), Path::new("/tmp/a")).unwrap();
        assert_eq!(m.input_dim, 4);
        assert_eq!(m.buckets, vec![1, 2]);
        assert_eq!(m.model("m").unwrap().params, 10);
        assert!(m.find("m", Kind::TrainStep, 2).is_some());
        assert!(m.find("m", Kind::TrainStep, 4).is_none());
        assert_eq!(m.bucket_for(2), Some(2));
        assert_eq!(m.bucket_for(3), None);
        assert_eq!(m.max_bucket(), 2);
    }

    #[test]
    fn rejects_missing_bucket() {
        let text = mini_manifest().replace(
            r#"{"name": "train_step_m_b2", "path": "t2.hlo.txt", "model": "m",
             "kind": "train_step", "bucket": 2, "params": 10},"#,
            "",
        );
        assert!(Manifest::parse(&text, Path::new("/tmp")).is_err());
    }

    #[test]
    fn rejects_bad_layout_sum() {
        let text = mini_manifest().replace("\"params\": 10, \"layout\"", "\"params\": 11, \"layout\"");
        assert!(Manifest::parse(&text, Path::new("/tmp")).is_err());
    }

    #[test]
    fn paths_joined_to_dir() {
        let m = Manifest::parse(&mini_manifest(), Path::new("/x/y")).unwrap();
        assert_eq!(
            m.find("m", Kind::Eval, 0).unwrap().path,
            PathBuf::from("/x/y/e.hlo.txt")
        );
    }
}
