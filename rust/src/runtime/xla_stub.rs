//! Build-time stub of the `xla` crate's API surface (PJRT CPU client).
//!
//! The offline build environment bakes in no `xla` crate, so `client.rs`
//! compiles against this stub (`use super::xla_stub as xla;`) and the PJRT
//! path reports a clean runtime error if selected — the pure-rust host
//! backend remains fully functional, and every test that needs PJRT
//! self-skips when `artifacts/` is absent. Re-linking the real crate is a
//! two-line change: add the `xla` dependency in Cargo.toml and point the
//! import in `client.rs` back at it.

use std::fmt;
use std::path::Path;

const UNAVAILABLE: &str =
    "PJRT/XLA unavailable: built against the xla stub (use --backend host, \
     or link the real `xla` crate — see runtime/xla_stub.rs)";

/// Debug-printable error mirroring the real crate's error type.
pub struct XlaError(String);

impl XlaError {
    fn unavailable() -> XlaError {
        XlaError(UNAVAILABLE.to_string())
    }
}

impl fmt::Debug for XlaError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

/// Element types literals can carry.
pub trait NativeType: Copy {}
impl NativeType for f32 {}
impl NativeType for f64 {}
impl NativeType for i32 {}
impl NativeType for i64 {}

pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient, XlaError> {
        Err(XlaError::unavailable())
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable, XlaError> {
        Err(XlaError::unavailable())
    }
}

pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>, XlaError> {
        Err(XlaError::unavailable())
    }
}

pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, XlaError> {
        Err(XlaError::unavailable())
    }
}

pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file<P: AsRef<Path>>(_path: P) -> Result<HloModuleProto, XlaError> {
        Err(XlaError::unavailable())
    }
}

pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

pub struct Literal;

impl Literal {
    pub fn vec1<T: NativeType>(_v: &[T]) -> Literal {
        Literal
    }

    pub fn scalar<T: NativeType>(_v: T) -> Literal {
        Literal
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal, XlaError> {
        Ok(Literal)
    }

    pub fn to_tuple(&self) -> Result<Vec<Literal>, XlaError> {
        Err(XlaError::unavailable())
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>, XlaError> {
        Err(XlaError::unavailable())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_reports_clean_unavailability() {
        let err = PjRtClient::cpu().err().unwrap();
        assert!(format!("{err:?}").contains("unavailable"));
    }
}
