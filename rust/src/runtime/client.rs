//! PJRT runtime: loads the AOT HLO-text artifacts and executes them on the
//! CPU client. This is the only place python-produced bits are touched —
//! python itself never runs on the training path.
//!
//! Pattern follows /opt/xla-example/load_hlo: `HloModuleProto::from_text_file`
//! -> `XlaComputation::from_proto` -> `client.compile` -> `execute`.
//! Executables are compiled lazily and cached per artifact name (a model ×
//! bucket grid is 30+ modules; most runs touch a handful).

use std::collections::HashMap;
use std::path::Path;
use std::time::Instant;

use anyhow::{bail, Context, Result};

use super::manifest::{Kind, Manifest};
// The offline build has no real `xla` crate; compile against the stub
// (swap this import back to the crate to re-enable PJRT execution).
use super::xla_stub as xla;

/// Outcome of one train-step execution.
#[derive(Clone, Debug)]
pub struct StepOut {
    pub grads: Vec<f32>,
    pub loss: f32,
    /// number of correctly-classified live (mask=1) samples
    pub correct: f32,
}

/// Outcome of one eval execution.
#[derive(Clone, Copy, Debug)]
pub struct EvalOut {
    pub loss: f32,
    pub correct: f32,
}

/// Counters for the §Perf pass (compile vs execute time).
#[derive(Clone, Copy, Debug, Default)]
pub struct RuntimeStats {
    pub compiles: usize,
    pub compile_secs: f64,
    pub executes: usize,
    pub execute_secs: f64,
}

/// The PJRT-backed runtime.
pub struct Runtime {
    client: xla::PjRtClient,
    pub manifest: Manifest,
    cache: HashMap<String, xla::PjRtLoadedExecutable>,
    stats: RuntimeStats,
}

impl Runtime {
    /// Load the manifest in `dir` and create the CPU PJRT client.
    pub fn load(dir: &Path) -> Result<Runtime> {
        let manifest = Manifest::load(dir)?;
        let client = xla::PjRtClient::cpu()
            .map_err(|e| anyhow::anyhow!("PJRT cpu client: {e:?}"))?;
        Ok(Runtime { client, manifest, cache: HashMap::new(), stats: RuntimeStats::default() })
    }

    pub fn stats(&self) -> RuntimeStats {
        self.stats
    }

    /// Read the deterministic initial parameter vector for `model`.
    pub fn init_params(&self, model: &str) -> Result<Vec<f32>> {
        let art = self
            .manifest
            .find(model, Kind::Init, 0)
            .with_context(|| format!("no init artifact for {model}"))?;
        let bytes = std::fs::read(&art.path)
            .with_context(|| format!("reading {}", art.path.display()))?;
        if bytes.len() != art.params * 4 {
            bail!(
                "init {}: {} bytes, want {} f32",
                art.path.display(),
                bytes.len(),
                art.params
            );
        }
        Ok(bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }

    fn executable(&mut self, model: &str, kind: Kind, bucket: usize) -> Result<&xla::PjRtLoadedExecutable> {
        let art = self
            .manifest
            .find(model, kind, bucket)
            .with_context(|| format!("no artifact: model={model} kind={kind:?} bucket={bucket}"))?
            .clone();
        if !self.cache.contains_key(&art.name) {
            let t0 = Instant::now();
            let proto = xla::HloModuleProto::from_text_file(&art.path)
                .map_err(|e| anyhow::anyhow!("parsing {}: {e:?}", art.path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .map_err(|e| anyhow::anyhow!("compiling {}: {e:?}", art.name))?;
            self.stats.compiles += 1;
            self.stats.compile_secs += t0.elapsed().as_secs_f64();
            self.cache.insert(art.name.clone(), exe);
        }
        self.cache
            .get(&art.name)
            .with_context(|| format!("executable cache lost {} after insert", art.name))
    }

    /// Pre-compile every artifact a training run will need (optional warmup
    /// so the first period's latency is not dominated by XLA compilation).
    pub fn warmup(&mut self, model: &str, buckets: &[usize]) -> Result<()> {
        for &b in buckets {
            self.executable(model, Kind::TrainStep, b)?;
        }
        self.executable(model, Kind::ApplyUpdate, 0)?;
        let eb = self.manifest.eval_batch;
        self.executable(model, Kind::Eval, eb)?;
        Ok(())
    }

    fn run(
        &mut self,
        model: &str,
        kind: Kind,
        bucket: usize,
        args: &[xla::Literal],
    ) -> Result<Vec<xla::Literal>> {
        // compile (cached) first so execute timing is pure execution
        self.executable(model, kind, bucket)?;
        let t0 = Instant::now();
        let key = artifact_key(&self.manifest, model, kind, bucket)?;
        let exe = self
            .cache
            .get(&key)
            .with_context(|| format!("executable cache lost {key} after warm compile"))?;
        let bufs = exe
            .execute::<xla::Literal>(args)
            .map_err(|e| anyhow::anyhow!("executing {model}/{kind:?}/b{bucket}: {e:?}"))?;
        let lit = bufs[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("fetching result: {e:?}"))?;
        let parts = lit
            .to_tuple()
            .map_err(|e| anyhow::anyhow!("untupling result: {e:?}"))?;
        self.stats.executes += 1;
        self.stats.execute_secs += t0.elapsed().as_secs_f64();
        Ok(parts)
    }

    /// One forward-backward pass over an exact-`bucket` batch.
    /// `x` is row-major `[bucket, input_dim]`, `y` labels, `w` the 0/1 mask.
    pub fn train_step(
        &mut self,
        model: &str,
        params: &[f32],
        x: &[f32],
        y: &[i32],
        w: &[f32],
        bucket: usize,
    ) -> Result<StepOut> {
        let d = self.manifest.input_dim;
        let p = self.manifest.model(model)?.params;
        if params.len() != p || x.len() != bucket * d || y.len() != bucket || w.len() != bucket {
            bail!(
                "train_step shape mismatch: params {}/{p}, x {}/{}, y {}/{bucket}, w {}/{bucket}",
                params.len(), x.len(), bucket * d, y.len(), w.len()
            );
        }
        let args = [
            xla::Literal::vec1(params),
            xla::Literal::vec1(x)
                .reshape(&[bucket as i64, d as i64])
                .map_err(|e| anyhow::anyhow!("reshape x: {e:?}"))?,
            xla::Literal::vec1(y),
            xla::Literal::vec1(w),
        ];
        let parts = self.run(model, Kind::TrainStep, bucket, &args)?;
        if parts.len() != 3 {
            bail!("train_step returned {}-tuple, want 3", parts.len());
        }
        let grads = parts[0]
            .to_vec::<f32>()
            .map_err(|e| anyhow::anyhow!("grads: {e:?}"))?;
        let loss = scalar_f32(&parts[1])?;
        let correct = scalar_f32(&parts[2])?;
        Ok(StepOut { grads, loss, correct })
    }

    /// Pad a true batch of `n <= bucket_for(n)` samples into the smallest
    /// bucket and run it; the mask keeps semantics exact.
    pub fn train_step_padded(
        &mut self,
        model: &str,
        params: &[f32],
        x: &[f32],
        y: &[i32],
    ) -> Result<StepOut> {
        let d = self.manifest.input_dim;
        let n = y.len();
        if n == 0 || x.len() != n * d {
            bail!("train_step_padded: bad batch (n={n}, x={})", x.len());
        }
        let bucket = self
            .manifest
            .bucket_for(n)
            .with_context(|| format!("batch {n} exceeds max bucket {}", self.manifest.max_bucket()))?;
        let mut xp = vec![0f32; bucket * d];
        xp[..n * d].copy_from_slice(x);
        let mut yp = vec![0i32; bucket];
        yp[..n].copy_from_slice(y);
        let mut wp = vec![0f32; bucket];
        wp[..n].fill(1.0);
        self.train_step(model, params, &xp, &yp, &wp, bucket)
    }

    /// One SGD step on the flat parameter vector (L1 sgd kernel inside).
    pub fn apply_update(
        &mut self,
        model: &str,
        params: &[f32],
        grads: &[f32],
        lr: f32,
    ) -> Result<Vec<f32>> {
        let p = self.manifest.model(model)?.params;
        if params.len() != p || grads.len() != p {
            bail!("apply_update shape mismatch: {} / {} vs P={p}", params.len(), grads.len());
        }
        let args = [
            xla::Literal::vec1(params),
            xla::Literal::vec1(grads),
            xla::Literal::scalar(lr),
        ];
        let parts = self.run(model, Kind::ApplyUpdate, 0, &args)?;
        if parts.len() != 1 {
            bail!("apply_update returned {}-tuple, want 1", parts.len());
        }
        parts[0]
            .to_vec::<f32>()
            .map_err(|e| anyhow::anyhow!("params out: {e:?}"))
    }

    /// Evaluate on one fixed-size eval batch (manifest.eval_batch rows).
    pub fn evaluate(&mut self, model: &str, params: &[f32], x: &[f32], y: &[i32]) -> Result<EvalOut> {
        let d = self.manifest.input_dim;
        let eb = self.manifest.eval_batch;
        if x.len() != eb * d || y.len() != eb {
            bail!("evaluate wants exactly eval_batch={eb} rows");
        }
        let args = [
            xla::Literal::vec1(params),
            xla::Literal::vec1(x)
                .reshape(&[eb as i64, d as i64])
                .map_err(|e| anyhow::anyhow!("reshape x: {e:?}"))?,
            xla::Literal::vec1(y),
        ];
        let parts = self.run(model, Kind::Eval, eb, &args)?;
        if parts.len() != 2 {
            bail!("eval returned {}-tuple, want 2", parts.len());
        }
        Ok(EvalOut { loss: scalar_f32(&parts[0])?, correct: scalar_f32(&parts[1])? })
    }

    /// Evaluate a whole dataset by chunking into eval batches (last chunk
    /// wraps around; caller passes full arrays). Returns (mean loss, accuracy).
    pub fn evaluate_dataset(
        &mut self,
        model: &str,
        params: &[f32],
        xs: &[f32],
        ys: &[i32],
    ) -> Result<(f64, f64)> {
        let d = self.manifest.input_dim;
        let eb = self.manifest.eval_batch;
        let n = ys.len();
        if n < eb {
            bail!("evaluate_dataset needs >= eval_batch={eb} rows, got {n}");
        }
        let mut total_loss = 0.0;
        let mut total_correct = 0.0;
        let mut rows = 0usize;
        let mut i = 0;
        while i < n {
            let start = if i + eb <= n { i } else { n - eb }; // wrap the tail
            let got = self.evaluate(
                model,
                params,
                &xs[start * d..(start + eb) * d],
                &ys[start..start + eb],
            )?;
            // tail overlap double-counts up to eb-1 rows; acceptable for
            // monitoring, and exact when n % eb == 0 (the default configs).
            total_loss += got.loss as f64 * eb as f64;
            total_correct += got.correct as f64;
            rows += eb;
            i += eb;
        }
        Ok((total_loss / rows as f64, total_correct / rows as f64))
    }
}

fn artifact_key(man: &Manifest, model: &str, kind: Kind, bucket: usize) -> Result<String> {
    Ok(man
        .find(model, kind, bucket)
        .with_context(|| format!("no artifact {model}/{kind:?}/b{bucket}"))?
        .name
        .clone())
}

fn scalar_f32(lit: &xla::Literal) -> Result<f32> {
    lit.to_vec::<f32>()
        .map_err(|e| anyhow::anyhow!("scalar: {e:?}"))?
        .first()
        .copied()
        .context("empty scalar literal")
}
