//! Pure-rust oracle of the L2 model zoo (mini_dense / mini_res / mini_mobile).
//!
//! Three jobs:
//!  1. integration-test oracle: identical params + batch must give the same
//!     loss/grads as the AOT XLA path (rust/tests/integration_runtime.rs);
//!  2. fast backend for the large Table-II sweeps (hundreds of federated
//!     periods × many schemes), where PJRT per-call overhead dominates;
//!  3. lets `cargo test` run without artifacts present.
//!
//! The architecture is reconstructed from the manifest's flat-param layout
//! (tensor names are the contract, see python/compile/model.py), so host and
//! XLA views can never drift silently: any layout change breaks parsing.
//!
//! Hot-path allocation discipline: `train_step_ws`/`forward_tape` draw every
//! intermediate buffer (activation tape, d-activation accumulators, dlogits,
//! replicated bias rows, row-concats) from a caller-owned [`Workspace`]
//! pool. Buffer shapes are fixed per (model, batch) shape, so after the
//! first step the pool is warm and steady-state training allocates only the
//! returned gradient vector.

use anyhow::{bail, Context, Result};

use crate::util::linalg::{gemm, gemm_at, gemm_bt};
use crate::util::rng::Pcg;

/// One layer as reconstructed from the layout.
#[derive(Clone, Debug, PartialEq)]
enum Layer {
    /// y = relu?(x W + b); offsets of W [in,out] and b [out].
    Dense { name: String, w: usize, b: usize, din: usize, dout: usize, relu: bool },
    /// DenseNet concat marker: input of the next layer is concat of all
    /// previous activations (handled by the family enum below).
    /// (mini_dense is recognized structurally, not with a marker.)
    /// mini_mobile separable: dw scale [w] then pointwise dense.
    Sep { dw: usize, w: usize, b: usize, width: usize },
    /// mini_res residual pair: h = relu(h + relu(h A + a) B + b).
    Res { aw: usize, ab: usize, bw: usize, bb: usize, width: usize },
}

/// Model family tag — drives the forward/backward composition.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Family {
    Dense,
    Res,
    Mobile,
}

/// Pure-rust model instance.
#[derive(Clone, Debug)]
pub struct HostModel {
    pub name: String,
    pub family: Family,
    pub input_dim: usize,
    pub classes: usize,
    pub params: usize,
    layers: Vec<Layer>,
    head: (usize, usize, usize), // (w offset, b offset, head input width)
}

/// Flat-layout cursor: resolves (name, shape) -> offset.
struct Cursor<'a> {
    entries: &'a [(String, Vec<usize>)],
    offsets: Vec<usize>,
}

impl<'a> Cursor<'a> {
    fn new(entries: &'a [(String, Vec<usize>)]) -> Self {
        let mut offsets = Vec::with_capacity(entries.len());
        let mut off = 0;
        for (_, shape) in entries {
            offsets.push(off);
            off += shape.iter().product::<usize>();
        }
        Cursor { entries, offsets }
    }

    fn find(&self, name: &str) -> Option<(usize, &'a [usize])> {
        self.entries
            .iter()
            .position(|(n, _)| n == name)
            .map(|i| (self.offsets[i], self.entries[i].1.as_slice()))
    }
}

/// Reusable per-worker scratch arena for train-step intermediates.
///
/// A best-fit pool of f32 buffers plus a pool of tape "shells" (the outer
/// `Vec<Vec<f32>>`). Buffers are taken by length, used, and recycled; the
/// multiset of shapes a train step needs is constant per (model, batch)
/// shape, so the pool stabilizes after one step and reuse is exact.
/// Reuse never changes numerics: every taken buffer is fully re-filled
/// (zeroed or copied) before use.
#[derive(Debug, Default)]
pub struct Workspace {
    pool: Vec<Vec<f32>>,
    shells: Vec<Vec<Vec<f32>>>,
}

impl Workspace {
    pub fn new() -> Self {
        Workspace::default()
    }

    /// Number of pooled buffers currently idle (test/diagnostic hook for
    /// the "pool stabilizes" property).
    pub fn pooled_buffers(&self) -> usize {
        self.pool.len()
    }

    /// An empty buffer with capacity >= `len` (best-fit from the pool).
    fn grab(&mut self, len: usize) -> Vec<f32> {
        let mut best: Option<usize> = None;
        for (i, b) in self.pool.iter().enumerate() {
            if b.capacity() < len {
                continue;
            }
            let tighter = match best {
                None => true,
                Some(j) => b.capacity() < self.pool[j].capacity(),
            };
            if tighter {
                best = Some(i);
            }
        }
        match best {
            Some(i) => {
                let mut v = self.pool.swap_remove(i);
                v.clear();
                v
            }
            None => Vec::with_capacity(len),
        }
    }

    /// A buffer of exactly `len` elements, each set to `value`.
    pub fn take_filled(&mut self, len: usize, value: f32) -> Vec<f32> {
        let mut v = self.grab(len);
        v.resize(len, value);
        v
    }

    /// A zero-filled buffer of exactly `len` elements.
    pub fn take_zeroed(&mut self, len: usize) -> Vec<f32> {
        self.take_filled(len, 0.0)
    }

    /// A buffer holding a copy of `src`.
    pub fn copy_of(&mut self, src: &[f32]) -> Vec<f32> {
        let mut v = self.grab(src.len());
        v.extend_from_slice(src);
        v
    }

    /// Return a buffer to the pool for reuse.
    pub fn recycle(&mut self, v: Vec<f32>) {
        if v.capacity() > 0 {
            self.pool.push(v);
        }
    }

    /// An empty tape shell (outer vec) from the pool.
    fn take_shell(&mut self) -> Vec<Vec<f32>> {
        self.shells.pop().unwrap_or_default()
    }

    /// Recycle a tape: inner buffers go to the pool, the shell is kept.
    fn recycle_tape(&mut self, mut tape: Vec<Vec<f32>>) {
        for v in tape.drain(..) {
            self.recycle(v);
        }
        self.shells.push(tape);
    }
}

impl HostModel {
    /// Reconstruct the model from its manifest layout.
    pub fn from_layout(
        model: &str,
        layout: &[(String, Vec<usize>)],
        input_dim: usize,
        classes: usize,
    ) -> Result<HostModel> {
        let cur = Cursor::new(layout);
        let total: usize = layout.iter().map(|(_, s)| s.iter().product::<usize>()).sum();
        let family = match model {
            "mini_dense" => Family::Dense,
            "mini_res" => Family::Res,
            "mini_mobile" => Family::Mobile,
            other => bail!("host model: unknown family {other:?}"),
        };
        let mut layers = Vec::new();
        match family {
            Family::Dense => {
                for i in 0.. {
                    let Some((w, ws)) = cur.find(&format!("blk{i}_w")) else { break };
                    let (b, _) = cur
                        .find(&format!("blk{i}_b"))
                        .context("dense block missing bias")?;
                    layers.push(Layer::Dense {
                        name: format!("blk{i}"),
                        w,
                        b,
                        din: ws[0],
                        dout: ws[1],
                        relu: true,
                    });
                }
            }
            Family::Res => {
                let (w, ws) = cur.find("stem_w").context("missing stem_w")?;
                let (b, _) = cur.find("stem_b").context("missing stem_b")?;
                layers.push(Layer::Dense {
                    name: "stem".into(),
                    w,
                    b,
                    din: ws[0],
                    dout: ws[1],
                    relu: true,
                });
                for i in 0.. {
                    let Some((aw, aws)) = cur.find(&format!("res{i}a_w")) else { break };
                    let (ab, _) = cur.find(&format!("res{i}a_b")).context("res a_b")?;
                    let (bw, _) = cur.find(&format!("res{i}b_w")).context("res b_w")?;
                    let (bb, _) = cur.find(&format!("res{i}b_b")).context("res b_b")?;
                    layers.push(Layer::Res { aw, ab, bw, bb, width: aws[0] });
                }
            }
            Family::Mobile => {
                let (w, ws) = cur.find("stem_w").context("missing stem_w")?;
                let (b, _) = cur.find("stem_b").context("missing stem_b")?;
                layers.push(Layer::Dense {
                    name: "stem".into(),
                    w,
                    b,
                    din: ws[0],
                    dout: ws[1],
                    relu: true,
                });
                for i in 0.. {
                    let Some((dw, dws)) = cur.find(&format!("sep{i}_dw")) else { break };
                    let (w, _) = cur.find(&format!("sep{i}_w")).context("sep w")?;
                    let (b, _) = cur.find(&format!("sep{i}_b")).context("sep b")?;
                    layers.push(Layer::Sep { dw, w, b, width: dws[0] });
                }
            }
        }
        let (hw, hws) = cur.find("head_w").context("missing head_w")?;
        let (hb, _) = cur.find("head_b").context("missing head_b")?;
        if hws[1] != classes {
            bail!("head width {} != classes {classes}", hws[1]);
        }
        Ok(HostModel {
            name: model.to_string(),
            family,
            input_dim,
            classes,
            params: total,
            layers,
            head: (hw, hb, hws[0]),
        })
    }

    /// Forward pass; returns logits [n, classes] and the activation tape
    /// (ending with the stashed head input), all drawn from `ws`.
    fn forward_tape(
        &self,
        flat: &[f32],
        x: &[f32],
        n: usize,
        ws: &mut Workspace,
    ) -> (Vec<f32>, Vec<Vec<f32>>) {
        let d = self.input_dim;
        debug_assert_eq!(x.len(), n * d);
        let mut tape: Vec<Vec<f32>> = ws.take_shell();
        tape.push(ws.copy_of(x));
        match self.family {
            Family::Dense => {
                // activation i+1 = relu(concat(tape...) W + b)
                for l in &self.layers {
                    let Layer::Dense { w, b, din, dout, .. } = l else { unreachable!() };
                    let cat = concat_rows(&tape, n, ws);
                    debug_assert_eq!(cat.len(), n * din);
                    let mut h = bias_rows(&flat[*b..*b + *dout], n, ws);
                    gemm(n, *din, *dout, &cat, &flat[*w..*w + din * dout], &mut h);
                    ws.recycle(cat);
                    relu_inplace(&mut h);
                    tape.push(h);
                }
            }
            Family::Res => {
                for l in &self.layers {
                    match l {
                        Layer::Dense { w, b, din, dout, .. } => {
                            let mut h = bias_rows(&flat[*b..*b + *dout], n, ws);
                            let x0 = tape_top(&tape);
                            gemm(n, *din, *dout, x0, &flat[*w..*w + din * dout], &mut h);
                            relu_inplace(&mut h);
                            tape.push(h);
                        }
                        Layer::Res { aw, ab, bw, bb, width } => {
                            let wd = *width;
                            let mut inner = bias_rows(&flat[*ab..*ab + wd], n, ws);
                            let h = tape_top(&tape);
                            gemm(n, wd, wd, h, &flat[*aw..*aw + wd * wd], &mut inner);
                            relu_inplace(&mut inner);
                            let mut out = bias_rows(&flat[*bb..*bb + wd], n, ws);
                            gemm(n, wd, wd, &inner, &flat[*bw..*bw + wd * wd], &mut out);
                            let h = tape_top(&tape);
                            for (o, &hh) in out.iter_mut().zip(h) {
                                *o += hh; // skip connection (pre-relu sum)
                            }
                            relu_inplace(&mut out);
                            tape.push(inner); // a-activation
                            tape.push(out);
                        }
                        _ => unreachable!(),
                    }
                }
            }
            Family::Mobile => {
                for l in &self.layers {
                    match l {
                        Layer::Dense { w, b, din, dout, .. } => {
                            let mut h = bias_rows(&flat[*b..*b + *dout], n, ws);
                            let x0 = tape_top(&tape);
                            gemm(n, *din, *dout, x0, &flat[*w..*w + din * dout], &mut h);
                            relu_inplace(&mut h);
                            tape.push(h);
                        }
                        Layer::Sep { dw, w, b, width } => {
                            let wd = *width;
                            let scale = &flat[*dw..*dw + wd];
                            let mut dwo = ws.take_zeroed(n * wd);
                            let h = tape_top(&tape);
                            for i in 0..n {
                                for j in 0..wd {
                                    dwo[i * wd + j] = (h[i * wd + j] * scale[j]).max(0.0);
                                }
                            }
                            let mut out = bias_rows(&flat[*b..*b + wd], n, ws);
                            gemm(n, wd, wd, &dwo, &flat[*w..*w + wd * wd], &mut out);
                            relu_inplace(&mut out);
                            tape.push(dwo); // depthwise activation
                            tape.push(out);
                        }
                        _ => unreachable!(),
                    }
                }
            }
        }
        // head
        let (hw, hb, hin) = self.head;
        let head_in = match self.family {
            Family::Dense => concat_rows(&tape, n, ws),
            _ => ws.copy_of(tape_top(&tape)),
        };
        debug_assert_eq!(head_in.len(), n * hin);
        let mut logits = bias_rows(&flat[hb..hb + self.classes], n, ws);
        gemm(n, hin, self.classes, &head_in, &flat[hw..hw + hin * self.classes], &mut logits);
        tape.push(head_in); // stash head input for backward
        (logits, tape)
    }

    /// Forward only: logits [n, classes].
    pub fn forward(&self, flat: &[f32], x: &[f32], n: usize) -> Vec<f32> {
        let mut ws = Workspace::new();
        let (logits, tape) = self.forward_tape(flat, x, n, &mut ws);
        ws.recycle_tape(tape);
        logits
    }

    /// Masked mean CE loss + correct count (mirrors masked_softmax_xent_ref).
    pub fn loss(&self, flat: &[f32], x: &[f32], y: &[i32], w: &[f32]) -> (f32, f32) {
        let n = y.len();
        let logits = self.forward(flat, x, n);
        softmax_xent_loss(&logits, y, w, self.classes)
    }

    /// Full train step: (grads, loss, correct) — mirrors the AOT train_step.
    /// One-shot form; hot loops should hold a [`Workspace`] and call
    /// [`HostModel::train_step_ws`] instead.
    pub fn train_step(&self, flat: &[f32], x: &[f32], y: &[i32], w: &[f32]) -> (Vec<f32>, f32, f32) {
        self.train_step_ws(flat, x, y, w, &mut Workspace::new())
    }

    /// Full train step drawing every intermediate from `ws`: after the
    /// first call with a given (model, batch) shape, the only allocation
    /// left is the returned gradient vector.
    pub fn train_step_ws(
        &self,
        flat: &[f32],
        x: &[f32],
        y: &[i32],
        w: &[f32],
        ws: &mut Workspace,
    ) -> (Vec<f32>, f32, f32) {
        let n = y.len();
        let c = self.classes;
        let (logits, mut tape) = self.forward_tape(flat, x, n, ws);
        let mut dlogits = ws.take_zeroed(n * c);
        let (loss, correct) = softmax_xent_grad(&logits, y, w, c, &mut dlogits);
        ws.recycle(logits);
        let mut grads = vec![0f32; self.params];

        // head backward (head input was stashed at the end of the tape)
        let (hw, hb, hin) = self.head;
        let head_in = tape_pop(&mut tape);
        gemm_at(n, hin, c, &head_in, &dlogits, &mut grads[hw..hw + hin * c]);
        col_sums(&dlogits, n, c, &mut grads[hb..hb + c]);
        let mut dhead_in = ws.take_zeroed(n * hin);
        gemm_bt(n, hin, c, &dlogits, &flat[hw..hw + hin * c], &mut dhead_in);
        ws.recycle(dlogits);
        ws.recycle(head_in);

        match self.family {
            Family::Dense => self.backward_dense(flat, &tape, dhead_in, n, &mut grads, ws),
            Family::Res => self.backward_res(flat, &tape, dhead_in, n, &mut grads, ws),
            Family::Mobile => self.backward_mobile(flat, &tape, dhead_in, n, &mut grads, ws),
        }
        ws.recycle_tape(tape);
        (grads, loss, correct)
    }

    fn backward_dense(
        &self,
        flat: &[f32],
        acts: &[Vec<f32>],
        dhead_in: Vec<f32>,
        n: usize,
        grads: &mut [f32],
        ws: &mut Workspace,
    ) {
        // acts: [x, h1, .., hL]; the head consumed concat(x, h1..hL).
        let widths: Vec<usize> = acts.iter().map(|a| a.len() / n).collect();
        // d(activation) accumulators, seeded by splitting dhead_in.
        let mut dacts: Vec<Vec<f32>> = ws.take_shell();
        for a in acts {
            dacts.push(ws.take_zeroed(a.len()));
        }
        split_rows(&dhead_in, n, &widths, &mut dacts, true);
        ws.recycle(dhead_in);
        // walk blocks backward; block i consumed concat(acts[..=i]).
        for (bi, l) in self.layers.iter().enumerate().rev() {
            let Layer::Dense { w, b, din, dout, .. } = l else { unreachable!() };
            let out_idx = bi + 1;
            // relu gate
            let mut dh = ws.copy_of(&dacts[out_idx]);
            relu_gate(&mut dh, &acts[out_idx]);
            let cat = concat_rows(&acts[..=bi], n, ws);
            gemm_at(n, *din, *dout, &cat, &dh, &mut grads[*w..*w + din * dout]);
            col_sums(&dh, n, *dout, &mut grads[*b..*b + *dout]);
            ws.recycle(cat);
            let mut dcat = ws.take_zeroed(n * din);
            gemm_bt(n, *din, *dout, &dh, &flat[*w..*w + din * dout], &mut dcat);
            ws.recycle(dh);
            split_rows(&dcat, n, &widths[..=bi], &mut dacts, true);
            ws.recycle(dcat);
        }
        ws.recycle_tape(dacts);
    }

    fn backward_res(
        &self,
        flat: &[f32],
        tape: &[Vec<f32>],
        dhead_in: Vec<f32>,
        n: usize,
        grads: &mut [f32],
        ws: &mut Workspace,
    ) {
        // tape: [x, stem, (a0, o0), (a1, o1), ...]
        let mut dout = dhead_in; // gradient wrt current output activation
        let mut ti = tape.len() - 1; // index of last real activation
        for l in self.layers.iter().rev() {
            match l {
                Layer::Res { aw, ab, bw, bb, width } => {
                    let wd = *width;
                    let out = &tape[ti]; // relu(h + inner B + b)
                    let a_act = &tape[ti - 1]; // relu(h A + a)
                    let h = &tape[ti - 2]; // block input
                    let mut dsum = dout; // gate in place (dout is dead after)
                    relu_gate(&mut dsum, out);
                    // dsum flows to both skip (dh) and the B-branch
                    let mut db_in = ws.take_zeroed(n * wd); // d(a_act)
                    gemm_at(n, wd, wd, a_act, &dsum, &mut grads[*bw..*bw + wd * wd]);
                    col_sums(&dsum, n, wd, &mut grads[*bb..*bb + wd]);
                    gemm_bt(n, wd, wd, &dsum, &flat[*bw..*bw + wd * wd], &mut db_in);
                    relu_gate(&mut db_in, a_act);
                    gemm_at(n, wd, wd, h, &db_in, &mut grads[*aw..*aw + wd * wd]);
                    col_sums(&db_in, n, wd, &mut grads[*ab..*ab + wd]);
                    let mut dh = dsum; // skip path
                    gemm_bt(n, wd, wd, &db_in, &flat[*aw..*aw + wd * wd], &mut dh);
                    ws.recycle(db_in);
                    dout = dh;
                    ti -= 2;
                }
                Layer::Dense { w, b, din, dout: dd, .. } => {
                    let out = &tape[ti];
                    let x0 = &tape[ti - 1];
                    let mut dh = dout; // gate in place
                    relu_gate(&mut dh, out);
                    gemm_at(n, *din, *dd, x0, &dh, &mut grads[*w..*w + din * dd]);
                    col_sums(&dh, n, *dd, &mut grads[*b..*b + *dd]);
                    let mut dx = ws.take_zeroed(n * din);
                    gemm_bt(n, *din, *dd, &dh, &flat[*w..*w + din * dd], &mut dx);
                    ws.recycle(dh);
                    dout = dx;
                    ti -= 1;
                }
                _ => unreachable!(),
            }
        }
        ws.recycle(dout);
    }

    fn backward_mobile(
        &self,
        flat: &[f32],
        tape: &[Vec<f32>],
        dhead_in: Vec<f32>,
        n: usize,
        grads: &mut [f32],
        ws: &mut Workspace,
    ) {
        let mut dout = dhead_in;
        let mut ti = tape.len() - 1;
        for l in self.layers.iter().rev() {
            match l {
                Layer::Sep { dw, w, b, width } => {
                    let wd = *width;
                    let out = &tape[ti]; // relu(dwo W + b)
                    let dwo = &tape[ti - 1]; // relu(h * scale)
                    let h = &tape[ti - 2];
                    let mut dh_out = dout; // gate in place
                    relu_gate(&mut dh_out, out);
                    gemm_at(n, wd, wd, dwo, &dh_out, &mut grads[*w..*w + wd * wd]);
                    col_sums(&dh_out, n, wd, &mut grads[*b..*b + wd]);
                    let mut ddwo = ws.take_zeroed(n * wd);
                    gemm_bt(n, wd, wd, &dh_out, &flat[*w..*w + wd * wd], &mut ddwo);
                    relu_gate(&mut ddwo, dwo);
                    // d scale_j = sum_i h_ij * ddwo_ij ; dh_ij = scale_j * ddwo_ij
                    let scale = &flat[*dw..*dw + wd];
                    let gscale = &mut grads[*dw..*dw + wd];
                    let mut dh = dh_out; // reuse: fully overwritten below
                    for i in 0..n {
                        for j in 0..wd {
                            let g = ddwo[i * wd + j];
                            gscale[j] += h[i * wd + j] * g;
                            dh[i * wd + j] = scale[j] * g;
                        }
                    }
                    ws.recycle(ddwo);
                    dout = dh;
                    ti -= 2;
                }
                Layer::Dense { w, b, din, dout: dd, .. } => {
                    let out = &tape[ti];
                    let x0 = &tape[ti - 1];
                    let mut dh = dout; // gate in place
                    relu_gate(&mut dh, out);
                    gemm_at(n, *din, *dd, x0, &dh, &mut grads[*w..*w + din * dd]);
                    col_sums(&dh, n, *dd, &mut grads[*b..*b + *dd]);
                    let mut dx = ws.take_zeroed(n * din);
                    gemm_bt(n, *din, *dd, &dh, &flat[*w..*w + din * dd], &mut dx);
                    ws.recycle(dh);
                    dout = dx;
                    ti -= 1;
                }
                _ => unreachable!(),
            }
        }
        ws.recycle(dout);
    }

    /// Host-side parameter init (used when running without artifacts; NOT
    /// bit-identical to the jax init — tests that compare against XLA pass
    /// explicit params instead).
    pub fn init_params_host(&self, layout: &[(String, Vec<usize>)], seed: u64) -> Vec<f32> {
        let mut rng = Pcg::seeded(seed);
        let mut out = Vec::with_capacity(self.params);
        for (name, shape) in layout {
            let sz: usize = shape.iter().product();
            if name.ends_with("_b") {
                out.extend(std::iter::repeat(0f32).take(sz));
            } else if name.ends_with("_dw") {
                out.extend(std::iter::repeat(1f32).take(sz));
            } else {
                let fan_in = shape[0] as f64;
                let fan_out = shape.last().map_or(fan_in, |&v| v as f64);
                let s = (2.0 / (fan_in + fan_out)).sqrt();
                out.extend((0..sz).map(|_| (rng.normal() * s) as f32));
            }
        }
        out
    }
}

/// Top of the activation tape as a slice. `forward_tape` seeds the tape
/// with the batch input before any layer reads it, so the tape is never
/// empty while a forward pass is walking it.
fn tape_top(tape: &[Vec<f32>]) -> &[f32] {
    // lint: allow(panic-path): forward_tape pushes the input before any layer reads the tape
    tape.last().expect("activation tape is never empty").as_slice()
}

/// Pop the stashed head input off the tape for the backward pass.
/// `forward_tape` pushes it as its last act, so the pop always succeeds.
fn tape_pop(tape: &mut Vec<Vec<f32>>) -> Vec<f32> {
    // lint: allow(panic-path): forward_tape stashes the head input as its final push
    tape.pop().expect("tape holds the stashed head input")
}

// -- shared numeric helpers --------------------------------------------------

fn relu_inplace(h: &mut [f32]) {
    for v in h {
        if *v < 0.0 {
            *v = 0.0;
        }
    }
}

/// Gate dh by relu'(out): out > 0 passes (out is the post-relu activation).
fn relu_gate(dh: &mut [f32], out: &[f32]) {
    for (d, &o) in dh.iter_mut().zip(out) {
        if o <= 0.0 {
            *d = 0.0;
        }
    }
}

/// Replicate bias to n rows (buffer drawn from the workspace).
fn bias_rows(bias: &[f32], n: usize, ws: &mut Workspace) -> Vec<f32> {
    let mut out = ws.grab(n * bias.len());
    for _ in 0..n {
        out.extend_from_slice(bias);
    }
    out
}

/// Row-wise concat of per-activation matrices (all n rows; buffer drawn
/// from the workspace and written once, append-only — no pre-zeroing).
fn concat_rows(parts: &[Vec<f32>], n: usize, ws: &mut Workspace) -> Vec<f32> {
    let total: usize = parts.iter().map(|p| p.len() / n).sum();
    let mut out = ws.grab(n * total);
    for i in 0..n {
        for p in parts {
            let w = p.len() / n;
            out.extend_from_slice(&p[i * w..(i + 1) * w]);
        }
    }
    debug_assert_eq!(out.len(), n * total);
    out
}

/// Split row-concatenated gradient back into per-activation pieces,
/// accumulating (+=) into dacts[0..widths.len()].
fn split_rows(cat: &[f32], n: usize, widths: &[usize], dacts: &mut [Vec<f32>], accumulate: bool) {
    let total: usize = widths.iter().sum();
    debug_assert_eq!(cat.len(), n * total);
    for i in 0..n {
        let mut off = 0;
        for (k, &w) in widths.iter().enumerate() {
            let src = &cat[i * total + off..i * total + off + w];
            let dst = &mut dacts[k][i * w..(i + 1) * w];
            if accumulate {
                for (d, &s) in dst.iter_mut().zip(src) {
                    *d += s;
                }
            } else {
                dst.copy_from_slice(src);
            }
            off += w;
        }
    }
}

/// Column sums of d [n, c] accumulated into out [c].
fn col_sums(d: &[f32], n: usize, c: usize, out: &mut [f32]) {
    for i in 0..n {
        for j in 0..c {
            out[j] += d[i * c + j];
        }
    }
}

/// Masked softmax CE, loss/accuracy only: (mean loss, correct count).
fn softmax_xent_loss(logits: &[f32], y: &[i32], w: &[f32], c: usize) -> (f32, f32) {
    let n = y.len();
    debug_assert_eq!(logits.len(), n * c);
    let denom = w.iter().sum::<f32>().max(1.0);
    let mut loss = 0f32;
    let mut correct = 0f32;
    for i in 0..n {
        let row = &logits[i * c..(i + 1) * c];
        let (zmax, sum) = row_lse(row);
        let yi = y[i] as usize;
        loss += w[i] * (sum.ln() - (row[yi] - zmax));
        if row_argmax(row) == yi {
            correct += w[i];
        }
    }
    (loss / denom, correct)
}

/// Masked softmax CE with gradient: fills `dlogits` [n,c] (fully
/// overwritten) and returns (mean loss, correct count).
fn softmax_xent_grad(
    logits: &[f32],
    y: &[i32],
    w: &[f32],
    c: usize,
    dlogits: &mut [f32],
) -> (f32, f32) {
    let n = y.len();
    debug_assert_eq!(logits.len(), n * c);
    debug_assert_eq!(dlogits.len(), n * c);
    let denom = w.iter().sum::<f32>().max(1.0);
    let mut loss = 0f32;
    let mut correct = 0f32;
    for i in 0..n {
        let row = &logits[i * c..(i + 1) * c];
        let (zmax, sum) = row_lse(row);
        let yi = y[i] as usize;
        loss += w[i] * (sum.ln() - (row[yi] - zmax));
        if row_argmax(row) == yi {
            correct += w[i];
        }
        let coef = w[i] / denom;
        for j in 0..c {
            let p = (row[j] - zmax).exp() / sum;
            dlogits[i * c + j] = coef * (p - if j == yi { 1.0 } else { 0.0 });
        }
    }
    (loss / denom, correct)
}

/// Stable softmax row statistics: (row max, Σ exp(v - max)).
fn row_lse(row: &[f32]) -> (f32, f32) {
    let zmax = row.iter().copied().fold(f32::NEG_INFINITY, f32::max);
    let mut sum = 0f32;
    for &v in row {
        sum += (v - zmax).exp();
    }
    (zmax, sum)
}

/// NaN-safe argmax: total_cmp orders NaN consistently instead of
/// panicking mid-experiment when a run diverges.
fn row_argmax(row: &[f32]) -> usize {
    row.iter().enumerate().max_by(|a, b| a.1.total_cmp(b.1)).map_or(0, |(i, _)| i)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn layout_dense() -> Vec<(String, Vec<usize>)> {
        // tiny mini_dense: D=6, growth=4, blocks=2, classes=3
        vec![
            ("blk0_w".into(), vec![6, 4]),
            ("blk0_b".into(), vec![4]),
            ("blk1_w".into(), vec![10, 4]),
            ("blk1_b".into(), vec![4]),
            ("head_w".into(), vec![14, 3]),
            ("head_b".into(), vec![3]),
        ]
    }

    fn layout_res() -> Vec<(String, Vec<usize>)> {
        vec![
            ("stem_w".into(), vec![6, 5]),
            ("stem_b".into(), vec![5]),
            ("res0a_w".into(), vec![5, 5]),
            ("res0a_b".into(), vec![5]),
            ("res0b_w".into(), vec![5, 5]),
            ("res0b_b".into(), vec![5]),
            ("head_w".into(), vec![5, 3]),
            ("head_b".into(), vec![3]),
        ]
    }

    fn layout_mobile() -> Vec<(String, Vec<usize>)> {
        vec![
            ("stem_w".into(), vec![6, 5]),
            ("stem_b".into(), vec![5]),
            ("sep0_dw".into(), vec![5]),
            ("sep0_w".into(), vec![5, 5]),
            ("sep0_b".into(), vec![5]),
            ("head_w".into(), vec![5, 3]),
            ("head_b".into(), vec![3]),
        ]
    }

    fn rand_params(m: &HostModel, layout: &[(String, Vec<usize>)], seed: u64) -> Vec<f32> {
        // random (not glorot-zero) so grads flow everywhere incl. biases
        let mut r = Pcg::seeded(seed);
        let mut p = m.init_params_host(layout, seed);
        for v in &mut p {
            *v += 0.1 * r.normal() as f32;
        }
        p
    }

    fn batch(n: usize, d: usize, c: usize, seed: u64) -> (Vec<f32>, Vec<i32>, Vec<f32>) {
        let mut r = Pcg::seeded(seed);
        let x: Vec<f32> = (0..n * d).map(|_| r.normal() as f32).collect();
        let y: Vec<i32> = (0..n).map(|_| r.below(c as u64) as i32).collect();
        let mut w = vec![1f32; n];
        if n > 2 {
            w[n - 1] = 0.0; // exercise masking
        }
        (x, y, w)
    }

    /// Central-difference gradient check on a random subset of parameters.
    fn grad_check(model: &str, layout: Vec<(String, Vec<usize>)>) {
        let (d, c) = (6, 3);
        let m = HostModel::from_layout(model, &layout, d, c).unwrap();
        let p = rand_params(&m, &layout, 1);
        let (x, y, w) = batch(5, d, c, 2);
        let (g, _, _) = m.train_step(&p, &x, &y, &w);
        let mut rng = Pcg::seeded(3);
        let eps = 1e-3f32;
        let mut checked = 0;
        for _ in 0..40 {
            let i = rng.below(m.params as u64) as usize;
            let mut pp = p.clone();
            pp[i] += eps;
            let (lp, _) = m.loss(&pp, &x, &y, &w);
            pp[i] -= 2.0 * eps;
            let (lm, _) = m.loss(&pp, &x, &y, &w);
            let num = (lp - lm) / (2.0 * eps);
            assert!(
                (num - g[i]).abs() < 2e-3 + 0.05 * num.abs().max(g[i].abs()),
                "{model} param {i}: numeric {num} vs analytic {}",
                g[i]
            );
            checked += 1;
        }
        assert_eq!(checked, 40);
    }

    #[test]
    fn grad_check_dense() {
        grad_check("mini_dense", layout_dense());
    }

    #[test]
    fn grad_check_res() {
        grad_check("mini_res", layout_res());
    }

    #[test]
    fn grad_check_mobile() {
        grad_check("mini_mobile", layout_mobile());
    }

    #[test]
    fn mask_zero_rows_have_no_effect() {
        let layout = layout_res();
        let m = HostModel::from_layout("mini_res", &layout, 6, 3).unwrap();
        let p = rand_params(&m, &layout, 7);
        let (x, y, _) = batch(4, 6, 3, 8);
        let w_all = vec![1f32, 1.0, 1.0, 0.0];
        let (g1, l1, _) = m.train_step(&p, &x, &y, &w_all);
        // change the masked row's data: nothing may move
        let mut x2 = x.clone();
        for v in &mut x2[3 * 6..4 * 6] {
            *v = 99.0;
        }
        let (g2, l2, _) = m.train_step(&p, &x2, &y, &w_all);
        assert_eq!(l1, l2);
        assert_eq!(g1, g2);
    }

    #[test]
    fn loss_decreases_under_sgd() {
        let layout = layout_dense();
        let m = HostModel::from_layout("mini_dense", &layout, 6, 3).unwrap();
        let mut p = rand_params(&m, &layout, 11);
        let (x, y, w) = batch(16, 6, 3, 12);
        let (_, l0, _) = m.train_step(&p, &x, &y, &w);
        for _ in 0..50 {
            let (g, _, _) = m.train_step(&p, &x, &y, &w);
            for (pv, gv) in p.iter_mut().zip(&g) {
                *pv -= 0.5 * gv;
            }
        }
        let (_, l1, _) = m.train_step(&p, &x, &y, &w);
        assert!(l1 < l0 * 0.5, "loss {l0} -> {l1}");
    }

    #[test]
    fn rejects_unknown_family() {
        assert!(HostModel::from_layout("resnet50", &layout_res(), 6, 3).is_err());
    }

    #[test]
    fn param_count_matches_layout() {
        let layout = layout_mobile();
        let m = HostModel::from_layout("mini_mobile", &layout, 6, 3).unwrap();
        let want: usize = layout.iter().map(|(_, s)| s.iter().product::<usize>()).sum();
        assert_eq!(m.params, want);
    }

    /// Workspace reuse is invisible to numerics (a reused arena produces
    /// bitwise-identical steps) and the pool stabilizes after the first
    /// step — steady-state training recycles instead of allocating.
    #[test]
    fn workspace_reuse_bitwise_stable_all_families() {
        for (model, layout) in [
            ("mini_dense", layout_dense()),
            ("mini_res", layout_res()),
            ("mini_mobile", layout_mobile()),
        ] {
            let (d, c) = (6, 3);
            let m = HostModel::from_layout(model, &layout, d, c).unwrap();
            let p = rand_params(&m, &layout, 21);
            let (x, y, w) = batch(5, d, c, 22);
            let mut ws = Workspace::new();
            let first = m.train_step_ws(&p, &x, &y, &w, &mut ws);
            let pooled = ws.pooled_buffers();
            assert!(pooled > 0, "{model}: nothing recycled");
            for _ in 0..3 {
                let again = m.train_step_ws(&p, &x, &y, &w, &mut ws);
                assert_eq!(first.0, again.0, "{model}: grads drifted under reuse");
                assert_eq!(first.1.to_bits(), again.1.to_bits(), "{model}: loss");
                assert_eq!(first.2.to_bits(), again.2.to_bits(), "{model}: correct");
                assert_eq!(ws.pooled_buffers(), pooled, "{model}: pool kept growing");
            }
            // and the one-shot path (fresh workspace) agrees too
            let fresh = m.train_step(&p, &x, &y, &w);
            assert_eq!(first.0, fresh.0, "{model}: ws vs fresh");
        }
    }
}
