//! Pure-rust oracle of the L2 model zoo (mini_dense / mini_res / mini_mobile).
//!
//! Three jobs:
//!  1. integration-test oracle: identical params + batch must give the same
//!     loss/grads as the AOT XLA path (rust/tests/integration_runtime.rs);
//!  2. fast backend for the large Table-II sweeps (hundreds of federated
//!     periods × many schemes), where PJRT per-call overhead dominates;
//!  3. lets `cargo test` run without artifacts present.
//!
//! The architecture is reconstructed from the manifest's flat-param layout
//! (tensor names are the contract, see python/compile/model.py), so host and
//! XLA views can never drift silently: any layout change breaks parsing.

use anyhow::{bail, Context, Result};

use crate::util::linalg::{gemm, gemm_at, gemm_bt};
use crate::util::rng::Pcg;

/// One layer as reconstructed from the layout.
#[derive(Clone, Debug, PartialEq)]
enum Layer {
    /// y = relu?(x W + b); offsets of W [in,out] and b [out].
    Dense { name: String, w: usize, b: usize, din: usize, dout: usize, relu: bool },
    /// DenseNet concat marker: input of the next layer is concat of all
    /// previous activations (handled by the family enum below).
    /// (mini_dense is recognized structurally, not with a marker.)
    /// mini_mobile separable: dw scale [w] then pointwise dense.
    Sep { dw: usize, w: usize, b: usize, width: usize },
    /// mini_res residual pair: h = relu(h + relu(h A + a) B + b).
    Res { aw: usize, ab: usize, bw: usize, bb: usize, width: usize },
}

/// Model family tag — drives the forward/backward composition.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Family {
    Dense,
    Res,
    Mobile,
}

/// Pure-rust model instance.
#[derive(Clone, Debug)]
pub struct HostModel {
    pub name: String,
    pub family: Family,
    pub input_dim: usize,
    pub classes: usize,
    pub params: usize,
    layers: Vec<Layer>,
    head: (usize, usize, usize), // (w offset, b offset, head input width)
}

/// Flat-layout cursor: resolves (name, shape) -> offset.
struct Cursor<'a> {
    entries: &'a [(String, Vec<usize>)],
    offsets: Vec<usize>,
}

impl<'a> Cursor<'a> {
    fn new(entries: &'a [(String, Vec<usize>)]) -> Self {
        let mut offsets = Vec::with_capacity(entries.len());
        let mut off = 0;
        for (_, shape) in entries {
            offsets.push(off);
            off += shape.iter().product::<usize>();
        }
        Cursor { entries, offsets }
    }

    fn find(&self, name: &str) -> Option<(usize, &'a [usize])> {
        self.entries
            .iter()
            .position(|(n, _)| n == name)
            .map(|i| (self.offsets[i], self.entries[i].1.as_slice()))
    }
}

impl HostModel {
    /// Reconstruct the model from its manifest layout.
    pub fn from_layout(
        model: &str,
        layout: &[(String, Vec<usize>)],
        input_dim: usize,
        classes: usize,
    ) -> Result<HostModel> {
        let cur = Cursor::new(layout);
        let total: usize = layout.iter().map(|(_, s)| s.iter().product::<usize>()).sum();
        let family = match model {
            "mini_dense" => Family::Dense,
            "mini_res" => Family::Res,
            "mini_mobile" => Family::Mobile,
            other => bail!("host model: unknown family {other:?}"),
        };
        let mut layers = Vec::new();
        match family {
            Family::Dense => {
                for i in 0.. {
                    let Some((w, ws)) = cur.find(&format!("blk{i}_w")) else { break };
                    let (b, _) = cur
                        .find(&format!("blk{i}_b"))
                        .context("dense block missing bias")?;
                    layers.push(Layer::Dense {
                        name: format!("blk{i}"),
                        w,
                        b,
                        din: ws[0],
                        dout: ws[1],
                        relu: true,
                    });
                }
            }
            Family::Res => {
                let (w, ws) = cur.find("stem_w").context("missing stem_w")?;
                let (b, _) = cur.find("stem_b").context("missing stem_b")?;
                layers.push(Layer::Dense {
                    name: "stem".into(),
                    w,
                    b,
                    din: ws[0],
                    dout: ws[1],
                    relu: true,
                });
                for i in 0.. {
                    let Some((aw, aws)) = cur.find(&format!("res{i}a_w")) else { break };
                    let (ab, _) = cur.find(&format!("res{i}a_b")).context("res a_b")?;
                    let (bw, _) = cur.find(&format!("res{i}b_w")).context("res b_w")?;
                    let (bb, _) = cur.find(&format!("res{i}b_b")).context("res b_b")?;
                    layers.push(Layer::Res { aw, ab, bw, bb, width: aws[0] });
                }
            }
            Family::Mobile => {
                let (w, ws) = cur.find("stem_w").context("missing stem_w")?;
                let (b, _) = cur.find("stem_b").context("missing stem_b")?;
                layers.push(Layer::Dense {
                    name: "stem".into(),
                    w,
                    b,
                    din: ws[0],
                    dout: ws[1],
                    relu: true,
                });
                for i in 0.. {
                    let Some((dw, dws)) = cur.find(&format!("sep{i}_dw")) else { break };
                    let (w, _) = cur.find(&format!("sep{i}_w")).context("sep w")?;
                    let (b, _) = cur.find(&format!("sep{i}_b")).context("sep b")?;
                    layers.push(Layer::Sep { dw, w, b, width: dws[0] });
                }
            }
        }
        let (hw, hws) = cur.find("head_w").context("missing head_w")?;
        let (hb, _) = cur.find("head_b").context("missing head_b")?;
        if hws[1] != classes {
            bail!("head width {} != classes {classes}", hws[1]);
        }
        Ok(HostModel {
            name: model.to_string(),
            family,
            input_dim,
            classes,
            params: total,
            layers,
            head: (hw, hb, hws[0]),
        })
    }

    /// Forward pass; returns logits [n, classes] and the activation tape.
    fn forward_tape(&self, flat: &[f32], x: &[f32], n: usize) -> (Vec<f32>, Vec<Vec<f32>>) {
        let d = self.input_dim;
        debug_assert_eq!(x.len(), n * d);
        let mut tape: Vec<Vec<f32>> = vec![x.to_vec()];
        match self.family {
            Family::Dense => {
                // activation i+1 = relu(concat(tape...) W + b)
                for l in &self.layers {
                    let Layer::Dense { w, b, din, dout, .. } = l else { unreachable!() };
                    let cat = concat_rows(&tape, n);
                    debug_assert_eq!(cat.len(), n * din);
                    let mut h = bias_rows(&flat[*b..*b + *dout], n);
                    gemm(n, *din, *dout, &cat, &flat[*w..*w + din * dout], &mut h);
                    relu_inplace(&mut h);
                    tape.push(h);
                }
            }
            Family::Res => {
                for l in &self.layers {
                    match l {
                        Layer::Dense { w, b, din, dout, .. } => {
                            let x0 = tape.last().unwrap().clone();
                            let mut h = bias_rows(&flat[*b..*b + *dout], n);
                            gemm(n, *din, *dout, &x0, &flat[*w..*w + din * dout], &mut h);
                            relu_inplace(&mut h);
                            tape.push(h);
                        }
                        Layer::Res { aw, ab, bw, bb, width } => {
                            let wd = *width;
                            let h = tape.last().unwrap().clone();
                            let mut inner = bias_rows(&flat[*ab..*ab + wd], n);
                            gemm(n, wd, wd, &h, &flat[*aw..*aw + wd * wd], &mut inner);
                            relu_inplace(&mut inner);
                            tape.push(inner.clone()); // a-activation
                            let mut out = bias_rows(&flat[*bb..*bb + wd], n);
                            gemm(n, wd, wd, &inner, &flat[*bw..*bw + wd * wd], &mut out);
                            for (o, &hh) in out.iter_mut().zip(&h) {
                                *o += hh; // skip connection (pre-relu sum)
                            }
                            relu_inplace(&mut out);
                            tape.push(out);
                        }
                        _ => unreachable!(),
                    }
                }
            }
            Family::Mobile => {
                for l in &self.layers {
                    match l {
                        Layer::Dense { w, b, din, dout, .. } => {
                            let x0 = tape.last().unwrap().clone();
                            let mut h = bias_rows(&flat[*b..*b + *dout], n);
                            gemm(n, *din, *dout, &x0, &flat[*w..*w + din * dout], &mut h);
                            relu_inplace(&mut h);
                            tape.push(h);
                        }
                        Layer::Sep { dw, w, b, width } => {
                            let wd = *width;
                            let h = tape.last().unwrap().clone();
                            let scale = &flat[*dw..*dw + wd];
                            let mut dwo = vec![0f32; n * wd];
                            for i in 0..n {
                                for j in 0..wd {
                                    dwo[i * wd + j] = (h[i * wd + j] * scale[j]).max(0.0);
                                }
                            }
                            tape.push(dwo.clone()); // depthwise activation
                            let mut out = bias_rows(&flat[*b..*b + wd], n);
                            gemm(n, wd, wd, &dwo, &flat[*w..*w + wd * wd], &mut out);
                            relu_inplace(&mut out);
                            tape.push(out);
                        }
                        _ => unreachable!(),
                    }
                }
            }
        }
        // head
        let (hw, hb, hin) = self.head;
        let head_in = match self.family {
            Family::Dense => concat_rows(&tape, n),
            _ => tape.last().unwrap().clone(),
        };
        debug_assert_eq!(head_in.len(), n * hin);
        let mut logits = bias_rows(&flat[hb..hb + self.classes], n);
        gemm(n, hin, self.classes, &head_in, &flat[hw..hw + hin * self.classes], &mut logits);
        tape.push(head_in); // stash head input for backward
        (logits, tape)
    }

    /// Forward only: logits [n, classes].
    pub fn forward(&self, flat: &[f32], x: &[f32], n: usize) -> Vec<f32> {
        self.forward_tape(flat, x, n).0
    }

    /// Masked mean CE loss + correct count (mirrors masked_softmax_xent_ref).
    pub fn loss(&self, flat: &[f32], x: &[f32], y: &[i32], w: &[f32]) -> (f32, f32) {
        let n = y.len();
        let logits = self.forward(flat, x, n);
        let (loss, correct, _) = softmax_xent(&logits, y, w, self.classes);
        (loss, correct)
    }

    /// Full train step: (grads, loss, correct) — mirrors the AOT train_step.
    pub fn train_step(&self, flat: &[f32], x: &[f32], y: &[i32], w: &[f32]) -> (Vec<f32>, f32, f32) {
        let n = y.len();
        let c = self.classes;
        let (logits, tape) = self.forward_tape(flat, x, n);
        let (loss, correct, mut dlogits) = softmax_xent(&logits, y, w, c);
        let mut grads = vec![0f32; self.params];

        // head backward
        let (hw, hb, hin) = self.head;
        let head_in = tape.last().unwrap();
        gemm_at(n, hin, c, head_in, &dlogits, &mut grads[hw..hw + hin * c]);
        col_sums(&dlogits, n, c, &mut grads[hb..hb + c]);
        let mut dhead_in = vec![0f32; n * hin];
        gemm_bt(n, hin, c, &dlogits, &flat[hw..hw + hin * c], &mut dhead_in);
        dlogits.clear();

        match self.family {
            Family::Dense => self.backward_dense(flat, &tape, dhead_in, n, &mut grads),
            Family::Res => self.backward_res(flat, &tape, dhead_in, n, &mut grads),
            Family::Mobile => self.backward_mobile(flat, &tape, dhead_in, n, &mut grads),
        }
        (grads, loss, correct)
    }

    fn backward_dense(
        &self,
        flat: &[f32],
        tape: &[Vec<f32>],
        dhead_in: Vec<f32>,
        n: usize,
        grads: &mut [f32],
    ) {
        // tape: [x, h1, .., hL, head_in]; head_in = concat(x, h1..hL).
        let acts = &tape[..tape.len() - 1];
        let widths: Vec<usize> = acts.iter().map(|a| a.len() / n).collect();
        // d(activation) accumulators, seeded by splitting dhead_in.
        let mut dacts: Vec<Vec<f32>> = acts.iter().map(|a| vec![0f32; a.len()]).collect();
        split_rows(&dhead_in, n, &widths, &mut dacts, true);
        // walk blocks backward; block i consumed concat(acts[..=i]).
        for (bi, l) in self.layers.iter().enumerate().rev() {
            let Layer::Dense { w, b, din, dout, .. } = l else { unreachable!() };
            let out_idx = bi + 1;
            // relu gate
            let mut dh = dacts[out_idx].clone();
            relu_gate(&mut dh, &acts[out_idx]);
            let cat = concat_rows(&acts[..=bi].to_vec(), n);
            gemm_at(n, *din, *dout, &cat, &dh, &mut grads[*w..*w + din * dout]);
            col_sums(&dh, n, *dout, &mut grads[*b..*b + *dout]);
            let mut dcat = vec![0f32; n * din];
            gemm_bt(n, *din, *dout, &dh, &flat[*w..*w + din * dout], &mut dcat);
            split_rows(&dcat, n, &widths[..=bi], &mut dacts, true);
        }
    }

    fn backward_res(
        &self,
        flat: &[f32],
        tape: &[Vec<f32>],
        dhead_in: Vec<f32>,
        n: usize,
        grads: &mut [f32],
    ) {
        // tape: [x, stem, (a0, o0), (a1, o1), ..., head_in(copy of last o)]
        let mut dout = dhead_in; // gradient wrt current output activation
        let mut ti = tape.len() - 2; // index of last real activation
        for l in self.layers.iter().rev() {
            match l {
                Layer::Res { aw, ab, bw, bb, width } => {
                    let wd = *width;
                    let out = &tape[ti]; // relu(h + inner B + b)
                    let a_act = &tape[ti - 1]; // relu(h A + a)
                    let h = &tape[ti - 2]; // block input
                    let mut dsum = dout.clone();
                    relu_gate(&mut dsum, out);
                    // dsum flows to both skip (dh) and the B-branch
                    let mut db_in = vec![0f32; n * wd]; // d(a_act)
                    gemm_at(n, wd, wd, a_act, &dsum, &mut grads[*bw..*bw + wd * wd]);
                    col_sums(&dsum, n, wd, &mut grads[*bb..*bb + wd]);
                    gemm_bt(n, wd, wd, &dsum, &flat[*bw..*bw + wd * wd], &mut db_in);
                    relu_gate(&mut db_in, a_act);
                    gemm_at(n, wd, wd, h, &db_in, &mut grads[*aw..*aw + wd * wd]);
                    col_sums(&db_in, n, wd, &mut grads[*ab..*ab + wd]);
                    let mut dh = dsum; // skip path
                    gemm_bt(n, wd, wd, &db_in, &flat[*aw..*aw + wd * wd], &mut dh);
                    dout = dh;
                    ti -= 2;
                }
                Layer::Dense { w, b, din, dout: dd, .. } => {
                    let out = &tape[ti];
                    let x0 = &tape[ti - 1];
                    let mut dh = dout.clone();
                    relu_gate(&mut dh, out);
                    gemm_at(n, *din, *dd, x0, &dh, &mut grads[*w..*w + din * dd]);
                    col_sums(&dh, n, *dd, &mut grads[*b..*b + *dd]);
                    let mut dx = vec![0f32; n * din];
                    gemm_bt(n, *din, *dd, &dh, &flat[*w..*w + din * dd], &mut dx);
                    dout = dx;
                    ti -= 1;
                }
                _ => unreachable!(),
            }
        }
    }

    fn backward_mobile(
        &self,
        flat: &[f32],
        tape: &[Vec<f32>],
        dhead_in: Vec<f32>,
        n: usize,
        grads: &mut [f32],
    ) {
        let mut dout = dhead_in;
        let mut ti = tape.len() - 2;
        for l in self.layers.iter().rev() {
            match l {
                Layer::Sep { dw, w, b, width } => {
                    let wd = *width;
                    let out = &tape[ti]; // relu(dwo W + b)
                    let dwo = &tape[ti - 1]; // relu(h * scale)
                    let h = &tape[ti - 2];
                    let mut dh_out = dout.clone();
                    relu_gate(&mut dh_out, out);
                    gemm_at(n, wd, wd, dwo, &dh_out, &mut grads[*w..*w + wd * wd]);
                    col_sums(&dh_out, n, wd, &mut grads[*b..*b + wd]);
                    let mut ddwo = vec![0f32; n * wd];
                    gemm_bt(n, wd, wd, &dh_out, &flat[*w..*w + wd * wd], &mut ddwo);
                    relu_gate(&mut ddwo, dwo);
                    // d scale_j = sum_i h_ij * ddwo_ij ; dh_ij = scale_j * ddwo_ij
                    let scale = &flat[*dw..*dw + wd];
                    let gscale = &mut grads[*dw..*dw + wd];
                    let mut dh = vec![0f32; n * wd];
                    for i in 0..n {
                        for j in 0..wd {
                            let g = ddwo[i * wd + j];
                            gscale[j] += h[i * wd + j] * g;
                            dh[i * wd + j] = scale[j] * g;
                        }
                    }
                    dout = dh;
                    ti -= 2;
                }
                Layer::Dense { w, b, din, dout: dd, .. } => {
                    let out = &tape[ti];
                    let x0 = &tape[ti - 1];
                    let mut dh = dout.clone();
                    relu_gate(&mut dh, out);
                    gemm_at(n, *din, *dd, x0, &dh, &mut grads[*w..*w + din * dd]);
                    col_sums(&dh, n, *dd, &mut grads[*b..*b + *dd]);
                    let mut dx = vec![0f32; n * din];
                    gemm_bt(n, *din, *dd, &dh, &flat[*w..*w + din * dd], &mut dx);
                    dout = dx;
                    ti -= 1;
                }
                _ => unreachable!(),
            }
        }
    }

    /// Host-side parameter init (used when running without artifacts; NOT
    /// bit-identical to the jax init — tests that compare against XLA pass
    /// explicit params instead).
    pub fn init_params_host(&self, layout: &[(String, Vec<usize>)], seed: u64) -> Vec<f32> {
        let mut rng = Pcg::seeded(seed);
        let mut out = Vec::with_capacity(self.params);
        for (name, shape) in layout {
            let sz: usize = shape.iter().product();
            if name.ends_with("_b") {
                out.extend(std::iter::repeat(0f32).take(sz));
            } else if name.ends_with("_dw") {
                out.extend(std::iter::repeat(1f32).take(sz));
            } else {
                let fan_in = shape[0] as f64;
                let fan_out = *shape.last().unwrap() as f64;
                let s = (2.0 / (fan_in + fan_out)).sqrt();
                out.extend((0..sz).map(|_| (rng.normal() * s) as f32));
            }
        }
        out
    }
}

// -- shared numeric helpers --------------------------------------------------

fn relu_inplace(h: &mut [f32]) {
    for v in h {
        if *v < 0.0 {
            *v = 0.0;
        }
    }
}

/// Gate dh by relu'(out): out > 0 passes (out is the post-relu activation).
fn relu_gate(dh: &mut [f32], out: &[f32]) {
    for (d, &o) in dh.iter_mut().zip(out) {
        if o <= 0.0 {
            *d = 0.0;
        }
    }
}

/// Replicate bias to n rows.
fn bias_rows(bias: &[f32], n: usize) -> Vec<f32> {
    let d = bias.len();
    let mut out = vec![0f32; n * d];
    for i in 0..n {
        out[i * d..(i + 1) * d].copy_from_slice(bias);
    }
    out
}

/// Row-wise concat of per-activation matrices (all n rows).
fn concat_rows(parts: &[Vec<f32>], n: usize) -> Vec<f32> {
    let widths: Vec<usize> = parts.iter().map(|p| p.len() / n).collect();
    let total: usize = widths.iter().sum();
    let mut out = vec![0f32; n * total];
    for i in 0..n {
        let mut off = 0;
        for (p, &w) in parts.iter().zip(&widths) {
            out[i * total + off..i * total + off + w].copy_from_slice(&p[i * w..(i + 1) * w]);
            off += w;
        }
    }
    out
}

/// Split row-concatenated gradient back into per-activation pieces,
/// accumulating (+=) into dacts[0..widths.len()].
fn split_rows(cat: &[f32], n: usize, widths: &[usize], dacts: &mut [Vec<f32>], accumulate: bool) {
    let total: usize = widths.iter().sum();
    debug_assert_eq!(cat.len(), n * total);
    for i in 0..n {
        let mut off = 0;
        for (k, &w) in widths.iter().enumerate() {
            let src = &cat[i * total + off..i * total + off + w];
            let dst = &mut dacts[k][i * w..(i + 1) * w];
            if accumulate {
                for (d, &s) in dst.iter_mut().zip(src) {
                    *d += s;
                }
            } else {
                dst.copy_from_slice(src);
            }
            off += w;
        }
    }
}

/// Column sums of d [n, c] accumulated into out [c].
fn col_sums(d: &[f32], n: usize, c: usize, out: &mut [f32]) {
    for i in 0..n {
        for j in 0..c {
            out[j] += d[i * c + j];
        }
    }
}

/// Masked softmax CE: returns (mean loss, correct count, dlogits [n,c]).
fn softmax_xent(logits: &[f32], y: &[i32], w: &[f32], c: usize) -> (f32, f32, Vec<f32>) {
    let n = y.len();
    debug_assert_eq!(logits.len(), n * c);
    let denom = w.iter().sum::<f32>().max(1.0);
    let mut loss = 0f32;
    let mut correct = 0f32;
    let mut dlogits = vec![0f32; n * c];
    for i in 0..n {
        let row = &logits[i * c..(i + 1) * c];
        let zmax = row.iter().copied().fold(f32::NEG_INFINITY, f32::max);
        let mut sum = 0f32;
        for &v in row {
            sum += (v - zmax).exp();
        }
        let lse = sum.ln();
        let yi = y[i] as usize;
        loss += w[i] * (lse - (row[yi] - zmax));
        // NaN-safe argmax: total_cmp orders NaN consistently instead of
        // panicking mid-experiment when a run diverges.
        let argmax = row
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.total_cmp(b.1))
            .unwrap()
            .0;
        if argmax == yi {
            correct += w[i];
        }
        let coef = w[i] / denom;
        for j in 0..c {
            let p = (row[j] - zmax).exp() / sum;
            dlogits[i * c + j] = coef * (p - if j == yi { 1.0 } else { 0.0 });
        }
    }
    (loss / denom, correct, dlogits)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn layout_dense() -> Vec<(String, Vec<usize>)> {
        // tiny mini_dense: D=6, growth=4, blocks=2, classes=3
        vec![
            ("blk0_w".into(), vec![6, 4]),
            ("blk0_b".into(), vec![4]),
            ("blk1_w".into(), vec![10, 4]),
            ("blk1_b".into(), vec![4]),
            ("head_w".into(), vec![14, 3]),
            ("head_b".into(), vec![3]),
        ]
    }

    fn layout_res() -> Vec<(String, Vec<usize>)> {
        vec![
            ("stem_w".into(), vec![6, 5]),
            ("stem_b".into(), vec![5]),
            ("res0a_w".into(), vec![5, 5]),
            ("res0a_b".into(), vec![5]),
            ("res0b_w".into(), vec![5, 5]),
            ("res0b_b".into(), vec![5]),
            ("head_w".into(), vec![5, 3]),
            ("head_b".into(), vec![3]),
        ]
    }

    fn layout_mobile() -> Vec<(String, Vec<usize>)> {
        vec![
            ("stem_w".into(), vec![6, 5]),
            ("stem_b".into(), vec![5]),
            ("sep0_dw".into(), vec![5]),
            ("sep0_w".into(), vec![5, 5]),
            ("sep0_b".into(), vec![5]),
            ("head_w".into(), vec![5, 3]),
            ("head_b".into(), vec![3]),
        ]
    }

    fn rand_params(m: &HostModel, layout: &[(String, Vec<usize>)], seed: u64) -> Vec<f32> {
        // random (not glorot-zero) so grads flow everywhere incl. biases
        let mut r = Pcg::seeded(seed);
        let mut p = m.init_params_host(layout, seed);
        for v in &mut p {
            *v += 0.1 * r.normal() as f32;
        }
        p
    }

    fn batch(n: usize, d: usize, c: usize, seed: u64) -> (Vec<f32>, Vec<i32>, Vec<f32>) {
        let mut r = Pcg::seeded(seed);
        let x: Vec<f32> = (0..n * d).map(|_| r.normal() as f32).collect();
        let y: Vec<i32> = (0..n).map(|_| r.below(c as u64) as i32).collect();
        let mut w = vec![1f32; n];
        if n > 2 {
            w[n - 1] = 0.0; // exercise masking
        }
        (x, y, w)
    }

    /// Central-difference gradient check on a random subset of parameters.
    fn grad_check(model: &str, layout: Vec<(String, Vec<usize>)>) {
        let (d, c) = (6, 3);
        let m = HostModel::from_layout(model, &layout, d, c).unwrap();
        let p = rand_params(&m, &layout, 1);
        let (x, y, w) = batch(5, d, c, 2);
        let (g, _, _) = m.train_step(&p, &x, &y, &w);
        let mut rng = Pcg::seeded(3);
        let eps = 1e-3f32;
        let mut checked = 0;
        for _ in 0..40 {
            let i = rng.below(m.params as u64) as usize;
            let mut pp = p.clone();
            pp[i] += eps;
            let (lp, _) = m.loss(&pp, &x, &y, &w);
            pp[i] -= 2.0 * eps;
            let (lm, _) = m.loss(&pp, &x, &y, &w);
            let num = (lp - lm) / (2.0 * eps);
            assert!(
                (num - g[i]).abs() < 2e-3 + 0.05 * num.abs().max(g[i].abs()),
                "{model} param {i}: numeric {num} vs analytic {}",
                g[i]
            );
            checked += 1;
        }
        assert_eq!(checked, 40);
    }

    #[test]
    fn grad_check_dense() {
        grad_check("mini_dense", layout_dense());
    }

    #[test]
    fn grad_check_res() {
        grad_check("mini_res", layout_res());
    }

    #[test]
    fn grad_check_mobile() {
        grad_check("mini_mobile", layout_mobile());
    }

    #[test]
    fn mask_zero_rows_have_no_effect() {
        let layout = layout_res();
        let m = HostModel::from_layout("mini_res", &layout, 6, 3).unwrap();
        let p = rand_params(&m, &layout, 7);
        let (x, y, _) = batch(4, 6, 3, 8);
        let w_all = vec![1f32, 1.0, 1.0, 0.0];
        let (g1, l1, _) = m.train_step(&p, &x, &y, &w_all);
        // change the masked row's data: nothing may move
        let mut x2 = x.clone();
        for v in &mut x2[3 * 6..4 * 6] {
            *v = 99.0;
        }
        let (g2, l2, _) = m.train_step(&p, &x2, &y, &w_all);
        assert_eq!(l1, l2);
        assert_eq!(g1, g2);
    }

    #[test]
    fn loss_decreases_under_sgd() {
        let layout = layout_dense();
        let m = HostModel::from_layout("mini_dense", &layout, 6, 3).unwrap();
        let mut p = rand_params(&m, &layout, 11);
        let (x, y, w) = batch(16, 6, 3, 12);
        let (_, l0, _) = m.train_step(&p, &x, &y, &w);
        for _ in 0..50 {
            let (g, _, _) = m.train_step(&p, &x, &y, &w);
            for (pv, gv) in p.iter_mut().zip(&g) {
                *pv -= 0.5 * gv;
            }
        }
        let (_, l1, _) = m.train_step(&p, &x, &y, &w);
        assert!(l1 < l0 * 0.5, "loss {l0} -> {l1}");
    }

    #[test]
    fn rejects_unknown_family() {
        assert!(HostModel::from_layout("resnet50", &layout_res(), 6, 3).is_err());
    }

    #[test]
    fn param_count_matches_layout() {
        let layout = layout_mobile();
        let m = HostModel::from_layout("mini_mobile", &layout, 6, 3).unwrap();
        let want: usize = layout.iter().map(|(_, s)| s.iter().product::<usize>()).sum();
        assert_eq!(m.params, want);
    }
}
