//! Runtime layer: the bridge from the rust coordinator to the AOT-compiled
//! XLA computations (PJRT CPU client via the `xla` crate).
//!
//! `manifest` parses the artifact registry written by `python/compile/aot.py`;
//! `client` compiles + executes the HLO; `hostmodel` is a pure-rust oracle of
//! the same models used by tests and by runs without artifacts.

pub mod client;
pub mod hostmodel;
pub mod manifest;
pub(crate) mod xla_stub;

pub use client::{EvalOut, Runtime, RuntimeStats, StepOut};
pub use manifest::{Artifact, Kind, Manifest, ModelMeta};
