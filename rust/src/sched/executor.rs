//! Per-policy round execution on the event queue.
//!
//! The trainer plans a period (scheme.rs), then hands the plan here. The
//! scheduler turns the plan's per-device nominal finish times plus the
//! straggler perturbations into completion events, drains the queue
//! according to the round policy, and folds the surviving contributions
//! into the caller's per-family server-side [`Aggregator`]s (one per
//! model family of the fleet's `BackendSet`). All simulated-time
//! arithmetic stays in here and is returned as `RoundReport::duration`;
//! the trainer owns the `SimClock` and is the only place that advances it.
//!
//! Determinism: event times are computed on the coordinator thread from
//! counter-derived straggler draws, the queue pops in `(time, device)`
//! order, and gradient execution goes through the `exec` rounds whose
//! results land in device-ordered slots — so every policy produces
//! bitwise-identical `TrainLog` records at any thread count.

use std::time::Instant;

use anyhow::{bail, Result};

use super::policy::RoundPolicy;
use super::queue::{Event, EventQueue};
use crate::coordinator::fleet_backends::BackendSet;
use crate::coordinator::scheme::Plan;
use crate::coordinator::worker::Worker;
use crate::data::Dataset;
use crate::device::StragglerModel;
use crate::exec::{self, Engine};
use crate::fault::FaultPlan;
use crate::grad::{Aggregator, GradGuard};
use crate::obs::{ObsSink, Outcome};
use crate::opt::types::Instance;

/// One buffered async contribution, computed at dispatch time against the
/// then-current global parameters and held until its completion event.
struct Pending {
    grad: Vec<f32>,
    batch: usize,
    loss: f64,
    /// the period the gradient was computed in (staleness anchor)
    period: u64,
}

/// What one scheduled round did, for the trainer's bookkeeping.
#[derive(Clone, Copy, Debug)]
pub struct RoundReport {
    /// simulated seconds this period took end-to-end (incl. downlink)
    pub duration: f64,
    /// batch-weighted mean train loss over the *applied* gradients
    /// (NaN when nothing arrived — the trainer carries the previous loss)
    pub train_loss: f64,
    /// total batch actually applied this period (drives xi estimation)
    pub b_effective: usize,
    /// gradients applied this period
    pub applied: usize,
    /// devices lost to dropout this period
    pub dropped: usize,
    /// devices that missed the deadline (their batch is carried forward)
    pub late: usize,
    /// batch-weighted mean staleness of the applied gradients (async)
    pub stale_mean: f64,
    /// devices unreachable this period (fault-injected crash windows)
    pub crashed: usize,
    /// contributions whose payload was detected corrupt this period
    pub corrupt: usize,
    /// corrupt contributions the quarantine rejected or clipped
    pub quarantined: usize,
    /// whether any gradient entered the aggregate (callers skip the
    /// server update otherwise)
    pub updated: bool,
    /// wall seconds spent in the serial merge section (perf telemetry
    /// only — never feeds back into results)
    pub reduce_secs: f64,
}

/// Visit each participating device id: the sampled subset when one is
/// given (`device::ClientSampler` output — strictly ascending), the whole
/// fleet otherwise. Keeps the full-participation path allocation-free.
fn for_each_participant(k: usize, participants: Option<&[usize]>, mut f: impl FnMut(usize)) {
    match participants {
        Some(ids) => ids.iter().for_each(|&d| f(d)),
        None => (0..k).for_each(&mut f),
    }
}

/// One buffered in-flight contribution in serializable form — the
/// checkpoint image of a [`Pending`] event.
#[derive(Clone, Debug, PartialEq)]
pub struct InflightRecord {
    /// absolute completion time of the upload
    pub time: f64,
    pub device: usize,
    /// the period the gradient was computed in (staleness anchor)
    pub period: u64,
    pub batch: usize,
    pub loss: f64,
    pub grad: Vec<f32>,
}

/// Serializable scheduler state: the cross-period pieces a resumed run
/// must restore for bitwise replay (carry ledger, busy flags, async
/// in-flight queue). Records are in the queue's canonical (time, device)
/// pop order.
#[derive(Clone, Debug, PartialEq)]
pub struct SchedCheckpoint {
    pub carry: Vec<usize>,
    pub busy: Vec<bool>,
    pub inflight: Vec<InflightRecord>,
}

/// Policy-driven round scheduler. Owns the cross-period event queue (async
/// in-flight work), per-device busy flags, and the deadline carry ledger.
pub struct RoundScheduler {
    policy: RoundPolicy,
    straggler: StragglerModel,
    fault: FaultPlan,
    guard: GradGuard,
    seed: u64,
    /// in-flight async contributions, keyed by absolute completion time
    inflight: EventQueue<Pending>,
    busy: Vec<bool>,
    /// per-device batch deferred by a missed deadline, re-planned into the
    /// device's next period (capped at its batch ceiling)
    carry: Vec<usize>,
}

impl RoundScheduler {
    pub fn new(
        policy: RoundPolicy,
        straggler: StragglerModel,
        fault: FaultPlan,
        guard: GradGuard,
        k: usize,
        seed: u64,
    ) -> Result<RoundScheduler> {
        policy.validate()?;
        Ok(RoundScheduler {
            policy,
            straggler,
            fault,
            guard,
            seed,
            inflight: EventQueue::new(),
            busy: vec![false; k],
            carry: vec![0; k],
        })
    }

    pub fn policy(&self) -> RoundPolicy {
        self.policy
    }

    /// Devices whose deadline-missed batch is pending re-planning.
    pub fn carried(&self) -> &[usize] {
        &self.carry
    }

    /// Snapshot the cross-period state for a checkpoint. In-flight events
    /// are emitted in the queue's (time, device) pop order, so the image
    /// is canonical whatever the internal heap layout.
    pub fn snapshot(&self) -> SchedCheckpoint {
        let inflight = self
            .inflight
            .events_sorted()
            .into_iter()
            .map(|e| InflightRecord {
                time: e.time,
                device: e.device,
                period: e.payload.period,
                batch: e.payload.batch,
                loss: e.payload.loss,
                grad: e.payload.grad.clone(),
            })
            .collect();
        SchedCheckpoint { carry: self.carry.clone(), busy: self.busy.clone(), inflight }
    }

    /// Restore a [`SchedCheckpoint`] into this scheduler, replacing the
    /// carry ledger, busy flags, and in-flight queue wholesale.
    pub fn restore(&mut self, ck: SchedCheckpoint) -> Result<()> {
        if ck.carry.len() != self.carry.len() || ck.busy.len() != self.busy.len() {
            bail!(
                "scheduler checkpoint is for a {}-device fleet, this run has {}",
                ck.carry.len().max(ck.busy.len()),
                self.carry.len()
            );
        }
        self.carry = ck.carry;
        self.busy = ck.busy;
        self.inflight.clear();
        for r in ck.inflight {
            self.inflight.push(
                r.time,
                r.device,
                Pending { grad: r.grad, batch: r.batch, loss: r.loss, period: r.period },
            );
        }
        Ok(())
    }

    /// Wipe the carry of any device rejoining from a *cold* crash this
    /// period: a cold rejoin lost its local state, deferred batch
    /// included. A warm rejoin keeps its ledger entry. No-op when crash
    /// injection is off (zero RNG draws, bitwise-identical run).
    fn wipe_cold_rejoin_carry(&mut self, period: u64) {
        if self.fault.crash_rate <= 0.0 {
            return;
        }
        for (k, c) in self.carry.iter_mut().enumerate() {
            if *c > 0 && self.fault.rejoined_cold(self.seed, period, k as u64) {
                *c = 0;
            }
        }
    }

    /// Fold the deadline carry ledger into this period's plan: each
    /// deferred batch is added to its device's planned batch and the
    /// device's nominal finish time extended by the extra compute. Growth
    /// is capped twice — at the device's batch ceiling AND at the compute
    /// it can still fit before this period's deadline — so a carried
    /// device always remains able to arrive on time at nominal speed
    /// (otherwise a large carry would deterministically re-miss every
    /// period and the device would livelock out of the training run).
    /// Carry beyond the caps is forfeited. A crashed device's carry stays
    /// in the ledger until it rejoins (wiped if the rejoin is cold).
    /// No-op for non-deadline policies. The plan's predicted compute grows
    /// with the finish time so the audit row still reflects what was
    /// actually scheduled.
    pub fn apply_carry(&mut self, plan: &mut Plan, inst: &Instance, period: u64) {
        self.wipe_cold_rejoin_carry(period);
        let RoundPolicy::Deadline { factor } = self.policy else {
            return;
        };
        let deadline = plan.t_up * factor;
        for (k, c) in self.carry.iter_mut().enumerate() {
            if *c == 0 {
                continue;
            }
            if self.fault.crash_rate > 0.0 && self.fault.is_down(self.seed, period, k as u64) {
                continue; // unreachable this period; ledger entry survives
            }
            let d = &inst.devices[k];
            let cap = (d.b_max.floor() as usize).max(plan.batches[k]);
            // compute headroom before the deadline, in samples
            let headroom = ((deadline - plan.finish[k]).max(0.0) * d.speed).floor() as usize;
            let grown = (plan.batches[k] + (*c).min(headroom)).min(cap);
            let added = grown - plan.batches[k];
            if added > 0 {
                plan.batches[k] = grown;
                plan.finish[k] += added as f64 / d.speed;
                if let Some(pt) = plan.predicted.get_mut(k) {
                    pt.compute += added as f64 / d.speed;
                }
            }
            *c = 0; // a re-miss re-adds the (grown) batch
        }
    }

    /// Sampled-round form of [`RoundScheduler::apply_carry`]: `plan` is
    /// global-indexed (zeros outside the sample) while `inst.devices[i]`
    /// describes global device `ids[i]` — the optimizer solved over the
    /// participants only. Carry owned by devices *outside* this round's
    /// sample stays in the ledger until they are drawn again.
    pub fn apply_carry_sampled(
        &mut self,
        plan: &mut Plan,
        inst: &Instance,
        ids: &[usize],
        period: u64,
    ) {
        self.wipe_cold_rejoin_carry(period);
        let RoundPolicy::Deadline { factor } = self.policy else {
            return;
        };
        let deadline = plan.t_up * factor;
        for (i, &g) in ids.iter().enumerate() {
            if self.fault.crash_rate > 0.0 && self.fault.is_down(self.seed, period, g as u64) {
                continue;
            }
            let c = &mut self.carry[g];
            if *c == 0 {
                continue;
            }
            let d = &inst.devices[i];
            let cap = (d.b_max.floor() as usize).max(plan.batches[g]);
            let headroom = ((deadline - plan.finish[g]).max(0.0) * d.speed).floor() as usize;
            let grown = (plan.batches[g] + (*c).min(headroom)).min(cap);
            let added = grown - plan.batches[g];
            if added > 0 {
                plan.batches[g] = grown;
                plan.finish[g] += added as f64 / d.speed;
                if let Some(pt) = plan.predicted.get_mut(g) {
                    pt.compute += added as f64 / d.speed;
                }
            }
            *c = 0;
        }
    }

    /// Execute one gradient-exchange period under the configured policy.
    /// `period` is the round's RNG/staleness coordinate (the trainer's
    /// `server.period` before the post-round increment), `now` the current
    /// simulated time, and `aggs` the caller's reset server accumulators —
    /// one per model family (`BackendSet` order), exactly one for a
    /// homogeneous fleet. `participants` restricts the round to a sampled
    /// subset of device ids (strictly ascending, as produced by
    /// `device::ClientSampler`); `None` is the legacy full-participation
    /// path and stays bitwise-identical to it.
    #[allow(clippy::too_many_arguments)]
    pub fn gradient_period(
        &mut self,
        engine: &Engine,
        backends: &BackendSet<'_>,
        workers: &mut [Worker],
        params: &[Vec<f32>],
        train: &Dataset,
        plan: &Plan,
        period: u64,
        now: f64,
        participants: Option<&[usize]>,
        aggs: &mut [Aggregator],
        obs: &mut ObsSink,
    ) -> Result<RoundReport> {
        debug_assert_eq!(workers.len(), self.busy.len(), "fleet size changed under scheduler");
        if aggs.len() != backends.family_count() {
            anyhow::bail!(
                "{} server accumulators for {} model families",
                aggs.len(),
                backends.family_count()
            );
        }
        if let Some(ids) = participants {
            let k = workers.len();
            let ascending = ids.windows(2).all(|w| w[0] < w[1]);
            if ids.is_empty() || !ascending || ids.last().is_some_and(|&d| d >= k) {
                anyhow::bail!("participant ids must be non-empty, ascending, and < fleet size");
            }
        }
        match self.policy {
            RoundPolicy::Sync => self.barrier_period(
                engine,
                backends,
                workers,
                params,
                train,
                plan,
                period,
                now,
                participants,
                aggs,
                obs,
            ),
            RoundPolicy::Deadline { factor } => self.deadline_period(
                factor,
                engine,
                backends,
                workers,
                params,
                train,
                plan,
                period,
                now,
                participants,
                aggs,
                obs,
            ),
            RoundPolicy::Async { alpha, beta, quorum } => self.async_period(
                alpha,
                beta,
                quorum,
                engine,
                backends,
                workers,
                params,
                train,
                plan,
                period,
                now,
                participants,
                aggs,
                obs,
            ),
        }
    }

    /// Sync: the paper's barrier, expressed as "drain the event queue".
    /// With the straggler model inactive every arrival is the plan's
    /// clamped nominal finish, so the barrier lands exactly on the plan's
    /// uplink makespan and the period duration reproduces `plan.t_period`
    /// bitwise. A dropped device is detected at the nominal makespan and
    /// excluded from the reduce; the barrier still waits for every
    /// surviving straggler.
    #[allow(clippy::too_many_arguments)]
    fn barrier_period(
        &mut self,
        engine: &Engine,
        backends: &BackendSet<'_>,
        workers: &mut [Worker],
        params: &[Vec<f32>],
        train: &Dataset,
        plan: &Plan,
        period: u64,
        now: f64,
        participants: Option<&[usize]>,
        aggs: &mut [Aggregator],
        obs: &mut ObsSink,
    ) -> Result<RoundReport> {
        let k = workers.len();
        let m = participants.map_or(k, <[usize]>::len);
        let mut queue: EventQueue<()> = EventQueue::new();
        // full participation starts all-true (a `None` mask if nobody
        // drops); a sampled round starts all-false and admits participants
        let mut mask = vec![participants.is_none(); k];
        let mut dropped = 0usize;
        let mut crashed = 0usize;
        // devices whose upload arrives corrupt: they pace the barrier like
        // any arrival but leave the clean sharded fold — their payloads
        // are computed, contaminated, and screened separately below.
        // Ascending device order (the participant walk is ascending).
        let mut corrupt_jobs: Vec<(usize, usize)> = Vec::new();
        let fault_on = self.fault.device_faults_active();
        let fault = &self.fault;
        let straggler = &self.straggler;
        let seed = self.seed;
        let obs = &mut *obs;
        for_each_participant(k, participants, |d| {
            if fault_on && fault.is_down(seed, period, d as u64) {
                mask[d] = false;
                crashed += 1;
                obs.instant("crash", "fault", d + 1, now);
                obs.audit_outcome(d, Outcome::Crashed);
                return;
            }
            let pert = straggler.sample(seed, period, d as u64);
            if pert.dropped {
                mask[d] = false;
                dropped += 1;
                obs.instant("drop", "straggler", d + 1, now);
                obs.audit_outcome(d, Outcome::Dropped);
                return;
            }
            let dur = plan.finish[d] * pert.slowdown;
            obs.span_arg("round", "device", d + 1, now, dur, &[("batch", plan.batches[d] as f64)]);
            obs.observe("round.arrival_latency", dur);
            obs.audit_arrival(d, dur);
            let corrupt = if fault_on { fault.corrupts(seed, period, d as u64) } else { None };
            match corrupt {
                Some(kind) => {
                    mask[d] = false;
                    corrupt_jobs.push((d, plan.batches[d].max(1)));
                    queue.push(dur, d, ());
                    obs.instant_label("corrupt", "fault", d + 1, now + dur, "kind", kind.label());
                }
                None => {
                    mask[d] = true;
                    queue.push(dur, d, ());
                    obs.audit_outcome(d, Outcome::Applied);
                }
            }
        });
        // the fold below is commutative, so the queue's total order buys
        // no extra determinism here — sync runs on the queue so all three
        // policies share one event representation (and one code path to
        // audit), not because pop order matters to a barrier
        let mut barrier = plan.t_up;
        while let Some(e) = queue.pop() {
            barrier = barrier.max(e.time);
        }
        obs.instant("barrier_close", "round", 0, now + barrier);
        let excluded = dropped + crashed + corrupt_jobs.len();
        let mask_opt = if participants.is_some() || excluded > 0 { Some(&mask[..]) } else { None };
        let (mut loss_acc, mut w_acc, reduce_secs) = self.run_masked(
            engine, backends, workers, params, train, plan, mask_opt, period, aggs,
        )?;
        let (c_loss, c_w, rejected) = self.apply_corrupt_jobs(
            engine, backends, workers, params, train, &corrupt_jobs, period, aggs, now + barrier,
            obs,
        )?;
        loss_acc += c_loss;
        w_acc += c_w;
        let planned: usize = plan.batches.iter().sum();
        Ok(RoundReport {
            duration: barrier + plan.t_down,
            train_loss: if w_acc > 0.0 { loss_acc / w_acc } else { f64::NAN },
            b_effective: if dropped + crashed + rejected == 0 { planned } else { w_acc as usize },
            applied: m - dropped - crashed - rejected,
            dropped,
            late: 0,
            stale_mean: 0.0,
            crashed,
            corrupt: aggs.iter().map(Aggregator::corrupt_contributions).sum(),
            quarantined: aggs.iter().map(Aggregator::quarantined_contributions).sum(),
            updated: aggs.iter().any(|a| a.contributions() > 0),
            reduce_secs,
        })
    }

    /// Deadline: pop arrivals up to `factor * t_up`; later events are
    /// discarded from the reduce and their planned batch carried into the
    /// device's next period. Crash detection matches the sync barrier's
    /// model — a dropped device is noticed at the nominal makespan `t_up`
    /// — so a round only waits out the full deadline when a *straggler*
    /// actually misses it. Period-for-period a deadline round therefore
    /// never closes after the barrier would have.
    #[allow(clippy::too_many_arguments)]
    fn deadline_period(
        &mut self,
        factor: f64,
        engine: &Engine,
        backends: &BackendSet<'_>,
        workers: &mut [Worker],
        params: &[Vec<f32>],
        train: &Dataset,
        plan: &Plan,
        period: u64,
        now: f64,
        participants: Option<&[usize]>,
        aggs: &mut [Aggregator],
        obs: &mut ObsSink,
    ) -> Result<RoundReport> {
        let k = workers.len();
        let m = participants.map_or(k, <[usize]>::len);
        let deadline = plan.t_up * factor;
        let mut queue: EventQueue<()> = EventQueue::new();
        let mut mask = vec![false; k];
        let mut dropped = 0usize;
        let mut crashed = 0usize;
        let fault_on = self.fault.device_faults_active();
        let fault = &self.fault;
        let straggler = &self.straggler;
        let seed = self.seed;
        {
            let obs = &mut *obs;
            for_each_participant(k, participants, |d| {
                if fault_on && fault.is_down(seed, period, d as u64) {
                    crashed += 1;
                    obs.instant("crash", "fault", d + 1, now);
                    obs.audit_outcome(d, Outcome::Crashed);
                    return;
                }
                let pert = straggler.sample(seed, period, d as u64);
                if pert.dropped {
                    dropped += 1;
                    obs.instant("drop", "straggler", d + 1, now);
                    obs.audit_outcome(d, Outcome::Dropped);
                } else {
                    queue.push(plan.finish[d] * pert.slowdown, d, ());
                }
            });
        }
        let mut late = 0usize;
        let mut arrived = 0usize;
        let mut t_close = 0f64;
        // corrupt on-time arrivals pace the round like any other but are
        // screened outside the clean fold; collected in pop order, sorted
        // back to device order for the subset executor
        let mut corrupt_jobs: Vec<(usize, usize)> = Vec::new();
        while let Some(e) = queue.pop() {
            let d = e.device;
            obs.span_arg("round", "device", d + 1, now, e.time, &[("batch", plan.batches[d] as f64)]);
            if e.time <= deadline {
                arrived += 1;
                t_close = t_close.max(e.time);
                obs.observe("round.arrival_latency", e.time);
                obs.audit_arrival(d, e.time);
                let corrupt = if fault_on { fault.corrupts(seed, period, d as u64) } else { None };
                match corrupt {
                    Some(kind) => {
                        corrupt_jobs.push((d, plan.batches[d].max(1)));
                        obs.instant_label(
                            "corrupt",
                            "fault",
                            d + 1,
                            now + e.time,
                            "kind",
                            kind.label(),
                        );
                    }
                    None => {
                        mask[d] = true;
                        obs.audit_outcome(d, Outcome::Applied);
                    }
                }
            } else {
                late += 1;
                let carried = plan.batches[d].max(1);
                self.carry[d] += carried;
                obs.audit_arrival(d, e.time);
                obs.audit_outcome(d, Outcome::Late);
                obs.audit_carry(d, carried);
                obs.instant_arg(
                    "deadline_miss",
                    "sched",
                    d + 1,
                    now + deadline,
                    &[("arrival", e.time), ("carry_batches", carried as f64)],
                );
                obs.inc("sched.carry_batches", carried as u64);
            }
        }
        corrupt_jobs.sort_unstable();
        if dropped > 0 || crashed > 0 {
            t_close = t_close.max(plan.t_up);
        }
        if late > 0 {
            t_close = deadline;
        }
        obs.instant("deadline_close", "round", 0, now + t_close);
        let all_in = participants.is_none() && arrived == k && corrupt_jobs.is_empty();
        let mask_opt = if all_in { None } else { Some(&mask[..]) };
        let (mut loss_acc, mut w_acc, reduce_secs) = self.run_masked(
            engine, backends, workers, params, train, plan, mask_opt, period, aggs,
        )?;
        let (c_loss, c_w, rejected) = self.apply_corrupt_jobs(
            engine, backends, workers, params, train, &corrupt_jobs, period, aggs, now + t_close,
            obs,
        )?;
        loss_acc += c_loss;
        w_acc += c_w;
        let planned: usize = plan.batches.iter().sum();
        Ok(RoundReport {
            duration: t_close + plan.t_down,
            train_loss: if w_acc > 0.0 { loss_acc / w_acc } else { f64::NAN },
            b_effective: if arrived == m && rejected == 0 { planned } else { w_acc as usize },
            applied: arrived - rejected,
            dropped,
            late,
            stale_mean: 0.0,
            crashed,
            corrupt: aggs.iter().map(Aggregator::corrupt_contributions).sum(),
            quarantined: aggs.iter().map(Aggregator::quarantined_contributions).sum(),
            updated: aggs.iter().any(|a| a.contributions() > 0),
            reduce_secs,
        })
    }

    /// Async: dispatch every idle device against the current parameters,
    /// then close the round at the quorum-th arrival in the cross-period
    /// queue. Busy devices keep computing; their gradients land in a later
    /// round discounted by `alpha / (1 + s)^beta`.
    #[allow(clippy::too_many_arguments)]
    fn async_period(
        &mut self,
        alpha: f64,
        beta: f64,
        quorum: f64,
        engine: &Engine,
        backends: &BackendSet<'_>,
        workers: &mut [Worker],
        params: &[Vec<f32>],
        train: &Dataset,
        plan: &Plan,
        period: u64,
        now: f64,
        participants: Option<&[usize]>,
        aggs: &mut [Aggregator],
        obs: &mut ObsSink,
    ) -> Result<RoundReport> {
        let k = workers.len();
        let m = participants.map_or(k, <[usize]>::len);
        // 0. crash pass: a device that is down this period loses whatever
        //    it had in flight (the upload dies with it) and cannot be
        //    dispatched. Counted once per down participant.
        let mut crashed = 0usize;
        if self.fault.crash_rate > 0.0 {
            let fault = &self.fault;
            let seed = self.seed;
            let mut killed: Vec<(usize, u64)> = Vec::new();
            self.inflight.retain(|e| {
                if fault.is_down(seed, period, e.device as u64) {
                    killed.push((e.device, e.payload.period));
                    false
                } else {
                    true
                }
            });
            for (d, src) in killed {
                self.busy[d] = false;
                obs.instant("inflight_lost", "fault", d + 1, now);
                obs.inc("fault.inflight_lost", 1);
                obs.audit_resolve(d, src, Outcome::Crashed, None);
            }
        }
        // 1. dispatch idle devices (device order; a dropped device loses
        //    this period's work and is re-dispatched next period — sampled
        //    rounds only dispatch this round's draw, but a busy device that
        //    fell out of the sample still completes and lands stale)
        let mut jobs: Vec<(usize, usize)> = Vec::new();
        let mut arrivals: Vec<f64> = Vec::new();
        let mut dropped = 0usize;
        let fault_on = self.fault.device_faults_active();
        let fault = &self.fault;
        let busy = &self.busy;
        let straggler = &self.straggler;
        let seed = self.seed;
        {
            let obs = &mut *obs;
            for_each_participant(k, participants, |d| {
                if fault_on && fault.is_down(seed, period, d as u64) {
                    crashed += 1;
                    obs.instant("crash", "fault", d + 1, now);
                    obs.audit_outcome(d, Outcome::Crashed);
                    return;
                }
                if busy[d] {
                    return;
                }
                let pert = straggler.sample(seed, period, d as u64);
                if pert.dropped {
                    dropped += 1;
                    obs.instant("drop", "straggler", d + 1, now);
                    obs.audit_outcome(d, Outcome::Dropped);
                    return;
                }
                let dur = plan.finish[d] * pert.slowdown;
                obs.span_arg(
                    "round",
                    "device",
                    d + 1,
                    now,
                    dur,
                    &[("batch", plan.batches[d] as f64)],
                );
                // outcome stays Pending until the upload lands in a later
                // round's quorum (resolved there against this source row)
                obs.audit_arrival(d, dur);
                jobs.push((d, plan.batches[d].max(1)));
                arrivals.push(now + dur);
            });
        }
        if !jobs.is_empty() {
            let outcomes = exec::gradient_round_subset(
                engine, backends, workers, params, train, &jobs, self.seed, period,
            )?;
            for ((&(dev, batch), &at), mut o) in jobs.iter().zip(&arrivals).zip(outcomes) {
                // corruption strikes the upload as it leaves the device —
                // at dispatch, against the dispatch period's draw — and is
                // only *detected* when the payload reaches the aggregator
                if fault_on {
                    if let Some(kind) = self.fault.corrupts(self.seed, period, dev as u64) {
                        self.fault.contaminate(self.seed, period, dev as u64, kind, &mut o.grad);
                        obs.instant_label("corrupt", "fault", dev + 1, at, "kind", kind.label());
                    }
                }
                self.busy[dev] = true;
                self.inflight
                    .push(at, dev, Pending { grad: o.grad, batch, loss: o.loss, period });
            }
        }
        // 2. close the round at the quorum-th pending arrival
        if self.inflight.is_empty() {
            // everyone dropped/crashed or nothing in flight: an idle
            // period of the nominal length, no update
            return Ok(RoundReport {
                duration: plan.t_period,
                train_loss: f64::NAN,
                b_effective: 0,
                applied: 0,
                dropped,
                late: 0,
                stale_mean: 0.0,
                crashed,
                corrupt: 0,
                quarantined: 0,
                updated: false,
                reduce_secs: 0.0,
            });
        }
        let need = ((quorum * m as f64).ceil() as usize).clamp(1, m).min(self.inflight.len());
        let mut popped: Vec<Event<Pending>> = Vec::with_capacity(need);
        for i in 0..need {
            match self.inflight.pop() {
                Some(e) => popped.push(e),
                None => bail!(
                    "async close: in-flight queue exhausted after {i} of {need} quorum \
                     arrivals (scheduler state corrupted — queue length was {} at the \
                     quorum computation)",
                    need
                ),
            }
        }
        // anything else already in by the aggregation instant joins this
        // round too (an arrival during the following downlink waits for
        // the next round: its gradient is applied against the *next*
        // update, which is exactly what its staleness count then says)
        let t_close = match popped.last() {
            Some(e) => e.time.max(now),
            None => bail!(
                "async close: quorum of {need} produced no arrivals \
                 (scheduler state corrupted — quorum is clamped to >= 1)"
            ),
        };
        while self.inflight.peek_time().is_some_and(|t| t <= t_close) {
            match self.inflight.pop() {
                Some(e) => popped.push(e),
                None => bail!(
                    "async close: in-flight queue emptied while draining arrivals \
                     before t_close = {t_close} (peek/pop disagree — queue corrupted)"
                ),
            }
        }
        obs.instant_arg(
            "quorum_close",
            "round",
            0,
            t_close,
            &[("quorum", need as f64), ("arrived", popped.len() as f64)],
        );
        // 3. apply in arrival order with staleness-discounted weights,
        //    each gradient through the quarantine into its device's
        //    family accumulator
        // lint: allow(wall-clock): WallStats wall-time accounting — never enters SimClock
        let t0 = Instant::now();
        let mut loss_acc = 0f64;
        let mut w_acc = 0f64;
        let mut stale_acc = 0f64;
        let mut rejected = 0usize;
        for e in &popped {
            self.busy[e.device] = false;
            let s = period - e.payload.period;
            let w = e.payload.batch as f64;
            let verdict = aggs[backends.family_of(e.device)].add_stale_guarded(
                &e.payload.grad,
                w,
                s,
                alpha,
                beta,
                &self.guard,
            )?;
            if verdict.corrupt() {
                obs.instant_label(
                    "quarantine",
                    "guard",
                    e.device + 1,
                    e.time,
                    "verdict",
                    verdict.label(),
                );
                obs.inc("agg.quarantine_verdicts", 1);
            }
            obs.observe("round.staleness", s as f64);
            obs.audit_resolve(
                e.device,
                e.payload.period,
                if verdict.applied() { Outcome::Applied } else { Outcome::Quarantined },
                Some(s),
            );
            if verdict.applied() {
                obs.instant_arg(
                    "apply",
                    "round",
                    e.device + 1,
                    e.time,
                    &[("staleness", s as f64), ("weight", w)],
                );
                loss_acc += e.payload.loss * w;
                w_acc += w;
                stale_acc += s as f64 * w;
            } else {
                rejected += 1;
            }
        }
        Ok(RoundReport {
            duration: (t_close - now) + plan.t_down,
            train_loss: if w_acc > 0.0 { loss_acc / w_acc } else { f64::NAN },
            b_effective: w_acc as usize,
            applied: popped.len() - rejected,
            dropped,
            late: 0,
            stale_mean: if w_acc > 0.0 { stale_acc / w_acc } else { 0.0 },
            crashed,
            corrupt: aggs.iter().map(Aggregator::corrupt_contributions).sum(),
            quarantined: aggs.iter().map(Aggregator::quarantined_contributions).sum(),
            updated: aggs.iter().any(|a| a.contributions() > 0),
            reduce_secs: t0.elapsed().as_secs_f64(),
        })
    }

    #[cfg(test)]
    fn carry_mut(&mut self) -> &mut Vec<usize> {
        &mut self.carry
    }

    /// Compute, contaminate, and quarantine-screen the corrupt arrivals of
    /// a barrier/deadline round. `jobs` is `(device, batch)` in strictly
    /// ascending device order (the subset executor's contract). Returns
    /// the loss/weight mass of the contributions the guard let through and
    /// the count it rejected; detection counters land in the family
    /// accumulators themselves. `verdict_ts` is the simulated instant the
    /// screen runs (the round close), stamped on the quarantine events.
    #[allow(clippy::too_many_arguments)]
    fn apply_corrupt_jobs(
        &self,
        engine: &Engine,
        backends: &BackendSet<'_>,
        workers: &mut [Worker],
        params: &[Vec<f32>],
        train: &Dataset,
        jobs: &[(usize, usize)],
        period: u64,
        aggs: &mut [Aggregator],
        verdict_ts: f64,
        obs: &mut ObsSink,
    ) -> Result<(f64, f64, usize)> {
        if jobs.is_empty() {
            return Ok((0.0, 0.0, 0));
        }
        let outcomes = exec::gradient_round_subset(
            engine, backends, workers, params, train, jobs, self.seed, period,
        )?;
        let mut loss_acc = 0f64;
        let mut w_acc = 0f64;
        let mut rejected = 0usize;
        for (&(d, batch), mut o) in jobs.iter().zip(outcomes) {
            if let Some(kind) = self.fault.corrupts(self.seed, period, d as u64) {
                self.fault.contaminate(self.seed, period, d as u64, kind, &mut o.grad);
            }
            let w = batch as f64;
            let verdict = aggs[backends.family_of(d)].add_guarded(&o.grad, w, &self.guard)?;
            if verdict.corrupt() {
                obs.instant_label(
                    "quarantine",
                    "guard",
                    d + 1,
                    verdict_ts,
                    "verdict",
                    verdict.label(),
                );
                obs.inc("agg.quarantine_verdicts", 1);
            }
            obs.audit_outcome(
                d,
                if verdict.applied() { Outcome::Applied } else { Outcome::Quarantined },
            );
            if verdict.applied() {
                loss_acc += o.loss * w;
                w_acc += w;
            } else {
                rejected += 1;
            }
        }
        Ok((loss_acc, w_acc, rejected))
    }

    /// Shared barrier/deadline execution tail: the sharded gradient round
    /// over the (possibly masked) fleet, merged into the per-family server
    /// accumulators in device order — the exact fold the legacy
    /// synchronous path used, so a `None` mask on a homogeneous fleet
    /// reproduces it bitwise. Family tags are checked on every merge, so
    /// a shard can never land in the wrong family's accumulator.
    #[allow(clippy::too_many_arguments)]
    fn run_masked(
        &self,
        engine: &Engine,
        backends: &BackendSet<'_>,
        workers: &mut [Worker],
        params: &[Vec<f32>],
        train: &Dataset,
        plan: &Plan,
        mask: Option<&[bool]>,
        period: u64,
        aggs: &mut [Aggregator],
    ) -> Result<(f64, f64, f64)> {
        let shards = exec::gradient_round_sharded_masked(
            engine,
            backends,
            workers,
            params,
            train,
            &plan.batches,
            mask,
            self.seed,
            period,
        )?;
        // lint: allow(wall-clock): WallStats wall-time accounting — never enters SimClock
        let t0 = Instant::now();
        let mut loss_acc = 0f64;
        let mut w_acc = 0f64;
        for s in &shards {
            for (f, a) in &s.aggs {
                aggs[*f].merge(a)?;
            }
            loss_acc += s.loss;
            w_acc += s.weight;
        }
        Ok((loss_acc, w_acc, t0.elapsed().as_secs_f64()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::opt::types::test_instance;

    fn plan_for(inst: &Instance) -> Plan {
        let k = inst.k();
        Plan {
            batches: vec![10; k],
            t_period: 1.2,
            t_up: 1.0,
            t_down: 0.2,
            finish: vec![0.9; k],
            predicted: vec![crate::opt::types::PredictedTiming::default(); k],
            predicted_efficiency: None,
        }
    }

    fn sched_for(policy: RoundPolicy, k: usize) -> RoundScheduler {
        RoundScheduler::new(
            policy,
            StragglerModel::none(),
            FaultPlan::none(),
            GradGuard::off(),
            k,
            7,
        )
        .unwrap()
    }

    #[test]
    fn apply_carry_grows_batches_and_finish_then_clears() {
        let inst = test_instance(3);
        let policy = RoundPolicy::Deadline { factor: 1.25 };
        let mut sched = sched_for(policy, 3);
        let mut plan = plan_for(&inst);
        sched.carry_mut()[1] = 6;
        sched.apply_carry(&mut plan, &inst, 0);
        assert_eq!(plan.batches, vec![10, 16, 10]);
        // finish extends by exactly the extra compute time
        let extra = 6.0 / inst.devices[1].speed;
        assert_eq!(plan.finish[1], 0.9 + extra);
        assert_eq!(plan.finish[0], 0.9);
        // the audit's predicted compute tracks the grown schedule
        assert_eq!(plan.predicted[1].compute, extra);
        assert_eq!(plan.predicted[0].compute, 0.0);
        // the ledger is consumed
        assert_eq!(sched.carried(), &[0, 0, 0]);
    }

    #[test]
    fn apply_carry_caps_at_deadline_headroom_and_batch_ceiling() {
        // a huge carry must not grow the batch past what the device can
        // still compute before the deadline — that would deterministically
        // re-miss every period (livelock)
        let inst = test_instance(2); // device 0: speed 20, b_max 128
        let policy = RoundPolicy::Deadline { factor: 1.25 };
        let mut sched = sched_for(policy, 2);
        let mut plan = plan_for(&inst); // t_up 1.0, finish 0.9 -> headroom 0.35s = 7 samples
        sched.carry_mut()[0] = 10_000;
        sched.apply_carry(&mut plan, &inst, 0);
        assert_eq!(plan.batches[0], 17, "carry must cap at the deadline headroom");
        assert!(plan.finish[0] <= plan.t_up * 1.25);
        assert_eq!(sched.carried(), &[0, 0], "excess carry is forfeited");
        // with a loose deadline the batch ceiling binds instead
        let policy = RoundPolicy::Deadline { factor: 10.0 };
        let mut sched = sched_for(policy, 2);
        let mut plan = plan_for(&inst);
        sched.carry_mut()[0] = 10_000;
        sched.apply_carry(&mut plan, &inst, 0);
        assert_eq!(plan.batches[0], 128, "loose deadline: cap at floor(b_max)");
    }

    #[test]
    fn apply_carry_noop_for_non_deadline_policies() {
        let inst = test_instance(2);
        let mut sched = sched_for(RoundPolicy::Sync, 2);
        let mut plan = plan_for(&inst);
        sched.carry_mut()[0] = 6;
        sched.apply_carry(&mut plan, &inst, 0);
        assert_eq!(plan.batches[0], 10);
        assert_eq!(sched.carried(), &[6, 0]);
    }

    #[test]
    fn snapshot_restore_roundtrips_scheduler_state() {
        let mut sched = sched_for(RoundPolicy::Sync, 3);
        sched.carry_mut()[2] = 4;
        sched.busy[1] = true;
        let p1 = Pending { grad: vec![1.0, -2.0], batch: 8, loss: 0.5, period: 3 };
        sched.inflight.push(2.5, 1, p1);
        sched.inflight.push(1.0, 0, Pending { grad: vec![0.25], batch: 4, loss: 0.1, period: 2 });
        let ck = sched.snapshot();
        assert_eq!(ck.carry, vec![0, 0, 4]);
        assert_eq!(ck.busy, vec![false, true, false]);
        // canonical (time, device) order regardless of push order
        assert_eq!(ck.inflight[0].device, 0);
        assert_eq!(ck.inflight[1].device, 1);
        let mut fresh = sched_for(RoundPolicy::Sync, 3);
        fresh.restore(ck.clone()).unwrap();
        assert_eq!(fresh.snapshot(), ck);
        // fleet-size mismatch is a structured error, not a panic
        let mut wrong = sched_for(RoundPolicy::Sync, 2);
        let err = wrong.restore(ck).unwrap_err().to_string();
        assert!(err.contains("3-device fleet"), "{err}");
    }
}
