//! Deterministic discrete-event queue.
//!
//! A min-heap keyed by `(completion time, device id)`. Times are compared
//! with `f64::total_cmp` and ties broken by device id, so the pop order is
//! a *total* order that depends only on the events pushed — never on push
//! order, thread scheduling, or hash state. This is the ordering half of
//! the `sched/` determinism contract (see sched/mod.rs).

use std::cmp::Ordering;
use std::collections::BinaryHeap;

/// One scheduled completion: `payload` reaches the server at `time`.
#[derive(Clone, Debug)]
pub struct Event<P> {
    /// absolute simulated time (seconds)
    pub time: f64,
    /// device id — the deterministic tie-break
    pub device: usize,
    pub payload: P,
}

/// Heap entry with the (time, device) ordering reversed so the std
/// max-heap pops the *earliest* event first.
struct Entry<P>(Event<P>);

impl<P> PartialEq for Entry<P> {
    fn eq(&self, other: &Self) -> bool {
        self.0.time.to_bits() == other.0.time.to_bits() && self.0.device == other.0.device
    }
}

impl<P> Eq for Entry<P> {}

impl<P> PartialOrd for Entry<P> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl<P> Ord for Entry<P> {
    fn cmp(&self, other: &Self) -> Ordering {
        other
            .0
            .time
            .total_cmp(&self.0.time)
            .then_with(|| other.0.device.cmp(&self.0.device))
    }
}

/// Min-queue of completion events.
pub struct EventQueue<P> {
    heap: BinaryHeap<Entry<P>>,
}

impl<P> Default for EventQueue<P> {
    fn default() -> Self {
        Self::new()
    }
}

impl<P> EventQueue<P> {
    pub fn new() -> Self {
        EventQueue { heap: BinaryHeap::new() }
    }

    /// Schedule `payload` to complete at `time` (panics on negative or
    /// non-finite times — those are always upstream bugs, like
    /// `SimClock::advance`).
    pub fn push(&mut self, time: f64, device: usize, payload: P) {
        assert!(time.is_finite() && time >= 0.0, "bad event time {time}");
        self.heap.push(Entry(Event { time, device, payload }));
    }

    /// Remove and return the earliest event (ties broken by device id).
    pub fn pop(&mut self) -> Option<Event<P>> {
        self.heap.pop().map(|e| e.0)
    }

    /// Completion time of the earliest pending event.
    pub fn peek_time(&self) -> Option<f64> {
        self.heap.peek().map(|e| e.0.time)
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    pub fn clear(&mut self) {
        self.heap.clear();
    }

    /// All pending events in pop order (time, then device) without
    /// draining the queue — checkpoint serialization walks this so the
    /// on-disk order is canonical whatever the internal heap layout.
    pub fn events_sorted(&self) -> Vec<&Event<P>> {
        let mut evs: Vec<&Event<P>> = self.heap.iter().map(|e| &e.0).collect();
        evs.sort_by(|a, b| a.time.total_cmp(&b.time).then_with(|| a.device.cmp(&b.device)));
        evs
    }

    /// Keep only the events satisfying `keep` (fault injection cancels
    /// the in-flight work of crashed devices). Rebuilds the heap; the
    /// (time, device) total order of survivors is unchanged.
    pub fn retain<F: FnMut(&Event<P>) -> bool>(&mut self, mut keep: F) {
        let drained = std::mem::take(&mut self.heap);
        self.heap = drained.into_iter().filter(|e| keep(&e.0)).collect();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_time_order_regardless_of_push_order() {
        let mut q = EventQueue::new();
        for (t, d) in [(3.0, 0), (1.0, 4), (2.0, 2), (0.5, 7)] {
            q.push(t, d, d * 10);
        }
        let order: Vec<(f64, usize, usize)> = std::iter::from_fn(|| q.pop())
            .map(|e| (e.time, e.device, e.payload))
            .collect();
        assert_eq!(order, vec![(0.5, 7, 70), (1.0, 4, 40), (2.0, 2, 20), (3.0, 0, 0)]);
        assert!(q.is_empty());
    }

    #[test]
    fn ties_break_by_device_id() {
        // push in descending device order; pops must come back ascending
        let mut q = EventQueue::new();
        for d in [5usize, 3, 9, 1] {
            q.push(2.5, d, ());
        }
        let devs: Vec<usize> = std::iter::from_fn(|| q.pop()).map(|e| e.device).collect();
        assert_eq!(devs, vec![1, 3, 5, 9]);
    }

    #[test]
    fn peek_matches_pop() {
        let mut q = EventQueue::new();
        q.push(4.0, 1, ());
        q.push(2.0, 0, ());
        assert_eq!(q.peek_time(), Some(2.0));
        assert_eq!(q.len(), 2);
        q.pop();
        assert_eq!(q.peek_time(), Some(4.0));
        q.clear();
        assert_eq!(q.peek_time(), None);
    }

    #[test]
    fn events_sorted_matches_pop_order() {
        let mut q = EventQueue::new();
        for (t, d) in [(3.0, 0), (1.0, 4), (1.0, 2), (0.5, 7)] {
            q.push(t, d, ());
        }
        let sorted: Vec<(f64, usize)> =
            q.events_sorted().iter().map(|e| (e.time, e.device)).collect();
        let popped: Vec<(f64, usize)> =
            std::iter::from_fn(|| q.pop()).map(|e| (e.time, e.device)).collect();
        assert_eq!(sorted, popped);
        assert_eq!(sorted, vec![(0.5, 7), (1.0, 2), (1.0, 4), (3.0, 0)]);
    }

    #[test]
    fn retain_filters_and_keeps_order() {
        let mut q = EventQueue::new();
        for (t, d) in [(3.0, 0), (1.0, 4), (2.0, 2), (0.5, 7)] {
            q.push(t, d, ());
        }
        q.retain(|e| e.device != 4 && e.device != 7);
        let order: Vec<usize> = std::iter::from_fn(|| q.pop()).map(|e| e.device).collect();
        assert_eq!(order, vec![2, 0]);
        // retaining nothing empties the queue
        let mut q2: EventQueue<()> = EventQueue::new();
        q2.push(1.0, 1, ());
        q2.retain(|_| false);
        assert!(q2.is_empty());
    }

    #[test]
    #[should_panic]
    fn rejects_nan_time() {
        EventQueue::new().push(f64::NAN, 0, ());
    }

    #[test]
    #[should_panic]
    fn rejects_negative_time() {
        EventQueue::new().push(-1.0, 0, ());
    }
}
