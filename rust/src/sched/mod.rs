//! Deterministic discrete-event round scheduler.
//!
//! The paper's TDMA frame is fully synchronous: every period barriers on
//! the slowest device, so one straggler stalls the whole fleet. This
//! subsystem replaces that implicit barrier with an explicit event queue
//! keyed by simulated completion time and offers three round policies:
//!
//! * [`RoundPolicy::Sync`] — the original barrier, refactored onto the
//!   queue (drain everything; period ends at the last arrival);
//! * [`RoundPolicy::Deadline`] — semi-synchronous: arrivals after
//!   `factor x` the nominal makespan are dropped from the reduce and
//!   their batch re-planned into the device's next period;
//! * [`RoundPolicy::Async`] — buffered-asynchronous: the round closes at
//!   a quorum of arrivals and stale gradients are applied later with the
//!   weight `alpha / (1 + s)^beta` (`grad::Aggregator::add_stale`).
//!
//! Determinism contract (validated by `tests/exec_determinism.rs`), the
//! same three mechanisms as `exec/` plus one for event ordering:
//!
//! 1. every event time is computed on the coordinator thread from the
//!    plan's nominal per-device finish times and counter-derived straggler
//!    draws (`device::StragglerModel::sample` keyed by `(seed, period,
//!    device)`) — fault injection is independent of execution order;
//! 2. the queue pops in `(time, device id)` order under `f64::total_cmp`,
//!    a total order over events — ties cannot be broken by push order,
//!    thread scheduling, or hash state;
//! 3. gradient execution goes through the `exec` rounds (device-ordered
//!    result slots, K-determined shard boundaries), and every aggregation
//!    — masked shard merges and staleness-weighted async applies alike —
//!    happens in that popped/device order with f64 accumulation.
//!
//! With the straggler model inactive, `Sync` reproduces the legacy
//! synchronous trainer bitwise: arrivals are the plan's clamped nominal
//! finish times, so the barrier lands exactly on the plan's uplink
//! makespan and the period advances by `plan.t_period`.

pub mod executor;
pub mod policy;
pub mod queue;

pub use executor::{InflightRecord, RoundReport, RoundScheduler, SchedCheckpoint};
pub use policy::{RoundPolicy, POLICY_NAMES};
pub use queue::{Event, EventQueue};
