//! Round policies: how a training period closes.
//!
//! `Sync` is the paper's TDMA barrier (wait for every device). `Deadline`
//! is semi-synchronous in the spirit of adaptive-aggregation FL (Wang et
//! al., arXiv:1804.05271): the server stops waiting at a deadline and
//! re-plans the missing contributions into the next period. `Async` is a
//! buffered-asynchronous mode (Prakash et al., arXiv:2111.00637 frame the
//! staleness-vs-delay tradeoff it navigates): the server closes a round as
//! soon as a quorum of gradients has arrived and discounts late, stale
//! gradients by `alpha / (1 + s)^beta`.

use anyhow::{bail, Result};

/// Accepted `--policy` / `train.policy` values (keep in sync with
/// [`RoundPolicy::parse`]; the CLI help and error paths print this).
pub const POLICY_NAMES: &str = "sync | deadline | async";

/// How the coordinator closes each training period.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub enum RoundPolicy {
    /// Barrier on the slowest device (the paper's synchronous frame).
    #[default]
    Sync,
    /// Semi-synchronous: the server waits until `factor` x the period's
    /// nominal uplink makespan; devices that miss the deadline are dropped
    /// from the reduce and their planned batch is carried into their next
    /// period's plan.
    Deadline {
        /// deadline as a multiple of the nominal makespan, >= 1
        factor: f64,
    },
    /// Buffered-asynchronous: each round closes once `quorum` (fraction of
    /// the fleet) gradients are buffered; devices still computing keep
    /// their in-flight work and deliver it in a later round, discounted by
    /// the staleness weight `alpha / (1 + s)^beta` where `s` is the age of
    /// the round the gradient was computed in.
    Async {
        /// base staleness weight, in (0, 1]
        alpha: f64,
        /// staleness decay exponent, >= 0
        beta: f64,
        /// fraction of the fleet that closes a round, in (0, 1]
        quorum: f64,
    },
}

impl RoundPolicy {
    /// Every per-policy knob name, in canonical (underscore) form. Config
    /// keys prefix these with `train.`; CLI flags swap `_` for `-`. The
    /// single source of truth for the stray-knob rejection on both
    /// surfaces.
    pub const ALL_KNOBS: &'static [&'static str] =
        &["deadline_factor", "async_alpha", "async_beta", "quorum"];

    /// The subset of [`Self::ALL_KNOBS`] that applies to this policy.
    pub fn knob_names(&self) -> &'static [&'static str] {
        match self {
            RoundPolicy::Sync => &[],
            RoundPolicy::Deadline { .. } => &["deadline_factor"],
            RoundPolicy::Async { .. } => &["async_alpha", "async_beta", "quorum"],
        }
    }

    /// Parse a policy name as used in configs and on the CLI; knob fields
    /// start at their defaults (`deadline` factor 1.25; `async` alpha 0.6,
    /// beta 0.5, quorum 0.5).
    pub fn parse(s: &str) -> Option<RoundPolicy> {
        match s {
            "sync" => Some(RoundPolicy::Sync),
            "deadline" | "semi-sync" | "semisync" => Some(RoundPolicy::Deadline { factor: 1.25 }),
            "async" => Some(RoundPolicy::Async { alpha: 0.6, beta: 0.5, quorum: 0.5 }),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            RoundPolicy::Sync => "sync",
            RoundPolicy::Deadline { .. } => "deadline",
            RoundPolicy::Async { .. } => "async",
        }
    }

    pub fn is_sync(&self) -> bool {
        matches!(self, RoundPolicy::Sync)
    }

    pub fn validate(&self) -> Result<()> {
        match *self {
            RoundPolicy::Sync => {}
            RoundPolicy::Deadline { factor } => {
                if !(factor.is_finite() && factor >= 1.0) {
                    bail!("deadline factor must be finite and >= 1, got {factor}");
                }
            }
            RoundPolicy::Async { alpha, beta, quorum } => {
                if !(alpha.is_finite() && alpha > 0.0 && alpha <= 1.0) {
                    bail!("async alpha must be in (0, 1], got {alpha}");
                }
                if !(beta.is_finite() && beta >= 0.0) {
                    bail!("async beta must be finite and >= 0, got {beta}");
                }
                if !(quorum.is_finite() && quorum > 0.0 && quorum <= 1.0) {
                    bail!("async quorum must be in (0, 1], got {quorum}");
                }
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_roundtrips_names() {
        for name in ["sync", "deadline", "async"] {
            let p = RoundPolicy::parse(name).unwrap();
            assert_eq!(p.name(), name);
            p.validate().unwrap();
        }
        assert_eq!(RoundPolicy::parse("semi-sync").unwrap().name(), "deadline");
        assert!(RoundPolicy::parse("fifo").is_none());
    }

    #[test]
    fn default_is_sync() {
        assert!(RoundPolicy::default().is_sync());
        assert!(!RoundPolicy::parse("async").unwrap().is_sync());
    }

    #[test]
    fn knob_table_is_a_disjoint_cover() {
        // every policy's knobs come from ALL_KNOBS, and no knob belongs
        // to two policies — the invariant the stray-knob rejection on the
        // CLI/config surfaces relies on
        let policies = [
            RoundPolicy::Sync,
            RoundPolicy::parse("deadline").unwrap(),
            RoundPolicy::parse("async").unwrap(),
        ];
        let mut seen: Vec<&str> = Vec::new();
        for p in policies {
            for &k in p.knob_names() {
                assert!(RoundPolicy::ALL_KNOBS.contains(&k), "{k} missing from ALL_KNOBS");
                assert!(!seen.contains(&k), "{k} claimed by two policies");
                seen.push(k);
            }
        }
        assert_eq!(seen.len(), RoundPolicy::ALL_KNOBS.len());
    }

    #[test]
    fn validate_rejects_bad_knobs() {
        assert!(RoundPolicy::Deadline { factor: 0.9 }.validate().is_err());
        assert!(RoundPolicy::Deadline { factor: f64::INFINITY }.validate().is_err());
        assert!(RoundPolicy::Async { alpha: 0.0, beta: 0.5, quorum: 0.5 }.validate().is_err());
        assert!(RoundPolicy::Async { alpha: 1.5, beta: 0.5, quorum: 0.5 }.validate().is_err());
        assert!(RoundPolicy::Async { alpha: 0.5, beta: -1.0, quorum: 0.5 }.validate().is_err());
        assert!(RoundPolicy::Async { alpha: 0.5, beta: 0.5, quorum: 0.0 }.validate().is_err());
        assert!(RoundPolicy::Async { alpha: 0.5, beta: 0.5, quorum: 1.1 }.validate().is_err());
        assert!(RoundPolicy::Async { alpha: 0.5, beta: 0.0, quorum: 1.0 }.validate().is_ok());
    }
}
