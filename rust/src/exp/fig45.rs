//! Fig. 4 / Fig. 5 reproduction: GPU scenario, K = 6 identical GPUs —
//! global training loss and test accuracy vs *simulated training time* for
//! the proposed scheme vs the online (B=1), full-batch (B=128) and random-
//! batch baselines; Fig. 4 = IID, Fig. 5 = non-IID (paper §VI-D).

use anyhow::Result;

use super::common::{run_scheme, BackendKind};
use crate::config::Experiment;
use crate::coordinator::Scheme;
use crate::data::Partition;
use crate::metrics::Recorder;
use crate::opt::BatchPolicy;

/// One policy's time series.
#[derive(Clone, Debug)]
pub struct Fig45Series {
    pub policy: &'static str,
    pub csv: String,
    pub final_loss: f64,
    pub final_acc: Option<f64>,
    pub total_time: f64,
    pub periods: usize,
    pub log: crate::coordinator::TrainLog,
}

fn policies() -> Vec<(Scheme, &'static str)> {
    vec![
        (Scheme::Proposed, "proposed"),
        (Scheme::Fixed { policy: BatchPolicy::Online, optimal_slots: true }, "online"),
        (Scheme::Fixed { policy: BatchPolicy::Full, optimal_slots: true }, "full_batch"),
        (Scheme::Fixed { policy: BatchPolicy::Random, optimal_slots: true }, "random"),
    ]
}

/// Run one figure (IID for Fig. 4, non-IID for Fig. 5): every policy gets
/// the same simulated-time budget.
pub fn run(
    base: &Experiment,
    partition: Partition,
    time_budget: f64,
    max_periods: usize,
    kind: BackendKind,
) -> Result<Vec<Fig45Series>> {
    let mut out = Vec::new();
    for (scheme, name) in policies() {
        let mut exp = base.clone();
        exp.k = 6;
        exp.gpu = true;
        exp.partition = partition;
        exp.trainer.eval_every = 5;
        let log = run_scheme(&exp, scheme, kind, max_periods, 0, Some(time_budget))?;
        out.push(Fig45Series {
            policy: name,
            csv: log.to_csv(),
            final_loss: log.final_loss().unwrap_or(f64::NAN),
            final_acc: log.final_acc(),
            total_time: log.total_time(),
            periods: log.records.len(),
            log,
        });
    }
    Ok(out)
}

pub fn drive(
    rec: &Recorder,
    base: &Experiment,
    fig: u8,
    time_budget: f64,
    max_periods: usize,
    kind: BackendKind,
) -> Result<()> {
    let partition = if fig == 4 { Partition::Iid } else { Partition::NonIid };
    println!(
        "Fig. {fig} — GPU scenario ({:?}), loss/accuracy vs training time (budget {time_budget} s)",
        partition
    );
    let series = run(base, partition, time_budget, max_periods, kind)?;
    for s in &series {
        rec.csv(&format!("fig{fig}_{}", s.policy), &s.csv)?;
        let line = format!(
            "  {:<12} periods={:<5} time={:>8.1}s final loss {:.4} acc {}",
            s.policy,
            s.periods,
            s.total_time,
            s.final_loss,
            s.final_acc.map(|a| format!("{:.3}", a)).unwrap_or("n/a".into())
        );
        println!("{line}");
        rec.log(&line)?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_base() -> Experiment {
        let mut base = Experiment::default();
        base.synth.dim = 24;
        base.train_n = 800;
        base.test_n = 200;
        base
    }

    #[test]
    fn proposed_fastest_to_loss_target() {
        // headline of Fig. 4/5: the proposed scheme reaches a given loss
        // level in the least simulated training time.
        let series = run(&small_base(), Partition::Iid, 150.0, 30, BackendKind::Host).unwrap();
        let target = 1.5; // between init (~ln 10) and converged
        let prop = series.iter().find(|s| s.policy == "proposed").unwrap();
        let t_prop = prop.log.time_to_loss(target).expect("proposed reaches target");
        for s in &series {
            if s.policy == "proposed" {
                continue;
            }
            let t = s.log.time_to_loss(target).unwrap_or(f64::INFINITY);
            assert!(
                t_prop <= t * 1.05,
                "proposed {t_prop}s vs {} {t}s to loss {target}",
                s.policy
            );
        }
    }

    #[test]
    fn online_runs_many_cheap_periods() {
        let series = run(&small_base(), Partition::NonIid, 60.0, 100, BackendKind::Host).unwrap();
        let online = series.iter().find(|s| s.policy == "online").unwrap();
        let full = series.iter().find(|s| s.policy == "full_batch").unwrap();
        // online periods are cheaper -> more of them fit in the budget
        assert!(
            online.periods >= full.periods,
            "online {} vs full {}",
            online.periods,
            full.periods
        );
    }
}
