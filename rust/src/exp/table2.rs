//! Table II reproduction: test accuracy + training speedup of the four
//! schemes (individual learning, model-based FL, gradient-based FL,
//! proposed) in the IID and non-IID cases, K ∈ {6, 12} (paper §VI-C).
//!
//! Speedup is measured as the paper does: the ratio of "training speeds",
//! i.e. (time for individual learning to reach the common loss target) /
//! (time for the scheme to reach it). The common target is the loosest of
//! the schemes' final train losses so every scheme reaches it; schemes that
//! plateau above it are assigned their total time (a *lower bound* on their
//! slowdown, noted in the output).

use anyhow::Result;

use super::common::{run_scheme, BackendKind};
use crate::config::Experiment;
use crate::coordinator::{Scheme, TrainLog};
use crate::data::Partition;
use crate::metrics::{speedup, Recorder};

/// One scheme's Table-II cell.
#[derive(Clone, Debug)]
pub struct Table2Row {
    pub scheme: &'static str,
    pub test_acc: f64,
    pub speedup: f64,
    pub reached_target: bool,
    pub sim_time: f64,
}

fn schemes() -> Vec<(Scheme, &'static str)> {
    vec![
        (Scheme::Individual { local_batch: 128 }, "individual"),
        (Scheme::ModelFl { local_batch: 32 }, "model_fl"),
        (Scheme::GradientFl, "gradient_fl"),
        (Scheme::Proposed, "proposed"),
    ]
}

/// Run one (K, partition) cell of Table II.
pub fn run_cell(
    base: &Experiment,
    k: usize,
    partition: Partition,
    periods: usize,
    warm_steps: usize,
    kind: BackendKind,
) -> Result<Vec<Table2Row>> {
    let mut logs: Vec<(&'static str, TrainLog)> = Vec::new();
    for (scheme, name) in schemes() {
        let mut exp = base.clone();
        exp.k = k;
        exp.partition = partition;
        exp.trainer.eval_every = (periods / 10).max(1);
        // model-FL / gradient-FL process whole shards per period: give all
        // schemes the same period budget but cap wall time by periods only.
        let log = run_scheme(&exp, scheme, kind, periods, warm_steps, None)?;
        logs.push((name, log));
    }
    // common loss target: the loosest final loss across schemes (everyone
    // can reach it), padded 2%
    let target = logs
        .iter()
        .map(|(_, l)| l.final_loss().unwrap_or(f64::INFINITY))
        .fold(f64::NEG_INFINITY, f64::max)
        * 1.02;
    let time_of = |log: &TrainLog| -> (f64, bool) {
        match log.time_to_loss(target) {
            Some(t) => (t.max(1e-9), true),
            None => (log.total_time(), false),
        }
    };
    let (t_ind, _) = time_of(&logs[0].1);
    let mut rows = Vec::with_capacity(logs.len());
    for entry in &logs {
        let (name, log) = (entry.0, &entry.1);
        let (t, reached) = time_of(log);
        rows.push(Table2Row {
            scheme: name,
            test_acc: log.final_acc().unwrap_or(f64::NAN),
            speedup: speedup(t_ind, t)?,
            reached_target: reached,
            sim_time: log.total_time(),
        });
    }
    Ok(rows)
}

/// Full Table II: both partitions for one K.
pub fn drive(
    rec: &Recorder,
    base: &Experiment,
    k: usize,
    periods: usize,
    warm_steps: usize,
    kind: BackendKind,
) -> Result<()> {
    println!("Table II (K={k}) — test accuracy / training speedup vs individual learning");
    println!(
        "{:<14} {:>10} {:>10} {:>12} {:>10} {:>10} {:>12}",
        "scheme", "acc(IID)", "spd(IID)", "", "acc(nIID)", "spd(nIID)", ""
    );
    let iid = run_cell(base, k, Partition::Iid, periods, warm_steps, kind)?;
    let noniid = run_cell(base, k, Partition::NonIid, periods, warm_steps, kind)?;
    let mut csv = String::from("scheme,partition,test_acc,speedup,reached_target,sim_time\n");
    for (a, b) in iid.iter().zip(&noniid) {
        println!(
            "{:<14} {:>9.2}% {:>9.2}x {:>12} {:>9.2}% {:>9.2}x {:>12}",
            a.scheme,
            a.test_acc * 100.0,
            a.speedup,
            if a.reached_target { "" } else { "(plateau)" },
            b.test_acc * 100.0,
            b.speedup,
            if b.reached_target { "" } else { "(plateau)" },
        );
        for (r, part) in [(a, "iid"), (b, "noniid")] {
            csv.push_str(&format!(
                "{},{},{:.4},{:.4},{},{:.2}\n",
                r.scheme, part, r.test_acc, r.speedup, r.reached_target, r.sim_time
            ));
        }
    }
    rec.csv(&format!("table2_k{k}"), &csv)?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cell_smoke_ordering() {
        // tiny-scale run; the structural claims that must hold even at
        // smoke scale: proposed has the highest speedup among FL schemes,
        // model-FL is the slowest FL scheme.
        let mut base = Experiment::default();
        base.synth.dim = 24;
        base.train_n = 800;
        base.test_n = 200;
        let rows = run_cell(&base, 4, Partition::Iid, 25, 30, BackendKind::Host).unwrap();
        assert_eq!(rows.len(), 4);
        let get = |n: &str| rows.iter().find(|r| r.scheme == n).unwrap();
        // the invariant that must hold even at toy scale: the proposed
        // scheme is strictly the fastest (the gradient_fl > model_fl gap
        // needs realistic payload sizes and is asserted by the full-scale
        // experiment run, EXPERIMENTS.md)
        let prop = get("proposed");
        for r in &rows {
            assert!(
                prop.speedup >= r.speedup,
                "proposed {} slower than {} {}",
                prop.speedup,
                r.scheme,
                r.speedup
            );
            assert!((0.0..=1.0).contains(&r.test_acc), "{:?}", r);
            assert!(r.sim_time > 0.0);
        }
    }
}
