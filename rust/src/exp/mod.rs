//! Paper-experiment drivers — one module per table/figure (DESIGN.md §4).

pub mod common;
pub mod fig2;
pub mod fig3;
pub mod fig45;
pub mod table2;

pub use common::BackendKind;
