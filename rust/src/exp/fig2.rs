//! Fig. 2 reproduction: GPU local-gradient-calculation latency vs training
//! batchsize — (a) the theoretical piecewise model, (b) simulated
//! measurements on the three DNN profiles + the recovered fit, validating
//! Assumption 1 exactly the way the paper does (model vs measured curves).

use anyhow::Result;

use crate::device::paper_profiles;
use crate::metrics::Recorder;
use crate::util::rng::Pcg;
use crate::util::stats::fit_piecewise;

/// One profile's sweep output.
#[derive(Clone, Debug)]
pub struct Fig2Row {
    pub model: &'static str,
    pub b: f64,
    pub t_model: f64,
    pub t_measured: f64,
}

/// Run the sweep; returns rows + per-model fit summary lines.
pub fn run(noise_frac: f64, seed: u64) -> (Vec<Fig2Row>, Vec<String>) {
    let mut rng = Pcg::seeded(seed);
    let mut rows = Vec::new();
    let mut fits = Vec::new();
    for (name, gpu) in paper_profiles() {
        let bs: Vec<f64> = (1..=128).map(|b| b as f64).collect();
        let ts: Vec<f64> = bs.iter().map(|&b| gpu.measure(b, noise_frac, &mut rng)).collect();
        for (&b, &t) in bs.iter().zip(&ts) {
            rows.push(Fig2Row { model: name, b, t_model: gpu.grad_latency(b), t_measured: t });
        }
        let fit = fit_piecewise(&bs, &ts);
        fits.push(format!(
            "{name}: true (t_l={:.4}, c={:.5}, B_th={:.0}) fitted (t_l={:.4}, c={:.5}, B_th={:.0}) rss={:.3e}",
            gpu.t_flat, gpu.slope, gpu.b_th, fit.t_l, fit.c, fit.b_th, fit.rss
        ));
    }
    (rows, fits)
}

/// Driver: print + record CSV.
pub fn drive(rec: &Recorder) -> Result<()> {
    let (rows, fits) = run(0.02, 42);
    let mut csv = String::from("model,batchsize,t_model,t_measured\n");
    for r in &rows {
        csv.push_str(&format!("{},{},{:.6},{:.6}\n", r.model, r.b, r.t_model, r.t_measured));
    }
    rec.csv("fig2_latency", &csv)?;
    println!("Fig. 2 — GPU training function (flat then linear in B):");
    for f in &fits {
        println!("  {f}");
        rec.log(f)?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shape_is_flat_then_linear() {
        let (rows, _) = run(0.0, 1);
        for model in ["densenet", "googlenet", "pnasnet"] {
            let m: Vec<&Fig2Row> = rows.iter().filter(|r| r.model == model).collect();
            assert_eq!(m.len(), 128);
            // flat region: identical latencies at B=1 and B=8
            assert_eq!(m[0].t_model, m[7].t_model);
            // strictly increasing at the tail
            assert!(m[127].t_model > m[100].t_model);
        }
    }

    #[test]
    fn fits_recover_knees() {
        let (_, fits) = run(0.02, 7);
        assert_eq!(fits.len(), 3);
        for f in fits {
            assert!(f.contains("fitted"));
        }
    }
}
