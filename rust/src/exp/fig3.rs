//! Fig. 3 reproduction: generalization across DNN models — global training
//! loss (a) and test accuracy (b) vs training period for the three model
//! families × two learning rates, non-IID data, K = 12 with CPU tiers
//! {0.7, 1.4, 2.1} GHz × 4 (paper §VI-B).

use anyhow::Result;

use super::common::{run_scheme, BackendKind};
use crate::config::Experiment;
use crate::coordinator::Scheme;
use crate::data::Partition;
use crate::metrics::Recorder;

/// One (model, lr) series.
#[derive(Clone, Debug)]
pub struct Fig3Series {
    pub model: String,
    pub lr: f64,
    pub csv: String,
    pub final_loss: f64,
    pub final_acc: Option<f64>,
}

pub fn run(base: &Experiment, periods: usize, kind: BackendKind) -> Result<Vec<Fig3Series>> {
    let mut out = Vec::new();
    for model in ["mini_dense", "mini_res", "mini_mobile"] {
        for lr_scale in [1.0, 0.5] {
            let mut exp = base.clone();
            exp.model = model.to_string();
            exp.k = 12;
            exp.partition = Partition::NonIid;
            exp.trainer.base_lr *= lr_scale;
            exp.trainer.eval_every = (periods / 20).max(1);
            let log = run_scheme(&exp, Scheme::Proposed, kind, periods, 0, None)?;
            out.push(Fig3Series {
                model: model.to_string(),
                lr: exp.trainer.base_lr,
                csv: log.to_csv(),
                final_loss: log.final_loss().unwrap_or(f64::NAN),
                final_acc: log.final_acc(),
            });
        }
    }
    Ok(out)
}

pub fn drive(rec: &Recorder, base: &Experiment, periods: usize, kind: BackendKind) -> Result<()> {
    println!("Fig. 3 — proposed scheme across 3 models x 2 learning rates (non-IID, K=12)");
    let series = run(base, periods, kind)?;
    for s in &series {
        rec.csv(&format!("fig3_{}_lr{}", s.model, s.lr), &s.csv)?;
        let line = format!(
            "  {} lr={:.3}: final loss {:.4}, final acc {}",
            s.model,
            s.lr,
            s.final_loss,
            s.final_acc.map(|a| format!("{:.3}", a)).unwrap_or("n/a".into())
        );
        println!("{line}");
        rec.log(&line)?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_models_converge_smoke() {
        // tiny-scale smoke: every (model, lr) run must reduce train loss
        let mut base = Experiment::default();
        base.synth.dim = 24;
        base.train_n = 600;
        base.test_n = 200;
        let series = run(&base, 12, BackendKind::Host).unwrap();
        assert_eq!(series.len(), 6);
        for s in &series {
            let first: f64 = s
                .csv
                .lines()
                .nth(1)
                .unwrap()
                .split(',')
                .nth(4)
                .unwrap()
                .parse()
                .unwrap();
            assert!(
                s.final_loss < first * 1.1,
                "{} lr={}: {first} -> {}",
                s.model,
                s.lr,
                s.final_loss
            );
        }
    }
}
