//! Shared wiring for the paper-experiment drivers: build the world
//! (dataset + fleet + backend) from an `Experiment` and run one scheme.

use anyhow::Result;

use crate::config::Experiment;
use crate::coordinator::{
    Backend, BackendSet, HostBackend, PjrtBackend, Scheme, TrainLog, Trainer,
};
use crate::data::{generate, Dataset};
use crate::runtime::Runtime;
use crate::util::rng::Pcg;

/// Which compute backend the experiment uses.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BackendKind {
    /// pure-rust oracle — fast, used for the big scheme sweeps
    Host,
    /// AOT XLA via PJRT — the production path (requires `make artifacts`)
    Pjrt,
}

impl BackendKind {
    pub fn parse(s: &str) -> Option<BackendKind> {
        match s {
            "host" => Some(BackendKind::Host),
            "pjrt" | "xla" => Some(BackendKind::Pjrt),
            _ => None,
        }
    }
}

/// Build one backend for `model` under this experiment's data geometry.
fn build_backend(exp: &Experiment, model: &str, kind: BackendKind) -> Result<Box<dyn Backend>> {
    match kind {
        BackendKind::Host => Ok(Box::new(HostBackend::for_model(
            model,
            exp.synth.dim,
            exp.synth.classes,
            exp.trainer.seed,
        )?)),
        BackendKind::Pjrt => {
            let dir = std::env::var("FEEL_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
            let rt = Runtime::load(std::path::Path::new(&dir))?;
            anyhow::ensure!(
                rt.manifest.input_dim == exp.synth.dim,
                "artifacts input_dim {} != experiment dim {} (re-run aot.py or set data.dim)",
                rt.manifest.input_dim,
                exp.synth.dim
            );
            Ok(Box::new(PjrtBackend::new(rt, model)?))
        }
    }
}

/// Build the (single) backend for an experiment's default model.
pub fn make_backend(exp: &Experiment, kind: BackendKind) -> Result<Box<dyn Backend>> {
    build_backend(exp, &exp.model, kind)
}

/// The owning form of `coordinator::BackendSet`: one boxed backend per
/// model family plus the device → family assignment, resolved from the
/// experiment's per-tier rules (`fleet.backends` / `--backends`).
/// Experiments hold a `FleetBackends` and lend [`FleetBackends::set`]
/// views to trainers, exactly as they held a `Box<dyn Backend>` and lent
/// `as_ref()` before.
pub struct FleetBackends {
    boxes: Vec<Box<dyn Backend>>,
    names: Vec<String>,
    assign: Vec<usize>,
}

impl FleetBackends {
    /// The one place the borrowed view is assembled — `set()` and the
    /// build-time validation in [`make_fleet_backends`] must construct
    /// the exact same thing or the `expect` below loses its
    /// justification.
    fn build_set(&self) -> Result<BackendSet<'_>> {
        BackendSet::new(
            self.names
                .iter()
                .cloned()
                .zip(self.boxes.iter().map(|b| b.as_ref()))
                .collect(),
            self.assign.clone(),
        )
    }

    /// The borrowed view a `Trainer` resolves devices through.
    pub fn set(&self) -> BackendSet<'_> {
        self.build_set().expect("validated when the FleetBackends was built")
    }

    /// Number of distinct model families.
    pub fn family_count(&self) -> usize {
        self.boxes.len()
    }
}

/// Resolve an experiment's per-tier backend rules into an owned backend
/// fleet. No rules = the classic homogeneous fleet on `exp.model` and
/// `kind`; rules override their tier (a rule without an explicit backend
/// kind inherits `kind`), uncovered tiers fall back to the default. Two
/// tiers naming the same model must agree on the backend kind — the
/// model is one family with one canonical backend.
pub fn make_fleet_backends(exp: &Experiment, kind: BackendKind) -> Result<FleetBackends> {
    anyhow::ensure!(exp.k >= 1, "fleet.k must be >= 1");
    exp.check_backend_tiers()?;
    // per-tier (model, kind) spec, defaulting to the experiment's model
    let mut tier_spec: Vec<(String, BackendKind)> =
        (0..exp.tier_count()).map(|_| (exp.model.clone(), kind)).collect();
    for r in &exp.backends {
        let bk = match &r.backend {
            None => kind,
            Some(s) => BackendKind::parse(s)
                .ok_or_else(|| anyhow::anyhow!("bad backend {s:?} in fleet.backends"))?,
        };
        tier_spec[r.tier] = (r.model.clone(), bk);
    }
    // distinct model families in first-device order; devices assign to
    // their tier's family
    let mut names: Vec<String> = Vec::new();
    let mut kinds: Vec<BackendKind> = Vec::new();
    let mut assign = Vec::with_capacity(exp.k);
    for id in 0..exp.k {
        let (model, bk) = &tier_spec[exp.tier_of(id)];
        let fam = match names.iter().position(|n| n == model) {
            Some(f) => {
                anyhow::ensure!(
                    kinds[f] == *bk,
                    "model {model:?} is assigned both {:?} and {bk:?} backends — one model \
                     family needs one canonical backend",
                    kinds[f]
                );
                f
            }
            None => {
                names.push(model.clone());
                kinds.push(*bk);
                names.len() - 1
            }
        };
        assign.push(fam);
    }
    let boxes = names
        .iter()
        .zip(&kinds)
        .map(|(model, bk)| build_backend(exp, model, *bk))
        .collect::<Result<Vec<_>>>()?;
    let fleet = FleetBackends { boxes, names, assign };
    // validate the derived set once so `set()` can never fail later
    fleet.build_set()?;
    Ok(fleet)
}

/// Generate this experiment's train/test datasets. The same seed is used
/// for both so they share class prototypes (train/test from one
/// distribution); `generate` itself splits determinism by sample index.
pub fn make_data(exp: &Experiment) -> (Dataset, Dataset) {
    let seed = exp.trainer.seed ^ 0x7e57_da7a;
    let train = generate(&exp.synth, exp.train_n, seed);
    let test = generate(&exp.synth, exp.test_n, seed);
    (train, test)
}

/// Run one scheme to completion (warm start optional) and return its log.
/// Honors the experiment's per-tier backend rules — a config with
/// `fleet.backends` runs a heterogeneous fleet; without, this is the
/// classic single-backend path (`Trainer::new`-equivalent bitwise).
#[allow(clippy::too_many_arguments)]
pub fn run_scheme(
    exp: &Experiment,
    scheme: Scheme,
    kind: BackendKind,
    periods: usize,
    warm_steps: usize,
    time_limit: Option<f64>,
) -> Result<TrainLog> {
    let backends = make_fleet_backends(exp, kind)?;
    let (train, test) = make_data(exp);
    let mut rng = Pcg::seeded(exp.trainer.seed ^ 0xf1ee7);
    let fleet = exp.fleet(&mut rng);
    let mut cfg = exp.trainer.clone();
    cfg.scheme = scheme;
    let mut tr =
        Trainer::with_backends(cfg, fleet, &train, &test, exp.partition, backends.set())?;
    if warm_steps > 0 {
        tr.warm_start(warm_steps, 64, 0.05)?;
    }
    match time_limit {
        Some(t) => tr.run_for_time(t, periods)?,
        None => tr.run(periods)?,
    };
    Ok(tr.log.clone())
}
