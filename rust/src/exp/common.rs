//! Shared wiring for the paper-experiment drivers: build the world
//! (dataset + fleet + backend) from an `Experiment` and run one scheme.

use anyhow::Result;

use crate::config::Experiment;
use crate::coordinator::{Backend, HostBackend, PjrtBackend, Scheme, TrainLog, Trainer};
use crate::data::{generate, Dataset};
use crate::runtime::Runtime;
use crate::util::rng::Pcg;

/// Which compute backend the experiment uses.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BackendKind {
    /// pure-rust oracle — fast, used for the big scheme sweeps
    Host,
    /// AOT XLA via PJRT — the production path (requires `make artifacts`)
    Pjrt,
}

impl BackendKind {
    pub fn parse(s: &str) -> Option<BackendKind> {
        match s {
            "host" => Some(BackendKind::Host),
            "pjrt" | "xla" => Some(BackendKind::Pjrt),
            _ => None,
        }
    }
}

/// Build the backend for an experiment.
pub fn make_backend(exp: &Experiment, kind: BackendKind) -> Result<Box<dyn Backend>> {
    match kind {
        BackendKind::Host => Ok(Box::new(HostBackend::for_model(
            &exp.model,
            exp.synth.dim,
            exp.synth.classes,
            exp.trainer.seed,
        )?)),
        BackendKind::Pjrt => {
            let dir = std::env::var("FEEL_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
            let rt = Runtime::load(std::path::Path::new(&dir))?;
            anyhow::ensure!(
                rt.manifest.input_dim == exp.synth.dim,
                "artifacts input_dim {} != experiment dim {} (re-run aot.py or set data.dim)",
                rt.manifest.input_dim,
                exp.synth.dim
            );
            Ok(Box::new(PjrtBackend::new(rt, &exp.model)?))
        }
    }
}

/// Generate this experiment's train/test datasets. The same seed is used
/// for both so they share class prototypes (train/test from one
/// distribution); `generate` itself splits determinism by sample index.
pub fn make_data(exp: &Experiment) -> (Dataset, Dataset) {
    let seed = exp.trainer.seed ^ 0x7e57_da7a;
    let train = generate(&exp.synth, exp.train_n, seed);
    let test = generate(&exp.synth, exp.test_n, seed);
    (train, test)
}

/// Run one scheme to completion (warm start optional) and return its log.
#[allow(clippy::too_many_arguments)]
pub fn run_scheme(
    exp: &Experiment,
    scheme: Scheme,
    kind: BackendKind,
    periods: usize,
    warm_steps: usize,
    time_limit: Option<f64>,
) -> Result<TrainLog> {
    let backend = make_backend(exp, kind)?;
    let (train, test) = make_data(exp);
    let mut rng = Pcg::seeded(exp.trainer.seed ^ 0xf1ee7);
    let fleet = exp.fleet(&mut rng);
    let mut cfg = exp.trainer.clone();
    cfg.scheme = scheme;
    let mut tr = Trainer::new(cfg, fleet, &train, &test, exp.partition, backend.as_ref())?;
    if warm_steps > 0 {
        tr.warm_start(warm_steps, 64, 0.05)?;
    }
    match time_limit {
        Some(t) => tr.run_for_time(t, periods)?,
        None => tr.run(periods)?,
    };
    Ok(tr.log.clone())
}
