//! Shared wiring for the paper-experiment drivers: build the world
//! (dataset + fleet + backend) from an `Experiment` and run one scheme —
//! flat single-cell or hierarchical (`topology.cells` > 1).

use std::path::Path;

use anyhow::{Context, Result};

use crate::config::Experiment;
use crate::coordinator::{
    Backend, BackendSet, HostBackend, PjrtBackend, Scheme, TrainLog, Trainer,
};
use crate::data::{generate, Dataset};
use crate::device::Device;
use crate::hier::{CellTopology, CellWorld, HierConfig, HierTrainer};
use crate::runtime::Runtime;
use crate::util::rng::Pcg;

/// Which compute backend the experiment uses.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BackendKind {
    /// pure-rust oracle — fast, used for the big scheme sweeps
    Host,
    /// AOT XLA via PJRT — the production path (requires `make artifacts`)
    Pjrt,
}

impl BackendKind {
    pub fn parse(s: &str) -> Option<BackendKind> {
        match s {
            "host" => Some(BackendKind::Host),
            "pjrt" | "xla" => Some(BackendKind::Pjrt),
            _ => None,
        }
    }
}

/// Build one backend for `model` under this experiment's data geometry.
fn build_backend(exp: &Experiment, model: &str, kind: BackendKind) -> Result<Box<dyn Backend>> {
    match kind {
        BackendKind::Host => Ok(Box::new(HostBackend::for_model(
            model,
            exp.synth.dim,
            exp.synth.classes,
            exp.trainer.seed,
        )?)),
        BackendKind::Pjrt => {
            let dir = std::env::var("FEEL_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
            let rt = Runtime::load(std::path::Path::new(&dir))?;
            anyhow::ensure!(
                rt.manifest.input_dim == exp.synth.dim,
                "artifacts input_dim {} != experiment dim {} (re-run aot.py or set data.dim)",
                rt.manifest.input_dim,
                exp.synth.dim
            );
            Ok(Box::new(PjrtBackend::new(rt, model)?))
        }
    }
}

/// Build the (single) backend for an experiment's default model.
pub fn make_backend(exp: &Experiment, kind: BackendKind) -> Result<Box<dyn Backend>> {
    build_backend(exp, &exp.model, kind)
}

/// The owning form of `coordinator::BackendSet`: one boxed backend per
/// model family plus the device → family assignment, resolved from the
/// experiment's per-tier rules (`fleet.backends` / `--backends`).
/// Experiments hold a `FleetBackends` and lend [`FleetBackends::set`]
/// views to trainers, exactly as they held a `Box<dyn Backend>` and lent
/// `as_ref()` before.
pub struct FleetBackends {
    boxes: Vec<Box<dyn Backend>>,
    names: Vec<String>,
    assign: Vec<usize>,
}

impl FleetBackends {
    /// The one place the borrowed view is assembled — `set()` and the
    /// build-time validation in [`make_fleet_backends`] must construct
    /// the exact same thing or the `expect` below loses its
    /// justification.
    fn build_set(&self) -> Result<BackendSet<'_>> {
        BackendSet::new(
            self.names
                .iter()
                .cloned()
                .zip(self.boxes.iter().map(|b| b.as_ref()))
                .collect(),
            self.assign.clone(),
        )
    }

    /// The borrowed view a `Trainer` resolves devices through.
    pub fn set(&self) -> BackendSet<'_> {
        // lint: allow(panic-path): same construction validated when the FleetBackends was built
        self.build_set().expect("validated when the FleetBackends was built")
    }

    /// Number of distinct model families.
    pub fn family_count(&self) -> usize {
        self.boxes.len()
    }
}

/// Resolve an experiment's per-tier backend rules into an owned backend
/// fleet. No rules = the classic homogeneous fleet on `exp.model` and
/// `kind`; rules override their tier (a rule without an explicit backend
/// kind inherits `kind`), uncovered tiers fall back to the default. Two
/// tiers naming the same model must agree on the backend kind — the
/// model is one family with one canonical backend.
pub fn make_fleet_backends(exp: &Experiment, kind: BackendKind) -> Result<FleetBackends> {
    anyhow::ensure!(exp.k >= 1, "fleet.k must be >= 1");
    exp.check_backend_tiers()?;
    // per-tier (model, kind) spec, defaulting to the experiment's model
    let mut tier_spec: Vec<(String, BackendKind)> =
        (0..exp.tier_count()).map(|_| (exp.model.clone(), kind)).collect();
    for r in &exp.backends {
        let bk = match &r.backend {
            None => kind,
            Some(s) => BackendKind::parse(s)
                .ok_or_else(|| anyhow::anyhow!("bad backend {s:?} in fleet.backends"))?,
        };
        tier_spec[r.tier] = (r.model.clone(), bk);
    }
    // distinct model families in first-device order; devices assign to
    // their tier's family
    let mut names: Vec<String> = Vec::new();
    let mut kinds: Vec<BackendKind> = Vec::new();
    let mut assign = Vec::with_capacity(exp.k);
    for id in 0..exp.k {
        let (model, bk) = &tier_spec[exp.tier_of(id)];
        let fam = match names.iter().position(|n| n == model) {
            Some(f) => {
                anyhow::ensure!(
                    kinds[f] == *bk,
                    "model {model:?} is assigned both {:?} and {bk:?} backends — one model \
                     family needs one canonical backend",
                    kinds[f]
                );
                f
            }
            None => {
                names.push(model.clone());
                kinds.push(*bk);
                names.len() - 1
            }
        };
        assign.push(fam);
    }
    let boxes = names
        .iter()
        .zip(&kinds)
        .map(|(model, bk)| build_backend(exp, model, *bk))
        .collect::<Result<Vec<_>>>()?;
    let fleet = FleetBackends { boxes, names, assign };
    // validate the derived set once so `set()` can never fail later
    fleet.build_set()?;
    Ok(fleet)
}

/// Generate this experiment's train/test datasets. The same seed is used
/// for both so they share class prototypes (train/test from one
/// distribution); `generate` itself splits determinism by sample index.
pub fn make_data(exp: &Experiment) -> (Dataset, Dataset) {
    let seed = exp.trainer.seed ^ 0x7e57_da7a;
    let train = generate(&exp.synth, exp.train_n, seed);
    let test = generate(&exp.synth, exp.test_n, seed);
    (train, test)
}

/// The owned world of a hierarchical experiment: per-cell fleets, data
/// shards, and backend registries, plus the shared test set. Trainers
/// borrow from it (`HierWorld::cell_worlds`), exactly as flat experiments
/// hold a `FleetBackends`/`Dataset` and lend views to a `Trainer`.
pub struct HierWorld {
    pub topo: CellTopology,
    pub fleets: Vec<Vec<Device>>,
    pub cell_train: Vec<Dataset>,
    pub test: Dataset,
    backends: Vec<FleetBackends>,
}

impl HierWorld {
    /// Drain the per-cell fleets out of the world so `cell_worlds` can
    /// hand them to the trainer without a deep clone. Split from
    /// `cell_worlds` so the `&mut self` borrow ends before the trainer
    /// starts borrowing `&self` views.
    pub fn take_fleets(&mut self) -> Vec<Vec<Device>> {
        std::mem::take(&mut self.fleets)
    }

    /// Per-cell views for `HierTrainer::new`, in cell order: borrowed
    /// data/backends, with ownership of the (taken) fleets moved in.
    pub fn cell_worlds(&self, fleets: Vec<Vec<Device>>) -> Result<Vec<CellWorld<'_>>> {
        anyhow::ensure!(
            fleets.len() == self.cell_train.len(),
            "{} fleets for {} cells (did take_fleets run twice?)",
            fleets.len(),
            self.cell_train.len()
        );
        Ok(fleets
            .into_iter()
            .zip(&self.cell_train)
            .zip(&self.backends)
            .map(|((fleet, train), fb)| CellWorld { fleet, backends: fb.set(), train })
            .collect())
    }
}

/// Build a hierarchical world from an experiment: split the fleet into
/// `exp.cells` contiguous cells on even bandwidth budgets
/// (`CellTopology`), split the dataset across cells by the experiment's
/// partition kind, and resolve each cell's backend registry from the
/// per-tier rules (tiers are cell-local: a cell's device `j` sits in
/// tier `j % 3`, the same shape `paper_cpu_fleet` gives the flat run).
/// One cell reproduces the flat world bitwise: the same fleet RNG stream,
/// the whole band, the dataset in natural order.
pub fn make_hier_world(exp: &Experiment, kind: BackendKind) -> Result<HierWorld> {
    let topo = CellTopology::new(exp.k, exp.cells, exp.tau, exp.cell)?;
    let (train, test) = make_data(exp);
    let mut drng = Pcg::seeded(exp.trainer.seed ^ 0xce11_da7a);
    let cell_train: Vec<Dataset> = topo
        .split_data(&train, exp.partition, &mut drng)
        .iter()
        .map(|idx| train.subset(idx))
        .collect();
    let mut frng = Pcg::seeded(exp.trainer.seed ^ 0xf1ee7);
    let mut fleets = Vec::with_capacity(topo.cells());
    let mut backends = Vec::with_capacity(topo.cells());
    for c in 0..topo.cells() {
        let kc = topo.size(c);
        anyhow::ensure!(
            cell_train[c].len() >= 2 * kc,
            "cell {c} got {} samples for {} devices — raise data.train_n or the \
             partition's alpha",
            cell_train[c].len(),
            kc
        );
        fleets.push(exp.fleet_with(kc, topo.config(c), &mut frng));
        let mut cell_exp = exp.clone();
        cell_exp.k = kc;
        let fb = make_fleet_backends(&cell_exp, kind)
            .with_context(|| format!("resolving cell {c}'s backend rules (cell fleet k = {kc})"))?;
        backends.push(fb);
    }
    Ok(HierWorld { topo, fleets, cell_train, test, backends })
}

/// What a hierarchical run produced, beyond the merged log.
pub struct HierRun {
    /// all cells' records interleaved period-major (see
    /// `HierTrainer::merged_log`)
    pub log: TrainLog,
    pub cells: usize,
    pub tau: usize,
    pub cloud_rounds: usize,
    /// simulated seconds at the end of the run: the slowest cell's clock
    /// after the final cloud barrier — the hierarchy's makespan. NOT the
    /// merged log's last record (that is the last *cell's* pre-barrier
    /// time, which understates a run whose slowest cell sits elsewhere).
    pub sim_time: f64,
    /// Chrome trace-event JSON of the whole hierarchy (only when the run
    /// was traced — see [`run_hier_scheme_traced`])
    pub trace: Option<String>,
    /// per-period metrics snapshots as JSONL (only when traced)
    pub metrics: Option<String>,
    /// predicted-vs-realized audit ledger as JSONL (only when traced)
    pub audit: Option<String>,
}

/// Run one scheme through the hierarchical topology the experiment
/// describes (`topology.cells` cells, cloud merges every `topology.tau`
/// edge rounds). The `topology.cells = 1` degenerate case reproduces
/// [`run_scheme`] record-for-record.
pub fn run_hier_scheme(
    exp: &Experiment,
    scheme: Scheme,
    kind: BackendKind,
    periods: usize,
    warm_steps: usize,
) -> Result<HierRun> {
    run_hier_scheme_checkpointed(exp, scheme, kind, periods, warm_steps, 0, None, None)
}

/// [`run_hier_scheme`] with the checkpoint/resume seam exposed: save the
/// hierarchy to `checkpoint` every `every` tau-blocks (plus a final
/// snapshot), and/or restore state from `resume` before running. A
/// resumed run skips the warm start — its model state comes from the
/// checkpoint.
#[allow(clippy::too_many_arguments)]
pub fn run_hier_scheme_checkpointed(
    exp: &Experiment,
    scheme: Scheme,
    kind: BackendKind,
    periods: usize,
    warm_steps: usize,
    every: usize,
    checkpoint: Option<&Path>,
    resume: Option<&Path>,
) -> Result<HierRun> {
    run_hier_scheme_traced(exp, scheme, kind, periods, warm_steps, every, checkpoint, resume, false)
}

/// [`run_hier_scheme_checkpointed`] with observability: when `obs` is
/// set, every cell's trainer and the cloud tier record trace events and
/// metrics, returned on the `HierRun`. The training numerics are
/// bitwise-identical either way.
#[allow(clippy::too_many_arguments)]
pub fn run_hier_scheme_traced(
    exp: &Experiment,
    scheme: Scheme,
    kind: BackendKind,
    periods: usize,
    warm_steps: usize,
    every: usize,
    checkpoint: Option<&Path>,
    resume: Option<&Path>,
    obs: bool,
) -> Result<HierRun> {
    let mut world = make_hier_world(exp, kind)?;
    let fleets = world.take_fleets();
    let mut cfg = exp.trainer.clone();
    cfg.scheme = scheme;
    // tau flows from the topology (one source of truth), the per-cell
    // policies and sampling fraction from the experiment's overrides
    let hc = HierConfig {
        tau: world.topo.tau(),
        policies: exp.resolved_cell_policies(),
        cell_frac: exp.cell_frac,
    };
    let worlds = world.cell_worlds(fleets)?;
    let mut tr = HierTrainer::new(cfg, hc, worlds, &world.test, exp.partition)?;
    if obs {
        tr.enable_obs();
    }
    match resume {
        Some(path) => tr.resume_from(path)?,
        None if warm_steps > 0 => tr.warm_start(warm_steps, 64, 0.05)?,
        None => {}
    }
    match checkpoint {
        Some(path) => {
            tr.run_checkpointed(periods, every, path)?;
            tr.save_checkpoint(path)?;
        }
        None => tr.run(periods)?,
    }
    Ok(HierRun {
        log: tr.merged_log(),
        cells: tr.cell_count(),
        tau: tr.tau(),
        cloud_rounds: tr.cloud_rounds(),
        sim_time: tr.sim_time(),
        trace: obs.then(|| tr.export_trace()),
        metrics: obs.then(|| tr.export_metrics()),
        audit: obs.then(|| tr.export_audit()),
    })
}

/// Run one scheme to completion (warm start optional) and return its log.
/// Honors the experiment's per-tier backend rules — a config with
/// `fleet.backends` runs a heterogeneous fleet; without, this is the
/// classic single-backend path (`Trainer::new`-equivalent bitwise).
#[allow(clippy::too_many_arguments)]
pub fn run_scheme(
    exp: &Experiment,
    scheme: Scheme,
    kind: BackendKind,
    periods: usize,
    warm_steps: usize,
    time_limit: Option<f64>,
) -> Result<TrainLog> {
    let backends = make_fleet_backends(exp, kind)?;
    let (train, test) = make_data(exp);
    let mut rng = Pcg::seeded(exp.trainer.seed ^ 0xf1ee7);
    let fleet = exp.fleet(&mut rng);
    let mut cfg = exp.trainer.clone();
    cfg.scheme = scheme;
    let mut tr =
        Trainer::with_backends(cfg, fleet, &train, &test, exp.partition, backends.set())?;
    if warm_steps > 0 {
        tr.warm_start(warm_steps, 64, 0.05)?;
    }
    match time_limit {
        Some(t) => tr.run_for_time(t, periods)?,
        None => tr.run(periods)?,
    };
    Ok(tr.log.clone())
}
