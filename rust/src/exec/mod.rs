//! Parallel device-execution engine.
//!
//! The FEEL coordinator plans each period (scheme.rs picks per-device
//! batchsizes and prices the period's latency under the wireless/compute
//! models), then *executes* the K per-device learning steps. Execution is
//! embarrassingly parallel — each device's step depends only on the global
//! parameters, the device's own state, and a counter-derived RNG stream —
//! so this module fans it out over a scoped thread pool.
//!
//! Determinism contract (validated by `tests/exec_determinism.rs`):
//! running any scheme with any `--threads` value produces bitwise-identical
//! `TrainLog` records. Three mechanisms enforce it:
//!
//! 1. per-device RNG streams are derived from `(seed, period, device_id)`
//!    (`Pcg::for_device`), never from shared sampler state, so batch
//!    selection cannot depend on execution order;
//! 2. workers return their contributions and **all cross-device reduction
//!    happens on the caller's thread in fixed device order** (f64
//!    accumulation via `grad::Aggregator`);
//! 3. results are collected into device-indexed slots, so thread
//!    scheduling cannot reorder them.

pub mod engine;
pub mod round;

pub use engine::Engine;
pub use round::{
    eval_round, gradient_round, individual_round, model_fl_round, GradOutcome, LocalFitOutcome,
    LocalStepOutcome,
};
