//! Parallel device-execution engine.
//!
//! The FEEL coordinator plans each period (scheme.rs picks per-device
//! batchsizes and prices the period's latency under the wireless/compute
//! models), then *executes* the K per-device learning steps. Execution is
//! embarrassingly parallel — each device's step depends only on the global
//! parameters, the device's own state, and a counter-derived RNG stream —
//! so this module fans it out over a scoped thread pool.
//!
//! Determinism contract (validated by `tests/exec_determinism.rs`):
//! running any scheme with any `--threads` value produces bitwise-identical
//! `TrainLog` records. Three mechanisms enforce it:
//!
//! 1. per-device RNG streams are derived from `(seed, period, device_id)`
//!    (`Pcg::for_device`), never from shared sampler state, so batch
//!    selection cannot depend on execution order;
//! 2. all cross-device reduction happens in fixed device order with f64
//!    accumulation (`grad::Aggregator`). The gradient path folds devices
//!    into per-shard aggregators on the workers (`gradient_round_sharded`),
//!    but shard boundaries are a pure function of the fleet size K — never
//!    the thread count — so the fold grouping is invariant too;
//! 3. results are collected into device-/shard-indexed slots, so thread
//!    scheduling cannot reorder them.
//!
//! Heterogeneous fleets add a fourth mechanism, not an exception: each
//! executor resolves a device's backend and model family through
//! `coordinator::BackendSet` — a pure function of the device id — and the
//! sharded fold keeps one tagged aggregator per family inside each shard
//! (`GradShard::aggs`), so mixed fleets reduce per family in the same
//! fixed device order.

pub mod engine;
pub mod round;

pub use engine::Engine;
pub use round::{
    agg_shard_size, eval_round, gradient_round, gradient_round_sharded,
    gradient_round_sharded_masked, gradient_round_subset, individual_round, model_fl_round,
    GradOutcome, GradShard, LocalFitOutcome, LocalStepOutcome, MAX_AGG_SHARDS,
};
