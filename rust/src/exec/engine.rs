//! The scoped-thread fan-out primitive the round executors are built on.

use anyhow::Result;

use crate::util::threads;

/// Deterministic parallel executor: runs an indexed job per item on up to
/// `threads` scoped threads and returns the results in item order.
///
/// Items are split into contiguous chunks (one per thread); each result
/// lands in its item's slot, so the output order — and therefore every
/// downstream reduction — is independent of thread scheduling.
#[derive(Clone, Copy, Debug)]
pub struct Engine {
    threads: usize,
}

impl Engine {
    /// `threads = 0` resolves to the crate-wide default
    /// (`util::threads::global_threads()`, i.e. all cores unless the CLI
    /// `--threads` flag or `train.threads` config key capped it).
    pub fn new(threads: usize) -> Engine {
        let t = if threads == 0 { threads::global_threads() } else { threads };
        Engine { threads: t.max(1) }
    }

    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Run `f(k, &mut items[k])` for every item, in parallel, returning the
    /// results in item order. The first error (by item order) is returned
    /// after all workers finish.
    pub fn run_mut<T, R, F>(&self, items: &mut [T], f: F) -> Result<Vec<R>>
    where
        T: Send,
        R: Send,
        F: Fn(usize, &mut T) -> Result<R> + Sync,
    {
        // an item is a chunk of one
        self.run_chunked(items, 1, |k, _, ts| f(k, &mut ts[0]))
    }

    /// Run `f(ci, offset, chunk)` for every contiguous `chunk`-sized block
    /// of `items`, in parallel, returning one result per chunk in chunk
    /// order. `offset` is the index of the chunk's first item.
    ///
    /// Chunk boundaries are a pure function of `(items.len(), chunk)` —
    /// never of the thread count — so callers that fold a chunk serially
    /// (e.g. per-shard gradient aggregation) get identical fold groupings,
    /// and therefore identical numerics, at any `--threads` value. Threads
    /// only decide *which worker* runs a chunk, never what the chunk is.
    pub fn run_chunked<T, R, F>(&self, items: &mut [T], chunk: usize, f: F) -> Result<Vec<R>>
    where
        T: Send,
        R: Send,
        F: Fn(usize, usize, &mut [T]) -> Result<R> + Sync,
    {
        let n = items.len();
        if n == 0 {
            return Ok(Vec::new());
        }
        let chunk = chunk.max(1);
        let nchunks = n.div_ceil(chunk);
        let threads = self.threads.min(nchunks);
        if threads <= 1 {
            return threads::with_budget(1, || {
                items
                    .chunks_mut(chunk)
                    .enumerate()
                    .map(|(ci, ts)| f(ci, ci * chunk, ts))
                    .collect()
            });
        }
        // contiguous runs of `per` whole chunks per worker thread
        let per = nchunks.div_ceil(threads);
        let mut slots: Vec<Option<Result<R>>> = Vec::with_capacity(nchunks);
        slots.resize_with(nchunks, || None);
        std::thread::scope(|s| {
            let f = &f;
            for (g, (group, outs)) in items
                .chunks_mut(per * chunk)
                .zip(slots.chunks_mut(per))
                .enumerate()
            {
                let base = g * per;
                s.spawn(move || {
                    // budget 1: device jobs must not nest another fan-out
                    threads::with_budget(1, || {
                        for (j, (ts, o)) in
                            group.chunks_mut(chunk).zip(outs.iter_mut()).enumerate()
                        {
                            let ci = base + j;
                            *o = Some(f(ci, ci * chunk, ts));
                        }
                    });
                });
            }
        });
        slots
            .into_iter()
            .map(|o| o.unwrap_or_else(|| Err(anyhow::anyhow!("exec worker lost a slot"))))
            .collect()
    }

    /// Run `f(k)` for `k in 0..n`, in parallel, returning results in index
    /// order. The read-only variant of `run_mut` for jobs that borrow their
    /// inputs immutably (e.g. per-device evaluation).
    pub fn run_indexed<R, F>(&self, n: usize, f: F) -> Result<Vec<R>>
    where
        R: Send,
        F: Fn(usize) -> Result<R> + Sync,
    {
        let threads = self.threads.min(n);
        if threads <= 1 {
            return threads::with_budget(1, || (0..n).map(&f).collect());
        }
        let chunk = n.div_ceil(threads);
        let mut slots: Vec<Option<Result<R>>> = Vec::with_capacity(n);
        slots.resize_with(n, || None);
        std::thread::scope(|s| {
            let f = &f;
            for (ci, outs) in slots.chunks_mut(chunk).enumerate() {
                s.spawn(move || {
                    threads::with_budget(1, || {
                        for (j, o) in outs.iter_mut().enumerate() {
                            *o = Some(f(ci * chunk + j));
                        }
                    });
                });
            }
        });
        slots
            .into_iter()
            .map(|o| o.unwrap_or_else(|| Err(anyhow::anyhow!("exec worker lost a slot"))))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn results_in_item_order_any_thread_count() {
        for threads in [1usize, 2, 3, 8, 64] {
            let e = Engine::new(threads);
            let mut items: Vec<usize> = (0..17).collect();
            let out = e.run_mut(&mut items, |k, v| Ok(k * 10 + *v)).unwrap();
            assert_eq!(out, (0..17).map(|k| k * 11).collect::<Vec<_>>(), "t={threads}");
        }
    }

    #[test]
    fn mutations_land_on_the_right_item() {
        let e = Engine::new(4);
        let mut items = vec![0usize; 10];
        e.run_mut(&mut items, |k, v| {
            *v = k + 1;
            Ok(())
        })
        .unwrap();
        assert_eq!(items, (1..=10).collect::<Vec<_>>());
    }

    #[test]
    fn errors_propagate() {
        let e = Engine::new(3);
        let mut items = vec![(); 6];
        let r = e.run_mut(&mut items, |k, _| {
            if k == 4 {
                anyhow::bail!("device {k} failed")
            }
            Ok(k)
        });
        assert!(r.unwrap_err().to_string().contains("device 4"));
    }

    #[test]
    fn empty_and_indexed() {
        let e = Engine::new(8);
        let mut empty: Vec<u32> = Vec::new();
        assert!(e.run_mut(&mut empty, |_, _| Ok(0)).unwrap().is_empty());
        assert!(e.run_indexed(0, |_| Ok(0)).unwrap().is_empty());
        let out = e.run_indexed(9, |k| Ok(k * k)).unwrap();
        assert_eq!(out, (0..9).map(|k| k * k).collect::<Vec<_>>());
    }

    #[test]
    fn zero_resolves_to_cores() {
        let e = Engine::new(0);
        assert!(e.threads() >= 1);
    }

    #[test]
    fn chunked_results_identical_at_any_thread_count() {
        // 17 items, chunk 3 -> chunks [0..3), [3..6), ..., [15..17)
        let want: Vec<(usize, usize, usize)> = vec![
            (0, 0, 3),
            (1, 3, 3),
            (2, 6, 3),
            (3, 9, 3),
            (4, 12, 3),
            (5, 15, 2),
        ];
        for threads in [1usize, 2, 3, 8, 64] {
            let e = Engine::new(threads);
            let mut items: Vec<usize> = (0..17).collect();
            let out = e
                .run_chunked(&mut items, 3, |ci, off, ts| {
                    // items land in the right chunk
                    for (j, v) in ts.iter().enumerate() {
                        assert_eq!(*v, off + j);
                    }
                    Ok((ci, off, ts.len()))
                })
                .unwrap();
            assert_eq!(out, want, "t={threads}");
        }
    }

    #[test]
    fn chunked_mutations_and_errors() {
        let e = Engine::new(4);
        let mut items = vec![0usize; 10];
        e.run_chunked(&mut items, 4, |_, off, ts| {
            for (j, v) in ts.iter_mut().enumerate() {
                *v = off + j + 1;
            }
            Ok(())
        })
        .unwrap();
        assert_eq!(items, (1..=10).collect::<Vec<_>>());

        let mut items = vec![(); 9];
        let r = e.run_chunked(&mut items, 2, |ci, _, _| {
            if ci == 3 {
                anyhow::bail!("shard {ci} failed")
            }
            Ok(ci)
        });
        assert!(r.unwrap_err().to_string().contains("shard 3"));

        let mut empty: Vec<u8> = Vec::new();
        assert!(e.run_chunked(&mut empty, 5, |_, _, _| Ok(())).unwrap().is_empty());
    }
}
