//! The scoped-thread fan-out primitive the round executors are built on.

use anyhow::Result;

use crate::util::threads;

/// Deterministic parallel executor: runs an indexed job per item on up to
/// `threads` scoped threads and returns the results in item order.
///
/// Items are split into contiguous chunks (one per thread); each result
/// lands in its item's slot, so the output order — and therefore every
/// downstream reduction — is independent of thread scheduling.
#[derive(Clone, Copy, Debug)]
pub struct Engine {
    threads: usize,
}

impl Engine {
    /// `threads = 0` resolves to the crate-wide default
    /// (`util::threads::global_threads()`, i.e. all cores unless the CLI
    /// `--threads` flag or `train.threads` config key capped it).
    pub fn new(threads: usize) -> Engine {
        let t = if threads == 0 { threads::global_threads() } else { threads };
        Engine { threads: t.max(1) }
    }

    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Run `f(k, &mut items[k])` for every item, in parallel, returning the
    /// results in item order. The first error (by item order) is returned
    /// after all workers finish.
    pub fn run_mut<T, R, F>(&self, items: &mut [T], f: F) -> Result<Vec<R>>
    where
        T: Send,
        R: Send,
        F: Fn(usize, &mut T) -> Result<R> + Sync,
    {
        let n = items.len();
        let threads = self.threads.min(n);
        if threads <= 1 {
            // single-worker path: per-device jobs also get a serial budget,
            // so `threads = 1` means one thread, full stop
            return threads::with_budget(1, || {
                items.iter_mut().enumerate().map(|(k, t)| f(k, t)).collect()
            });
        }
        let chunk = n.div_ceil(threads);
        let mut slots: Vec<Option<Result<R>>> = Vec::with_capacity(n);
        slots.resize_with(n, || None);
        std::thread::scope(|s| {
            let f = &f;
            for (ci, (ts, outs)) in
                items.chunks_mut(chunk).zip(slots.chunks_mut(chunk)).enumerate()
            {
                s.spawn(move || {
                    // budget 1: device jobs must not nest another fan-out
                    threads::with_budget(1, || {
                        for (j, (t, o)) in ts.iter_mut().zip(outs.iter_mut()).enumerate() {
                            *o = Some(f(ci * chunk + j, t));
                        }
                    });
                });
            }
        });
        slots.into_iter().map(|o| o.expect("exec worker lost a slot")).collect()
    }

    /// Run `f(k)` for `k in 0..n`, in parallel, returning results in index
    /// order. The read-only variant of `run_mut` for jobs that borrow their
    /// inputs immutably (e.g. per-device evaluation).
    pub fn run_indexed<R, F>(&self, n: usize, f: F) -> Result<Vec<R>>
    where
        R: Send,
        F: Fn(usize) -> Result<R> + Sync,
    {
        let threads = self.threads.min(n);
        if threads <= 1 {
            return threads::with_budget(1, || (0..n).map(&f).collect());
        }
        let chunk = n.div_ceil(threads);
        let mut slots: Vec<Option<Result<R>>> = Vec::with_capacity(n);
        slots.resize_with(n, || None);
        std::thread::scope(|s| {
            let f = &f;
            for (ci, outs) in slots.chunks_mut(chunk).enumerate() {
                s.spawn(move || {
                    threads::with_budget(1, || {
                        for (j, o) in outs.iter_mut().enumerate() {
                            *o = Some(f(ci * chunk + j));
                        }
                    });
                });
            }
        });
        slots.into_iter().map(|o| o.expect("exec worker lost a slot")).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn results_in_item_order_any_thread_count() {
        for threads in [1usize, 2, 3, 8, 64] {
            let e = Engine::new(threads);
            let mut items: Vec<usize> = (0..17).collect();
            let out = e.run_mut(&mut items, |k, v| Ok(k * 10 + *v)).unwrap();
            assert_eq!(out, (0..17).map(|k| k * 11).collect::<Vec<_>>(), "t={threads}");
        }
    }

    #[test]
    fn mutations_land_on_the_right_item() {
        let e = Engine::new(4);
        let mut items = vec![0usize; 10];
        e.run_mut(&mut items, |k, v| {
            *v = k + 1;
            Ok(())
        })
        .unwrap();
        assert_eq!(items, (1..=10).collect::<Vec<_>>());
    }

    #[test]
    fn errors_propagate() {
        let e = Engine::new(3);
        let mut items = vec![(); 6];
        let r = e.run_mut(&mut items, |k, _| {
            if k == 4 {
                anyhow::bail!("device {k} failed")
            }
            Ok(k)
        });
        assert!(r.unwrap_err().to_string().contains("device 4"));
    }

    #[test]
    fn empty_and_indexed() {
        let e = Engine::new(8);
        let mut empty: Vec<u32> = Vec::new();
        assert!(e.run_mut(&mut empty, |_, _| Ok(0)).unwrap().is_empty());
        assert!(e.run_indexed(0, |_| Ok(0)).unwrap().is_empty());
        let out = e.run_indexed(9, |k| Ok(k * k)).unwrap();
        assert_eq!(out, (0..9).map(|k| k * k).collect::<Vec<_>>());
    }

    #[test]
    fn zero_resolves_to_cores() {
        let e = Engine::new(0);
        assert!(e.threads() >= 1);
    }
}
