//! Per-scheme round executors: the *execution* half of a FEEL period.
//!
//! `scheme::plan_period` decides what each device should do; these
//! functions do it, fanning the K device steps out over the engine and
//! returning per-device outcomes **in device order** so the trainer can
//! reduce them deterministically (see exec/mod.rs for the contract).
//!
//! Heterogeneous fleets: every executor resolves each device's backend
//! and model family through a [`BackendSet`] instead of sharing one
//! `&dyn Backend`; `params` is the per-family parameter view
//! (`Server::all_params`). The assignment is a pure function of the
//! device id, so nothing about the determinism contract changes.

use anyhow::{bail, Context, Result};

use super::engine::Engine;
use crate::coordinator::fleet_backends::BackendSet;
use crate::coordinator::worker::Worker;
use crate::data::Dataset;
use crate::grad::Aggregator;
use crate::util::rng::Pcg;

/// Cap on aggregation shards per round. Shard boundaries must be a pure
/// function of the fleet size K (never the thread count) to keep the
/// determinism contract, so the shard size is `ceil(K / MAX_AGG_SHARDS)`:
/// per-device shards up to K = 32, then a bounded number of contiguous
/// device ranges that each engine worker folds locally.
pub const MAX_AGG_SHARDS: usize = 32;

/// Devices per aggregation shard for a K-device fleet.
pub fn agg_shard_size(k: usize) -> usize {
    k.div_ceil(MAX_AGG_SHARDS).max(1)
}

/// One device's gradient-scheme contribution.
pub struct GradOutcome {
    /// the gradient as the server will see it (post compression round-trip)
    pub grad: Vec<f32>,
    /// aggregation weight |B_k|
    pub weight: f64,
    /// the device's mean train loss on its batch
    pub loss: f64,
}

/// One device's model-FL (FedAvg) contribution.
pub struct LocalFitOutcome {
    /// locally-trained parameters
    pub params: Vec<f32>,
    /// averaging weight N_k (shard size)
    pub weight: f64,
    /// last local-step loss
    pub loss: f64,
}

/// One device's individual-learning step summary.
pub struct LocalStepOutcome {
    pub weight: f64,
    pub loss: f64,
}

/// One contiguous device range's folded gradient-round contribution.
pub struct GradShard {
    /// batch-weighted partial aggregates, one per model family present in
    /// the shard, in first-device order; devices are added in ascending
    /// device order with f64 accumulation. Each aggregator carries its
    /// family tag ([`Aggregator::for_family`]), so merging a shard into
    /// the wrong family's server accumulator is rejected. Homogeneous
    /// fleets always see exactly one entry (family 0); a fully-masked
    /// shard comes back with no entries and merges as a no-op.
    pub aggs: Vec<(usize, Aggregator)>,
    /// Σ loss_k · |B_k| over the shard, in device order
    pub loss: f64,
    /// Σ |B_k| over the shard
    pub weight: f64,
}

impl GradShard {
    /// The shard's aggregator for model family `f`, if any device of that
    /// family contributed.
    pub fn family_agg(&self, f: usize) -> Option<&Aggregator> {
        self.aggs.iter().find(|(fam, _)| *fam == f).map(|(_, a)| a)
    }
}

/// Geometry guard every executor runs before fanning out: the per-family
/// parameter view must match the backend set and the worker slice must
/// cover the whole fleet. Failing here gives a clear error instead of a
/// slice panic inside an engine worker.
fn check_fleet_geometry(
    backends: &BackendSet<'_>,
    workers: usize,
    params: &[Vec<f32>],
) -> Result<()> {
    backends.check_params(params)?;
    if workers != backends.k() {
        bail!("{workers} workers for a {}-device backend set", backends.k());
    }
    Ok(())
}

/// [`check_fleet_geometry`] plus the per-device batch plan length, for
/// the executors that take one batch per device.
fn check_round_geometry(
    backends: &BackendSet<'_>,
    workers: usize,
    params: &[Vec<f32>],
    batches: usize,
) -> Result<()> {
    check_fleet_geometry(backends, workers, params)?;
    if batches != workers {
        bail!("{batches} planned batches for {workers} devices");
    }
    Ok(())
}

/// Steps 1–3 of a gradient-exchange period: every device samples its
/// planned batch, runs forward/backward on its family's global parameters,
/// and compresses its gradient. Aggregation stays with the caller.
///
/// The trainer's production path is [`gradient_round_sharded`]; this
/// per-device form is the *reference* the sharded fold is tested against
/// (`sharded_round_matches_streaming_reduce`) and the entry point for
/// callers that need the raw per-device gradients. Any change to the
/// sampling/compression/weighting here must be mirrored there.
#[allow(clippy::too_many_arguments)]
pub fn gradient_round(
    engine: &Engine,
    backends: &BackendSet<'_>,
    workers: &mut [Worker],
    params: &[Vec<f32>],
    train: &Dataset,
    batches: &[usize],
    seed: u64,
    period: u64,
) -> Result<Vec<GradOutcome>> {
    check_round_geometry(backends, workers.len(), params, batches.len())?;
    engine.run_mut(workers, |k, w| {
        let backend = backends.for_device(k);
        let global = params[backends.family_of(k)].as_slice();
        let b = batches[k].max(1);
        let mut rng = Pcg::for_device(seed, period, k as u64);
        let (x, y) = w.data.sample_with(train, b, &mut rng);
        let step = backend
            .train_step_ws(global, &x, &y, &mut w.scratch)
            .with_context(|| format!("device {k} train_step"))?;
        let (grad, _bits) = w.compress(step.grads);
        Ok(GradOutcome { grad, weight: b as f64, loss: step.loss as f64 })
    })
}

/// The sharded form of [`gradient_round`]: devices are split into
/// contiguous shards of `agg_shard_size(K)` and each engine worker folds
/// its shard's gradients straight into per-family local [`Aggregator`]s
/// (f64, device order) instead of materializing K dense gradients for a
/// single-thread streaming reduce. The caller combines the returned
/// shards — still in device order — via `Aggregator::merge`.
///
/// Thread-count invariance: shard boundaries come from K alone (see
/// [`agg_shard_size`]) and `Engine::run_chunked` never lets the thread
/// count reshape chunks, so the f64 fold grouping — and the final global
/// gradient — is bitwise identical at any `--threads` value. The family
/// split inside a shard is a pure function of the device ids it covers.
#[allow(clippy::too_many_arguments)]
pub fn gradient_round_sharded(
    engine: &Engine,
    backends: &BackendSet<'_>,
    workers: &mut [Worker],
    params: &[Vec<f32>],
    train: &Dataset,
    batches: &[usize],
    seed: u64,
    period: u64,
) -> Result<Vec<GradShard>> {
    gradient_round_sharded_masked(
        engine, backends, workers, params, train, batches, None, seed, period,
    )
}

/// [`gradient_round_sharded`] with a participation mask: devices whose
/// mask entry is `false` (dropped by the straggler model or past a
/// deadline — see `sched/`) are skipped entirely, contributing neither
/// compute nor weight. Shard boundaries are unchanged, so a shard whose
/// devices are all masked comes back *empty* (zero contributions) and
/// merges as a no-op; a `None` mask is bitwise-identical to the unmasked
/// round. Skipping cannot perturb other devices: each device's batch draw
/// comes from its own counter-derived RNG stream.
#[allow(clippy::too_many_arguments)]
pub fn gradient_round_sharded_masked(
    engine: &Engine,
    backends: &BackendSet<'_>,
    workers: &mut [Worker],
    params: &[Vec<f32>],
    train: &Dataset,
    batches: &[usize],
    mask: Option<&[bool]>,
    seed: u64,
    period: u64,
) -> Result<Vec<GradShard>> {
    check_round_geometry(backends, workers.len(), params, batches.len())?;
    if let Some(m) = mask {
        if m.len() != workers.len() {
            bail!("mask length {} != fleet size {}", m.len(), workers.len());
        }
    }
    let shard = agg_shard_size(workers.len());
    engine.run_chunked(workers, shard, |_, base, devs| {
        let mut aggs: Vec<(usize, Aggregator)> = Vec::new();
        let mut loss = 0f64;
        let mut weight = 0f64;
        for (j, w) in devs.iter_mut().enumerate() {
            let k = base + j;
            if mask.is_some_and(|m| !m[k]) {
                continue;
            }
            let fam = backends.family_of(k);
            let backend = backends.for_device(k);
            let global = params[fam].as_slice();
            let b = batches[k].max(1);
            let mut rng = Pcg::for_device(seed, period, k as u64);
            let (x, y) = w.data.sample_with(train, b, &mut rng);
            let step = backend
                .train_step_ws(global, &x, &y, &mut w.scratch)
                .with_context(|| format!("device {k} train_step"))?;
            let (grad, _bits) = w.compress(step.grads);
            let slot = match aggs.iter().position(|(f, _)| *f == fam) {
                Some(p) => p,
                None => {
                    aggs.push((fam, Aggregator::for_family(global.len(), fam as u32)));
                    aggs.len() - 1
                }
            };
            aggs[slot].1.add(&grad, b as f64)?;
            loss += step.loss as f64 * b as f64;
            weight += b as f64;
        }
        Ok(GradShard { aggs, loss, weight })
    })
}

/// Gradient steps for an arbitrary *subset* of the fleet — async rounds
/// (`sched/`) dispatch only the devices that are idle. `jobs` lists
/// `(device id, batchsize)` in strictly ascending device order; outcomes
/// come back in the same order. The RNG stream still keys on the device's
/// global id and the round's period, so a device samples the same batch
/// whether it runs in a full or a subset round of the same period.
pub fn gradient_round_subset(
    engine: &Engine,
    backends: &BackendSet<'_>,
    workers: &mut [Worker],
    params: &[Vec<f32>],
    train: &Dataset,
    jobs: &[(usize, usize)],
    seed: u64,
    period: u64,
) -> Result<Vec<GradOutcome>> {
    check_fleet_geometry(backends, workers.len(), params)?;
    for w in jobs.windows(2) {
        if w[1].0 <= w[0].0 {
            bail!("subset jobs must be in strictly ascending device order");
        }
    }
    if let Some(&(last, _)) = jobs.last() {
        if last >= workers.len() {
            bail!("job device {last} out of range (K = {})", workers.len());
        }
    }
    let mut subset: Vec<(usize, usize, &mut Worker)> = Vec::with_capacity(jobs.len());
    let mut ji = 0usize;
    for (k, w) in workers.iter_mut().enumerate() {
        if ji < jobs.len() && jobs[ji].0 == k {
            subset.push((k, jobs[ji].1, w));
            ji += 1;
        }
    }
    engine.run_mut(&mut subset, |_, (k, b, w)| {
        let k = *k;
        let backend = backends.for_device(k);
        let global = params[backends.family_of(k)].as_slice();
        let b = (*b).max(1);
        let mut rng = Pcg::for_device(seed, period, k as u64);
        let (x, y) = w.data.sample_with(train, b, &mut rng);
        let step = backend
            .train_step_ws(global, &x, &y, &mut w.scratch)
            .with_context(|| format!("device {k} train_step"))?;
        let (grad, _bits) = w.compress(step.grads);
        Ok(GradOutcome { grad, weight: b as f64, loss: step.loss as f64 })
    })
}

/// Model-based FL round: one local epoch per device from the global
/// parameters, returning the locally-trained models for FedAvg. The
/// trainer restricts this scheme to homogeneous fleets (parameter
/// averaging across families is undefined), but the executor still
/// resolves per device for uniformity.
#[allow(clippy::too_many_arguments)]
pub fn model_fl_round(
    engine: &Engine,
    backends: &BackendSet<'_>,
    workers: &mut [Worker],
    params: &[Vec<f32>],
    train: &Dataset,
    local_batch: usize,
    lr: f32,
    seed: u64,
    period: u64,
) -> Result<Vec<LocalFitOutcome>> {
    check_fleet_geometry(backends, workers.len(), params)?;
    engine.run_mut(workers, |k, w| {
        let backend = backends.for_device(k);
        // the working copy of the globals comes from the worker's pool,
        // and every superseded parameter buffer goes back to it — the
        // local-epoch loop stops churning p-sized allocations
        let mut local = w.scratch.copy_of(params[backends.family_of(k)].as_slice());
        let n = w.shard_len();
        let steps = n.div_ceil(local_batch).max(1);
        let mut rng = Pcg::for_device(seed, period, k as u64);
        let mut last_loss = 0f32;
        for _ in 0..steps {
            let (x, y) = w.data.sample_with(train, local_batch.min(n), &mut rng);
            let s = backend
                .train_step_ws(&local, &x, &y, &mut w.scratch)
                .with_context(|| format!("device {k} local step"))?;
            last_loss = s.loss;
            let next = backend.apply_update(&local, &s.grads, lr)?;
            w.scratch.recycle(std::mem::replace(&mut local, next));
        }
        Ok(LocalFitOutcome { params: local, weight: n as f64, loss: last_loss as f64 })
    })
}

/// Individual-learning round: one local mini-batch step per device on its
/// own parameters (initialized from its family's global on first touch).
#[allow(clippy::too_many_arguments)]
pub fn individual_round(
    engine: &Engine,
    backends: &BackendSet<'_>,
    workers: &mut [Worker],
    params: &[Vec<f32>],
    train: &Dataset,
    batches: &[usize],
    lr: f32,
    seed: u64,
    period: u64,
) -> Result<Vec<LocalStepOutcome>> {
    check_round_geometry(backends, workers.len(), params, batches.len())?;
    engine.run_mut(workers, |k, w| {
        let backend = backends.for_device(k);
        // first touch draws the family-global copy from the worker's pool;
        // thereafter the kept local model is updated and its predecessor
        // buffer recycled instead of dropped
        let local = match w.local_params.take() {
            Some(v) => v,
            None => w.scratch.copy_of(params[backends.family_of(k)].as_slice()),
        };
        let b = batches[k].max(1);
        let mut rng = Pcg::for_device(seed, period, k as u64);
        let (x, y) = w.data.sample_with(train, b, &mut rng);
        let s = backend
            .train_step_ws(&local, &x, &y, &mut w.scratch)
            .with_context(|| format!("device {k} individual step"))?;
        let next = backend.apply_update(&local, &s.grads, lr)?;
        w.scratch.recycle(local);
        w.local_params = Some(next);
        Ok(LocalStepOutcome { weight: b as f64, loss: s.loss as f64 })
    })
}

/// Per-device evaluation (individual learning): each device's local model
/// (falling back to its family's global) against the held-out set, in
/// device order. Takes the workers mutably so evaluation draws its
/// scratch from each worker's `Workspace` instead of allocating.
pub fn eval_round(
    engine: &Engine,
    backends: &BackendSet<'_>,
    workers: &mut [Worker],
    params: &[Vec<f32>],
    x: &[f32],
    y: &[i32],
) -> Result<Vec<(f64, f64)>> {
    check_fleet_geometry(backends, workers.len(), params)?;
    engine.run_mut(workers, |k, w| {
        let backend = backends.for_device(k);
        let global = params[backends.family_of(k)].as_slice();
        let local = match &w.local_params {
            Some(p) => p.as_slice(),
            None => global,
        };
        backend.evaluate_ws(local, x, y, &mut w.scratch)
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::Sbc;
    use crate::coordinator::backend::{Backend, HostBackend};
    use crate::data::synthetic::{generate, SynthConfig};
    use crate::data::DeviceData;

    fn world(k: usize, p_sbc: bool) -> (Dataset, Vec<Worker>, HostBackend) {
        let cfg = SynthConfig { dim: 12, ..Default::default() };
        let train = generate(&cfg, 40 * k, 1);
        let be = HostBackend::for_model("mini_dense", 12, 10, 2).unwrap();
        let p = be.params();
        let workers: Vec<Worker> = (0..k)
            .map(|id| {
                let idx: Vec<usize> = (id * 40..(id + 1) * 40).collect();
                let sbc = if p_sbc { Some(Sbc::new(0.01, p)) } else { None };
                Worker::new(id, DeviceData::new(idx, Pcg::seeded(id as u64)), sbc)
            })
            .collect();
        (train, workers, be)
    }

    #[test]
    fn gradient_round_thread_invariant() {
        let (train, mut w1, be) = world(5, true);
        let (_, mut w4, _) = world(5, true);
        let set = BackendSet::homogeneous(5, "mini_dense", &be);
        let fams = vec![be.init_params().unwrap()];
        let batches = vec![8usize; 5];
        let a = gradient_round(&Engine::new(1), &set, &mut w1, &fams, &train, &batches, 9, 3)
            .unwrap();
        let b = gradient_round(&Engine::new(4), &set, &mut w4, &fams, &train, &batches, 9, 3)
            .unwrap();
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.grad, y.grad);
            assert_eq!(x.loss.to_bits(), y.loss.to_bits());
            assert_eq!(x.weight, y.weight);
        }
    }

    #[test]
    fn sharded_round_matches_streaming_reduce() {
        // K = 5 -> per-device shards; fold must equal the per-device round
        // reduced in device order with the same f64 aggregator.
        let (train, mut w_dev, be) = world(5, true);
        let (_, mut w_shard, _) = world(5, true);
        let set = BackendSet::homogeneous(5, "mini_dense", &be);
        let fams = vec![be.init_params().unwrap()];
        let batches = vec![6usize; 5];
        let outcomes =
            gradient_round(&Engine::new(2), &set, &mut w_dev, &fams, &train, &batches, 7, 2)
                .unwrap();
        let mut stream = Aggregator::new(fams[0].len());
        for o in &outcomes {
            stream.add(&o.grad, o.weight).unwrap();
        }
        let shards = gradient_round_sharded(
            &Engine::new(2),
            &set,
            &mut w_shard,
            &fams,
            &train,
            &batches,
            7,
            2,
        )
        .unwrap();
        assert_eq!(shards.len(), 5); // per-device shards at K <= 32
        let merged = Aggregator::reduce_shards(
            shards.into_iter().flat_map(|s| s.aggs.into_iter().map(|(_, a)| a)).collect(),
        )
        .unwrap();
        assert_eq!(merged.finish().unwrap(), stream.finish().unwrap());
    }

    #[test]
    fn shard_size_fixed_by_fleet_size() {
        assert_eq!(agg_shard_size(1), 1);
        assert_eq!(agg_shard_size(32), 1);
        assert_eq!(agg_shard_size(33), 2);
        assert_eq!(agg_shard_size(64), 2);
        assert_eq!(agg_shard_size(1000), 32);
        // shard count never exceeds the cap
        for k in [1usize, 7, 32, 33, 64, 999, 4096] {
            assert!(k.div_ceil(agg_shard_size(k)) <= MAX_AGG_SHARDS, "k={k}");
        }
    }

    #[test]
    fn masked_round_skips_devices_and_none_mask_matches() {
        let (train, mut w_a, be) = world(5, true);
        let (_, mut w_b, _) = world(5, true);
        let (_, mut w_c, _) = world(5, true);
        let set = BackendSet::homogeneous(5, "mini_dense", &be);
        let fams = vec![be.init_params().unwrap()];
        let batches = vec![6usize; 5];
        let full = gradient_round_sharded(
            &Engine::new(2), &set, &mut w_a, &fams, &train, &batches, 7, 2,
        )
        .unwrap();
        let none_mask = gradient_round_sharded_masked(
            &Engine::new(2), &set, &mut w_b, &fams, &train, &batches, None, 7, 2,
        )
        .unwrap();
        for (a, b) in full.iter().zip(&none_mask) {
            assert_eq!(a.loss.to_bits(), b.loss.to_bits());
            assert_eq!(a.weight, b.weight);
            assert_eq!(
                a.family_agg(0).unwrap().average().unwrap(),
                b.family_agg(0).unwrap().average().unwrap()
            );
        }
        // drop devices 1 and 3: their shards (K=5 -> per-device) come back
        // empty and the others are untouched
        let mask = vec![true, false, true, false, true];
        let masked = gradient_round_sharded_masked(
            &Engine::new(2), &set, &mut w_c, &fams, &train, &batches, Some(&mask), 7, 2,
        )
        .unwrap();
        assert_eq!(masked.len(), 5);
        for (k, (m, f)) in masked.iter().zip(&full).enumerate() {
            if mask[k] {
                assert_eq!(m.family_agg(0).unwrap().contributions(), 1, "device {k}");
                assert_eq!(m.loss.to_bits(), f.loss.to_bits(), "device {k}");
            } else {
                assert!(m.aggs.is_empty(), "device {k}: shard must be empty");
                assert_eq!(m.weight, 0.0);
                assert_eq!(m.loss, 0.0);
            }
        }
        // mask length mismatch is a clean error
        let (_, mut w_d, _) = world(5, true);
        let short = [true; 3];
        assert!(gradient_round_sharded_masked(
            &Engine::new(1), &set, &mut w_d, &fams, &train, &batches, Some(&short[..]), 7, 2,
        )
        .is_err());
    }

    #[test]
    fn subset_round_matches_full_round_per_device() {
        // a device's gradient in a subset round must equal its gradient in
        // the full per-device round of the same (seed, period)
        let (train, mut w_full, be) = world(5, true);
        let (_, mut w_sub, _) = world(5, true);
        let set = BackendSet::homogeneous(5, "mini_dense", &be);
        let fams = vec![be.init_params().unwrap()];
        let batches = vec![6usize; 5];
        let full = gradient_round(
            &Engine::new(2), &set, &mut w_full, &fams, &train, &batches, 9, 4,
        )
        .unwrap();
        let jobs = vec![(1usize, 6usize), (3, 6), (4, 6)];
        let sub = gradient_round_subset(
            &Engine::new(2), &set, &mut w_sub, &fams, &train, &jobs, 9, 4,
        )
        .unwrap();
        assert_eq!(sub.len(), 3);
        for (o, &(dev, _)) in sub.iter().zip(&jobs) {
            assert_eq!(o.grad, full[dev].grad, "device {dev}");
            assert_eq!(o.loss.to_bits(), full[dev].loss.to_bits(), "device {dev}");
        }
        // unsorted or out-of-range jobs are clean errors
        let (_, mut w_bad, _) = world(5, true);
        assert!(gradient_round_subset(
            &Engine::new(1), &set, &mut w_bad, &fams, &train, &[(3, 4), (1, 4)], 9, 4,
        )
        .is_err());
        assert!(gradient_round_subset(
            &Engine::new(1), &set, &mut w_bad, &fams, &train, &[(5, 4)], 9, 4,
        )
        .is_err());
        // empty subset is a no-op
        let out = gradient_round_subset(
            &Engine::new(1), &set, &mut w_bad, &fams, &train, &[], 9, 4,
        )
        .unwrap();
        assert!(out.is_empty());
    }

    #[test]
    fn zero_batches_clamp_to_one_never_empty() {
        // backends reject empty batches outright (coordinator/backend.rs),
        // so the rounds' `.max(1)` clamp is what guarantees a plan with a
        // zero entry still dispatches a real step instead of erroring
        let (train, mut workers, be) = world(3, false);
        let set = BackendSet::homogeneous(3, "mini_dense", &be);
        let fams = vec![be.init_params().unwrap()];
        let batches = vec![0usize, 4, 0];
        let out = gradient_round(
            &Engine::new(2), &set, &mut workers, &fams, &train, &batches, 5, 1,
        )
        .unwrap();
        assert_eq!(out.len(), 3);
        for (k, o) in out.iter().enumerate() {
            assert!(o.weight >= 1.0, "device {k}: weight {}", o.weight);
            assert!(o.loss.is_finite(), "device {k}");
        }
        let (_, mut workers, _) = world(3, false);
        let shards = gradient_round_sharded(
            &Engine::new(2), &set, &mut workers, &fams, &train, &batches, 5, 1,
        )
        .unwrap();
        for s in &shards {
            assert!(s.weight >= 1.0);
            assert!(s.loss.is_finite());
        }
    }

    #[test]
    fn individual_round_keeps_local_params() {
        let (train, mut workers, be) = world(3, false);
        let set = BackendSet::homogeneous(3, "mini_dense", &be);
        let fams = vec![be.init_params().unwrap()];
        let batches = vec![4usize; 3];
        individual_round(
            &Engine::new(2),
            &set,
            &mut workers,
            &fams,
            &train,
            &batches,
            0.1,
            1,
            0,
        )
        .unwrap();
        for w in &workers {
            let local = w.local_params.as_ref().unwrap();
            assert_eq!(local.len(), fams[0].len());
            assert_ne!(local, &fams[0]);
        }
    }

    /// Mixed two-family fleet: shards carry per-family aggregators tagged
    /// with their family id, each family's fold matches a homogeneous
    /// reference round over just its devices, and merging a shard into
    /// the wrong family's accumulator is rejected.
    #[test]
    fn mixed_fleet_sharded_round_splits_families() {
        let k = 6;
        let cfg = SynthConfig { dim: 12, ..Default::default() };
        let train = generate(&cfg, 40 * k, 1);
        let dense = HostBackend::for_model("mini_dense", 12, 10, 2).unwrap();
        let res = HostBackend::for_model("mini_res", 12, 10, 2).unwrap();
        // devices 0,2,4 -> dense (family 0); 1,3,5 -> res (family 1)
        let assign: Vec<usize> = (0..k).map(|id| id % 2).collect();
        let set = BackendSet::new(
            vec![("mini_dense".into(), &dense as &dyn Backend), ("mini_res".into(), &res)],
            assign.clone(),
        )
        .unwrap();
        let fams = set.init_all().unwrap();
        let mk_workers = || -> Vec<Worker> {
            (0..k)
                .map(|id| {
                    let idx: Vec<usize> = (id * 40..(id + 1) * 40).collect();
                    Worker::new(id, DeviceData::new(idx, Pcg::seeded(id as u64)), None)
                })
                .collect()
        };
        let batches = vec![6usize; k];
        let mut workers = mk_workers();
        let shards = gradient_round_sharded(
            &Engine::new(2), &set, &mut workers, &fams, &train, &batches, 7, 2,
        )
        .unwrap();
        // per-device shards at K=6: each carries exactly its device's family
        assert_eq!(shards.len(), k);
        for (dev, s) in shards.iter().enumerate() {
            assert_eq!(s.aggs.len(), 1, "device {dev}");
            assert_eq!(s.aggs[0].0, assign[dev], "device {dev}");
            assert_eq!(s.aggs[0].1.family(), assign[dev] as u32);
        }
        // per-family server accumulators: merging works family-by-family...
        let mut acc0 = Aggregator::for_family(set.family_params(0), 0);
        let mut acc1 = Aggregator::for_family(set.family_params(1), 1);
        for s in &shards {
            for (f, a) in &s.aggs {
                match *f {
                    0 => acc0.merge(a).unwrap(),
                    _ => acc1.merge(a).unwrap(),
                }
            }
        }
        assert_eq!(acc0.contributions(), 3);
        assert_eq!(acc1.contributions(), 3);
        // ...and cross-family merging is a clear error
        let err = acc0.merge(&shards[1].aggs[0].1).unwrap_err().to_string();
        assert!(err.contains("cross-family"), "{err}");
        // each family's reduce matches the per-device reference gradients
        let mut workers = mk_workers();
        let reference = gradient_round(
            &Engine::new(1), &set, &mut workers, &fams, &train, &batches, 7, 2,
        )
        .unwrap();
        for (f, acc) in [(0usize, acc0), (1, acc1)] {
            let mut stream = Aggregator::for_family(set.family_params(f), f as u32);
            for (dev, o) in reference.iter().enumerate() {
                if assign[dev] == f {
                    stream.add(&o.grad, o.weight).unwrap();
                }
            }
            assert_eq!(acc.finish().unwrap(), stream.finish().unwrap(), "family {f}");
        }
        // geometry violations are caught before fan-out
        let mut workers = mk_workers();
        assert!(gradient_round_sharded(
            &Engine::new(1), &set, &mut workers, &fams[..1], &train, &batches, 7, 2,
        )
        .is_err());
    }

    #[test]
    fn eval_round_uses_family_globals_and_worker_scratch() {
        let (_train, mut workers, be) = world(3, false);
        let set = BackendSet::homogeneous(3, "mini_dense", &be);
        let fams = vec![be.init_params().unwrap()];
        let cfg = SynthConfig { dim: 12, ..Default::default() };
        let test = generate(&cfg, 30, 9);
        let out = eval_round(
            &Engine::new(2), &set, &mut workers, &fams, &test.x, &test.y,
        )
        .unwrap();
        assert_eq!(out.len(), 3);
        // no local params: every device evaluates the family global
        let direct = be.evaluate(&fams[0], &test.x, &test.y).unwrap();
        for (l, a) in &out {
            assert_eq!(l.to_bits(), direct.0.to_bits());
            assert_eq!(a.to_bits(), direct.1.to_bits());
        }
        // the eval scratch landed in the worker workspaces
        assert!(workers.iter().all(|w| w.scratch.pooled_buffers() > 0));
    }
}
