//! Per-scheme round executors: the *execution* half of a FEEL period.
//!
//! `scheme::plan_period` decides what each device should do; these
//! functions do it, fanning the K device steps out over the engine and
//! returning per-device outcomes **in device order** so the trainer can
//! reduce them deterministically (see exec/mod.rs for the contract).

use anyhow::{bail, Context, Result};

use super::engine::Engine;
use crate::coordinator::backend::Backend;
use crate::coordinator::worker::Worker;
use crate::data::Dataset;
use crate::grad::Aggregator;
use crate::util::rng::Pcg;

/// Cap on aggregation shards per round. Shard boundaries must be a pure
/// function of the fleet size K (never the thread count) to keep the
/// determinism contract, so the shard size is `ceil(K / MAX_AGG_SHARDS)`:
/// per-device shards up to K = 32, then a bounded number of contiguous
/// device ranges that each engine worker folds locally.
pub const MAX_AGG_SHARDS: usize = 32;

/// Devices per aggregation shard for a K-device fleet.
pub fn agg_shard_size(k: usize) -> usize {
    k.div_ceil(MAX_AGG_SHARDS).max(1)
}

/// One device's gradient-scheme contribution.
pub struct GradOutcome {
    /// the gradient as the server will see it (post compression round-trip)
    pub grad: Vec<f32>,
    /// aggregation weight |B_k|
    pub weight: f64,
    /// the device's mean train loss on its batch
    pub loss: f64,
}

/// One device's model-FL (FedAvg) contribution.
pub struct LocalFitOutcome {
    /// locally-trained parameters
    pub params: Vec<f32>,
    /// averaging weight N_k (shard size)
    pub weight: f64,
    /// last local-step loss
    pub loss: f64,
}

/// One device's individual-learning step summary.
pub struct LocalStepOutcome {
    pub weight: f64,
    pub loss: f64,
}

/// One contiguous device range's folded gradient-round contribution.
pub struct GradShard {
    /// batch-weighted partial aggregate over the shard's devices (added in
    /// ascending device order, f64 accumulation)
    pub agg: Aggregator,
    /// Σ loss_k · |B_k| over the shard, in device order
    pub loss: f64,
    /// Σ |B_k| over the shard
    pub weight: f64,
}

/// Steps 1–3 of a gradient-exchange period: every device samples its
/// planned batch, runs forward/backward on the global parameters, and
/// compresses its gradient. Aggregation stays with the caller.
///
/// The trainer's production path is [`gradient_round_sharded`]; this
/// per-device form is the *reference* the sharded fold is tested against
/// (`sharded_round_matches_streaming_reduce`) and the entry point for
/// callers that need the raw per-device gradients. Any change to the
/// sampling/compression/weighting here must be mirrored there.
#[allow(clippy::too_many_arguments)]
pub fn gradient_round(
    engine: &Engine,
    backend: &dyn Backend,
    workers: &mut [Worker],
    params: &[f32],
    train: &Dataset,
    batches: &[usize],
    seed: u64,
    period: u64,
) -> Result<Vec<GradOutcome>> {
    engine.run_mut(workers, |k, w| {
        let b = batches[k].max(1);
        let mut rng = Pcg::for_device(seed, period, k as u64);
        let (x, y) = w.data.sample_with(train, b, &mut rng);
        let step = backend
            .train_step_ws(params, &x, &y, &mut w.scratch)
            .with_context(|| format!("device {k} train_step"))?;
        let (grad, _bits) = w.compress(step.grads);
        Ok(GradOutcome { grad, weight: b as f64, loss: step.loss as f64 })
    })
}

/// The sharded form of [`gradient_round`]: devices are split into
/// contiguous shards of `agg_shard_size(K)` and each engine worker folds
/// its shard's gradients straight into a local [`Aggregator`] (f64, device
/// order) instead of materializing K dense gradients for a single-thread
/// streaming reduce. The caller combines the returned shards — still in
/// device order — via `Aggregator::merge`/`reduce_shards`.
///
/// Thread-count invariance: shard boundaries come from K alone (see
/// [`agg_shard_size`]) and `Engine::run_chunked` never lets the thread
/// count reshape chunks, so the f64 fold grouping — and the final global
/// gradient — is bitwise identical at any `--threads` value.
#[allow(clippy::too_many_arguments)]
pub fn gradient_round_sharded(
    engine: &Engine,
    backend: &dyn Backend,
    workers: &mut [Worker],
    params: &[f32],
    train: &Dataset,
    batches: &[usize],
    seed: u64,
    period: u64,
) -> Result<Vec<GradShard>> {
    gradient_round_sharded_masked(
        engine, backend, workers, params, train, batches, None, seed, period,
    )
}

/// [`gradient_round_sharded`] with a participation mask: devices whose
/// mask entry is `false` (dropped by the straggler model or past a
/// deadline — see `sched/`) are skipped entirely, contributing neither
/// compute nor weight. Shard boundaries are unchanged, so a shard whose
/// devices are all masked comes back *empty* (zero contributions) and
/// merges as a no-op; a `None` mask is bitwise-identical to the unmasked
/// round. Skipping cannot perturb other devices: each device's batch draw
/// comes from its own counter-derived RNG stream.
#[allow(clippy::too_many_arguments)]
pub fn gradient_round_sharded_masked(
    engine: &Engine,
    backend: &dyn Backend,
    workers: &mut [Worker],
    params: &[f32],
    train: &Dataset,
    batches: &[usize],
    mask: Option<&[bool]>,
    seed: u64,
    period: u64,
) -> Result<Vec<GradShard>> {
    if let Some(m) = mask {
        if m.len() != workers.len() {
            bail!("mask length {} != fleet size {}", m.len(), workers.len());
        }
    }
    let p = params.len();
    let shard = agg_shard_size(workers.len());
    engine.run_chunked(workers, shard, |_, base, devs| {
        let mut agg = Aggregator::new(p);
        let mut loss = 0f64;
        let mut weight = 0f64;
        for (j, w) in devs.iter_mut().enumerate() {
            let k = base + j;
            if mask.is_some_and(|m| !m[k]) {
                continue;
            }
            let b = batches[k].max(1);
            let mut rng = Pcg::for_device(seed, period, k as u64);
            let (x, y) = w.data.sample_with(train, b, &mut rng);
            let step = backend
                .train_step_ws(params, &x, &y, &mut w.scratch)
                .with_context(|| format!("device {k} train_step"))?;
            let (grad, _bits) = w.compress(step.grads);
            agg.add(&grad, b as f64)?;
            loss += step.loss as f64 * b as f64;
            weight += b as f64;
        }
        Ok(GradShard { agg, loss, weight })
    })
}

/// Gradient steps for an arbitrary *subset* of the fleet — async rounds
/// (`sched/`) dispatch only the devices that are idle. `jobs` lists
/// `(device id, batchsize)` in strictly ascending device order; outcomes
/// come back in the same order. The RNG stream still keys on the device's
/// global id and the round's period, so a device samples the same batch
/// whether it runs in a full or a subset round of the same period.
pub fn gradient_round_subset(
    engine: &Engine,
    backend: &dyn Backend,
    workers: &mut [Worker],
    params: &[f32],
    train: &Dataset,
    jobs: &[(usize, usize)],
    seed: u64,
    period: u64,
) -> Result<Vec<GradOutcome>> {
    for w in jobs.windows(2) {
        if w[1].0 <= w[0].0 {
            bail!("subset jobs must be in strictly ascending device order");
        }
    }
    if let Some(&(last, _)) = jobs.last() {
        if last >= workers.len() {
            bail!("job device {last} out of range (K = {})", workers.len());
        }
    }
    let mut subset: Vec<(usize, usize, &mut Worker)> = Vec::with_capacity(jobs.len());
    let mut ji = 0usize;
    for (k, w) in workers.iter_mut().enumerate() {
        if ji < jobs.len() && jobs[ji].0 == k {
            subset.push((k, jobs[ji].1, w));
            ji += 1;
        }
    }
    engine.run_mut(&mut subset, |_, (k, b, w)| {
        let k = *k;
        let b = (*b).max(1);
        let mut rng = Pcg::for_device(seed, period, k as u64);
        let (x, y) = w.data.sample_with(train, b, &mut rng);
        let step = backend
            .train_step_ws(params, &x, &y, &mut w.scratch)
            .with_context(|| format!("device {k} train_step"))?;
        let (grad, _bits) = w.compress(step.grads);
        Ok(GradOutcome { grad, weight: b as f64, loss: step.loss as f64 })
    })
}

/// Model-based FL round: one local epoch per device from the global
/// parameters, returning the locally-trained models for FedAvg.
#[allow(clippy::too_many_arguments)]
pub fn model_fl_round(
    engine: &Engine,
    backend: &dyn Backend,
    workers: &mut [Worker],
    global: &[f32],
    train: &Dataset,
    local_batch: usize,
    lr: f32,
    seed: u64,
    period: u64,
) -> Result<Vec<LocalFitOutcome>> {
    engine.run_mut(workers, |k, w| {
        let mut params = global.to_vec();
        let n = w.shard_len();
        let steps = n.div_ceil(local_batch).max(1);
        let mut rng = Pcg::for_device(seed, period, k as u64);
        let mut last_loss = 0f32;
        for _ in 0..steps {
            let (x, y) = w.data.sample_with(train, local_batch.min(n), &mut rng);
            let s = backend
                .train_step_ws(&params, &x, &y, &mut w.scratch)
                .with_context(|| format!("device {k} local step"))?;
            last_loss = s.loss;
            params = backend.apply_update(&params, &s.grads, lr)?;
        }
        Ok(LocalFitOutcome { params, weight: n as f64, loss: last_loss as f64 })
    })
}

/// Individual-learning round: one local mini-batch step per device on its
/// own parameters (initialized from `global` on first touch).
#[allow(clippy::too_many_arguments)]
pub fn individual_round(
    engine: &Engine,
    backend: &dyn Backend,
    workers: &mut [Worker],
    global: &[f32],
    train: &Dataset,
    batches: &[usize],
    lr: f32,
    seed: u64,
    period: u64,
) -> Result<Vec<LocalStepOutcome>> {
    engine.run_mut(workers, |k, w| {
        let mut params = w.local_params.take().unwrap_or_else(|| global.to_vec());
        let b = batches[k].max(1);
        let mut rng = Pcg::for_device(seed, period, k as u64);
        let (x, y) = w.data.sample_with(train, b, &mut rng);
        let s = backend
            .train_step_ws(&params, &x, &y, &mut w.scratch)
            .with_context(|| format!("device {k} individual step"))?;
        params = backend.apply_update(&params, &s.grads, lr)?;
        w.local_params = Some(params);
        Ok(LocalStepOutcome { weight: b as f64, loss: s.loss as f64 })
    })
}

/// Per-device evaluation (individual learning): each device's local model
/// (falling back to `global`) against the held-out set, in device order.
pub fn eval_round(
    engine: &Engine,
    backend: &dyn Backend,
    workers: &[Worker],
    global: &[f32],
    x: &[f32],
    y: &[i32],
) -> Result<Vec<(f64, f64)>> {
    engine.run_indexed(workers.len(), |k| {
        let params = workers[k].local_params.as_deref().unwrap_or(global);
        backend.evaluate(params, x, y)
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::Sbc;
    use crate::coordinator::backend::HostBackend;
    use crate::data::synthetic::{generate, SynthConfig};
    use crate::data::DeviceData;

    fn world(k: usize, p_sbc: bool) -> (Dataset, Vec<Worker>, HostBackend) {
        let cfg = SynthConfig { dim: 12, ..Default::default() };
        let train = generate(&cfg, 40 * k, 1);
        let be = HostBackend::for_model("mini_dense", 12, 10, 2).unwrap();
        let p = be.params();
        let workers: Vec<Worker> = (0..k)
            .map(|id| {
                let idx: Vec<usize> = (id * 40..(id + 1) * 40).collect();
                let sbc = if p_sbc { Some(Sbc::new(0.01, p)) } else { None };
                Worker::new(id, DeviceData::new(idx, Pcg::seeded(id as u64)), sbc)
            })
            .collect();
        (train, workers, be)
    }

    #[test]
    fn gradient_round_thread_invariant() {
        let (train, mut w1, be) = world(5, true);
        let (_, mut w4, _) = world(5, true);
        let params = be.init_params().unwrap();
        let batches = vec![8usize; 5];
        let a = gradient_round(&Engine::new(1), &be, &mut w1, &params, &train, &batches, 9, 3)
            .unwrap();
        let b = gradient_round(&Engine::new(4), &be, &mut w4, &params, &train, &batches, 9, 3)
            .unwrap();
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.grad, y.grad);
            assert_eq!(x.loss.to_bits(), y.loss.to_bits());
            assert_eq!(x.weight, y.weight);
        }
    }

    #[test]
    fn sharded_round_matches_streaming_reduce() {
        // K = 5 -> per-device shards; fold must equal the per-device round
        // reduced in device order with the same f64 aggregator.
        let (train, mut w_dev, be) = world(5, true);
        let (_, mut w_shard, _) = world(5, true);
        let params = be.init_params().unwrap();
        let batches = vec![6usize; 5];
        let outcomes =
            gradient_round(&Engine::new(2), &be, &mut w_dev, &params, &train, &batches, 7, 2)
                .unwrap();
        let mut stream = Aggregator::new(params.len());
        for o in &outcomes {
            stream.add(&o.grad, o.weight).unwrap();
        }
        let shards = gradient_round_sharded(
            &Engine::new(2),
            &be,
            &mut w_shard,
            &params,
            &train,
            &batches,
            7,
            2,
        )
        .unwrap();
        assert_eq!(shards.len(), 5); // per-device shards at K <= 32
        let merged =
            Aggregator::reduce_shards(shards.into_iter().map(|s| s.agg).collect()).unwrap();
        assert_eq!(merged.finish().unwrap(), stream.finish().unwrap());
    }

    #[test]
    fn shard_size_fixed_by_fleet_size() {
        assert_eq!(agg_shard_size(1), 1);
        assert_eq!(agg_shard_size(32), 1);
        assert_eq!(agg_shard_size(33), 2);
        assert_eq!(agg_shard_size(64), 2);
        assert_eq!(agg_shard_size(1000), 32);
        // shard count never exceeds the cap
        for k in [1usize, 7, 32, 33, 64, 999, 4096] {
            assert!(k.div_ceil(agg_shard_size(k)) <= MAX_AGG_SHARDS, "k={k}");
        }
    }

    #[test]
    fn masked_round_skips_devices_and_none_mask_matches() {
        let (train, mut w_a, be) = world(5, true);
        let (_, mut w_b, _) = world(5, true);
        let (_, mut w_c, _) = world(5, true);
        let params = be.init_params().unwrap();
        let batches = vec![6usize; 5];
        let full = gradient_round_sharded(
            &Engine::new(2), &be, &mut w_a, &params, &train, &batches, 7, 2,
        )
        .unwrap();
        let none_mask = gradient_round_sharded_masked(
            &Engine::new(2), &be, &mut w_b, &params, &train, &batches, None, 7, 2,
        )
        .unwrap();
        for (a, b) in full.iter().zip(&none_mask) {
            assert_eq!(a.loss.to_bits(), b.loss.to_bits());
            assert_eq!(a.weight, b.weight);
            assert_eq!(a.agg.average().unwrap(), b.agg.average().unwrap());
        }
        // drop devices 1 and 3: their shards (K=5 -> per-device) come back
        // empty and the others are untouched
        let mask = vec![true, false, true, false, true];
        let masked = gradient_round_sharded_masked(
            &Engine::new(2), &be, &mut w_c, &params, &train, &batches, Some(&mask), 7, 2,
        )
        .unwrap();
        assert_eq!(masked.len(), 5);
        for (k, (m, f)) in masked.iter().zip(&full).enumerate() {
            if mask[k] {
                assert_eq!(m.agg.contributions(), 1, "device {k}");
                assert_eq!(m.loss.to_bits(), f.loss.to_bits(), "device {k}");
            } else {
                assert_eq!(m.agg.contributions(), 0, "device {k}: shard must be empty");
                assert_eq!(m.weight, 0.0);
                assert_eq!(m.loss, 0.0);
            }
        }
        // mask length mismatch is a clean error
        let (_, mut w_d, _) = world(5, true);
        let short = [true; 3];
        assert!(gradient_round_sharded_masked(
            &Engine::new(1), &be, &mut w_d, &params, &train, &batches, Some(&short[..]), 7, 2,
        )
        .is_err());
    }

    #[test]
    fn subset_round_matches_full_round_per_device() {
        // a device's gradient in a subset round must equal its gradient in
        // the full per-device round of the same (seed, period)
        let (train, mut w_full, be) = world(5, true);
        let (_, mut w_sub, _) = world(5, true);
        let params = be.init_params().unwrap();
        let batches = vec![6usize; 5];
        let full = gradient_round(
            &Engine::new(2), &be, &mut w_full, &params, &train, &batches, 9, 4,
        )
        .unwrap();
        let jobs = vec![(1usize, 6usize), (3, 6), (4, 6)];
        let sub = gradient_round_subset(
            &Engine::new(2), &be, &mut w_sub, &params, &train, &jobs, 9, 4,
        )
        .unwrap();
        assert_eq!(sub.len(), 3);
        for (o, &(dev, _)) in sub.iter().zip(&jobs) {
            assert_eq!(o.grad, full[dev].grad, "device {dev}");
            assert_eq!(o.loss.to_bits(), full[dev].loss.to_bits(), "device {dev}");
        }
        // unsorted or out-of-range jobs are clean errors
        let (_, mut w_bad, _) = world(5, true);
        assert!(gradient_round_subset(
            &Engine::new(1), &be, &mut w_bad, &params, &train, &[(3, 4), (1, 4)], 9, 4,
        )
        .is_err());
        assert!(gradient_round_subset(
            &Engine::new(1), &be, &mut w_bad, &params, &train, &[(5, 4)], 9, 4,
        )
        .is_err());
        // empty subset is a no-op
        let out = gradient_round_subset(
            &Engine::new(1), &be, &mut w_bad, &params, &train, &[], 9, 4,
        )
        .unwrap();
        assert!(out.is_empty());
    }

    #[test]
    fn individual_round_keeps_local_params() {
        let (train, mut workers, be) = world(3, false);
        let params = be.init_params().unwrap();
        let batches = vec![4usize; 3];
        individual_round(
            &Engine::new(2),
            &be,
            &mut workers,
            &params,
            &train,
            &batches,
            0.1,
            1,
            0,
        )
        .unwrap();
        for w in &workers {
            let local = w.local_params.as_ref().unwrap();
            assert_eq!(local.len(), params.len());
            assert_ne!(local, &params);
        }
    }
}
