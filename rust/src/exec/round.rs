//! Per-scheme round executors: the *execution* half of a FEEL period.
//!
//! `scheme::plan_period` decides what each device should do; these
//! functions do it, fanning the K device steps out over the engine and
//! returning per-device outcomes **in device order** so the trainer can
//! reduce them deterministically (see exec/mod.rs for the contract).

use anyhow::{Context, Result};

use super::engine::Engine;
use crate::coordinator::backend::Backend;
use crate::coordinator::worker::Worker;
use crate::data::Dataset;
use crate::util::rng::Pcg;

/// One device's gradient-scheme contribution.
pub struct GradOutcome {
    /// the gradient as the server will see it (post compression round-trip)
    pub grad: Vec<f32>,
    /// aggregation weight |B_k|
    pub weight: f64,
    /// the device's mean train loss on its batch
    pub loss: f64,
}

/// One device's model-FL (FedAvg) contribution.
pub struct LocalFitOutcome {
    /// locally-trained parameters
    pub params: Vec<f32>,
    /// averaging weight N_k (shard size)
    pub weight: f64,
    /// last local-step loss
    pub loss: f64,
}

/// One device's individual-learning step summary.
pub struct LocalStepOutcome {
    pub weight: f64,
    pub loss: f64,
}

/// Steps 1–3 of a gradient-exchange period: every device samples its
/// planned batch, runs forward/backward on the global parameters, and
/// compresses its gradient. Aggregation stays with the caller.
#[allow(clippy::too_many_arguments)]
pub fn gradient_round(
    engine: &Engine,
    backend: &dyn Backend,
    workers: &mut [Worker],
    params: &[f32],
    train: &Dataset,
    batches: &[usize],
    seed: u64,
    period: u64,
) -> Result<Vec<GradOutcome>> {
    engine.run_mut(workers, |k, w| {
        let b = batches[k].max(1);
        let mut rng = Pcg::for_device(seed, period, k as u64);
        let (x, y) = w.data.sample_with(train, b, &mut rng);
        let step = backend
            .train_step(params, &x, &y)
            .with_context(|| format!("device {k} train_step"))?;
        let (grad, _bits) = w.compress(step.grads);
        Ok(GradOutcome { grad, weight: b as f64, loss: step.loss as f64 })
    })
}

/// Model-based FL round: one local epoch per device from the global
/// parameters, returning the locally-trained models for FedAvg.
#[allow(clippy::too_many_arguments)]
pub fn model_fl_round(
    engine: &Engine,
    backend: &dyn Backend,
    workers: &mut [Worker],
    global: &[f32],
    train: &Dataset,
    local_batch: usize,
    lr: f32,
    seed: u64,
    period: u64,
) -> Result<Vec<LocalFitOutcome>> {
    engine.run_mut(workers, |k, w| {
        let mut params = global.to_vec();
        let n = w.shard_len();
        let steps = n.div_ceil(local_batch).max(1);
        let mut rng = Pcg::for_device(seed, period, k as u64);
        let mut last_loss = 0f32;
        for _ in 0..steps {
            let (x, y) = w.data.sample_with(train, local_batch.min(n), &mut rng);
            let s = backend
                .train_step(&params, &x, &y)
                .with_context(|| format!("device {k} local step"))?;
            last_loss = s.loss;
            params = backend.apply_update(&params, &s.grads, lr)?;
        }
        Ok(LocalFitOutcome { params, weight: n as f64, loss: last_loss as f64 })
    })
}

/// Individual-learning round: one local mini-batch step per device on its
/// own parameters (initialized from `global` on first touch).
#[allow(clippy::too_many_arguments)]
pub fn individual_round(
    engine: &Engine,
    backend: &dyn Backend,
    workers: &mut [Worker],
    global: &[f32],
    train: &Dataset,
    batches: &[usize],
    lr: f32,
    seed: u64,
    period: u64,
) -> Result<Vec<LocalStepOutcome>> {
    engine.run_mut(workers, |k, w| {
        let mut params = w.local_params.take().unwrap_or_else(|| global.to_vec());
        let b = batches[k].max(1);
        let mut rng = Pcg::for_device(seed, period, k as u64);
        let (x, y) = w.data.sample_with(train, b, &mut rng);
        let s = backend
            .train_step(&params, &x, &y)
            .with_context(|| format!("device {k} individual step"))?;
        params = backend.apply_update(&params, &s.grads, lr)?;
        w.local_params = Some(params);
        Ok(LocalStepOutcome { weight: b as f64, loss: s.loss as f64 })
    })
}

/// Per-device evaluation (individual learning): each device's local model
/// (falling back to `global`) against the held-out set, in device order.
pub fn eval_round(
    engine: &Engine,
    backend: &dyn Backend,
    workers: &[Worker],
    global: &[f32],
    x: &[f32],
    y: &[i32],
) -> Result<Vec<(f64, f64)>> {
    engine.run_indexed(workers.len(), |k| {
        let params = workers[k].local_params.as_deref().unwrap_or(global);
        backend.evaluate(params, x, y)
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::Sbc;
    use crate::coordinator::backend::HostBackend;
    use crate::data::synthetic::{generate, SynthConfig};
    use crate::data::DeviceData;

    fn world(k: usize, p_sbc: bool) -> (Dataset, Vec<Worker>, HostBackend) {
        let cfg = SynthConfig { dim: 12, ..Default::default() };
        let train = generate(&cfg, 40 * k, 1);
        let be = HostBackend::for_model("mini_dense", 12, 10, 2).unwrap();
        let p = be.params();
        let workers: Vec<Worker> = (0..k)
            .map(|id| {
                let idx: Vec<usize> = (id * 40..(id + 1) * 40).collect();
                let sbc = if p_sbc { Some(Sbc::new(0.01, p)) } else { None };
                Worker::new(id, DeviceData::new(idx, Pcg::seeded(id as u64)), sbc)
            })
            .collect();
        (train, workers, be)
    }

    #[test]
    fn gradient_round_thread_invariant() {
        let (train, mut w1, be) = world(5, true);
        let (_, mut w4, _) = world(5, true);
        let params = be.init_params().unwrap();
        let batches = vec![8usize; 5];
        let a = gradient_round(&Engine::new(1), &be, &mut w1, &params, &train, &batches, 9, 3)
            .unwrap();
        let b = gradient_round(&Engine::new(4), &be, &mut w4, &params, &train, &batches, 9, 3)
            .unwrap();
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.grad, y.grad);
            assert_eq!(x.loss.to_bits(), y.loss.to_bits());
            assert_eq!(x.weight, y.weight);
        }
    }

    #[test]
    fn individual_round_keeps_local_params() {
        let (train, mut workers, be) = world(3, false);
        let params = be.init_params().unwrap();
        let batches = vec![4usize; 3];
        individual_round(
            &Engine::new(2),
            &be,
            &mut workers,
            &params,
            &train,
            &batches,
            0.1,
            1,
            0,
        )
        .unwrap();
        for w in &workers {
            let local = w.local_params.as_ref().unwrap();
            assert_eq!(local.len(), params.len());
            assert_ne!(local, &params);
        }
    }
}
