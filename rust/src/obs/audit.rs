//! The audit ledger: predicted-vs-realized round accounting.
//!
//! The optimizer predicts where every device's simulated seconds go
//! (compute, slotted upload, TDMA share — captured on the `Plan` as
//! [`PredictedTiming`](crate::opt::PredictedTiming) rows); the round
//! scheduler then realizes perturbed arrivals and outcomes. The ledger
//! records both sides, per period and per device, so `feel audit` can
//! derive learning efficiency (loss decrement ÷ simulated seconds, the
//! paper's eq. 15 measured instead of predicted), compute/comm/wait
//! decomposition, bandwidth utilization, and straggler regret
//! (realized ÷ predicted).
//!
//! Discipline matches the rest of `obs`: the ledger lives inside
//! `ObsSink`'s `Option`, records simulated time only, never draws RNG,
//! and never touches numerics — so collection is bitwise invisible in the
//! `TrainLog` and its JSONL export is byte-identical at any thread count
//! (pinned in `tests/observability.rs`).

use crate::coordinator::scheme::Plan;
use crate::util::json::{num, obj, s, Json};

/// How one device's planned contribution resolved.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Outcome {
    /// planned (or dispatched) but not resolved by the period close —
    /// async in-flight work resolves into its *source* period's row later
    Pending,
    /// gradient entered the aggregate
    Applied,
    /// payload arrived corrupt and the quarantine kept it out
    Quarantined,
    /// lost to straggler dropout
    Dropped,
    /// unreachable in a fault-injected crash window
    Crashed,
    /// missed the deadline; batch carried into the device's next period
    Late,
}

impl Outcome {
    pub fn label(&self) -> &'static str {
        match self {
            Outcome::Pending => "pending",
            Outcome::Applied => "applied",
            Outcome::Quarantined => "quarantined",
            Outcome::Dropped => "dropped",
            Outcome::Crashed => "crashed",
            Outcome::Late => "late",
        }
    }
}

/// One device's predicted and realized accounting for one period.
#[derive(Clone, Debug)]
pub struct DeviceAudit {
    pub device: usize,
    /// planned batch (post-carry — what the scheduler executed against)
    pub batch: usize,
    /// predicted local compute seconds (post-carry)
    pub p_compute: f64,
    /// predicted slotted upload seconds (+inf = no slot)
    pub p_comm: f64,
    /// predicted TDMA slot share in [0, 1]
    pub p_slot: f64,
    /// predicted arrival, seconds from period start (the plan's clamped
    /// nominal finish time)
    pub p_finish: f64,
    /// realized arrival, seconds from period start (None: never arrived)
    pub r_finish: Option<f64>,
    pub outcome: Outcome,
    /// rounds the gradient waited before application (async only)
    pub staleness: Option<u64>,
    /// batch deferred into the next period by a deadline miss
    pub carry: usize,
}

/// One period's full predicted-vs-realized row.
#[derive(Clone, Debug)]
pub struct PeriodAudit {
    /// 1-based period number (matches `PeriodRecord.period`)
    pub period: u64,
    pub cell: usize,
    /// simulated time at period start
    pub t_start: f64,
    /// predicted uplink makespan
    pub p_t_up: f64,
    /// predicted downlink makespan
    pub p_t_down: f64,
    /// predicted end-to-end period latency
    pub p_t_period: f64,
    /// the optimizer's predicted learning efficiency (if it ran)
    pub p_efficiency: Option<f64>,
    /// realized period duration (simulated seconds)
    pub r_duration: f64,
    pub b_total: u64,
    pub applied: u64,
    /// realized loss decrement this period
    pub loss_dec: f64,
    pub devices: Vec<DeviceAudit>,
}

/// One cloud-merge event in the hier trainer's cloud lane.
#[derive(Clone, Copy, Debug)]
pub struct CloudAudit {
    /// 1-based tau-block number (matches the cloud metrics snapshot)
    pub block: u64,
    /// barrier time of the merge (slowest cell's clock)
    pub t_cloud: f64,
    /// cells that contributed to the merge
    pub cells: usize,
}

/// One rendered JSONL line with its merge key, mirroring
/// [`Snap`](crate::obs::metrics::Snap).
#[derive(Clone, Debug)]
pub struct AuditLine {
    pub period: u64,
    pub cell: usize,
    pub line: String,
}

/// Per-run audit ledger: one [`PeriodAudit`] row per training period plus
/// (on the hier cloud sink) one [`CloudAudit`] row per tau-block.
#[derive(Clone, Debug, Default)]
pub struct AuditLedger {
    cell: usize,
    rows: Vec<PeriodAudit>,
    cloud: Vec<CloudAudit>,
}

fn jnum(v: f64) -> Json {
    if v.is_finite() {
        Json::Num(v)
    } else {
        Json::Null
    }
}

impl AuditLedger {
    pub fn new(cell: usize) -> AuditLedger {
        AuditLedger { cell, rows: Vec::new(), cloud: Vec::new() }
    }

    /// Open a period row from the plan: one device entry per planned
    /// participant (`batches[d] > 0`; a sampled-out device holds no row).
    /// Call after the carry ledger was folded in, so the predicted side is
    /// what the scheduler actually executes against.
    pub fn begin(&mut self, period: u64, t_start: f64, plan: &Plan) {
        let devices = plan
            .batches
            .iter()
            .enumerate()
            .filter(|&(_, &b)| b > 0)
            .map(|(d, &b)| DeviceAudit {
                device: d,
                batch: b,
                p_compute: plan.predicted.get(d).map_or(0.0, |p| p.compute),
                p_comm: plan.predicted.get(d).map_or(0.0, |p| p.comm),
                p_slot: plan.predicted.get(d).map_or(0.0, |p| p.slot_share),
                p_finish: plan.finish.get(d).copied().unwrap_or(0.0),
                r_finish: None,
                outcome: Outcome::Pending,
                staleness: None,
                carry: 0,
            })
            .collect();
        self.rows.push(PeriodAudit {
            period,
            cell: self.cell,
            t_start,
            p_t_up: plan.t_up,
            p_t_down: plan.t_down,
            p_t_period: plan.t_period,
            p_efficiency: plan.predicted_efficiency,
            r_duration: 0.0,
            b_total: 0,
            applied: 0,
            loss_dec: 0.0,
            devices,
        })
    }

    fn open_device(&mut self, d: usize) -> Option<&mut DeviceAudit> {
        self.rows
            .last_mut()
            .and_then(|row| row.devices.iter_mut().find(|da| da.device == d))
    }

    /// Realized arrival of device `d` in the open period, seconds from
    /// period start.
    pub fn arrival(&mut self, d: usize, t_rel: f64) {
        if let Some(da) = self.open_device(d) {
            da.r_finish = Some(t_rel);
        }
    }

    /// Resolve device `d`'s outcome in the open period.
    pub fn outcome(&mut self, d: usize, outcome: Outcome) {
        if let Some(da) = self.open_device(d) {
            da.outcome = outcome;
        }
    }

    /// Record a deadline-miss carry for device `d` in the open period.
    pub fn carry(&mut self, d: usize, batches: usize) {
        if let Some(da) = self.open_device(d) {
            da.carry = batches;
        }
    }

    /// Resolve an async contribution into its *source* period's row.
    /// `src_round` is the scheduler's round coordinate (the trainer's
    /// pre-increment period counter — row number minus one). A source row
    /// from before the ledger existed (resume, obs enabled mid-run) is
    /// silently absent.
    pub fn resolve(&mut self, d: usize, src_round: u64, outcome: Outcome, staleness: Option<u64>) {
        let period = src_round + 1;
        if let Some(row) = self.rows.iter_mut().rev().find(|r| r.period == period) {
            if let Some(da) = row.devices.iter_mut().find(|da| da.device == d) {
                da.outcome = outcome;
                da.staleness = staleness;
            }
        }
    }

    /// Barrier-scheme fill (ModelFl / Individual bypass the round
    /// scheduler): every unresolved device arrived exactly on its nominal
    /// finish and was applied.
    pub fn barrier_fill(&mut self) {
        if let Some(row) = self.rows.last_mut() {
            for da in &mut row.devices {
                if da.outcome == Outcome::Pending && da.r_finish.is_none() {
                    da.r_finish = Some(da.p_finish);
                    da.outcome = Outcome::Applied;
                }
            }
        }
    }

    /// Close the open period row with the realized round totals.
    pub fn end(&mut self, duration: f64, loss_dec: f64, b_total: u64, applied: u64) {
        if let Some(row) = self.rows.last_mut() {
            row.r_duration = duration;
            row.loss_dec = loss_dec;
            row.b_total = b_total;
            row.applied = applied;
        }
    }

    /// Record one cloud merge (hier cloud lane; `block` is 1-based).
    pub fn cloud_merge(&mut self, block: u64, t_cloud: f64, cells: usize) {
        self.cloud.push(CloudAudit { block, t_cloud, cells });
    }

    pub fn rows(&self) -> &[PeriodAudit] {
        &self.rows
    }

    pub fn cloud(&self) -> &[CloudAudit] {
        &self.cloud
    }

    /// Render every row as a JSONL line with its `(period, cell)` merge
    /// key. Cloud rows key on their block number (the cloud snapshot
    /// convention), so a merged stream interleaves them deterministically.
    pub fn lines(&self) -> Vec<AuditLine> {
        let mut out = Vec::with_capacity(self.rows.len() + self.cloud.len());
        for row in &self.rows {
            out.push(AuditLine {
                period: row.period,
                cell: row.cell,
                line: period_json(row).to_string(),
            });
        }
        for c in &self.cloud {
            out.push(AuditLine {
                period: c.block,
                cell: self.cell,
                line: cloud_json(c, self.cell).to_string(),
            });
        }
        out
    }

    /// This ledger's rows alone as one JSONL document.
    pub fn to_jsonl(&self) -> String {
        let mut out = String::new();
        for l in self.lines() {
            out.push_str(&l.line);
            out.push('\n');
        }
        out
    }
}

fn device_json(da: &DeviceAudit) -> Json {
    obj(vec![
        ("batch", num(da.batch as f64)),
        ("carry", num(da.carry as f64)),
        ("device", num(da.device as f64)),
        ("outcome", s(da.outcome.label())),
        ("p_comm", jnum(da.p_comm)),
        ("p_compute", jnum(da.p_compute)),
        ("p_finish", jnum(da.p_finish)),
        ("p_slot", jnum(da.p_slot)),
        ("r_finish", da.r_finish.map_or(Json::Null, jnum)),
        ("staleness", da.staleness.map_or(Json::Null, |v| num(v as f64))),
    ])
}

fn period_json(row: &PeriodAudit) -> Json {
    obj(vec![
        ("applied", num(row.applied as f64)),
        ("b_total", num(row.b_total as f64)),
        ("cell", num(row.cell as f64)),
        ("devices", Json::Arr(row.devices.iter().map(device_json).collect())),
        ("kind", s("period")),
        ("loss_dec", jnum(row.loss_dec)),
        ("p_efficiency", row.p_efficiency.map_or(Json::Null, jnum)),
        ("p_t_down", jnum(row.p_t_down)),
        ("p_t_period", jnum(row.p_t_period)),
        ("p_t_up", jnum(row.p_t_up)),
        ("period", num(row.period as f64)),
        ("r_duration", jnum(row.r_duration)),
        ("t_start", jnum(row.t_start)),
    ])
}

fn cloud_json(c: &CloudAudit, cell: usize) -> Json {
    obj(vec![
        ("block", num(c.block as f64)),
        ("cell", num(cell as f64)),
        ("cells", num(c.cells as f64)),
        ("kind", s("cloud")),
        ("t_cloud", jnum(c.t_cloud)),
    ])
}

/// Merge per-cell ledgers (plus the hier cloud ledger) into one JSONL
/// document ordered by `(period, cell)` — the same stable-sort convention
/// as [`merge_snaps`](crate::obs::metrics::merge_snaps).
pub fn merge_audit(parts: &[&AuditLedger]) -> String {
    let mut all: Vec<AuditLine> = parts.iter().flat_map(|p| p.lines()).collect();
    all.sort_by_key(|l| (l.period, l.cell));
    let mut out = String::new();
    for l in all {
        out.push_str(&l.line);
        out.push('\n');
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::opt::types::PredictedTiming;

    fn plan(k: usize) -> Plan {
        Plan {
            batches: vec![10; k],
            t_period: 1.2,
            t_up: 1.0,
            t_down: 0.2,
            finish: vec![0.9; k],
            predicted: vec![
                PredictedTiming { compute: 0.5, comm: 0.4, slot_share: 1.0 / k as f64 };
                k
            ],
            predicted_efficiency: Some(0.05),
        }
    }

    #[test]
    fn ledger_records_a_full_period_roundtrip() {
        let mut led = AuditLedger::new(0);
        led.begin(1, 0.0, &plan(3));
        led.arrival(0, 0.9);
        led.outcome(0, Outcome::Applied);
        led.outcome(1, Outcome::Dropped);
        led.arrival(2, 1.3);
        led.outcome(2, Outcome::Late);
        led.carry(2, 10);
        led.end(1.45, 0.02, 20, 1);
        let row = &led.rows()[0];
        assert_eq!(row.period, 1);
        assert_eq!(row.devices.len(), 3);
        assert_eq!(row.devices[0].r_finish, Some(0.9));
        assert_eq!(row.devices[0].outcome, Outcome::Applied);
        assert_eq!(row.devices[1].r_finish, None);
        assert_eq!(row.devices[1].outcome, Outcome::Dropped);
        assert_eq!(row.devices[2].carry, 10);
        assert_eq!(row.r_duration, 1.45);
        assert_eq!(row.applied, 1);
        // hooks on a device outside the row are silent no-ops
        led.arrival(9, 1.0);
        led.outcome(9, Outcome::Applied);
    }

    #[test]
    fn resolve_lands_in_the_source_period_row() {
        let mut led = AuditLedger::new(0);
        led.begin(1, 0.0, &plan(2));
        led.arrival(1, 0.9);
        led.end(1.2, 0.01, 20, 1);
        led.begin(2, 1.2, &plan(2));
        // device 1's round-0 dispatch applies two rounds later, stale
        led.resolve(1, 0, Outcome::Applied, Some(2));
        assert_eq!(led.rows()[0].devices[1].outcome, Outcome::Applied);
        assert_eq!(led.rows()[0].devices[1].staleness, Some(2));
        assert_eq!(led.rows()[1].devices[1].outcome, Outcome::Pending);
        // a source round before the ledger existed is silently absent
        led.resolve(0, 99, Outcome::Applied, Some(1));
    }

    #[test]
    fn barrier_fill_realizes_the_prediction_exactly() {
        let mut led = AuditLedger::new(0);
        led.begin(1, 0.0, &plan(2));
        led.barrier_fill();
        led.end(1.2, 0.01, 20, 2);
        for da in &led.rows()[0].devices {
            assert_eq!(da.r_finish, Some(da.p_finish));
            assert_eq!(da.outcome, Outcome::Applied);
        }
    }

    #[test]
    fn zero_batch_devices_hold_no_row() {
        let mut p = plan(3);
        p.batches[1] = 0;
        let mut led = AuditLedger::new(0);
        led.begin(1, 0.0, &p);
        let ids: Vec<usize> = led.rows()[0].devices.iter().map(|d| d.device).collect();
        assert_eq!(ids, vec![0, 2]);
    }

    #[test]
    fn jsonl_lines_parse_and_merge_orders_by_period_then_cell() {
        let mut a = AuditLedger::new(0);
        a.begin(1, 0.0, &plan(1));
        a.end(1.2, 0.01, 10, 1);
        a.begin(2, 1.2, &plan(1));
        a.end(1.2, 0.01, 10, 1);
        let mut b = AuditLedger::new(1);
        b.begin(1, 0.0, &plan(1));
        b.end(1.3, 0.02, 10, 1);
        let mut cloud = AuditLedger::new(2);
        cloud.cloud_merge(1, 1.3, 2);
        let merged = merge_audit(&[&a, &b, &cloud]);
        let lines: Vec<&str> = merged.lines().collect();
        assert_eq!(lines.len(), 4);
        for line in &lines {
            Json::parse(line).unwrap();
        }
        // (1, cell 0), (1, cell 1), (1, cloud on lane 2), (2, cell 0)
        let key = |l: &str| {
            let v = Json::parse(l).unwrap();
            let p = v.get("period").or_else(|| v.get("block")).and_then(Json::as_usize);
            (p, v.get("cell").and_then(Json::as_usize))
        };
        assert_eq!(key(lines[0]), (Some(1), Some(0)));
        assert_eq!(key(lines[1]), (Some(1), Some(1)));
        assert_eq!(key(lines[2]), (Some(1), Some(2)));
        assert_eq!(key(lines[3]), (Some(2), Some(0)));
        // non-finite predictions render as null, not bare inf
        let mut p = plan(1);
        p.predicted[0].comm = f64::INFINITY;
        let mut led = AuditLedger::new(0);
        led.begin(1, 0.0, &p);
        let line = led.to_jsonl();
        assert!(line.contains("\"p_comm\":null"), "{line}");
        Json::parse(line.trim()).unwrap();
    }
}
