//! Observability: structured tracing + metrics, zero-cost when off.
//!
//! `ObsSink` is the handle threaded through the trainers and the round
//! scheduler. Disabled (the default) it is a single `None` check per call
//! and allocates nothing; enabling it never draws RNG, never touches
//! numerics, and never reads the host wall clock on the trace path, so an
//! instrumented run reproduces an uninstrumented run's `TrainLog` bitwise
//! and the same seed yields byte-identical trace files at any thread count
//! (both pinned in `tests/observability.rs`).
//!
//! - `trace`: spans/instants on the simulated clock, exported as Chrome
//!   trace-event JSON (`--trace FILE`, open in chrome://tracing or
//!   Perfetto). Cells map to pids (the hier cloud lane is pid = #cells),
//!   devices to tids (coordinator = 0, device d = d + 1).
//! - `metrics`: named counters/gauges/histograms snapshotted per period and
//!   dumped as JSONL (`--metrics-out FILE`; summarize with `feel report`).
//! - `audit`: the predicted-vs-realized round ledger, dumped as JSONL
//!   (`--audit FILE`; summarize with `feel audit` via `efficiency`).

pub mod audit;
pub mod efficiency;
pub mod metrics;
pub mod trace;

pub use audit::{merge_audit, AuditLedger, Outcome};
pub use efficiency::summarize_audit_jsonl;
pub use metrics::{merge_snaps, summarize_jsonl, Histogram, MetricsRegistry, Snap};
pub use trace::{chrome_trace, merge_traces, TraceEvent};

use crate::coordinator::scheme::Plan;

/// Observability sink: disabled by default. Enabled, it records into one
/// trace-event buffer and one metrics registry, stamping every event with
/// the pid fixed at enable time (the owning trainer's cell id).
#[derive(Debug, Default)]
pub struct ObsSink {
    inner: Option<Box<ObsInner>>,
}

#[derive(Debug)]
struct ObsInner {
    pid: usize,
    events: Vec<TraceEvent>,
    metrics: MetricsRegistry,
    audit: AuditLedger,
}

impl ObsSink {
    pub fn disabled() -> ObsSink {
        ObsSink { inner: None }
    }

    pub fn enabled(pid: usize) -> ObsSink {
        ObsSink {
            inner: Some(Box::new(ObsInner {
                pid,
                events: Vec::new(),
                metrics: MetricsRegistry::default(),
                audit: AuditLedger::new(pid),
            })),
        }
    }

    pub fn is_enabled(&self) -> bool {
        self.inner.is_some()
    }

    // -- trace -------------------------------------------------------------

    /// Record a complete span: `ts`/`dur` in simulated seconds, `tid` 0 for
    /// the coordinator lane or `device + 1` for a device lane.
    pub fn span(&mut self, name: &'static str, cat: &'static str, tid: usize, ts: f64, dur: f64) {
        if let Some(inner) = &mut self.inner {
            inner
                .events
                .push(TraceEvent::span(name, cat, inner.pid, tid, ts, dur));
        }
    }

    pub fn span_arg(
        &mut self,
        name: &'static str,
        cat: &'static str,
        tid: usize,
        ts: f64,
        dur: f64,
        args: &[(&'static str, f64)],
    ) {
        if let Some(inner) = &mut self.inner {
            let mut e = TraceEvent::span(name, cat, inner.pid, tid, ts, dur);
            e.args.extend_from_slice(args);
            inner.events.push(e);
        }
    }

    pub fn instant(&mut self, name: &'static str, cat: &'static str, tid: usize, ts: f64) {
        if let Some(inner) = &mut self.inner {
            inner
                .events
                .push(TraceEvent::instant(name, cat, inner.pid, tid, ts));
        }
    }

    pub fn instant_arg(
        &mut self,
        name: &'static str,
        cat: &'static str,
        tid: usize,
        ts: f64,
        args: &[(&'static str, f64)],
    ) {
        if let Some(inner) = &mut self.inner {
            let mut e = TraceEvent::instant(name, cat, inner.pid, tid, ts);
            e.args.extend_from_slice(args);
            inner.events.push(e);
        }
    }

    /// Instant carrying one string arg (e.g. a quarantine verdict name).
    pub fn instant_label(
        &mut self,
        name: &'static str,
        cat: &'static str,
        tid: usize,
        ts: f64,
        key: &'static str,
        value: &'static str,
    ) {
        if let Some(inner) = &mut self.inner {
            inner
                .events
                .push(TraceEvent::instant(name, cat, inner.pid, tid, ts).label(key, value));
        }
    }

    /// The recorded event buffer (empty when disabled).
    pub fn events(&self) -> &[TraceEvent] {
        match &self.inner {
            Some(inner) => &inner.events,
            None => &[],
        }
    }

    // -- metrics -----------------------------------------------------------

    pub fn inc(&mut self, name: &'static str, by: u64) {
        if let Some(inner) = &mut self.inner {
            inner.metrics.inc(name, by);
        }
    }

    pub fn gauge(&mut self, name: &'static str, v: f64) {
        if let Some(inner) = &mut self.inner {
            inner.metrics.gauge(name, v);
        }
    }

    pub fn observe(&mut self, name: &'static str, v: f64) {
        if let Some(inner) = &mut self.inner {
            inner.metrics.observe(name, v);
        }
    }

    /// Freeze the cumulative metrics into one JSONL snapshot line.
    pub fn snapshot(&mut self, period: u64) {
        if let Some(inner) = &mut self.inner {
            let cell = inner.pid;
            inner.metrics.snapshot(period, cell);
        }
    }

    pub fn metrics(&self) -> Option<&MetricsRegistry> {
        self.inner.as_deref().map(|inner| &inner.metrics)
    }

    pub fn snaps(&self) -> &[Snap] {
        match &self.inner {
            Some(inner) => inner.metrics.snaps(),
            None => &[],
        }
    }

    /// Metrics JSONL for this sink alone (empty when disabled).
    pub fn to_jsonl(&self) -> String {
        match &self.inner {
            Some(inner) => inner.metrics.to_jsonl(),
            None => String::new(),
        }
    }

    // -- audit -------------------------------------------------------------

    /// Open a period's audit row from its (post-carry) plan. `period` is
    /// the 1-based period number the row will report as.
    pub fn audit_begin(&mut self, period: u64, t_start: f64, plan: &Plan) {
        if let Some(inner) = &mut self.inner {
            inner.audit.begin(period, t_start, plan);
        }
    }

    /// Realized arrival of `device` in the open period row, seconds from
    /// period start.
    pub fn audit_arrival(&mut self, device: usize, t_rel: f64) {
        if let Some(inner) = &mut self.inner {
            inner.audit.arrival(device, t_rel);
        }
    }

    /// Resolve `device`'s outcome in the open period row.
    pub fn audit_outcome(&mut self, device: usize, outcome: Outcome) {
        if let Some(inner) = &mut self.inner {
            inner.audit.outcome(device, outcome);
        }
    }

    /// Record a deadline-miss carry in the open period row.
    pub fn audit_carry(&mut self, device: usize, batches: usize) {
        if let Some(inner) = &mut self.inner {
            inner.audit.carry(device, batches);
        }
    }

    /// Resolve an async contribution into its source period's row;
    /// `src_round` is the scheduler's round coordinate (pre-increment
    /// period counter).
    pub fn audit_resolve(
        &mut self,
        device: usize,
        src_round: u64,
        outcome: Outcome,
        staleness: Option<u64>,
    ) {
        if let Some(inner) = &mut self.inner {
            inner.audit.resolve(device, src_round, outcome, staleness);
        }
    }

    /// Barrier-scheme fill: unresolved devices realized their prediction
    /// exactly (ModelFl / Individual bypass the round scheduler).
    pub fn audit_barrier_fill(&mut self) {
        if let Some(inner) = &mut self.inner {
            inner.audit.barrier_fill();
        }
    }

    /// Close the open period row with the realized round totals.
    pub fn audit_end(&mut self, duration: f64, loss_dec: f64, b_total: u64, applied: u64) {
        if let Some(inner) = &mut self.inner {
            inner.audit.end(duration, loss_dec, b_total, applied);
        }
    }

    /// Record one cloud merge on the hier cloud lane (1-based block).
    pub fn audit_cloud(&mut self, block: u64, t_cloud: f64, cells: usize) {
        if let Some(inner) = &mut self.inner {
            inner.audit.cloud_merge(block, t_cloud, cells);
        }
    }

    pub fn audit(&self) -> Option<&AuditLedger> {
        self.inner.as_deref().map(|inner| &inner.audit)
    }

    /// Audit JSONL for this sink alone (empty when disabled).
    pub fn audit_jsonl(&self) -> String {
        match &self.inner {
            Some(inner) => inner.audit.to_jsonl(),
            None => String::new(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_records_nothing() {
        let mut sink = ObsSink::disabled();
        sink.span("round", "device", 1, 0.0, 1.0);
        sink.instant("crash", "fault", 2, 0.5);
        sink.inc("round.applied", 1);
        sink.observe("round.duration", 1.0);
        sink.snapshot(1);
        sink.audit_arrival(0, 1.0);
        sink.audit_outcome(0, Outcome::Applied);
        sink.audit_end(1.0, 0.1, 10, 1);
        sink.audit_cloud(1, 2.0, 3);
        assert!(!sink.is_enabled());
        assert!(sink.events().is_empty());
        assert!(sink.snaps().is_empty());
        assert!(sink.metrics().is_none());
        assert!(sink.audit().is_none());
        assert_eq!(sink.to_jsonl(), "");
        assert_eq!(sink.audit_jsonl(), "");
    }

    #[test]
    fn enabled_stamps_pid_and_snapshots_cell() {
        let mut sink = ObsSink::enabled(3);
        sink.span("round", "device", 1, 0.0, 1.0);
        sink.instant_label("quarantine", "guard", 2, 0.5, "verdict", "rejected");
        sink.inc("agg.quarantined", 1);
        sink.snapshot(7);
        assert_eq!(sink.events().len(), 2);
        assert!(sink.events().iter().all(|e| e.pid == 3));
        assert_eq!(sink.snaps().len(), 1);
        assert_eq!(sink.snaps()[0].cell, 3);
        assert_eq!(sink.snaps()[0].period, 7);
        assert_eq!(sink.metrics().unwrap().counter("agg.quarantined"), 1);
        // the audit ledger snapshots the sink's cell id too
        sink.audit_cloud(1, 0.5, 2);
        let audit = sink.audit().unwrap();
        assert_eq!(audit.cloud().len(), 1);
        assert!(sink.audit_jsonl().contains("\"cell\":3"));
    }
}
