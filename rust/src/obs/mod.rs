//! Observability: structured tracing + metrics, zero-cost when off.
//!
//! `ObsSink` is the handle threaded through the trainers and the round
//! scheduler. Disabled (the default) it is a single `None` check per call
//! and allocates nothing; enabling it never draws RNG, never touches
//! numerics, and never reads the host wall clock on the trace path, so an
//! instrumented run reproduces an uninstrumented run's `TrainLog` bitwise
//! and the same seed yields byte-identical trace files at any thread count
//! (both pinned in `tests/observability.rs`).
//!
//! - `trace`: spans/instants on the simulated clock, exported as Chrome
//!   trace-event JSON (`--trace FILE`, open in chrome://tracing or
//!   Perfetto). Cells map to pids (the hier cloud lane is pid = #cells),
//!   devices to tids (coordinator = 0, device d = d + 1).
//! - `metrics`: named counters/gauges/histograms snapshotted per period and
//!   dumped as JSONL (`--metrics-out FILE`; summarize with `feel report`).

pub mod metrics;
pub mod trace;

pub use metrics::{merge_snaps, summarize_jsonl, Histogram, MetricsRegistry, Snap};
pub use trace::{chrome_trace, merge_traces, TraceEvent};

/// Observability sink: disabled by default. Enabled, it records into one
/// trace-event buffer and one metrics registry, stamping every event with
/// the pid fixed at enable time (the owning trainer's cell id).
#[derive(Debug, Default)]
pub struct ObsSink {
    inner: Option<Box<ObsInner>>,
}

#[derive(Debug)]
struct ObsInner {
    pid: usize,
    events: Vec<TraceEvent>,
    metrics: MetricsRegistry,
}

impl ObsSink {
    pub fn disabled() -> ObsSink {
        ObsSink { inner: None }
    }

    pub fn enabled(pid: usize) -> ObsSink {
        ObsSink {
            inner: Some(Box::new(ObsInner {
                pid,
                events: Vec::new(),
                metrics: MetricsRegistry::default(),
            })),
        }
    }

    pub fn is_enabled(&self) -> bool {
        self.inner.is_some()
    }

    // -- trace -------------------------------------------------------------

    /// Record a complete span: `ts`/`dur` in simulated seconds, `tid` 0 for
    /// the coordinator lane or `device + 1` for a device lane.
    pub fn span(&mut self, name: &'static str, cat: &'static str, tid: usize, ts: f64, dur: f64) {
        if let Some(inner) = &mut self.inner {
            inner
                .events
                .push(TraceEvent::span(name, cat, inner.pid, tid, ts, dur));
        }
    }

    pub fn span_arg(
        &mut self,
        name: &'static str,
        cat: &'static str,
        tid: usize,
        ts: f64,
        dur: f64,
        args: &[(&'static str, f64)],
    ) {
        if let Some(inner) = &mut self.inner {
            let mut e = TraceEvent::span(name, cat, inner.pid, tid, ts, dur);
            e.args.extend_from_slice(args);
            inner.events.push(e);
        }
    }

    pub fn instant(&mut self, name: &'static str, cat: &'static str, tid: usize, ts: f64) {
        if let Some(inner) = &mut self.inner {
            inner
                .events
                .push(TraceEvent::instant(name, cat, inner.pid, tid, ts));
        }
    }

    pub fn instant_arg(
        &mut self,
        name: &'static str,
        cat: &'static str,
        tid: usize,
        ts: f64,
        args: &[(&'static str, f64)],
    ) {
        if let Some(inner) = &mut self.inner {
            let mut e = TraceEvent::instant(name, cat, inner.pid, tid, ts);
            e.args.extend_from_slice(args);
            inner.events.push(e);
        }
    }

    /// Instant carrying one string arg (e.g. a quarantine verdict name).
    pub fn instant_label(
        &mut self,
        name: &'static str,
        cat: &'static str,
        tid: usize,
        ts: f64,
        key: &'static str,
        value: &'static str,
    ) {
        if let Some(inner) = &mut self.inner {
            inner
                .events
                .push(TraceEvent::instant(name, cat, inner.pid, tid, ts).label(key, value));
        }
    }

    /// The recorded event buffer (empty when disabled).
    pub fn events(&self) -> &[TraceEvent] {
        match &self.inner {
            Some(inner) => &inner.events,
            None => &[],
        }
    }

    // -- metrics -----------------------------------------------------------

    pub fn inc(&mut self, name: &'static str, by: u64) {
        if let Some(inner) = &mut self.inner {
            inner.metrics.inc(name, by);
        }
    }

    pub fn gauge(&mut self, name: &'static str, v: f64) {
        if let Some(inner) = &mut self.inner {
            inner.metrics.gauge(name, v);
        }
    }

    pub fn observe(&mut self, name: &'static str, v: f64) {
        if let Some(inner) = &mut self.inner {
            inner.metrics.observe(name, v);
        }
    }

    /// Freeze the cumulative metrics into one JSONL snapshot line.
    pub fn snapshot(&mut self, period: u64) {
        if let Some(inner) = &mut self.inner {
            let cell = inner.pid;
            inner.metrics.snapshot(period, cell);
        }
    }

    pub fn metrics(&self) -> Option<&MetricsRegistry> {
        self.inner.as_deref().map(|inner| &inner.metrics)
    }

    pub fn snaps(&self) -> &[Snap] {
        match &self.inner {
            Some(inner) => inner.metrics.snaps(),
            None => &[],
        }
    }

    /// Metrics JSONL for this sink alone (empty when disabled).
    pub fn to_jsonl(&self) -> String {
        match &self.inner {
            Some(inner) => inner.metrics.to_jsonl(),
            None => String::new(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_records_nothing() {
        let mut sink = ObsSink::disabled();
        sink.span("round", "device", 1, 0.0, 1.0);
        sink.instant("crash", "fault", 2, 0.5);
        sink.inc("round.applied", 1);
        sink.observe("round.duration", 1.0);
        sink.snapshot(1);
        assert!(!sink.is_enabled());
        assert!(sink.events().is_empty());
        assert!(sink.snaps().is_empty());
        assert!(sink.metrics().is_none());
        assert_eq!(sink.to_jsonl(), "");
    }

    #[test]
    fn enabled_stamps_pid_and_snapshots_cell() {
        let mut sink = ObsSink::enabled(3);
        sink.span("round", "device", 1, 0.0, 1.0);
        sink.instant_label("quarantine", "guard", 2, 0.5, "verdict", "rejected");
        sink.inc("agg.quarantined", 1);
        sink.snapshot(7);
        assert_eq!(sink.events().len(), 2);
        assert!(sink.events().iter().all(|e| e.pid == 3));
        assert_eq!(sink.snaps().len(), 1);
        assert_eq!(sink.snaps()[0].cell, 3);
        assert_eq!(sink.snaps()[0].period, 7);
        assert_eq!(sink.metrics().unwrap().counter("agg.quarantined"), 1);
    }
}
