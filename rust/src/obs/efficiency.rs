//! Learning-efficiency derivations over an audit JSONL dump — the
//! `feel audit` backend.
//!
//! Consumes the ledger rows `obs/audit.rs` exports and derives, per
//! period: realized learning efficiency (loss decrement ÷ simulated
//! seconds, the paper's eq. 15 measured), the predicted compute/comm/wait
//! decomposition of the uplink subperiod, bandwidth utilization (sum of
//! TDMA slot shares), and straggler regret (realized ÷ predicted period
//! time). The run-level summary aggregates these plus outcome tallies.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{anyhow, bail, Result};

use crate::util::json::Json;

/// One period row's derived quantities.
#[derive(Clone, Debug)]
pub struct PeriodEfficiency {
    pub period: u64,
    pub cell: usize,
    pub b_total: f64,
    pub applied: f64,
    /// realized learning efficiency: loss decrement / realized seconds
    pub efficiency: f64,
    /// predicted end-to-end period latency
    pub t_pred: f64,
    /// realized period duration
    pub t_real: f64,
    /// straggler regret: realized / predicted period time (1.0 = the
    /// clean barrier case; 0.0 when the prediction is degenerate)
    pub regret: f64,
    /// sum of participants' TDMA slot shares (1.0 = full frame used)
    pub bw_util: f64,
    /// participant-summed predicted uplink seconds, decomposed
    pub compute_secs: f64,
    pub comm_secs: f64,
    pub wait_secs: f64,
}

/// Outcome tallies across every device row.
#[derive(Clone, Copy, Debug, Default)]
pub struct OutcomeTally {
    pub applied: u64,
    pub quarantined: u64,
    pub dropped: u64,
    pub crashed: u64,
    pub late: u64,
    pub pending: u64,
}

fn f(v: &Json, key: &str) -> f64 {
    v.get(key).and_then(Json::as_f64).unwrap_or(0.0)
}

/// Derive one period row. The decomposition charges each participant its
/// predicted compute and upload seconds (upload capped at the makespan
/// headroom, so a slotless +inf renders as "the rest of the subperiod")
/// and books the remainder of the uplink makespan as wait.
fn derive_period(v: &Json) -> PeriodEfficiency {
    let t_pred = f(v, "p_t_period");
    let t_real = f(v, "r_duration");
    let loss_dec = f(v, "loss_dec");
    let t_up = f(v, "p_t_up");
    let mut bw_util = 0.0;
    let mut compute_secs = 0.0;
    let mut comm_secs = 0.0;
    let mut wait_secs = 0.0;
    if let Some(devices) = v.get("devices").and_then(Json::as_arr) {
        for d in devices {
            bw_util += f(d, "p_slot");
            let compute = f(d, "p_compute").min(t_up);
            // null p_comm (no slot) reads as 0.0 and is then capped into
            // the headroom — an infinite upload never arrives, so its
            // whole remaining subperiod is communication stall
            let comm = match d.get("p_comm").and_then(Json::as_f64) {
                Some(c) => c.min((t_up - compute).max(0.0)),
                None => (t_up - compute).max(0.0),
            };
            compute_secs += compute;
            comm_secs += comm;
            wait_secs += (t_up - compute - comm).max(0.0);
        }
    }
    PeriodEfficiency {
        period: f(v, "period") as u64,
        cell: f(v, "cell") as usize,
        b_total: f(v, "b_total"),
        applied: f(v, "applied"),
        efficiency: if t_real > 0.0 { loss_dec / t_real } else { 0.0 },
        t_pred,
        t_real,
        regret: if t_pred > 0.0 { t_real / t_pred } else { 0.0 },
        bw_util,
        compute_secs,
        comm_secs,
        wait_secs,
    }
}

fn tally_outcomes(v: &Json, tally: &mut OutcomeTally, stale: &mut (f64, u64)) {
    if let Some(devices) = v.get("devices").and_then(Json::as_arr) {
        for d in devices {
            match d.get("outcome").and_then(Json::as_str) {
                Some("applied") => tally.applied += 1,
                Some("quarantined") => tally.quarantined += 1,
                Some("dropped") => tally.dropped += 1,
                Some("crashed") => tally.crashed += 1,
                Some("late") => tally.late += 1,
                _ => tally.pending += 1,
            }
            if let Some(s) = d.get("staleness").and_then(Json::as_f64) {
                stale.0 += s;
                stale.1 += 1;
            }
        }
    }
}

/// `feel audit` backend: parse an audit JSONL dump into a per-period table
/// plus a run-level efficiency summary (the `feel report` rendering
/// style).
pub fn summarize_audit_jsonl(src: &str) -> Result<String> {
    let mut periods: Vec<PeriodEfficiency> = Vec::new();
    let mut tally = OutcomeTally::default();
    let mut stale = (0.0f64, 0u64);
    let mut cells: BTreeMap<usize, ()> = BTreeMap::new();
    let mut cloud_merges = 0usize;
    for (i, line) in src.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        let v = Json::parse(line).map_err(|e| anyhow!("audit line {}: {e}", i + 1))?;
        match v.get("kind").and_then(Json::as_str) {
            Some("period") => {
                let p = derive_period(&v);
                cells.insert(p.cell, ());
                tally_outcomes(&v, &mut tally, &mut stale);
                periods.push(p);
            }
            Some("cloud") => cloud_merges += 1,
            _ => bail!("audit line {}: missing kind", i + 1),
        }
    }
    if periods.is_empty() {
        bail!("no audit period rows found");
    }

    let mut out = String::new();
    let _ = writeln!(
        out,
        "audit report — {} period(s), {} cell(s), {cloud_merges} cloud merge(s)",
        periods.len(),
        cells.len(),
    );
    let _ = writeln!(
        out,
        "\n  {:>6} {:>5} {:>8} {:>8} {:>12} {:>11} {:>11} {:>8} {:>8}",
        "period", "cell", "b_total", "applied", "efficiency", "t_pred", "t_real", "regret",
        "bw_util"
    );
    for p in &periods {
        let _ = writeln!(
            out,
            "  {:>6} {:>5} {:>8.0} {:>8.0} {:>12.6} {:>11.6} {:>11.6} {:>8.3} {:>8.3}",
            p.period, p.cell, p.b_total, p.applied, p.efficiency, p.t_pred, p.t_real, p.regret,
            p.bw_util
        );
    }

    let n = periods.len() as f64;
    let eff_mean = periods.iter().map(|p| p.efficiency).sum::<f64>() / n;
    let regret_mean = periods.iter().map(|p| p.regret).sum::<f64>() / n;
    let regret_max = periods.iter().map(|p| p.regret).fold(0.0f64, f64::max);
    let bw_mean = periods.iter().map(|p| p.bw_util).sum::<f64>() / n;
    let compute: f64 = periods.iter().map(|p| p.compute_secs).sum();
    let comm: f64 = periods.iter().map(|p| p.comm_secs).sum();
    let wait: f64 = periods.iter().map(|p| p.wait_secs).sum();
    let up_total = (compute + comm + wait).max(f64::MIN_POSITIVE);
    let _ = writeln!(out, "\nrun summary:");
    let _ = writeln!(
        out,
        "  {:<24} {eff_mean:>12.6}   (loss decrement / simulated second)",
        "efficiency (mean)"
    );
    let _ = writeln!(
        out,
        "  {:<24} compute {:>5.1}%  comm {:>5.1}%  wait {:>5.1}%   (predicted uplink budget)",
        "time decomposition",
        100.0 * compute / up_total,
        100.0 * comm / up_total,
        100.0 * wait / up_total,
    );
    let _ = writeln!(
        out,
        "  {:<24} mean {regret_mean:>8.3}  max {regret_max:>8.3}   (realized / predicted period)",
        "straggler regret"
    );
    let _ = writeln!(
        out,
        "  {:<24} {bw_mean:>12.3}   (mean sum of TDMA slot shares)",
        "bandwidth utilization"
    );
    let _ = writeln!(
        out,
        "  {:<24} applied {}  quarantined {}  dropped {}  crashed {}  late {}  pending {}",
        "outcomes",
        tally.applied,
        tally.quarantined,
        tally.dropped,
        tally.crashed,
        tally.late,
        tally.pending,
    );
    if stale.1 > 0 {
        let _ = writeln!(
            out,
            "  {:<24} {:>12.3}   (over {} stale appl{})",
            "staleness (mean)",
            stale.0 / stale.1 as f64,
            stale.1,
            if stale.1 == 1 { "ication" } else { "ications" },
        );
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::scheme::Plan;
    use crate::obs::audit::{AuditLedger, Outcome};
    use crate::opt::types::PredictedTiming;

    fn ledger() -> AuditLedger {
        let plan = Plan {
            batches: vec![10, 20],
            t_period: 1.2,
            t_up: 1.0,
            t_down: 0.2,
            finish: vec![0.9, 1.0],
            predicted: vec![
                PredictedTiming { compute: 0.5, comm: 0.4, slot_share: 0.5 },
                PredictedTiming { compute: 0.7, comm: 0.3, slot_share: 0.5 },
            ],
            predicted_efficiency: Some(0.05),
        };
        let mut led = AuditLedger::new(0);
        led.begin(1, 0.0, &plan);
        led.arrival(0, 0.9);
        led.outcome(0, Outcome::Applied);
        led.arrival(1, 1.0);
        led.outcome(1, Outcome::Applied);
        led.end(1.2, 0.012, 30, 2);
        led
    }

    #[test]
    fn derives_efficiency_regret_and_decomposition() {
        let report = summarize_audit_jsonl(&ledger().to_jsonl()).unwrap();
        assert!(report.contains("1 period(s), 1 cell(s), 0 cloud merge(s)"), "{report}");
        // efficiency = 0.012 / 1.2 = 0.01; zero regret case = ratio 1.000
        assert!(report.contains("0.010000"), "{report}");
        assert!(report.contains("1.000"), "{report}");
        // full frame: both devices hold half the slots
        assert!(report.contains("bandwidth utilization"), "{report}");
        assert!(report.contains("applied 2"), "{report}");
        // decomposition covers the whole predicted uplink budget:
        // compute 0.5 + 0.7, comm 0.4 + 0.3, wait 0.1 + 0.0 over 2 s
        assert!(report.contains("compute  60.0%"), "{report}");
        assert!(report.contains("comm  35.0%"), "{report}");
        assert!(report.contains("wait   5.0%"), "{report}");
    }

    #[test]
    fn counts_cloud_rows_and_rejects_garbage() {
        let mut led = ledger();
        led.cloud_merge(1, 1.2, 3);
        let report = summarize_audit_jsonl(&led.to_jsonl()).unwrap();
        assert!(report.contains("1 cloud merge(s)"), "{report}");
        assert!(summarize_audit_jsonl("").is_err());
        assert!(summarize_audit_jsonl("not json\n").is_err());
        assert!(summarize_audit_jsonl("{\"kind\":\"cloud\"}\n").is_err()); // no periods
        assert!(summarize_audit_jsonl("{\"period\":1}\n").is_err()); // no kind
    }
}
