//! Metrics registry: named counters, gauges, and fixed-bucket histograms.
//!
//! Counters and gauges are cumulative over a run; histograms use validated
//! strictly-ascending bucket bounds plus an implicit +inf overflow bucket.
//! `MetricsRegistry::snapshot` freezes the registry into one JSONL line per
//! `(period, cell)`; `summarize_jsonl` is the `feel report` backend that
//! turns a JSONL dump back into a per-run table (totals per counter,
//! p50/p95/max per histogram).
//!
//! Wall-clock derived values (e.g. `wall.solver_secs`) may flow into the
//! metrics JSONL — it is a measurement artifact, not a byte-pinned one. The
//! *trace* path must stay byte-identical across thread counts, so only
//! simulated-time quantities ever reach the tracer (`obs::trace`).

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{anyhow, bail, Result};

use crate::util::json::{num, obj, Json};

/// Exponentially-spaced bucket upper bounds: `start * factor^i`.
pub fn exponential_bounds(start: f64, factor: f64, count: usize) -> Vec<f64> {
    let mut out = Vec::with_capacity(count);
    let mut b = start;
    for _ in 0..count {
        out.push(b);
        b *= factor;
    }
    out
}

/// Default histogram bounds: 26 doubling buckets from 1e-3 (~1e-3 .. ~3.4e4)
/// — wide enough for simulated seconds, staleness counts, and batch tallies.
fn default_bounds() -> Vec<f64> {
    exponential_bounds(1e-3, 2.0, 26)
}

fn jnum(v: f64) -> Json {
    if v.is_finite() {
        Json::Num(v)
    } else {
        Json::Null
    }
}

/// Fixed-bucket histogram: `counts[i]` tallies observations with
/// `v <= bounds[i]` (first matching bucket); `counts[bounds.len()]` is the
/// +inf overflow bucket.
#[derive(Clone, Debug)]
pub struct Histogram {
    bounds: Vec<f64>,
    counts: Vec<u64>,
    total: u64,
    sum: f64,
    min: f64,
    max: f64,
}

impl Histogram {
    /// Bounds must be non-empty, finite, and strictly ascending (the
    /// overflow bucket is implicit — never pass +inf).
    pub fn new(bounds: Vec<f64>) -> Result<Histogram> {
        if bounds.is_empty() {
            bail!("histogram needs at least one bucket bound");
        }
        if bounds.iter().any(|b| !b.is_finite()) {
            bail!("histogram bounds must be finite (the overflow bucket is implicit)");
        }
        for w in bounds.windows(2) {
            if w[0] >= w[1] {
                bail!(
                    "histogram bounds must be strictly ascending: {} then {}",
                    w[0],
                    w[1]
                );
            }
        }
        let n = bounds.len() + 1;
        Ok(Histogram {
            bounds,
            counts: vec![0; n],
            total: 0,
            sum: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        })
    }

    pub fn exponential(start: f64, factor: f64, count: usize) -> Result<Histogram> {
        Histogram::new(exponential_bounds(start, factor, count))
    }

    /// Record one observation. NaN is rejected (returns `false`) rather
    /// than silently poisoning `sum`/`min`/`max`.
    pub fn record(&mut self, v: f64) -> bool {
        if v.is_nan() {
            return false;
        }
        let i = self
            .bounds
            .iter()
            .position(|b| v <= *b)
            .unwrap_or(self.bounds.len());
        self.counts[i] += 1;
        self.total += 1;
        self.sum += v;
        self.min = self.min.min(v);
        self.max = self.max.max(v);
        true
    }

    pub fn bounds(&self) -> &[f64] {
        &self.bounds
    }

    pub fn counts(&self) -> &[u64] {
        &self.counts
    }

    pub fn total(&self) -> u64 {
        self.total
    }

    pub fn sum(&self) -> f64 {
        self.sum
    }

    /// Observed extrema; 0.0 on an empty histogram.
    pub fn min(&self) -> f64 {
        if self.total == 0 {
            0.0
        } else {
            self.min
        }
    }

    pub fn max(&self) -> f64 {
        if self.total == 0 {
            0.0
        } else {
            self.max
        }
    }

    /// Upper bound of the bucket holding the `q`-quantile rank
    /// (`rank = ceil(q * total)`, clamped to `[1, total]`); the overflow
    /// bucket reports the observed max. 0.0 on an empty histogram.
    pub fn quantile(&self, q: f64) -> f64 {
        if self.total == 0 {
            return 0.0;
        }
        let rank = ((q * self.total as f64).ceil() as u64).clamp(1, self.total);
        let mut seen = 0u64;
        for (i, c) in self.counts.iter().enumerate() {
            seen += c;
            if seen >= rank {
                return if i < self.bounds.len() {
                    self.bounds[i]
                } else {
                    self.max
                };
            }
        }
        self.max
    }

    fn stats_json(&self) -> Json {
        if self.total == 0 {
            return obj(vec![("total", num(0.0))]);
        }
        obj(vec![
            ("total", num(self.total as f64)),
            ("sum", jnum(self.sum)),
            ("min", jnum(self.min)),
            ("max", jnum(self.max)),
            ("p50", jnum(self.quantile(0.5))),
            ("p95", jnum(self.quantile(0.95))),
        ])
    }
}

/// One frozen JSONL line: the cumulative registry state after `period` on
/// `cell`.
#[derive(Clone, Debug)]
pub struct Snap {
    pub period: u64,
    pub cell: usize,
    pub line: String,
}

/// Named counters, gauges, and histograms plus the per-period snapshot log.
#[derive(Clone, Debug, Default)]
pub struct MetricsRegistry {
    counters: BTreeMap<&'static str, u64>,
    gauges: BTreeMap<&'static str, f64>,
    hists: BTreeMap<&'static str, Histogram>,
    snaps: Vec<Snap>,
}

impl MetricsRegistry {
    pub fn inc(&mut self, name: &'static str, by: u64) {
        *self.counters.entry(name).or_insert(0) += by;
    }

    pub fn gauge(&mut self, name: &'static str, v: f64) {
        self.gauges.insert(name, v);
    }

    /// Record into `name`'s histogram, creating it with the default
    /// exponential buckets on first touch. NaN observations are dropped.
    /// An observation past the last bound still records (into the +inf
    /// overflow bucket) but also bumps the `obs.hist_overflow` counter, so
    /// a silently saturated histogram is diagnosable from `feel report`.
    pub fn observe(&mut self, name: &'static str, v: f64) {
        let h = self
            .hists
            .entry(name)
            // lint: allow(panic-path): default_bounds() is a fixed ascending literal
            .or_insert_with(|| Histogram::new(default_bounds()).expect("default bounds are valid"));
        let overflowed = match h.bounds().last() {
            Some(&top) => v > top, // false for NaN, true for +inf
            None => false,
        };
        if h.record(v) && overflowed {
            self.inc("obs.hist_overflow", 1);
        }
    }

    /// Pre-register `name` with custom buckets (before any `observe`).
    pub fn register_hist(&mut self, name: &'static str, hist: Histogram) {
        self.hists.insert(name, hist);
    }

    pub fn counter(&self, name: &str) -> u64 {
        self.counters.get(name).copied().unwrap_or(0)
    }

    pub fn hist(&self, name: &str) -> Option<&Histogram> {
        self.hists.get(name)
    }

    /// Freeze the cumulative state into one JSONL line.
    pub fn snapshot(&mut self, period: u64, cell: usize) {
        let counters = Json::Obj(
            self.counters
                .iter()
                .map(|(k, v)| (k.to_string(), num(*v as f64)))
                .collect(),
        );
        let gauges = Json::Obj(
            self.gauges
                .iter()
                .map(|(k, v)| (k.to_string(), jnum(*v)))
                .collect(),
        );
        let hists = Json::Obj(
            self.hists
                .iter()
                .map(|(k, h)| (k.to_string(), h.stats_json()))
                .collect(),
        );
        let line = obj(vec![
            ("period", num(period as f64)),
            ("cell", num(cell as f64)),
            ("counters", counters),
            ("gauges", gauges),
            ("hists", hists),
        ])
        .to_string();
        self.snaps.push(Snap { period, cell, line });
    }

    pub fn snaps(&self) -> &[Snap] {
        &self.snaps
    }

    pub fn to_jsonl(&self) -> String {
        let mut out = String::new();
        for snap in &self.snaps {
            out.push_str(&snap.line);
            out.push('\n');
        }
        out
    }
}

/// Merge per-cell snapshot streams into one JSONL document ordered by
/// `(period, cell)`. `sort_by_key` is stable, so the merged stream is a
/// pure function of the inputs.
pub fn merge_snaps(parts: &[&[Snap]]) -> String {
    let mut all: Vec<&Snap> = parts.iter().flat_map(|p| p.iter()).collect();
    all.sort_by_key(|snap| (snap.period, snap.cell));
    let mut out = String::new();
    for snap in all {
        out.push_str(&snap.line);
        out.push('\n');
    }
    out
}

/// `feel report` backend: summarize a metrics JSONL dump into a per-run
/// table. Snapshots are cumulative, so totals come from each cell's *last*
/// snapshot; counters are summed across cells, gauges and histograms are
/// listed per cell when more than one is present.
pub fn summarize_jsonl(src: &str) -> Result<String> {
    let mut last: BTreeMap<usize, Json> = BTreeMap::new();
    let mut n = 0usize;
    for (i, line) in src.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        let v = Json::parse(line).map_err(|e| anyhow!("metrics line {}: {e}", i + 1))?;
        let cell = v
            .get("cell")
            .and_then(Json::as_usize)
            .ok_or_else(|| anyhow!("metrics line {}: missing cell", i + 1))?;
        last.insert(cell, v);
        n += 1;
    }
    if last.is_empty() {
        bail!("no metric snapshots found");
    }
    let multi = last.len() > 1;
    let label = |name: &str, cell: usize| {
        if multi {
            format!("{name}[cell {cell}]")
        } else {
            name.to_string()
        }
    };

    let mut out = String::new();
    let _ = writeln!(out, "observability report — {n} snapshots, {} cell(s)", last.len());

    let mut totals: BTreeMap<String, f64> = BTreeMap::new();
    for v in last.values() {
        if let Some(cs) = v.get("counters").and_then(Json::as_obj) {
            for (k, c) in cs {
                *totals.entry(k.clone()).or_insert(0.0) += c.as_f64().unwrap_or(0.0);
            }
        }
    }
    if !totals.is_empty() {
        let _ = writeln!(out, "\ncounters (totals):");
        for (k, v) in &totals {
            let _ = writeln!(out, "  {k:<32} {v:>12.0}");
        }
    }
    if let Some(&n) = totals.get("obs.hist_overflow") {
        if n > 0.0 {
            let _ = writeln!(
                out,
                "\nwarning: {n:.0} observation(s) landed in a +inf overflow bucket — \
                 histogram bounds may be saturated"
            );
        }
    }

    let mut wrote_gauge_header = false;
    for (cell, v) in &last {
        if let Some(gs) = v.get("gauges").and_then(Json::as_obj) {
            for (k, g) in gs {
                if !wrote_gauge_header {
                    let _ = writeln!(out, "\ngauges (last snapshot):");
                    wrote_gauge_header = true;
                }
                let name = label(k, *cell);
                match g.as_f64() {
                    Some(x) => {
                        let _ = writeln!(out, "  {name:<32} {x:>14.6}");
                    }
                    None => {
                        let _ = writeln!(out, "  {name:<32} {:>14}", "nan");
                    }
                }
            }
        }
    }

    let mut wrote_hist_header = false;
    for (cell, v) in &last {
        if let Some(hs) = v.get("hists").and_then(Json::as_obj) {
            for (k, h) in hs {
                if !wrote_hist_header {
                    let _ = writeln!(out, "\nhistograms (count / p50 / p95 / max):");
                    wrote_hist_header = true;
                }
                let name = label(k, *cell);
                let total = h.get("total").and_then(Json::as_f64).unwrap_or(0.0);
                let p50 = h.get("p50").and_then(Json::as_f64).unwrap_or(0.0);
                let p95 = h.get("p95").and_then(Json::as_f64).unwrap_or(0.0);
                let max = h.get("max").and_then(Json::as_f64).unwrap_or(0.0);
                let _ = writeln!(
                    out,
                    "  {name:<32} {total:>8.0} {p50:>12.6} {p95:>12.6} {max:>12.6}"
                );
            }
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_boundaries() {
        let mut h = Histogram::new(vec![0.0, 1.0, 2.0]).unwrap();
        assert!(h.record(0.0)); // exactly the first bound → bucket 0
        assert!(h.record(-0.5)); // below every bound → bucket 0
        assert!(h.record(1.0)); // exactly an interior bound → bucket 1
        assert!(h.record(1.5)); // between bounds → bucket 2
        assert!(h.record(2.0)); // exactly the last bound → bucket 2
        assert!(h.record(3.0)); // past the last bound → overflow
        assert!(h.record(f64::INFINITY)); // +inf → overflow
        assert_eq!(h.counts(), &[2, 1, 2, 2]);
        assert_eq!(h.total(), 7);
        assert_eq!(h.min(), -0.5);
        assert_eq!(h.max(), f64::INFINITY);
    }

    #[test]
    fn nan_rejected() {
        let mut h = Histogram::new(vec![1.0]).unwrap();
        assert!(!h.record(f64::NAN));
        assert_eq!(h.total(), 0);
        assert_eq!(h.counts(), &[0, 0]);
        assert!(h.record(0.5));
        assert_eq!(h.total(), 1);
        assert!(h.sum().is_finite());
    }

    #[test]
    fn invalid_bounds_rejected() {
        assert!(Histogram::new(vec![]).is_err());
        assert!(Histogram::new(vec![1.0, 1.0]).is_err());
        assert!(Histogram::new(vec![2.0, 1.0]).is_err());
        assert!(Histogram::new(vec![1.0, f64::INFINITY]).is_err());
        assert!(Histogram::new(vec![f64::NAN]).is_err());
    }

    #[test]
    fn quantiles_walk_buckets() {
        let mut h = Histogram::new(vec![1.0, 2.0, 4.0]).unwrap();
        assert_eq!(h.quantile(0.5), 0.0); // empty
        for _ in 0..9 {
            h.record(0.5);
        }
        h.record(10.0); // overflow
        assert_eq!(h.quantile(0.5), 1.0);
        assert_eq!(h.quantile(0.89), 1.0);
        assert_eq!(h.quantile(0.95), 10.0); // overflow bucket reports max
        assert_eq!(h.quantile(0.0), 1.0); // rank clamps to 1
        assert_eq!(h.quantile(1.0), 10.0);
    }

    #[test]
    fn exponential_bounds_shape() {
        let b = exponential_bounds(1.0, 2.0, 4);
        assert_eq!(b, vec![1.0, 2.0, 4.0, 8.0]);
        assert!(Histogram::exponential(1e-3, 2.0, 26).is_ok());
    }

    #[test]
    fn registry_snapshot_lines_parse() {
        let mut m = MetricsRegistry::default();
        m.inc("round.applied", 3);
        m.gauge("train.loss", 0.25);
        m.gauge("bad.gauge", f64::NAN); // must render as null, not NaN
        m.observe("round.duration", 1.5);
        m.observe("round.duration", f64::NAN); // dropped
        m.snapshot(1, 0);
        m.inc("round.applied", 2);
        m.snapshot(2, 0);
        assert_eq!(m.counter("round.applied"), 5);
        assert_eq!(m.hist("round.duration").unwrap().total(), 1);
        let jsonl = m.to_jsonl();
        let lines: Vec<&str> = jsonl.lines().collect();
        assert_eq!(lines.len(), 2);
        for line in &lines {
            let v = Json::parse(line).unwrap();
            assert!(v.get("counters").is_some());
        }
        let v2 = Json::parse(lines[1]).unwrap();
        assert_eq!(
            v2.get("counters").unwrap().get("round.applied").unwrap().as_f64(),
            Some(5.0)
        );
        assert_eq!(v2.get("gauges").unwrap().get("bad.gauge"), Some(&Json::Null));
    }

    #[test]
    fn merge_orders_by_period_then_cell() {
        let mk = |period, cell| Snap {
            period,
            cell,
            line: format!("{{\"cell\":{cell},\"period\":{period}}}"),
        };
        let a = vec![mk(1, 0), mk(2, 0)];
        let b = vec![mk(1, 1), mk(2, 1)];
        let merged = merge_snaps(&[&a, &b]);
        let cells: Vec<&str> = merged.lines().collect();
        assert_eq!(
            cells,
            vec![
                "{\"cell\":0,\"period\":1}",
                "{\"cell\":1,\"period\":1}",
                "{\"cell\":0,\"period\":2}",
                "{\"cell\":1,\"period\":2}",
            ]
        );
    }

    #[test]
    fn observe_counts_overflow_and_report_warns() {
        let mut m = MetricsRegistry::default();
        m.register_hist("lat", Histogram::new(vec![1.0, 2.0]).unwrap());
        m.observe("lat", 0.5); // in range
        m.observe("lat", 2.0); // exactly the last bound: not overflow
        assert_eq!(m.counter("obs.hist_overflow"), 0);
        m.observe("lat", 3.0); // past the last bound
        m.observe("lat", f64::INFINITY); // +inf overflows too
        m.observe("lat", f64::NAN); // dropped, never counted
        assert_eq!(m.counter("obs.hist_overflow"), 2);
        assert_eq!(m.hist("lat").unwrap().total(), 4);
        m.snapshot(1, 0);
        let report = summarize_jsonl(&m.to_jsonl()).unwrap();
        assert!(report.contains("obs.hist_overflow"), "{report}");
        assert!(report.contains("warning: 2 observation(s)"), "{report}");
        // a clean run carries no warning
        let mut clean = MetricsRegistry::default();
        clean.observe("lat", 0.5);
        clean.snapshot(1, 0);
        let report = summarize_jsonl(&clean.to_jsonl()).unwrap();
        assert!(!report.contains("warning"), "{report}");
    }

    #[test]
    fn report_summarizes_last_snapshot() {
        let mut m = MetricsRegistry::default();
        m.inc("agg.quarantined", 1);
        m.observe("round.duration", 2.0);
        m.gauge("train.loss", 1.5);
        m.snapshot(1, 0);
        m.inc("agg.quarantined", 4);
        m.snapshot(2, 0);
        let report = summarize_jsonl(&m.to_jsonl()).unwrap();
        assert!(report.contains("2 snapshots"));
        assert!(report.contains("agg.quarantined"));
        assert!(report.contains("5")); // cumulative total from the last line
        assert!(report.contains("round.duration"));
        assert!(summarize_jsonl("").is_err());
        assert!(summarize_jsonl("not json\n").is_err());
    }
}
