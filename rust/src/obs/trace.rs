//! Event tracer on the simulated clock, exported as Chrome trace-event JSON.
//!
//! Events carry simulated-seconds timestamps (`SimClock` time — never the
//! host wall clock), a pid (the cell id; the hier cloud lane is
//! pid = #cells) and a tid (0 = the coordinator lane, device d = tid d+1).
//! `chrome_trace` renders a buffer in the Trace Event Format that
//! chrome://tracing and Perfetto load directly: `ph:"X"` complete spans,
//! `ph:"i"` instants, plus `ph:"M"` metadata naming each process/thread
//! lane.
//!
//! Byte-determinism: rendering walks events in buffer order and every JSON
//! object keeps sorted key order (`util::json::Json::Obj` is a `BTreeMap`),
//! so equal buffers render to equal bytes. Buffers themselves are only ever
//! filled on the coordinator thread and merged in fixed cell order
//! (`merge_traces` is a stable sort), so the same seed yields byte-identical
//! trace files at any thread count.

use std::collections::BTreeSet;

use crate::util::json::{num, obj, s, Json};

/// One trace event: a complete span (`dur = Some`) or an instant.
#[derive(Clone, Debug, PartialEq)]
pub struct TraceEvent {
    /// Simulated seconds.
    pub ts: f64,
    /// Span duration in simulated seconds; `None` renders as an instant.
    pub dur: Option<f64>,
    /// Cell id (flat runs: 0); the hier cloud aggregator uses pid = #cells.
    pub pid: usize,
    /// 0 = coordinator lane; device d = tid d + 1.
    pub tid: usize,
    pub name: &'static str,
    pub cat: &'static str,
    /// Numeric `args` shown in the trace viewer's detail pane.
    pub args: Vec<(&'static str, f64)>,
    /// String `args` (e.g. a quarantine verdict name).
    pub labels: Vec<(&'static str, &'static str)>,
}

impl TraceEvent {
    pub fn span(
        name: &'static str,
        cat: &'static str,
        pid: usize,
        tid: usize,
        ts: f64,
        dur: f64,
    ) -> TraceEvent {
        TraceEvent {
            ts,
            dur: Some(dur),
            pid,
            tid,
            name,
            cat,
            args: Vec::new(),
            labels: Vec::new(),
        }
    }

    pub fn instant(
        name: &'static str,
        cat: &'static str,
        pid: usize,
        tid: usize,
        ts: f64,
    ) -> TraceEvent {
        TraceEvent {
            ts,
            dur: None,
            pid,
            tid,
            name,
            cat,
            args: Vec::new(),
            labels: Vec::new(),
        }
    }

    pub fn arg(mut self, key: &'static str, value: f64) -> TraceEvent {
        self.args.push((key, value));
        self
    }

    pub fn label(mut self, key: &'static str, value: &'static str) -> TraceEvent {
        self.labels.push((key, value));
        self
    }
}

/// Concatenate per-cell buffers (callers pass them in fixed cell order) and
/// stable-sort by timestamp: ties keep the input order, so the merged buffer
/// is a pure function of the inputs — never of thread scheduling.
pub fn merge_traces(parts: Vec<Vec<TraceEvent>>) -> Vec<TraceEvent> {
    let mut all: Vec<TraceEvent> = parts.into_iter().flatten().collect();
    all.sort_by(|a, b| a.ts.total_cmp(&b.ts));
    all
}

fn jnum(v: f64) -> Json {
    if v.is_finite() {
        Json::Num(v)
    } else {
        Json::Null
    }
}

/// Render a buffer as a Chrome trace-event JSON document (object form, with
/// `displayTimeUnit`), timestamps in microseconds. `cloud_pid` names that
/// process lane "cloud" instead of "cell N".
pub fn chrome_trace(events: &[TraceEvent], cloud_pid: Option<usize>) -> String {
    let mut pids = BTreeSet::new();
    let mut lanes = BTreeSet::new();
    for e in events {
        pids.insert(e.pid);
        lanes.insert((e.pid, e.tid));
    }
    let mut out = Vec::with_capacity(events.len() + pids.len() + lanes.len());
    for p in &pids {
        let pname = if cloud_pid == Some(*p) {
            "cloud".to_string()
        } else {
            format!("cell {p}")
        };
        out.push(obj(vec![
            ("ph", s("M")),
            ("name", s("process_name")),
            ("pid", num(*p as f64)),
            ("tid", num(0.0)),
            ("args", obj(vec![("name", Json::Str(pname))])),
        ]));
    }
    for (p, t) in &lanes {
        let tname = if *t == 0 {
            "coordinator".to_string()
        } else {
            format!("device {}", t - 1)
        };
        out.push(obj(vec![
            ("ph", s("M")),
            ("name", s("thread_name")),
            ("pid", num(*p as f64)),
            ("tid", num(*t as f64)),
            ("args", obj(vec![("name", Json::Str(tname))])),
        ]));
    }
    for e in events {
        let mut a: Vec<(&str, Json)> = Vec::new();
        for (k, v) in &e.args {
            a.push((k, jnum(*v)));
        }
        for (k, v) in &e.labels {
            a.push((k, s(v)));
        }
        let mut fields = vec![
            ("name", s(e.name)),
            ("cat", s(e.cat)),
            ("pid", num(e.pid as f64)),
            ("tid", num(e.tid as f64)),
            ("ts", jnum(e.ts * 1e6)),
        ];
        match e.dur {
            Some(d) => {
                fields.push(("ph", s("X")));
                fields.push(("dur", jnum(d * 1e6)));
            }
            None => {
                fields.push(("ph", s("i")));
                fields.push(("s", s("t")));
            }
        }
        if !a.is_empty() {
            fields.push(("args", obj(a)));
        }
        out.push(obj(fields));
    }
    obj(vec![
        ("displayTimeUnit", s("ms")),
        ("traceEvents", Json::Arr(out)),
    ])
    .to_string()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_spans_and_instants() {
        let events = vec![
            TraceEvent::span("round", "device", 0, 3, 1.5, 0.25).arg("batch", 10.0),
            TraceEvent::instant("drop", "straggler", 0, 4, 1.5).label("why", "dropout"),
        ];
        let text = chrome_trace(&events, None);
        let v = Json::parse(&text).unwrap();
        let evs = v.get("traceEvents").unwrap().as_arr().unwrap();
        // 1 process_name + 2 thread_name metadata events precede the payload.
        assert_eq!(evs.len(), 5);
        let span = &evs[3];
        assert_eq!(span.get("ph").unwrap().as_str(), Some("X"));
        assert_eq!(span.get("ts").unwrap().as_f64(), Some(1.5e6));
        assert_eq!(span.get("dur").unwrap().as_f64(), Some(0.25e6));
        assert_eq!(
            span.get("args").unwrap().get("batch").unwrap().as_f64(),
            Some(10.0)
        );
        let inst = &evs[4];
        assert_eq!(inst.get("ph").unwrap().as_str(), Some("i"));
        assert_eq!(
            inst.get("args").unwrap().get("why").unwrap().as_str(),
            Some("dropout")
        );
    }

    #[test]
    fn lane_metadata_names_cells_devices_and_cloud() {
        let events = vec![
            TraceEvent::instant("a", "c", 0, 0, 0.0),
            TraceEvent::instant("b", "c", 2, 1, 0.0),
        ];
        let text = chrome_trace(&events, Some(2));
        assert!(text.contains("\"cell 0\""));
        assert!(text.contains("\"cloud\""));
        assert!(text.contains("\"coordinator\""));
        assert!(text.contains("\"device 0\""));
    }

    #[test]
    fn merge_is_stable_on_ties() {
        let a = vec![
            TraceEvent::instant("a0", "c", 0, 0, 1.0),
            TraceEvent::instant("a1", "c", 0, 0, 3.0),
        ];
        let b = vec![TraceEvent::instant("b0", "c", 1, 0, 1.0)];
        let merged = merge_traces(vec![a, b]);
        let names: Vec<&str> = merged.iter().map(|e| e.name).collect();
        // Equal timestamps keep cell order: a0 (cell 0) before b0 (cell 1).
        assert_eq!(names, vec!["a0", "b0", "a1"]);
    }

    #[test]
    fn rendering_is_deterministic_and_valid_json() {
        let make = || {
            vec![
                TraceEvent::span("round", "device", 1, 2, 0.5, 1.0).arg("w", 2.0),
                TraceEvent::instant("crash", "fault", 1, 3, 0.5),
            ]
        };
        let t1 = chrome_trace(&make(), None);
        let t2 = chrome_trace(&make(), None);
        assert_eq!(t1, t2);
        assert!(Json::parse(&t1).is_ok());
    }

    #[test]
    fn non_finite_args_render_as_null_not_invalid_json() {
        let events = vec![TraceEvent::instant("x", "c", 0, 0, 0.0).arg("bad", f64::NAN)];
        let text = chrome_trace(&events, None);
        let v = Json::parse(&text).unwrap();
        let evs = v.get("traceEvents").unwrap().as_arr().unwrap();
        assert_eq!(evs.last().unwrap().get("args").unwrap().get("bad"), Some(&Json::Null));
    }
}
