//! Deterministic PRNG substrate (no external crates are available offline).
//!
//! PCG-XSH-RR 64/32 core (O'Neill 2014) with helpers for the distributions
//! the wireless simulator needs: uniform, standard normal (Box–Muller),
//! exponential, and Rayleigh. Every stochastic component in the crate takes
//! a `Pcg` seeded from the experiment config, so whole experiments replay
//! bit-identically.

/// PCG-XSH-RR 64/32: 64-bit LCG state, 32-bit output, period 2^64.
#[derive(Clone, Debug)]
pub struct Pcg {
    state: u64,
    inc: u64,
}

const PCG_MULT: u64 = 6364136223846793005;

impl Pcg {
    /// Seed with an arbitrary value; `stream` selects an independent
    /// sequence (distinct streams never collide).
    pub fn new(seed: u64, stream: u64) -> Self {
        let mut rng = Pcg { state: 0, inc: (stream << 1) | 1 };
        rng.next_u32();
        rng.state = rng.state.wrapping_add(seed);
        rng.next_u32();
        rng
    }

    /// Convenience single-stream constructor.
    pub fn seeded(seed: u64) -> Self {
        Self::new(seed, 0xda3e_39cb_94b9_5bdb)
    }

    /// Derive a child RNG for subsystem `tag` (stable, collision-free fork).
    pub fn fork(&mut self, tag: u64) -> Pcg {
        let s = self.next_u64();
        Pcg::new(s ^ tag.wrapping_mul(0x9e37_79b9_7f4a_7c15), tag)
    }

    /// Counter-derived stream for device `device` in period `period` of a
    /// run seeded with `seed`. Unlike `fork`, no RNG state is consumed:
    /// the stream depends only on the three coordinates, so per-device
    /// sampling is identical no matter which thread runs the device or in
    /// which order the fleet executes (the exec-engine invariant).
    pub fn for_device(seed: u64, period: u64, device: u64) -> Pcg {
        let state = splitmix64(seed)
            .wrapping_add(splitmix64(period.wrapping_mul(0xa24b_aed4_963e_e407)));
        Pcg::new(splitmix64(state ^ device.wrapping_mul(0x9e37_79b9_7f4a_7c15)), device)
    }

    /// The raw generator registers, for checkpoint serialization. Paired
    /// with [`Pcg::from_state`]: restoring them reproduces the stream
    /// bitwise from exactly where it left off.
    pub fn state(&self) -> (u64, u64) {
        (self.state, self.inc)
    }

    /// Rebuild a generator from [`Pcg::state`] registers verbatim — no
    /// seeding rounds, the next draw continues the checkpointed stream.
    pub fn from_state(state: u64, inc: u64) -> Pcg {
        Pcg { state, inc }
    }

    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in [0, 1) with 53-bit resolution.
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform integer in [0, n) without modulo bias (Lemire reduction).
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0, "below(0)");
        loop {
            let x = self.next_u64();
            let (hi, lo) = mul_hi_lo(x, n);
            if lo >= n || lo >= x.wrapping_neg() % n {
                return hi;
            }
        }
    }

    /// Uniform integer in [lo, hi] inclusive.
    pub fn range_u64(&mut self, lo: u64, hi: u64) -> u64 {
        assert!(lo <= hi);
        lo + self.below(hi - lo + 1)
    }

    /// Standard normal via Box–Muller (one value per call; simple > fast
    /// here — channel sampling is not a hot path).
    pub fn normal(&mut self) -> f64 {
        let u1 = loop {
            let u = self.f64();
            if u > 0.0 {
                break u;
            }
        };
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// N(mu, sigma^2).
    pub fn normal_scaled(&mut self, mu: f64, sigma: f64) -> f64 {
        mu + sigma * self.normal()
    }

    /// Exp(1) via inverse CDF.
    pub fn exponential(&mut self) -> f64 {
        let u = loop {
            let u = self.f64();
            if u > 0.0 {
                break u;
            }
        };
        -u.ln()
    }

    /// Rayleigh(sigma): amplitude |h| of a CN(0, 2*sigma^2) channel tap.
    /// `E[X^2] = 2 sigma^2`; the unit-power channel uses sigma = 1/sqrt(2).
    pub fn rayleigh(&mut self, sigma: f64) -> f64 {
        sigma * (2.0 * self.exponential()).sqrt()
    }

    /// Gamma(shape, 1) via Marsaglia–Tsang squeeze (shape >= 1) with the
    /// `Gamma(a) = Gamma(a+1) * U^(1/a)` boost below 1 — the draw the
    /// Dirichlet data partition normalizes into per-device class shares.
    pub fn gamma(&mut self, shape: f64) -> f64 {
        assert!(shape.is_finite() && shape > 0.0, "gamma shape must be positive, got {shape}");
        if shape < 1.0 {
            let u = loop {
                let u = self.f64();
                if u > 0.0 {
                    break u;
                }
            };
            return self.gamma(shape + 1.0) * u.powf(1.0 / shape);
        }
        let d = shape - 1.0 / 3.0;
        let c = 1.0 / (9.0 * d).sqrt();
        loop {
            let x = self.normal();
            let v = 1.0 + c * x;
            if v <= 0.0 {
                continue;
            }
            let v = v * v * v;
            let u = self.f64();
            // squeeze first (cheap accept), exact log test second
            if u < 1.0 - 0.0331 * x * x * x * x {
                return d * v;
            }
            if u.ln() < 0.5 * x * x + d * (1.0 - v + v.ln()) {
                return d * v;
            }
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        if xs.is_empty() {
            return;
        }
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from 0..n (partial Fisher–Yates).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n, "sample_indices k={k} > n={n}");
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.below((n - i) as u64) as usize;
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }
}

#[inline]
fn mul_hi_lo(a: u64, b: u64) -> (u64, u64) {
    let wide = (a as u128) * (b as u128);
    ((wide >> 64) as u64, wide as u64)
}

/// SplitMix64 finalizer (Steele et al. 2014) — bijective avalanche mix used
/// to turn correlated (seed, period, device) coordinates into well-separated
/// PCG seeds.
pub fn splitmix64(x: u64) -> u64 {
    let mut z = x.wrapping_add(0x9e37_79b9_7f4a_7c15);
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    z ^ (z >> 31)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_replay() {
        let mut a = Pcg::seeded(42);
        let mut b = Pcg::seeded(42);
        for _ in 0..1000 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn state_roundtrip_continues_stream() {
        let mut a = Pcg::seeded(42);
        for _ in 0..17 {
            a.next_u64();
        }
        let (s, inc) = a.state();
        let mut b = Pcg::from_state(s, inc);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn distinct_seeds_differ() {
        let mut a = Pcg::seeded(1);
        let mut b = Pcg::seeded(2);
        let same = (0..100).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 3);
    }

    #[test]
    fn for_device_is_replayable_and_distinct() {
        // same coordinates -> identical stream
        let mut a = Pcg::for_device(7, 3, 1);
        let mut b = Pcg::for_device(7, 3, 1);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        // any coordinate change -> a different stream
        for (p, d) in [(3u64, 2u64), (4, 1), (3, 0)] {
            let mut a = Pcg::for_device(7, 3, 1);
            let mut c = Pcg::for_device(7, p, d);
            let same = (0..100).filter(|_| a.next_u32() == c.next_u32()).count();
            assert!(same < 3, "period {p} device {d}");
        }
    }

    #[test]
    fn splitmix_avalanches() {
        // neighbouring inputs land far apart
        let a = splitmix64(0);
        let b = splitmix64(1);
        assert_ne!(a, b);
        assert!((a ^ b).count_ones() > 10);
    }

    #[test]
    fn fork_is_independent() {
        let mut root = Pcg::seeded(7);
        let mut c1 = root.fork(1);
        let mut c2 = root.fork(2);
        let same = (0..100).filter(|_| c1.next_u32() == c2.next_u32()).count();
        assert!(same < 3);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Pcg::seeded(3);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    #[cfg_attr(miri, ignore)] // statistical sweep, far too slow under miri
    fn below_unbiased_small() {
        let mut r = Pcg::seeded(5);
        let mut counts = [0usize; 7];
        let n = 70_000;
        for _ in 0..n {
            counts[r.below(7) as usize] += 1;
        }
        for &c in &counts {
            let expect = n / 7;
            assert!((c as i64 - expect as i64).abs() < (expect as i64) / 10);
        }
    }

    #[test]
    #[cfg_attr(miri, ignore)] // statistical sweep, far too slow under miri
    fn normal_moments() {
        let mut r = Pcg::seeded(11);
        let n = 200_000;
        let (mut s, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.normal();
            s += x;
            s2 += x * x;
        }
        let mean = s / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
    }

    #[test]
    #[cfg_attr(miri, ignore)] // statistical sweep, far too slow under miri
    fn rayleigh_second_moment() {
        // E[X^2] = 2 sigma^2; with sigma = 1/sqrt(2), E[X^2] = 1 (unit power).
        let mut r = Pcg::seeded(13);
        let sigma = 1.0 / 2f64.sqrt();
        let n = 200_000;
        let mut s2 = 0.0;
        for _ in 0..n {
            let x = r.rayleigh(sigma);
            assert!(x >= 0.0);
            s2 += x * x;
        }
        assert!((s2 / n as f64 - 1.0).abs() < 0.02);
    }

    #[test]
    #[cfg_attr(miri, ignore)] // statistical sweep, far too slow under miri
    fn gamma_moments_above_and_below_one() {
        // Gamma(shape, 1): mean = shape, var = shape — both branches of
        // the sampler (Marsaglia–Tsang >= 1, boosted < 1)
        for shape in [0.3f64, 2.5] {
            let mut r = Pcg::seeded(31);
            let n = 200_000;
            let (mut s, mut s2) = (0.0, 0.0);
            for _ in 0..n {
                let x = r.gamma(shape);
                assert!(x > 0.0);
                s += x;
                s2 += x * x;
            }
            let mean = s / n as f64;
            let var = s2 / n as f64 - mean * mean;
            assert!((mean - shape).abs() < 0.05 * shape.max(0.2), "shape {shape}: mean {mean}");
            assert!((var - shape).abs() < 0.08 * shape.max(0.2), "shape {shape}: var {var}");
        }
    }

    #[test]
    fn gamma_deterministic_replay() {
        let mut a = Pcg::seeded(37);
        let mut b = Pcg::seeded(37);
        for _ in 0..200 {
            assert_eq!(a.gamma(0.4).to_bits(), b.gamma(0.4).to_bits());
        }
    }

    #[test]
    #[cfg_attr(miri, ignore)] // statistical sweep, far too slow under miri
    fn exponential_mean() {
        let mut r = Pcg::seeded(17);
        let n = 200_000;
        let mut s = 0.0;
        for _ in 0..n {
            s += r.exponential();
        }
        assert!((s / n as f64 - 1.0).abs() < 0.02);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Pcg::seeded(19);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Pcg::seeded(23);
        let s = r.sample_indices(50, 20);
        assert_eq!(s.len(), 20);
        let mut d = s.clone();
        d.sort_unstable();
        d.dedup();
        assert_eq!(d.len(), 20);
        assert!(d.iter().all(|&i| i < 50));
    }

    #[test]
    fn range_u64_inclusive_bounds_hit() {
        let mut r = Pcg::seeded(29);
        let (mut lo_seen, mut hi_seen) = (false, false);
        for _ in 0..10_000 {
            let x = r.range_u64(3, 6);
            assert!((3..=6).contains(&x));
            lo_seen |= x == 3;
            hi_seen |= x == 6;
        }
        assert!(lo_seen && hi_seen);
    }
}
