//! Crate-wide worker-thread knob for the parallel execution paths
//! (`exec::Engine` device fan-out, `util::linalg` row-blocked GEMM).
//!
//! The count is a *cap on concurrency*, never a semantic input: every
//! parallel path in the crate is required to produce bitwise-identical
//! results at any thread count (see `tests/exec_determinism.rs`). A value
//! of 0 means "auto" — use every available core.

use std::cell::Cell;
use std::sync::atomic::{AtomicUsize, Ordering};

/// Configured global thread count (0 = auto). Set once at startup by the
/// CLI `--threads` flag / `train.threads` config key.
static THREADS: AtomicUsize = AtomicUsize::new(0);

thread_local! {
    /// Per-thread parallelism budget override (0 = unset, fall back to the
    /// global knob). `exec::Engine` sets this to 1 inside its workers so
    /// nested code (the linalg row-blocked GEMMs) stays serial instead of
    /// spawning threads² under the device fan-out.
    static LOCAL_BUDGET: Cell<usize> = const { Cell::new(0) };
}

/// Number of logical cores the host exposes (>= 1).
pub fn available() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Resolve a configured count: 0 = all available cores.
pub fn resolve(threads: usize) -> usize {
    if threads == 0 {
        available()
    } else {
        threads
    }
}

/// Set the crate-wide default thread count (0 = auto).
pub fn set_global_threads(n: usize) {
    THREADS.store(n, Ordering::Relaxed);
}

/// The crate-wide default thread count, resolved (always >= 1).
pub fn global_threads() -> usize {
    resolve(THREADS.load(Ordering::Relaxed))
}

/// The parallelism budget for the current thread (always >= 1): the
/// innermost `with_budget` override, else the global knob. Nested parallel
/// code (linalg GEMM blocking) must consult this, not `global_threads`.
pub fn local_budget() -> usize {
    let b = LOCAL_BUDGET.with(Cell::get);
    if b == 0 {
        global_threads()
    } else {
        b
    }
}

/// Run `f` with this thread's parallelism budget set to `resolve(n)`,
/// restoring the previous budget afterwards.
pub fn with_budget<R>(n: usize, f: impl FnOnce() -> R) -> R {
    let prev = LOCAL_BUDGET.with(|c| {
        let prev = c.get();
        c.set(resolve(n).max(1));
        prev
    });
    let out = f();
    LOCAL_BUDGET.with(|c| c.set(prev));
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn available_positive() {
        assert!(available() >= 1);
    }

    #[test]
    fn resolve_auto_and_explicit() {
        assert_eq!(resolve(0), available());
        assert_eq!(resolve(3), 3);
    }

    #[test]
    fn global_default_is_auto() {
        // other tests may race on the global; only check it resolves >= 1
        assert!(global_threads() >= 1);
    }

    #[test]
    fn budget_scopes_and_nests() {
        let outer = local_budget();
        assert!(outer >= 1);
        let inner = with_budget(1, || {
            let one = local_budget();
            let nested = with_budget(5, local_budget);
            (one, nested)
        });
        assert_eq!(inner, (1, 5));
        // restored after the scope
        assert_eq!(local_budget(), outer);
    }
}
