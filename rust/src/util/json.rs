//! Minimal JSON parser + writer (serde is unavailable offline).
//!
//! Supports the full JSON grammar minus exotic number forms; used for the
//! artifact manifest (`artifacts/manifest.json`) written by the python AOT
//! pipeline and for metrics output. Strings support the standard escapes
//! plus `\uXXXX` (BMP only — sufficient for the manifest, which is ASCII).

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Objects keep sorted key order (BTreeMap) so output is
/// deterministic.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(src: &str) -> Result<Json, JsonError> {
        let mut p = Parser { s: src.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.s.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    // -- typed accessors ---------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        match self {
            Json::Num(n) if *n >= 0.0 && n.fract() == 0.0 => Some(*n as usize),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// `obj.get(key)` that errors with the key name — manifest parsing wants
    /// actionable messages, not silent Nones.
    pub fn req(&self, key: &str) -> Result<&Json, JsonError> {
        self.get(key).ok_or_else(|| JsonError {
            msg: format!("missing key {key:?}"),
            offset: 0,
        })
    }
}

#[derive(Debug, Clone, PartialEq)]
pub struct JsonError {
    pub msg: String,
    pub offset: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.offset, self.msg)
    }
}

impl std::error::Error for JsonError {}

struct Parser<'a> {
    s: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { msg: msg.to_string(), offset: self.i }
    }

    fn skip_ws(&mut self) {
        while self.i < self.s.len()
            && matches!(self.s[self.i], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.s.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected {:?}", c as char)))
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.s[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected {word}")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'n') => self.lit("null", Json::Null),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            self.skip_ws();
            out.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(out));
                }
                _ => return Err(self.err("expected , or ]")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut out = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            self.skip_ws();
            out.insert(key, self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(out));
                }
                _ => return Err(self.err("expected , or }")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'n') => out.push('\n'),
                        Some(b'r') => out.push('\r'),
                        Some(b't') => out.push('\t'),
                        Some(b'u') => {
                            if self.i + 5 > self.s.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.s[self.i + 1..self.i + 5])
                                    .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            out.push(
                                char::from_u32(cp)
                                    .ok_or_else(|| self.err("bad codepoint"))?,
                            );
                            self.i += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // advance over one UTF-8 scalar
                    let start = self.i;
                    let len = utf8_len(self.s[start]);
                    if start + len > self.s.len() {
                        return Err(self.err("bad utf-8"));
                    }
                    out.push_str(
                        std::str::from_utf8(&self.s[start..start + len])
                            .map_err(|_| self.err("bad utf-8"))?,
                    );
                    self.i += len;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e') | Some(b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+') | Some(b'-')) {
                self.i += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        let text = std::str::from_utf8(&self.s[start..self.i])
            .map_err(|_| self.err("bad number"))?;
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

fn utf8_len(b: u8) -> usize {
    match b {
        0x00..=0x7f => 1,
        0xc0..=0xdf => 2,
        0xe0..=0xef => 3,
        _ => 4,
    }
}

// ---------------------------------------------------------------------------
// Writer
// ---------------------------------------------------------------------------

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::Str(s) => write_escaped(f, s),
            Json::Arr(v) => {
                write!(f, "[")?;
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{x}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write_escaped(f, k)?;
                    write!(f, ":{v}")?;
                }
                write!(f, "}}")
            }
        }
    }
}

fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    write!(f, "\"")?;
    for c in s.chars() {
        match c {
            '"' => write!(f, "\\\"")?,
            '\\' => write!(f, "\\\\")?,
            '\n' => write!(f, "\\n")?,
            '\r' => write!(f, "\\r")?,
            '\t' => write!(f, "\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    write!(f, "\"")
}

/// Builder helpers for metrics emission.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

pub fn num(n: f64) -> Json {
    Json::Num(n)
}

pub fn s(v: &str) -> Json {
    Json::Str(v.to_string())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse("\"a\\nb\"").unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a":[1,2,{"b":false}],"c":"x"}"#).unwrap();
        assert_eq!(v.get("c").unwrap().as_str(), Some("x"));
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr[1].as_f64(), Some(2.0));
        assert_eq!(arr[2].get("b").unwrap().as_bool(), Some(false));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("").is_err());
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("{\"a\" 1}").is_err());
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(Json::parse("\"\\u0041\"").unwrap(), Json::Str("A".into()));
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"arr":[1,2.5,null],"name":"x\"y","ok":true}"#;
        let v = Json::parse(src).unwrap();
        let out = v.to_string();
        assert_eq!(Json::parse(&out).unwrap(), v);
    }

    #[test]
    fn whitespace_tolerant() {
        let v = Json::parse(" {\n\t\"a\" :\r [ 1 , 2 ] } ").unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 2);
    }

    #[test]
    fn as_usize_rejects_fraction_and_negative() {
        assert_eq!(Json::Num(3.0).as_usize(), Some(3));
        assert_eq!(Json::Num(3.5).as_usize(), None);
        assert_eq!(Json::Num(-1.0).as_usize(), None);
    }

    #[test]
    fn utf8_passthrough() {
        let v = Json::parse("\"héllo→\"").unwrap();
        assert_eq!(v.as_str(), Some("héllo→"));
    }
}
