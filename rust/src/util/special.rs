//! Special functions: exponential integral E1 and the closed-form ergodic
//! Rayleigh-fading rate built on it.
//!
//! For |h|^2 ~ Exp(1) and mean SNR gamma, the ergodic spectral efficiency is
//!   E[log2(1 + gamma*X)] = e^(1/gamma) * E1(1/gamma) / ln 2
//! which the wireless substrate uses as the analytic counterpart of the
//! Monte-Carlo average in eq. (5)-(6); a unit test pins them together.

/// Exponential integral E1(x) = ∫_x^∞ e^{-t}/t dt, x > 0.
///
/// Series for x <= 1 (Abramowitz & Stegun 5.1.11), continued fraction
/// (modified Lentz) for x > 1. Relative error < 1e-12 over (0, 700].
pub fn e1(x: f64) -> f64 {
    assert!(x > 0.0, "e1 domain x > 0, got {x}");
    const EULER: f64 = 0.5772156649015328606;
    if x <= 1.0 {
        // E1(x) = -gamma - ln x + sum_{k>=1} (-1)^{k+1} x^k / (k * k!)
        let mut sum = 0.0;
        let mut term = 1.0;
        for k in 1..200 {
            term *= -x / k as f64;
            let add = -term / k as f64;
            sum += add;
            if add.abs() < 1e-17 * (1.0 + sum.abs()) {
                break;
            }
        }
        -EULER - x.ln() + sum
    } else {
        // E1(x) = e^{-x} * CF, CF = 1/(x+1- 1/(x+3- 4/(x+5- 9/(x+7- ...))))
        // via modified Lentz on the standard continued fraction.
        let tiny = 1e-300;
        let mut b = x + 1.0;
        let mut c = 1.0 / tiny;
        let mut d = 1.0 / b;
        let mut h = d;
        for i in 1..200 {
            let a = -(i as f64) * (i as f64);
            b += 2.0;
            d = 1.0 / (a * d + b);
            c = b + a / c;
            let del = c * d;
            h *= del;
            if (del - 1.0).abs() < 1e-15 {
                break;
            }
        }
        (-x).exp() * h
    }
}

/// Ergodic rate factor E[log2(1 + gamma * X)], X ~ Exp(1) (unit-power
/// Rayleigh), in bit/s/Hz. `gamma` is the mean SNR (linear).
pub fn ergodic_log2_rayleigh(gamma: f64) -> f64 {
    assert!(gamma > 0.0);
    let inv = 1.0 / gamma;
    // e^{1/g} E1(1/g) overflows for tiny gamma if computed naively; for
    // inv > 700 use the asymptotic e^x E1(x) ~ 1/x (1 - 1/x + 2/x^2 ...).
    let ex_e1 = if inv > 700.0 {
        (1.0 / inv) * (1.0 - 1.0 / inv + 2.0 / (inv * inv))
    } else {
        inv.exp() * e1(inv)
    };
    ex_e1 / std::f64::consts::LN_2
}

/// dB -> linear power ratio.
pub fn db_to_lin(db: f64) -> f64 {
    10f64.powf(db / 10.0)
}

/// linear power ratio -> dB.
pub fn lin_to_db(lin: f64) -> f64 {
    assert!(lin > 0.0);
    10.0 * lin.log10()
}

/// dBm -> watts.
pub fn dbm_to_watt(dbm: f64) -> f64 {
    db_to_lin(dbm - 30.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn e1_known_values() {
        // Reference values (A&S tables / mpmath).
        let cases = [
            (0.1, 1.822_923_958_4),
            (0.5, 0.559_773_594_8),
            (1.0, 0.219_383_934_4),
            (2.0, 0.048_900_510_7),
            (5.0, 0.001_148_295_6),
            (10.0, 4.156_968_93e-6),
        ];
        for (x, want) in cases {
            let got = e1(x);
            assert!(
                (got - want).abs() < 1e-9 * (1.0 + want),
                "E1({x}) = {got}, want {want}"
            );
        }
    }

    #[test]
    fn e1_continuity_at_switch() {
        // series vs continued fraction must agree near x = 1.
        let lo = e1(1.0 - 1e-9);
        let hi = e1(1.0 + 1e-9);
        assert!((lo - hi).abs() < 1e-9);
    }

    #[test]
    #[cfg_attr(miri, ignore)] // statistical sweep, far too slow under miri
    fn ergodic_rate_matches_monte_carlo() {
        let mut rng = crate::util::rng::Pcg::seeded(7);
        for &gamma in &[0.1, 1.0, 10.0, 100.0] {
            let n = 400_000;
            let mut s = 0.0;
            for _ in 0..n {
                let x = rng.exponential();
                s += (1.0 + gamma * x).log2();
            }
            let mc = s / n as f64;
            let cf = ergodic_log2_rayleigh(gamma);
            assert!(
                (mc - cf).abs() / cf < 0.01,
                "gamma={gamma}: mc={mc} cf={cf}"
            );
        }
    }

    #[test]
    fn ergodic_rate_monotone_in_snr() {
        let mut prev = 0.0;
        for i in 1..50 {
            let g = 10f64.powf(-3.0 + i as f64 * 0.2);
            let r = ergodic_log2_rayleigh(g);
            assert!(r > prev);
            prev = r;
        }
    }

    #[test]
    fn ergodic_rate_tiny_snr_no_overflow() {
        let r = ergodic_log2_rayleigh(1e-6);
        assert!(r > 0.0 && r < 1e-5);
    }

    #[test]
    fn db_conversions() {
        assert!((db_to_lin(3.0) - 1.995).abs() < 1e-2);
        assert!((lin_to_db(100.0) - 20.0).abs() < 1e-12);
        assert!((dbm_to_watt(30.0) - 1.0).abs() < 1e-12);
        assert!((dbm_to_watt(28.0) - 0.631).abs() < 1e-3);
    }
}
