//! Statistics substrate: summaries, quantiles, least squares, and the
//! piecewise-linear (breakpoint) fitter used to recover the paper's GPU
//! training-function coefficients from measured (batchsize, latency) data.

/// Running summary with Welford variance.
#[derive(Clone, Debug, Default)]
pub struct Summary {
    n: usize,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Summary {
    pub fn new() -> Self {
        Summary { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> usize {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn var(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }

    pub fn min(&self) -> f64 {
        self.min
    }

    pub fn max(&self) -> f64 {
        self.max
    }
}

/// Collect from an iterator.
pub fn summarize<I: IntoIterator<Item = f64>>(xs: I) -> Summary {
    let mut s = Summary::new();
    for x in xs {
        s.push(x);
    }
    s
}

/// Quantile with linear interpolation (q in [0,1]); sorts a copy.
pub fn quantile(xs: &[f64], q: f64) -> f64 {
    assert!(!xs.is_empty(), "quantile of empty slice");
    assert!((0.0..=1.0).contains(&q));
    let mut v = xs.to_vec();
    // total order: a NaN sample sorts to the top instead of panicking
    v.sort_by(|a, b| a.total_cmp(b));
    let pos = q * (v.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (pos - lo as f64) * (v[hi] - v[lo])
    }
}

/// Ordinary least squares y = a + b*x. Returns (a, b, r2).
pub fn linfit(xs: &[f64], ys: &[f64]) -> (f64, f64, f64) {
    assert_eq!(xs.len(), ys.len());
    assert!(xs.len() >= 2, "linfit needs >= 2 points");
    let n = xs.len() as f64;
    let mx = xs.iter().sum::<f64>() / n;
    let my = ys.iter().sum::<f64>() / n;
    let mut sxx = 0.0;
    let mut sxy = 0.0;
    let mut syy = 0.0;
    for (&x, &y) in xs.iter().zip(ys) {
        sxx += (x - mx) * (x - mx);
        sxy += (x - mx) * (y - my);
        syy += (y - my) * (y - my);
    }
    let b = if sxx > 0.0 { sxy / sxx } else { 0.0 };
    let a = my - b * mx;
    let r2 = if syy > 0.0 { (sxy * sxy) / (sxx * syy) } else { 1.0 };
    (a, b, r2)
}

/// Fit of the paper's GPU training function (eq. 26):
/// `t(B) = t_l` for `B <= b_th`, `t(B) = c*(B - b_th) + t_l` beyond.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct PiecewiseFit {
    /// Flat-region latency `t_l` (seconds).
    pub t_l: f64,
    /// Linear-region slope `c` (seconds per sample).
    pub c: f64,
    /// Breakpoint `B_th`.
    pub b_th: f64,
    /// Residual sum of squares of the fit.
    pub rss: f64,
}

impl PiecewiseFit {
    pub fn eval(&self, b: f64) -> f64 {
        if b <= self.b_th {
            self.t_l
        } else {
            self.c * (b - self.b_th) + self.t_l
        }
    }
}

/// Least-squares breakpoint search: try each candidate split index, fit the
/// flat region by its mean and the tail by constrained OLS anchored at
/// (b_th, t_l); keep the split minimizing RSS. O(n^2) — n is tens of points.
pub fn fit_piecewise(bs: &[f64], ts: &[f64]) -> PiecewiseFit {
    assert_eq!(bs.len(), ts.len());
    assert!(bs.len() >= 4, "fit_piecewise needs >= 4 points");
    let mut best: Option<PiecewiseFit> = None;
    // split index k: points [0..=k] flat, [k..] linear (breakpoint at bs[k]).
    for k in 1..bs.len() - 1 {
        let t_l = ts[..=k].iter().sum::<f64>() / (k + 1) as f64;
        let b_th = bs[k];
        // constrained slope through (b_th, t_l): c = sum((b-b_th)(t-t_l)) / sum((b-b_th)^2)
        let mut num = 0.0;
        let mut den = 0.0;
        for i in k..bs.len() {
            let db = bs[i] - b_th;
            num += db * (ts[i] - t_l);
            den += db * db;
        }
        let c = if den > 0.0 { (num / den).max(0.0) } else { 0.0 };
        let fit = PiecewiseFit { t_l, c, b_th, rss: 0.0 };
        let rss: f64 = bs
            .iter()
            .zip(ts)
            .map(|(&b, &t)| {
                let e = t - fit.eval(b);
                e * e
            })
            .sum();
        let fit = PiecewiseFit { rss, ..fit };
        if best.as_ref().map_or(true, |b| rss < b.rss) {
            best = Some(fit);
        }
    }
    // lint: allow(panic-path): the len >= 4 assert above guarantees >= 2 loop passes
    best.expect("split loop ran at least twice")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basic() {
        let s = summarize([1.0, 2.0, 3.0, 4.0]);
        assert_eq!(s.count(), 4);
        assert!((s.mean() - 2.5).abs() < 1e-12);
        assert!((s.var() - 5.0 / 3.0).abs() < 1e-12);
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 4.0);
    }

    #[test]
    fn quantile_interpolates() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(quantile(&xs, 0.0), 1.0);
        assert_eq!(quantile(&xs, 1.0), 4.0);
        assert!((quantile(&xs, 0.5) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn linfit_exact_line() {
        let xs = [0.0, 1.0, 2.0, 3.0];
        let ys: Vec<f64> = xs.iter().map(|x| 2.0 + 3.0 * x).collect();
        let (a, b, r2) = linfit(&xs, &ys);
        assert!((a - 2.0).abs() < 1e-12);
        assert!((b - 3.0).abs() < 1e-12);
        assert!((r2 - 1.0).abs() < 1e-12);
    }

    #[test]
    fn piecewise_recovers_knee() {
        // t_l = 0.05, b_th = 32, c = 0.002 — the Fig. 2(a) shape.
        let bs: Vec<f64> = (1..=128).map(|b| b as f64).collect();
        let ts: Vec<f64> = bs
            .iter()
            .map(|&b| if b <= 32.0 { 0.05 } else { 0.002 * (b - 32.0) + 0.05 })
            .collect();
        let fit = fit_piecewise(&bs, &ts);
        assert!((fit.t_l - 0.05).abs() < 1e-3, "{fit:?}");
        assert!((fit.b_th - 32.0).abs() <= 1.0, "{fit:?}");
        assert!((fit.c - 0.002).abs() < 1e-4, "{fit:?}");
    }

    #[test]
    fn piecewise_tolerates_noise() {
        let mut rng = crate::util::rng::Pcg::seeded(99);
        let bs: Vec<f64> = (1..=128).map(|b| b as f64).collect();
        let ts: Vec<f64> = bs
            .iter()
            .map(|&b| {
                let base = if b <= 24.0 { 0.08 } else { 0.003 * (b - 24.0) + 0.08 };
                base * (1.0 + 0.02 * rng.normal())
            })
            .collect();
        let fit = fit_piecewise(&bs, &ts);
        assert!((fit.b_th - 24.0).abs() <= 4.0, "{fit:?}");
        assert!((fit.c - 0.003).abs() < 3e-4, "{fit:?}");
    }
}
