//! Substrate utilities built in-repo (the offline environment provides no
//! crates beyond `xla`/`anyhow`): PRNG, JSON, statistics, special functions.

pub mod json;
pub mod linalg;
pub mod rng;
pub mod special;
pub mod stats;
pub mod threads;
