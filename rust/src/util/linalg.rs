//! Dense linear algebra substrate for the host model: row-major f32 GEMM
//! with the three orientations backprop needs, written cache-consciously
//! (ikj loop order, contiguous row blocks). Large calls are fanned out over
//! `util::threads::global_threads()` scoped threads by *output-row blocks*,
//! which keeps every output element's accumulation order identical to the
//! single-thread path — results are bitwise identical at any thread count.
//! Good enough that the pure-rust oracle can drive the large Table-II
//! sweeps; the AOT/XLA path remains the production hot path.

use crate::util::threads;

/// Only fan out when a call is worth a thread spawn: below this many
/// multiply-adds the serial kernel wins.
const PAR_FLOP_THRESHOLD: usize = 1 << 24;

/// Number of row blocks to split `rows` output rows into for a call of
/// `flops` multiply-adds (1 = stay serial). Consults the thread-local
/// budget, which `exec::Engine` pins to 1 inside its device workers — so
/// per-device train steps never nest a second fan-out (no threads² under
/// the engine), and `TrainerConfig::threads` caps eval-path GEMMs too.
fn row_blocks(rows: usize, flops: usize) -> usize {
    let t = threads::local_budget();
    if t <= 1 || rows < 2 || flops < PAR_FLOP_THRESHOLD {
        1
    } else {
        t.min(rows)
    }
}

/// c[m,n] += a[m,k] * b[k,n] (row-major).
pub fn gemm(m: usize, k: usize, n: usize, a: &[f32], b: &[f32], c: &mut [f32]) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(c.len(), m * n);
    let blocks = row_blocks(m, m * k * n);
    if blocks <= 1 {
        return gemm_block(m, k, n, a, b, c);
    }
    let rows_per = m.div_ceil(blocks);
    std::thread::scope(|s| {
        for (bi, cc) in c.chunks_mut(rows_per * n).enumerate() {
            let rows = cc.len() / n;
            let lo = bi * rows_per;
            let aa = &a[lo * k..(lo + rows) * k];
            s.spawn(move || gemm_block(rows, k, n, aa, b, cc));
        }
    });
}

fn gemm_block(m: usize, k: usize, n: usize, a: &[f32], b: &[f32], c: &mut [f32]) {
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        let crow = &mut c[i * n..(i + 1) * n];
        for (kk, &av) in arow.iter().enumerate() {
            if av == 0.0 {
                continue;
            }
            let brow = &b[kk * n..(kk + 1) * n];
            for j in 0..n {
                crow[j] += av * brow[j];
            }
        }
    }
}

/// c[k,n] += a[m,k]^T * d[m,n]  (weight gradient: x^T dy).
///
/// Parallel split is over blocks of c's rows (the k dimension); each block
/// scans all m samples in order, so per-element accumulation order matches
/// the serial kernel exactly.
pub fn gemm_at(m: usize, k: usize, n: usize, a: &[f32], d: &[f32], c: &mut [f32]) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(d.len(), m * n);
    debug_assert_eq!(c.len(), k * n);
    let blocks = row_blocks(k, m * k * n);
    if blocks <= 1 {
        return gemm_at_block(m, 0, k, k, n, a, d, c);
    }
    let rows_per = k.div_ceil(blocks);
    std::thread::scope(|s| {
        for (bi, cc) in c.chunks_mut(rows_per * n).enumerate() {
            let rows = cc.len() / n;
            let lo = bi * rows_per;
            s.spawn(move || gemm_at_block(m, lo, rows, k, n, a, d, cc));
        }
    });
}

/// One kk-block of `gemm_at`: `c_block` holds rows `k_lo..k_lo+k_rows` of c.
fn gemm_at_block(
    m: usize,
    k_lo: usize,
    k_rows: usize,
    k: usize,
    n: usize,
    a: &[f32],
    d: &[f32],
    c_block: &mut [f32],
) {
    for i in 0..m {
        let aseg = &a[i * k + k_lo..i * k + k_lo + k_rows];
        let drow = &d[i * n..(i + 1) * n];
        for (kk, &av) in aseg.iter().enumerate() {
            if av == 0.0 {
                continue;
            }
            let crow = &mut c_block[kk * n..(kk + 1) * n];
            for j in 0..n {
                crow[j] += av * drow[j];
            }
        }
    }
}

/// c[m,k] += d[m,n] * b[k,n]^T  (input gradient: dy W^T).
pub fn gemm_bt(m: usize, k: usize, n: usize, d: &[f32], b: &[f32], c: &mut [f32]) {
    debug_assert_eq!(d.len(), m * n);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(c.len(), m * k);
    let blocks = row_blocks(m, m * k * n);
    if blocks <= 1 {
        return gemm_bt_block(m, k, n, d, b, c);
    }
    let rows_per = m.div_ceil(blocks);
    std::thread::scope(|s| {
        for (bi, cc) in c.chunks_mut(rows_per * k).enumerate() {
            let rows = cc.len() / k;
            let lo = bi * rows_per;
            let dd = &d[lo * n..(lo + rows) * n];
            s.spawn(move || gemm_bt_block(rows, k, n, dd, b, cc));
        }
    });
}

fn gemm_bt_block(m: usize, k: usize, n: usize, d: &[f32], b: &[f32], c: &mut [f32]) {
    for i in 0..m {
        let drow = &d[i * n..(i + 1) * n];
        let crow = &mut c[i * k..(i + 1) * k];
        for kk in 0..k {
            let brow = &b[kk * n..(kk + 1) * n];
            let mut acc = 0.0f32;
            for j in 0..n {
                acc += drow[j] * brow[j];
            }
            crow[kk] += acc;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn naive(m: usize, k: usize, n: usize, a: &[f32], b: &[f32]) -> Vec<f32> {
        let mut c = vec![0f32; m * n];
        for i in 0..m {
            for j in 0..n {
                for kk in 0..k {
                    c[i * n + j] += a[i * k + kk] * b[kk * n + j];
                }
            }
        }
        c
    }

    fn filled(len: usize, seed: u64) -> Vec<f32> {
        let mut r = crate::util::rng::Pcg::seeded(seed);
        (0..len).map(|_| r.normal() as f32).collect()
    }

    #[test]
    fn gemm_matches_naive() {
        let (m, k, n) = (7, 11, 5);
        let a = filled(m * k, 1);
        let b = filled(k * n, 2);
        let mut c = vec![0f32; m * n];
        gemm(m, k, n, &a, &b, &mut c);
        let want = naive(m, k, n, &a, &b);
        for (x, y) in c.iter().zip(&want) {
            assert!((x - y).abs() < 1e-4);
        }
    }

    #[test]
    fn gemm_at_is_transpose_product() {
        let (m, k, n) = (6, 4, 3);
        let a = filled(m * k, 3);
        let d = filled(m * n, 4);
        let mut c = vec![0f32; k * n];
        gemm_at(m, k, n, &a, &d, &mut c);
        // naive a^T d
        let mut want = vec![0f32; k * n];
        for kk in 0..k {
            for j in 0..n {
                for i in 0..m {
                    want[kk * n + j] += a[i * k + kk] * d[i * n + j];
                }
            }
        }
        for (x, y) in c.iter().zip(&want) {
            assert!((x - y).abs() < 1e-4);
        }
    }

    #[test]
    fn gemm_bt_is_product_transpose() {
        let (m, k, n) = (5, 6, 4);
        let d = filled(m * n, 5);
        let b = filled(k * n, 6);
        let mut c = vec![0f32; m * k];
        gemm_bt(m, k, n, &d, &b, &mut c);
        let mut want = vec![0f32; m * k];
        for i in 0..m {
            for kk in 0..k {
                for j in 0..n {
                    want[i * k + kk] += d[i * n + j] * b[kk * n + j];
                }
            }
        }
        for (x, y) in c.iter().zip(&want) {
            assert!((x - y).abs() < 1e-4);
        }
    }

    #[test]
    fn gemm_accumulates() {
        let mut c = vec![1.0f32; 1];
        gemm(1, 1, 1, &[2.0], &[3.0], &mut c);
        assert_eq!(c[0], 7.0);
    }

    /// Forcing the blocked path (by calling the block kernels directly on a
    /// split) must be bitwise identical to the serial kernel — the
    /// determinism invariant the threaded dispatch relies on.
    #[test]
    fn blocked_kernels_bitwise_equal_serial() {
        let (m, k, n) = (32, 24, 17);
        let a = filled(m * k, 7);
        let b = filled(k * n, 8);
        let d = filled(m * n, 9);

        // gemm: split rows of c
        let mut serial = vec![0f32; m * n];
        gemm_block(m, k, n, &a, &b, &mut serial);
        let mut split = vec![0f32; m * n];
        let rows = 10;
        for (bi, cc) in split.chunks_mut(rows * n).enumerate() {
            let r = cc.len() / n;
            let lo = bi * rows;
            gemm_block(r, k, n, &a[lo * k..(lo + r) * k], &b, cc);
        }
        assert_eq!(serial, split);

        // gemm_at: split rows of c (the k dimension)
        let mut serial = vec![0f32; k * n];
        gemm_at_block(m, 0, k, k, n, &a, &d, &mut serial);
        let mut split = vec![0f32; k * n];
        let rows = 7;
        for (bi, cc) in split.chunks_mut(rows * n).enumerate() {
            let r = cc.len() / n;
            gemm_at_block(m, bi * rows, r, k, n, &a, &d, cc);
        }
        assert_eq!(serial, split);

        // gemm_bt: split rows of c
        let mut serial = vec![0f32; m * k];
        gemm_bt_block(m, k, n, &d, &b, &mut serial);
        let mut split = vec![0f32; m * k];
        let rows = 9;
        for (bi, cc) in split.chunks_mut(rows * k).enumerate() {
            let r = cc.len() / k;
            let lo = bi * rows;
            gemm_bt_block(r, k, n, &d[lo * n..(lo + r) * n], &b, cc);
        }
        assert_eq!(serial, split);
    }

    /// A call big enough to cross the parallel threshold still matches the
    /// serial block kernel exactly.
    #[test]
    fn parallel_dispatch_bitwise_equal_serial() {
        let (m, k, n) = (512, 192, 256); // 25M madds > PAR_FLOP_THRESHOLD
        let a = filled(m * k, 11);
        let b = filled(k * n, 12);
        let mut par = vec![0f32; m * n];
        gemm(m, k, n, &a, &b, &mut par);
        let mut ser = vec![0f32; m * n];
        gemm_block(m, k, n, &a, &b, &mut ser);
        assert_eq!(par, ser);
    }
}
