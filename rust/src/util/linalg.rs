//! Dense linear algebra substrate for the host model: row-major f32 GEMM
//! with the three orientations backprop needs, written cache-consciously
//! (ikj loop order, 64-wide j blocking). Good enough that the pure-rust
//! oracle can drive the large Table-II sweeps; the AOT/XLA path remains the
//! production hot path.

/// c[m,n] += a[m,k] * b[k,n] (row-major).
pub fn gemm(m: usize, k: usize, n: usize, a: &[f32], b: &[f32], c: &mut [f32]) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(c.len(), m * n);
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        let crow = &mut c[i * n..(i + 1) * n];
        for (kk, &av) in arow.iter().enumerate() {
            if av == 0.0 {
                continue;
            }
            let brow = &b[kk * n..(kk + 1) * n];
            for j in 0..n {
                crow[j] += av * brow[j];
            }
        }
    }
}

/// c[k,n] += a[m,k]^T * d[m,n]  (weight gradient: x^T dy).
pub fn gemm_at(m: usize, k: usize, n: usize, a: &[f32], d: &[f32], c: &mut [f32]) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(d.len(), m * n);
    debug_assert_eq!(c.len(), k * n);
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        let drow = &d[i * n..(i + 1) * n];
        for (kk, &av) in arow.iter().enumerate() {
            if av == 0.0 {
                continue;
            }
            let crow = &mut c[kk * n..(kk + 1) * n];
            for j in 0..n {
                crow[j] += av * drow[j];
            }
        }
    }
}

/// c[m,k] += d[m,n] * b[k,n]^T  (input gradient: dy W^T).
pub fn gemm_bt(m: usize, k: usize, n: usize, d: &[f32], b: &[f32], c: &mut [f32]) {
    debug_assert_eq!(d.len(), m * n);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(c.len(), m * k);
    for i in 0..m {
        let drow = &d[i * n..(i + 1) * n];
        let crow = &mut c[i * k..(i + 1) * k];
        for kk in 0..k {
            let brow = &b[kk * n..(kk + 1) * n];
            let mut acc = 0.0f32;
            for j in 0..n {
                acc += drow[j] * brow[j];
            }
            crow[kk] += acc;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn naive(m: usize, k: usize, n: usize, a: &[f32], b: &[f32]) -> Vec<f32> {
        let mut c = vec![0f32; m * n];
        for i in 0..m {
            for j in 0..n {
                for kk in 0..k {
                    c[i * n + j] += a[i * k + kk] * b[kk * n + j];
                }
            }
        }
        c
    }

    fn filled(len: usize, seed: u64) -> Vec<f32> {
        let mut r = crate::util::rng::Pcg::seeded(seed);
        (0..len).map(|_| r.normal() as f32).collect()
    }

    #[test]
    fn gemm_matches_naive() {
        let (m, k, n) = (7, 11, 5);
        let a = filled(m * k, 1);
        let b = filled(k * n, 2);
        let mut c = vec![0f32; m * n];
        gemm(m, k, n, &a, &b, &mut c);
        let want = naive(m, k, n, &a, &b);
        for (x, y) in c.iter().zip(&want) {
            assert!((x - y).abs() < 1e-4);
        }
    }

    #[test]
    fn gemm_at_is_transpose_product() {
        let (m, k, n) = (6, 4, 3);
        let a = filled(m * k, 3);
        let d = filled(m * n, 4);
        let mut c = vec![0f32; k * n];
        gemm_at(m, k, n, &a, &d, &mut c);
        // naive a^T d
        let mut want = vec![0f32; k * n];
        for kk in 0..k {
            for j in 0..n {
                for i in 0..m {
                    want[kk * n + j] += a[i * k + kk] * d[i * n + j];
                }
            }
        }
        for (x, y) in c.iter().zip(&want) {
            assert!((x - y).abs() < 1e-4);
        }
    }

    #[test]
    fn gemm_bt_is_product_transpose() {
        let (m, k, n) = (5, 6, 4);
        let d = filled(m * n, 5);
        let b = filled(k * n, 6);
        let mut c = vec![0f32; m * k];
        gemm_bt(m, k, n, &d, &b, &mut c);
        let mut want = vec![0f32; m * k];
        for i in 0..m {
            for kk in 0..k {
                for j in 0..n {
                    want[i * k + kk] += d[i * n + j] * b[kk * n + j];
                }
            }
        }
        for (x, y) in c.iter().zip(&want) {
            assert!((x - y).abs() < 1e-4);
        }
    }

    #[test]
    fn gemm_accumulates() {
        let mut c = vec![1.0f32; 1];
        gemm(1, 1, 1, &[2.0], &[3.0], &mut c);
        assert_eq!(c[0], 7.0);
    }
}
