//! Dense linear algebra substrate for the host model: row-major f32 GEMM
//! with the three orientations backprop needs.
//!
//! The serial core is a cache-blocked, panel-packing microkernel in the
//! BLIS mold: the depth dimension is split into `KC` panels, operand
//! panels are packed into contiguous micro-tile layouts (`MR`-row A
//! strips, `NR`-column B strips), and an `MR`×`NR` register-tile inner
//! loop accumulates with no branches so LLVM autovectorizes it. All three
//! orientations (`gemm`, `gemm_at`, `gemm_bt`) share one packed kernel via
//! index accessors, so the transposed views pay only a packing-order cost.
//!
//! Large calls are fanned out over `util::threads::global_threads()` scoped
//! threads by *output-row blocks*. Every output element is computed by
//! exactly one thread and its depth-accumulation order (ascending within
//! each `KC` panel, panels in ascending order) is independent of the row
//! split, so results are **bitwise identical at any thread count**. The
//! kernel choice (packed vs. small fallback) is made once per call from the
//! full problem shape, never per block, for the same reason.
//!
//! Absolute numerics differ slightly from the pre-packing kernel: the
//! register tile accumulates each `KC` panel separately before adding it to
//! C, which reassociates the f32 sums. Consumers hold comparisons to ~1e-4
//! relative tolerance (see tests/integration_runtime.rs), which this stays
//! well inside.

use std::cell::RefCell;

use crate::util::threads;

/// Only fan out when a call is worth a thread spawn: below this many
/// multiply-adds the serial kernel wins.
const PAR_FLOP_THRESHOLD: usize = 1 << 24;

/// Below this many multiply-adds the panel-packing overhead beats the
/// cache wins; use the plain ikj fallback kernel.
const PACK_FLOP_THRESHOLD: usize = 1 << 15;

/// Register-tile rows (A micro-strip height). `MC % MR == 0`.
const MR: usize = 4;
/// Register-tile columns (B micro-strip width). `NC % NR == 0`.
const NR: usize = 8;
/// Output rows per packed A panel (A panel = `MC`×`KC` ≈ 64 KiB, L2-warm).
const MC: usize = 64;
/// Depth per packed panel (shared by the A and B panels).
const KC: usize = 256;
/// Output columns per packed B panel (B panel = `KC`×`NC` ≈ 256 KiB).
const NC: usize = 256;

thread_local! {
    /// Per-thread (A, B) packing buffers. Reused across every GEMM the
    /// owning thread runs — for an engine worker that's all layers × all
    /// devices it folds within a round (engine threads are scoped per
    /// round, so the buffers are re-created once per round per worker, not
    /// per call).
    static PANELS: RefCell<(Vec<f32>, Vec<f32>)> =
        const { RefCell::new((Vec::new(), Vec::new())) };
}

/// Number of row blocks to split `rows` output rows into for a call of
/// `flops` multiply-adds (1 = stay serial). Consults the thread-local
/// budget, which `exec::Engine` pins to 1 inside its device workers — so
/// per-device train steps never nest a second fan-out (no threads² under
/// the engine), and `TrainerConfig::threads` caps eval-path GEMMs too.
fn row_blocks(rows: usize, flops: usize) -> usize {
    let t = threads::local_budget();
    if t <= 1 || rows < 2 || flops < PAR_FLOP_THRESHOLD {
        1
    } else {
        t.min(rows)
    }
}

/// Kernel choice for a call of `flops` multiply-adds. Decided once per
/// call from the full shape (never per row block) so the choice — and the
/// per-element accumulation order — cannot depend on the thread count.
fn use_packed(flops: usize) -> bool {
    flops >= PACK_FLOP_THRESHOLD
}

/// c[m,n] += a[m,k] * b[k,n] (row-major).
pub fn gemm(m: usize, k: usize, n: usize, a: &[f32], b: &[f32], c: &mut [f32]) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(c.len(), m * n);
    let packed = use_packed(m * k * n);
    let blocks = row_blocks(m, m * k * n);
    if blocks <= 1 {
        return gemm_rows(packed, m, 0, k, n, a, b, c);
    }
    let rows_per = m.div_ceil(blocks);
    std::thread::scope(|s| {
        for (bi, cc) in c.chunks_mut(rows_per * n).enumerate() {
            let rows = cc.len() / n;
            let lo = bi * rows_per;
            s.spawn(move || gemm_rows(packed, rows, lo, k, n, a, b, cc));
        }
    });
}

/// Rows `lo..lo+rows` of the `gemm` output (`cc` = that row block of c).
fn gemm_rows(
    packed: bool,
    rows: usize,
    lo: usize,
    k: usize,
    n: usize,
    a: &[f32],
    b: &[f32],
    cc: &mut [f32],
) {
    if packed {
        gemm_packed(rows, k, n, |i, kk| a[(lo + i) * k + kk], |kk, j| b[kk * n + j], cc);
    } else {
        gemm_small(rows, k, n, &a[lo * k..(lo + rows) * k], b, cc);
    }
}

/// Branchless serial fallback for shapes too small to pack (ikj order; the
/// old kernel's `av == 0.0` early-continue is gone so the inner loop
/// autovectorizes on dense inputs).
fn gemm_small(m: usize, k: usize, n: usize, a: &[f32], b: &[f32], c: &mut [f32]) {
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        let crow = &mut c[i * n..(i + 1) * n];
        for (kk, &av) in arow.iter().enumerate() {
            let brow = &b[kk * n..(kk + 1) * n];
            for j in 0..n {
                crow[j] += av * brow[j];
            }
        }
    }
}

/// c[k,n] += a[m,k]^T * d[m,n]  (weight gradient: x^T dy).
///
/// Parallel split is over blocks of c's rows (the k dimension); each block
/// scans all m samples in ascending order, so per-element accumulation
/// order matches the serial kernel exactly.
pub fn gemm_at(m: usize, k: usize, n: usize, a: &[f32], d: &[f32], c: &mut [f32]) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(d.len(), m * n);
    debug_assert_eq!(c.len(), k * n);
    let packed = use_packed(m * k * n);
    let blocks = row_blocks(k, m * k * n);
    if blocks <= 1 {
        return gemm_at_rows(packed, m, 0, k, k, n, a, d, c);
    }
    let rows_per = k.div_ceil(blocks);
    std::thread::scope(|s| {
        for (bi, cc) in c.chunks_mut(rows_per * n).enumerate() {
            let rows = cc.len() / n;
            let lo = bi * rows_per;
            s.spawn(move || gemm_at_rows(packed, m, lo, rows, k, n, a, d, cc));
        }
    });
}

/// Rows `k_lo..k_lo+k_rows` of the `gemm_at` output (the k dimension);
/// depth is the sample dimension m.
fn gemm_at_rows(
    packed: bool,
    m: usize,
    k_lo: usize,
    k_rows: usize,
    k: usize,
    n: usize,
    a: &[f32],
    d: &[f32],
    cb: &mut [f32],
) {
    if packed {
        gemm_packed(k_rows, m, n, |i, s| a[s * k + k_lo + i], |s, j| d[s * n + j], cb);
    } else {
        gemm_at_small(m, k_lo, k_rows, k, n, a, d, cb);
    }
}

/// Branchless fallback for one k-row block of `gemm_at`.
fn gemm_at_small(
    m: usize,
    k_lo: usize,
    k_rows: usize,
    k: usize,
    n: usize,
    a: &[f32],
    d: &[f32],
    c_block: &mut [f32],
) {
    for i in 0..m {
        let aseg = &a[i * k + k_lo..i * k + k_lo + k_rows];
        let drow = &d[i * n..(i + 1) * n];
        for (kk, &av) in aseg.iter().enumerate() {
            let crow = &mut c_block[kk * n..(kk + 1) * n];
            for j in 0..n {
                crow[j] += av * drow[j];
            }
        }
    }
}

/// c[m,k] += d[m,n] * b[k,n]^T  (input gradient: dy W^T).
pub fn gemm_bt(m: usize, k: usize, n: usize, d: &[f32], b: &[f32], c: &mut [f32]) {
    debug_assert_eq!(d.len(), m * n);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(c.len(), m * k);
    let packed = use_packed(m * k * n);
    let blocks = row_blocks(m, m * k * n);
    if blocks <= 1 {
        return gemm_bt_rows(packed, m, 0, k, n, d, b, c);
    }
    let rows_per = m.div_ceil(blocks);
    std::thread::scope(|s| {
        for (bi, cc) in c.chunks_mut(rows_per * k).enumerate() {
            let rows = cc.len() / k;
            let lo = bi * rows_per;
            s.spawn(move || gemm_bt_rows(packed, rows, lo, k, n, d, b, cc));
        }
    });
}

/// Rows `lo..lo+rows` of the `gemm_bt` output; depth is n.
fn gemm_bt_rows(
    packed: bool,
    rows: usize,
    lo: usize,
    k: usize,
    n: usize,
    d: &[f32],
    b: &[f32],
    cc: &mut [f32],
) {
    if packed {
        gemm_packed(rows, n, k, |i, j| d[(lo + i) * n + j], |j, kk| b[kk * n + j], cc);
    } else {
        gemm_bt_small(rows, k, n, &d[lo * n..(lo + rows) * n], b, cc);
    }
}

/// Dot-product fallback for `gemm_bt` (both operands row-contiguous).
fn gemm_bt_small(m: usize, k: usize, n: usize, d: &[f32], b: &[f32], c: &mut [f32]) {
    for i in 0..m {
        let drow = &d[i * n..(i + 1) * n];
        let crow = &mut c[i * k..(i + 1) * k];
        for kk in 0..k {
            let brow = &b[kk * n..(kk + 1) * n];
            let mut acc = 0.0f32;
            for j in 0..n {
                acc += drow[j] * brow[j];
            }
            crow[kk] += acc;
        }
    }
}

/// The packed-tile core: c[i*n + j] += Σ_s av(i, s) · bv(s, j) for an m×n
/// output with `depth` reduction terms. `av`/`bv` are index accessors so
/// the three GEMM orientations (and their strided/transposed operand
/// views) monomorphize onto this one kernel; packing makes every inner
/// loop read contiguous memory regardless of the source stride.
#[inline(always)]
fn gemm_packed<A, B>(m: usize, depth: usize, n: usize, av: A, bv: B, c: &mut [f32])
where
    A: Fn(usize, usize) -> f32,
    B: Fn(usize, usize) -> f32,
{
    debug_assert_eq!(c.len(), m * n);
    PANELS.with(|cell| {
        let mut panels = cell.borrow_mut();
        let (apack, bpack) = &mut *panels;
        apack.resize(MC * KC, 0.0);
        bpack.resize(KC * NC, 0.0);
        for jc in (0..n).step_by(NC) {
            let nc = NC.min(n - jc);
            for pc in (0..depth).step_by(KC) {
                let kc = KC.min(depth - pc);
                pack_b(&bv, pc, kc, jc, nc, bpack);
                for ic in (0..m).step_by(MC) {
                    let mc = MC.min(m - ic);
                    pack_a(&av, ic, mc, pc, kc, apack);
                    for jr in (0..nc).step_by(NR) {
                        let cols = NR.min(nc - jr);
                        let bp = &bpack[(jr / NR) * (kc * NR)..][..kc * NR];
                        for ir in (0..mc).step_by(MR) {
                            let rows = MR.min(mc - ir);
                            let ap = &apack[(ir / MR) * (kc * MR)..][..kc * MR];
                            let acc = microkernel(kc, ap, bp);
                            for (r, arow) in acc.iter().enumerate().take(rows) {
                                let crow =
                                    &mut c[(ic + ir + r) * n + jc + jr..][..cols];
                                for (cv, &a) in crow.iter_mut().zip(&arow[..cols]) {
                                    *cv += a;
                                }
                            }
                        }
                    }
                }
            }
        }
    });
}

/// Pack the `mc`×`kc` A block starting at (ic, pc) into `MR`-row strips:
/// strip `it` holds rows `ic+it*MR ..`, laid out depth-major so the
/// microkernel reads `MR` consecutive values per depth step. Ragged edge
/// rows are zero-padded (harmless: the padded products are never written
/// back to c).
#[inline(always)]
fn pack_a<A: Fn(usize, usize) -> f32>(
    av: &A,
    ic: usize,
    mc: usize,
    pc: usize,
    kc: usize,
    apack: &mut [f32],
) {
    for (it, ir) in (0..mc).step_by(MR).enumerate() {
        let rows = MR.min(mc - ir);
        let panel = &mut apack[it * kc * MR..(it + 1) * kc * MR];
        for kk in 0..kc {
            for r in 0..MR {
                panel[kk * MR + r] =
                    if r < rows { av(ic + ir + r, pc + kk) } else { 0.0 };
            }
        }
    }
}

/// Pack the `kc`×`nc` B block starting at (pc, jc) into `NR`-column
/// strips, depth-major, zero-padding ragged edge columns.
#[inline(always)]
fn pack_b<B: Fn(usize, usize) -> f32>(
    bv: &B,
    pc: usize,
    kc: usize,
    jc: usize,
    nc: usize,
    bpack: &mut [f32],
) {
    for (jt, jr) in (0..nc).step_by(NR).enumerate() {
        let cols = NR.min(nc - jr);
        let panel = &mut bpack[jt * kc * NR..(jt + 1) * kc * NR];
        for kk in 0..kc {
            for j in 0..NR {
                panel[kk * NR + j] =
                    if j < cols { bv(pc + kk, jc + jr + j) } else { 0.0 };
            }
        }
    }
}

/// The `MR`×`NR` register tile: one packed A strip × one packed B strip
/// over `kc` depth steps. Constant trip counts + branchless body keep the
/// accumulators in registers and let LLVM unroll/vectorize the `NR` loop.
#[inline(always)]
fn microkernel(kc: usize, ap: &[f32], bp: &[f32]) -> [[f32; NR]; MR] {
    let mut acc = [[0f32; NR]; MR];
    for kk in 0..kc {
        let a = &ap[kk * MR..kk * MR + MR];
        let b = &bp[kk * NR..kk * NR + NR];
        for r in 0..MR {
            let ar = a[r];
            let arow = &mut acc[r];
            for j in 0..NR {
                arow[j] += ar * b[j];
            }
        }
    }
    acc
}

/// The pre-microkernel serial kernel, kept verbatim (including its branchy
/// `av == 0.0` skip) as the frozen baseline `benches/bench_gemm.rs`
/// measures speedups against. Not used by any production path.
pub fn gemm_ref(m: usize, k: usize, n: usize, a: &[f32], b: &[f32], c: &mut [f32]) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(c.len(), m * n);
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        let crow = &mut c[i * n..(i + 1) * n];
        for (kk, &av) in arow.iter().enumerate() {
            if av == 0.0 {
                continue;
            }
            let brow = &b[kk * n..(kk + 1) * n];
            for j in 0..n {
                crow[j] += av * brow[j];
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn naive(m: usize, k: usize, n: usize, a: &[f32], b: &[f32]) -> Vec<f32> {
        let mut c = vec![0f32; m * n];
        for i in 0..m {
            for j in 0..n {
                for kk in 0..k {
                    c[i * n + j] += a[i * k + kk] * b[kk * n + j];
                }
            }
        }
        c
    }

    fn naive_at(m: usize, k: usize, n: usize, a: &[f32], d: &[f32]) -> Vec<f32> {
        let mut c = vec![0f32; k * n];
        for kk in 0..k {
            for j in 0..n {
                for i in 0..m {
                    c[kk * n + j] += a[i * k + kk] * d[i * n + j];
                }
            }
        }
        c
    }

    fn naive_bt(m: usize, k: usize, n: usize, d: &[f32], b: &[f32]) -> Vec<f32> {
        let mut c = vec![0f32; m * k];
        for i in 0..m {
            for kk in 0..k {
                for j in 0..n {
                    c[i * k + kk] += d[i * n + j] * b[kk * n + j];
                }
            }
        }
        c
    }

    fn filled(len: usize, seed: u64) -> Vec<f32> {
        let mut r = crate::util::rng::Pcg::seeded(seed);
        (0..len).map(|_| r.normal() as f32).collect()
    }

    fn assert_close(got: &[f32], want: &[f32], label: &str) {
        assert_eq!(got.len(), want.len(), "{label}: length");
        for (i, (x, y)) in got.iter().zip(want).enumerate() {
            assert!(
                (x - y).abs() <= 1e-4 * (1.0 + y.abs()),
                "{label}[{i}]: {x} vs {y}"
            );
        }
    }

    #[test]
    fn gemm_matches_naive() {
        let (m, k, n) = (7, 11, 5);
        let a = filled(m * k, 1);
        let b = filled(k * n, 2);
        let mut c = vec![0f32; m * n];
        gemm(m, k, n, &a, &b, &mut c);
        assert_close(&c, &naive(m, k, n, &a, &b), "gemm small");
    }

    #[test]
    fn gemm_at_is_transpose_product() {
        let (m, k, n) = (6, 4, 3);
        let a = filled(m * k, 3);
        let d = filled(m * n, 4);
        let mut c = vec![0f32; k * n];
        gemm_at(m, k, n, &a, &d, &mut c);
        assert_close(&c, &naive_at(m, k, n, &a, &d), "gemm_at small");
    }

    #[test]
    fn gemm_bt_is_product_transpose() {
        let (m, k, n) = (5, 6, 4);
        let d = filled(m * n, 5);
        let b = filled(k * n, 6);
        let mut c = vec![0f32; m * k];
        gemm_bt(m, k, n, &d, &b, &mut c);
        assert_close(&c, &naive_bt(m, k, n, &d, &b), "gemm_bt small");
    }

    #[test]
    fn gemm_accumulates() {
        let mut c = vec![1.0f32; 1];
        gemm(1, 1, 1, &[2.0], &[3.0], &mut c);
        assert_eq!(c[0], 7.0);
    }

    #[test]
    fn gemm_ref_matches_naive() {
        let (m, k, n) = (9, 13, 6);
        let a = filled(m * k, 21);
        let b = filled(k * n, 22);
        let mut c = vec![0f32; m * n];
        gemm_ref(m, k, n, &a, &b, &mut c);
        assert_close(&c, &naive(m, k, n, &a, &b), "gemm_ref");
    }

    /// Packed microkernel vs the naive oracle across ragged shapes — m, k,
    /// n deliberately not multiples of MR/NR/KC so every zero-padded edge
    /// path runs. Forced through the packed path regardless of size.
    #[test]
    #[cfg_attr(miri, ignore)] // large GEMM sweep, far too slow under miri
    fn packed_matches_naive_ragged_shapes() {
        let shapes = [
            (1usize, 1usize, 1usize),
            (3, 5, 2),
            (4, 8, 8),
            (5, 300, 11),
            (17, 9, 33),
            (33, 64, 17),
            (65, 129, 63),
            (70, 260, 40),
            (128, 33, 9),
            (130, 70, 270),
        ];
        for (si, &(m, k, n)) in shapes.iter().enumerate() {
            let seed = 100 + 3 * si as u64;
            let a = filled(m * k, seed);
            let b = filled(k * n, seed + 1);
            let d = filled(m * n, seed + 2);

            let mut c = vec![0f32; m * n];
            gemm_rows(true, m, 0, k, n, &a, &b, &mut c);
            assert_close(&c, &naive(m, k, n, &a, &b), &format!("packed gemm {m}x{k}x{n}"));

            let mut c = vec![0f32; k * n];
            gemm_at_rows(true, m, 0, k, k, n, &a, &d, &mut c);
            assert_close(
                &c,
                &naive_at(m, k, n, &a, &d),
                &format!("packed gemm_at {m}x{k}x{n}"),
            );

            let mut c = vec![0f32; m * k];
            gemm_bt_rows(true, m, 0, k, n, &d, &b, &mut c);
            assert_close(
                &c,
                &naive_bt(m, k, n, &d, &b),
                &format!("packed gemm_bt {m}x{k}x{n}"),
            );
        }
    }

    /// Packed kernels accumulate (+=) into a pre-filled c, like every
    /// caller (bias rows, gradient slabs) relies on.
    #[test]
    fn packed_accumulates_into_prefilled_c() {
        let (m, k, n) = (37, 41, 23);
        let a = filled(m * k, 31);
        let b = filled(k * n, 32);
        let bias = filled(m * n, 33);
        let mut c = bias.clone();
        gemm_rows(true, m, 0, k, n, &a, &b, &mut c);
        let mut want = naive(m, k, n, &a, &b);
        for (w, &v) in want.iter_mut().zip(&bias) {
            *w += v;
        }
        assert_close(&c, &want, "packed accumulate");
    }

    /// The determinism invariant the threaded dispatch relies on: splitting
    /// the output rows into blocks must be bitwise identical to the
    /// one-shot call, for all three orientations, on the packed path.
    #[test]
    #[cfg_attr(miri, ignore)] // large GEMM sweep, far too slow under miri
    fn packed_row_split_bitwise_equal_one_shot() {
        let (m, k, n) = (70, 90, 50);
        let a = filled(m * k, 7);
        let b = filled(k * n, 8);
        let d = filled(m * n, 9);

        // gemm: split rows of c
        let mut one = vec![0f32; m * n];
        gemm_rows(true, m, 0, k, n, &a, &b, &mut one);
        let mut split = vec![0f32; m * n];
        let rows = 11;
        for (bi, cc) in split.chunks_mut(rows * n).enumerate() {
            let r = cc.len() / n;
            gemm_rows(true, r, bi * rows, k, n, &a, &b, cc);
        }
        assert_eq!(one, split);

        // gemm_at: split rows of c (the k dimension)
        let mut one = vec![0f32; k * n];
        gemm_at_rows(true, m, 0, k, k, n, &a, &d, &mut one);
        let mut split = vec![0f32; k * n];
        let rows = 7;
        for (bi, cc) in split.chunks_mut(rows * n).enumerate() {
            let r = cc.len() / n;
            gemm_at_rows(true, m, bi * rows, r, k, n, &a, &d, cc);
        }
        assert_eq!(one, split);

        // gemm_bt: split rows of c
        let mut one = vec![0f32; m * k];
        gemm_bt_rows(true, m, 0, k, n, &d, &b, &mut one);
        let mut split = vec![0f32; m * k];
        let rows = 9;
        for (bi, cc) in split.chunks_mut(rows * k).enumerate() {
            let r = cc.len() / k;
            gemm_bt_rows(true, r, bi * rows, k, n, &d, &b, cc);
        }
        assert_eq!(one, split);
    }

    /// A call big enough to cross the parallel threshold is bitwise equal
    /// under any thread budget (the public-API form of the invariant).
    #[test]
    #[cfg_attr(miri, ignore)] // large GEMM sweep, far too slow under miri
    fn parallel_dispatch_bitwise_equal_serial() {
        let (m, k, n) = (512, 192, 256); // 25M madds > PAR_FLOP_THRESHOLD
        let a = filled(m * k, 11);
        let b = filled(k * n, 12);
        let mut ser = vec![0f32; m * n];
        threads::with_budget(1, || gemm(m, k, n, &a, &b, &mut ser));
        for t in [2usize, 8] {
            let mut par = vec![0f32; m * n];
            threads::with_budget(t, || gemm(m, k, n, &a, &b, &mut par));
            assert_eq!(ser, par, "budget {t}");
        }
    }
}
