//! Gradient plumbing at the edge server (DESIGN.md S8).

pub mod aggregate;
pub mod guard;

pub use aggregate::{aggregate, staleness_factor, Aggregator};
pub use guard::{GradGuard, GradVerdict, Quarantine, QUARANTINE_NAMES};
