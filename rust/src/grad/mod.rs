//! Gradient plumbing at the edge server (DESIGN.md S8).

pub mod aggregate;

pub use aggregate::{aggregate, staleness_factor, Aggregator};
