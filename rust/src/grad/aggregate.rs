//! Global gradient aggregation (paper eq. 1):
//! `g = (1/|U B_k|) * sum_k |B_k| g_k` — batch-weighted averaging at the
//! edge server.

use anyhow::{bail, Result};

use super::guard::{GradGuard, GradVerdict, Quarantine};

/// Streaming weighted aggregator: server-side state for one period.
///
/// Heterogeneous fleets (`coordinator::fleet_backends`) aggregate one
/// parameter space per *model family*; each aggregator carries its
/// family tag so shards from different families can never merge — even
/// when their parameter counts happen to coincide.
#[derive(Clone, Debug)]
pub struct Aggregator {
    acc: Vec<f64>,
    total_weight: f64,
    contributions: usize,
    /// contributions whose payload was detected corrupt (non-finite, or
    /// over the guard's norm bound) — counted whatever the policy did
    corrupt: usize,
    /// corrupt contributions the guard rejected or clipped
    quarantined: usize,
    /// parameter-space tag (0 for homogeneous fleets)
    family: u32,
}

impl Aggregator {
    pub fn new(p: usize) -> Self {
        Aggregator::for_family(p, 0)
    }

    /// An aggregator for one model family's parameter space. `merge` and
    /// `reduce_shards` reject mixing across family tags.
    pub fn for_family(p: usize, family: u32) -> Self {
        Aggregator {
            acc: vec![0f64; p],
            total_weight: 0.0,
            contributions: 0,
            corrupt: 0,
            quarantined: 0,
            family,
        }
    }

    /// The parameter-space tag this aggregator accepts shards from.
    pub fn family(&self) -> u32 {
        self.family
    }

    /// Clear for the next period, keeping the f64 accumulator allocation —
    /// the server-side aggregator is a long-lived object reset each round,
    /// not reallocated (p can be millions of terms).
    pub fn reset(&mut self) {
        self.acc.iter_mut().for_each(|a| *a = 0.0);
        self.total_weight = 0.0;
        self.contributions = 0;
        self.corrupt = 0;
        self.quarantined = 0;
    }

    /// Add one device's gradient with weight |B_k|.
    ///
    /// A non-finite payload is *accepted* (historical behaviour: eq. 1 is
    /// applied verbatim) but bumps the corrupt counter so a poisoned
    /// round is visible in the log instead of surfacing as an unexplained
    /// NaN loss periods later. Route through [`add_guarded`]
    /// (`Aggregator::add_guarded`) to act on corruption.
    pub fn add(&mut self, grad: &[f32], weight: f64) -> Result<()> {
        if grad.len() != self.acc.len() {
            bail!("gradient length {} != {}", grad.len(), self.acc.len());
        }
        if !(weight > 0.0 && weight.is_finite()) {
            bail!("non-positive weight {weight}");
        }
        let mut finite = true;
        for (a, &g) in self.acc.iter_mut().zip(grad) {
            finite &= g.is_finite();
            *a += weight * g as f64;
        }
        if !finite {
            self.corrupt += 1;
        }
        self.total_weight += weight;
        self.contributions += 1;
        Ok(())
    }

    pub fn contributions(&self) -> usize {
        self.contributions
    }

    /// Contributions whose payload was detected corrupt — non-finite
    /// anywhere on the add path, plus norm outliers on the guarded path.
    pub fn corrupt_contributions(&self) -> usize {
        self.corrupt
    }

    /// Corrupt contributions the quarantine rejected or clipped.
    pub fn quarantined_contributions(&self) -> usize {
        self.quarantined
    }

    /// Screened add: apply the guard's quarantine policy to one payload.
    ///
    /// Verdicts are a pure function of the single payload, so guarded
    /// adds inside sharded reduces stay order-free; with the guard off
    /// the numerics are bitwise-identical to [`add`] (`Aggregator::add`).
    pub fn add_guarded(
        &mut self,
        grad: &[f32],
        weight: f64,
        guard: &GradGuard,
    ) -> Result<GradVerdict> {
        if grad.len() != self.acc.len() {
            bail!("gradient length {} != {}", grad.len(), self.acc.len());
        }
        let finite = grad.iter().all(|g| g.is_finite());
        let outlier = finite
            && guard.checks_norm()
            && grad.iter().map(|&g| g as f64 * g as f64).sum::<f64>().sqrt() > guard.max_norm;
        if finite && !outlier {
            self.add(grad, weight)?;
            return Ok(GradVerdict::Clean);
        }
        match guard.policy {
            Quarantine::Off => {
                // `add` bumps the corrupt counter for non-finite payloads
                // itself; a finite norm outlier it cannot see
                self.add(grad, weight)?;
                if outlier {
                    self.corrupt += 1;
                }
                Ok(GradVerdict::Tainted)
            }
            Quarantine::Abort => {
                if finite {
                    bail!(
                        "quarantine=abort: gradient L2 norm exceeds bound {} \
                         (corrupt payload in a run configured to treat corruption as a bug)",
                        guard.max_norm
                    );
                }
                bail!(
                    "quarantine=abort: non-finite gradient payload \
                     (corrupt payload in a run configured to treat corruption as a bug)"
                );
            }
            Quarantine::Reject => {
                self.corrupt += 1;
                self.quarantined += 1;
                Ok(GradVerdict::Rejected)
            }
            Quarantine::Clip => {
                // sanitize a copy: zero non-finite terms, then rescale the
                // survivor onto the norm bound if it still exceeds it
                let mut clean: Vec<f32> =
                    grad.iter().map(|&g| if g.is_finite() { g } else { 0.0 }).collect();
                if guard.checks_norm() {
                    let norm =
                        clean.iter().map(|&g| g as f64 * g as f64).sum::<f64>().sqrt();
                    if norm > guard.max_norm {
                        let scale = (guard.max_norm / norm) as f32;
                        clean.iter_mut().for_each(|g| *g *= scale);
                    }
                }
                self.add(&clean, weight)?;
                self.corrupt += 1;
                self.quarantined += 1;
                Ok(GradVerdict::Clipped)
            }
        }
    }

    /// Screened [`add_stale`] (`Aggregator::add_stale`): the staleness
    /// discount applies to the weight exactly as on the unguarded path,
    /// then the payload goes through the quarantine.
    pub fn add_stale_guarded(
        &mut self,
        grad: &[f32],
        weight: f64,
        staleness: u64,
        alpha: f64,
        beta: f64,
        guard: &GradGuard,
    ) -> Result<GradVerdict> {
        let w = (weight * staleness_factor(alpha, beta, staleness)).max(f64::MIN_POSITIVE);
        self.add_guarded(grad, w, guard)
    }

    /// Add a contribution drawn under partial participation: the weight is
    /// scaled by the Horvitz–Thompson factor `1 / inclusion_prob`, so the
    /// accumulated *sum* is an unbiased estimate of the full-participation
    /// sum. Under uniform inclusion the factor cancels in [`average`]
    /// (`Aggregator::average`) — the correction matters wherever absolute
    /// totals leave the aggregator (cloud merges, effective-batch
    /// accounting). `inclusion_prob = 1.0` reproduces [`add`]
    /// (`Aggregator::add`) bitwise.
    pub fn add_inverse_prob(
        &mut self,
        grad: &[f32],
        weight: f64,
        inclusion_prob: f64,
    ) -> Result<()> {
        if !(inclusion_prob > 0.0 && inclusion_prob <= 1.0) {
            bail!("inclusion probability must be in (0, 1], got {inclusion_prob}");
        }
        self.add(grad, weight / inclusion_prob)
    }

    /// Staleness-aware add (async rounds, see `sched/`): the gradient
    /// enters eq. 1 with its batch weight discounted by the polynomial
    /// decay `alpha / (1 + s)^beta` ([`staleness_factor`]). At staleness 0
    /// every contribution carries the same `alpha`, which cancels in the
    /// weighted average — so an all-fresh async round aggregates exactly
    /// like the synchronous path.
    pub fn add_stale(
        &mut self,
        grad: &[f32],
        weight: f64,
        staleness: u64,
        alpha: f64,
        beta: f64,
    ) -> Result<()> {
        // an extreme decay can underflow the discount to exactly 0.0
        // ((1 + s)^beta overflows to inf for large beta); floor it so an
        // ancient gradient degrades to a negligible contribution instead
        // of tripping `add`'s positive-weight guard mid-run
        let w = (weight * staleness_factor(alpha, beta, staleness)).max(f64::MIN_POSITIVE);
        self.add(grad, w)
    }

    /// Merge another aggregator's partial state (a *shard*) into this one.
    /// Accumulation is f64 throughout, so merging contiguous shards in
    /// device order reproduces the order the streaming `add` path would
    /// have used per shard; cross-shard grouping differs only by f64
    /// addition reassociation (exact for integer-valued contributions).
    pub fn merge(&mut self, other: &Aggregator) -> Result<()> {
        if other.family != self.family {
            bail!(
                "cross-family shard merge: family {} into family {} (heterogeneous fleets \
                 aggregate one parameter space per model family)",
                other.family,
                self.family
            );
        }
        if other.acc.len() != self.acc.len() {
            bail!("shard length {} != {}", other.acc.len(), self.acc.len());
        }
        for (a, &b) in self.acc.iter_mut().zip(&other.acc) {
            *a += b;
        }
        self.total_weight += other.total_weight;
        self.contributions += other.contributions;
        self.corrupt += other.corrupt;
        self.quarantined += other.quarantined;
        Ok(())
    }

    /// Reduce a set of shard aggregators into one by a *sequential fold in
    /// the given order* — deliberately not a pairwise tree: the f64
    /// grouping is part of the bitwise-reproducibility contract, so the
    /// combine order must stay fixed (shards are produced in device order
    /// and merged in device order).
    pub fn reduce_shards(shards: Vec<Aggregator>) -> Result<Aggregator> {
        let mut it = shards.into_iter();
        let mut root = it.next().ok_or_else(|| anyhow::anyhow!("no shards to reduce"))?;
        for s in it {
            root.merge(&s)?;
        }
        Ok(root)
    }

    /// The batch-weighted average (eq. 1) without consuming the
    /// accumulator, so a reused server-side aggregator can emit one global
    /// gradient per period across its lifetime.
    pub fn average(&self) -> Result<Vec<f32>> {
        if self.contributions == 0 {
            bail!("no gradients aggregated");
        }
        let w = self.total_weight;
        Ok(self.acc.iter().map(|a| (a / w) as f32).collect())
    }

    /// Finish: the batch-weighted average (eq. 1), consuming form.
    pub fn finish(self) -> Result<Vec<f32>> {
        self.average()
    }
}

/// Polynomial staleness discount `alpha / (1 + s)^beta` (FedAsync-style):
/// a gradient computed `s` server rounds ago keeps `alpha` of its weight
/// at `s = 0` and decays polynomially from there.
pub fn staleness_factor(alpha: f64, beta: f64, staleness: u64) -> f64 {
    alpha / (1.0 + staleness as f64).powf(beta)
}

/// One-shot convenience: aggregate a slice of (grad, weight) pairs.
pub fn aggregate(grads: &[(&[f32], f64)]) -> Result<Vec<f32>> {
    let p = grads
        .first()
        .map(|(g, _)| g.len())
        .ok_or_else(|| anyhow::anyhow!("empty aggregation"))?;
    let mut agg = Aggregator::new(p);
    for (g, w) in grads {
        agg.add(g, *w)?;
    }
    agg.finish()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn weighted_average_eq1() {
        let g1 = vec![1.0f32, 2.0];
        let g2 = vec![3.0f32, 4.0];
        // B1 = 1, B2 = 3 -> g = (1*g1 + 3*g2)/4 = [2.5, 3.5]
        let out = aggregate(&[(&g1, 1.0), (&g2, 3.0)]).unwrap();
        assert_eq!(out, vec![2.5, 3.5]);
    }

    #[test]
    fn equal_weights_plain_mean() {
        let g1 = vec![2.0f32];
        let g2 = vec![4.0f32];
        let g3 = vec![6.0f32];
        let out = aggregate(&[(&g1, 5.0), (&g2, 5.0), (&g3, 5.0)]).unwrap();
        assert_eq!(out, vec![4.0]);
    }

    #[test]
    fn rejects_mismatched_length() {
        let mut a = Aggregator::new(3);
        assert!(a.add(&[1.0, 2.0], 1.0).is_err());
    }

    #[test]
    fn rejects_bad_weight() {
        let mut a = Aggregator::new(1);
        assert!(a.add(&[1.0], 0.0).is_err());
        assert!(a.add(&[1.0], -2.0).is_err());
        assert!(a.add(&[1.0], f64::NAN).is_err());
    }

    #[test]
    fn inverse_prob_scales_weight_and_full_prob_is_identity() {
        let g = vec![2.0f32, -4.0];
        let mut a = Aggregator::new(2);
        a.add_inverse_prob(&g, 3.0, 0.25).unwrap();
        let mut b = Aggregator::new(2);
        b.add(&g, 12.0).unwrap();
        assert_eq!(a.average().unwrap(), b.average().unwrap());
        // probability 1.0 divides by exactly 1.0: bitwise add()
        let mut c = Aggregator::new(2);
        c.add_inverse_prob(&g, 3.0, 1.0).unwrap();
        let mut d = Aggregator::new(2);
        d.add(&g, 3.0).unwrap();
        assert_eq!(c.average().unwrap(), d.average().unwrap());
        // out-of-range probabilities are rejected
        for p in [0.0, -0.5, 1.5, f64::NAN] {
            assert!(a.add_inverse_prob(&g, 3.0, p).is_err(), "prob {p}");
        }
    }

    #[test]
    fn rejects_empty_finish() {
        assert!(Aggregator::new(2).finish().is_err());
    }

    #[test]
    fn merge_rejects_mismatched_shards() {
        let mut a = Aggregator::new(3);
        let b = Aggregator::new(2);
        assert!(a.merge(&b).is_err());
        assert!(Aggregator::reduce_shards(Vec::new()).is_err());
    }

    #[test]
    fn merge_rejects_cross_family_shards() {
        // same parameter count, different model family: still rejected
        let mut a = Aggregator::for_family(4, 0);
        let mut b = Aggregator::for_family(4, 1);
        b.add(&[1.0; 4], 2.0).unwrap();
        let err = a.merge(&b).unwrap_err().to_string();
        assert!(err.contains("cross-family"), "{err}");
        assert!(Aggregator::reduce_shards(vec![
            Aggregator::for_family(4, 0),
            Aggregator::for_family(4, 1),
        ])
        .is_err());
        // same family merges fine and keeps the tag
        let mut c = Aggregator::for_family(4, 1);
        c.merge(&b).unwrap();
        assert_eq!(c.family(), 1);
        assert_eq!(c.contributions(), 1);
        assert_eq!(Aggregator::new(4).family(), 0);
    }

    #[test]
    fn shard_merge_equals_streaming_add() {
        // integer-valued grads/weights: f64 sums are exact, so shard-merge
        // must equal the device-order streaming path *bitwise*
        let grads: Vec<Vec<f32>> = (0..8)
            .map(|k| (0..16).map(|i| ((k * 31 + i * 7) % 23) as f32 - 11.0).collect())
            .collect();
        let mut stream = Aggregator::new(16);
        for (k, g) in grads.iter().enumerate() {
            stream.add(g, (k + 1) as f64).unwrap();
        }
        let shards: Vec<Aggregator> = grads
            .chunks(3)
            .enumerate()
            .map(|(ci, ch)| {
                let mut a = Aggregator::new(16);
                for (j, g) in ch.iter().enumerate() {
                    a.add(g, (ci * 3 + j + 1) as f64).unwrap();
                }
                a
            })
            .collect();
        let merged = Aggregator::reduce_shards(shards).unwrap();
        assert_eq!(merged.contributions(), stream.contributions());
        let a = stream.finish().unwrap();
        let b = merged.finish().unwrap();
        assert_eq!(a, b);
    }

    #[test]
    fn reset_reuse_equals_fresh() {
        let g1 = vec![1.0f32, -2.0, 4.0];
        let g2 = vec![0.5f32, 3.0, -1.0];
        let mut reused = Aggregator::new(3);
        reused.add(&g1, 2.0).unwrap();
        reused.add(&g2, 1.0).unwrap();
        let first = reused.average().unwrap();
        // reset and run a different period through the same accumulator
        reused.reset();
        assert_eq!(reused.contributions(), 0);
        reused.add(&g2, 5.0).unwrap();
        let mut fresh = Aggregator::new(3);
        fresh.add(&g2, 5.0).unwrap();
        assert_eq!(reused.average().unwrap(), fresh.average().unwrap());
        // average() is repeatable and agrees with finish()
        assert_eq!(reused.average().unwrap(), reused.clone().finish().unwrap());
        assert_ne!(first, reused.average().unwrap());
        // reset clears the "has contributions" state too
        reused.reset();
        assert!(reused.average().is_err());
    }

    #[test]
    fn empty_shard_merge_property() {
        // a deadline round can hand the reducer shards where *every*
        // device was dropped: merging an empty shard must be a bitwise
        // no-op anywhere in the fold, and an all-empty reduce must surface
        // the "no gradients" error instead of emitting zeros
        let mut rng = crate::util::rng::Pcg::seeded(7);
        for trial in 0..20u64 {
            let p = 32;
            let k = 1 + (trial % 5) as usize;
            let grads: Vec<Vec<f32>> =
                (0..k).map(|_| (0..p).map(|_| rng.normal() as f32).collect()).collect();
            // interleave an empty shard before, between, and after the
            // real per-device shards
            let mut shards: Vec<Aggregator> = Vec::new();
            shards.push(Aggregator::new(p)); // leading empty
            for (i, g) in grads.iter().enumerate() {
                let mut a = Aggregator::new(p);
                a.add(g, (i + 1) as f64).unwrap();
                shards.push(a);
                shards.push(Aggregator::new(p)); // trailing empties
            }
            let merged = Aggregator::reduce_shards(shards).unwrap();
            assert_eq!(merged.contributions(), k, "trial {trial}");
            let mut dense = Aggregator::new(p);
            for (i, g) in grads.iter().enumerate() {
                dense.add(g, (i + 1) as f64).unwrap();
            }
            assert_eq!(
                merged.finish().unwrap(),
                dense.finish().unwrap(),
                "trial {trial}: empty shards must not perturb the fold"
            );
        }
        // all shards empty: contributions stay 0 and averaging errors
        let all_empty: Vec<Aggregator> = (0..4).map(|_| Aggregator::new(8)).collect();
        let merged = Aggregator::reduce_shards(all_empty).unwrap();
        assert_eq!(merged.contributions(), 0);
        assert!(merged.average().is_err());
        // merging an empty shard of the wrong width is still rejected
        let mut a = Aggregator::new(8);
        assert!(a.merge(&Aggregator::new(4)).is_err());
    }

    #[test]
    fn staleness_factor_decay() {
        // s = 0 keeps alpha; decay is monotone in s and steeper in beta
        assert_eq!(staleness_factor(0.6, 0.5, 0), 0.6);
        assert_eq!(staleness_factor(1.0, 0.0, 9), 1.0); // beta 0: no decay
        let f1 = staleness_factor(0.6, 0.5, 1);
        let f2 = staleness_factor(0.6, 0.5, 2);
        assert!(f1 < 0.6 && f2 < f1);
        assert!(staleness_factor(0.6, 2.0, 1) < f1);
        assert!((f1 - 0.6 / 2f64.sqrt()).abs() < 1e-15);
    }

    #[test]
    fn add_stale_discounts_weight() {
        let g1 = vec![1.0f32, 0.0];
        let g2 = vec![0.0f32, 1.0];
        // fresh-only aggregation at uniform staleness == plain aggregation
        let mut fresh = Aggregator::new(2);
        fresh.add_stale(&g1, 2.0, 0, 0.6, 0.5).unwrap();
        fresh.add_stale(&g2, 2.0, 0, 0.6, 0.5).unwrap();
        assert_eq!(fresh.finish().unwrap(), vec![0.5, 0.5]);
        // a stale gradient is down-weighted against a fresh one:
        // beta = 1, s = 3 -> factor 1/4 of alpha
        let mut mixed = Aggregator::new(2);
        mixed.add_stale(&g1, 4.0, 0, 1.0, 1.0).unwrap();
        mixed.add_stale(&g2, 4.0, 3, 1.0, 1.0).unwrap();
        let out = mixed.finish().unwrap();
        assert!((out[0] - 0.8).abs() < 1e-7, "{out:?}");
        assert!((out[1] - 0.2).abs() < 1e-7, "{out:?}");
        // an extreme decay ((1+s)^beta = inf -> factor 0) degrades to a
        // negligible contribution, never to a mid-run error
        let mut extreme = Aggregator::new(2);
        extreme.add_stale(&g1, 4.0, 0, 1.0, 400.0).unwrap();
        extreme.add_stale(&g2, 4.0, 9, 1.0, 400.0).unwrap();
        let out = extreme.finish().unwrap();
        assert!((out[0] - 1.0).abs() < 1e-7, "{out:?}");
        assert!(out[1].abs() < 1e-7, "{out:?}");
    }

    #[test]
    fn nan_contribution_is_counted_not_silent() {
        // satellite: even with quarantine off, a NaN payload must be
        // countable — numerics unchanged, counter bumped
        let mut a = Aggregator::new(2);
        a.add(&[1.0, 2.0], 1.0).unwrap();
        assert_eq!(a.corrupt_contributions(), 0);
        a.add(&[f32::NAN, 0.0], 1.0).unwrap();
        a.add(&[0.0, f32::INFINITY], 2.0).unwrap();
        assert_eq!(a.contributions(), 3);
        assert_eq!(a.corrupt_contributions(), 2);
        assert_eq!(a.quarantined_contributions(), 0);
        assert!(a.average().unwrap()[0].is_nan());
        // stale adds scan too
        let mut s = Aggregator::new(1);
        s.add_stale(&[f32::NEG_INFINITY], 1.0, 2, 0.6, 0.5).unwrap();
        assert_eq!(s.corrupt_contributions(), 1);
        // reset clears the new counters with everything else
        a.reset();
        assert_eq!(a.corrupt_contributions(), 0);
        assert_eq!(a.quarantined_contributions(), 0);
    }

    #[test]
    fn guarded_add_off_is_bitwise_plain_add() {
        let g = vec![1.5f32, -2.25, f32::NAN];
        let off = GradGuard::off();
        let mut guarded = Aggregator::new(3);
        let v = guarded.add_guarded(&g, 2.0, &off).unwrap();
        assert_eq!(v, GradVerdict::Tainted);
        let mut plain = Aggregator::new(3);
        plain.add(&g, 2.0).unwrap();
        assert_eq!(guarded.acc, plain.acc);
        assert_eq!(guarded.total_weight.to_bits(), plain.total_weight.to_bits());
        assert_eq!(guarded.corrupt_contributions(), plain.corrupt_contributions());
        // clean payloads come back Clean under any policy
        let clip = GradGuard::new(Quarantine::Clip, 100.0).unwrap();
        let mut c = Aggregator::new(2);
        assert_eq!(c.add_guarded(&[3.0, 4.0], 1.0, &clip).unwrap(), GradVerdict::Clean);
        assert_eq!(c.corrupt_contributions(), 0);
        // off + finite bound: norm outliers are added untouched but counted
        let watch = GradGuard::new(Quarantine::Off, 1.0).unwrap();
        let mut w = Aggregator::new(2);
        assert_eq!(w.add_guarded(&[3.0, 4.0], 1.0, &watch).unwrap(), GradVerdict::Tainted);
        assert_eq!(w.corrupt_contributions(), 1);
        assert_eq!(w.quarantined_contributions(), 0);
        assert_eq!(w.average().unwrap(), vec![3.0, 4.0]);
    }

    #[test]
    fn guarded_reject_drops_corrupt_payloads() {
        let guard = GradGuard::new(Quarantine::Reject, 10.0).unwrap();
        let mut a = Aggregator::new(2);
        assert_eq!(a.add_guarded(&[1.0, 1.0], 1.0, &guard).unwrap(), GradVerdict::Clean);
        assert_eq!(a.add_guarded(&[f32::NAN, 1.0], 1.0, &guard).unwrap(), GradVerdict::Rejected);
        // finite but over the norm bound: also rejected
        assert_eq!(a.add_guarded(&[30.0, 40.0], 1.0, &guard).unwrap(), GradVerdict::Rejected);
        assert_eq!(a.contributions(), 1);
        assert_eq!(a.corrupt_contributions(), 2);
        assert_eq!(a.quarantined_contributions(), 2);
        assert_eq!(a.average().unwrap(), vec![1.0, 1.0]);
        // length mismatch still errors before any screening
        assert!(a.add_guarded(&[1.0], 1.0, &guard).is_err());
    }

    #[test]
    fn guarded_clip_sanitizes_and_rescales() {
        let guard = GradGuard::new(Quarantine::Clip, 5.0).unwrap();
        // 3-4-5 triangle scaled by 10: norm 50, clipped back to 5
        let mut a = Aggregator::new(2);
        assert_eq!(a.add_guarded(&[30.0, 40.0], 1.0, &guard).unwrap(), GradVerdict::Clipped);
        let out = a.average().unwrap();
        assert!((out[0] - 3.0).abs() < 1e-5 && (out[1] - 4.0).abs() < 1e-5, "{out:?}");
        assert_eq!(a.quarantined_contributions(), 1);
        // non-finite terms are zeroed before the norm is taken
        let mut b = Aggregator::new(3);
        assert_eq!(
            b.add_guarded(&[f32::INFINITY, 3.0, 4.0], 2.0, &guard).unwrap(),
            GradVerdict::Clipped
        );
        let out = b.average().unwrap();
        assert_eq!(out[0], 0.0);
        assert!((out[1] - 3.0).abs() < 1e-5 && (out[2] - 4.0).abs() < 1e-5, "{out:?}");
        // an all-NaN payload clips to zeros (dilutes, never poisons)
        let mut z = Aggregator::new(2);
        z.add_guarded(&[1.0, 1.0], 1.0, &guard).unwrap();
        z.add_guarded(&[f32::NAN, f32::NAN], 1.0, &guard).unwrap();
        assert_eq!(z.average().unwrap(), vec![0.5, 0.5]);
    }

    #[test]
    fn guarded_abort_fails_loudly() {
        let guard = GradGuard::new(Quarantine::Abort, 10.0).unwrap();
        let mut a = Aggregator::new(2);
        assert_eq!(a.add_guarded(&[1.0, 2.0], 1.0, &guard).unwrap(), GradVerdict::Clean);
        let err = a.add_guarded(&[f32::NAN, 0.0], 1.0, &guard).unwrap_err().to_string();
        assert!(err.contains("non-finite"), "{err}");
        let err = a.add_guarded(&[30.0, 40.0], 1.0, &guard).unwrap_err().to_string();
        assert!(err.contains("norm"), "{err}");
        // nothing partial was applied
        assert_eq!(a.contributions(), 1);
        assert_eq!(a.average().unwrap(), vec![1.0, 2.0]);
    }

    #[test]
    fn add_stale_guarded_discounts_like_unguarded() {
        let guard = GradGuard::new(Quarantine::Reject, 100.0).unwrap();
        let mut a = Aggregator::new(2);
        a.add_stale_guarded(&[4.0, 0.0], 4.0, 0, 1.0, 1.0, &guard).unwrap();
        a.add_stale_guarded(&[0.0, 4.0], 4.0, 3, 1.0, 1.0, &guard).unwrap();
        let mut b = Aggregator::new(2);
        b.add_stale(&[4.0, 0.0], 4.0, 0, 1.0, 1.0).unwrap();
        b.add_stale(&[0.0, 4.0], 4.0, 3, 1.0, 1.0).unwrap();
        assert_eq!(a.average().unwrap(), b.average().unwrap());
        // a stale corrupt payload is still screened
        assert_eq!(
            a.add_stale_guarded(&[f32::NAN, 0.0], 4.0, 1, 1.0, 1.0, &guard).unwrap(),
            GradVerdict::Rejected
        );
    }

    #[test]
    fn merge_sums_corruption_counters() {
        let guard = GradGuard::new(Quarantine::Reject, 10.0).unwrap();
        let mut a = Aggregator::new(2);
        a.add(&[f32::NAN, 0.0], 1.0).unwrap();
        let mut b = Aggregator::new(2);
        b.add_guarded(&[f32::NAN, 0.0], 1.0, &guard).unwrap();
        b.add(&[1.0, 1.0], 1.0).unwrap();
        a.merge(&b).unwrap();
        assert_eq!(a.corrupt_contributions(), 2);
        assert_eq!(a.quarantined_contributions(), 1);
        assert_eq!(a.contributions(), 2);
    }

    #[test]
    fn numerically_stable_many_contributions() {
        // f64 accumulation: a million tiny contributions keep precision
        let mut a = Aggregator::new(1);
        for _ in 0..1_000_000 {
            a.add(&[1e-3], 1.0).unwrap();
        }
        let out = a.finish().unwrap();
        assert!((out[0] - 1e-3).abs() < 1e-9, "{}", out[0]);
    }
}
