//! Gradient quarantine: server-side payload screening at the aggregation
//! seam.
//!
//! A production fleet uploads what it uploads — diverged devices send
//! NaN/Inf payloads, byzantine or faulty radios send garbage with huge
//! norms. Today every contribution flows straight into the server
//! accumulator; one poisoned payload turns the global model into NaN a
//! few periods later with nothing in the log to explain it. The
//! [`GradGuard`] closes that seam: every contribution is screened for
//! non-finite values and (optionally) an L2-norm bound, and the
//! configured [`Quarantine`] policy decides what happens to offenders —
//! count-only, reject, sanitize-and-clip, or abort the round.
//!
//! The guard is deliberately *stateless and order-free*: verdicts are a
//! pure function of the single payload, so screening inside sharded
//! reduces stays bitwise thread-invariant. With the guard off, screened
//! adds are bitwise-identical to unscreened ones — offenders are merely
//! counted (`Aggregator::corrupt_contributions`), never altered.

use anyhow::{bail, Result};

/// Accepted `fault.quarantine` values (CLI/config errors print this).
pub const QUARANTINE_NAMES: &str = "off | reject | clip | abort";

/// What to do with a corrupt (non-finite or norm-outlier) contribution.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum Quarantine {
    /// accept and count — today's numerics, bitwise, but visible
    #[default]
    Off,
    /// drop the contribution from the aggregate (counted as quarantined)
    Reject,
    /// sanitize: zero non-finite terms, rescale to the norm bound
    Clip,
    /// fail the round loudly — for runs where corruption means a bug
    Abort,
}

impl Quarantine {
    pub fn parse(s: &str) -> Option<Quarantine> {
        match s {
            "off" => Some(Quarantine::Off),
            "reject" => Some(Quarantine::Reject),
            "clip" => Some(Quarantine::Clip),
            "abort" => Some(Quarantine::Abort),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Quarantine::Off => "off",
            Quarantine::Reject => "reject",
            Quarantine::Clip => "clip",
            Quarantine::Abort => "abort",
        }
    }
}

/// The quarantine policy plus its detection threshold.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct GradGuard {
    pub policy: Quarantine,
    /// L2-norm bound above which a (finite) contribution counts as an
    /// outlier; `f64::INFINITY` disables the norm check
    pub max_norm: f64,
}

impl Default for GradGuard {
    fn default() -> Self {
        GradGuard::off()
    }
}

impl GradGuard {
    /// No screening beyond the always-on non-finite count.
    pub fn off() -> GradGuard {
        GradGuard { policy: Quarantine::Off, max_norm: f64::INFINITY }
    }

    /// Checked constructor (the config/CLI surfaces funnel through here).
    pub fn new(policy: Quarantine, max_norm: f64) -> Result<GradGuard> {
        if !(max_norm > 0.0) {
            bail!("quarantine norm bound must be > 0, got {max_norm}");
        }
        Ok(GradGuard { policy, max_norm })
    }

    /// Whether this guard can alter aggregation (reject/clip/abort). An
    /// `Off` guard — even with a finite norm bound — only counts.
    pub fn is_active(&self) -> bool {
        self.policy != Quarantine::Off
    }

    /// Whether the norm screen is on at all.
    pub fn checks_norm(&self) -> bool {
        self.max_norm.is_finite()
    }
}

/// What the guard decided about one contribution.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum GradVerdict {
    /// finite, within the norm bound: added untouched
    Clean,
    /// corrupt but the policy is `Off`: added untouched, counted
    Tainted,
    /// corrupt under `Clip`: sanitized/rescaled copy added, counted
    Clipped,
    /// corrupt under `Reject`: not added, counted
    Rejected,
}

impl GradVerdict {
    /// Did the contribution (possibly sanitized) enter the aggregate?
    pub fn applied(&self) -> bool {
        !matches!(self, GradVerdict::Rejected)
    }

    /// Was the payload detected corrupt (whatever the policy did)?
    pub fn corrupt(&self) -> bool {
        !matches!(self, GradVerdict::Clean)
    }

    /// Stable name for trace events and metrics.
    pub fn label(&self) -> &'static str {
        match self {
            GradVerdict::Clean => "clean",
            GradVerdict::Tainted => "tainted",
            GradVerdict::Clipped => "clipped",
            GradVerdict::Rejected => "rejected",
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_and_names_roundtrip() {
        for q in [Quarantine::Off, Quarantine::Reject, Quarantine::Clip, Quarantine::Abort] {
            assert_eq!(Quarantine::parse(q.name()), Some(q));
        }
        assert_eq!(Quarantine::parse("fifo"), None);
        assert!(QUARANTINE_NAMES.contains("reject") && QUARANTINE_NAMES.contains("abort"));
    }

    #[test]
    fn guard_validates_norm_bound() {
        assert!(GradGuard::new(Quarantine::Reject, 0.0).is_err());
        assert!(GradGuard::new(Quarantine::Reject, -1.0).is_err());
        assert!(GradGuard::new(Quarantine::Reject, f64::NAN).is_err());
        let g = GradGuard::new(Quarantine::Reject, 10.0).unwrap();
        assert!(g.is_active() && g.checks_norm());
        // infinity is a legal bound: non-finite screening only
        let g = GradGuard::new(Quarantine::Clip, f64::INFINITY).unwrap();
        assert!(g.is_active() && !g.checks_norm());
        // off + finite bound = detection-only observability
        let g = GradGuard::new(Quarantine::Off, 5.0).unwrap();
        assert!(!g.is_active() && g.checks_norm());
        assert_eq!(GradGuard::default(), GradGuard::off());
    }

    #[test]
    fn verdict_predicates() {
        assert!(GradVerdict::Clean.applied() && !GradVerdict::Clean.corrupt());
        assert!(GradVerdict::Tainted.applied() && GradVerdict::Tainted.corrupt());
        assert!(GradVerdict::Clipped.applied() && GradVerdict::Clipped.corrupt());
        assert!(!GradVerdict::Rejected.applied() && GradVerdict::Rejected.corrupt());
    }
}
