//! Dataset partitioning across devices (paper §VI-A):
//!
//! * IID — shuffle all samples, split into K equal parts;
//! * non-IID (pathological) — sort by label, split into 2K shards of size
//!   N/(2K), give each device two shards (most devices see only two digits);
//! * Dirichlet(α) — per-class device shares drawn from Dir(α) (Hsu et al.
//!   style label skew): α → 0 approaches one-class devices, α → ∞
//!   approaches IID. The knob the hierarchical topology uses to control
//!   per-cell data skew.

use crate::data::synthetic::Dataset;
use crate::util::rng::Pcg;

/// Partition kind.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Partition {
    Iid,
    NonIid,
    /// Label-Dirichlet skew: for every class, device shares ~ Dir(alpha).
    Dirichlet { alpha: f64 },
}

impl Partition {
    /// Parse a partition name: `iid`, `noniid`/`non-iid`/`non_iid`, or
    /// `dirichlet[:alpha]` (alpha defaults to 0.5; must be finite and
    /// positive).
    pub fn parse(s: &str) -> Option<Partition> {
        match s {
            "iid" => Some(Partition::Iid),
            "noniid" | "non-iid" | "non_iid" => Some(Partition::NonIid),
            _ => {
                let rest = s.strip_prefix("dirichlet")?;
                let alpha = match rest.strip_prefix(':') {
                    Some(a) => a.parse::<f64>().ok()?,
                    None if rest.is_empty() => 0.5,
                    None => return None,
                };
                (alpha.is_finite() && alpha > 0.0).then_some(Partition::Dirichlet { alpha })
            }
        }
    }
}

/// Per-device sample indices into the global dataset.
pub fn partition(ds: &Dataset, k: usize, kind: Partition, rng: &mut Pcg) -> Vec<Vec<usize>> {
    assert!(k >= 1 && ds.len() >= 2 * k, "dataset too small for K={k}");
    match kind {
        Partition::Iid => {
            let mut idx: Vec<usize> = (0..ds.len()).collect();
            rng.shuffle(&mut idx);
            chunk_even(&idx, k)
        }
        Partition::NonIid => {
            // sort by label (stable on index for determinism)
            let mut idx: Vec<usize> = (0..ds.len()).collect();
            idx.sort_by_key(|&i| (ds.y[i], i));
            // 2K shards, each device gets two (randomly paired)
            let shards = chunk_even(&idx, 2 * k);
            let mut order: Vec<usize> = (0..2 * k).collect();
            rng.shuffle(&mut order);
            (0..k)
                .map(|d| {
                    let mut s = shards[order[2 * d]].clone();
                    s.extend_from_slice(&shards[order[2 * d + 1]]);
                    s
                })
                .collect()
        }
        Partition::Dirichlet { alpha } => dirichlet_partition(ds, k, alpha, rng),
    }
}

/// Label-Dirichlet partition: every class's samples are split across the
/// K devices proportionally to a Dir(alpha) draw (cumulative rounding, so
/// coverage is exact and deterministic given the RNG). Devices left with
/// fewer than one sample are topped up from the largest shard — the
/// `DeviceData` sampler requires a non-empty shard on every device.
fn dirichlet_partition(ds: &Dataset, k: usize, alpha: f64, rng: &mut Pcg) -> Vec<Vec<usize>> {
    assert!(alpha.is_finite() && alpha > 0.0, "dirichlet alpha must be positive, got {alpha}");
    let mut by_class: Vec<Vec<usize>> = vec![Vec::new(); ds.classes];
    for i in 0..ds.len() {
        by_class[ds.y[i] as usize].push(i);
    }
    let mut out: Vec<Vec<usize>> = vec![Vec::new(); k];
    for class_idx in by_class.iter_mut() {
        if class_idx.is_empty() {
            continue;
        }
        rng.shuffle(class_idx);
        let mut w: Vec<f64> = (0..k).map(|_| rng.gamma(alpha)).collect();
        let total: f64 = w.iter().sum();
        if !(total > 0.0 && total.is_finite()) {
            // a tiny alpha can underflow every gamma draw to 0: degrade to
            // an even split instead of a 0/0 share
            w = vec![1.0; k];
        }
        let total: f64 = w.iter().sum();
        let n = class_idx.len();
        let mut start = 0usize;
        let mut cum = 0f64;
        for (d, &wd) in w.iter().enumerate() {
            cum += wd;
            let end = if d + 1 == k {
                n
            } else {
                (((cum / total) * n as f64).round() as usize).clamp(start, n)
            };
            out[d].extend_from_slice(&class_idx[start..end]);
            start = end;
        }
    }
    // non-empty-shard guarantee: move one sample at a time from the
    // currently-largest shard (ties broken by highest device id — a
    // deterministic rule, not an RNG draw)
    for d in 0..k {
        while out[d].is_empty() {
            let donor = (0..k)
                .filter(|&j| j != d && out[j].len() > 1)
                .max_by_key(|&j| out[j].len())
                // lint: allow(panic-path): ds.len() >= 2K (checked above) guarantees a donor
                .expect("ds.len() >= 2K guarantees a donor shard");
            // lint: allow(panic-path): donor filter requires len() > 1
            let s = out[donor].pop().expect("donor shard is non-empty");
            out[d].push(s);
        }
    }
    out
}

fn chunk_even(idx: &[usize], parts: usize) -> Vec<Vec<usize>> {
    let n = idx.len();
    let base = n / parts;
    let rem = n % parts;
    let mut out = Vec::with_capacity(parts);
    let mut off = 0;
    for p in 0..parts {
        let sz = base + usize::from(p < rem);
        out.push(idx[off..off + sz].to_vec());
        off += sz;
    }
    out
}

/// Even split sizes for `n` items over `parts` buckets (first buckets take
/// the remainder) — the same arithmetic `chunk_even` uses, exported for
/// callers that only need the shape (e.g. `hier::CellTopology`).
pub fn split_sizes(n: usize, parts: usize) -> Vec<usize> {
    assert!(parts >= 1, "split into zero parts");
    let base = n / parts;
    let rem = n % parts;
    (0..parts).map(|p| base + usize::from(p < rem)).collect()
}

/// Number of distinct labels a device sees (non-IID diagnostics).
pub fn label_diversity(ds: &Dataset, part: &[usize]) -> usize {
    let mut seen = vec![false; ds.classes];
    for &i in part {
        seen[ds.y[i] as usize] = true;
    }
    seen.iter().filter(|&&s| s).count()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{generate, SynthConfig};

    fn ds() -> Dataset {
        generate(&SynthConfig { dim: 8, ..Default::default() }, 1200, 5)
    }

    #[test]
    fn covers_all_samples_disjointly() {
        let ds = ds();
        let mut rng = Pcg::seeded(1);
        for kind in [
            Partition::Iid,
            Partition::NonIid,
            Partition::Dirichlet { alpha: 0.3 },
        ] {
            let parts = partition(&ds, 12, kind, &mut rng);
            let mut all: Vec<usize> = parts.iter().flatten().copied().collect();
            all.sort_unstable();
            assert_eq!(all, (0..ds.len()).collect::<Vec<_>>(), "{kind:?}");
        }
    }

    #[test]
    fn sizes_even() {
        let ds = ds();
        let mut rng = Pcg::seeded(2);
        let parts = partition(&ds, 6, Partition::NonIid, &mut rng);
        for p in &parts {
            assert_eq!(p.len(), 200);
        }
    }

    #[test]
    fn split_sizes_shape() {
        assert_eq!(split_sizes(10, 3), vec![4, 3, 3]);
        assert_eq!(split_sizes(9, 3), vec![3, 3, 3]);
        assert_eq!(split_sizes(2, 4), vec![1, 1, 0, 0]);
        assert_eq!(split_sizes(0, 2), vec![0, 0]);
    }

    #[test]
    fn iid_has_full_label_diversity() {
        let ds = ds();
        let mut rng = Pcg::seeded(3);
        let parts = partition(&ds, 12, Partition::Iid, &mut rng);
        for p in &parts {
            assert_eq!(label_diversity(&ds, p), 10);
        }
    }

    #[test]
    fn noniid_is_pathological() {
        let ds = ds();
        let mut rng = Pcg::seeded(4);
        let parts = partition(&ds, 12, Partition::NonIid, &mut rng);
        // every device sees at most ~3 labels (2 shards, shard boundaries
        // can straddle one label change each)
        for p in &parts {
            let div = label_diversity(&ds, p);
            assert!(div <= 4, "device sees {div} labels");
        }
        // and collectively the distribution is skewed vs IID
        let avg: f64 = parts
            .iter()
            .map(|p| label_diversity(&ds, p) as f64)
            .sum::<f64>()
            / 12.0;
        assert!(avg < 4.0, "avg diversity {avg}");
    }

    /// The fraction of a shard taken by its most-common label: ~1/classes
    /// under IID, approaching 1 as alpha -> 0.
    fn max_label_frac(ds: &Dataset, part: &[usize]) -> f64 {
        let mut counts = vec![0usize; ds.classes];
        for &i in part {
            counts[ds.y[i] as usize] += 1;
        }
        *counts.iter().max().unwrap() as f64 / part.len().max(1) as f64
    }

    #[test]
    fn dirichlet_alpha_controls_label_skew() {
        let ds = ds();
        // small alpha: strongly skewed shards (each dominated by few labels)
        let mut rng = Pcg::seeded(6);
        let skewed = partition(&ds, 12, Partition::Dirichlet { alpha: 0.1 }, &mut rng);
        let skew: f64 =
            skewed.iter().map(|p| max_label_frac(&ds, p)).sum::<f64>() / skewed.len() as f64;
        // large alpha: near-uniform label mix, like IID
        let mut rng = Pcg::seeded(6);
        let flat = partition(&ds, 12, Partition::Dirichlet { alpha: 100.0 }, &mut rng);
        let uniform: f64 =
            flat.iter().map(|p| max_label_frac(&ds, p)).sum::<f64>() / flat.len() as f64;
        assert!(skew > 0.35, "alpha 0.1 mean max-label share {skew}");
        assert!(uniform < 0.2, "alpha 100 mean max-label share {uniform}");
        assert!(skew > 1.5 * uniform, "{skew} vs {uniform}");
        // skewed shards also lose label diversity relative to IID's 10/10
        let avg_div: f64 = skewed
            .iter()
            .map(|p| label_diversity(&ds, p) as f64)
            .sum::<f64>()
            / skewed.len() as f64;
        assert!(avg_div < 8.0, "alpha 0.1 avg diversity {avg_div}");
    }

    #[test]
    fn dirichlet_every_shard_non_empty_at_extreme_alpha() {
        let ds = ds();
        let mut rng = Pcg::seeded(7);
        let parts = partition(&ds, 24, Partition::Dirichlet { alpha: 0.01 }, &mut rng);
        assert_eq!(parts.len(), 24);
        for (d, p) in parts.iter().enumerate() {
            assert!(!p.is_empty(), "device {d} got an empty shard");
        }
        let total: usize = parts.iter().map(|p| p.len()).sum();
        assert_eq!(total, ds.len());
    }

    #[test]
    fn deterministic_given_seed() {
        let ds = ds();
        for kind in [Partition::NonIid, Partition::Dirichlet { alpha: 0.3 }] {
            let a = partition(&ds, 6, kind, &mut Pcg::seeded(9));
            let b = partition(&ds, 6, kind, &mut Pcg::seeded(9));
            assert_eq!(a, b, "{kind:?}");
        }
    }

    #[test]
    fn parse_kind() {
        assert_eq!(Partition::parse("iid"), Some(Partition::Iid));
        assert_eq!(Partition::parse("non-iid"), Some(Partition::NonIid));
        assert_eq!(Partition::parse("dirichlet:0.3"), Some(Partition::Dirichlet { alpha: 0.3 }));
        assert_eq!(Partition::parse("dirichlet"), Some(Partition::Dirichlet { alpha: 0.5 }));
        assert_eq!(Partition::parse("dirichlet:"), None);
        assert_eq!(Partition::parse("dirichlet:x"), None);
        assert_eq!(Partition::parse("dirichlet:-1"), None);
        assert_eq!(Partition::parse("dirichlet:0"), None);
        assert_eq!(Partition::parse("dirichlet:nan"), None);
        assert_eq!(Partition::parse("dirichletx"), None);
        assert_eq!(Partition::parse("x"), None);
    }
}
