//! Dataset partitioning across devices (paper §VI-A):
//!
//! * IID — shuffle all samples, split into K equal parts;
//! * non-IID (pathological) — sort by label, split into 2K shards of size
//!   N/(2K), give each device two shards (most devices see only two digits).

use crate::data::synthetic::Dataset;
use crate::util::rng::Pcg;

/// Partition kind.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Partition {
    Iid,
    NonIid,
}

impl Partition {
    pub fn parse(s: &str) -> Option<Partition> {
        match s {
            "iid" => Some(Partition::Iid),
            "noniid" | "non-iid" | "non_iid" => Some(Partition::NonIid),
            _ => None,
        }
    }
}

/// Per-device sample indices into the global dataset.
pub fn partition(ds: &Dataset, k: usize, kind: Partition, rng: &mut Pcg) -> Vec<Vec<usize>> {
    assert!(k >= 1 && ds.len() >= 2 * k, "dataset too small for K={k}");
    match kind {
        Partition::Iid => {
            let mut idx: Vec<usize> = (0..ds.len()).collect();
            rng.shuffle(&mut idx);
            chunk_even(&idx, k)
        }
        Partition::NonIid => {
            // sort by label (stable on index for determinism)
            let mut idx: Vec<usize> = (0..ds.len()).collect();
            idx.sort_by_key(|&i| (ds.y[i], i));
            // 2K shards, each device gets two (randomly paired)
            let shards = chunk_even(&idx, 2 * k);
            let mut order: Vec<usize> = (0..2 * k).collect();
            rng.shuffle(&mut order);
            (0..k)
                .map(|d| {
                    let mut s = shards[order[2 * d]].clone();
                    s.extend_from_slice(&shards[order[2 * d + 1]]);
                    s
                })
                .collect()
        }
    }
}

fn chunk_even(idx: &[usize], parts: usize) -> Vec<Vec<usize>> {
    let n = idx.len();
    let base = n / parts;
    let rem = n % parts;
    let mut out = Vec::with_capacity(parts);
    let mut off = 0;
    for p in 0..parts {
        let sz = base + usize::from(p < rem);
        out.push(idx[off..off + sz].to_vec());
        off += sz;
    }
    out
}

/// Number of distinct labels a device sees (non-IID diagnostics).
pub fn label_diversity(ds: &Dataset, part: &[usize]) -> usize {
    let mut seen = vec![false; ds.classes];
    for &i in part {
        seen[ds.y[i] as usize] = true;
    }
    seen.iter().filter(|&&s| s).count()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{generate, SynthConfig};

    fn ds() -> Dataset {
        generate(&SynthConfig { dim: 8, ..Default::default() }, 1200, 5)
    }

    #[test]
    fn covers_all_samples_disjointly() {
        let ds = ds();
        let mut rng = Pcg::seeded(1);
        for kind in [Partition::Iid, Partition::NonIid] {
            let parts = partition(&ds, 12, kind, &mut rng);
            let mut all: Vec<usize> = parts.iter().flatten().copied().collect();
            all.sort_unstable();
            assert_eq!(all, (0..ds.len()).collect::<Vec<_>>(), "{kind:?}");
        }
    }

    #[test]
    fn sizes_even() {
        let ds = ds();
        let mut rng = Pcg::seeded(2);
        let parts = partition(&ds, 6, Partition::NonIid, &mut rng);
        for p in &parts {
            assert_eq!(p.len(), 200);
        }
    }

    #[test]
    fn iid_has_full_label_diversity() {
        let ds = ds();
        let mut rng = Pcg::seeded(3);
        let parts = partition(&ds, 12, Partition::Iid, &mut rng);
        for p in &parts {
            assert_eq!(label_diversity(&ds, p), 10);
        }
    }

    #[test]
    fn noniid_is_pathological() {
        let ds = ds();
        let mut rng = Pcg::seeded(4);
        let parts = partition(&ds, 12, Partition::NonIid, &mut rng);
        // every device sees at most ~3 labels (2 shards, shard boundaries
        // can straddle one label change each)
        for p in &parts {
            let div = label_diversity(&ds, p);
            assert!(div <= 4, "device sees {div} labels");
        }
        // and collectively the distribution is skewed vs IID
        let avg: f64 = parts
            .iter()
            .map(|p| label_diversity(&ds, p) as f64)
            .sum::<f64>()
            / 12.0;
        assert!(avg < 4.0, "avg diversity {avg}");
    }

    #[test]
    fn deterministic_given_seed() {
        let ds = ds();
        let a = partition(&ds, 6, Partition::NonIid, &mut Pcg::seeded(9));
        let b = partition(&ds, 6, Partition::NonIid, &mut Pcg::seeded(9));
        assert_eq!(a, b);
    }

    #[test]
    fn parse_kind() {
        assert_eq!(Partition::parse("iid"), Some(Partition::Iid));
        assert_eq!(Partition::parse("non-iid"), Some(Partition::NonIid));
        assert_eq!(Partition::parse("x"), None);
    }
}
