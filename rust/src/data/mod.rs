//! Data substrate (DESIGN.md S12): synthetic CIFAR-10 stand-in, IID /
//! pathological non-IID partitioning, per-device batch sampling.

pub mod loader;
pub mod partition;
pub mod synthetic;

pub use loader::DeviceData;
pub use partition::{label_diversity, partition, Partition};
pub use synthetic::{generate, Dataset, SynthConfig};
