//! Synthetic CIFAR-10 stand-in (DESIGN.md §3 substitution).
//!
//! The offline environment has no real dataset, so we generate a
//! deterministic 10-class image distribution that is non-trivially
//! learnable: each class has a smooth random "prototype image" (low
//! frequency structure via separable random features); a sample is
//! `prototype + within-class deformation + pixel noise`, normalized
//! per-feature. The classes overlap enough that accuracy saturates below
//! 100% and loss curves have the familiar decay shape — which is what the
//! paper's experiments measure (relative scheme ordering, not absolute
//! CIFAR numbers).

use crate::util::rng::Pcg;

/// A labeled dataset with row-major features.
#[derive(Clone, Debug)]
pub struct Dataset {
    pub x: Vec<f32>,
    pub y: Vec<i32>,
    pub dim: usize,
    pub classes: usize,
}

impl Dataset {
    pub fn len(&self) -> usize {
        self.y.len()
    }

    pub fn is_empty(&self) -> bool {
        self.y.is_empty()
    }

    pub fn row(&self, i: usize) -> &[f32] {
        &self.x[i * self.dim..(i + 1) * self.dim]
    }

    /// Gather rows into a dense batch (x, y).
    pub fn gather(&self, idx: &[usize]) -> (Vec<f32>, Vec<i32>) {
        let mut x = Vec::with_capacity(idx.len() * self.dim);
        let mut y = Vec::with_capacity(idx.len());
        for &i in idx {
            x.extend_from_slice(self.row(i));
            y.push(self.y[i]);
        }
        (x, y)
    }

    /// A new dataset holding the selected rows, in the given order — the
    /// per-cell slice the hierarchical topology hands each edge server.
    /// Selecting `0..len` in order reproduces the dataset bitwise (the
    /// flat-trainer degenerate case of `hier::CellTopology`).
    pub fn subset(&self, idx: &[usize]) -> Dataset {
        let (x, y) = self.gather(idx);
        Dataset { x, y, dim: self.dim, classes: self.classes }
    }
}

/// Generation parameters.
#[derive(Clone, Copy, Debug)]
pub struct SynthConfig {
    pub dim: usize,
    pub classes: usize,
    /// class-prototype magnitude (signal)
    pub signal: f64,
    /// within-class structured deformation magnitude
    pub deform: f64,
    /// i.i.d. pixel noise magnitude
    pub noise: f64,
    /// rank of the within-class deformation subspace
    pub deform_rank: usize,
}

impl Default for SynthConfig {
    fn default() -> Self {
        SynthConfig {
            dim: 768, // 16x16x3
            classes: 10,
            signal: 1.0,
            deform: 0.8,
            noise: 0.6,
            deform_rank: 8,
        }
    }
}

/// Generate `n` samples with balanced class counts (as balanced as n allows).
pub fn generate(cfg: &SynthConfig, n: usize, seed: u64) -> Dataset {
    let mut rng = Pcg::seeded(seed ^ 0x5eed_da7a);
    let d = cfg.dim;
    let c = cfg.classes;
    // class prototypes: smooth-ish random vectors (sum of a few separable
    // random features keeps them correlated across dimensions)
    let mut protos = vec![0f32; c * d];
    for cls in 0..c {
        for _ in 0..4 {
            let freq = rng.range_f64(0.5, 4.0);
            let phase = rng.range_f64(0.0, std::f64::consts::TAU);
            let amp = cfg.signal * rng.range_f64(0.3, 1.0);
            for j in 0..d {
                let t = j as f64 / d as f64;
                protos[cls * d + j] +=
                    (amp * (std::f64::consts::TAU * freq * t + phase).sin()) as f32;
            }
        }
    }
    // within-class deformation directions (shared subspace per class)
    let r = cfg.deform_rank;
    let mut dirs = vec![0f32; c * r * d];
    for v in dirs.iter_mut() {
        *v = (rng.normal() / (d as f64).sqrt()) as f32;
    }

    let mut x = vec![0f32; n * d];
    let mut y = vec![0i32; n];
    for i in 0..n {
        let cls = i % c; // balanced
        y[i] = cls as i32;
        let row = &mut x[i * d..(i + 1) * d];
        row.copy_from_slice(&protos[cls * d..(cls + 1) * d]);
        // structured deformation
        for rr in 0..r {
            let coef = (cfg.deform * rng.normal()) as f32 * (d as f64).sqrt() as f32;
            let dir = &dirs[(cls * r + rr) * d..(cls * r + rr + 1) * d];
            for (p, &dv) in row.iter_mut().zip(dir) {
                *p += coef * dv;
            }
        }
        // pixel noise
        for p in row.iter_mut() {
            *p += (cfg.noise * rng.normal()) as f32;
        }
    }
    // global feature standardization (train-time preprocessing stand-in)
    for j in 0..d {
        let mut mean = 0f64;
        for i in 0..n {
            mean += x[i * d + j] as f64;
        }
        mean /= n as f64;
        let mut var = 0f64;
        for i in 0..n {
            let v = x[i * d + j] as f64 - mean;
            var += v * v;
        }
        let std = (var / n as f64).sqrt().max(1e-6);
        for i in 0..n {
            x[i * d + j] = ((x[i * d + j] as f64 - mean) / std) as f32;
        }
    }
    Dataset { x, y, dim: d, classes: c }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let cfg = SynthConfig { dim: 32, ..Default::default() };
        let a = generate(&cfg, 100, 7);
        let b = generate(&cfg, 100, 7);
        assert_eq!(a.x, b.x);
        assert_eq!(a.y, b.y);
        let c = generate(&cfg, 100, 8);
        assert_ne!(a.x, c.x);
    }

    #[test]
    fn balanced_labels() {
        let cfg = SynthConfig { dim: 16, ..Default::default() };
        let ds = generate(&cfg, 1000, 1);
        let mut counts = [0usize; 10];
        for &y in &ds.y {
            counts[y as usize] += 1;
        }
        assert!(counts.iter().all(|&c| c == 100));
    }

    #[test]
    fn standardized_features() {
        let cfg = SynthConfig { dim: 24, ..Default::default() };
        let ds = generate(&cfg, 2000, 2);
        for j in 0..ds.dim {
            let mut mean = 0f64;
            let mut var = 0f64;
            for i in 0..ds.len() {
                mean += ds.x[i * ds.dim + j] as f64;
            }
            mean /= ds.len() as f64;
            for i in 0..ds.len() {
                let v = ds.x[i * ds.dim + j] as f64 - mean;
                var += v * v;
            }
            var /= ds.len() as f64;
            assert!(mean.abs() < 1e-3, "feature {j} mean {mean}");
            assert!((var - 1.0).abs() < 1e-2, "feature {j} var {var}");
        }
    }

    #[test]
    fn classes_linearly_separable_in_part() {
        // nearest-prototype classification on held-out data must beat chance
        // decisively (the data carries class signal).
        let cfg = SynthConfig { dim: 64, ..Default::default() };
        let train = generate(&cfg, 2000, 3);
        let test = generate(&cfg, 500, 3); // same generator -> same protos
        let d = cfg.dim;
        let c = cfg.classes;
        // class means from train
        let mut means = vec![0f32; c * d];
        let mut counts = vec![0f32; c];
        for i in 0..train.len() {
            let cls = train.y[i] as usize;
            counts[cls] += 1.0;
            for j in 0..d {
                means[cls * d + j] += train.x[i * d + j];
            }
        }
        for cls in 0..c {
            for j in 0..d {
                means[cls * d + j] /= counts[cls];
            }
        }
        let mut correct = 0;
        for i in 0..test.len() {
            let row = test.row(i);
            let mut best = (f32::INFINITY, 0usize);
            for cls in 0..c {
                let m = &means[cls * d..(cls + 1) * d];
                let dist: f32 = row.iter().zip(m).map(|(a, b)| (a - b) * (a - b)).sum();
                if dist < best.0 {
                    best = (dist, cls);
                }
            }
            if best.1 == test.y[i] as usize {
                correct += 1;
            }
        }
        let acc = correct as f64 / test.len() as f64;
        assert!(acc > 0.35, "nearest-prototype acc {acc} barely above chance");
        assert!(acc < 0.999, "data degenerate (perfectly separable): {acc}");
    }

    #[test]
    fn gather_rows() {
        let cfg = SynthConfig { dim: 8, ..Default::default() };
        let ds = generate(&cfg, 50, 4);
        let (x, y) = ds.gather(&[3, 10, 49]);
        assert_eq!(x.len(), 3 * 8);
        assert_eq!(y, vec![ds.y[3], ds.y[10], ds.y[49]]);
        assert_eq!(&x[8..16], ds.row(10));
    }

    #[test]
    fn subset_rows_and_identity() {
        let cfg = SynthConfig { dim: 8, ..Default::default() };
        let ds = generate(&cfg, 50, 4);
        let sub = ds.subset(&[10, 3]);
        assert_eq!(sub.len(), 2);
        assert_eq!(sub.row(0), ds.row(10));
        assert_eq!(sub.row(1), ds.row(3));
        assert_eq!(sub.y, vec![ds.y[10], ds.y[3]]);
        assert_eq!((sub.dim, sub.classes), (ds.dim, ds.classes));
        // the in-order full subset is the dataset, bitwise
        let all: Vec<usize> = (0..ds.len()).collect();
        let full = ds.subset(&all);
        assert_eq!(full.x, ds.x);
        assert_eq!(full.y, ds.y);
    }
}
