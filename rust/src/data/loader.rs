//! Per-device batch sampling (paper step 1: "each device randomly selects a
//! subset of data B_k from the local dataset").

use crate::data::synthetic::Dataset;
use crate::util::rng::Pcg;

/// Total lexicographic order on feature rows via `f32::total_cmp`.
/// `<[f32] as PartialOrd>::partial_cmp(..).unwrap()` panics the moment a
/// row carries a NaN (a corrupt reading, an upstream overflow); this
/// order sorts NaN rows deterministically instead, so sort/dedup passes
/// over sampled batches survive them.
pub fn row_cmp(a: &[f32], b: &[f32]) -> std::cmp::Ordering {
    for (x, y) in a.iter().zip(b) {
        let o = x.total_cmp(y);
        if o != std::cmp::Ordering::Equal {
            return o;
        }
    }
    a.len().cmp(&b.len())
}

/// A device's local shard + sampler state.
#[derive(Clone, Debug)]
pub struct DeviceData {
    /// indices into the global dataset owned by this device
    pub indices: Vec<usize>,
    rng: Pcg,
}

impl DeviceData {
    pub fn new(indices: Vec<usize>, rng: Pcg) -> Self {
        assert!(!indices.is_empty(), "device with empty shard");
        DeviceData { indices, rng }
    }

    pub fn len(&self) -> usize {
        self.indices.len()
    }

    pub fn is_empty(&self) -> bool {
        self.indices.is_empty()
    }

    /// Sample a batch of `b` rows without replacement (with replacement if
    /// `b` exceeds the shard, which the paper's B^max <= N_k precludes but
    /// tiny test shards may hit), advancing the shard's own sampler state.
    pub fn sample(&mut self, ds: &Dataset, b: usize) -> (Vec<f32>, Vec<i32>) {
        let mut rng = self.rng.clone();
        let out = self.sample_with(ds, b, &mut rng);
        self.rng = rng;
        out
    }

    /// The shard sampler's RNG registers, for checkpoint serialization.
    pub fn rng_state(&self) -> (u64, u64) {
        self.rng.state()
    }

    /// Restore checkpointed sampler registers verbatim.
    pub fn restore_rng_state(&mut self, state: u64, inc: u64) {
        self.rng = Pcg::from_state(state, inc);
    }

    /// Same sampling, but driven by an externally-supplied RNG. The exec
    /// engine derives one per `(seed, period, device)` so batch selection
    /// is independent of execution order and thread count.
    pub fn sample_with(&self, ds: &Dataset, b: usize, rng: &mut Pcg) -> (Vec<f32>, Vec<i32>) {
        assert!(b >= 1);
        let picks: Vec<usize> = if b <= self.indices.len() {
            rng.sample_indices(self.indices.len(), b)
                .into_iter()
                .map(|j| self.indices[j])
                .collect()
        } else {
            (0..b)
                .map(|_| self.indices[rng.below(self.indices.len() as u64) as usize])
                .collect()
        };
        ds.gather(&picks)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{generate, SynthConfig};

    #[test]
    fn samples_only_own_shard() {
        let ds = generate(&SynthConfig { dim: 4, ..Default::default() }, 100, 1);
        let own: Vec<usize> = (40..60).collect();
        let own_rows: Vec<Vec<f32>> = own.iter().map(|&i| ds.row(i).to_vec()).collect();
        let mut dd = DeviceData::new(own.clone(), Pcg::seeded(2));
        for _ in 0..20 {
            let (x, _) = dd.sample(&ds, 5);
            for r in x.chunks(4) {
                assert!(own_rows.iter().any(|o| o == r));
            }
        }
    }

    #[test]
    fn without_replacement_distinct() {
        let ds = generate(&SynthConfig { dim: 4, ..Default::default() }, 100, 1);
        let mut dd = DeviceData::new((0..50).collect(), Pcg::seeded(3));
        let (x, _) = dd.sample(&ds, 50);
        let mut rows: Vec<&[f32]> = x.chunks(4).collect();
        rows.sort_by(|a, b| row_cmp(a, b));
        rows.dedup();
        assert_eq!(rows.len(), 50);
    }

    #[test]
    fn nan_rows_sort_without_panicking() {
        // regression: the old `partial_cmp(..).unwrap()` comparator
        // panicked on the first NaN row; `row_cmp` is a total order
        let mut ds = generate(&SynthConfig { dim: 4, ..Default::default() }, 40, 1);
        // poison one feature of row 3 and all of row 7
        ds.x[3 * 4 + 1] = f32::NAN;
        for v in ds.x[7 * 4..8 * 4].iter_mut() {
            *v = f32::NAN;
        }
        let mut dd = DeviceData::new((0..20).collect(), Pcg::seeded(8));
        let (x, _) = dd.sample(&ds, 20);
        let mut rows: Vec<&[f32]> = x.chunks(4).collect();
        rows.sort_by(|a, b| row_cmp(a, b));
        rows.dedup_by(|a, b| row_cmp(a, b) == std::cmp::Ordering::Equal);
        // all 20 sampled rows are distinct, NaN rows included
        assert_eq!(rows.len(), 20);
        // and the result is actually ordered under the total order
        for w in rows.windows(2) {
            assert_ne!(row_cmp(w[0], w[1]), std::cmp::Ordering::Greater);
        }
    }

    #[test]
    fn row_cmp_total_order_on_nans() {
        use std::cmp::Ordering;
        let nan = f32::NAN;
        assert_eq!(row_cmp(&[1.0, nan], &[1.0, nan]), Ordering::Equal);
        assert_eq!(row_cmp(&[1.0], &[1.0, 2.0]), Ordering::Less);
        // total_cmp: every NaN has a defined place (positive NaN sorts
        // above +inf), so comparisons never panic and stay antisymmetric
        let a = [nan, 0.0];
        let b = [1.0, 0.0];
        assert_eq!(row_cmp(&a, &b), row_cmp(&b, &a).reverse());
    }

    #[test]
    fn oversample_with_replacement() {
        let ds = generate(&SynthConfig { dim: 4, ..Default::default() }, 100, 1);
        let mut dd = DeviceData::new((0..10).collect(), Pcg::seeded(4));
        let (x, y) = dd.sample(&ds, 32);
        assert_eq!(x.len(), 32 * 4);
        assert_eq!(y.len(), 32);
    }
}
