//! Uniform gradient quantization (paper: d-bit quantization of every
//! gradient term before transmission; d = 64 in the experiments, i.e.
//! effectively lossless — smaller d trades accuracy for bits, which the
//! ablation bench sweeps).

/// d-bit symmetric uniform quantizer over the tensor's own dynamic range.
#[derive(Clone, Copy, Debug)]
pub struct Quantizer {
    pub bits: u32,
}

/// A quantized gradient: scale + integer codes (the wire format's
/// information content; we keep codes as i64 for simulation).
#[derive(Clone, Debug)]
pub struct Quantized {
    pub scale: f32,
    pub codes: Vec<i64>,
    pub bits: u32,
}

impl Quantizer {
    pub fn new(bits: u32) -> Self {
        assert!((1..=64).contains(&bits), "bits in 1..=64");
        Quantizer { bits }
    }

    /// Quantize; d >= 32 is treated as lossless passthrough (codes hold the
    /// f32 bit patterns) matching the paper's d = 64 setting.
    pub fn encode(&self, g: &[f32]) -> Quantized {
        if self.bits >= 32 {
            return Quantized {
                scale: 1.0,
                codes: g.iter().map(|&v| v.to_bits() as i64).collect(),
                bits: self.bits,
            };
        }
        let max = g.iter().fold(0f32, |m, &v| m.max(v.abs()));
        let levels = (1i64 << (self.bits - 1)) - 1; // symmetric
        let scale = if max > 0.0 { max / levels as f32 } else { 1.0 };
        let codes = g
            .iter()
            .map(|&v| ((v / scale).round() as i64).clamp(-levels, levels))
            .collect();
        Quantized { scale, codes, bits: self.bits }
    }

    pub fn decode(&self, q: &Quantized) -> Vec<f32> {
        if q.bits >= 32 {
            return q.codes.iter().map(|&c| f32::from_bits(c as u32)).collect();
        }
        q.codes.iter().map(|&c| c as f32 * q.scale).collect()
    }

    /// Wire size in bits of a quantized vector (codes only; scale is O(1)).
    pub fn wire_bits(&self, n: usize) -> u64 {
        self.bits as u64 * n as u64
    }

    /// Worst-case absolute error of one round trip.
    pub fn max_error(&self, g: &[f32]) -> f32 {
        if self.bits >= 32 {
            return 0.0;
        }
        let max = g.iter().fold(0f32, |m, &v| m.max(v.abs()));
        let levels = (1i64 << (self.bits - 1)) - 1;
        0.5 * max / levels as f32
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg;

    fn grads(n: usize, seed: u64) -> Vec<f32> {
        let mut r = Pcg::seeded(seed);
        (0..n).map(|_| r.normal() as f32 * 0.1).collect()
    }

    #[test]
    fn lossless_at_32_plus_bits() {
        let g = grads(1000, 1);
        for bits in [32, 64] {
            let q = Quantizer::new(bits);
            let out = q.decode(&q.encode(&g));
            assert_eq!(out, g);
        }
    }

    #[test]
    fn error_bounded_by_half_step() {
        let g = grads(5000, 2);
        for bits in [4, 8, 12, 16] {
            let q = Quantizer::new(bits);
            let enc = q.encode(&g);
            let out = q.decode(&enc);
            // half-step bound plus a small slack for f32 scale rounding
            let bound = q.max_error(&g) * (1.0 + 1e-2) + f32::EPSILON;
            for (a, b) in g.iter().zip(&out) {
                assert!((a - b).abs() <= bound, "{bits} bits: |{a}-{b}| > {bound}");
            }
        }
    }

    #[test]
    fn error_decreases_with_bits() {
        let g = grads(5000, 3);
        let mut prev = f32::INFINITY;
        for bits in [4, 6, 8, 10, 12] {
            let q = Quantizer::new(bits);
            let out = q.decode(&q.encode(&g));
            let mse: f32 = g
                .iter()
                .zip(&out)
                .map(|(a, b)| (a - b) * (a - b))
                .sum::<f32>()
                / g.len() as f32;
            assert!(mse <= prev, "{bits} bits mse {mse} > prev {prev}");
            prev = mse;
        }
    }

    #[test]
    fn zero_vector_roundtrip() {
        let q = Quantizer::new(8);
        let g = vec![0f32; 100];
        assert_eq!(q.decode(&q.encode(&g)), g);
    }

    #[test]
    fn wire_bits_counts() {
        assert_eq!(Quantizer::new(8).wire_bits(1000), 8000);
        assert_eq!(Quantizer::new(64).wire_bits(10), 640);
    }
}
