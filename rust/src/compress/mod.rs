//! Gradient compression substrate (DESIGN.md S9): d-bit quantization and
//! sparse binary compression with error feedback. Determines the wire size
//! `s = r * d * p` the latency model uses, and injects the real compression
//! error into the learning loop.

pub mod quantize;
pub mod sbc;

pub use quantize::{Quantized, Quantizer};
pub use sbc::{Sbc, SbcMessage};
