//! Sparse binary compression (paper ref [24], Sattler et al. 2018) — the
//! gradient compressor the experiments use with ratio r = 0.005.
//!
//! Encoder: keep the top-k entries by magnitude (k = round(r_sparse * p)),
//! split survivors by sign, replace each group by its mean magnitude, and
//! transmit {mean+, mean-, positions}. Positions dominate the wire size;
//! with distance (golomb-ish) coding the paper's effective total ratio is
//! r = 0.005 of the raw d*p bits — we account wire size analytically and
//! also implement a real bit-accurate position coder for the tests.
//!
//! The *residual* (error feedback) stays on the device and is added to the
//! next period's gradient — without it, top-k compression stalls training.

/// SBC encoder/decoder with error feedback.
#[derive(Clone, Debug)]
pub struct Sbc {
    /// fraction of entries kept (sparsity), e.g. 0.005
    pub keep_frac: f64,
    /// per-device residual from error feedback
    residual: Vec<f32>,
}

/// Encoded message.
#[derive(Clone, Debug)]
pub struct SbcMessage {
    pub len: usize,
    pub mean_pos: f32,
    pub mean_neg: f32,
    /// kept positions with sign (+: true)
    pub entries: Vec<(u32, bool)>,
}

impl Sbc {
    pub fn new(keep_frac: f64, p: usize) -> Self {
        assert!(keep_frac > 0.0 && keep_frac <= 1.0);
        Sbc { keep_frac, residual: vec![0f32; p] }
    }

    /// Number of entries kept for a vector of length `p`.
    pub fn k_of(&self, p: usize) -> usize {
        ((self.keep_frac * p as f64).round() as usize).clamp(1, p)
    }

    /// Encode `g` (adding the residual first), update the residual.
    pub fn encode(&mut self, g: &[f32]) -> SbcMessage {
        let p = g.len();
        assert_eq!(p, self.residual.len(), "gradient length changed");
        let mut acc: Vec<f32> = g
            .iter()
            .zip(&self.residual)
            .map(|(a, r)| a + r)
            .collect();
        let k = self.k_of(p);
        // threshold = k-th largest |value| via select_nth
        let mut mags: Vec<f32> = acc.iter().map(|v| v.abs()).collect();
        let kth = {
            let idx = p - k;
            // total_cmp: a NaN gradient term (diverged training) must not
            // panic the compressor mid-round; identical ordering for
            // normal values (magnitudes are never -0.0)
            mags.select_nth_unstable_by(idx, |a, b| a.total_cmp(b));
            mags[idx]
        };
        let mut pos_sum = 0f64;
        let mut pos_n = 0usize;
        let mut neg_sum = 0f64;
        let mut neg_n = 0usize;
        let mut entries = Vec::with_capacity(k);
        for (i, &v) in acc.iter().enumerate() {
            if v.abs() >= kth && entries.len() < k && v != 0.0 {
                if v > 0.0 {
                    pos_sum += v as f64;
                    pos_n += 1;
                } else {
                    neg_sum += (-v) as f64;
                    neg_n += 1;
                }
                entries.push((i as u32, v > 0.0));
            }
        }
        let mean_pos = if pos_n > 0 { (pos_sum / pos_n as f64) as f32 } else { 0.0 };
        let mean_neg = if neg_n > 0 { (neg_sum / neg_n as f64) as f32 } else { 0.0 };
        // residual: what we did not transmit
        for &(i, b_pos) in &entries {
            let i = i as usize;
            let sent = if b_pos { mean_pos } else { -mean_neg };
            acc[i] -= sent;
        }
        self.residual.copy_from_slice(&acc);
        SbcMessage { len: p, mean_pos, mean_neg, entries }
    }

    /// Decode into a dense vector.
    pub fn decode(msg: &SbcMessage) -> Vec<f32> {
        let mut out = vec![0f32; msg.len];
        for &(i, pos) in &msg.entries {
            out[i as usize] = if pos { msg.mean_pos } else { -msg.mean_neg };
        }
        out
    }

    /// Wire size in bits: positions as log2(p) each + 2 f32 means + signs.
    pub fn wire_bits(msg: &SbcMessage) -> u64 {
        let pos_bits = (msg.len as f64).log2().ceil() as u64;
        msg.entries.len() as u64 * (pos_bits + 1) + 2 * 32
    }

    /// Effective compression ratio vs raw d-bit dense transmission.
    pub fn ratio(msg: &SbcMessage, dense_bits_per_term: u32) -> f64 {
        Sbc::wire_bits(msg) as f64 / (msg.len as u64 * dense_bits_per_term as u64) as f64
    }

    pub fn residual_norm(&self) -> f64 {
        self.residual.iter().map(|&v| (v as f64) * (v as f64)).sum::<f64>().sqrt()
    }

    /// The error-feedback residual, for checkpoint serialization —
    /// device-local state that must survive a resume for bitwise replay.
    pub fn residual(&self) -> &[f32] {
        &self.residual
    }

    /// Restore a checkpointed residual (length must match the parameter
    /// space this compressor was built for).
    pub fn restore_residual(&mut self, residual: Vec<f32>) -> anyhow::Result<()> {
        if residual.len() != self.residual.len() {
            anyhow::bail!(
                "residual length {} != {} (checkpoint from a different model?)",
                residual.len(),
                self.residual.len()
            );
        }
        self.residual = residual;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg;

    fn grads(n: usize, seed: u64) -> Vec<f32> {
        let mut r = Pcg::seeded(seed);
        (0..n).map(|_| r.normal() as f32).collect()
    }

    #[test]
    fn keeps_exactly_k() {
        let mut sbc = Sbc::new(0.01, 10_000);
        let msg = sbc.encode(&grads(10_000, 1));
        assert_eq!(msg.entries.len(), 100);
    }

    #[test]
    fn decode_sparsity_and_signs() {
        let mut sbc = Sbc::new(0.05, 1000);
        let g = grads(1000, 2);
        let msg = sbc.encode(&g);
        let out = Sbc::decode(&msg);
        let nz = out.iter().filter(|&&v| v != 0.0).count();
        assert_eq!(nz, msg.entries.len());
        for &(i, pos) in &msg.entries {
            let v = out[i as usize];
            assert_eq!(v > 0.0, pos);
        }
    }

    #[test]
    fn top_k_selected() {
        // the kept positions must be the k largest |g + residual| (residual
        // starts at 0 so just |g|)
        let mut sbc = Sbc::new(0.01, 1000);
        let g = grads(1000, 3);
        let msg = sbc.encode(&g);
        let mut mags: Vec<f32> = g.iter().map(|v| v.abs()).collect();
        mags.sort_by(|a, b| b.total_cmp(a));
        let kth = mags[msg.entries.len() - 1];
        for &(i, _) in &msg.entries {
            assert!(g[i as usize].abs() >= kth * (1.0 - 1e-6));
        }
    }

    #[test]
    fn encode_survives_nan_gradient_terms() {
        // regression: the top-k threshold selection compared magnitudes
        // with partial_cmp().unwrap(), which panicked the moment a
        // diverged gradient carried a NaN term; under the total order a
        // NaN magnitude sorts above +inf and (failing every >= test) is
        // simply never selected
        let mut sbc = Sbc::new(0.01, 1000);
        let mut g = grads(1000, 5);
        g[17] = f32::NAN;
        let msg = sbc.encode(&g);
        assert!(!msg.entries.is_empty());
        assert!(msg.entries.iter().all(|&(i, _)| i != 17));
    }

    #[test]
    fn error_feedback_conserves_mass() {
        // group-mean encoding preserves group sums, so across rounds:
        //   sum(delivered) == sum(inputs) - sum(final residual)
        // — the invariant that makes error feedback unbiased in aggregate.
        let p = 1000;
        let mut sbc = Sbc::new(0.01, p);
        let mut rng = Pcg::seeded(17);
        let mut input_mass = 0f64;
        let mut delivered_mass = 0f64;
        for _ in 0..100 {
            let g: Vec<f32> = (0..p).map(|_| rng.normal() as f32 * 0.01).collect();
            input_mass += g.iter().map(|&v| v as f64).sum::<f64>();
            let msg = sbc.encode(&g);
            delivered_mass += Sbc::decode(&msg).iter().map(|&v| v as f64).sum::<f64>();
        }
        let residual_mass: f64 = sbc.residual.iter().map(|&v| v as f64).sum();
        assert!(
            (delivered_mass - (input_mass - residual_mass)).abs() < 1e-2,
            "delivered {delivered_mass} vs input-residual {}",
            input_mass - residual_mass
        );
    }

    #[test]
    fn wire_ratio_near_paper_setting() {
        // keep 0.5% of terms, 10-bit positions + sign vs 64-bit dense:
        // ratio ~ 0.005 * 11/64 ~ 0.001; with the paper's bookkeeping
        // (r=0.005 counting 64-bit payloads) we are comfortably under it.
        let mut sbc = Sbc::new(0.005, 570_000);
        let msg = sbc.encode(&grads(570_000, 4));
        let ratio = Sbc::ratio(&msg, 64);
        assert!(ratio < 0.005, "ratio {ratio}");
        assert!(ratio > 0.0001);
    }

    #[test]
    fn residual_bounded_over_time() {
        let mut sbc = Sbc::new(0.02, 2000);
        let mut r = Pcg::seeded(5);
        let mut norms = Vec::new();
        for _ in 0..100 {
            let g: Vec<f32> = (0..2000).map(|_| r.normal() as f32 * 0.1).collect();
            sbc.encode(&g);
            norms.push(sbc.residual_norm());
        }
        // residual shouldn't blow up linearly — error feedback drains it
        let early = norms[10];
        let late = norms[99];
        assert!(late < early * 3.0, "residual grows: {early} -> {late}");
    }
}
