//! Training schemes (paper §VI-C/D): the proposed joint policy and every
//! baseline it is compared against. A scheme's job each period is to
//! *plan*: pick per-device batchsizes and price the period's end-to-end
//! latency under the wireless/compute models. The trainer then executes
//! the learning side of the plan.

use anyhow::Result;

use crate::opt;
use crate::opt::baselines::{batches_for, solve_equal_slots, solve_fixed_batches, BatchPolicy};
use crate::opt::types::{predicted_timings, quantize, Instance, PredictedTiming};
use crate::util::rng::Pcg;

/// Which scheme drives the training loop.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Scheme {
    /// The paper's contribution: joint batchsize + slot optimization.
    Proposed,
    /// Gradient-based FL [40]: one-step SGD on the full local dataset each
    /// period, equal slots (no joint optimization).
    GradientFl,
    /// Model-based FL (FedAvg [19]): one local epoch, then parameter
    /// averaging; parameters travel uncompressed.
    ModelFl { local_batch: usize },
    /// Individual learning: local training only; one final averaging.
    Individual { local_batch: usize },
    /// GPU-scenario fixed-batch baselines (Fig. 4/5): online/full/random,
    /// optionally with optimal slots for their fixed batches.
    Fixed { policy: BatchPolicy, optimal_slots: bool },
}

impl Scheme {
    pub fn name(&self) -> &'static str {
        match self {
            Scheme::Proposed => "proposed",
            Scheme::GradientFl => "gradient_fl",
            Scheme::ModelFl { .. } => "model_fl",
            Scheme::Individual { .. } => "individual",
            Scheme::Fixed { policy, .. } => match policy {
                BatchPolicy::Online => "online",
                BatchPolicy::Full => "full_batch",
                BatchPolicy::Random => "random_batch",
                BatchPolicy::Equal(_) => "equal_batch",
            },
        }
    }

    /// Does this scheme exchange gradients (vs parameters / nothing)?
    pub fn exchanges_gradients(&self) -> bool {
        matches!(self, Scheme::Proposed | Scheme::GradientFl | Scheme::Fixed { .. })
    }
}

/// One period's plan: what each device trains on and what it costs.
#[derive(Clone, Debug)]
pub struct Plan {
    /// per-device batchsizes to actually execute
    pub batches: Vec<usize>,
    /// end-to-end simulated latency of the period (eq. 14 / eq. 28)
    pub t_period: f64,
    /// subperiod breakdown for telemetry
    pub t_up: f64,
    pub t_down: f64,
    /// per-device *nominal* arrival time at the server (local gradient +
    /// upload, seconds from period start), clamped into `[0, t_up]` — the
    /// event times the `sched/` round policies perturb and schedule on.
    /// Invariant: `finish.len() == K` and `max_k finish[k] <= t_up`, so a
    /// jitter-free barrier lands exactly on the plan's uplink makespan.
    pub finish: Vec<f64>,
    /// per-device predicted timing decomposition (compute / comm / slot
    /// share) — the audit ledger's "what the optimizer expected" side.
    /// Invariant: `predicted.len() == K` and for every device
    /// `(compute + comm).min(t_up)` reproduces `finish[k]` bitwise.
    pub predicted: Vec<PredictedTiming>,
    /// the optimizer's predicted learning efficiency (if it ran)
    pub predicted_efficiency: Option<f64>,
}

/// Per-device nominal uplink-arrival times under the slot vector `tau_ul`
/// for an upload of `bits` per device: the same affine-compute +
/// slotted-upload expression the makespan formulas fold with `max`,
/// clamped to the solved makespan `t_up` so bisection slack can never push
/// an arrival past the barrier it solved for. A non-positive slot means
/// the device never uploads (clamps to `t_up`).
fn uplink_finish_times(
    inst: &Instance,
    batches: &[f64],
    tau_ul: &[f64],
    bits: f64,
    t_up: f64,
) -> Vec<f64> {
    inst.devices
        .iter()
        .zip(batches)
        .zip(tau_ul)
        .map(|((d, &b), &tk)| {
            let t_comm = if tk > 0.0 {
                bits * inst.frame_ul / (tk * d.rate_ul)
            } else {
                f64::INFINITY
            };
            (d.offset + b / d.speed + t_comm).min(t_up)
        })
        .collect()
}

/// Plan one period for `scheme` given this period's `Instance` (rates
/// already embedded) and the per-device shard sizes.
pub fn plan_period(
    scheme: Scheme,
    inst: &Instance,
    shard_sizes: &[usize],
    param_bits: f64,
    eps: f64,
    rng: &mut Pcg,
) -> Result<Plan> {
    match scheme {
        Scheme::Proposed => {
            let g = opt::solve(inst, eps)?;
            let batches = g.solution.quantized_batches(inst);
            let finish = uplink_finish_times(
                inst,
                &g.solution.batches,
                &g.solution.tau_ul,
                inst.s_bits,
                g.solution.t_up,
            );
            let predicted =
                predicted_timings(inst, &g.solution.batches, &g.solution.tau_ul, inst.s_bits);
            Ok(Plan {
                batches,
                t_period: g.solution.period_latency(),
                t_up: g.solution.t_up,
                t_down: g.solution.t_down,
                finish,
                predicted,
                predicted_efficiency: Some(g.efficiency),
            })
        }
        Scheme::GradientFl => {
            // full local dataset; equal slots on both links
            let batches: Vec<f64> = shard_sizes.iter().map(|&n| n as f64).collect();
            let sol = solve_equal_slots(inst, &batches);
            let finish = uplink_finish_times(inst, &batches, &sol.tau_ul, inst.s_bits, sol.t_up);
            let predicted = predicted_timings(inst, &batches, &sol.tau_ul, inst.s_bits);
            Ok(Plan {
                batches: shard_sizes.to_vec(),
                t_period: sol.period_latency(),
                t_up: sol.t_up,
                t_down: sol.t_down,
                finish,
                predicted,
                predicted_efficiency: None,
            })
        }
        Scheme::ModelFl { local_batch: _ } => {
            // one local epoch of compute (processes N_k samples), then an
            // uncompressed parameter exchange on equal slots.
            let k = inst.k();
            let t_compute = inst
                .devices
                .iter()
                .zip(shard_sizes)
                .map(|(d, &n)| d.offset + n as f64 / d.speed)
                .fold(0.0f64, f64::max);
            let tau_ul = inst.frame_ul / k as f64;
            let tau_dl = inst.frame_dl / k as f64;
            let t_ul = inst
                .devices
                .iter()
                .map(|d| param_bits * inst.frame_ul / (tau_ul * d.rate_ul))
                .fold(0.0f64, f64::max);
            let t_dl = inst
                .devices
                .iter()
                .map(|d| param_bits * inst.frame_dl / (tau_dl * d.rate_dl) + d.update_lat)
                .fold(0.0f64, f64::max);
            let t_up = t_compute + t_ul;
            let batches_f: Vec<f64> = shard_sizes.iter().map(|&n| n as f64).collect();
            let tau = vec![tau_ul; k];
            let finish = uplink_finish_times(inst, &batches_f, &tau, param_bits, t_up);
            let predicted = predicted_timings(inst, &batches_f, &tau, param_bits);
            Ok(Plan {
                batches: shard_sizes.to_vec(), // one epoch touches the shard
                t_period: t_compute + t_ul + t_dl,
                t_up,
                t_down: t_dl,
                finish,
                predicted,
                predicted_efficiency: None,
            })
        }
        Scheme::Individual { local_batch } => {
            // no communication at all; period = one local mini-batch step
            let batches: Vec<usize> = shard_sizes
                .iter()
                .map(|&n| local_batch.min(n).max(1))
                .collect();
            let t = inst
                .devices
                .iter()
                .zip(&batches)
                .map(|(d, &b)| d.offset + b as f64 / d.speed + d.update_lat)
                .fold(0.0f64, f64::max);
            let finish = inst
                .devices
                .iter()
                .zip(&batches)
                .map(|(d, &b)| (d.offset + b as f64 / d.speed + d.update_lat).min(t))
                .collect();
            // no communication: compute carries the update latency so it
            // matches the finish expression; zero comm, zero slot share
            let predicted = inst
                .devices
                .iter()
                .zip(&batches)
                .map(|(d, &b)| PredictedTiming {
                    compute: d.offset + b as f64 / d.speed + d.update_lat,
                    comm: 0.0,
                    slot_share: 0.0,
                })
                .collect();
            Ok(Plan {
                batches,
                t_period: t,
                t_up: t,
                t_down: 0.0,
                finish,
                predicted,
                predicted_efficiency: None,
            })
        }
        Scheme::Fixed { policy, optimal_slots } => {
            let batches_f = batches_for(policy, inst, rng);
            let sol = if optimal_slots {
                solve_fixed_batches(inst, &batches_f, eps)?
            } else {
                solve_equal_slots(inst, &batches_f)
            };
            let batches = quantize(&batches_f, inst);
            let finish = uplink_finish_times(inst, &batches_f, &sol.tau_ul, inst.s_bits, sol.t_up);
            let predicted = predicted_timings(inst, &batches_f, &sol.tau_ul, inst.s_bits);
            Ok(Plan {
                batches,
                t_period: sol.period_latency(),
                t_up: sol.t_up,
                t_down: sol.t_down,
                finish,
                predicted,
                predicted_efficiency: None,
            })
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::opt::types::test_instance;

    const EPS: f64 = 1e-9;

    fn shards(k: usize) -> Vec<usize> {
        vec![500; k]
    }

    #[test]
    fn proposed_fastest_per_unit_loss_decay() {
        let inst = test_instance(6);
        let mut rng = Pcg::seeded(1);
        let prop = plan_period(Scheme::Proposed, &inst, &shards(6), 32.0 * 570_000.0, EPS, &mut rng)
            .unwrap();
        // efficiency of proposed >= efficiency of the fixed policies
        let e_prop = inst.loss_decay(prop.batches.iter().sum::<usize>() as f64)
            / prop.t_period;
        for policy in [BatchPolicy::Online, BatchPolicy::Full, BatchPolicy::Random] {
            let p = plan_period(
                Scheme::Fixed { policy, optimal_slots: true },
                &inst,
                &shards(6),
                0.0,
                EPS,
                &mut rng,
            )
            .unwrap();
            let e = inst.loss_decay(p.batches.iter().sum::<usize>() as f64) / p.t_period;
            assert!(e_prop >= e * (1.0 - 0.02), "{policy:?}: {e} vs {e_prop}");
        }
    }

    #[test]
    fn gradient_fl_slower_than_proposed() {
        // full-dataset gradients cost far more compute per period
        let inst = test_instance(6);
        let mut rng = Pcg::seeded(2);
        let prop =
            plan_period(Scheme::Proposed, &inst, &shards(6), 0.0, EPS, &mut rng).unwrap();
        let gfl =
            plan_period(Scheme::GradientFl, &inst, &shards(6), 0.0, EPS, &mut rng).unwrap();
        assert!(gfl.t_period > prop.t_period);
    }

    #[test]
    fn model_fl_upload_dominated_by_params() {
        // uncompressed parameters (32 bits * p) vs compressed gradients
        // (r*d*p = 0.32 * p bits): period latency much larger
        let inst = test_instance(6);
        let mut rng = Pcg::seeded(3);
        let param_bits = 32.0 * 570_000.0;
        let mfl = plan_period(
            Scheme::ModelFl { local_batch: 32 },
            &inst,
            &shards(6),
            param_bits,
            EPS,
            &mut rng,
        )
        .unwrap();
        let gfl =
            plan_period(Scheme::GradientFl, &inst, &shards(6), 0.0, EPS, &mut rng).unwrap();
        assert!(mfl.t_period > gfl.t_period, "{} vs {}", mfl.t_period, gfl.t_period);
    }

    #[test]
    fn individual_no_downlink() {
        let inst = test_instance(4);
        let mut rng = Pcg::seeded(4);
        let p = plan_period(
            Scheme::Individual { local_batch: 128 },
            &inst,
            &shards(4),
            0.0,
            EPS,
            &mut rng,
        )
        .unwrap();
        assert_eq!(p.t_down, 0.0);
        assert!(p.batches.iter().all(|&b| b == 128));
    }

    #[test]
    fn finish_times_clamped_and_cover_fleet() {
        // every plan exposes K nominal arrival times in [0, t_up]; for the
        // equal-slot gradient scheme the slowest arrival IS the makespan
        // (same fold, same float ops), which is what lets a jitter-free
        // sync barrier reproduce t_period bitwise
        let inst = test_instance(6);
        let mut rng = Pcg::seeded(6);
        for scheme in [
            Scheme::Proposed,
            Scheme::GradientFl,
            Scheme::ModelFl { local_batch: 32 },
            Scheme::Individual { local_batch: 64 },
            Scheme::Fixed { policy: BatchPolicy::Random, optimal_slots: true },
        ] {
            let p = plan_period(scheme, &inst, &shards(6), 32.0 * 570_000.0, EPS, &mut rng)
                .unwrap();
            assert_eq!(p.finish.len(), 6, "{scheme:?}");
            assert_eq!(p.predicted.len(), 6, "{scheme:?}");
            for (k, &f) in p.finish.iter().enumerate() {
                assert!(
                    f.is_finite() && f >= 0.0 && f <= p.t_up,
                    "{scheme:?} device {k}: finish {f} outside [0, {}]",
                    p.t_up
                );
                // the predicted decomposition re-folds into the nominal
                // arrival time bitwise — the audit ledger relies on this
                let pt = &p.predicted[k];
                assert_eq!(
                    (pt.compute + pt.comm).min(p.t_up).to_bits(),
                    f.to_bits(),
                    "{scheme:?} device {k}"
                );
                assert!((0.0..=1.0).contains(&pt.slot_share), "{scheme:?} device {k}");
            }
        }
        let gfl = plan_period(Scheme::GradientFl, &inst, &shards(6), 0.0, EPS, &mut rng).unwrap();
        let max_finish = gfl.finish.iter().fold(0.0f64, |a, &b| a.max(b));
        assert_eq!(max_finish.to_bits(), gfl.t_up.to_bits());
    }

    #[test]
    fn plans_respect_batch_bounds_for_fixed() {
        let inst = test_instance(5);
        let mut rng = Pcg::seeded(5);
        for policy in [BatchPolicy::Online, BatchPolicy::Full, BatchPolicy::Random] {
            let p = plan_period(
                Scheme::Fixed { policy, optimal_slots: false },
                &inst,
                &shards(5),
                0.0,
                EPS,
                &mut rng,
            )
            .unwrap();
            for (&b, d) in p.batches.iter().zip(&inst.devices) {
                assert!(b as f64 >= d.b_min && b as f64 <= d.b_max);
            }
        }
    }
}
