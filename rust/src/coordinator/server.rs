//! Edge-server state: global parameters and per-period aggregation
//! (paper steps 3–5 of the training period).
//!
//! Heterogeneous fleets (`fleet_backends`) give the server one global
//! parameter vector per *model family*; homogeneous fleets have exactly
//! one, and every accessor that doesn't name a family reads family 0.

use anyhow::{bail, Result};

use crate::grad::Aggregator;

/// The edge server.
pub struct Server {
    /// per-family global parameters (family ids from `BackendSet`)
    params: Vec<Vec<f32>>,
    /// running count of completed training periods
    pub period: usize,
}

impl Server {
    /// Single-family server (the homogeneous-fleet form).
    pub fn new(params: Vec<f32>) -> Self {
        Server { params: vec![params], period: 0 }
    }

    /// One global parameter vector per model family, in family order.
    pub fn new_multi(params: Vec<Vec<f32>>) -> Result<Self> {
        if params.is_empty() {
            bail!("server needs at least one model family");
        }
        Ok(Server { params, period: 0 })
    }

    /// Number of model families this server holds parameters for.
    pub fn families(&self) -> usize {
        self.params.len()
    }

    /// Family 0's parameters — the single global model of a homogeneous
    /// fleet, and the *reference* family of a mixed one.
    pub fn params(&self) -> &[f32] {
        &self.params[0]
    }

    /// Family `f`'s global parameters.
    pub fn family_params(&self, f: usize) -> &[f32] {
        &self.params[f]
    }

    /// All families' parameters, in family order — the per-family view
    /// the exec rounds resolve devices against.
    pub fn all_params(&self) -> &[Vec<f32>] {
        &self.params
    }

    /// Replace family `f`'s parameters (post-update).
    pub fn set_family_params(&mut self, f: usize, params: Vec<f32>) {
        self.params[f] = params;
    }

    /// Reference-family parameter count (see [`Server::params`]).
    pub fn p(&self) -> usize {
        self.params[0].len()
    }

    /// Aggregate per-device gradients weighted by their batch sizes
    /// (eq. 1) and return the global gradient (reference family).
    pub fn aggregate(&self, grads: &[(Vec<f32>, f64)]) -> Result<Vec<f32>> {
        let mut agg = Aggregator::new(self.p());
        for (g, w) in grads {
            agg.add(g, *w)?;
        }
        agg.finish()
    }

    /// FedAvg-style parameter averaging weighted by shard size
    /// (homogeneous fleets only — model-FL across families is rejected
    /// at trainer construction).
    pub fn average_params(&mut self, params: &[(Vec<f32>, f64)]) -> Result<()> {
        let mut agg = Aggregator::new(self.p());
        for (p, w) in params {
            agg.add(p, *w)?;
        }
        self.params[0] = agg.finish()?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn aggregate_weighted() {
        let s = Server::new(vec![0.0; 2]);
        let g = s
            .aggregate(&[(vec![1.0, 0.0], 1.0), (vec![3.0, 2.0], 3.0)])
            .unwrap();
        assert_eq!(g, vec![2.5, 1.5]);
    }

    #[test]
    fn average_params_fedavg() {
        let mut s = Server::new(vec![0.0; 1]);
        s.average_params(&[(vec![1.0], 100.0), (vec![5.0], 300.0)]).unwrap();
        assert_eq!(s.params(), &[4.0]);
    }

    #[test]
    fn multi_family_params_are_independent() {
        let mut s = Server::new_multi(vec![vec![1.0, 2.0], vec![3.0; 5]]).unwrap();
        assert_eq!(s.families(), 2);
        assert_eq!(s.p(), 2);
        assert_eq!(s.family_params(1).len(), 5);
        s.set_family_params(1, vec![9.0; 5]);
        assert_eq!(s.family_params(0), &[1.0, 2.0]);
        assert_eq!(s.family_params(1), &[9.0; 5]);
        assert_eq!(s.all_params().len(), 2);
        assert!(Server::new_multi(vec![]).is_err());
    }
}
