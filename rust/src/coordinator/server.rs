//! Edge-server state: global parameters and per-period aggregation
//! (paper steps 3–5 of the training period).

use anyhow::Result;

use crate::grad::Aggregator;

/// The edge server.
pub struct Server {
    pub params: Vec<f32>,
    /// running count of completed training periods
    pub period: usize,
}

impl Server {
    pub fn new(params: Vec<f32>) -> Self {
        Server { params, period: 0 }
    }

    pub fn p(&self) -> usize {
        self.params.len()
    }

    /// Aggregate per-device gradients weighted by their batch sizes
    /// (eq. 1) and return the global gradient.
    pub fn aggregate(&self, grads: &[(Vec<f32>, f64)]) -> Result<Vec<f32>> {
        let mut agg = Aggregator::new(self.p());
        for (g, w) in grads {
            agg.add(g, *w)?;
        }
        agg.finish()
    }

    /// FedAvg-style parameter averaging weighted by shard size.
    pub fn average_params(&mut self, params: &[(Vec<f32>, f64)]) -> Result<()> {
        let mut agg = Aggregator::new(self.p());
        for (p, w) in params {
            agg.add(p, *w)?;
        }
        self.params = agg.finish()?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn aggregate_weighted() {
        let s = Server::new(vec![0.0; 2]);
        let g = s
            .aggregate(&[(vec![1.0, 0.0], 1.0), (vec![3.0, 2.0], 3.0)])
            .unwrap();
        assert_eq!(g, vec![2.5, 1.5]);
    }

    #[test]
    fn average_params_fedavg() {
        let mut s = Server::new(vec![0.0; 1]);
        s.average_params(&[(vec![1.0], 100.0), (vec![5.0], 300.0)]).unwrap();
        assert_eq!(s.params, vec![4.0]);
    }
}
