//! Compute backend abstraction: where a device's forward/backward actually
//! executes.
//!
//! * `PjrtBackend` — the production path: AOT HLO artifacts on the PJRT CPU
//!   client (python never runs here).
//! * `HostBackend` — the pure-rust oracle, used by tests and by the large
//!   Table-II sweeps where PJRT per-call overhead would dominate the
//!   hundreds of thousands of tiny train steps.
//!
//! Both receive *exact* batch semantics: PJRT pads into pow-2 buckets with
//! a mask (runtime::client), the host model runs the exact batch.

use std::sync::Mutex;

use anyhow::{anyhow, bail, Result};

use crate::runtime::hostmodel::{HostModel, Workspace};
use crate::runtime::Runtime;

/// One train-step result.
#[derive(Clone, Debug)]
pub struct Step {
    pub grads: Vec<f32>,
    pub loss: f32,
    pub correct: f32,
}

/// Where device compute runs.
///
/// Thread-safe by contract: the exec engine shares each backend across
/// every device worker mapped to it (one fleet-wide backend in the
/// homogeneous case, one per model family under a
/// `fleet_backends::BackendSet`), so every method takes `&self` and
/// implementations must be `Send + Sync`. Methods are pure functions of
/// their inputs (any internal state — caches, stats — must not affect
/// results).
pub trait Backend: Send + Sync {
    /// Number of flat parameters.
    fn params(&self) -> usize;
    /// Deterministic initial parameter vector.
    fn init_params(&self) -> Result<Vec<f32>>;
    /// Forward/backward on an exact batch.
    fn train_step(&self, params: &[f32], x: &[f32], y: &[i32]) -> Result<Step>;
    /// Forward/backward drawing intermediates from a caller-owned
    /// [`Workspace`] (one per exec-engine worker slot), so steady-state
    /// steps stop hitting the allocator. Backends without host-side
    /// intermediates (PJRT) ignore the workspace; results are identical to
    /// `train_step` either way.
    fn train_step_ws(
        &self,
        params: &[f32],
        x: &[f32],
        y: &[i32],
        ws: &mut Workspace,
    ) -> Result<Step> {
        let _ = ws;
        self.train_step(params, x, y)
    }
    /// SGD update.
    fn apply_update(&self, params: &[f32], grads: &[f32], lr: f32) -> Result<Vec<f32>>;
    /// Mean loss + accuracy over a dataset.
    fn evaluate(&self, params: &[f32], x: &[f32], y: &[i32]) -> Result<(f64, f64)>;
    /// [`Backend::evaluate`] drawing host-side scratch (the per-sample
    /// weight vector) from a caller-owned [`Workspace`], so periodic
    /// evaluation stops hitting the allocator. Backends without host-side
    /// scratch ignore the workspace; results are identical either way.
    fn evaluate_ws(
        &self,
        params: &[f32],
        x: &[f32],
        y: &[i32],
        ws: &mut Workspace,
    ) -> Result<(f64, f64)> {
        let _ = ws;
        self.evaluate(params, x, y)
    }
}

/// PJRT-backed production path. The PJRT client serializes execution (its
/// executable cache and stats are mutable), so the runtime sits behind a
/// mutex; per-call concurrency for this backend comes from PJRT's own
/// intra-op parallelism rather than the exec engine's fan-out.
pub struct PjrtBackend {
    pub rt: Mutex<Runtime>,
    pub model: String,
    /// flat parameter count, cached at construction — `params()` must
    /// never lock the runtime (a poisoned mutex would panic via
    /// `.expect()`) nor index the manifest map (a missing model would
    /// panic too); both failure modes are caught once in `new`
    params: usize,
}

impl PjrtBackend {
    pub fn new(rt: Runtime, model: &str) -> Result<Self> {
        let params = rt.manifest.model(model)?.params; // validate + cache
        Ok(PjrtBackend { rt: Mutex::new(rt), model: model.to_string(), params })
    }

    fn lock(&self) -> Result<std::sync::MutexGuard<'_, Runtime>> {
        self.rt.lock().map_err(|_| anyhow!("PJRT runtime mutex poisoned"))
    }
}

impl Backend for PjrtBackend {
    fn params(&self) -> usize {
        self.params
    }

    fn init_params(&self) -> Result<Vec<f32>> {
        self.lock()?.init_params(&self.model)
    }

    fn train_step(&self, params: &[f32], x: &[f32], y: &[i32]) -> Result<Step> {
        // an empty batch would divide by n below and hand the aggregator a
        // NaN loss that silently poisons the round — fail loudly instead
        if y.is_empty() {
            bail!("train_step on an empty batch (model {:?})", self.model);
        }
        // batches larger than the biggest bucket are chunked and aggregated
        // (weighted by chunk size) — exact full-batch semantics
        let mut rt = self.lock()?;
        let max_b = rt.manifest.max_bucket();
        let d = rt.manifest.input_dim;
        let n = y.len();
        if n <= max_b {
            let out = rt.train_step_padded(&self.model, params, x, y)?;
            return Ok(Step { grads: out.grads, loss: out.loss, correct: out.correct });
        }
        let p = params.len();
        let mut agg = crate::grad::Aggregator::new(p);
        let mut loss = 0f64;
        let mut correct = 0f64;
        let mut i = 0;
        while i < n {
            let end = (i + max_b).min(n);
            let out = rt.train_step_padded(
                &self.model,
                params,
                &x[i * d..end * d],
                &y[i..end],
            )?;
            let w = (end - i) as f64;
            agg.add(&out.grads, w)?;
            loss += out.loss as f64 * w;
            correct += out.correct as f64;
            i = end;
        }
        Ok(Step {
            grads: agg.finish()?,
            loss: (loss / n as f64) as f32,
            correct: correct as f32,
        })
    }

    fn apply_update(&self, params: &[f32], grads: &[f32], lr: f32) -> Result<Vec<f32>> {
        self.lock()?.apply_update(&self.model, params, grads, lr)
    }

    fn evaluate(&self, params: &[f32], x: &[f32], y: &[i32]) -> Result<(f64, f64)> {
        if y.is_empty() {
            bail!("evaluate on an empty dataset (model {:?})", self.model);
        }
        self.lock()?.evaluate_dataset(&self.model, params, x, y)
    }
}

/// Pure-rust oracle path.
pub struct HostBackend {
    pub model: HostModel,
    layout: Vec<(String, Vec<usize>)>,
    seed: u64,
}

impl HostBackend {
    pub fn new(model: HostModel, layout: Vec<(String, Vec<usize>)>, seed: u64) -> Self {
        HostBackend { model, layout, seed }
    }

    /// Convenience: build a host backend for a named model family with the
    /// same default geometry as the python side.
    pub fn for_model(name: &str, input_dim: usize, classes: usize, seed: u64) -> Result<Self> {
        let layout = default_layout(name, input_dim, classes)?;
        let model = HostModel::from_layout(name, &layout, input_dim, classes)?;
        Ok(HostBackend::new(model, layout, seed))
    }
}

/// Mirror of python/compile/model.py's default layouts (growth 192 /
/// width 256 / width 384, 3 blocks).
pub fn default_layout(
    name: &str,
    input_dim: usize,
    classes: usize,
) -> Result<Vec<(String, Vec<usize>)>> {
    let mut l: Vec<(String, Vec<usize>)> = Vec::new();
    match name {
        "mini_dense" => {
            let growth = 192;
            let mut width = input_dim;
            for i in 0..3 {
                l.push((format!("blk{i}_w"), vec![width, growth]));
                l.push((format!("blk{i}_b"), vec![growth]));
                width += growth;
            }
            l.push(("head_w".into(), vec![width, classes]));
            l.push(("head_b".into(), vec![classes]));
        }
        "mini_res" => {
            let width = 256;
            l.push(("stem_w".into(), vec![input_dim, width]));
            l.push(("stem_b".into(), vec![width]));
            for i in 0..3 {
                l.push((format!("res{i}a_w"), vec![width, width]));
                l.push((format!("res{i}a_b"), vec![width]));
                l.push((format!("res{i}b_w"), vec![width, width]));
                l.push((format!("res{i}b_b"), vec![width]));
            }
            l.push(("head_w".into(), vec![width, classes]));
            l.push(("head_b".into(), vec![classes]));
        }
        "mini_mobile" => {
            let width = 384;
            l.push(("stem_w".into(), vec![input_dim, width]));
            l.push(("stem_b".into(), vec![width]));
            for i in 0..3 {
                l.push((format!("sep{i}_dw"), vec![width]));
                l.push((format!("sep{i}_w"), vec![width, width]));
                l.push((format!("sep{i}_b"), vec![width]));
            }
            l.push(("head_w".into(), vec![width, classes]));
            l.push(("head_b".into(), vec![classes]));
        }
        other => anyhow::bail!("unknown model {other:?}"),
    }
    Ok(l)
}

impl Backend for HostBackend {
    fn params(&self) -> usize {
        self.model.params
    }

    fn init_params(&self) -> Result<Vec<f32>> {
        Ok(self.model.init_params_host(&self.layout, self.seed))
    }

    fn train_step(&self, params: &[f32], x: &[f32], y: &[i32]) -> Result<Step> {
        self.train_step_ws(params, x, y, &mut Workspace::new())
    }

    fn train_step_ws(
        &self,
        params: &[f32],
        x: &[f32],
        y: &[i32],
        ws: &mut Workspace,
    ) -> Result<Step> {
        if y.is_empty() {
            bail!("train_step on an empty batch (model {:?})", self.model.name);
        }
        let w = ws.take_filled(y.len(), 1.0);
        let (grads, loss, correct) = self.model.train_step_ws(params, x, y, &w, ws);
        ws.recycle(w);
        Ok(Step { grads, loss, correct })
    }

    fn apply_update(&self, params: &[f32], grads: &[f32], lr: f32) -> Result<Vec<f32>> {
        Ok(params
            .iter()
            .zip(grads)
            .map(|(p, g)| p - lr * g)
            .collect())
    }

    fn evaluate(&self, params: &[f32], x: &[f32], y: &[i32]) -> Result<(f64, f64)> {
        self.evaluate_ws(params, x, y, &mut Workspace::new())
    }

    fn evaluate_ws(
        &self,
        params: &[f32],
        x: &[f32],
        y: &[i32],
        ws: &mut Workspace,
    ) -> Result<(f64, f64)> {
        if y.is_empty() {
            bail!("evaluate on an empty dataset (model {:?})", self.model.name);
        }
        let n = y.len();
        // the uniform per-sample weight vector comes from the workspace
        // pool instead of a fresh `vec![1f32; n]` every eval call
        let w = ws.take_filled(n, 1.0);
        let (loss, correct) = self.model.loss(params, x, y, &w);
        ws.recycle(w);
        Ok((loss as f64, correct as f64 / n as f64))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg;

    fn batch(n: usize, d: usize, c: usize, seed: u64) -> (Vec<f32>, Vec<i32>) {
        let mut r = Pcg::seeded(seed);
        (
            (0..n * d).map(|_| r.normal() as f32).collect(),
            (0..n).map(|_| r.below(c as u64) as i32).collect(),
        )
    }

    #[test]
    fn host_backend_trains() {
        let be = HostBackend::for_model("mini_res", 32, 5, 1).unwrap();
        let mut params = be.init_params().unwrap();
        let (x, y) = batch(16, 32, 5, 2);
        let s0 = be.train_step(&params, &x, &y).unwrap();
        for _ in 0..30 {
            let s = be.train_step(&params, &x, &y).unwrap();
            params = be.apply_update(&params, &s.grads, 0.2).unwrap();
        }
        let s1 = be.train_step(&params, &x, &y).unwrap();
        assert!(s1.loss < s0.loss * 0.6, "{} -> {}", s0.loss, s1.loss);
    }

    #[test]
    fn default_layouts_all_models() {
        for m in ["mini_dense", "mini_res", "mini_mobile"] {
            let be = HostBackend::for_model(m, 768, 10, 0).unwrap();
            assert!(be.params() > 100_000, "{m}: {}", be.params());
        }
        assert!(HostBackend::for_model("nope", 8, 2, 0).is_err());
    }

    #[test]
    fn backend_is_object_safe_and_shared() {
        // the exec engine's usage pattern: one &dyn Backend across threads
        let be = HostBackend::for_model("mini_dense", 8, 3, 1).unwrap();
        let dy: &dyn Backend = &be;
        let params = dy.init_params().unwrap();
        std::thread::scope(|s| {
            for seed in 0..3u64 {
                let params = &params;
                s.spawn(move || {
                    let (x, y) = batch(4, 8, 3, seed);
                    dy.train_step(params, &x, &y).unwrap();
                });
            }
        });
    }

    #[test]
    fn empty_batches_error_cleanly() {
        // n == 0 used to divide by zero and hand the aggregator a NaN loss
        let be = HostBackend::for_model("mini_dense", 8, 3, 1).unwrap();
        let params = be.init_params().unwrap();
        let err = be.train_step(&params, &[], &[]).unwrap_err().to_string();
        assert!(err.contains("empty batch"), "{err}");
        let mut ws = Workspace::new();
        assert!(be.train_step_ws(&params, &[], &[], &mut ws).is_err());
        let err = be.evaluate(&params, &[], &[]).unwrap_err().to_string();
        assert!(err.contains("empty dataset"), "{err}");
        assert!(be.evaluate_ws(&params, &[], &[], &mut ws).is_err());
    }

    #[test]
    fn eval_workspace_matches_one_shot_and_stops_allocating() {
        let be = HostBackend::for_model("mini_dense", 8, 3, 1).unwrap();
        let params = be.init_params().unwrap();
        let (x, y) = batch(12, 8, 3, 5);
        let (l0, a0) = be.evaluate(&params, &x, &y).unwrap();
        let mut ws = Workspace::new();
        let (l1, a1) = be.evaluate_ws(&params, &x, &y, &mut ws).unwrap();
        assert_eq!(l0.to_bits(), l1.to_bits());
        assert_eq!(a0.to_bits(), a1.to_bits());
        // after the first call the weight buffer comes from the pool
        let pooled = ws.pooled_buffers();
        assert!(pooled > 0);
        let (l2, _) = be.evaluate_ws(&params, &x, &y, &mut ws).unwrap();
        assert_eq!(l1.to_bits(), l2.to_bits());
        assert_eq!(ws.pooled_buffers(), pooled, "eval must recycle, not grow the pool");
    }

    #[test]
    fn host_eval_consistent_with_train_loss() {
        let be = HostBackend::for_model("mini_mobile", 16, 4, 3).unwrap();
        let params = be.init_params().unwrap();
        let (x, y) = batch(24, 16, 4, 4);
        let s = be.train_step(&params, &x, &y).unwrap();
        let (loss, acc) = be.evaluate(&params, &x, &y).unwrap();
        assert!((loss - s.loss as f64).abs() < 1e-5);
        assert!((acc - s.correct as f64 / 24.0).abs() < 1e-9);
    }
}
