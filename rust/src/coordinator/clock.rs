//! Simulated wall clock. The wireless/compute latencies are analytic
//! (DESIGN.md §3 substitution), so training time advances by the computed
//! per-period latency T (eq. 14) rather than host time.

/// Simulated clock, in seconds.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct SimClock {
    now: f64,
}

impl SimClock {
    pub fn new() -> Self {
        SimClock { now: 0.0 }
    }

    /// Advance by `dt` seconds (panics on negative or non-finite dt — a
    /// negative latency is always an upstream bug).
    pub fn advance(&mut self, dt: f64) {
        assert!(dt.is_finite() && dt >= 0.0, "bad clock advance {dt}");
        self.now += dt;
    }

    /// Advance to the absolute time `t` (event-queue style). Panics if `t`
    /// would move the clock backwards: simulated time is monotone, and a
    /// past-dated event is always an upstream bug.
    pub fn advance_to(&mut self, t: f64) {
        assert!(t.is_finite() && t >= self.now, "clock cannot rewind {} -> {t}", self.now);
        self.now = t;
    }

    pub fn now(&self) -> f64 {
        self.now
    }

    /// Restore a checkpointed absolute time. Unlike [`SimClock::advance_to`]
    /// this may move the clock backwards — resume replaces the whole clock,
    /// it does not advance it — but a non-finite or negative time is still
    /// always a corrupt checkpoint.
    pub fn restore(&mut self, t: f64) {
        assert!(t.is_finite() && t >= 0.0, "bad clock restore {t}");
        self.now = t;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn advances() {
        let mut c = SimClock::new();
        c.advance(1.5);
        c.advance(0.5);
        assert_eq!(c.now(), 2.0);
    }

    #[test]
    fn advances_to_absolute_times() {
        let mut c = SimClock::new();
        c.advance_to(2.5);
        c.advance_to(2.5); // no-op, not a rewind
        c.advance(0.5);
        assert_eq!(c.now(), 3.0);
    }

    #[test]
    #[should_panic]
    fn rejects_negative() {
        SimClock::new().advance(-1.0);
    }

    #[test]
    #[should_panic]
    fn rejects_rewind() {
        let mut c = SimClock::new();
        c.advance(2.0);
        c.advance_to(1.0);
    }

    #[test]
    #[should_panic]
    fn rejects_nan() {
        SimClock::new().advance(f64::NAN);
    }
}
