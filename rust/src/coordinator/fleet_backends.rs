//! Heterogeneous multi-backend fleets: route each device to its own
//! compute backend while keeping the bitwise thread-invariance contract.
//!
//! Real federated-edge fleets are mixed — small devices train a small
//! model on the host path while big ones run a large model (or PJRT when
//! linked). [`BackendSet`] is the per-device registry the trainer, the
//! exec round executors, and the round scheduler resolve through instead
//! of sharing one `&dyn Backend`:
//!
//! * every *model family* (distinct parameter space, keyed by model name)
//!   appears once, in first-device order — family ids index the server's
//!   per-family parameter vectors and the per-family [`Aggregator`]s
//!   (`grad::Aggregator::for_family` tags shards so cross-family merges
//!   are rejected even when parameter counts coincide);
//! * every device id maps to exactly one family — a device's `Workspace`
//!   therefore only ever sees one model's buffer shapes, so mixed fleets
//!   keep the zero-alloc steady state;
//! * the assignment is a pure function of the device id (per-tier rules
//!   in `config::schema`, `fleet.backends` / `--backends`), never of the
//!   thread count — determinism is untouched.
//!
//! Per-tier compute latency needs no new machinery: the planner's
//! per-device nominal finish times (`Plan::finish`) already price each
//! device's own compute module, and the `sched/` policies schedule on
//! those.
//!
//! [`Aggregator`]: crate::grad::Aggregator

use anyhow::{bail, Result};

use super::backend::Backend;

/// Per-device backend registry: distinct model families plus a
/// device-id → family assignment. Borrowed backends keep ownership with
/// the caller (mirroring how `Trainer` always borrowed its backend);
/// `exp::common::FleetBackends` is the owning form experiments build
/// from config.
pub struct BackendSet<'a> {
    /// family names (model names), distinct, first-device order
    names: Vec<String>,
    /// one backend per family (same order)
    backends: Vec<&'a dyn Backend>,
    /// flat parameter count per family, cached once
    params: Vec<usize>,
    /// device id -> family index
    assign: Vec<usize>,
}

impl<'a> BackendSet<'a> {
    /// Every device on one backend — the classic single-backend trainer.
    pub fn homogeneous(k: usize, name: &str, backend: &'a dyn Backend) -> BackendSet<'a> {
        BackendSet {
            names: vec![name.to_string()],
            backends: vec![backend],
            params: vec![backend.params()],
            assign: vec![0; k],
        }
    }

    /// Build from distinct `(family name, backend)` pairs and a
    /// device → family assignment. Families must be non-empty, uniquely
    /// named, and each referenced by at least one device.
    pub fn new(
        families: Vec<(String, &'a dyn Backend)>,
        assign: Vec<usize>,
    ) -> Result<BackendSet<'a>> {
        if families.is_empty() {
            bail!("backend set needs at least one model family");
        }
        if assign.is_empty() {
            bail!("backend set needs at least one device");
        }
        for (i, (name, _)) in families.iter().enumerate() {
            if families[..i].iter().any(|(n, _)| n == name) {
                bail!("duplicate model family {name:?} in backend set");
            }
        }
        for (dev, &f) in assign.iter().enumerate() {
            if f >= families.len() {
                bail!(
                    "device {dev} assigned to family {f}, but the set has {} families",
                    families.len()
                );
            }
        }
        for f in 0..families.len() {
            if !assign.contains(&f) {
                bail!("model family {:?} is assigned to no device", families[f].0);
            }
        }
        let (names, backends): (Vec<String>, Vec<&dyn Backend>) = families.into_iter().unzip();
        let params = backends.iter().map(|b| b.params()).collect();
        Ok(BackendSet { names, backends, params, assign })
    }

    /// Fleet size K.
    pub fn k(&self) -> usize {
        self.assign.len()
    }

    /// Number of distinct model families (1 for homogeneous fleets).
    pub fn family_count(&self) -> usize {
        self.backends.len()
    }

    /// Does every device share one family? (The single-backend fast
    /// paths — direct eval, FedAvg — key on this.)
    pub fn is_homogeneous(&self) -> bool {
        self.family_count() == 1
    }

    /// The backend device `dev` trains on.
    pub fn for_device(&self, dev: usize) -> &'a dyn Backend {
        self.backends[self.assign[dev]]
    }

    /// The model family device `dev` belongs to.
    pub fn family_of(&self, dev: usize) -> usize {
        self.assign[dev]
    }

    /// Family `f`'s canonical backend (init / server update / eval).
    pub fn family_backend(&self, f: usize) -> &'a dyn Backend {
        self.backends[f]
    }

    pub fn family_name(&self, f: usize) -> &str {
        &self.names[f]
    }

    /// Flat parameter count of family `f` (cached; never locks).
    pub fn family_params(&self, f: usize) -> usize {
        self.params[f]
    }

    /// Flat parameter count of device `dev`'s model.
    pub fn device_params(&self, dev: usize) -> usize {
        self.params[self.assign[dev]]
    }

    /// Devices assigned to family `f`.
    pub fn family_size(&self, f: usize) -> usize {
        self.assign.iter().filter(|&&a| a == f).count()
    }

    /// Deterministic initial parameters for every family, in family order.
    pub fn init_all(&self) -> Result<Vec<Vec<f32>>> {
        self.backends.iter().map(|b| b.init_params()).collect()
    }

    /// Validate a per-family parameter slice against this set's geometry —
    /// the guard every exec round runs before fanning out, so a
    /// mixed-fleet mismatch fails with a clear error instead of a
    /// slice panic deep inside a worker.
    pub fn check_params(&self, params: &[Vec<f32>]) -> Result<()> {
        if params.len() != self.family_count() {
            bail!(
                "got {} parameter vectors for {} model families",
                params.len(),
                self.family_count()
            );
        }
        for (f, p) in params.iter().enumerate() {
            if p.len() != self.params[f] {
                bail!(
                    "family {:?} parameter vector has {} terms, model wants {}",
                    self.names[f],
                    p.len(),
                    self.params[f]
                );
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::backend::HostBackend;

    fn hosts() -> (HostBackend, HostBackend) {
        (
            HostBackend::for_model("mini_dense", 8, 3, 1).unwrap(),
            HostBackend::for_model("mini_res", 8, 3, 1).unwrap(),
        )
    }

    #[test]
    fn homogeneous_set_routes_every_device_to_one_family() {
        let (a, _) = hosts();
        let set = BackendSet::homogeneous(5, "mini_dense", &a);
        assert_eq!(set.k(), 5);
        assert_eq!(set.family_count(), 1);
        assert!(set.is_homogeneous());
        for d in 0..5 {
            assert_eq!(set.family_of(d), 0);
            assert_eq!(set.device_params(d), a.params());
        }
        assert_eq!(set.family_name(0), "mini_dense");
        assert_eq!(set.family_size(0), 5);
        let init = set.init_all().unwrap();
        assert_eq!(init.len(), 1);
        assert_eq!(init[0], a.init_params().unwrap());
        set.check_params(&init).unwrap();
    }

    #[test]
    fn mixed_set_resolves_per_device() {
        let (a, b) = hosts();
        let assign = vec![0, 1, 0, 1, 1];
        let set = BackendSet::new(
            vec![("mini_dense".into(), &a as &dyn Backend), ("mini_res".into(), &b)],
            assign,
        )
        .unwrap();
        assert!(!set.is_homogeneous());
        assert_eq!(set.family_count(), 2);
        assert_eq!(set.family_size(0), 2);
        assert_eq!(set.family_size(1), 3);
        assert_eq!(set.family_of(3), 1);
        assert_eq!(set.for_device(0).params(), a.params());
        assert_eq!(set.for_device(1).params(), b.params());
        assert_ne!(set.family_params(0), set.family_params(1));
        let init = set.init_all().unwrap();
        assert_eq!(init[0].len(), a.params());
        assert_eq!(init[1].len(), b.params());
        set.check_params(&init).unwrap();
        // geometry violations fail with clear errors
        assert!(set.check_params(&init[..1]).is_err());
        let mut bad = init.clone();
        bad[1].pop();
        let err = set.check_params(&bad).unwrap_err().to_string();
        assert!(err.contains("mini_res"), "{err}");
    }

    #[test]
    fn rejects_malformed_sets() {
        let (a, b) = hosts();
        // empty families / devices
        assert!(BackendSet::new(vec![], vec![0]).is_err());
        assert!(
            BackendSet::new(vec![("m".into(), &a as &dyn Backend)], vec![]).is_err()
        );
        // out-of-range assignment
        assert!(
            BackendSet::new(vec![("m".into(), &a as &dyn Backend)], vec![0, 1]).is_err()
        );
        // duplicate family name
        assert!(BackendSet::new(
            vec![("m".into(), &a as &dyn Backend), ("m".into(), &b)],
            vec![0, 1],
        )
        .is_err());
        // unused family
        let err = BackendSet::new(
            vec![("m".into(), &a as &dyn Backend), ("n".into(), &b)],
            vec![0, 0],
        )
        .unwrap_err()
        .to_string();
        assert!(err.contains("no device"), "{err}");
    }
}
