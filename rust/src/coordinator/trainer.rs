//! The FEEL training loop: periods of plan → local gradients → compress →
//! aggregate → update, with the simulated clock advancing by each period's
//! end-to-end latency (paper steps 1–5, Fig. 1).
//!
//! Planning (scheme.rs) runs on the coordinator thread; execution of the K
//! per-device steps is fanned out through `exec::Engine`, and for
//! gradient-exchange schemes the period is *closed* by the round policy in
//! `sched/` (sync barrier / deadline / async quorum, with the straggler
//! model perturbing per-device completion events). All cross-device
//! reductions happen in fixed device/event order, so numerics are
//! bitwise-identical at any thread count. Simulated time advances only
//! through [`SimClock`], from the scheduler-reported period duration.

use std::path::Path;
use std::time::Instant;

use anyhow::{bail, Context, Result};

use super::backend::Backend;
use super::checkpoint::{self, ByteReader, ByteWriter};
use super::clock::SimClock;
use super::fleet_backends::BackendSet;
use super::scheme::{plan_period, Plan, Scheme};
use super::server::Server;
use super::worker::Worker;
use super::xi::XiEstimator;
use crate::compress::Sbc;
use crate::data::{partition, Dataset, DeviceData, Partition};
use crate::device::{ClientSampler, Device, StragglerModel};
use crate::exec::{self, Engine};
use crate::fault::FaultPlan;
use crate::grad::{Aggregator, GradGuard};
use crate::obs::ObsSink;
use crate::opt::types::Instance;
use crate::runtime::hostmodel::Workspace;
use crate::sched::{InflightRecord, RoundPolicy, RoundReport, RoundScheduler, SchedCheckpoint};
use crate::util::rng::Pcg;
use crate::wireless::PeriodRates;

/// Stream tag for sampled-mode link evolution: each sampled device's
/// channel draw comes from its own `(seed ^ TAG, period, device)`
/// counter-derived stream instead of the trainer's sequential RNG, so a
/// round's draws cost O(sampled) and never depend on which other devices
/// were drawn.
const SAMPLED_LINK_TAG: u64 = 0x11ab_ca5e_11ab_ca5e;

/// Trainer configuration (see config/ for the file-based form).
#[derive(Clone, Debug)]
pub struct TrainerConfig {
    pub scheme: Scheme,
    /// batch ceiling B^max (paper: 128)
    pub b_max: usize,
    /// gradient quantization bits d (paper: 64)
    pub quant_bits: u32,
    /// SBC keep fraction; None disables compression (dense f32 wire)
    pub sbc_keep: Option<f64>,
    /// effective compressed-gradient wire ratio r (paper: 0.005) — used by
    /// the *latency model*; the actual coder is applied to the numerics
    pub wire_ratio: f64,
    /// TDMA frame lengths (paper: 10 ms each)
    pub frame_ul: f64,
    pub frame_dl: f64,
    /// base learning rate; per-period lr = base * sqrt(B / (K * b_max))
    pub base_lr: f64,
    /// initial xi estimate + EWMA weight
    pub xi_init: f64,
    pub xi_alpha: f64,
    /// evaluate on the test set every this many periods (0 = never)
    pub eval_every: usize,
    /// optimizer tolerance
    pub eps: f64,
    pub seed: u64,
    /// worker threads for per-device execution (0 = all cores). Changes
    /// wall-clock only — numerics are identical at any value.
    pub threads: usize,
    /// how gradient-exchange rounds close: barrier / deadline / async
    /// quorum (see `sched::RoundPolicy`). Non-gradient schemes are
    /// barrier-only.
    pub policy: RoundPolicy,
    /// per-device latency jitter + dropout injected into round scheduling
    /// (`StragglerModel::none()` = the paper's deterministic latencies)
    pub straggler: StragglerModel,
    /// per-round client sampling fraction in (0, 1]: each period draws an
    /// independent Bernoulli(frac) participant set from a counter-derived
    /// stream and plans/executes over that subset only. 1.0 routes the
    /// legacy full-participation path bitwise. Gradient-exchange schemes
    /// only.
    pub sample_frac: f64,
    /// seeded fault injection: device crash windows and gradient payload
    /// corruption (`FaultPlan::none()` = no faults, zero extra RNG draws).
    /// Gradient-exchange schemes only.
    pub fault: FaultPlan,
    /// server-side gradient quarantine: what happens to non-finite or
    /// norm-outlier contributions (`GradGuard::off()` = accept everything,
    /// corrupt payloads still counted). Gradient-exchange schemes only.
    pub guard: GradGuard,
}

impl Default for TrainerConfig {
    fn default() -> Self {
        TrainerConfig {
            scheme: Scheme::Proposed,
            b_max: 128,
            quant_bits: 64,
            sbc_keep: Some(0.005),
            wire_ratio: 0.005,
            frame_ul: 0.01,
            frame_dl: 0.01,
            base_lr: 0.35,
            xi_init: 0.05,
            xi_alpha: 0.1,
            eval_every: 10,
            eps: 1e-6,
            seed: 0,
            threads: 0,
            policy: RoundPolicy::Sync,
            straggler: StragglerModel::none(),
            sample_frac: 1.0,
            fault: FaultPlan::none(),
            guard: GradGuard::off(),
        }
    }
}

/// One period's record.
#[derive(Clone, Copy, Debug)]
pub struct PeriodRecord {
    pub period: usize,
    /// simulated seconds at the END of this period
    pub sim_time: f64,
    pub t_period: f64,
    pub b_total: usize,
    pub train_loss: f64,
    pub lr: f64,
    pub test_loss: Option<f64>,
    pub test_acc: Option<f64>,
    /// measured learning efficiency dL/T of this period
    pub efficiency: f64,
    /// gradients applied this period (== K under a clean sync barrier)
    pub applied: usize,
    /// devices lost to dropout this period
    pub dropped: usize,
    /// devices that missed the deadline (batch carried to next period)
    pub late: usize,
    /// batch-weighted mean staleness of the applied gradients (async; 0
    /// for barrier/deadline rounds)
    pub stale_mean: f64,
    /// cell this record's trainer serves (hier runs; 0 for flat trainers)
    pub cell: usize,
    /// whether a cross-cell cloud merge closed this period (`hier/`
    /// stamps it on the last record of every tau-block; always false for
    /// flat single-cell runs)
    pub cloud: bool,
    /// devices unreachable this period (fault-injected crash windows)
    pub crashed: usize,
    /// contributions whose payload was detected corrupt this period
    pub corrupt: usize,
    /// corrupt contributions the quarantine rejected or clipped
    pub quarantined: usize,
}

/// Wall-clock accounting of the coordinator's *serial* sections, summed
/// over the run — the denominator-side of the ROADMAP "perf trajectory"
/// item (the serial fraction is what caps periods/sec scaling at K = 64+).
/// Wall times are measurement, not simulation: they never feed back into
/// results and are excluded from the determinism contract.
#[derive(Clone, Copy, Debug, Default)]
pub struct WallStats {
    /// channel draws + per-period planning (the paper's solver), seconds
    pub solver_secs: f64,
    /// shard combine + global apply_update / FedAvg, seconds
    pub reduce_secs: f64,
    /// total wall seconds spent inside `step_period`
    pub total_secs: f64,
}

impl WallStats {
    /// Fraction of period wall time spent in the serial coordinator
    /// sections (0.0 when nothing has run yet).
    pub fn serial_fraction(&self) -> f64 {
        if self.total_secs > 0.0 {
            (self.solver_secs + self.reduce_secs) / self.total_secs
        } else {
            0.0
        }
    }
}

/// Whole-run log.
#[derive(Clone, Debug, Default)]
pub struct TrainLog {
    pub records: Vec<PeriodRecord>,
    /// serial-fraction wall-clock accounting (see [`WallStats`])
    pub wall: WallStats,
}

impl TrainLog {
    pub fn final_acc(&self) -> Option<f64> {
        self.records.iter().rev().find_map(|r| r.test_acc)
    }

    pub fn final_loss(&self) -> Option<f64> {
        self.records.last().map(|r| r.train_loss)
    }

    /// Simulated seconds at the end of the run — the final `SimClock`
    /// reading, and the one axis on which sync / deadline / async runs are
    /// comparable (every policy advances the same clock).
    pub fn sim_time(&self) -> f64 {
        self.records.last().map(|r| r.sim_time).unwrap_or(0.0)
    }

    pub fn total_time(&self) -> f64 {
        self.sim_time()
    }

    /// First simulated time at which the train loss fell below `target`
    /// (None if never) — the Table-II "training speed" measure.
    pub fn time_to_loss(&self, target: f64) -> Option<f64> {
        self.records
            .iter()
            .find(|r| r.train_loss <= target)
            .map(|r| r.sim_time)
    }

    /// Mean train loss over periods `[start, start + len)` — the guarded
    /// form of the head/tail window slicing convergence checks use. Returns
    /// a clean error (instead of a slice panic) when the run is shorter
    /// than the requested window.
    pub fn mean_loss_window(&self, start: usize, len: usize) -> Result<f64> {
        let n = self.records.len();
        let Some(end) = start.checked_add(len) else {
            bail!("loss window {start}+{len} overflows");
        };
        if len == 0 || end > n {
            bail!("loss window [{start}, {end}) out of range: run has {n} periods");
        }
        Ok(self.records[start..end].iter().map(|r| r.train_loss).sum::<f64>() / len as f64)
    }

    /// First simulated time at which test accuracy reached `target`.
    pub fn time_to_acc(&self, target: f64) -> Option<f64> {
        self.records
            .iter()
            .find(|r| r.test_acc.is_some_and(|a| a >= target))
            .map(|r| r.sim_time)
    }

    /// CSV dump (header + one row per period). New columns are only ever
    /// appended on the right, so index-based readers of older dumps stand.
    pub fn to_csv(&self) -> String {
        let mut out = String::from(
            "period,sim_time,t_period,b_total,train_loss,lr,test_loss,test_acc,efficiency,\
             applied,dropped,late,stale_mean,cell,cloud,crashed,corrupt,quarantined\n",
        );
        for r in &self.records {
            out.push_str(&format!(
                "{},{:.6},{:.6},{},{:.6},{:.5},{},{},{:.6},{},{},{},{:.3},{},{},{},{},{}\n",
                r.period,
                r.sim_time,
                r.t_period,
                r.b_total,
                r.train_loss,
                r.lr,
                r.test_loss.map(|v| format!("{v:.6}")).unwrap_or_default(),
                r.test_acc.map(|v| format!("{v:.6}")).unwrap_or_default(),
                r.efficiency,
                r.applied,
                r.dropped,
                r.late,
                r.stale_mean,
                r.cell,
                u8::from(r.cloud),
                r.crashed,
                r.corrupt,
                r.quarantined,
            ));
        }
        out
    }
}

/// The coordinator: owns the fleet, the data, the backend set and the
/// loop. Heterogeneous fleets route each device to its own backend
/// through a [`BackendSet`]; the server keeps one global model (and one
/// long-lived gradient accumulator) per model family.
pub struct Trainer<'a> {
    pub cfg: TrainerConfig,
    pub fleet: Vec<Device>,
    pub workers: Vec<Worker>,
    pub server: Server,
    backends: BackendSet<'a>,
    engine: Engine,
    train: &'a Dataset,
    test: &'a Dataset,
    clock: SimClock,
    xi: XiEstimator,
    rng: Pcg,
    last_train_loss: Option<f64>,
    /// long-lived server-side accumulators, one per model family, reset
    /// each period (their p-sized f64 buffers are allocated once per run,
    /// not once per round)
    aggs: Vec<Aggregator>,
    /// round-policy scheduler: event queue, straggler injection, deadline
    /// carry ledger, async in-flight work
    sched: RoundScheduler,
    /// per-round participant sampler (`None` = full participation — the
    /// legacy path, untouched down to the RNG draw order)
    sampler: Option<ClientSampler>,
    /// per-period link-rate scratch, reused across periods so the channel
    /// draw allocates nothing after the first round
    rates_scratch: Vec<PeriodRates>,
    /// coordinator-thread eval scratch (global-model evaluation path)
    eval_scratch: Workspace,
    /// which cell of a hierarchical topology this trainer serves (stamped
    /// into every `PeriodRecord`; 0 for flat single-cell runs)
    cell_id: usize,
    /// structured tracing + metrics sink (disabled by default — off-path
    /// runs are bitwise-identical to an uninstrumented build). Not part
    /// of the checkpoint payload: a resumed run restarts its trace.
    obs: ObsSink,
    pub log: TrainLog,
}

impl<'a> Trainer<'a> {
    /// Homogeneous fleet: every device trains on `backend`.
    pub fn new(
        cfg: TrainerConfig,
        fleet: Vec<Device>,
        train: &'a Dataset,
        test: &'a Dataset,
        kind: Partition,
        backend: &'a dyn Backend,
    ) -> Result<Self> {
        let k = fleet.len();
        Trainer::with_backends(
            cfg,
            fleet,
            train,
            test,
            kind,
            BackendSet::homogeneous(k, "default", backend),
        )
    }

    /// Heterogeneous fleet: each device resolves its backend and model
    /// family through `backends` (see `coordinator::fleet_backends`). A
    /// single-family set reproduces [`Trainer::new`] bitwise.
    pub fn with_backends(
        cfg: TrainerConfig,
        fleet: Vec<Device>,
        train: &'a Dataset,
        test: &'a Dataset,
        kind: Partition,
        backends: BackendSet<'a>,
    ) -> Result<Self> {
        if backends.k() != fleet.len() {
            bail!(
                "backend set covers {} devices, fleet has {}",
                backends.k(),
                fleet.len()
            );
        }
        // FedAvg averages parameter vectors across devices — undefined
        // across model families
        if !backends.is_homogeneous() && matches!(cfg.scheme, Scheme::ModelFl { .. }) {
            bail!(
                "scheme {:?} requires a homogeneous fleet: parameter averaging across \
                 model families is undefined (families here: {})",
                cfg.scheme.name(),
                (0..backends.family_count())
                    .map(|f| backends.family_name(f).to_string())
                    .collect::<Vec<_>>()
                    .join(", ")
            );
        }
        let mut rng = Pcg::seeded(cfg.seed);
        let parts = partition(train, fleet.len(), kind, &mut rng);
        let workers = parts
            .into_iter()
            .enumerate()
            .map(|(id, idx)| {
                // the compressor is sized to the device's own gradient
                // geometry (its family's parameter count)
                let p = backends.device_params(id);
                let sbc = cfg.sbc_keep.map(|f| Sbc::new(f, p));
                Worker::new(id, DeviceData::new(idx, rng.fork(id as u64 + 1)), sbc)
            })
            .collect();
        let params = backends.init_all()?;
        let xi = XiEstimator::new(cfg.xi_init, cfg.xi_alpha);
        let engine = Engine::new(cfg.threads);
        let aggs = (0..backends.family_count())
            .map(|f| Aggregator::for_family(backends.family_params(f), f as u32))
            .collect();
        // round policies and straggler injection act on the gradient
        // aggregation path; the local-training schemes have no per-period
        // server reduce to schedule around
        if !cfg.scheme.exchanges_gradients() {
            if !cfg.policy.is_sync() {
                bail!(
                    "round policy {:?} requires a gradient-exchange scheme, got {:?}",
                    cfg.policy.name(),
                    cfg.scheme.name()
                );
            }
            if cfg.straggler.is_active() {
                bail!(
                    "the straggler model requires a gradient-exchange scheme, got {:?}",
                    cfg.scheme.name()
                );
            }
        }
        // fault injection and the gradient quarantine act on the same
        // aggregation path as the round policies above
        if !cfg.scheme.exchanges_gradients() {
            if cfg.fault.is_active() {
                bail!(
                    "fault injection requires a gradient-exchange scheme, got {:?}",
                    cfg.scheme.name()
                );
            }
            if cfg.guard.is_active() {
                bail!(
                    "the gradient quarantine requires a gradient-exchange scheme, got {:?}",
                    cfg.scheme.name()
                );
            }
        }
        // revalidate pub-field structs that may not have come through the
        // checked constructors
        StragglerModel::new(cfg.straggler.jitter, cfg.straggler.dropout)?;
        FaultPlan::new(
            cfg.fault.crash_rate,
            cfg.fault.crash_len,
            cfg.fault.corrupt_rate,
            cfg.fault.corrupt_noise,
            cfg.fault.outage_rate,
        )?;
        GradGuard::new(cfg.guard.policy, cfg.guard.max_norm)?;
        // client sampling rides the gradient-aggregation path too: a
        // sampled round reweights the aggregate by the inclusion
        // probability, which has no analogue for the local-training schemes
        if cfg.sample_frac < 1.0 && !cfg.scheme.exchanges_gradients() {
            bail!(
                "client sampling (sample_frac {}) requires a gradient-exchange scheme, got {:?}",
                cfg.sample_frac,
                cfg.scheme.name()
            );
        }
        let sampler = if cfg.sample_frac < 1.0 {
            Some(ClientSampler::devices(cfg.seed, cfg.sample_frac)?)
        } else if cfg.sample_frac == 1.0 {
            None
        } else {
            bail!("sample_frac must be in (0, 1], got {}", cfg.sample_frac);
        };
        let sched = RoundScheduler::new(
            cfg.policy,
            cfg.straggler,
            cfg.fault,
            cfg.guard,
            fleet.len(),
            cfg.seed,
        )?;
        Ok(Trainer {
            cfg,
            fleet,
            workers,
            server: Server::new_multi(params)?,
            backends,
            engine,
            train,
            test,
            clock: SimClock::new(),
            xi,
            rng,
            last_train_loss: None,
            aggs,
            sched,
            sampler,
            rates_scratch: Vec::new(),
            eval_scratch: Workspace::new(),
            cell_id: 0,
            obs: ObsSink::disabled(),
            log: TrainLog::default(),
        })
    }

    /// Worker threads the per-device fan-out uses.
    pub fn threads(&self) -> usize {
        self.engine.threads()
    }

    /// Tag this trainer as cell `c` of a hierarchical topology: every
    /// subsequent `PeriodRecord` carries the id (`hier::HierTrainer` sets
    /// it once at construction; flat trainers stay at 0).
    pub fn set_cell_id(&mut self, c: usize) {
        self.cell_id = c;
    }

    pub fn cell_id(&self) -> usize {
        self.cell_id
    }

    /// Turn on structured tracing + metrics for this trainer. Events are
    /// stamped with the trainer's cell id as their trace process lane, so
    /// call this *after* [`Trainer::set_cell_id`]. Enabling consumes no
    /// RNG draws and changes no numerics — the produced `TrainLog` is
    /// bitwise-identical to a disabled run's.
    pub fn enable_obs(&mut self) {
        self.obs = ObsSink::enabled(self.cell_id);
    }

    /// The trainer's observability sink (disabled sinks report nothing).
    pub fn obs(&self) -> &ObsSink {
        &self.obs
    }

    pub fn obs_mut(&mut self) -> &mut ObsSink {
        &mut self.obs
    }

    /// Render the collected trace as Chrome trace-event JSON (empty event
    /// list when tracing was never enabled). Flat runs have no cloud lane.
    pub fn export_trace(&self) -> String {
        crate::obs::chrome_trace(self.obs.events(), None)
    }

    /// Per-period metrics snapshots as JSONL (empty when disabled).
    pub fn export_metrics(&self) -> String {
        self.obs.to_jsonl()
    }

    /// Predicted-vs-realized audit ledger as JSONL (empty when disabled;
    /// summarize with `feel audit`).
    pub fn export_audit(&self) -> String {
        self.obs.audit_jsonl()
    }

    /// The per-device backend registry this trainer resolves through —
    /// the cloud aggregator walks it to pair up model families across
    /// cells by name.
    pub fn backend_set(&self) -> &BackendSet<'a> {
        &self.backends
    }

    /// Total training samples across this trainer's device shards — the
    /// FedAvg weight of this cell's edge model in the cloud merge.
    pub fn total_samples(&self) -> usize {
        self.workers.iter().map(|w| w.shard_len()).sum()
    }

    /// Advance the simulated clock to the absolute time `t` (>= now): the
    /// cloud-barrier hook — after a cross-cell merge every cell resumes
    /// from the slowest cell's clock, so the next period's records start
    /// from the shared synchronization point.
    pub fn sync_clock_to(&mut self, t: f64) {
        self.clock.advance_to(t);
    }

    /// Warm-start: train every family's global model centrally for
    /// `steps` SGD steps of batchsize `b` before the federated comparison
    /// (Table II starts from a pre-trained model). All families see the
    /// same drawn batches — one RNG draw per step regardless of the
    /// family count, so a homogeneous run is untouched.
    pub fn warm_start(&mut self, steps: usize, b: usize, lr: f32) -> Result<()> {
        let n = self.train.len();
        let budget = self.engine.threads();
        for _ in 0..steps {
            let idx = self.rng.sample_indices(n, b.min(n));
            let (x, y) = self.train.gather(&idx);
            for f in 0..self.backends.family_count() {
                let backend = self.backends.family_backend(f);
                let params = self.server.family_params(f);
                // centralized steps run on the coordinator thread: cap
                // their GEMM fan-out at the trainer's budget, like
                // evaluate() does
                let s = crate::util::threads::with_budget(budget, || {
                    backend.train_step(params, &x, &y)
                })?;
                let updated = backend.apply_update(params, &s.grads, lr)?;
                self.server.set_family_params(f, updated);
            }
        }
        // local-training schemes start every device from its family's
        // warm model
        if matches!(self.cfg.scheme, Scheme::Individual { .. }) {
            for (id, w) in self.workers.iter_mut().enumerate() {
                let f = self.backends.family_of(id);
                w.local_params = Some(self.server.family_params(f).to_vec());
            }
        }
        Ok(())
    }

    /// Parameter count the latency model prices payloads against: the
    /// *largest* family's. The optimizer's `Instance` carries one fleet-
    /// wide upload size, so mixed fleets are priced conservatively (and
    /// symmetrically — the number cannot depend on which tier happens to
    /// hold device 0). Homogeneous fleets see exactly their model's count.
    fn wire_params(&self) -> usize {
        (0..self.backends.family_count())
            .map(|f| self.backends.family_params(f))
            .max()
            // lint: allow(panic-path): BackendSet construction rejects empty fleets
            .expect("backend set has at least one family")
    }

    /// Gradient payload size in bits under the latency model: s = r*d*p.
    fn grad_wire_bits(&self) -> f64 {
        self.cfg.wire_ratio * self.cfg.quant_bits as f64 * self.wire_params() as f64
    }

    /// Parameter payload for model-based FL: d bits per term, no sparse
    /// compression (parameters are dense; the paper's 200x gap between
    /// parameter and compressed-gradient traffic comes from exactly this).
    fn param_wire_bits(&self) -> f64 {
        self.cfg.quant_bits as f64 * self.wire_params() as f64
    }

    /// eta = O(sqrt(B)) scaling (paper §III-A, refs [36][37]) for an
    /// aggregated batch of `b`; capped at 1x base so whole-shard schemes
    /// (gradient/model FL) don't blow up. A sampled round scales the
    /// applied batch by the inverse inclusion probability first — the
    /// Horvitz–Thompson estimate of the batch the full fleet would have
    /// contributed — so the step size stays unbiased for the
    /// full-participation schedule. `b / 1.0 == b` bitwise, so the
    /// unsampled path is untouched.
    fn lr_for_batch(&self, b: usize) -> f64 {
        let b_est = b as f64 / self.cfg.sample_frac;
        self.cfg.base_lr
            * (b_est / (self.fleet.len() * self.cfg.b_max) as f64)
                .sqrt()
                .min(1.0)
    }

    /// This period's optimizer instance from fresh channel draws. The
    /// rate buffer is trainer-owned scratch, reused across periods.
    fn period_instance(&mut self) -> Result<Instance> {
        let mut rates = std::mem::take(&mut self.rates_scratch);
        rates.clear();
        {
            let rng = &mut self.rng;
            rates.extend(self.fleet.iter_mut().map(|d| d.link.step(rng)));
        }
        let inst = Instance::from_fleet(
            &self.fleet,
            &rates,
            self.cfg.b_max as f64,
            self.grad_wire_bits(),
            self.cfg.frame_ul,
            self.cfg.frame_dl,
            self.xi.value(),
        );
        self.rates_scratch = rates;
        inst
    }

    /// Sampled-round optimizer instance: O(sampled) channel draws keyed by
    /// `(seed, period, device)` counter-derived streams, so each device's
    /// link evolution is independent of which other devices were drawn and
    /// of thread count. Only sampled devices' Gauss–Markov shadow state
    /// advances — link evolution is participation-indexed in sampled mode
    /// (a deliberate modeling choice: O(K) per-round work would defeat the
    /// point of sampling).
    fn sampled_period_instance(&mut self, ids: &[usize]) -> Result<Instance> {
        let period = self.server.period as u64;
        let mut rates = std::mem::take(&mut self.rates_scratch);
        rates.clear();
        for &g in ids {
            let mut lrng = Pcg::for_device(self.cfg.seed ^ SAMPLED_LINK_TAG, period, g as u64);
            rates.push(self.fleet[g].link.step(&mut lrng));
        }
        let inst = Instance::from_fleet_ids(
            &self.fleet,
            ids,
            &rates,
            self.cfg.b_max as f64,
            self.grad_wire_bits(),
            self.cfg.frame_ul,
            self.cfg.frame_dl,
            self.xi.value(),
        );
        self.rates_scratch = rates;
        inst
    }

    /// Run `periods` training periods; returns the log.
    pub fn run(&mut self, periods: usize) -> Result<&TrainLog> {
        for _ in 0..periods {
            self.step_period()?;
        }
        Ok(&self.log)
    }

    /// Run until the simulated clock passes `t_limit` seconds (Fig. 4/5's
    /// x-axis) or `max_periods` elapse.
    pub fn run_for_time(&mut self, t_limit: f64, max_periods: usize) -> Result<&TrainLog> {
        for _ in 0..max_periods {
            if self.clock.now() >= t_limit {
                break;
            }
            self.step_period()?;
        }
        Ok(&self.log)
    }

    /// One full training period (paper steps 1–5). For gradient-exchange
    /// schemes the round policy decides when the period closes and which
    /// contributions enter the reduce; the scheduler reports the period's
    /// effective duration and the clock advances by it — through
    /// [`SimClock`] only, so every policy shares one comparable time axis.
    pub fn step_period(&mut self) -> Result<()> {
        // lint: allow(wall-clock): WallStats wall-time accounting — never enters SimClock
        let t_step = Instant::now();
        // draw this period's participants first (counter-derived stream —
        // consumes nothing from the trainer RNG, so the unsampled path is
        // untouched down to the draw order)
        let sampled: Option<Vec<usize>> = self
            .sampler
            .map(|s| s.sample(self.server.period as u64, self.fleet.len()));
        let (inst, mut plan) = match &sampled {
            Some(ids) => {
                // O(sampled): instance, shard sizes, and the optimizer all
                // see the sampled subset only; the plan is then scattered
                // back to global device indexing for execution
                let inst = self.sampled_period_instance(ids)?;
                let shard_sizes: Vec<usize> =
                    ids.iter().map(|&g| self.workers[g].shard_len()).collect();
                let splan = plan_period(
                    self.cfg.scheme,
                    &inst,
                    &shard_sizes,
                    self.param_wire_bits(),
                    self.cfg.eps,
                    &mut self.rng,
                )?;
                (inst, scatter_plan(splan, ids, self.fleet.len()))
            }
            None => {
                let inst = self.period_instance()?;
                let shard_sizes: Vec<usize> =
                    self.workers.iter().map(|w| w.shard_len()).collect();
                let plan = plan_period(
                    self.cfg.scheme,
                    &inst,
                    &shard_sizes,
                    self.param_wire_bits(),
                    self.cfg.eps,
                    &mut self.rng,
                )?;
                (inst, plan)
            }
        };
        // deadline policy: fold batches deferred by last period's misses
        // back into this period's plan (no-op otherwise; crashed devices
        // keep their ledger entry, cold rejoins forfeit it)
        let rng_period = self.server.period as u64;
        match &sampled {
            Some(ids) => self.sched.apply_carry_sampled(&mut plan, &inst, ids, rng_period),
            None => self.sched.apply_carry(&mut plan, &inst, rng_period),
        }
        self.log.wall.solver_secs += t_step.elapsed().as_secs_f64();
        let b_total: usize = plan.batches.iter().sum();
        // audit: open this period's predicted-vs-realized row from the
        // post-carry plan (1-based display period, matching the record
        // pushed below). No-op when observability is off.
        self.obs
            .audit_begin(self.server.period as u64 + 1, self.clock.now(), &plan);

        let (report, lr) = match self.cfg.scheme {
            // gradient schemes compute their step size *after* the round
            // closes, from the batch that actually entered the update —
            // a deadline/async round may apply far less than the plan
            Scheme::Proposed | Scheme::GradientFl | Scheme::Fixed { .. } => {
                self.gradient_period(&plan, sampled.as_deref())?
            }
            Scheme::ModelFl { local_batch } => {
                // local steps see batch `local_batch`, not the plan's shard
                // total — scale eta by the batch they actually use
                let local_lr = self.cfg.base_lr
                    * (local_batch as f64 / self.cfg.b_max as f64).sqrt().min(1.0);
                let loss = self.model_fl_period(local_batch, local_lr as f32)?;
                // comm-free barrier schemes bypass the round scheduler:
                // every device realizes its prediction exactly
                self.obs.audit_barrier_fill();
                (barrier_report(loss, &plan, self.fleet.len(), b_total), self.lr_for_batch(b_total))
            }
            Scheme::Individual { .. } => {
                let lr = self.lr_for_batch(b_total);
                let loss = self.individual_period(&plan, lr as f32)?;
                self.obs.audit_barrier_fill();
                (barrier_report(loss, &plan, self.fleet.len(), b_total), lr)
            }
        };

        // a round where nothing arrived measures no loss: carry the last
        // one (NaN only if the very first round is empty). Keyed on
        // `updated`, not on NaN — a diverged round that did apply
        // gradients must keep its NaN visible in the log.
        let train_loss = if report.updated {
            report.train_loss
        } else {
            self.last_train_loss.unwrap_or(f64::NAN)
        };

        // xi bookkeeping from the measured loss decay over the batch that
        // actually entered the update
        let dl = if report.updated {
            if let Some(prev) = self.last_train_loss {
                self.xi.observe(prev - train_loss, report.b_effective.max(1) as f64);
            }
            let dl = self.last_train_loss.map(|p| p - train_loss).unwrap_or(0.0);
            self.last_train_loss = Some(train_loss);
            dl
        } else {
            0.0
        };

        // event-queue style: the clock jumps to the period's absolute end
        // time (`now + dt` — the same addition `advance` performs, so the
        // sync path stays bitwise)
        let t_start = self.clock.now();
        let t_end = t_start + report.duration;
        self.clock.advance_to(t_end);
        self.server.period += 1;
        let period = self.server.period;

        let (test_loss, test_acc) = if self.cfg.eval_every > 0
            && (period % self.cfg.eval_every == 0 || period == 1)
        {
            let (l, a) = self.evaluate()?;
            (Some(l), Some(a))
        } else {
            (None, None)
        };

        self.log.records.push(PeriodRecord {
            period,
            sim_time: self.clock.now(),
            t_period: report.duration,
            b_total,
            train_loss,
            lr,
            test_loss,
            test_acc,
            efficiency: if report.duration > 0.0 { dl / report.duration } else { 0.0 },
            applied: report.applied,
            dropped: report.dropped,
            late: report.late,
            stale_mean: report.stale_mean,
            cell: self.cell_id,
            cloud: false,
            crashed: report.crashed,
            corrupt: report.corrupt,
            quarantined: report.quarantined,
        });
        // observability: one span per period on the coordinator lane, the
        // round counters, and a per-period metrics snapshot. Everything
        // here derives from simulated-time quantities only — never wall
        // clock — so an enabled trace is deterministic across thread
        // counts and repeat runs.
        if self.obs.is_enabled() {
            self.obs.span_arg(
                "period",
                "round",
                0,
                t_start,
                report.duration,
                &[("b_total", b_total as f64), ("applied", report.applied as f64)],
            );
            self.obs.inc("round.applied", report.applied as u64);
            self.obs.inc("round.dropped", report.dropped as u64);
            self.obs.inc("round.late", report.late as u64);
            self.obs.inc("fault.crashed", report.crashed as u64);
            self.obs.inc("fault.corrupt", report.corrupt as u64);
            self.obs.inc("agg.quarantined", report.quarantined as u64);
            self.obs.observe("round.duration", report.duration);
            self.obs.gauge("train.loss", train_loss);
            self.obs.gauge("sim.time", t_end);
            self.obs
                .audit_end(report.duration, dl, b_total as u64, report.applied as u64);
            self.obs.snapshot(period as u64);
        }
        self.log.wall.total_secs += t_step.elapsed().as_secs_f64();
        Ok(())
    }

    /// Steps 1–5 for gradient-exchange schemes, closed by the round
    /// policy. The scheduler fans the device steps out on the engine
    /// (shard boundaries from K alone, device-order f64 folds — see
    /// exec/mod.rs), injects straggler perturbations, drains its event
    /// queue per the policy, and fills the long-lived per-family server
    /// accumulators; the trainer then applies each family's
    /// batch-weighted global gradient (eq. 1) to that family's model —
    /// a family nothing arrived for keeps its parameters standing. The
    /// step size is shared across families, scaled by `b_effective` (the
    /// total aggregated batch), which equals the planned total under a
    /// clean sync barrier but shrinks with every dropped or deferred
    /// contribution.
    fn gradient_period(
        &mut self,
        plan: &Plan,
        participants: Option<&[usize]>,
    ) -> Result<(RoundReport, f64)> {
        for agg in &mut self.aggs {
            agg.reset();
        }
        let report = self.sched.gradient_period(
            &self.engine,
            &self.backends,
            &mut self.workers,
            self.server.all_params(),
            self.train,
            plan,
            self.server.period as u64,
            self.clock.now(),
            participants,
            &mut self.aggs,
            &mut self.obs,
        )?;
        self.log.wall.reduce_secs += report.reduce_secs;
        let lr = self.lr_for_batch(report.b_effective);
        if report.updated {
            // lint: allow(wall-clock): WallStats wall-time accounting — never enters SimClock
            let t0 = Instant::now();
            for f in 0..self.aggs.len() {
                if self.aggs[f].contributions() == 0 {
                    continue;
                }
                let global = self.aggs[f].average()?;
                let backend = self.backends.family_backend(f);
                let updated =
                    backend.apply_update(self.server.family_params(f), &global, lr as f32)?;
                self.server.set_family_params(f, updated);
            }
            self.log.wall.reduce_secs += t0.elapsed().as_secs_f64();
        }
        Ok((report, lr))
    }

    /// Model-based FL: one local epoch per device (parallel), then FedAvg
    /// in fixed device order. Homogeneous fleets only (enforced at
    /// construction).
    fn model_fl_period(&mut self, local_batch: usize, lr: f32) -> Result<f64> {
        let outcomes = exec::model_fl_round(
            &self.engine,
            &self.backends,
            &mut self.workers,
            self.server.all_params(),
            self.train,
            local_batch,
            lr,
            self.cfg.seed,
            self.server.period as u64,
        )?;
        let mut loss_acc = 0f64;
        let mut w_acc = 0f64;
        let mut averaged: Vec<(Vec<f32>, f64)> = Vec::with_capacity(outcomes.len());
        for o in outcomes {
            loss_acc += o.loss * o.weight;
            w_acc += o.weight;
            averaged.push((o.params, o.weight));
        }
        // lint: allow(wall-clock): WallStats wall-time accounting — never enters SimClock
        let t0 = Instant::now();
        self.server.average_params(&averaged)?;
        self.log.wall.reduce_secs += t0.elapsed().as_secs_f64();
        Ok(loss_acc / w_acc)
    }

    /// Individual learning: one local step per device on its own params.
    fn individual_period(&mut self, plan: &Plan, lr: f32) -> Result<f64> {
        let outcomes = exec::individual_round(
            &self.engine,
            &self.backends,
            &mut self.workers,
            self.server.all_params(),
            self.train,
            &plan.batches,
            lr,
            self.cfg.seed,
            self.server.period as u64,
        )?;
        let mut loss_acc = 0f64;
        let mut w_acc = 0f64;
        for o in &outcomes {
            loss_acc += o.loss * o.weight;
            w_acc += o.weight;
        }
        Ok(loss_acc / w_acc)
    }

    /// Evaluate on the held-out set. Global-model schemes evaluate the
    /// server params — per family for mixed fleets, averaged weighted by
    /// family device count; individual learning averages each device's
    /// metrics (the paper's final step averages the models — we report
    /// the mean device performance, which matches its "isolated islands"
    /// framing), with the per-device evaluations fanned out on the
    /// engine. Takes `&mut self` so evaluation scratch comes from
    /// long-lived workspaces instead of the allocator.
    pub fn evaluate(&mut self) -> Result<(f64, f64)> {
        match self.cfg.scheme {
            Scheme::Individual { .. } => {
                let results = exec::eval_round(
                    &self.engine,
                    &self.backends,
                    &mut self.workers,
                    self.server.all_params(),
                    &self.test.x,
                    &self.test.y,
                )?;
                let n = results.len() as f64;
                let (loss, acc) = results
                    .iter()
                    .fold((0f64, 0f64), |(l, a), r| (l + r.0, a + r.1));
                Ok((loss / n, acc / n))
            }
            // full-dataset eval on the coordinator thread: the GEMM row
            // blocking inside may fan out, capped by the trainer's budget
            _ => {
                let budget = self.engine.threads();
                let backends = &self.backends;
                let server = &self.server;
                let ws = &mut self.eval_scratch;
                let (test_x, test_y) = (&self.test.x, &self.test.y);
                crate::util::threads::with_budget(budget, move || {
                    if backends.is_homogeneous() {
                        return backends
                            .family_backend(0)
                            .evaluate_ws(server.params(), test_x, test_y, ws);
                    }
                    // mixed fleet: mean over families weighted by how
                    // many devices train each model
                    let mut loss = 0f64;
                    let mut acc = 0f64;
                    for f in 0..backends.family_count() {
                        let kf = backends.family_size(f) as f64;
                        let (l, a) = backends.family_backend(f).evaluate_ws(
                            server.family_params(f),
                            test_x,
                            test_y,
                            ws,
                        )?;
                        loss += l * kf;
                        acc += a * kf;
                    }
                    let k = backends.k() as f64;
                    Ok((loss / k, acc / k))
                })
            }
        }
    }

    pub fn sim_time(&self) -> f64 {
        self.clock.now()
    }

    pub fn xi_value(&self) -> f64 {
        self.xi.value()
    }

    /// The round policy this trainer closes periods with.
    pub fn policy(&self) -> RoundPolicy {
        self.sched.policy()
    }

    /// Configuration fingerprint stamped into every checkpoint: a resumed
    /// run must have been constructed with the same seed, fleet size,
    /// model families, scheme, policy, straggler/sampling/fault knobs —
    /// everything the replay depends on. `threads` is deliberately
    /// excluded: numerics are thread-invariant, so a checkpoint written
    /// at one thread count resumes bitwise at any other.
    fn state_digest(&self) -> u64 {
        use crate::coordinator::checkpoint::fnv1a64;
        use crate::util::rng::splitmix64;
        let c = &self.cfg;
        let mut fields: Vec<u64> = vec![
            c.seed,
            self.fleet.len() as u64,
            self.backends.family_count() as u64,
        ];
        for f in 0..self.backends.family_count() {
            fields.push(self.backends.family_params(f) as u64);
            fields.push(fnv1a64(self.backends.family_name(f).as_bytes()));
        }
        fields.extend([
            fnv1a64(format!("{:?}", c.scheme).as_bytes()),
            fnv1a64(format!("{:?}", c.policy).as_bytes()),
            c.b_max as u64,
            c.quant_bits as u64,
            c.sbc_keep.map_or(u64::MAX, f64::to_bits),
            c.wire_ratio.to_bits(),
            c.frame_ul.to_bits(),
            c.frame_dl.to_bits(),
            c.base_lr.to_bits(),
            c.xi_init.to_bits(),
            c.xi_alpha.to_bits(),
            c.eval_every as u64,
            c.eps.to_bits(),
            c.straggler.jitter.to_bits(),
            c.straggler.dropout.to_bits(),
            c.sample_frac.to_bits(),
            c.fault.crash_rate.to_bits(),
            c.fault.crash_len,
            c.fault.corrupt_rate.to_bits(),
            c.fault.corrupt_noise.to_bits(),
            c.fault.outage_rate.to_bits(),
            fnv1a64(c.guard.policy.name().as_bytes()),
            c.guard.max_norm.to_bits(),
            self.cell_id as u64,
        ]);
        fields.iter().fold(0xfee1_cdc0_dec0_ffee_u64, |h, &v| splitmix64(h ^ v))
    }

    /// Serialize the full live training state — everything `step_period`
    /// reads or advances — as a checkpoint payload. Field order is the
    /// layout contract with [`Trainer::restore_payload`]; any change to
    /// either must bump `checkpoint::VERSION`.
    pub(crate) fn checkpoint_payload(&self) -> Vec<u8> {
        let mut w = ByteWriter::new();
        w.put_u64(self.state_digest());
        w.put_usize(self.server.period);
        w.put_f64(self.clock.now());
        let (xi_v, xi_n) = self.xi.snapshot();
        w.put_f64(xi_v);
        w.put_usize(xi_n);
        let (rs, ri) = self.rng.state();
        w.put_u64(rs);
        w.put_u64(ri);
        w.put_opt_f64(self.last_train_loss);
        w.put_usize(self.backends.family_count());
        for f in 0..self.backends.family_count() {
            w.put_f32s(self.server.family_params(f));
        }
        w.put_usize(self.fleet.len());
        for d in &self.fleet {
            let (ul, dl) = d.link.shadow_state();
            w.put_f64(ul);
            w.put_f64(dl);
        }
        for wk in &self.workers {
            let (s, i) = wk.data.rng_state();
            w.put_u64(s);
            w.put_u64(i);
            w.put_opt_f32s(wk.sbc.as_ref().map(Sbc::residual));
            w.put_opt_f32s(wk.local_params.as_deref());
        }
        let sck = self.sched.snapshot();
        for &c in &sck.carry {
            w.put_usize(c);
        }
        for &b in &sck.busy {
            w.put_bool(b);
        }
        w.put_usize(sck.inflight.len());
        for r in &sck.inflight {
            w.put_f64(r.time);
            w.put_usize(r.device);
            w.put_u64(r.period);
            w.put_usize(r.batch);
            w.put_f64(r.loss);
            w.put_f32s(&r.grad);
        }
        w.put_f64(self.log.wall.solver_secs);
        w.put_f64(self.log.wall.reduce_secs);
        w.put_f64(self.log.wall.total_secs);
        w.put_usize(self.log.records.len());
        for r in &self.log.records {
            w.put_usize(r.period);
            w.put_f64(r.sim_time);
            w.put_f64(r.t_period);
            w.put_usize(r.b_total);
            w.put_f64(r.train_loss);
            w.put_f64(r.lr);
            w.put_opt_f64(r.test_loss);
            w.put_opt_f64(r.test_acc);
            w.put_f64(r.efficiency);
            w.put_usize(r.applied);
            w.put_usize(r.dropped);
            w.put_usize(r.late);
            w.put_f64(r.stale_mean);
            w.put_usize(r.cell);
            w.put_bool(r.cloud);
            w.put_usize(r.crashed);
            w.put_usize(r.corrupt);
            w.put_usize(r.quarantined);
        }
        w.into_inner()
    }

    /// Restore a payload written by [`Trainer::checkpoint_payload`] into
    /// this (freshly constructed, identically configured) trainer.
    /// All-or-nothing: the complete payload is parsed and validated into
    /// locals first, so any failure leaves the trainer exactly as it was.
    pub(crate) fn restore_payload(&mut self, payload: &[u8]) -> Result<()> {
        let mut r = ByteReader::new(payload);
        let digest = r.get_u64()?;
        let own = self.state_digest();
        if digest != own {
            bail!(
                "checkpoint was produced by a different run configuration \
                 (digest {digest:#018x}, this run {own:#018x}): seed, fleet, scheme, \
                 policy, straggler, sampling, and fault knobs must all match"
            );
        }
        let period = r.get_usize()?;
        let now = r.get_f64()?;
        if !now.is_finite() || now < 0.0 {
            bail!("checkpoint corrupt: simulated clock {now}");
        }
        let xi_v = r.get_f64()?;
        let xi_n = r.get_usize()?;
        if !xi_v.is_finite() {
            bail!("checkpoint corrupt: xi estimate {xi_v}");
        }
        let rng_s = r.get_u64()?;
        let rng_i = r.get_u64()?;
        let last_loss = r.get_opt_f64()?;
        let nf = r.get_usize()?;
        if nf != self.backends.family_count() {
            bail!(
                "checkpoint has {nf} model families, this run has {}",
                self.backends.family_count()
            );
        }
        let mut fam_params = Vec::with_capacity(nf);
        for f in 0..nf {
            let p = r.get_f32s()?;
            if p.len() != self.backends.family_params(f) {
                bail!(
                    "checkpoint family {f} ({}) holds {} parameters, this run's model \
                     has {}",
                    self.backends.family_name(f),
                    p.len(),
                    self.backends.family_params(f)
                );
            }
            fam_params.push(p);
        }
        let k = r.get_usize()?;
        if k != self.fleet.len() {
            bail!("checkpoint is for a {k}-device fleet, this run has {}", self.fleet.len());
        }
        let mut shadows = Vec::with_capacity(k);
        for _ in 0..k {
            let ul = r.get_f64()?;
            let dl = r.get_f64()?;
            if !ul.is_finite() || !dl.is_finite() {
                bail!("checkpoint corrupt: non-finite shadowing state ({ul}, {dl})");
            }
            shadows.push((ul, dl));
        }
        struct WorkerState {
            rng: (u64, u64),
            residual: Option<Vec<f32>>,
            local_params: Option<Vec<f32>>,
        }
        let mut wstates = Vec::with_capacity(k);
        for (i, wk) in self.workers.iter().enumerate() {
            let s = r.get_u64()?;
            let inc = r.get_u64()?;
            let residual = r.get_opt_f32s()?;
            if residual.is_some() != wk.sbc.is_some() {
                bail!(
                    "checkpoint device {i} {} an SBC residual but this run {} a compressor",
                    if residual.is_some() { "carries" } else { "lacks" },
                    if wk.sbc.is_some() { "uses" } else { "does not use" }
                );
            }
            if let (Some(res), Some(sbc)) = (&residual, &wk.sbc) {
                if res.len() != sbc.residual().len() {
                    bail!(
                        "checkpoint device {i} residual has {} terms, this run's \
                         compressor holds {} (checkpoint from a different model?)",
                        res.len(),
                        sbc.residual().len()
                    );
                }
            }
            let local_params = r.get_opt_f32s()?;
            if let Some(lp) = &local_params {
                let want = self.backends.device_params(i);
                if lp.len() != want {
                    bail!(
                        "checkpoint device {i} local params have {} terms, its model \
                         has {want}",
                        lp.len()
                    );
                }
            }
            wstates.push(WorkerState { rng: (s, inc), residual, local_params });
        }
        let mut carry = Vec::with_capacity(k);
        for _ in 0..k {
            carry.push(r.get_usize()?);
        }
        let mut busy = Vec::with_capacity(k);
        for _ in 0..k {
            busy.push(r.get_bool()?);
        }
        let n_inflight = r.get_usize()?;
        let mut inflight = Vec::with_capacity(n_inflight.min(k * 2));
        for _ in 0..n_inflight {
            let time = r.get_f64()?;
            let device = r.get_usize()?;
            let iperiod = r.get_u64()?;
            let batch = r.get_usize()?;
            let loss = r.get_f64()?;
            let grad = r.get_f32s()?;
            if !time.is_finite() || time < 0.0 {
                bail!("checkpoint corrupt: in-flight event time {time}");
            }
            if device >= k {
                bail!("checkpoint corrupt: in-flight device {device} of a {k}-device fleet");
            }
            if grad.len() != self.backends.device_params(device) {
                bail!(
                    "checkpoint in-flight gradient for device {device} has {} terms, \
                     its model has {}",
                    grad.len(),
                    self.backends.device_params(device)
                );
            }
            inflight.push(InflightRecord { time, device, period: iperiod, batch, loss, grad });
        }
        let wall = WallStats {
            solver_secs: r.get_f64()?,
            reduce_secs: r.get_f64()?,
            total_secs: r.get_f64()?,
        };
        let n_records = r.get_usize()?;
        let mut records = Vec::with_capacity(n_records.min(payload.len() / 32));
        for _ in 0..n_records {
            records.push(PeriodRecord {
                period: r.get_usize()?,
                sim_time: r.get_f64()?,
                t_period: r.get_f64()?,
                b_total: r.get_usize()?,
                train_loss: r.get_f64()?,
                lr: r.get_f64()?,
                test_loss: r.get_opt_f64()?,
                test_acc: r.get_opt_f64()?,
                efficiency: r.get_f64()?,
                applied: r.get_usize()?,
                dropped: r.get_usize()?,
                late: r.get_usize()?,
                stale_mean: r.get_f64()?,
                cell: r.get_usize()?,
                cloud: r.get_bool()?,
                crashed: r.get_usize()?,
                corrupt: r.get_usize()?,
                quarantined: r.get_usize()?,
            });
        }
        r.expect_end()?;
        // everything parsed and validated — apply
        self.server.period = period;
        self.clock.restore(now);
        self.xi.restore(xi_v, xi_n);
        self.rng = Pcg::from_state(rng_s, rng_i);
        self.last_train_loss = last_loss;
        for (f, p) in fam_params.into_iter().enumerate() {
            self.server.set_family_params(f, p);
        }
        for (d, (ul, dl)) in self.fleet.iter_mut().zip(shadows) {
            d.link.restore_shadow_state(ul, dl);
        }
        for (wk, st) in self.workers.iter_mut().zip(wstates) {
            wk.data.restore_rng_state(st.rng.0, st.rng.1);
            if let (Some(res), Some(sbc)) = (st.residual, &mut wk.sbc) {
                sbc.restore_residual(res)?;
            }
            wk.local_params = st.local_params;
        }
        self.sched.restore(SchedCheckpoint { carry, busy, inflight })?;
        self.log = TrainLog { records, wall };
        Ok(())
    }

    /// Write the live training state to `path` as a flat checkpoint.
    pub fn save_checkpoint(&self, path: &Path) -> Result<()> {
        checkpoint::write_file(path, checkpoint::KIND_FLAT, &self.checkpoint_payload())
    }

    /// Load a flat checkpoint from `path` into this freshly constructed
    /// trainer. The trainer must have been built with the same
    /// configuration the checkpoint was written under (enforced by the
    /// digest); on any error the trainer is left untouched.
    pub fn resume_from(&mut self, path: &Path) -> Result<()> {
        let payload = checkpoint::read_file(path, checkpoint::KIND_FLAT)?;
        self.restore_payload(&payload)
            .with_context(|| format!("restoring checkpoint {}", path.display()))?;
        // stamped at the restored clock: the trace shows where in
        // simulated time the run picked back up, and the resume-period
        // gauge lets a metrics reader split pre/post-resume snapshots
        self.obs.instant("ckpt_restore", "ckpt", 0, self.clock.now());
        self.obs.instant("run.resumed", "ckpt", 0, self.clock.now());
        self.obs.inc("ckpt.restores", 1);
        self.obs.gauge("ckpt.resume_period", self.server.period as f64);
        Ok(())
    }

    /// Run `periods` training periods, writing a checkpoint to `path`
    /// whenever the global period count hits a multiple of `every`
    /// (`every == 0` never writes). Keyed on `server.period`, not the
    /// loop index, so the cadence survives resume.
    pub fn run_checkpointed(
        &mut self,
        periods: usize,
        every: usize,
        path: &Path,
    ) -> Result<&TrainLog> {
        for _ in 0..periods {
            self.step_period()?;
            if every > 0 && self.server.period % every == 0 {
                self.save_checkpoint(path)?;
                self.obs.instant("ckpt_save", "ckpt", 0, self.clock.now());
                self.obs.inc("ckpt.saves", 1);
            }
        }
        Ok(&self.log)
    }
}

/// Scatter a plan solved over the sampled subset (`splan.batches[i]`
/// belongs to global device `ids[i]`) back to global device indexing:
/// unsampled devices get batch 0 / finish 0.0 and are never dispatched
/// (the scheduler's participant list keeps them out of the round — the
/// executors clamp batches to >= 1, so masking is load-bearing, not just
/// an optimization). Scalar fields carry over unchanged.
fn scatter_plan(splan: Plan, ids: &[usize], k: usize) -> Plan {
    debug_assert_eq!(splan.batches.len(), ids.len());
    let mut batches = vec![0usize; k];
    let mut finish = vec![0f64; k];
    let mut predicted = vec![crate::opt::types::PredictedTiming::default(); k];
    for (i, &g) in ids.iter().enumerate() {
        batches[g] = splan.batches[i];
        finish[g] = splan.finish[i];
        predicted[g] = splan.predicted.get(i).copied().unwrap_or_default();
    }
    Plan {
        batches,
        t_period: splan.t_period,
        t_up: splan.t_up,
        t_down: splan.t_down,
        finish,
        predicted,
        predicted_efficiency: splan.predicted_efficiency,
    }
}

/// The trivial full-participation report for schemes that do not go
/// through the round scheduler (model-FL, individual learning): every
/// device contributes and the period lasts its planned length.
fn barrier_report(loss: f64, plan: &Plan, k: usize, b_total: usize) -> RoundReport {
    RoundReport {
        duration: plan.t_period,
        train_loss: loss,
        b_effective: b_total,
        applied: k,
        dropped: 0,
        late: 0,
        stale_mean: 0.0,
        crashed: 0,
        corrupt: 0,
        quarantined: 0,
        updated: true,
        reduce_secs: 0.0,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::backend::HostBackend;
    use crate::data::synthetic::{generate, SynthConfig};
    use crate::device::paper_cpu_fleet;
    use crate::wireless::CellConfig;

    fn tiny_world() -> (Dataset, Dataset, Vec<Device>) {
        let cfg = SynthConfig { dim: 24, ..Default::default() };
        let train = generate(&cfg, 600, 1);
        let test = generate(&cfg, 200, 1);
        let mut rng = Pcg::seeded(2);
        let fleet = paper_cpu_fleet(4, 7e7, 1e8, CellConfig::default(), 4.0, 0.5, &mut rng);
        (train, test, fleet)
    }

    fn run_scheme(scheme: Scheme, periods: usize) -> TrainLog {
        let (train, test, fleet) = tiny_world();
        let be = HostBackend::for_model("mini_res", 24, 10, 3).unwrap();
        let cfg = TrainerConfig { scheme, eval_every: periods, ..Default::default() };
        let mut tr = Trainer::new(cfg, fleet, &train, &test, Partition::Iid, &be).unwrap();
        tr.run(periods).unwrap();
        tr.log.clone()
    }

    #[test]
    fn proposed_loss_decreases() {
        let log = run_scheme(Scheme::Proposed, 40);
        assert_eq!(log.records.len(), 40);
        let first = log.mean_loss_window(0, 5).unwrap();
        let last = log.mean_loss_window(35, 5).unwrap();
        assert!(last < first, "loss {first} -> {last}");
        // simulated time strictly increases
        for w in log.records.windows(2) {
            assert!(w[1].sim_time > w[0].sim_time);
        }
    }

    #[test]
    fn loss_window_guards_short_runs() {
        let log = run_scheme(Scheme::Proposed, 3);
        // in-range window works
        assert!(log.mean_loss_window(0, 3).is_ok());
        // short run: a clean error, not a slice panic
        let err = log.mean_loss_window(35, 5).unwrap_err().to_string();
        assert!(err.contains("out of range"), "{err}");
        assert!(log.mean_loss_window(0, 4).is_err());
        assert!(log.mean_loss_window(0, 0).is_err());
        assert!(log.mean_loss_window(usize::MAX, 2).is_err());
    }

    #[test]
    fn all_schemes_run_and_learn() {
        for scheme in [
            Scheme::Proposed,
            Scheme::GradientFl,
            Scheme::ModelFl { local_batch: 32 },
            Scheme::Individual { local_batch: 64 },
            Scheme::Fixed { policy: crate::opt::BatchPolicy::Random, optimal_slots: true },
        ] {
            let log = run_scheme(scheme, 15);
            assert_eq!(log.records.len(), 15, "{scheme:?}");
            let l0 = log.records[0].train_loss;
            let l1 = log.records.last().unwrap().train_loss;
            assert!(l1 < l0 * 1.2, "{scheme:?}: loss {l0} -> {l1}");
            assert!(log.total_time() > 0.0);
        }
    }

    #[test]
    fn proposed_beats_fixed_policies_on_sim_time() {
        // at equal period counts the proposed scheme should reach a lower
        // (or equal) loss per unit simulated time — the paper's headline
        let prop = run_scheme(Scheme::Proposed, 30);
        let online = run_scheme(
            Scheme::Fixed { policy: crate::opt::BatchPolicy::Online, optimal_slots: true },
            30,
        );
        // compare loss achieved per simulated second
        let rate_prop =
            (prop.records[0].train_loss - prop.final_loss().unwrap()) / prop.total_time();
        let rate_online =
            (online.records[0].train_loss - online.final_loss().unwrap()) / online.total_time();
        assert!(
            rate_prop > rate_online,
            "proposed {rate_prop} vs online {rate_online}"
        );
    }

    #[test]
    fn eval_runs_and_is_bounded() {
        let (train, test, fleet) = tiny_world();
        let be = HostBackend::for_model("mini_res", 24, 10, 3).unwrap();
        let cfg = TrainerConfig { eval_every: 5, ..Default::default() };
        let mut tr = Trainer::new(cfg, fleet, &train, &test, Partition::NonIid, &be).unwrap();
        tr.run(10).unwrap();
        let acc = tr.log.final_acc().unwrap();
        assert!((0.0..=1.0).contains(&acc));
    }

    #[test]
    fn warm_start_reduces_initial_loss() {
        let (train, test, fleet) = tiny_world();
        let be = HostBackend::for_model("mini_res", 24, 10, 3).unwrap();
        let cfg = TrainerConfig::default();
        let mut tr =
            Trainer::new(cfg.clone(), fleet.clone(), &train, &test, Partition::Iid, &be)
                .unwrap();
        let (l_cold, _) = tr.evaluate().unwrap();
        tr.warm_start(80, 64, 0.05).unwrap();
        let (l_warm, _) = tr.evaluate().unwrap();
        assert!(l_warm < l_cold, "{l_cold} -> {l_warm}");
    }

    #[test]
    fn explicit_thread_count_respected() {
        let (train, test, fleet) = tiny_world();
        let be = HostBackend::for_model("mini_res", 24, 10, 3).unwrap();
        let cfg = TrainerConfig { threads: 3, eval_every: 0, ..Default::default() };
        let mut tr = Trainer::new(cfg, fleet, &train, &test, Partition::Iid, &be).unwrap();
        assert_eq!(tr.threads(), 3);
        tr.run(2).unwrap();
        assert_eq!(tr.log.records.len(), 2);
    }

    #[test]
    fn wall_stats_accumulate() {
        let log = run_scheme(Scheme::Proposed, 5);
        assert!(log.wall.total_secs > 0.0);
        assert!(log.wall.solver_secs > 0.0);
        assert!(log.wall.reduce_secs > 0.0);
        let f = log.wall.serial_fraction();
        assert!(f > 0.0 && f < 1.0, "serial fraction {f}");
        assert_eq!(WallStats::default().serial_fraction(), 0.0);
    }

    #[test]
    fn csv_well_formed() {
        let log = run_scheme(Scheme::Proposed, 5);
        let csv = log.to_csv();
        let lines: Vec<&str> = csv.trim().lines().collect();
        assert_eq!(lines.len(), 6);
        assert!(lines[0].starts_with("period,"));
        assert!(lines[0]
            .ends_with(",applied,dropped,late,stale_mean,cell,cloud,crashed,corrupt,quarantined"));
        assert_eq!(lines[0].split(',').count(), 18);
        assert_eq!(lines[1].split(',').count(), 18);
        // flat fault-free runs: cell 0, no cloud markers, no fault columns
        for line in &lines[1..] {
            assert!(line.ends_with(",0,0,0,0,0"), "{line}");
        }
    }

    #[test]
    fn csv_header_is_golden() {
        // the exact column names and order are a compatibility contract
        // with index-based readers of older dumps: new columns are only
        // ever appended on the right, never inserted or renamed. Any
        // change here must be a deliberate format bump.
        let header = TrainLog::default().to_csv();
        assert_eq!(
            header,
            "period,sim_time,t_period,b_total,train_loss,lr,test_loss,test_acc,\
             efficiency,applied,dropped,late,stale_mean,cell,cloud,crashed,\
             corrupt,quarantined\n"
        );
        let cols: Vec<&str> = header.trim().split(',').collect();
        assert_eq!(
            cols,
            [
                "period",
                "sim_time",
                "t_period",
                "b_total",
                "train_loss",
                "lr",
                "test_loss",
                "test_acc",
                "efficiency",
                "applied",
                "dropped",
                "late",
                "stale_mean",
                "cell",
                "cloud",
                "crashed",
                "corrupt",
                "quarantined"
            ]
        );
    }

    #[test]
    fn obs_traces_periods_and_snapshots_metrics() {
        let (train, test, fleet) = tiny_world();
        let be = HostBackend::for_model("mini_res", 24, 10, 3).unwrap();
        let cfg = TrainerConfig { eval_every: 0, ..Default::default() };
        let mut tr = Trainer::new(cfg, fleet, &train, &test, Partition::Iid, &be).unwrap();
        tr.enable_obs();
        tr.run(4).unwrap();
        // one period span per round on the coordinator lane, plus the
        // per-device round spans from the executor
        let periods =
            tr.obs().events().iter().filter(|e| e.name == "period").count();
        assert_eq!(periods, 4);
        assert!(tr.obs().events().iter().any(|e| e.name == "round"));
        let trace = tr.export_trace();
        assert!(crate::util::json::Json::parse(&trace).is_ok(), "{trace}");
        // one metrics snapshot per period, all applied under a clean
        // sync barrier
        let jsonl = tr.export_metrics();
        assert_eq!(jsonl.lines().count(), 4);
        let m = tr.obs().metrics().unwrap();
        assert_eq!(m.counter("round.applied"), 16);
        assert_eq!(m.counter("round.dropped"), 0);
        assert_eq!(m.hist("round.duration").unwrap().total(), 4);
        // the audit ledger closed one row per period, everyone applied
        let audit = tr.obs().audit().unwrap();
        assert_eq!(audit.rows().len(), 4);
        for (i, row) in audit.rows().iter().enumerate() {
            assert_eq!(row.period, i as u64 + 1);
            assert_eq!(row.devices.len(), 4);
            assert!(row
                .devices
                .iter()
                .all(|d| d.outcome == crate::obs::Outcome::Applied));
        }
        assert_eq!(tr.export_audit().lines().count(), 4);
    }

    #[test]
    fn deterministic_replay() {
        let a = run_scheme(Scheme::Proposed, 10);
        let b = run_scheme(Scheme::Proposed, 10);
        for (x, y) in a.records.iter().zip(&b.records) {
            assert_eq!(x.train_loss, y.train_loss);
            assert_eq!(x.b_total, y.b_total);
            assert_eq!(x.sim_time, y.sim_time);
        }
    }

    fn run_policy(policy: RoundPolicy, straggler: StragglerModel, periods: usize) -> TrainLog {
        let (train, test, fleet) = tiny_world();
        let be = HostBackend::for_model("mini_res", 24, 10, 3).unwrap();
        let cfg = TrainerConfig { policy, straggler, eval_every: 0, ..Default::default() };
        let mut tr = Trainer::new(cfg, fleet, &train, &test, Partition::Iid, &be).unwrap();
        tr.run(periods).unwrap();
        tr.log.clone()
    }

    #[test]
    fn sync_jitter_stretches_periods_without_touching_numerics() {
        // jitter under the sync barrier changes *time only*: the same
        // devices run the same batches, so losses are bitwise identical
        // and every period is at least as long as its jitter-free twin
        let base = run_policy(RoundPolicy::Sync, StragglerModel::none(), 10);
        let jit = run_policy(RoundPolicy::Sync, StragglerModel::new(0.5, 0.0).unwrap(), 10);
        assert_eq!(base.records.len(), jit.records.len());
        for (a, b) in base.records.iter().zip(&jit.records) {
            assert_eq!(a.train_loss.to_bits(), b.train_loss.to_bits());
            assert_eq!(a.b_total, b.b_total);
            assert!(b.t_period >= a.t_period, "{} < {}", b.t_period, a.t_period);
            assert_eq!(b.applied, 4);
            assert_eq!(b.dropped, 0);
        }
        assert!(jit.sim_time() > base.sim_time());
    }

    #[test]
    fn deadline_faster_than_sync_under_jitter() {
        // straggler draws are counter-derived and policy-independent: a
        // deadline round either closes with everyone (never after the
        // barrier would have) or at the deadline while sync waits past it,
        // so the deadline run finishes the same period count strictly
        // sooner once anything misses
        let sm = StragglerModel::new(0.5, 0.0).unwrap();
        let sync = run_policy(RoundPolicy::Sync, sm, 12);
        let dl = run_policy(RoundPolicy::Deadline { factor: 1.5 }, sm, 12);
        assert!(dl.sim_time() < sync.sim_time());
        let late: usize = dl.records.iter().map(|r| r.late).sum();
        assert!(late > 0, "expected at least one deadline miss");
        assert!(dl.records.iter().any(|r| r.applied > 0));
    }

    #[test]
    fn async_closes_early_and_applies_stale_gradients() {
        let sm = StragglerModel::new(0.5, 0.0).unwrap();
        let sync = run_policy(RoundPolicy::Sync, sm, 12);
        let policy = RoundPolicy::Async { alpha: 0.6, beta: 0.5, quorum: 0.5 };
        let a = run_policy(policy, sm, 12);
        assert!(a.sim_time() < sync.sim_time());
        // quorum 0.5 of K=4 leaves devices in flight: staleness must show
        assert!(a.records.iter().any(|r| r.stale_mean > 0.0));
        for r in &a.records {
            assert!(r.applied <= 4);
            assert!(r.late == 0);
            assert!(r.t_period > 0.0);
        }
        // async still learns
        let first = a.records[0].train_loss;
        let last = a.records.last().unwrap().train_loss;
        assert!(last < first * 1.2, "async loss {first} -> {last}");
    }

    #[test]
    fn dropout_survives_all_device_loss_rounds() {
        // pinned by the counter-derived straggler streams: at seed 0 with
        // dropout 0.9, K = 4 loses every device in periods 1-3 (device 0
        // survives period 0, device 2 survives period 4). Empty rounds
        // must skip the update and carry the loss, never error
        let sm = StragglerModel::new(0.2, 0.9).unwrap();
        let log = run_policy(RoundPolicy::Deadline { factor: 1.5 }, sm, 5);
        assert_eq!(log.records.len(), 5);
        assert_eq!(log.records[0].applied, 1);
        assert_eq!(log.records[0].dropped, 3);
        for p in 1..4 {
            assert_eq!(log.records[p].applied, 0, "period {p}");
            assert_eq!(log.records[p].dropped, 4, "period {p}");
            assert_eq!(
                log.records[p].train_loss.to_bits(),
                log.records[0].train_loss.to_bits(),
                "period {p}: an empty round must carry the previous loss"
            );
        }
        assert_eq!(log.records[4].applied, 1);
        for w in log.records.windows(2) {
            assert!(w[1].sim_time > w[0].sim_time);
        }
    }

    #[test]
    fn sampling_rejects_bad_fractions_and_local_training_schemes() {
        let (train, test, fleet) = tiny_world();
        let be = HostBackend::for_model("mini_res", 24, 10, 3).unwrap();
        for bad in [0.0, -0.25, 1.5, f64::NAN] {
            let cfg = TrainerConfig { sample_frac: bad, ..Default::default() };
            let r = Trainer::new(cfg, fleet.clone(), &train, &test, Partition::Iid, &be);
            assert!(r.is_err(), "sample_frac {bad} must be rejected");
        }
        // the HT reweighting has no analogue for local-training schemes
        let cfg = TrainerConfig {
            scheme: Scheme::ModelFl { local_batch: 32 },
            sample_frac: 0.5,
            ..Default::default()
        };
        let err = Trainer::new(cfg, fleet.clone(), &train, &test, Partition::Iid, &be)
            .err()
            .unwrap()
            .to_string();
        assert!(err.contains("gradient-exchange"), "{err}");
    }

    #[test]
    fn sampled_rounds_run_subsets_and_learn_under_every_policy() {
        let (train, test, fleet) = tiny_world();
        let be = HostBackend::for_model("mini_res", 24, 10, 3).unwrap();
        for policy in [
            RoundPolicy::Sync,
            RoundPolicy::Deadline { factor: 1.5 },
            RoundPolicy::Async { alpha: 0.6, beta: 0.5, quorum: 0.5 },
        ] {
            let cfg = TrainerConfig {
                sample_frac: 0.6,
                policy,
                eval_every: 0,
                ..Default::default()
            };
            let mut tr =
                Trainer::new(cfg, fleet.clone(), &train, &test, Partition::Iid, &be).unwrap();
            tr.run(12).unwrap();
            assert_eq!(tr.log.records.len(), 12, "{policy:?}");
            // Bernoulli(0.6) over K = 4 must leave someone out sometimes
            assert!(
                tr.log.records.iter().any(|r| r.applied < 4),
                "{policy:?}: no round ran a strict subset"
            );
            for r in &tr.log.records {
                assert!(r.applied <= 4, "{policy:?}");
                assert!(r.t_period > 0.0, "{policy:?}");
            }
            let l0 = tr.log.records[0].train_loss;
            let l1 = tr.log.records.last().unwrap().train_loss;
            assert!(l1 < l0 * 1.2, "{policy:?}: loss {l0} -> {l1}");
        }
    }

    fn mixed_backend_set<'a>(
        dense: &'a HostBackend,
        res: &'a HostBackend,
        k: usize,
    ) -> crate::coordinator::BackendSet<'a> {
        // even devices train mini_dense, odd train mini_res
        crate::coordinator::BackendSet::new(
            vec![
                ("mini_dense".into(), dense as &dyn Backend),
                ("mini_res".into(), res as &dyn Backend),
            ],
            (0..k).map(|id| id % 2).collect(),
        )
        .unwrap()
    }

    #[test]
    fn homogeneous_backend_set_matches_single_backend_bitwise() {
        // Trainer::with_backends on a one-family set must reproduce
        // Trainer::new exactly — the whole single-backend compatibility
        // story rests on this
        let (train, test, fleet) = tiny_world();
        let be = HostBackend::for_model("mini_res", 24, 10, 3).unwrap();
        let cfg = TrainerConfig { eval_every: 5, ..Default::default() };
        let mut a = Trainer::new(cfg.clone(), fleet.clone(), &train, &test, Partition::Iid, &be)
            .unwrap();
        a.run(6).unwrap();
        let set = crate::coordinator::BackendSet::homogeneous(fleet.len(), "mini_res", &be);
        let mut b =
            Trainer::with_backends(cfg, fleet, &train, &test, Partition::Iid, set).unwrap();
        b.run(6).unwrap();
        for (x, y) in a.log.records.iter().zip(&b.log.records) {
            assert_eq!(x.train_loss.to_bits(), y.train_loss.to_bits());
            assert_eq!(x.sim_time.to_bits(), y.sim_time.to_bits());
            assert_eq!(x.b_total, y.b_total);
            assert_eq!(x.test_loss.map(f64::to_bits), y.test_loss.map(f64::to_bits));
        }
    }

    #[test]
    fn mixed_fleet_trains_both_families_under_every_policy() {
        let (train, test, fleet) = tiny_world();
        let dense = HostBackend::for_model("mini_dense", 24, 10, 3).unwrap();
        let res = HostBackend::for_model("mini_res", 24, 10, 3).unwrap();
        for policy in [
            RoundPolicy::Sync,
            RoundPolicy::Deadline { factor: 1.5 },
            RoundPolicy::Async { alpha: 0.6, beta: 0.5, quorum: 0.5 },
        ] {
            let set = mixed_backend_set(&dense, &res, fleet.len());
            let cfg = TrainerConfig { policy, eval_every: 10, ..Default::default() };
            let mut tr = Trainer::with_backends(
                cfg,
                fleet.clone(),
                &train,
                &test,
                Partition::Iid,
                set,
            )
            .unwrap();
            // both families' parameters move away from their init
            let init = [
                tr.server.family_params(0).to_vec(),
                tr.server.family_params(1).to_vec(),
            ];
            tr.run(10).unwrap();
            assert_eq!(tr.log.records.len(), 10, "{policy:?}");
            for f in 0..2 {
                assert_ne!(
                    tr.server.family_params(f),
                    &init[f][..],
                    "{policy:?}: family {f} never updated"
                );
            }
            // mixed eval reports sane, bounded metrics
            let (loss, acc) = tr.evaluate().unwrap();
            assert!(loss.is_finite(), "{policy:?}");
            assert!((0.0..=1.0).contains(&acc), "{policy:?}");
            // and the run learns
            let l0 = tr.log.records[0].train_loss;
            let l1 = tr.log.records.last().unwrap().train_loss;
            assert!(l1 < l0 * 1.2, "{policy:?}: loss {l0} -> {l1}");
        }
    }

    #[test]
    fn mixed_fleet_warm_start_and_individual_scheme() {
        let (train, test, fleet) = tiny_world();
        let dense = HostBackend::for_model("mini_dense", 24, 10, 3).unwrap();
        let res = HostBackend::for_model("mini_res", 24, 10, 3).unwrap();
        let set = mixed_backend_set(&dense, &res, fleet.len());
        let cfg = TrainerConfig {
            scheme: Scheme::Individual { local_batch: 32 },
            eval_every: 2,
            ..Default::default()
        };
        let mut tr =
            Trainer::with_backends(cfg, fleet, &train, &test, Partition::Iid, set).unwrap();
        tr.warm_start(5, 32, 0.05).unwrap();
        // every device starts from its own family's warm model
        for (id, w) in tr.workers.iter().enumerate() {
            let f = id % 2;
            assert_eq!(
                w.local_params.as_deref().unwrap(),
                tr.server.family_params(f),
                "device {id}"
            );
        }
        tr.run(3).unwrap();
        assert!(tr.log.final_acc().is_some());
    }

    #[test]
    fn mixed_fleet_rejects_model_fl_and_size_mismatch() {
        let (train, test, fleet) = tiny_world();
        let dense = HostBackend::for_model("mini_dense", 24, 10, 3).unwrap();
        let res = HostBackend::for_model("mini_res", 24, 10, 3).unwrap();
        let set = mixed_backend_set(&dense, &res, fleet.len());
        let cfg = TrainerConfig {
            scheme: Scheme::ModelFl { local_batch: 32 },
            ..Default::default()
        };
        let err = Trainer::with_backends(cfg, fleet.clone(), &train, &test, Partition::Iid, set)
            .err()
            .unwrap()
            .to_string();
        assert!(err.contains("homogeneous"), "{err}");
        // backend set sized for a different fleet
        let set = mixed_backend_set(&dense, &res, fleet.len() + 2);
        let err = Trainer::with_backends(
            TrainerConfig::default(),
            fleet,
            &train,
            &test,
            Partition::Iid,
            set,
        )
        .err()
        .unwrap()
        .to_string();
        assert!(err.contains("devices"), "{err}");
    }

    #[test]
    fn non_gradient_schemes_reject_policies_and_stragglers() {
        let (train, test, fleet) = tiny_world();
        let be = HostBackend::for_model("mini_res", 24, 10, 3).unwrap();
        let cfg = TrainerConfig {
            scheme: Scheme::ModelFl { local_batch: 32 },
            policy: RoundPolicy::Async { alpha: 0.6, beta: 0.5, quorum: 0.5 },
            ..Default::default()
        };
        let err = Trainer::new(cfg, fleet.clone(), &train, &test, Partition::Iid, &be)
            .err()
            .unwrap()
            .to_string();
        assert!(err.contains("gradient-exchange"), "{err}");
        let cfg = TrainerConfig {
            scheme: Scheme::Individual { local_batch: 64 },
            straggler: StragglerModel { jitter: 0.5, dropout: 0.0 },
            ..Default::default()
        };
        assert!(Trainer::new(cfg, fleet.clone(), &train, &test, Partition::Iid, &be).is_err());
        // invalid straggler knobs are caught even via the pub-field path
        let cfg = TrainerConfig {
            straggler: StragglerModel { jitter: -1.0, dropout: 0.0 },
            ..Default::default()
        };
        assert!(Trainer::new(cfg, fleet, &train, &test, Partition::Iid, &be).is_err());
    }
}
