//! The FEEL training loop: periods of plan → local gradients → compress →
//! aggregate → update, with the simulated clock advancing by each period's
//! end-to-end latency (paper steps 1–5, Fig. 1).
//!
//! Planning (scheme.rs) runs on the coordinator thread; execution of the K
//! per-device steps is fanned out through `exec::Engine`, and for
//! gradient-exchange schemes the period is *closed* by the round policy in
//! `sched/` (sync barrier / deadline / async quorum, with the straggler
//! model perturbing per-device completion events). All cross-device
//! reductions happen in fixed device/event order, so numerics are
//! bitwise-identical at any thread count. Simulated time advances only
//! through [`SimClock`], from the scheduler-reported period duration.

use std::time::Instant;

use anyhow::{bail, Result};

use super::backend::Backend;
use super::clock::SimClock;
use super::scheme::{plan_period, Plan, Scheme};
use super::server::Server;
use super::worker::Worker;
use super::xi::XiEstimator;
use crate::compress::Sbc;
use crate::data::{partition, Dataset, DeviceData, Partition};
use crate::device::{Device, StragglerModel};
use crate::exec::{self, Engine};
use crate::grad::Aggregator;
use crate::opt::types::Instance;
use crate::sched::{RoundPolicy, RoundReport, RoundScheduler};
use crate::util::rng::Pcg;
use crate::wireless::PeriodRates;

/// Trainer configuration (see config/ for the file-based form).
#[derive(Clone, Debug)]
pub struct TrainerConfig {
    pub scheme: Scheme,
    /// batch ceiling B^max (paper: 128)
    pub b_max: usize,
    /// gradient quantization bits d (paper: 64)
    pub quant_bits: u32,
    /// SBC keep fraction; None disables compression (dense f32 wire)
    pub sbc_keep: Option<f64>,
    /// effective compressed-gradient wire ratio r (paper: 0.005) — used by
    /// the *latency model*; the actual coder is applied to the numerics
    pub wire_ratio: f64,
    /// TDMA frame lengths (paper: 10 ms each)
    pub frame_ul: f64,
    pub frame_dl: f64,
    /// base learning rate; per-period lr = base * sqrt(B / (K * b_max))
    pub base_lr: f64,
    /// initial xi estimate + EWMA weight
    pub xi_init: f64,
    pub xi_alpha: f64,
    /// evaluate on the test set every this many periods (0 = never)
    pub eval_every: usize,
    /// optimizer tolerance
    pub eps: f64,
    pub seed: u64,
    /// worker threads for per-device execution (0 = all cores). Changes
    /// wall-clock only — numerics are identical at any value.
    pub threads: usize,
    /// how gradient-exchange rounds close: barrier / deadline / async
    /// quorum (see `sched::RoundPolicy`). Non-gradient schemes are
    /// barrier-only.
    pub policy: RoundPolicy,
    /// per-device latency jitter + dropout injected into round scheduling
    /// (`StragglerModel::none()` = the paper's deterministic latencies)
    pub straggler: StragglerModel,
}

impl Default for TrainerConfig {
    fn default() -> Self {
        TrainerConfig {
            scheme: Scheme::Proposed,
            b_max: 128,
            quant_bits: 64,
            sbc_keep: Some(0.005),
            wire_ratio: 0.005,
            frame_ul: 0.01,
            frame_dl: 0.01,
            base_lr: 0.35,
            xi_init: 0.05,
            xi_alpha: 0.1,
            eval_every: 10,
            eps: 1e-6,
            seed: 0,
            threads: 0,
            policy: RoundPolicy::Sync,
            straggler: StragglerModel::none(),
        }
    }
}

/// One period's record.
#[derive(Clone, Copy, Debug)]
pub struct PeriodRecord {
    pub period: usize,
    /// simulated seconds at the END of this period
    pub sim_time: f64,
    pub t_period: f64,
    pub b_total: usize,
    pub train_loss: f64,
    pub lr: f64,
    pub test_loss: Option<f64>,
    pub test_acc: Option<f64>,
    /// measured learning efficiency dL/T of this period
    pub efficiency: f64,
    /// gradients applied this period (== K under a clean sync barrier)
    pub applied: usize,
    /// devices lost to dropout this period
    pub dropped: usize,
    /// devices that missed the deadline (batch carried to next period)
    pub late: usize,
    /// batch-weighted mean staleness of the applied gradients (async; 0
    /// for barrier/deadline rounds)
    pub stale_mean: f64,
}

/// Wall-clock accounting of the coordinator's *serial* sections, summed
/// over the run — the denominator-side of the ROADMAP "perf trajectory"
/// item (the serial fraction is what caps periods/sec scaling at K = 64+).
/// Wall times are measurement, not simulation: they never feed back into
/// results and are excluded from the determinism contract.
#[derive(Clone, Copy, Debug, Default)]
pub struct WallStats {
    /// channel draws + per-period planning (the paper's solver), seconds
    pub solver_secs: f64,
    /// shard combine + global apply_update / FedAvg, seconds
    pub reduce_secs: f64,
    /// total wall seconds spent inside `step_period`
    pub total_secs: f64,
}

impl WallStats {
    /// Fraction of period wall time spent in the serial coordinator
    /// sections (0.0 when nothing has run yet).
    pub fn serial_fraction(&self) -> f64 {
        if self.total_secs > 0.0 {
            (self.solver_secs + self.reduce_secs) / self.total_secs
        } else {
            0.0
        }
    }
}

/// Whole-run log.
#[derive(Clone, Debug, Default)]
pub struct TrainLog {
    pub records: Vec<PeriodRecord>,
    /// serial-fraction wall-clock accounting (see [`WallStats`])
    pub wall: WallStats,
}

impl TrainLog {
    pub fn final_acc(&self) -> Option<f64> {
        self.records.iter().rev().find_map(|r| r.test_acc)
    }

    pub fn final_loss(&self) -> Option<f64> {
        self.records.last().map(|r| r.train_loss)
    }

    /// Simulated seconds at the end of the run — the final `SimClock`
    /// reading, and the one axis on which sync / deadline / async runs are
    /// comparable (every policy advances the same clock).
    pub fn sim_time(&self) -> f64 {
        self.records.last().map(|r| r.sim_time).unwrap_or(0.0)
    }

    pub fn total_time(&self) -> f64 {
        self.sim_time()
    }

    /// First simulated time at which the train loss fell below `target`
    /// (None if never) — the Table-II "training speed" measure.
    pub fn time_to_loss(&self, target: f64) -> Option<f64> {
        self.records
            .iter()
            .find(|r| r.train_loss <= target)
            .map(|r| r.sim_time)
    }

    /// Mean train loss over periods `[start, start + len)` — the guarded
    /// form of the head/tail window slicing convergence checks use. Returns
    /// a clean error (instead of a slice panic) when the run is shorter
    /// than the requested window.
    pub fn mean_loss_window(&self, start: usize, len: usize) -> Result<f64> {
        let n = self.records.len();
        let Some(end) = start.checked_add(len) else {
            bail!("loss window {start}+{len} overflows");
        };
        if len == 0 || end > n {
            bail!("loss window [{start}, {end}) out of range: run has {n} periods");
        }
        Ok(self.records[start..end].iter().map(|r| r.train_loss).sum::<f64>() / len as f64)
    }

    /// First simulated time at which test accuracy reached `target`.
    pub fn time_to_acc(&self, target: f64) -> Option<f64> {
        self.records
            .iter()
            .find(|r| r.test_acc.is_some_and(|a| a >= target))
            .map(|r| r.sim_time)
    }

    /// CSV dump (header + one row per period).
    pub fn to_csv(&self) -> String {
        let mut out = String::from(
            "period,sim_time,t_period,b_total,train_loss,lr,test_loss,test_acc,efficiency,\
             applied,dropped,late,stale_mean\n",
        );
        for r in &self.records {
            out.push_str(&format!(
                "{},{:.6},{:.6},{},{:.6},{:.5},{},{},{:.6},{},{},{},{:.3}\n",
                r.period,
                r.sim_time,
                r.t_period,
                r.b_total,
                r.train_loss,
                r.lr,
                r.test_loss.map(|v| format!("{v:.6}")).unwrap_or_default(),
                r.test_acc.map(|v| format!("{v:.6}")).unwrap_or_default(),
                r.efficiency,
                r.applied,
                r.dropped,
                r.late,
                r.stale_mean,
            ));
        }
        out
    }
}

/// The coordinator: owns the fleet, the data, the backend and the loop.
pub struct Trainer<'a> {
    pub cfg: TrainerConfig,
    pub fleet: Vec<Device>,
    pub workers: Vec<Worker>,
    pub server: Server,
    backend: &'a dyn Backend,
    engine: Engine,
    train: &'a Dataset,
    test: &'a Dataset,
    clock: SimClock,
    xi: XiEstimator,
    rng: Pcg,
    last_train_loss: Option<f64>,
    /// long-lived server-side accumulator, reset each period (its p-sized
    /// f64 buffer is allocated once per run, not once per round)
    agg: Aggregator,
    /// round-policy scheduler: event queue, straggler injection, deadline
    /// carry ledger, async in-flight work
    sched: RoundScheduler,
    pub log: TrainLog,
}

impl<'a> Trainer<'a> {
    pub fn new(
        cfg: TrainerConfig,
        fleet: Vec<Device>,
        train: &'a Dataset,
        test: &'a Dataset,
        kind: Partition,
        backend: &'a dyn Backend,
    ) -> Result<Self> {
        let mut rng = Pcg::seeded(cfg.seed);
        let parts = partition(train, fleet.len(), kind, &mut rng);
        let p = backend.params();
        let workers = parts
            .into_iter()
            .enumerate()
            .map(|(id, idx)| {
                let sbc = cfg.sbc_keep.map(|f| Sbc::new(f, p));
                Worker::new(id, DeviceData::new(idx, rng.fork(id as u64 + 1)), sbc)
            })
            .collect();
        let params = backend.init_params()?;
        let xi = XiEstimator::new(cfg.xi_init, cfg.xi_alpha);
        let engine = Engine::new(cfg.threads);
        let agg = Aggregator::new(p);
        // round policies and straggler injection act on the gradient
        // aggregation path; the local-training schemes have no per-period
        // server reduce to schedule around
        if !cfg.scheme.exchanges_gradients() {
            if !cfg.policy.is_sync() {
                bail!(
                    "round policy {:?} requires a gradient-exchange scheme, got {:?}",
                    cfg.policy.name(),
                    cfg.scheme.name()
                );
            }
            if cfg.straggler.is_active() {
                bail!(
                    "the straggler model requires a gradient-exchange scheme, got {:?}",
                    cfg.scheme.name()
                );
            }
        }
        // revalidate pub-field structs that may not have come through the
        // checked constructors
        StragglerModel::new(cfg.straggler.jitter, cfg.straggler.dropout)?;
        let sched = RoundScheduler::new(cfg.policy, cfg.straggler, fleet.len(), cfg.seed)?;
        Ok(Trainer {
            cfg,
            fleet,
            workers,
            server: Server::new(params),
            backend,
            engine,
            train,
            test,
            clock: SimClock::new(),
            xi,
            rng,
            last_train_loss: None,
            agg,
            sched,
            log: TrainLog::default(),
        })
    }

    /// Worker threads the per-device fan-out uses.
    pub fn threads(&self) -> usize {
        self.engine.threads()
    }

    /// Warm-start: train the global model centrally for `steps` SGD steps
    /// of batchsize `b` before the federated comparison (Table II starts
    /// from a pre-trained model).
    pub fn warm_start(&mut self, steps: usize, b: usize, lr: f32) -> Result<()> {
        let n = self.train.len();
        let budget = self.engine.threads();
        for _ in 0..steps {
            let idx = self.rng.sample_indices(n, b.min(n));
            let (x, y) = self.train.gather(&idx);
            // centralized steps run on the coordinator thread: cap their
            // GEMM fan-out at the trainer's budget, like evaluate() does
            let s = crate::util::threads::with_budget(budget, || {
                self.backend.train_step(&self.server.params, &x, &y)
            })?;
            self.server.params =
                self.backend.apply_update(&self.server.params, &s.grads, lr)?;
        }
        // local-training schemes start every device from the warm model
        if matches!(self.cfg.scheme, Scheme::Individual { .. }) {
            for w in &mut self.workers {
                w.local_params = Some(self.server.params.clone());
            }
        }
        Ok(())
    }

    /// Gradient payload size in bits under the latency model: s = r*d*p.
    fn grad_wire_bits(&self) -> f64 {
        self.cfg.wire_ratio * self.cfg.quant_bits as f64 * self.server.p() as f64
    }

    /// Parameter payload for model-based FL: d bits per term, no sparse
    /// compression (parameters are dense; the paper's 200x gap between
    /// parameter and compressed-gradient traffic comes from exactly this).
    fn param_wire_bits(&self) -> f64 {
        self.cfg.quant_bits as f64 * self.server.p() as f64
    }

    /// eta = O(sqrt(B)) scaling (paper §III-A, refs [36][37]) for an
    /// aggregated batch of `b`; capped at 1x base so whole-shard schemes
    /// (gradient/model FL) don't blow up.
    fn lr_for_batch(&self, b: usize) -> f64 {
        self.cfg.base_lr
            * (b as f64 / (self.fleet.len() * self.cfg.b_max) as f64)
                .sqrt()
                .min(1.0)
    }

    /// This period's optimizer instance from fresh channel draws.
    fn period_instance(&mut self) -> Result<Instance> {
        let rates: Vec<PeriodRates> = {
            let rng = &mut self.rng;
            self.fleet.iter_mut().map(|d| d.link.step(rng)).collect()
        };
        Instance::from_fleet(
            &self.fleet,
            &rates,
            self.cfg.b_max as f64,
            self.grad_wire_bits(),
            self.cfg.frame_ul,
            self.cfg.frame_dl,
            self.xi.value(),
        )
    }

    /// Run `periods` training periods; returns the log.
    pub fn run(&mut self, periods: usize) -> Result<&TrainLog> {
        for _ in 0..periods {
            self.step_period()?;
        }
        Ok(&self.log)
    }

    /// Run until the simulated clock passes `t_limit` seconds (Fig. 4/5's
    /// x-axis) or `max_periods` elapse.
    pub fn run_for_time(&mut self, t_limit: f64, max_periods: usize) -> Result<&TrainLog> {
        for _ in 0..max_periods {
            if self.clock.now() >= t_limit {
                break;
            }
            self.step_period()?;
        }
        Ok(&self.log)
    }

    /// One full training period (paper steps 1–5). For gradient-exchange
    /// schemes the round policy decides when the period closes and which
    /// contributions enter the reduce; the scheduler reports the period's
    /// effective duration and the clock advances by it — through
    /// [`SimClock`] only, so every policy shares one comparable time axis.
    pub fn step_period(&mut self) -> Result<()> {
        let t_step = Instant::now();
        let inst = self.period_instance()?;
        let shard_sizes: Vec<usize> = self.workers.iter().map(|w| w.shard_len()).collect();
        let mut plan = plan_period(
            self.cfg.scheme,
            &inst,
            &shard_sizes,
            self.param_wire_bits(),
            self.cfg.eps,
            &mut self.rng,
        )?;
        // deadline policy: fold batches deferred by last period's misses
        // back into this period's plan (no-op otherwise)
        self.sched.apply_carry(&mut plan, &inst);
        self.log.wall.solver_secs += t_step.elapsed().as_secs_f64();
        let b_total: usize = plan.batches.iter().sum();

        let (report, lr) = match self.cfg.scheme {
            // gradient schemes compute their step size *after* the round
            // closes, from the batch that actually entered the update —
            // a deadline/async round may apply far less than the plan
            Scheme::Proposed | Scheme::GradientFl | Scheme::Fixed { .. } => {
                self.gradient_period(&plan)?
            }
            Scheme::ModelFl { local_batch } => {
                // local steps see batch `local_batch`, not the plan's shard
                // total — scale eta by the batch they actually use
                let local_lr = self.cfg.base_lr
                    * (local_batch as f64 / self.cfg.b_max as f64).sqrt().min(1.0);
                let loss = self.model_fl_period(local_batch, local_lr as f32)?;
                (barrier_report(loss, &plan, self.fleet.len(), b_total), self.lr_for_batch(b_total))
            }
            Scheme::Individual { .. } => {
                let lr = self.lr_for_batch(b_total);
                let loss = self.individual_period(&plan, lr as f32)?;
                (barrier_report(loss, &plan, self.fleet.len(), b_total), lr)
            }
        };

        // a round where nothing arrived measures no loss: carry the last
        // one (NaN only if the very first round is empty). Keyed on
        // `updated`, not on NaN — a diverged round that did apply
        // gradients must keep its NaN visible in the log.
        let train_loss = if report.updated {
            report.train_loss
        } else {
            self.last_train_loss.unwrap_or(f64::NAN)
        };

        // xi bookkeeping from the measured loss decay over the batch that
        // actually entered the update
        let dl = if report.updated {
            if let Some(prev) = self.last_train_loss {
                self.xi.observe(prev - train_loss, report.b_effective.max(1) as f64);
            }
            let dl = self.last_train_loss.map(|p| p - train_loss).unwrap_or(0.0);
            self.last_train_loss = Some(train_loss);
            dl
        } else {
            0.0
        };

        // event-queue style: the clock jumps to the period's absolute end
        // time (`now + dt` — the same addition `advance` performs, so the
        // sync path stays bitwise)
        let t_end = self.clock.now() + report.duration;
        self.clock.advance_to(t_end);
        self.server.period += 1;
        let period = self.server.period;

        let (test_loss, test_acc) = if self.cfg.eval_every > 0
            && (period % self.cfg.eval_every == 0 || period == 1)
        {
            let (l, a) = self.evaluate()?;
            (Some(l), Some(a))
        } else {
            (None, None)
        };

        self.log.records.push(PeriodRecord {
            period,
            sim_time: self.clock.now(),
            t_period: report.duration,
            b_total,
            train_loss,
            lr,
            test_loss,
            test_acc,
            efficiency: if report.duration > 0.0 { dl / report.duration } else { 0.0 },
            applied: report.applied,
            dropped: report.dropped,
            late: report.late,
            stale_mean: report.stale_mean,
        });
        self.log.wall.total_secs += t_step.elapsed().as_secs_f64();
        Ok(())
    }

    /// Steps 1–5 for gradient-exchange schemes, closed by the round
    /// policy. The scheduler fans the device steps out on the engine
    /// (shard boundaries from K alone, device-order f64 folds — see
    /// exec/mod.rs), injects straggler perturbations, drains its event
    /// queue per the policy, and fills the long-lived server accumulator;
    /// the trainer then applies the batch-weighted global gradient (eq. 1)
    /// — unless nothing arrived, in which case the parameters stand.
    /// Returns the round report plus the step size actually used — scaled
    /// by `b_effective` (the aggregated batch), which equals the planned
    /// total under a clean sync barrier but shrinks with every dropped or
    /// deferred contribution.
    fn gradient_period(&mut self, plan: &Plan) -> Result<(RoundReport, f64)> {
        self.agg.reset();
        let report = self.sched.gradient_period(
            &self.engine,
            self.backend,
            &mut self.workers,
            &self.server.params,
            self.train,
            plan,
            self.server.period as u64,
            self.clock.now(),
            &mut self.agg,
        )?;
        self.log.wall.reduce_secs += report.reduce_secs;
        let lr = self.lr_for_batch(report.b_effective);
        if report.updated {
            let t0 = Instant::now();
            let global = self.agg.average()?;
            self.server.params =
                self.backend.apply_update(&self.server.params, &global, lr as f32)?;
            self.log.wall.reduce_secs += t0.elapsed().as_secs_f64();
        }
        Ok((report, lr))
    }

    /// Model-based FL: one local epoch per device (parallel), then FedAvg
    /// in fixed device order.
    fn model_fl_period(&mut self, local_batch: usize, lr: f32) -> Result<f64> {
        let outcomes = exec::model_fl_round(
            &self.engine,
            self.backend,
            &mut self.workers,
            &self.server.params,
            self.train,
            local_batch,
            lr,
            self.cfg.seed,
            self.server.period as u64,
        )?;
        let mut loss_acc = 0f64;
        let mut w_acc = 0f64;
        let mut averaged: Vec<(Vec<f32>, f64)> = Vec::with_capacity(outcomes.len());
        for o in outcomes {
            loss_acc += o.loss * o.weight;
            w_acc += o.weight;
            averaged.push((o.params, o.weight));
        }
        let t0 = Instant::now();
        self.server.average_params(&averaged)?;
        self.log.wall.reduce_secs += t0.elapsed().as_secs_f64();
        Ok(loss_acc / w_acc)
    }

    /// Individual learning: one local step per device on its own params.
    fn individual_period(&mut self, plan: &Plan, lr: f32) -> Result<f64> {
        let outcomes = exec::individual_round(
            &self.engine,
            self.backend,
            &mut self.workers,
            &self.server.params,
            self.train,
            &plan.batches,
            lr,
            self.cfg.seed,
            self.server.period as u64,
        )?;
        let mut loss_acc = 0f64;
        let mut w_acc = 0f64;
        for o in &outcomes {
            loss_acc += o.loss * o.weight;
            w_acc += o.weight;
        }
        Ok(loss_acc / w_acc)
    }

    /// Evaluate on the held-out set. Global-model schemes evaluate the
    /// server params; individual learning averages each device's metrics
    /// (the paper's final step averages the models — we report the mean
    /// device performance, which matches its "isolated islands" framing),
    /// with the per-device evaluations fanned out on the engine.
    pub fn evaluate(&self) -> Result<(f64, f64)> {
        match self.cfg.scheme {
            Scheme::Individual { .. } => {
                let results = exec::eval_round(
                    &self.engine,
                    self.backend,
                    &self.workers,
                    &self.server.params,
                    &self.test.x,
                    &self.test.y,
                )?;
                let n = results.len() as f64;
                let (loss, acc) = results
                    .iter()
                    .fold((0f64, 0f64), |(l, a), r| (l + r.0, a + r.1));
                Ok((loss / n, acc / n))
            }
            // full-dataset eval on the coordinator thread: the GEMM row
            // blocking inside may fan out, capped by the trainer's budget
            _ => crate::util::threads::with_budget(self.engine.threads(), || {
                self.backend
                    .evaluate(&self.server.params, &self.test.x, &self.test.y)
            }),
        }
    }

    pub fn sim_time(&self) -> f64 {
        self.clock.now()
    }

    pub fn xi_value(&self) -> f64 {
        self.xi.value()
    }

    /// The round policy this trainer closes periods with.
    pub fn policy(&self) -> RoundPolicy {
        self.sched.policy()
    }
}

/// The trivial full-participation report for schemes that do not go
/// through the round scheduler (model-FL, individual learning): every
/// device contributes and the period lasts its planned length.
fn barrier_report(loss: f64, plan: &Plan, k: usize, b_total: usize) -> RoundReport {
    RoundReport {
        duration: plan.t_period,
        train_loss: loss,
        b_effective: b_total,
        applied: k,
        dropped: 0,
        late: 0,
        stale_mean: 0.0,
        updated: true,
        reduce_secs: 0.0,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::backend::HostBackend;
    use crate::data::synthetic::{generate, SynthConfig};
    use crate::device::paper_cpu_fleet;
    use crate::wireless::CellConfig;

    fn tiny_world() -> (Dataset, Dataset, Vec<Device>) {
        let cfg = SynthConfig { dim: 24, ..Default::default() };
        let train = generate(&cfg, 600, 1);
        let test = generate(&cfg, 200, 1);
        let mut rng = Pcg::seeded(2);
        let fleet = paper_cpu_fleet(4, 7e7, 1e8, CellConfig::default(), 4.0, 0.5, &mut rng);
        (train, test, fleet)
    }

    fn run_scheme(scheme: Scheme, periods: usize) -> TrainLog {
        let (train, test, fleet) = tiny_world();
        let be = HostBackend::for_model("mini_res", 24, 10, 3).unwrap();
        let cfg = TrainerConfig { scheme, eval_every: periods, ..Default::default() };
        let mut tr = Trainer::new(cfg, fleet, &train, &test, Partition::Iid, &be).unwrap();
        tr.run(periods).unwrap();
        tr.log.clone()
    }

    #[test]
    fn proposed_loss_decreases() {
        let log = run_scheme(Scheme::Proposed, 40);
        assert_eq!(log.records.len(), 40);
        let first = log.mean_loss_window(0, 5).unwrap();
        let last = log.mean_loss_window(35, 5).unwrap();
        assert!(last < first, "loss {first} -> {last}");
        // simulated time strictly increases
        for w in log.records.windows(2) {
            assert!(w[1].sim_time > w[0].sim_time);
        }
    }

    #[test]
    fn loss_window_guards_short_runs() {
        let log = run_scheme(Scheme::Proposed, 3);
        // in-range window works
        assert!(log.mean_loss_window(0, 3).is_ok());
        // short run: a clean error, not a slice panic
        let err = log.mean_loss_window(35, 5).unwrap_err().to_string();
        assert!(err.contains("out of range"), "{err}");
        assert!(log.mean_loss_window(0, 4).is_err());
        assert!(log.mean_loss_window(0, 0).is_err());
        assert!(log.mean_loss_window(usize::MAX, 2).is_err());
    }

    #[test]
    fn all_schemes_run_and_learn() {
        for scheme in [
            Scheme::Proposed,
            Scheme::GradientFl,
            Scheme::ModelFl { local_batch: 32 },
            Scheme::Individual { local_batch: 64 },
            Scheme::Fixed { policy: crate::opt::BatchPolicy::Random, optimal_slots: true },
        ] {
            let log = run_scheme(scheme, 15);
            assert_eq!(log.records.len(), 15, "{scheme:?}");
            let l0 = log.records[0].train_loss;
            let l1 = log.records.last().unwrap().train_loss;
            assert!(l1 < l0 * 1.2, "{scheme:?}: loss {l0} -> {l1}");
            assert!(log.total_time() > 0.0);
        }
    }

    #[test]
    fn proposed_beats_fixed_policies_on_sim_time() {
        // at equal period counts the proposed scheme should reach a lower
        // (or equal) loss per unit simulated time — the paper's headline
        let prop = run_scheme(Scheme::Proposed, 30);
        let online = run_scheme(
            Scheme::Fixed { policy: crate::opt::BatchPolicy::Online, optimal_slots: true },
            30,
        );
        // compare loss achieved per simulated second
        let rate_prop =
            (prop.records[0].train_loss - prop.final_loss().unwrap()) / prop.total_time();
        let rate_online =
            (online.records[0].train_loss - online.final_loss().unwrap()) / online.total_time();
        assert!(
            rate_prop > rate_online,
            "proposed {rate_prop} vs online {rate_online}"
        );
    }

    #[test]
    fn eval_runs_and_is_bounded() {
        let (train, test, fleet) = tiny_world();
        let be = HostBackend::for_model("mini_res", 24, 10, 3).unwrap();
        let cfg = TrainerConfig { eval_every: 5, ..Default::default() };
        let mut tr = Trainer::new(cfg, fleet, &train, &test, Partition::NonIid, &be).unwrap();
        tr.run(10).unwrap();
        let acc = tr.log.final_acc().unwrap();
        assert!((0.0..=1.0).contains(&acc));
    }

    #[test]
    fn warm_start_reduces_initial_loss() {
        let (train, test, fleet) = tiny_world();
        let be = HostBackend::for_model("mini_res", 24, 10, 3).unwrap();
        let cfg = TrainerConfig::default();
        let mut tr =
            Trainer::new(cfg.clone(), fleet.clone(), &train, &test, Partition::Iid, &be)
                .unwrap();
        let (l_cold, _) = tr.evaluate().unwrap();
        tr.warm_start(80, 64, 0.05).unwrap();
        let (l_warm, _) = tr.evaluate().unwrap();
        assert!(l_warm < l_cold, "{l_cold} -> {l_warm}");
    }

    #[test]
    fn explicit_thread_count_respected() {
        let (train, test, fleet) = tiny_world();
        let be = HostBackend::for_model("mini_res", 24, 10, 3).unwrap();
        let cfg = TrainerConfig { threads: 3, eval_every: 0, ..Default::default() };
        let mut tr = Trainer::new(cfg, fleet, &train, &test, Partition::Iid, &be).unwrap();
        assert_eq!(tr.threads(), 3);
        tr.run(2).unwrap();
        assert_eq!(tr.log.records.len(), 2);
    }

    #[test]
    fn wall_stats_accumulate() {
        let log = run_scheme(Scheme::Proposed, 5);
        assert!(log.wall.total_secs > 0.0);
        assert!(log.wall.solver_secs > 0.0);
        assert!(log.wall.reduce_secs > 0.0);
        let f = log.wall.serial_fraction();
        assert!(f > 0.0 && f < 1.0, "serial fraction {f}");
        assert_eq!(WallStats::default().serial_fraction(), 0.0);
    }

    #[test]
    fn csv_well_formed() {
        let log = run_scheme(Scheme::Proposed, 5);
        let csv = log.to_csv();
        let lines: Vec<&str> = csv.trim().lines().collect();
        assert_eq!(lines.len(), 6);
        assert!(lines[0].starts_with("period,"));
        assert!(lines[0].ends_with(",applied,dropped,late,stale_mean"));
        assert_eq!(lines[0].split(',').count(), 13);
        assert_eq!(lines[1].split(',').count(), 13);
    }

    #[test]
    fn deterministic_replay() {
        let a = run_scheme(Scheme::Proposed, 10);
        let b = run_scheme(Scheme::Proposed, 10);
        for (x, y) in a.records.iter().zip(&b.records) {
            assert_eq!(x.train_loss, y.train_loss);
            assert_eq!(x.b_total, y.b_total);
            assert_eq!(x.sim_time, y.sim_time);
        }
    }

    fn run_policy(policy: RoundPolicy, straggler: StragglerModel, periods: usize) -> TrainLog {
        let (train, test, fleet) = tiny_world();
        let be = HostBackend::for_model("mini_res", 24, 10, 3).unwrap();
        let cfg = TrainerConfig { policy, straggler, eval_every: 0, ..Default::default() };
        let mut tr = Trainer::new(cfg, fleet, &train, &test, Partition::Iid, &be).unwrap();
        tr.run(periods).unwrap();
        tr.log.clone()
    }

    #[test]
    fn sync_jitter_stretches_periods_without_touching_numerics() {
        // jitter under the sync barrier changes *time only*: the same
        // devices run the same batches, so losses are bitwise identical
        // and every period is at least as long as its jitter-free twin
        let base = run_policy(RoundPolicy::Sync, StragglerModel::none(), 10);
        let jit = run_policy(RoundPolicy::Sync, StragglerModel::new(0.5, 0.0).unwrap(), 10);
        assert_eq!(base.records.len(), jit.records.len());
        for (a, b) in base.records.iter().zip(&jit.records) {
            assert_eq!(a.train_loss.to_bits(), b.train_loss.to_bits());
            assert_eq!(a.b_total, b.b_total);
            assert!(b.t_period >= a.t_period, "{} < {}", b.t_period, a.t_period);
            assert_eq!(b.applied, 4);
            assert_eq!(b.dropped, 0);
        }
        assert!(jit.sim_time() > base.sim_time());
    }

    #[test]
    fn deadline_faster_than_sync_under_jitter() {
        // straggler draws are counter-derived and policy-independent: a
        // deadline round either closes with everyone (never after the
        // barrier would have) or at the deadline while sync waits past it,
        // so the deadline run finishes the same period count strictly
        // sooner once anything misses
        let sm = StragglerModel::new(0.5, 0.0).unwrap();
        let sync = run_policy(RoundPolicy::Sync, sm, 12);
        let dl = run_policy(RoundPolicy::Deadline { factor: 1.5 }, sm, 12);
        assert!(dl.sim_time() < sync.sim_time());
        let late: usize = dl.records.iter().map(|r| r.late).sum();
        assert!(late > 0, "expected at least one deadline miss");
        assert!(dl.records.iter().any(|r| r.applied > 0));
    }

    #[test]
    fn async_closes_early_and_applies_stale_gradients() {
        let sm = StragglerModel::new(0.5, 0.0).unwrap();
        let sync = run_policy(RoundPolicy::Sync, sm, 12);
        let policy = RoundPolicy::Async { alpha: 0.6, beta: 0.5, quorum: 0.5 };
        let a = run_policy(policy, sm, 12);
        assert!(a.sim_time() < sync.sim_time());
        // quorum 0.5 of K=4 leaves devices in flight: staleness must show
        assert!(a.records.iter().any(|r| r.stale_mean > 0.0));
        for r in &a.records {
            assert!(r.applied <= 4);
            assert!(r.late == 0);
            assert!(r.t_period > 0.0);
        }
        // async still learns
        let first = a.records[0].train_loss;
        let last = a.records.last().unwrap().train_loss;
        assert!(last < first * 1.2, "async loss {first} -> {last}");
    }

    #[test]
    fn dropout_survives_all_device_loss_rounds() {
        // pinned by the counter-derived straggler streams: at seed 0 with
        // dropout 0.9, K = 4 loses every device in periods 1-3 (device 0
        // survives period 0, device 2 survives period 4). Empty rounds
        // must skip the update and carry the loss, never error
        let sm = StragglerModel::new(0.2, 0.9).unwrap();
        let log = run_policy(RoundPolicy::Deadline { factor: 1.5 }, sm, 5);
        assert_eq!(log.records.len(), 5);
        assert_eq!(log.records[0].applied, 1);
        assert_eq!(log.records[0].dropped, 3);
        for p in 1..4 {
            assert_eq!(log.records[p].applied, 0, "period {p}");
            assert_eq!(log.records[p].dropped, 4, "period {p}");
            assert_eq!(
                log.records[p].train_loss.to_bits(),
                log.records[0].train_loss.to_bits(),
                "period {p}: an empty round must carry the previous loss"
            );
        }
        assert_eq!(log.records[4].applied, 1);
        for w in log.records.windows(2) {
            assert!(w[1].sim_time > w[0].sim_time);
        }
    }

    #[test]
    fn non_gradient_schemes_reject_policies_and_stragglers() {
        let (train, test, fleet) = tiny_world();
        let be = HostBackend::for_model("mini_res", 24, 10, 3).unwrap();
        let cfg = TrainerConfig {
            scheme: Scheme::ModelFl { local_batch: 32 },
            policy: RoundPolicy::Async { alpha: 0.6, beta: 0.5, quorum: 0.5 },
            ..Default::default()
        };
        let err = Trainer::new(cfg, fleet.clone(), &train, &test, Partition::Iid, &be)
            .err()
            .unwrap()
            .to_string();
        assert!(err.contains("gradient-exchange"), "{err}");
        let cfg = TrainerConfig {
            scheme: Scheme::Individual { local_batch: 64 },
            straggler: StragglerModel { jitter: 0.5, dropout: 0.0 },
            ..Default::default()
        };
        assert!(Trainer::new(cfg, fleet.clone(), &train, &test, Partition::Iid, &be).is_err());
        // invalid straggler knobs are caught even via the pub-field path
        let cfg = TrainerConfig {
            straggler: StragglerModel { jitter: -1.0, dropout: 0.0 },
            ..Default::default()
        };
        assert!(Trainer::new(cfg, fleet, &train, &test, Partition::Iid, &be).is_err());
    }
}
