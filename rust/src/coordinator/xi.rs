//! Online estimation of the loss-decay coefficient xi (eq. 8: dL = xi*sqrt(B)).
//!
//! The paper treats xi as a known model constant; in a running system it
//! must be estimated. Each period contributes the observation
//! `xi_obs = dL / sqrt(B)`; an EWMA with clamping to positive values keeps
//! the optimizer's instance well-posed even through noisy/negative loss
//! deltas (late training).

/// EWMA estimator of xi.
#[derive(Clone, Copy, Debug)]
pub struct XiEstimator {
    value: f64,
    alpha: f64,
    floor: f64,
    observations: usize,
}

impl XiEstimator {
    /// `initial` seeds the estimate before any observation; `alpha` is the
    /// EWMA weight of a new observation.
    pub fn new(initial: f64, alpha: f64) -> Self {
        assert!(initial > 0.0 && (0.0..=1.0).contains(&alpha));
        XiEstimator { value: initial, alpha, floor: initial * 1e-3, observations: 0 }
    }

    /// Record one period: observed global-loss decay `dl` at batch `b`.
    /// Negative decays (loss went up) are clamped to the floor observation
    /// instead of poisoning the estimate.
    pub fn observe(&mut self, dl: f64, b: f64) {
        assert!(b > 0.0);
        let obs = (dl / b.sqrt()).max(self.floor);
        self.value = (1.0 - self.alpha) * self.value + self.alpha * obs;
        self.observations += 1;
    }

    pub fn value(&self) -> f64 {
        self.value.max(self.floor)
    }

    pub fn observations(&self) -> usize {
        self.observations
    }

    /// The EWMA registers for checkpoint serialization (the knobs
    /// `alpha`/`floor` are config-derived and rebuilt on resume).
    pub fn snapshot(&self) -> (f64, usize) {
        (self.value, self.observations)
    }

    /// Restore [`XiEstimator::snapshot`] registers into a freshly
    /// configured estimator.
    pub fn restore(&mut self, value: f64, observations: usize) {
        assert!(value.is_finite(), "bad xi restore {value}");
        self.value = value;
        self.observations = observations;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn converges_to_constant_signal() {
        let mut e = XiEstimator::new(1.0, 0.2);
        for _ in 0..100 {
            e.observe(0.05 * 100f64.sqrt(), 100.0); // xi_obs = 0.05
        }
        assert!((e.value() - 0.05).abs() < 1e-3, "{}", e.value());
    }

    #[test]
    fn survives_negative_decays() {
        let mut e = XiEstimator::new(0.1, 0.3);
        for _ in 0..50 {
            e.observe(-1.0, 64.0);
        }
        assert!(e.value() > 0.0);
        assert!(e.value().is_finite());
    }

    #[test]
    fn tracks_changing_signal() {
        let mut e = XiEstimator::new(0.5, 0.3);
        for _ in 0..60 {
            e.observe(0.01 * 49f64.sqrt(), 49.0);
        }
        assert!((e.value() - 0.01).abs() < 2e-3);
    }
}
