//! Layer-3 coordination (DESIGN.md S5–S7, S11, S13): the FEEL training
//! loop, its schemes, device/server state, the simulated clock, and online
//! xi estimation.

pub mod backend;
pub mod checkpoint;
pub mod clock;
pub mod fleet_backends;
pub mod scheme;
pub mod server;
pub mod trainer;
pub mod worker;
pub mod xi;

pub use backend::{Backend, HostBackend, PjrtBackend};
pub use fleet_backends::BackendSet;
pub use scheme::{plan_period, Plan, Scheme};
pub use trainer::{PeriodRecord, TrainLog, Trainer, TrainerConfig, WallStats};
pub use xi::XiEstimator;
