//! Versioned checkpoint file format for [`Trainer`](super::Trainer) and
//! `hier::HierTrainer` resume.
//!
//! Layout (all integers little-endian):
//!
//! ```text
//! magic    8 bytes  b"FEELCKPT"
//! version  u32      bumped on any payload layout change
//! kind     u8       0 = flat trainer, 1 = hierarchical
//! len      u64      payload length in bytes
//! payload  len bytes
//! checksum u64      FNV-1a over everything above
//! ```
//!
//! The payload itself is a flat field stream written by [`ByteWriter`]
//! and parsed by [`ByteReader`] — no self-describing framing, so the
//! writer and reader must agree field-for-field; the `version` gate and
//! the trainer's configuration digest (first payload field) are what make
//! a mismatched read fail loudly instead of misparse. Restore is
//! all-or-nothing: callers parse the complete payload into locals before
//! touching live state, so a truncated or corrupted file can never leave
//! a trainer half-restored.

use std::path::Path;

use anyhow::{bail, Context, Result};

/// File magic, start of every checkpoint.
pub const MAGIC: [u8; 8] = *b"FEELCKPT";
/// Payload layout version this build writes and reads.
pub const VERSION: u32 = 1;
/// `kind` byte of a flat single-cell trainer checkpoint.
pub const KIND_FLAT: u8 = 0;
/// `kind` byte of a hierarchical multi-cell checkpoint.
pub const KIND_HIER: u8 = 1;

fn kind_name(kind: u8) -> &'static str {
    match kind {
        KIND_FLAT => "flat",
        KIND_HIER => "hierarchical",
        _ => "unknown",
    }
}

/// FNV-1a over a byte slice — not cryptographic, just a cheap detector
/// for truncation and bit rot.
pub fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100_0000_01b3);
    }
    h
}

/// Fixed-width little-endian view of a slice. Callers bound-check their
/// slices first, so a width miss is a reader bug — but it surfaces as a
/// structured error naming both widths, never a panic mid-restore.
fn le_array<const N: usize>(bytes: &[u8]) -> Result<[u8; N]> {
    bytes.try_into().map_err(|_| {
        anyhow::anyhow!("checkpoint frame slice is {} bytes, wanted {N}", bytes.len())
    })
}

/// Frame `payload` and write it to `path` (atomic enough for our use: a
/// partial write fails the checksum on read).
pub fn write_file(path: &Path, kind: u8, payload: &[u8]) -> Result<()> {
    let mut out = Vec::with_capacity(MAGIC.len() + 4 + 1 + 8 + payload.len() + 8);
    out.extend_from_slice(&MAGIC);
    out.extend_from_slice(&VERSION.to_le_bytes());
    out.push(kind);
    out.extend_from_slice(&(payload.len() as u64).to_le_bytes());
    out.extend_from_slice(payload);
    let sum = fnv1a64(&out);
    out.extend_from_slice(&sum.to_le_bytes());
    std::fs::write(path, &out)
        .with_context(|| format!("writing checkpoint {}", path.display()))
}

/// Read and validate a checkpoint file, returning its payload. Every
/// failure mode — missing file, bad magic, wrong version, wrong kind,
/// truncation, bit corruption — is a structured error naming the file.
pub fn read_file(path: &Path, expect_kind: u8) -> Result<Vec<u8>> {
    let raw = std::fs::read(path)
        .with_context(|| format!("reading checkpoint {}", path.display()))?;
    const HEADER: usize = 8 + 4 + 1 + 8;
    if raw.len() < HEADER + 8 {
        bail!(
            "checkpoint {} is truncated: {} bytes, the frame alone is {}",
            path.display(),
            raw.len(),
            HEADER + 8
        );
    }
    if raw[..8] != MAGIC {
        bail!("{} is not a FEEL checkpoint (bad magic)", path.display());
    }
    let version = u32::from_le_bytes(le_array(&raw[8..12])?);
    if version != VERSION {
        bail!(
            "checkpoint {} is layout version {version}; this build reads version {VERSION}",
            path.display()
        );
    }
    let kind = raw[12];
    if kind != expect_kind {
        bail!(
            "checkpoint {} is from a {} run, expected {}",
            path.display(),
            kind_name(kind),
            kind_name(expect_kind)
        );
    }
    let len = u64::from_le_bytes(le_array(&raw[13..21])?) as usize;
    if raw.len() != HEADER + len + 8 {
        bail!(
            "checkpoint {} is truncated or padded: header says {len}-byte payload, \
             file holds {} payload bytes",
            path.display(),
            raw.len().saturating_sub(HEADER + 8)
        );
    }
    let stored = u64::from_le_bytes(le_array(&raw[HEADER + len..])?);
    let computed = fnv1a64(&raw[..HEADER + len]);
    if stored != computed {
        bail!(
            "checkpoint {} failed its checksum (stored {stored:#018x}, computed \
             {computed:#018x}) — the file is corrupted",
            path.display()
        );
    }
    Ok(raw[HEADER..HEADER + len].to_vec())
}

/// Append-only payload serializer. Counterpart of [`ByteReader`]; the two
/// must stay field-for-field symmetric.
#[derive(Default)]
pub struct ByteWriter {
    buf: Vec<u8>,
}

impl ByteWriter {
    pub fn new() -> Self {
        ByteWriter { buf: Vec::new() }
    }

    pub fn into_inner(self) -> Vec<u8> {
        self.buf
    }

    pub fn put_u8(&mut self, v: u8) {
        self.buf.push(v);
    }

    pub fn put_bool(&mut self, v: bool) {
        self.buf.push(u8::from(v));
    }

    pub fn put_u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn put_usize(&mut self, v: usize) {
        self.put_u64(v as u64);
    }

    /// f64 by bit pattern — NaNs (a diverged loss) roundtrip exactly.
    pub fn put_f64(&mut self, v: f64) {
        self.put_u64(v.to_bits());
    }

    pub fn put_opt_f64(&mut self, v: Option<f64>) {
        self.put_bool(v.is_some());
        self.put_f64(v.unwrap_or(0.0));
    }

    /// Length-prefixed f32 slice by bit pattern.
    pub fn put_f32s(&mut self, vs: &[f32]) {
        self.put_usize(vs.len());
        for v in vs {
            self.buf.extend_from_slice(&v.to_bits().to_le_bytes());
        }
    }

    pub fn put_opt_f32s(&mut self, vs: Option<&[f32]>) {
        self.put_bool(vs.is_some());
        if let Some(vs) = vs {
            self.put_f32s(vs);
        }
    }

    /// Length-prefixed raw bytes — nests one payload (a cell trainer's)
    /// inside another (the hierarchy's).
    pub fn put_bytes(&mut self, bytes: &[u8]) {
        self.put_usize(bytes.len());
        self.buf.extend_from_slice(bytes);
    }
}

/// Cursor over a checkpoint payload. Every getter fails with a position-
/// stamped error instead of panicking when the payload runs short.
pub struct ByteReader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> ByteReader<'a> {
    pub fn new(buf: &'a [u8]) -> Self {
        ByteReader { buf, pos: 0 }
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        let left = self.buf.len() - self.pos;
        if n > left {
            bail!(
                "checkpoint payload truncated: wanted {n} bytes at offset {}, {left} left",
                self.pos
            );
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    pub fn get_u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    pub fn get_bool(&mut self) -> Result<bool> {
        match self.get_u8()? {
            0 => Ok(false),
            1 => Ok(true),
            b => bail!("checkpoint payload corrupt: bool byte {b} at offset {}", self.pos - 1),
        }
    }

    pub fn get_u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(le_array(self.take(8)?)?))
    }

    pub fn get_usize(&mut self) -> Result<usize> {
        let v = self.get_u64()?;
        usize::try_from(v)
            .map_err(|_| anyhow::anyhow!("checkpoint payload corrupt: count {v} overflows usize"))
    }

    pub fn get_f64(&mut self) -> Result<f64> {
        Ok(f64::from_bits(self.get_u64()?))
    }

    pub fn get_opt_f64(&mut self) -> Result<Option<f64>> {
        let present = self.get_bool()?;
        let v = self.get_f64()?;
        Ok(present.then_some(v))
    }

    pub fn get_f32s(&mut self) -> Result<Vec<f32>> {
        let n = self.get_usize()?;
        // guard the allocation against a corrupted length prefix
        if n > self.buf.len() {
            bail!(
                "checkpoint payload corrupt: f32 slice of {n} terms at offset {} but only \
                 {} payload bytes exist",
                self.pos,
                self.buf.len()
            );
        }
        let raw = self.take(n * 4)?;
        // chunks_exact(4) guarantees the width, so the array build is
        // infallible without a fallible conversion
        Ok(raw
            .chunks_exact(4)
            .map(|c| f32::from_bits(u32::from_le_bytes([c[0], c[1], c[2], c[3]])))
            .collect())
    }

    pub fn get_opt_f32s(&mut self) -> Result<Option<Vec<f32>>> {
        if self.get_bool()? {
            Ok(Some(self.get_f32s()?))
        } else {
            Ok(None)
        }
    }

    pub fn get_bytes(&mut self) -> Result<&'a [u8]> {
        let n = self.get_usize()?;
        self.take(n)
    }

    /// Assert the whole payload was consumed — trailing bytes mean the
    /// writer and reader disagree on the layout.
    pub fn expect_end(&self) -> Result<()> {
        let left = self.buf.len() - self.pos;
        if left > 0 {
            bail!("checkpoint payload has {left} unread trailing bytes — layout mismatch");
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn temp_path(tag: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("feel_ckpt_{tag}_{}", std::process::id()))
    }

    #[test]
    fn writer_reader_roundtrip_every_field_kind() {
        let mut w = ByteWriter::new();
        w.put_u8(7);
        w.put_bool(true);
        w.put_u64(u64::MAX);
        w.put_usize(42);
        w.put_f64(f64::NAN);
        w.put_opt_f64(None);
        w.put_opt_f64(Some(-1.5));
        w.put_f32s(&[1.0, f32::NEG_INFINITY, -0.0]);
        w.put_opt_f32s(None);
        w.put_opt_f32s(Some(&[2.5]));
        let buf = w.into_inner();
        let mut r = ByteReader::new(&buf);
        assert_eq!(r.get_u8().unwrap(), 7);
        assert!(r.get_bool().unwrap());
        assert_eq!(r.get_u64().unwrap(), u64::MAX);
        assert_eq!(r.get_usize().unwrap(), 42);
        assert!(r.get_f64().unwrap().is_nan());
        assert_eq!(r.get_opt_f64().unwrap(), None);
        assert_eq!(r.get_opt_f64().unwrap(), Some(-1.5));
        let v = r.get_f32s().unwrap();
        assert_eq!(v.len(), 3);
        assert_eq!(v[0], 1.0);
        assert_eq!(v[1], f32::NEG_INFINITY);
        assert_eq!(v[2].to_bits(), (-0.0f32).to_bits());
        assert_eq!(r.get_opt_f32s().unwrap(), None);
        assert_eq!(r.get_opt_f32s().unwrap(), Some(vec![2.5]));
        r.expect_end().unwrap();
    }

    #[test]
    fn reader_fails_loudly_on_truncation_and_trailing_bytes() {
        let mut w = ByteWriter::new();
        w.put_u64(5);
        let buf = w.into_inner();
        let mut r = ByteReader::new(&buf[..4]);
        let err = r.get_u64().unwrap_err().to_string();
        assert!(err.contains("truncated"), "{err}");
        // an f32 slice with an absurd length prefix must not allocate
        let mut w = ByteWriter::new();
        w.put_u64(u64::MAX / 8);
        let buf = w.into_inner();
        let err = ByteReader::new(&buf).get_f32s().unwrap_err().to_string();
        assert!(err.contains("corrupt"), "{err}");
        // trailing bytes are a layout mismatch
        let mut w = ByteWriter::new();
        w.put_u8(1);
        let buf = w.into_inner();
        let r = ByteReader::new(&buf);
        assert!(r.expect_end().is_err());
    }

    #[test]
    fn file_roundtrip_and_rejections() {
        let path = temp_path("roundtrip");
        let payload = b"some payload bytes".to_vec();
        write_file(&path, KIND_FLAT, &payload).unwrap();
        assert_eq!(read_file(&path, KIND_FLAT).unwrap(), payload);
        // wrong kind
        let err = read_file(&path, KIND_HIER).unwrap_err().to_string();
        assert!(err.contains("flat") && err.contains("hierarchical"), "{err}");
        // single-bit corruption in the payload fails the checksum
        let mut raw = std::fs::read(&path).unwrap();
        raw[25] ^= 0x01;
        std::fs::write(&path, &raw).unwrap();
        let err = read_file(&path, KIND_FLAT).unwrap_err().to_string();
        assert!(err.contains("checksum"), "{err}");
        // truncation is detected before the checksum is even consulted
        write_file(&path, KIND_FLAT, &payload).unwrap();
        let raw = std::fs::read(&path).unwrap();
        std::fs::write(&path, &raw[..raw.len() - 3]).unwrap();
        let err = read_file(&path, KIND_FLAT).unwrap_err().to_string();
        assert!(err.contains("truncated"), "{err}");
        // version gate
        let mut raw = {
            write_file(&path, KIND_FLAT, &payload).unwrap();
            std::fs::read(&path).unwrap()
        };
        raw[8..12].copy_from_slice(&99u32.to_le_bytes());
        std::fs::write(&path, &raw).unwrap();
        let err = read_file(&path, KIND_FLAT).unwrap_err().to_string();
        assert!(err.contains("version"), "{err}");
        // bad magic
        let mut raw = {
            write_file(&path, KIND_FLAT, &payload).unwrap();
            std::fs::read(&path).unwrap()
        };
        raw[0] = b'X';
        std::fs::write(&path, &raw).unwrap();
        let err = read_file(&path, KIND_FLAT).unwrap_err().to_string();
        assert!(err.contains("magic"), "{err}");
        std::fs::remove_file(&path).ok();
    }
}
