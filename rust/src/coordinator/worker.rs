//! Device-side state for the FEEL loop: the local shard/sampler, the SBC
//! compressor (with its error-feedback residual), and — for schemes that
//! train locally (individual learning, model-based FL) — local parameters.

use crate::compress::Sbc;
use crate::data::DeviceData;
use crate::runtime::hostmodel::Workspace;

/// One device's training-loop state.
pub struct Worker {
    pub id: usize,
    pub data: DeviceData,
    /// gradient compressor (None = transmit dense)
    pub sbc: Option<Sbc>,
    /// local parameters for local-training schemes (None = uses global)
    pub local_params: Option<Vec<f32>>,
    /// reusable train-step + eval buffer arena: sized on the first step,
    /// then steady-state steps stop allocating (see runtime::hostmodel).
    /// Effectively per-(worker, model-family): a device's family binding
    /// in the fleet's `BackendSet` never changes, so the pool only ever
    /// serves one model's buffer shapes — mixed fleets keep the
    /// zero-alloc path
    pub scratch: Workspace,
}

impl Worker {
    pub fn new(id: usize, data: DeviceData, sbc: Option<Sbc>) -> Self {
        Worker { id, data, sbc, local_params: None, scratch: Workspace::new() }
    }

    /// Pass a gradient through the device's compressor (identity if none).
    /// Returns (gradient as the server will see it, wire bits).
    pub fn compress(&mut self, grads: Vec<f32>) -> (Vec<f32>, u64) {
        match &mut self.sbc {
            Some(sbc) => {
                let msg = sbc.encode(&grads);
                let bits = Sbc::wire_bits(&msg);
                (Sbc::decode(&msg), bits)
            }
            None => {
                let bits = 32 * grads.len() as u64;
                (grads, bits)
            }
        }
    }

    pub fn shard_len(&self) -> usize {
        self.data.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{generate, SynthConfig};
    use crate::util::rng::Pcg;

    #[test]
    fn compress_identity_when_disabled() {
        let ds = generate(&SynthConfig { dim: 4, ..Default::default() }, 50, 1);
        let _ = &ds;
        let mut w = Worker::new(0, DeviceData::new(vec![0, 1, 2], Pcg::seeded(1)), None);
        let g = vec![1.0f32, -2.0, 3.0];
        let (out, bits) = w.compress(g.clone());
        assert_eq!(out, g);
        assert_eq!(bits, 96);
    }

    #[test]
    fn compress_sbc_sparsifies() {
        let mut w = Worker::new(
            0,
            DeviceData::new(vec![0], Pcg::seeded(2)),
            Some(Sbc::new(0.01, 1000)),
        );
        let mut rng = Pcg::seeded(3);
        let g: Vec<f32> = (0..1000).map(|_| rng.normal() as f32).collect();
        let (out, bits) = w.compress(g);
        let nz = out.iter().filter(|&&v| v != 0.0).count();
        assert_eq!(nz, 10);
        assert!(bits < 32 * 1000);
    }
}
