//! Property-testing micro-framework (proptest is unavailable offline).
//!
//! `forall(seed, cases, gen, prop)` runs `prop` on `cases` generated
//! inputs; on failure it performs greedy shrinking through the generator's
//! `shrink` candidates and reports the minimal failing case with the seed
//! needed to replay it. Deliberately tiny — generators are closures over
//! our `Pcg`, shrinking is by-value.

use crate::util::rng::Pcg;

/// A generator: produce a value from randomness, and propose smaller
/// variants of a failing value.
pub trait Gen {
    type Value: Clone + std::fmt::Debug;
    fn generate(&self, rng: &mut Pcg) -> Self::Value;
    fn shrink(&self, v: &Self::Value) -> Vec<Self::Value> {
        let _ = v;
        Vec::new()
    }
}

/// Run the property over `cases` random inputs. Panics (with replay info
/// and a shrunk counterexample) if the property fails.
pub fn forall<G: Gen>(seed: u64, cases: usize, gen: &G, prop: impl Fn(&G::Value) -> bool) {
    let mut rng = Pcg::seeded(seed);
    for case in 0..cases {
        let v = gen.generate(&mut rng);
        if !prop(&v) {
            // greedy shrink
            let mut cur = v.clone();
            let mut improved = true;
            let mut steps = 0;
            while improved && steps < 1000 {
                improved = false;
                for cand in gen.shrink(&cur) {
                    if !prop(&cand) {
                        cur = cand;
                        improved = true;
                        steps += 1;
                        break;
                    }
                }
            }
            panic!(
                "property failed (seed {seed}, case {case});\n  original: {v:?}\n  shrunk:   {cur:?}"
            );
        }
    }
}

/// Uniform f64 in a range.
pub struct F64Range(pub f64, pub f64);

impl Gen for F64Range {
    type Value = f64;
    fn generate(&self, rng: &mut Pcg) -> f64 {
        rng.range_f64(self.0, self.1)
    }
    fn shrink(&self, v: &f64) -> Vec<f64> {
        let mut out = Vec::new();
        if *v != self.0 {
            out.push(self.0);
            out.push(self.0 + (*v - self.0) / 2.0);
        }
        out
    }
}

/// Uniform usize in [lo, hi].
pub struct UsizeRange(pub usize, pub usize);

impl Gen for UsizeRange {
    type Value = usize;
    fn generate(&self, rng: &mut Pcg) -> usize {
        rng.range_u64(self.0 as u64, self.1 as u64) as usize
    }
    fn shrink(&self, v: &usize) -> Vec<usize> {
        let mut out = Vec::new();
        if *v > self.0 {
            out.push(self.0);
            out.push(self.0 + (*v - self.0) / 2);
        }
        out.dedup();
        out
    }
}

/// Fixed-length vector of another generator.
pub struct VecOf<G: Gen>(pub usize, pub G);

impl<G: Gen> Gen for VecOf<G> {
    type Value = Vec<G::Value>;
    fn generate(&self, rng: &mut Pcg) -> Self::Value {
        (0..self.0).map(|_| self.1.generate(rng)).collect()
    }
    fn shrink(&self, v: &Self::Value) -> Vec<Self::Value> {
        // shrink one element at a time (keep the length — fixed-size vec)
        let mut out = Vec::new();
        for (i, elem) in v.iter().enumerate() {
            for cand in self.1.shrink(elem) {
                let mut c = v.clone();
                c[i] = cand;
                out.push(c);
                if out.len() > 16 {
                    return out;
                }
            }
        }
        out
    }
}

/// Pair of two generators.
pub struct PairOf<A: Gen, B: Gen>(pub A, pub B);

impl<A: Gen, B: Gen> Gen for PairOf<A, B> {
    type Value = (A::Value, B::Value);
    fn generate(&self, rng: &mut Pcg) -> Self::Value {
        (self.0.generate(rng), self.1.generate(rng))
    }
    fn shrink(&self, v: &Self::Value) -> Vec<Self::Value> {
        let mut out: Vec<Self::Value> = self
            .0
            .shrink(&v.0)
            .into_iter()
            .map(|a| (a, v.1.clone()))
            .collect();
        out.extend(self.1.shrink(&v.1).into_iter().map(|b| (v.0.clone(), b)));
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        forall(1, 200, &F64Range(0.0, 10.0), |&x| (0.0..=10.0).contains(&x));
        forall(2, 200, &UsizeRange(1, 64), |&n| n >= 1 && n <= 64);
    }

    #[test]
    fn failing_property_shrinks() {
        let res = std::panic::catch_unwind(|| {
            forall(3, 500, &F64Range(0.0, 100.0), |&x| x < 50.0);
        });
        let msg = format!("{:?}", res.unwrap_err().downcast_ref::<String>());
        // the shrunk counterexample should be near the boundary (<= 75)
        assert!(msg.contains("shrunk"), "{msg}");
    }

    #[test]
    fn vec_generator_shapes() {
        forall(4, 50, &VecOf(5, UsizeRange(0, 9)), |v| {
            v.len() == 5 && v.iter().all(|&x| x <= 9)
        });
    }

    #[test]
    fn pair_generator() {
        forall(5, 50, &PairOf(F64Range(1.0, 2.0), UsizeRange(3, 4)), |(a, b)| {
            *a >= 1.0 && *a <= 2.0 && (3..=4).contains(b)
        });
    }
}
