//! Deterministic fault injection: device crashes, gradient payload
//! corruption, and cell outages.
//!
//! The paper's learning-efficiency criterion assumes every scheduled
//! device computes and uploads cleanly; a production FEEL fleet does
//! not. This module injects the three failure classes a real deployment
//! lives with — a device *crashing* (disappearing for a drawn number of
//! rounds, then rejoining cold or warm), a device uploading a *corrupt*
//! gradient (NaN/Inf or noise-contaminated), and a whole *cell* dropping
//! out of the hierarchy for tau-blocks at a time — so the scheduler,
//! quarantine (`grad::guard`), and checkpoint/resume paths have real
//! chaos to survive.
//!
//! Determinism contract (same as `device/straggler.rs`): every draw
//! comes from a counter-derived `Pcg::for_device` stream keyed by
//! `(seed ^ TAG, period, device)`. Faults are a pure function of the run
//! coordinates — independent of thread count and execution order — and
//! each fault class carries its own tag, so enabling or disabling one
//! class never shifts another's draws, nor the straggler/sampling/batch
//! streams that share the same coordinates.

use anyhow::{bail, Result};

use crate::util::rng::Pcg;

/// Stream tag for crash draws.
const CRASH_TAG: u64 = 0xc4a5_71fe_0bad_c0de;
/// Stream tag for payload-corruption draws. The noise *contamination*
/// stream reuses this tag on the high-bit device lane `device | 1 << 63`
/// so membership draws and noise draws never collide.
const CORRUPT_TAG: u64 = 0xbad6_4ad5_0c0a_a61e;
/// Stream tag for hier cell-outage draws (coordinates: tau-block, cell).
const OUTAGE_TAG: u64 = 0xce11_0074_a6ed_da4c;

/// Whether a device is reachable this period.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CrashState {
    /// reachable: schedules, computes, uploads as normal
    Up,
    /// crashed: invisible to the scheduler until period `rejoin`
    Down {
        /// first period the device is reachable again
        rejoin: u64,
        /// on rejoin the device lost local state (deadline headroom
        /// carry is wiped); a warm rejoin keeps it
        cold: bool,
    },
}

impl CrashState {
    pub fn is_down(&self) -> bool {
        matches!(self, CrashState::Down { .. })
    }
}

/// How a corrupt upload is mangled.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Corruption {
    /// NaN/Inf terms injected into the payload (a diverged device)
    NonFinite,
    /// zero-mean noise at `scale` × payload RMS added per element (a
    /// faulty radio / byzantine device — finite, so only a norm bound
    /// can catch it)
    Noise(f64),
}

impl Corruption {
    /// Stable name for trace events and metrics.
    pub fn label(&self) -> &'static str {
        match self {
            Corruption::NonFinite => "non_finite",
            Corruption::Noise(_) => "noise",
        }
    }
}

/// Seeded fleet-wide fault configuration.
///
/// All draws are per-coordinate pure functions, so the plan itself is
/// `Copy` state with no RNG inside — the same construction that keeps
/// `StragglerModel` thread-invariant.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct FaultPlan {
    /// per-period per-device probability a crash *starts*, in [0, 1)
    pub crash_rate: f64,
    /// maximum crash duration in periods (actual length uniform in
    /// 1..=crash_len); must be >= 1
    pub crash_len: u64,
    /// per-period per-device probability the upload is corrupt, in [0, 1)
    pub corrupt_rate: f64,
    /// noise amplitude for the `Corruption::Noise` class (multiple of
    /// payload RMS); 0 makes every corruption `NonFinite`
    pub corrupt_noise: f64,
    /// per-tau-block per-cell outage probability (hier only), in [0, 1)
    pub outage_rate: f64,
}

impl FaultPlan {
    /// Checked constructor (config/CLI surfaces funnel through here).
    pub fn new(
        crash_rate: f64,
        crash_len: u64,
        corrupt_rate: f64,
        corrupt_noise: f64,
        outage_rate: f64,
    ) -> Result<FaultPlan> {
        for (name, rate) in [
            ("fault.crash_rate", crash_rate),
            ("fault.corrupt_rate", corrupt_rate),
            ("fault.outage_rate", outage_rate),
        ] {
            if !(rate.is_finite() && (0.0..1.0).contains(&rate)) {
                bail!("{name} must be in [0, 1), got {rate}");
            }
        }
        if crash_len == 0 {
            bail!("fault.crash_len must be >= 1 period, got 0");
        }
        if !(corrupt_noise.is_finite() && corrupt_noise >= 0.0) {
            bail!("fault.corrupt_noise must be finite and >= 0, got {corrupt_noise}");
        }
        Ok(FaultPlan { crash_rate, crash_len, corrupt_rate, corrupt_noise, outage_rate })
    }

    /// No faults at all: the identity plan.
    pub fn none() -> FaultPlan {
        FaultPlan {
            crash_rate: 0.0,
            crash_len: 1,
            corrupt_rate: 0.0,
            corrupt_noise: 0.0,
            outage_rate: 0.0,
        }
    }

    /// Whether any fault class can fire. An inactive plan skips RNG
    /// entirely, so a zero-rate run is bitwise identical to one that
    /// never constructed a plan.
    pub fn is_active(&self) -> bool {
        self.device_faults_active() || self.outage_active()
    }

    /// Whether per-device faults (crash or corruption) can fire — the
    /// classes the flat round scheduler must handle.
    pub fn device_faults_active(&self) -> bool {
        self.crash_rate > 0.0 || self.corrupt_rate > 0.0
    }

    /// Whether hier cell outages can fire.
    pub fn outage_active(&self) -> bool {
        self.outage_rate > 0.0
    }

    /// The crash draw anchored at `period`: does a crash *start* here,
    /// and if so for how long and how does the device come back. Draw
    /// order is fixed (start uniform, length, cold coin) so future knobs
    /// never shift earlier draws.
    fn crash_draw(&self, seed: u64, period: u64, device: u64) -> Option<(u64, bool)> {
        let mut rng = Pcg::for_device(seed ^ CRASH_TAG, period, device);
        let starts = rng.f64() < self.crash_rate;
        let len = 1 + rng.below(self.crash_len);
        let cold = rng.f64() < 0.5;
        if starts {
            Some((len, cold))
        } else {
            None
        }
    }

    /// Whether `device` is up or down at `period`: a pure function of
    /// the coordinates, computed by scanning the bounded window of
    /// possible crash starts (`crash_len` periods back). Overlapping
    /// crashes resolve to the one holding the device down longest
    /// (ties to the later start), so the state is well defined without
    /// any cross-period mutable bookkeeping.
    pub fn crash_state(&self, seed: u64, period: u64, device: u64) -> CrashState {
        if self.crash_rate <= 0.0 {
            return CrashState::Up;
        }
        let lo = period.saturating_sub(self.crash_len - 1);
        // (rejoin, cold, start) of the governing crash
        let mut best: Option<(u64, bool, u64)> = None;
        for p in lo..=period {
            if let Some((len, cold)) = self.crash_draw(seed, p, device) {
                let rejoin = p + len;
                if rejoin <= period {
                    continue; // already over
                }
                let wins = match best {
                    None => true,
                    Some((br, _, bs)) => rejoin > br || (rejoin == br && p > bs),
                };
                if wins {
                    best = Some((rejoin, cold, p));
                }
            }
        }
        match best {
            None => CrashState::Up,
            Some((rejoin, cold, _)) => CrashState::Down { rejoin, cold },
        }
    }

    /// Convenience: is the device unreachable at `period`?
    pub fn is_down(&self, seed: u64, period: u64, device: u64) -> bool {
        self.crash_state(seed, period, device).is_down()
    }

    /// True exactly at the first period after a *cold* crash: the device
    /// is back but lost local state (the deadline scheduler wipes its
    /// headroom carry; a warm rejoin keeps it).
    pub fn rejoined_cold(&self, seed: u64, period: u64, device: u64) -> bool {
        if period == 0 || self.crash_rate <= 0.0 || self.is_down(seed, period, device) {
            return false;
        }
        match self.crash_state(seed, period - 1, device) {
            CrashState::Down { rejoin, cold } => rejoin == period && cold,
            CrashState::Up => false,
        }
    }

    /// Does `device`'s upload get corrupted this period, and how. The
    /// class coin is drawn even when the membership coin misses, so
    /// enabling noise corruption never shifts the membership stream.
    pub fn corrupts(&self, seed: u64, period: u64, device: u64) -> Option<Corruption> {
        if self.corrupt_rate <= 0.0 {
            return None;
        }
        let mut rng = Pcg::for_device(seed ^ CORRUPT_TAG, period, device);
        let hit = rng.f64() < self.corrupt_rate;
        let noisy = rng.f64() < 0.5;
        if !hit {
            return None;
        }
        if noisy && self.corrupt_noise > 0.0 {
            Some(Corruption::Noise(self.corrupt_noise))
        } else {
            Some(Corruption::NonFinite)
        }
    }

    /// Mangle a gradient payload in place per the drawn corruption
    /// class. Deterministic: the noise stream is keyed by the same
    /// coordinates on the high-bit device lane, so contamination is
    /// replayable and independent of the membership draw above.
    pub fn contaminate(
        &self,
        seed: u64,
        period: u64,
        device: u64,
        kind: Corruption,
        grad: &mut [f32],
    ) {
        if grad.is_empty() {
            return;
        }
        match kind {
            Corruption::NonFinite => {
                // a diverged device: NaN up front, infinities in the body
                grad[0] = f32::NAN;
                let n = grad.len();
                if n > 1 {
                    grad[n / 2] = f32::INFINITY;
                    grad[n - 1] = f32::NEG_INFINITY;
                }
            }
            Corruption::Noise(scale) => {
                let rms = (grad.iter().map(|&g| g as f64 * g as f64).sum::<f64>()
                    / grad.len() as f64)
                    .sqrt();
                let amp = scale * if rms > 0.0 { rms } else { 1.0 };
                let mut rng =
                    Pcg::for_device(seed ^ CORRUPT_TAG, period, device | (1u64 << 63));
                for g in grad.iter_mut() {
                    *g += (amp * rng.normal()) as f32;
                }
            }
        }
    }

    /// Whether `cell` is out for tau-block `block` (hier topology). An
    /// out cell misses the whole block — no local rounds, no cloud
    /// merge — and rejoins with its stale model, exactly the PR 6
    /// inactive-cell clock semantics.
    pub fn cell_out(&self, seed: u64, block: u64, cell: u64) -> bool {
        if self.outage_rate <= 0.0 {
            return false;
        }
        let mut rng = Pcg::for_device(seed ^ OUTAGE_TAG, block, cell);
        rng.f64() < self.outage_rate
    }
}

impl Default for FaultPlan {
    fn default() -> Self {
        FaultPlan::none()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn inactive_plan_is_identity() {
        let p = FaultPlan::none();
        assert!(!p.is_active() && !p.device_faults_active() && !p.outage_active());
        for d in 0..16 {
            assert_eq!(p.crash_state(7, 3, d), CrashState::Up);
            assert!(!p.rejoined_cold(7, 3, d));
            assert!(p.corrupts(7, 3, d).is_none());
            assert!(!p.cell_out(7, 3, d));
        }
        assert_eq!(FaultPlan::default(), FaultPlan::none());
    }

    #[test]
    fn validates_knobs() {
        assert!(FaultPlan::new(1.0, 1, 0.0, 0.0, 0.0).is_err());
        assert!(FaultPlan::new(-0.1, 1, 0.0, 0.0, 0.0).is_err());
        assert!(FaultPlan::new(f64::NAN, 1, 0.0, 0.0, 0.0).is_err());
        assert!(FaultPlan::new(0.1, 0, 0.0, 0.0, 0.0).is_err());
        assert!(FaultPlan::new(0.0, 1, 1.5, 0.0, 0.0).is_err());
        assert!(FaultPlan::new(0.0, 1, 0.0, -1.0, 0.0).is_err());
        assert!(FaultPlan::new(0.0, 1, 0.0, f64::INFINITY, 0.0).is_err());
        assert!(FaultPlan::new(0.0, 1, 0.0, 0.0, 1.0).is_err());
        assert!(FaultPlan::new(0.1, 3, 0.05, 2.0, 0.2).is_ok());
    }

    #[test]
    fn crash_windows_are_contiguous_and_bounded() {
        let plan = FaultPlan::new(0.15, 4, 0.0, 0.0, 0.0).unwrap();
        let seed = 11u64;
        for d in 0..64u64 {
            let mut down_run = 0u64;
            for period in 0..200u64 {
                match plan.crash_state(seed, period, d) {
                    CrashState::Down { rejoin, .. } => {
                        assert!(rejoin > period, "rejoin {rejoin} <= period {period}");
                        // a crash never exceeds crash_len periods past its
                        // latest possible start
                        assert!(rejoin <= period + plan.crash_len);
                        down_run += 1;
                        // the state is consistent with its own forecast:
                        // still down strictly before rejoin (a *fresh*
                        // crash may extend the window past it, so only
                        // the lower bound is pinned)
                        if rejoin > period + 1 {
                            assert!(plan.is_down(seed, period + 1, d));
                        }
                    }
                    CrashState::Up => {
                        down_run = 0;
                    }
                }
                // overlapping crashes can extend a run, but any *single*
                // stretch between clean gaps still ends
                assert!(down_run <= 50, "device {d} stuck down");
            }
        }
    }

    #[test]
    fn crash_rate_and_rejoin_split_sane() {
        let plan = FaultPlan::new(0.1, 3, 0.0, 0.0, 0.0).unwrap();
        let n = 4000u64;
        let mut down = 0usize;
        let (mut cold, mut rejoins) = (0usize, 0usize);
        for d in 0..n {
            for period in 1..20u64 {
                down += plan.is_down(1, period, d) as usize;
                if !plan.is_down(1, period, d) && plan.is_down(1, period - 1, d) {
                    rejoins += 1;
                    cold += plan.rejoined_cold(1, period, d) as usize;
                }
            }
        }
        // steady-state down probability: 1 - P(no covering start) =
        // 1 - 0.9 * (1 - 0.1*2/3) * (1 - 0.1/3) ~= 0.188
        let frac = down as f64 / (n as f64 * 19.0);
        assert!((frac - 0.188).abs() < 0.03, "down fraction {frac}");
        // cold/warm is a fair coin over rejoin events
        let cold_frac = cold as f64 / rejoins as f64;
        assert!((cold_frac - 0.5).abs() < 0.05, "cold fraction {cold_frac} of {rejoins}");
    }

    #[test]
    fn rejoined_cold_only_fires_at_the_boundary() {
        let plan = FaultPlan::new(0.2, 3, 0.0, 0.0, 0.0).unwrap();
        for d in 0..200u64 {
            for period in 1..40u64 {
                if plan.rejoined_cold(5, period, d) {
                    assert!(!plan.is_down(5, period, d));
                    assert!(plan.is_down(5, period - 1, d));
                }
                // never fires mid-uptime
                if !plan.is_down(5, period - 1, d) {
                    assert!(!plan.rejoined_cold(5, period, d));
                }
            }
        }
    }

    #[test]
    fn corruption_replayable_and_class_split() {
        let plan = FaultPlan::new(0.0, 1, 0.3, 2.0, 0.0).unwrap();
        let (mut hits, mut noisy) = (0usize, 0usize);
        for d in 0..4000u64 {
            let a = plan.corrupts(9, 2, d);
            assert_eq!(a, plan.corrupts(9, 2, d));
            if let Some(kind) = a {
                hits += 1;
                match kind {
                    Corruption::Noise(s) => {
                        assert_eq!(s, 2.0);
                        noisy += 1;
                    }
                    Corruption::NonFinite => {}
                }
            }
        }
        let rate = hits as f64 / 4000.0;
        assert!((rate - 0.3).abs() < 0.03, "corrupt rate {rate}");
        let split = noisy as f64 / hits as f64;
        assert!((split - 0.5).abs() < 0.06, "noise split {split}");
        // with corrupt_noise = 0 every hit is NonFinite, and the
        // membership draws are bitwise unchanged (class coin drawn either way)
        let hard = FaultPlan::new(0.0, 1, 0.3, 0.0, 0.0).unwrap();
        for d in 0..4000u64 {
            match (plan.corrupts(9, 2, d), hard.corrupts(9, 2, d)) {
                (Some(_), Some(Corruption::NonFinite)) | (None, None) => {}
                (a, b) => panic!("device {d}: membership shifted {a:?} vs {b:?}"),
            }
        }
    }

    #[test]
    fn contaminate_nonfinite_and_noise() {
        let plan = FaultPlan::new(0.0, 1, 0.3, 2.0, 0.0).unwrap();
        let base: Vec<f32> = (0..64).map(|i| (i as f32) * 0.1 - 3.0).collect();
        let mut nf = base.clone();
        plan.contaminate(9, 2, 5, Corruption::NonFinite, &mut nf);
        assert!(nf.iter().any(|g| !g.is_finite()));
        assert!(nf[0].is_nan());
        // noise: finite, replayable, actually different from the original
        let mut a = base.clone();
        let mut b = base.clone();
        plan.contaminate(9, 2, 5, Corruption::Noise(2.0), &mut a);
        plan.contaminate(9, 2, 5, Corruption::Noise(2.0), &mut b);
        assert_eq!(a, b);
        assert!(a.iter().all(|g| g.is_finite()));
        assert_ne!(a, base);
        // another device's noise stream is independent
        let mut c = base.clone();
        plan.contaminate(9, 2, 6, Corruption::Noise(2.0), &mut c);
        assert_ne!(a, c);
        // an all-zero payload still gets perturbed (RMS floor)
        let mut z = vec![0.0f32; 16];
        plan.contaminate(9, 2, 5, Corruption::Noise(1.0), &mut z);
        assert!(z.iter().any(|&g| g != 0.0));
        // empty payload is a no-op, not a panic
        plan.contaminate(9, 2, 5, Corruption::NonFinite, &mut []);
    }

    #[test]
    fn cell_outage_rate_and_replay() {
        let plan = FaultPlan::new(0.0, 1, 0.0, 0.0, 0.25).unwrap();
        let mut out = 0usize;
        for block in 0..500u64 {
            for cell in 0..8u64 {
                let o = plan.cell_out(3, block, cell);
                assert_eq!(o, plan.cell_out(3, block, cell));
                out += o as usize;
            }
        }
        let rate = out as f64 / 4000.0;
        assert!((rate - 0.25).abs() < 0.03, "outage rate {rate}");
    }

    #[test]
    fn fault_classes_use_disjoint_streams() {
        // same coordinates, different tags: enabling one class must not
        // move another's draws
        let all = FaultPlan::new(0.2, 3, 0.2, 1.0, 0.2).unwrap();
        let crash_only = FaultPlan::new(0.2, 3, 0.0, 0.0, 0.0).unwrap();
        let corrupt_only = FaultPlan::new(0.0, 1, 0.2, 1.0, 0.0).unwrap();
        let outage_only = FaultPlan::new(0.0, 1, 0.0, 0.0, 0.2).unwrap();
        for d in 0..500u64 {
            for period in 0..6u64 {
                assert_eq!(
                    all.crash_state(13, period, d),
                    crash_only.crash_state(13, period, d)
                );
                assert_eq!(all.corrupts(13, period, d), corrupt_only.corrupts(13, period, d));
                assert_eq!(all.cell_out(13, period, d), outage_only.cell_out(13, period, d));
            }
        }
        // and the crash/corrupt streams are genuinely different sequences
        let coincide = (0..500u64)
            .filter(|&d| {
                all.crash_draw(13, 1, d).is_some() == all.corrupts(13, 1, d).is_some()
            })
            .count();
        assert!((100..400).contains(&coincide), "{coincide} coincidences in 500");
    }
}
