//! The contract rules (R1–R6) and the pragma engine.
//!
//! Each rule matches token shapes produced by [`super::lexer`], with the
//! file's role (library / bench / test) and module deciding which rules
//! apply. A finding on line `F` is suppressed by a
//! `// lint: allow(<rule>): <reason>` pragma on line `F` or `F - 1`; the
//! reason is mandatory — an allow without a written justification is
//! itself a finding.

use std::collections::BTreeMap;

use super::lexer::{lex, mask_test_code, Pragma, TokKind, Token};
use super::{Finding, Rule};

/// Modules whose iteration/reduction order is part of the bitwise
/// thread-invariance contract (R3 forbids hash collections here).
pub const DET_MODULES: &[&str] =
    &["grad", "sched", "exec", "hier", "fault", "device", "coordinator"];

/// Files allowed to read the wall clock wholesale (R4). Everywhere else
/// a wall read needs a per-site `allow(wall-clock)` pragma — the
/// WallStats sites.
pub const WALL_ALLOW_FILES: &[&str] = &["src/benchkit.rs", "src/runtime/client.rs"];

/// The one module allowed to construct RNG state from scratch (R6).
pub const RNG_HOME: &str = "src/util/rng.rs";

/// Identifiers that smell like an RNG source other than `util::rng` —
/// entropy escapes and hash-randomization handles (R6). The offline
/// build has no `rand` crate, but the rule keeps one from sneaking in.
pub const BANNED_RNG_IDENTS: &[&str] = &[
    "thread_rng",
    "ThreadRng",
    "StdRng",
    "SmallRng",
    "OsRng",
    "from_entropy",
    "getrandom",
    "RandomState",
    "DefaultHasher",
];

const MSG_FLOAT_SORT: &str = "float comparison via partial_cmp().unwrap() — a NaN mid-run \
                              panics the reduce; use total_cmp (NaN-total order)";
const MSG_WALL_CLOCK: &str = "wall clock read outside the allowlist — simulated time flows \
                              through SimClock only; wall-time accounting carries a pragma";
const MSG_PCG_NEW: &str = "raw Pcg::new outside util::rng — derive streams via seeded / \
                           for_device / fork / from_state so tags stay collision-checked";

/// One `*_TAG: u64` constant definition, collected for the R2 registry.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct TagDef {
    pub name: String,
    pub value: u64,
    pub file: String,
    pub line: u32,
}

/// Lint one file's source. `rel` is the crate-relative path with `/`
/// separators (`src/...`, `benches/...`, `tests/...`); it decides which
/// rules apply. Returns per-file findings plus the file's tag constants
/// for the cross-file registry check ([`check_tags`]).
pub fn lint_source(rel: &str, src: &str) -> (Vec<Finding>, Vec<TagDef>) {
    if rel.starts_with("tests/") {
        // integration tests construct adversarial scenarios on purpose;
        // no contract rule applies there
        return (Vec::new(), Vec::new());
    }
    let is_bench = rel.starts_with("benches/");
    let (toks, pragmas) = lex(src);
    let masked = mask_test_code(&toks);
    let mut findings: Vec<Finding> = Vec::new();
    let allow = collect_pragmas(rel, &pragmas, &mut findings);
    let module = module_of(rel);
    let in_det_module = module.is_some_and(|m| DET_MODULES.contains(&m));
    let wall_exempt = is_bench || WALL_ALLOW_FILES.contains(&rel);

    let push = |findings: &mut Vec<Finding>, rule: Rule, line: u32, message: String| {
        let above = line > 0 && pragma_covers(&allow, line - 1, rule);
        if !(pragma_covers(&allow, line, rule) || above) {
            findings.push(Finding { rule, file: rel.to_string(), line, message });
        }
    };

    let mut tags: Vec<TagDef> = Vec::new();
    for i in 0..toks.len() {
        if masked[i] || toks[i].kind != TokKind::Ident {
            continue;
        }
        let t = toks[i].text.as_str();
        let line = toks[i].line;

        // R1 float-sort: partial_cmp(..).unwrap() / .expect(..)
        if t == "partial_cmp" && txt(&toks, i + 1) == "(" {
            let close = matching_paren(&toks, i + 1);
            let chained = txt(&toks, close + 1) == ".";
            if chained && matches!(txt(&toks, close + 2), "unwrap" | "expect") {
                push(&mut findings, Rule::FloatSort, line, MSG_FLOAT_SORT.to_string());
            }
        }

        // R2 tag registry: collect `const *_TAG: u64 = <literal>;`
        let tag_def = t == "const"
            && txt(&toks, i + 1).ends_with("_TAG")
            && txt(&toks, i + 2) == ":"
            && txt(&toks, i + 3) == "u64"
            && txt(&toks, i + 4) == "=";
        if tag_def {
            let name = txt(&toks, i + 1).to_string();
            let lit = toks.get(i + 5).filter(|v| v.kind == TokKind::Lit);
            match lit.and_then(parse_u64_lit) {
                Some(value) => tags.push(TagDef { name, value, file: rel.to_string(), line }),
                None => {
                    let msg = format!(
                        "{name} must be a literal u64 so the stream-tag registry can \
                         check it for collisions"
                    );
                    push(&mut findings, Rule::TagRegistry, line, msg);
                }
            }
        }

        // R3 hash-iter: HashMap/HashSet inside a deterministic module
        if (t == "HashMap" || t == "HashSet") && !is_bench && in_det_module {
            let msg = format!(
                "{t} in deterministic module `{}` — iteration order varies per \
                 process; use BTreeMap/BTreeSet or sort before iterating",
                module.unwrap_or_default()
            );
            push(&mut findings, Rule::HashIter, line, msg);
        }

        // R4 wall-clock: Instant::now / SystemTime outside the allowlist
        let is_instant_now = t == "Instant"
            && txt(&toks, i + 1) == ":"
            && txt(&toks, i + 2) == ":"
            && txt(&toks, i + 3) == "now";
        if (is_instant_now || t == "SystemTime") && !wall_exempt {
            push(&mut findings, Rule::WallClock, line, MSG_WALL_CLOCK.to_string());
        }

        // R5 panic-path: .unwrap()/.expect() in library code
        let panic_call = matches!(t, "unwrap" | "expect")
            && txt(&toks, i + 1) == "("
            && i > 0
            && toks[i - 1].text == ".";
        if panic_call && !is_bench {
            let msg = format!(
                ".{t}() in library code — return a structured error, or justify \
                 with `// lint: allow(panic-path): <why infallible>`"
            );
            push(&mut findings, Rule::PanicPath, line, msg);
        }

        // R6 rng-source: RNG construction outside util::rng
        if rel != RNG_HOME {
            if BANNED_RNG_IDENTS.contains(&t) {
                let msg = format!(
                    "{t} is an RNG source outside util::rng — every stream must \
                     come from the tagged Pcg API"
                );
                push(&mut findings, Rule::RngSource, line, msg);
            }
            let pcg_new = t == "Pcg"
                && txt(&toks, i + 1) == ":"
                && txt(&toks, i + 2) == ":"
                && txt(&toks, i + 3) == "new";
            if pcg_new {
                push(&mut findings, Rule::RngSource, line, MSG_PCG_NEW.to_string());
            }
        }
    }
    (findings, tags)
}

/// The cross-file half of R2: every `*_TAG` constant crate-wide must be
/// nonzero (a zero tag is the identity under `seed ^ TAG` — the stream
/// would alias the untagged base stream) and pairwise distinct (a
/// collision silently correlates two subsystems' draws).
pub fn check_tags(tags: &[TagDef]) -> Vec<Finding> {
    let mut findings = Vec::new();
    let mut seen: BTreeMap<u64, &TagDef> = BTreeMap::new();
    for tag in tags {
        if tag.value == 0 {
            findings.push(Finding {
                rule: Rule::TagRegistry,
                file: tag.file.clone(),
                line: tag.line,
                message: format!(
                    "{} is zero — `seed ^ 0` aliases the untagged base stream",
                    tag.name
                ),
            });
        }
        if let Some(prev) = seen.get(&tag.value) {
            findings.push(Finding {
                rule: Rule::TagRegistry,
                file: tag.file.clone(),
                line: tag.line,
                message: format!(
                    "{} ({:#018x}) collides with {} ({}:{}) — the two subsystems' \
                     draws would correlate",
                    tag.name, tag.value, prev.name, prev.file, prev.line
                ),
            });
        } else {
            seen.insert(tag.value, tag);
        }
    }
    findings
}

fn pragma_covers(allow: &BTreeMap<u32, Vec<Rule>>, line: u32, rule: Rule) -> bool {
    allow.get(&line).is_some_and(|rs| rs.contains(&rule))
}

/// Parse `allow(<rule>): <reason>` pragma bodies into a line -> rules
/// map; malformed bodies (unknown rule, missing reason) become findings.
fn collect_pragmas(
    rel: &str,
    pragmas: &[Pragma],
    findings: &mut Vec<Finding>,
) -> BTreeMap<u32, Vec<Rule>> {
    let mut allow: BTreeMap<u32, Vec<Rule>> = BTreeMap::new();
    for p in pragmas {
        match parse_allow(&p.body) {
            Some(rule) => allow.entry(p.line).or_default().push(rule),
            None => findings.push(Finding {
                rule: Rule::Pragma,
                file: rel.to_string(),
                line: p.line,
                message: format!(
                    "malformed lint pragma {:?} — want `lint: allow(<rule>): <reason>` \
                     with a non-empty reason",
                    p.body
                ),
            }),
        }
    }
    allow
}

fn parse_allow(body: &str) -> Option<Rule> {
    let rest = body.strip_prefix("allow(")?;
    let (slug, rest) = rest.split_once(')')?;
    let rule = Rule::from_slug(slug.trim())?;
    let reason = rest.trim().strip_prefix(':')?.trim();
    if reason.is_empty() {
        return None;
    }
    Some(rule)
}

/// Top-level module a `src/` file belongs to (`src/grad/aggregate.rs`
/// and `src/grad.rs` are both module `grad`).
fn module_of(rel: &str) -> Option<&str> {
    let rest = rel.strip_prefix("src/")?;
    match rest.split_once('/') {
        Some((dir, _)) => Some(dir),
        None => rest.strip_suffix(".rs"),
    }
}

/// Index of the `)` closing the `(` at `open` (token index), or the last
/// token if unbalanced.
fn matching_paren(toks: &[Token], open: usize) -> usize {
    let mut depth = 0isize;
    for (j, t) in toks.iter().enumerate().skip(open) {
        match t.text.as_str() {
            "(" => depth += 1,
            ")" => {
                depth -= 1;
                if depth == 0 {
                    return j;
                }
            }
            _ => {}
        }
    }
    toks.len().saturating_sub(1)
}

fn parse_u64_lit(tok: &Token) -> Option<u64> {
    let t: String = tok.text.chars().filter(|&c| c != '_').collect();
    let t = t.strip_suffix("u64").unwrap_or(&t);
    match t.strip_prefix("0x") {
        Some(hex) => u64::from_str_radix(hex, 16).ok(),
        None => t.parse().ok(),
    }
}

fn txt(toks: &[Token], i: usize) -> &str {
    toks.get(i).map_or("", |t| t.text.as_str())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn module_classification() {
        assert_eq!(module_of("src/grad/aggregate.rs"), Some("grad"));
        assert_eq!(module_of("src/cli.rs"), Some("cli"));
        assert_eq!(module_of("benches/bench_gemm.rs"), None);
        assert!(DET_MODULES.contains(&module_of("src/sched/queue.rs").unwrap_or("")));
    }

    #[test]
    fn tag_literal_parsing() {
        let tok = |s: &str| Token { kind: TokKind::Lit, text: s.into(), line: 1 };
        assert_eq!(parse_u64_lit(&tok("0xc4a5_71fe_0bad_c0de")), Some(0xc4a5_71fe_0bad_c0de));
        assert_eq!(parse_u64_lit(&tok("42")), Some(42));
        assert_eq!(parse_u64_lit(&tok("7u64")), Some(7));
        assert_eq!(parse_u64_lit(&tok("1.5")), None);
    }

    #[test]
    fn pragma_grammar() {
        assert_eq!(parse_allow("allow(panic-path): tape is never empty"), Some(Rule::PanicPath));
        assert_eq!(parse_allow("allow(wall-clock): WallStats only"), Some(Rule::WallClock));
        assert_eq!(parse_allow("allow(panic-path):"), None, "reason is mandatory");
        assert_eq!(parse_allow("allow(no-such-rule): x"), None);
        assert_eq!(parse_allow("disallow(panic-path): x"), None);
    }
}
