//! Minimal Rust lexer for the contract linter.
//!
//! std-only (the offline build has no `syn`): produces a flat token
//! stream — identifiers, single-character punctuation, opaque literals —
//! with line numbers. Comments (line, doc, nested block), strings, raw
//! strings, byte strings, char literals, and lifetimes are consumed as
//! units, so rules downstream match *token shapes*, never raw text: a
//! contract name inside a string or a comment can never false-positive.
//!
//! `// lint: ...` control comments are not discarded — they surface as
//! [`Pragma`] records so the rule engine can honour suppressions.

/// Token class; rules dispatch on kind + text.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TokKind {
    /// Identifier or keyword.
    Ident,
    /// One punctuation character (multi-char operators arrive split, so
    /// `::` is two `:` tokens) or a lifetime (`'a`, text kept verbatim).
    Punct,
    /// Any literal. Numbers keep their text verbatim (the tag registry
    /// parses them); strings and chars are opaque placeholders.
    Lit,
}

#[derive(Clone, Debug)]
pub struct Token {
    pub kind: TokKind,
    pub text: String,
    pub line: u32,
}

/// A `// lint: ...` control comment (doc-comment forms included).
#[derive(Clone, Debug)]
pub struct Pragma {
    pub line: u32,
    /// Comment body after the `lint:` marker, trimmed.
    pub body: String,
}

/// Lex `src` into tokens + lint pragmas. Never fails: unexpected bytes
/// are skipped, unterminated literals run to end of input — a lint pass
/// must degrade gracefully on code mid-edit.
pub fn lex(src: &str) -> (Vec<Token>, Vec<Pragma>) {
    let b = src.as_bytes();
    let mut toks: Vec<Token> = Vec::new();
    let mut pragmas: Vec<Pragma> = Vec::new();
    let mut i = 0usize;
    let mut line = 1u32;
    while i < b.len() {
        let c = b[i];
        // whitespace
        if c == b'\n' {
            line += 1;
            i += 1;
            continue;
        }
        if c == b' ' || c == b'\t' || c == b'\r' {
            i += 1;
            continue;
        }
        // line comment (also /// and //! doc forms); may carry a pragma
        if c == b'/' && b.get(i + 1) == Some(&b'/') {
            let end = line_end(b, i);
            let body = src[i..end].trim_start_matches('/').trim_start_matches('!').trim();
            if let Some(rest) = body.strip_prefix("lint:") {
                pragmas.push(Pragma { line, body: rest.trim().to_string() });
            }
            i = end;
            continue;
        }
        // block comment, nested
        if c == b'/' && b.get(i + 1) == Some(&b'*') {
            let mut depth = 1usize;
            i += 2;
            while i < b.len() && depth > 0 {
                if b[i] == b'/' && b.get(i + 1) == Some(&b'*') {
                    depth += 1;
                    i += 2;
                } else if b[i] == b'*' && b.get(i + 1) == Some(&b'/') {
                    depth -= 1;
                    i += 2;
                } else {
                    if b[i] == b'\n' {
                        line += 1;
                    }
                    i += 1;
                }
            }
            continue;
        }
        // raw / byte-raw string: r"..", r#".."#, br".., br#".."#
        if c == b'r' || c == b'b' {
            if let Some((hashes, open)) = raw_string_start(b, i) {
                let mut j = open; // first content byte
                let closed = loop {
                    if j >= b.len() {
                        break b.len();
                    }
                    if b[j] == b'"' && b[j + 1..].iter().take(hashes).all(|&h| h == b'#') {
                        break (j + 1 + hashes).min(b.len());
                    }
                    if b[j] == b'\n' {
                        line += 1;
                    }
                    j += 1;
                };
                toks.push(Token { kind: TokKind::Lit, text: "<rawstr>".into(), line });
                i = closed;
                continue;
            }
        }
        // string / byte string
        if c == b'"' || (c == b'b' && b.get(i + 1) == Some(&b'"')) {
            let mut j = i + if c == b'b' { 2 } else { 1 };
            while j < b.len() {
                match b[j] {
                    b'\\' => j += 2,
                    b'"' => break,
                    b'\n' => {
                        line += 1;
                        j += 1;
                    }
                    _ => j += 1,
                }
            }
            toks.push(Token { kind: TokKind::Lit, text: "<str>".into(), line });
            i = j + 1;
            continue;
        }
        // char literal, byte char (b'x'), or lifetime ('a, 'static, '_)
        if c == b'\'' || (c == b'b' && b.get(i + 1) == Some(&b'\'')) {
            let q = i + if c == b'b' { 1 } else { 0 }; // position of '
            let mut j = q + 1;
            while j < b.len() && (b[j].is_ascii_alphanumeric() || b[j] == b'_') {
                j += 1;
            }
            if c != b'b' && j > q + 1 && b.get(j) != Some(&b'\'') {
                // 'ident with no closing quote: a lifetime, not a char
                toks.push(Token { kind: TokKind::Punct, text: src[i..j].into(), line });
                i = j;
                continue;
            }
            // char: consume escape-aware to the closing quote
            let mut j = q + 1;
            while j < b.len() {
                match b[j] {
                    b'\\' => j += 2,
                    b'\'' => break,
                    _ => j += 1,
                }
            }
            toks.push(Token { kind: TokKind::Lit, text: "<char>".into(), line });
            i = j + 1;
            continue;
        }
        // identifier / keyword
        if c.is_ascii_alphabetic() || c == b'_' {
            let mut j = i + 1;
            while j < b.len() && (b[j].is_ascii_alphanumeric() || b[j] == b'_') {
                j += 1;
            }
            toks.push(Token { kind: TokKind::Ident, text: src[i..j].into(), line });
            i = j;
            continue;
        }
        // number (verbatim text: the tag registry parses it back)
        if c.is_ascii_digit() {
            let mut j = i + 1;
            while j < b.len() {
                let d = b[j];
                if d.is_ascii_alphanumeric() || d == b'_' {
                    j += 1;
                } else if d == b'.' && b.get(j + 1).is_some_and(|n| n.is_ascii_digit()) {
                    j += 1;
                } else if (d == b'+' || d == b'-')
                    && matches!(b[j - 1], b'e' | b'E')
                    && b.get(j + 1).is_some_and(|n| n.is_ascii_digit())
                {
                    j += 1;
                } else {
                    break;
                }
            }
            toks.push(Token { kind: TokKind::Lit, text: src[i..j].into(), line });
            i = j;
            continue;
        }
        // single punctuation byte; non-ASCII outside literals is skipped
        if c.is_ascii() {
            toks.push(Token { kind: TokKind::Punct, text: (c as char).to_string(), line });
        }
        i += 1;
    }
    (toks, pragmas)
}

/// If `b[i..]` opens a raw string (`r`/`br` + hashes + quote), return
/// (hash count, index of the first content byte).
fn raw_string_start(b: &[u8], i: usize) -> Option<(usize, usize)> {
    let mut j = i;
    if b[j] == b'b' {
        j += 1;
    }
    if b.get(j) != Some(&b'r') {
        return None;
    }
    j += 1;
    let mut hashes = 0usize;
    while b.get(j) == Some(&b'#') {
        hashes += 1;
        j += 1;
    }
    if b.get(j) == Some(&b'"') {
        Some((hashes, j + 1))
    } else {
        None
    }
}

fn line_end(b: &[u8], mut i: usize) -> usize {
    while i < b.len() && b[i] != b'\n' {
        i += 1;
    }
    i
}

/// Mark every token that lives in test-only code: an item (fn, mod, use,
/// const, impl, ...) directly under a `#[test]`, `#[cfg(test)]`, or
/// `#[cfg_attr(test, ...)]` attribute, the attribute itself included.
/// An item ends at the close of its first top-level brace block, or at a
/// top-level `;` for brace-less items. Out-of-line `#[cfg(test)] mod x;`
/// file modules are *not* followed (the repo keeps all test mods inline).
pub fn mask_test_code(toks: &[Token]) -> Vec<bool> {
    let mut mask = vec![false; toks.len()];
    let mut i = 0usize;
    while i < toks.len() {
        if toks[i].text == "#" && txt(toks, i + 1) == "[" {
            // collect the attribute's tokens up to its closing bracket
            let mut j = i + 2;
            let mut depth = 1usize;
            let mut attr = String::new();
            while j < toks.len() && depth > 0 {
                match toks[j].text.as_str() {
                    "[" => depth += 1,
                    "]" => depth -= 1,
                    _ => {}
                }
                if depth > 0 {
                    attr.push_str(&toks[j].text);
                }
                j += 1;
            }
            let is_test_attr = attr == "test"
                || attr == "cfg(test)"
                || attr.starts_with("cfg(test,")
                || attr.starts_with("cfg_attr(test,");
            if is_test_attr {
                let end = item_end(toks, j);
                for m in mask.iter_mut().take(end).skip(i) {
                    *m = true;
                }
                i = end;
                continue;
            }
            i = j;
            continue;
        }
        i += 1;
    }
    mask
}

/// Index one past the end of the item starting at `i`: the close of its
/// first top-level `{ ... }` block, or a `;` outside any nesting.
fn item_end(toks: &[Token], mut i: usize) -> usize {
    let mut braces = 0usize;
    let mut parens = 0isize;
    let mut brackets = 0isize;
    let mut seen_brace = false;
    while i < toks.len() {
        match toks[i].text.as_str() {
            "{" => {
                braces += 1;
                seen_brace = true;
            }
            "}" => {
                braces = braces.saturating_sub(1);
                if seen_brace && braces == 0 {
                    return i + 1;
                }
            }
            "(" => parens += 1,
            ")" => parens -= 1,
            "[" => brackets += 1,
            "]" => brackets -= 1,
            ";" if !seen_brace && parens == 0 && brackets == 0 => return i + 1,
            _ => {}
        }
        i += 1;
    }
    i
}

fn txt(toks: &[Token], i: usize) -> &str {
    toks.get(i).map_or("", |t| t.text.as_str())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn idents(src: &str) -> Vec<String> {
        lex(src)
            .0
            .into_iter()
            .filter(|t| t.kind == TokKind::Ident)
            .map(|t| t.text)
            .collect()
    }

    #[test]
    fn strings_and_comments_hide_tokens() {
        let src = r##"
            let a = "unwrap() partial_cmp"; // unwrap in a comment
            /* block unwrap /* nested HashMap */ still comment */
            let b = r#"raw "quoted" unwrap"#;
            let c = 'u'; let d = b'x'; let e: &'static str = "s";
        "##;
        let ids = idents(src);
        assert!(!ids.contains(&"unwrap".to_string()), "{ids:?}");
        assert!(!ids.contains(&"partial_cmp".to_string()));
        assert!(!ids.contains(&"HashMap".to_string()));
        // the real identifiers survive
        for want in ["let", "a", "b", "c", "d", "e", "str"] {
            assert!(ids.contains(&want.to_string()), "missing {want} in {ids:?}");
        }
    }

    #[test]
    fn lifetimes_are_not_char_literals() {
        // a naive lexer treats `'a` as an unterminated char and swallows
        // the rest of the file — everything after must still tokenize
        let src = "fn f<'a>(x: &'a str) -> &'a str { x.unwrap() }";
        let ids = idents(src);
        assert!(ids.contains(&"unwrap".to_string()), "{ids:?}");
        // and real char literals (escaped quote included) stay opaque
        let src = "let q = '\\''; let n = '\\n'; let z = 'z'; x.unwrap()";
        let ids = idents(src);
        assert_eq!(ids.iter().filter(|t| *t == "unwrap").count(), 1);
        assert!(!ids.contains(&"n".to_string()));
    }

    #[test]
    fn line_numbers_track_multiline_literals() {
        let src = "let a = \"one\ntwo\nthree\";\nlet tail = 1;";
        let (toks, _) = lex(src);
        let tail = toks.iter().find(|t| t.text == "tail").map(|t| t.line);
        assert_eq!(tail, Some(4));
    }

    #[test]
    fn pragmas_surface_with_lines() {
        let src = "// lint: allow(panic-path): reason here\nlet x = 1;\n// plain comment\n";
        let (_, pragmas) = lex(src);
        assert_eq!(pragmas.len(), 1);
        assert_eq!(pragmas[0].line, 1);
        assert_eq!(pragmas[0].body, "allow(panic-path): reason here");
    }

    #[test]
    fn numeric_literals_keep_text() {
        let (toks, _) = lex("const A_TAG: u64 = 0xde_ad_be_ef; let f = 1.5e-3;");
        let lits: Vec<&str> = toks
            .iter()
            .filter(|t| t.kind == TokKind::Lit)
            .map(|t| t.text.as_str())
            .collect();
        assert_eq!(lits, vec!["0xde_ad_be_ef", "1.5e-3"]);
    }

    #[test]
    fn range_expressions_do_not_merge() {
        // `0..10` must not lex as one number token
        let (toks, _) = lex("for i in 0..10 {}");
        let lits: Vec<&str> =
            toks.iter().filter(|t| t.kind == TokKind::Lit).map(|t| t.text.as_str()).collect();
        assert_eq!(lits, vec!["0", "10"]);
    }

    #[test]
    fn cfg_test_mask_covers_mod_and_fn() {
        let src = "
            fn live() { x.unwrap(); }
            #[cfg(test)]
            mod tests {
                fn helper() { y.unwrap(); }
            }
            #[test]
            fn t() { z.unwrap(); }
            fn live2() { w.unwrap(); }
        ";
        let (toks, _) = lex(src);
        let mask = mask_test_code(&toks);
        let live: Vec<&str> = toks
            .iter()
            .zip(&mask)
            .filter(|(t, &m)| !m && t.text == "unwrap")
            .map(|(t, _)| t.text.as_str())
            .collect();
        assert_eq!(live.len(), 2, "only live() and live2() unwraps are unmasked");
        let masked = toks.iter().zip(&mask).filter(|(t, &m)| m && t.text == "unwrap").count();
        assert_eq!(masked, 2);
    }

    #[test]
    fn cfg_test_mask_handles_braceless_items_and_stacked_attrs() {
        let src = "
            #[cfg(test)]
            use std::collections::HashMap;
            #[cfg(test)]
            #[derive(Debug)]
            struct Fix { a: u32 }
            fn live() { x.unwrap(); }
        ";
        let (toks, _) = lex(src);
        let mask = mask_test_code(&toks);
        for (t, &m) in toks.iter().zip(&mask) {
            match t.text.as_str() {
                "HashMap" | "Fix" | "derive" => assert!(m, "{} must be masked", t.text),
                "unwrap" => assert!(!m, "live code must stay unmasked"),
                _ => {}
            }
        }
    }

    #[test]
    fn non_test_cfg_attrs_do_not_mask() {
        let src = "#[cfg_attr(miri, ignore)]\nfn heavy() { x.unwrap(); }";
        let (toks, _) = lex(src);
        let mask = mask_test_code(&toks);
        let hidden = toks.iter().zip(&mask).any(|(t, &m)| t.text == "unwrap" && m);
        assert!(!hidden, "cfg_attr(miri, ...) is not test code");
    }
}
