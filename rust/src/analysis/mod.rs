//! Contract-enforcing static analysis (`feel lint`).
//!
//! Every subsystem rests on hand-maintained invariants — tagged RNG
//! streams, fixed-order `total_cmp` reductions, wall clock never touching
//! simulated time. This module turns them into a machine-checked pass:
//! a lightweight lexer ([`lexer`]) feeds a rule engine ([`rules`])
//! enforcing six contracts:
//!
//! | rule | slug | contract |
//! |------|------|----------|
//! | R1 | `float-sort` | no `partial_cmp().unwrap()` — `total_cmp` only |
//! | R2 | `tag-registry` | `*_TAG: u64` constants literal, nonzero, distinct |
//! | R3 | `hash-iter` | no `HashMap`/`HashSet` in deterministic modules |
//! | R4 | `wall-clock` | `Instant::now`/`SystemTime` on allowlist only |
//! | R5 | `panic-path` | no `.unwrap()`/`.expect()` in library code |
//! | R6 | `rng-source` | RNG construction lives in `util::rng` only |
//!
//! Suppression is per-site: `// lint: allow(<slug>): <reason>` on the
//! finding's line or the line above, reason mandatory. The pass never
//! runs in the training path — it reads source files, so enabling it
//! cannot change a `TrainLog` bitwise.
//!
//! Shipped three ways: the `feel lint [--json]` subcommand, the tier-1
//! test `tests/lint_contracts.rs` (pins the tree at zero findings), and
//! a CI lint-job step.

use std::fmt;
use std::fs;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::util::json::{self, Json};

pub mod lexer;
pub mod rules;

pub use rules::{check_tags, lint_source, TagDef};

/// The six contracts plus the meta-rule for malformed pragmas.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Rule {
    /// R1: float sorts must use `total_cmp`, never `partial_cmp().unwrap()`.
    FloatSort,
    /// R2: RNG stream tags are literal u64, nonzero, pairwise distinct.
    TagRegistry,
    /// R3: no hash-order iteration inside deterministic modules.
    HashIter,
    /// R4: wall-clock reads confined to the allowlist.
    WallClock,
    /// R5: no `.unwrap()`/`.expect()` in library code without a pragma.
    PanicPath,
    /// R6: RNG construction outside `util::rng` is forbidden.
    RngSource,
    /// A `// lint:` comment that does not parse as a valid pragma.
    Pragma,
}

impl Rule {
    const ALL: [Rule; 7] = [
        Rule::FloatSort,
        Rule::TagRegistry,
        Rule::HashIter,
        Rule::WallClock,
        Rule::PanicPath,
        Rule::RngSource,
        Rule::Pragma,
    ];

    /// Stable identifier used in pragmas, text output, and JSON.
    pub fn slug(self) -> &'static str {
        match self {
            Rule::FloatSort => "float-sort",
            Rule::TagRegistry => "tag-registry",
            Rule::HashIter => "hash-iter",
            Rule::WallClock => "wall-clock",
            Rule::PanicPath => "panic-path",
            Rule::RngSource => "rng-source",
            Rule::Pragma => "pragma",
        }
    }

    pub fn from_slug(slug: &str) -> Option<Rule> {
        Rule::ALL.into_iter().find(|r| r.slug() == slug)
    }
}

impl fmt::Display for Rule {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.slug())
    }
}

/// One contract violation at a source location.
#[derive(Clone, Debug)]
pub struct Finding {
    pub rule: Rule,
    /// Crate-relative path with `/` separators (`src/...`, `benches/...`).
    pub file: String,
    pub line: u32,
    pub message: String,
}

/// Lint every `.rs` file under `<root>/src` and `<root>/benches`, then
/// run the cross-file tag-registry check. Findings come back sorted by
/// (file, line, rule) so output is deterministic across platforms.
pub fn lint_tree(root: &Path) -> Result<Vec<Finding>> {
    let mut files: Vec<PathBuf> = Vec::new();
    for sub in ["src", "benches"] {
        let dir = root.join(sub);
        if dir.is_dir() {
            collect_rs(&dir, &mut files).with_context(|| format!("walking {}", dir.display()))?;
        }
    }
    if files.is_empty() {
        bail!("no .rs files under {} — is this the crate root?", root.display());
    }
    files.sort();
    let mut findings: Vec<Finding> = Vec::new();
    let mut tags: Vec<TagDef> = Vec::new();
    for path in &files {
        let src =
            fs::read_to_string(path).with_context(|| format!("reading {}", path.display()))?;
        let rel = rel_path(root, path);
        let (found, file_tags) = rules::lint_source(&rel, &src);
        findings.extend(found);
        tags.extend(file_tags);
    }
    findings.extend(rules::check_tags(&tags));
    findings.sort_by(|a, b| {
        (a.file.as_str(), a.line, a.rule).cmp(&(b.file.as_str(), b.line, b.rule))
    });
    Ok(findings)
}

/// Accept either the crate root (contains `src/`) or the repo root
/// (contains `rust/src/`).
pub fn resolve_root(arg: &Path) -> Result<PathBuf> {
    for cand in [arg.to_path_buf(), arg.join("rust")] {
        if cand.join("src").is_dir() {
            return Ok(cand);
        }
    }
    bail!("no src/ under {0} or {0}/rust — pass the crate or repo root", arg.display())
}

/// `file:line: [slug] message` lines, one per finding.
pub fn render_text(findings: &[Finding]) -> String {
    let mut out = String::new();
    for f in findings {
        out.push_str(&format!("{}:{}: [{}] {}\n", f.file, f.line, f.rule, f.message));
    }
    out
}

/// Machine-readable report for `feel lint --json`.
pub fn render_json(findings: &[Finding]) -> String {
    let items: Vec<Json> = findings
        .iter()
        .map(|f| {
            json::obj(vec![
                ("file", json::s(&f.file)),
                ("line", json::num(f.line as f64)),
                ("rule", json::s(f.rule.slug())),
                ("message", json::s(&f.message)),
            ])
        })
        .collect();
    let report = json::obj(vec![
        ("count", json::num(findings.len() as f64)),
        ("findings", Json::Arr(items)),
    ]);
    report.to_string()
}

/// Depth-first sorted walk collecting `.rs` files.
fn collect_rs(dir: &Path, out: &mut Vec<PathBuf>) -> std::io::Result<()> {
    let mut entries: Vec<PathBuf> = Vec::new();
    for entry in fs::read_dir(dir)? {
        entries.push(entry?.path());
    }
    entries.sort();
    for path in entries {
        if path.is_dir() {
            collect_rs(&path, out)?;
        } else if path.extension().is_some_and(|e| e == "rs") {
            out.push(path);
        }
    }
    Ok(())
}

/// Crate-relative path with `/` separators regardless of platform.
fn rel_path(root: &Path, path: &Path) -> String {
    let rel = path.strip_prefix(root).unwrap_or(path);
    let parts: Vec<String> =
        rel.components().map(|c| c.as_os_str().to_string_lossy().into_owned()).collect();
    parts.join("/")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn slugs_round_trip() {
        for rule in Rule::ALL {
            assert_eq!(Rule::from_slug(rule.slug()), Some(rule));
        }
        assert_eq!(Rule::from_slug("no-such"), None);
    }

    #[test]
    fn renderers_are_deterministic() {
        let f = Finding {
            rule: Rule::PanicPath,
            file: "src/x.rs".into(),
            line: 7,
            message: "msg".into(),
        };
        assert_eq!(render_text(&[f.clone()]), "src/x.rs:7: [panic-path] msg\n");
        let js = render_json(&[f]);
        assert!(js.contains("\"count\":1"), "{js}");
        assert!(js.contains("\"rule\":\"panic-path\""), "{js}");
    }
}
