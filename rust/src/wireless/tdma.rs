//! TDMA frame substrate (paper §II-C, eq. 10–11).
//!
//! Uplink and downlink are framed (paper: `T_f = 10 ms`, LTE). Within a
//! frame, device `k` owns a slot of duration `tau_k`; the slots of one frame
//! must pack: `sum_k tau_k <= T_f`. Transmitting `s` bits at average rate
//! `R_k` with a per-frame slot `tau_k` takes `s / (tau_k R_k)` frames, i.e.
//! latency `t_k = s T_f / (tau_k R_k)` — eq. (10)/(11).
//!
//! Besides the closed form, `FrameSimulator` replays the transmission
//! frame-by-frame (with optional per-frame fading on the instantaneous
//! rate) so tests can pin the formula against an executable model.

use anyhow::{bail, Result};

use crate::util::rng::Pcg;
use crate::wireless::rate::instantaneous_rate;

/// A TDMA slot allocation across K devices for one link direction.
#[derive(Clone, Debug, PartialEq)]
pub struct SlotAllocation {
    /// frame length in seconds
    pub frame_s: f64,
    /// per-device slot durations in seconds
    pub tau: Vec<f64>,
}

impl SlotAllocation {
    pub fn new(frame_s: f64, tau: Vec<f64>) -> Result<Self> {
        if frame_s <= 0.0 {
            bail!("frame length must be positive");
        }
        if tau.iter().any(|&t| t < 0.0 || !t.is_finite()) {
            bail!("slot durations must be non-negative and finite");
        }
        let used: f64 = tau.iter().sum();
        if used > frame_s * (1.0 + 1e-9) {
            bail!("slots over-pack the frame: {used} > {frame_s}");
        }
        Ok(SlotAllocation { frame_s, tau })
    }

    /// Equal split of the whole frame across K devices.
    pub fn equal(frame_s: f64, k: usize) -> Self {
        SlotAllocation { frame_s, tau: vec![frame_s / k as f64; k] }
    }

    /// Fraction of the frame actually used.
    pub fn utilization(&self) -> f64 {
        self.tau.iter().sum::<f64>() / self.frame_s
    }

    /// Closed-form upload latency of `s_bits` for device `k` at average
    /// rate `rate_bps` (eq. 10). Infinite if the device has no slot.
    pub fn latency(&self, k: usize, s_bits: f64, rate_bps: f64) -> f64 {
        let tau = self.tau[k];
        if tau <= 0.0 || rate_bps <= 0.0 {
            return f64::INFINITY;
        }
        s_bits * self.frame_s / (tau * rate_bps)
    }
}

/// Frame-by-frame executable model of one device's transmission.
pub struct FrameSimulator {
    /// frame length (s)
    pub frame_s: f64,
    /// slot duration within each frame (s)
    pub tau: f64,
    /// mean SNR (linear) — per-frame instantaneous rate is
    /// `W log2(1 + gamma |h|^2)` with |h|^2 redrawn each frame.
    pub gamma: f64,
    /// bandwidth (Hz)
    pub w_hz: f64,
}

impl FrameSimulator {
    /// Number of frames (and total seconds) to push `s_bits` through.
    /// With `fading = None` the deterministic average rate `avg_rate_bps`
    /// is used every frame — this must reproduce eq. (10) up to frame
    /// quantization.
    pub fn transmit(
        &self,
        s_bits: f64,
        avg_rate_bps: f64,
        mut fading: Option<&mut Pcg>,
    ) -> (usize, f64) {
        assert!(self.tau > 0.0 && s_bits > 0.0);
        let mut sent = 0.0;
        let mut frames = 0usize;
        while sent < s_bits {
            let rate = match fading.as_deref_mut() {
                Some(rng) => instantaneous_rate(self.w_hz, self.gamma, rng.exponential()),
                None => avg_rate_bps,
            };
            sent += rate * self.tau;
            frames += 1;
            if frames > 100_000_000 {
                // pathological starvation guard
                return (frames, f64::INFINITY);
            }
        }
        (frames, frames as f64 * self.frame_s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rejects_overpacked_frame() {
        assert!(SlotAllocation::new(0.01, vec![0.006, 0.006]).is_err());
        assert!(SlotAllocation::new(0.01, vec![0.004, 0.006]).is_ok());
    }

    #[test]
    fn rejects_negative_slots() {
        assert!(SlotAllocation::new(0.01, vec![-0.001, 0.002]).is_err());
    }

    #[test]
    fn equal_split_packs_exactly() {
        let a = SlotAllocation::equal(0.01, 8);
        assert!((a.utilization() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn latency_formula_eq10() {
        // s = 1 Mbit, R = 10 Mbit/s, tau = 1 ms of a 10 ms frame
        // frames = 1e6 / (1e-3 * 1e7) = 100 -> latency 1 s
        let a = SlotAllocation::new(0.01, vec![0.001]).unwrap();
        let t = a.latency(0, 1e6, 1e7);
        assert!((t - 1.0).abs() < 1e-9);
    }

    #[test]
    fn zero_slot_infinite_latency() {
        let a = SlotAllocation::new(0.01, vec![0.0]).unwrap();
        assert!(a.latency(0, 1e6, 1e7).is_infinite());
    }

    #[test]
    fn simulator_matches_closed_form_no_fading() {
        let sim = FrameSimulator { frame_s: 0.01, tau: 0.002, gamma: 10.0, w_hz: 10e6 };
        let rate = 8e6;
        let s_bits = 3.3e6;
        let (frames, secs) = sim.transmit(s_bits, rate, None);
        let exact = s_bits * 0.01 / (0.002 * rate);
        // frame quantization: sim rounds *up* to whole frames
        assert!(secs >= exact && secs <= exact + 0.01 + 1e-12, "{secs} vs {exact}");
        assert_eq!(frames, (exact / 0.01).ceil() as usize);
    }

    #[test]
    fn simulator_with_fading_near_average() {
        // over many frames the fading-aware time approaches the ergodic-rate
        // prediction (law of large numbers across frames)
        let mut rng = Pcg::seeded(8);
        let gamma = 10.0;
        let w = 10e6;
        let sim = FrameSimulator { frame_s: 0.01, tau: 0.001, gamma, w_hz: w };
        let avg = crate::wireless::rate::ergodic_rate(w, gamma);
        let s_bits = avg * 0.001 * 5_000.0; // ~5k frames worth
        let (_, secs) = sim.transmit(s_bits, avg, Some(&mut rng));
        let exact = s_bits * 0.01 / (0.001 * avg);
        assert!((secs - exact).abs() / exact < 0.05, "{secs} vs {exact}");
    }
}
