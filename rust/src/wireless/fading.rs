//! Fading processes: fast Rayleigh fading within a frame, log-normal
//! shadowing across training periods.
//!
//! The paper optimizes with the *average* rates (eq. 5–6) because a training
//! period spans many LTE frames; the per-period channel dynamics it appeals
//! to ("the batchsize of each device varies across training periods because
//! of the channel dynamics", Remark 2) enter through slow large-scale
//! variation. We model that as i.i.d. log-normal shadowing redrawn each
//! period on top of the static path loss; fast Rayleigh fading is averaged
//! analytically inside the rate computation.

use crate::util::rng::Pcg;

/// Per-period large-scale channel state of one device.
#[derive(Clone, Copy, Debug)]
pub struct ShadowingProcess {
    /// shadowing standard deviation in dB (0 disables dynamics)
    pub sigma_db: f64,
    /// temporal correlation of successive periods, in [0,1)
    /// (first-order Gauss–Markov; 0 = i.i.d.)
    pub rho: f64,
    state_db: f64,
}

impl ShadowingProcess {
    pub fn new(sigma_db: f64, rho: f64, rng: &mut Pcg) -> Self {
        assert!((0.0..1.0).contains(&rho), "rho in [0,1)");
        assert!(sigma_db >= 0.0);
        let state_db = sigma_db * rng.normal();
        ShadowingProcess { sigma_db, rho, state_db }
    }

    /// Advance one training period; returns the *linear* shadowing gain.
    pub fn step(&mut self, rng: &mut Pcg) -> f64 {
        let innov = (1.0 - self.rho * self.rho).sqrt() * self.sigma_db;
        self.state_db = self.rho * self.state_db + innov * rng.normal();
        10f64.powf(self.state_db / 10.0)
    }

    /// Current gain without advancing.
    pub fn gain(&self) -> f64 {
        10f64.powf(self.state_db / 10.0)
    }

    /// The raw dB state, for checkpoint serialization.
    pub fn state_db(&self) -> f64 {
        self.state_db
    }

    /// Restore a checkpointed dB state verbatim.
    pub fn restore_state_db(&mut self, state_db: f64) {
        assert!(state_db.is_finite(), "bad shadowing restore {state_db}");
        self.state_db = state_db;
    }
}

/// Draw one Rayleigh power realization |h|^2 ~ Exp(1).
pub fn rayleigh_power(rng: &mut Pcg) -> f64 {
    rng.exponential()
}

/// A block-fading trace: `n` i.i.d. |h|^2 samples (one per frame).
pub fn block_fading_trace(n: usize, rng: &mut Pcg) -> Vec<f64> {
    (0..n).map(|_| rayleigh_power(rng)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::stats::summarize;

    #[test]
    fn shadowing_zero_sigma_is_unity() {
        let mut rng = Pcg::seeded(1);
        let mut s = ShadowingProcess::new(0.0, 0.0, &mut rng);
        for _ in 0..100 {
            assert_eq!(s.step(&mut rng), 1.0);
        }
    }

    #[test]
    fn shadowing_log_moments() {
        let mut rng = Pcg::seeded(2);
        let mut s = ShadowingProcess::new(8.0, 0.0, &mut rng);
        let xs: Vec<f64> = (0..100_000)
            .map(|_| 10.0 * s.step(&mut rng).log10())
            .collect();
        let sum = summarize(xs.iter().copied());
        assert!(sum.mean().abs() < 0.15, "mean {}", sum.mean());
        assert!((sum.std() - 8.0).abs() < 0.15, "std {}", sum.std());
    }

    #[test]
    fn shadowing_correlation() {
        let mut rng = Pcg::seeded(3);
        let rho = 0.9;
        let mut s = ShadowingProcess::new(8.0, rho, &mut rng);
        let xs: Vec<f64> = (0..200_000)
            .map(|_| 10.0 * s.step(&mut rng).log10())
            .collect();
        // lag-1 autocorrelation ~ rho
        let n = xs.len();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var: f64 = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>();
        let cov: f64 = xs
            .windows(2)
            .map(|w| (w[0] - mean) * (w[1] - mean))
            .sum::<f64>();
        let r1 = cov / var;
        assert!((r1 - rho).abs() < 0.02, "r1 {r1}");
    }

    #[test]
    fn shadowing_correlated_is_seed_deterministic_across_periods() {
        // per-cell links key their shadowing off per-cell RNG streams: the
        // whole multi-cell determinism story needs a correlated (rho > 0)
        // process to replay bit-identically from its seed, period after
        // period, and to decorrelate the moment the seed changes
        let run = |seed: u64| -> Vec<f64> {
            let mut rng = Pcg::seeded(seed);
            let mut s = ShadowingProcess::new(6.0, 0.7, &mut rng);
            (0..64).map(|_| s.step(&mut rng)).collect()
        };
        let a = run(11);
        let b = run(11);
        for (p, (x, y)) in a.iter().zip(&b).enumerate() {
            assert_eq!(x.to_bits(), y.to_bits(), "period {p}");
        }
        let c = run(12);
        let same = a.iter().zip(&c).filter(|(x, y)| x == y).count();
        assert!(same < 3, "{same} of 64 periods collide across seeds");
    }

    #[test]
    fn shadowing_correlated_marginals_stationary() {
        // Gauss–Markov with innovation std (1 - rho^2)^1/2 * sigma and a
        // sigma-scaled initial state is stationary from t = 0: the dB
        // marginals keep mean 0 / std sigma at rho > 0, and the lag-2
        // autocorrelation is rho^2
        let mut rng = Pcg::seeded(5);
        let (sigma, rho) = (6.0, 0.7);
        let mut s = ShadowingProcess::new(sigma, rho, &mut rng);
        let xs: Vec<f64> = (0..200_000)
            .map(|_| 10.0 * s.step(&mut rng).log10())
            .collect();
        let n = xs.len() as f64;
        let mean = xs.iter().sum::<f64>() / n;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n;
        assert!(mean.abs() < 0.15, "mean {mean}");
        assert!((var.sqrt() - sigma).abs() < 0.15, "std {}", var.sqrt());
        let cov2: f64 = xs
            .windows(3)
            .map(|w| (w[0] - mean) * (w[2] - mean))
            .sum::<f64>()
            / n;
        let r2 = cov2 / var;
        assert!((r2 - rho * rho).abs() < 0.02, "lag-2 autocorrelation {r2}");
    }

    #[test]
    fn trace_len_and_mean() {
        let mut rng = Pcg::seeded(4);
        let t = block_fading_trace(100_000, &mut rng);
        assert_eq!(t.len(), 100_000);
        let m = t.iter().sum::<f64>() / t.len() as f64;
        assert!((m - 1.0).abs() < 0.02);
    }
}
