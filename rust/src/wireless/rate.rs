//! Average data rates (paper eq. 5–6) over Rayleigh block fading.
//!
//! `R_k = W * E_h[ log2(1 + P|h|^2 / N0) ]` with `|h|^2 ~ Exp(1)` under
//! unit-power Rayleigh fading. Two evaluators:
//!  * `ergodic_rate` — closed form via the exponential integral E1
//!    (util::special), used by the optimizer;
//!  * `monte_carlo_rate` — sample mean over fading draws, used to
//!    cross-validate the closed form (bench_channel + unit tests).

use crate::util::rng::Pcg;
use crate::util::special::ergodic_log2_rayleigh;

/// Closed-form average rate in bit/s for mean SNR `gamma` (linear) and
/// bandwidth `w_hz`.
pub fn ergodic_rate(w_hz: f64, gamma: f64) -> f64 {
    w_hz * ergodic_log2_rayleigh(gamma)
}

/// Monte-Carlo estimate of the same quantity over `n` fading draws.
pub fn monte_carlo_rate(w_hz: f64, gamma: f64, n: usize, rng: &mut Pcg) -> f64 {
    assert!(n > 0);
    let mut acc = 0.0;
    for _ in 0..n {
        let x = rng.exponential(); // |h|^2
        acc += (1.0 + gamma * x).log2();
    }
    w_hz * acc / n as f64
}

/// Instantaneous rate for one fading realization `h2 = |h|^2`.
pub fn instantaneous_rate(w_hz: f64, gamma: f64, h2: f64) -> f64 {
    w_hz * (1.0 + gamma * h2).log2()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn closed_form_vs_monte_carlo() {
        let mut rng = Pcg::seeded(3);
        for gamma in [0.5, 5.0, 50.0] {
            let cf = ergodic_rate(10e6, gamma);
            let mc = monte_carlo_rate(10e6, gamma, 300_000, &mut rng);
            assert!((cf - mc).abs() / cf < 0.01, "gamma={gamma}: {cf} vs {mc}");
        }
    }

    #[test]
    fn rate_scales_with_bandwidth() {
        let r1 = ergodic_rate(1e6, 10.0);
        let r2 = ergodic_rate(2e6, 10.0);
        assert!((r2 / r1 - 2.0).abs() < 1e-12);
    }

    #[test]
    fn jensen_gap_positive() {
        // E[log(1+gX)] < log(1+g) for E X = 1 (concavity).
        let gamma = 20.0;
        let erg = ergodic_log2_rayleigh(gamma);
        assert!(erg < (1.0 + gamma).log2());
        assert!(erg > 0.0);
    }

    #[test]
    fn instantaneous_zero_fading_zero_rate() {
        assert_eq!(instantaneous_rate(1e6, 100.0, 0.0), 0.0);
    }
}
