//! Wireless substrate (DESIGN.md S1–S2): path loss, fading, ergodic rates,
//! TDMA frames — everything the paper's eq. (5), (6), (10), (11) need.

pub mod fading;
pub mod link;
pub mod pathloss;
pub mod rate;
pub mod tdma;

pub use link::{DeviceLink, PeriodRates};
pub use pathloss::CellConfig;
pub use tdma::SlotAllocation;
