//! Path-loss model and link-budget arithmetic (paper §VI-A).
//!
//! The paper's single-cell setup: 200 m radius, BS at the center, devices
//! uniformly distributed, path loss `PL [dB] = 128.1 + 37.6 log10(d [km])`,
//! Rayleigh small-scale fading with unit variance, uplink/downlink transmit
//! power 28 dBm, bandwidth 10 MHz, noise power density -174 dBm/Hz.

use crate::util::rng::Pcg;
use crate::util::special::{db_to_lin, dbm_to_watt};

/// Static link parameters for one cell.
#[derive(Clone, Copy, Debug)]
pub struct CellConfig {
    /// cell radius in meters (paper: 200 m)
    pub radius_m: f64,
    /// uplink transmit power in dBm (paper: 28 dBm)
    pub p_ul_dbm: f64,
    /// downlink transmit power in dBm (paper: 28 dBm)
    pub p_dl_dbm: f64,
    /// system bandwidth in Hz (paper: 10 MHz)
    pub bandwidth_hz: f64,
    /// noise power spectral density in dBm/Hz (paper: -174)
    pub noise_dbm_per_hz: f64,
    /// minimum BS-device distance in meters (avoid the PL singularity)
    pub min_dist_m: f64,
}

impl Default for CellConfig {
    fn default() -> Self {
        CellConfig {
            radius_m: 200.0,
            p_ul_dbm: 28.0,
            p_dl_dbm: 28.0,
            bandwidth_hz: 10e6,
            noise_dbm_per_hz: -174.0,
            min_dist_m: 10.0,
        }
    }
}

impl CellConfig {
    /// Total noise power over the band, watts.
    pub fn noise_watt(&self) -> f64 {
        dbm_to_watt(self.noise_dbm_per_hz) * self.bandwidth_hz
    }

    /// The per-cell TDMA bandwidth budget of a `cells`-cell topology: the
    /// system band divided evenly, everything else (powers, radius, noise
    /// density) unchanged. One cell gets the whole band back bitwise
    /// (`x / 1.0 == x` exactly), which the flat-trainer degenerate case
    /// of `hier::CellTopology` relies on. Cross-cell interference is out
    /// of scope here — orthogonal bands make cells independent, and the
    /// reuse-1 interference model is the seam a later PR fills.
    pub fn split_bandwidth(&self, cells: usize) -> CellConfig {
        assert!(cells >= 1, "bandwidth split over zero cells");
        CellConfig { bandwidth_hz: self.bandwidth_hz / cells as f64, ..*self }
    }
}

/// `PL [dB] = 128.1 + 37.6 log10(d [km])` (3GPP macro, as in the paper).
pub fn pathloss_db(dist_m: f64) -> f64 {
    assert!(dist_m > 0.0, "pathloss at non-positive distance");
    128.1 + 37.6 * (dist_m / 1000.0).log10()
}

/// Linear channel power gain from path loss (no fading).
pub fn pathloss_gain(dist_m: f64) -> f64 {
    db_to_lin(-pathloss_db(dist_m))
}

/// Draw a uniform position in the disk and return its distance to the BS.
/// Area-uniform: r = R * sqrt(u), clamped to `min_dist_m`.
pub fn sample_distance(cfg: &CellConfig, rng: &mut Pcg) -> f64 {
    let r = cfg.radius_m * rng.f64().sqrt();
    r.max(cfg.min_dist_m)
}

/// Mean SNR (linear) of a device at `dist_m` on the uplink.
pub fn mean_snr_ul(cfg: &CellConfig, dist_m: f64) -> f64 {
    dbm_to_watt(cfg.p_ul_dbm) * pathloss_gain(dist_m) / cfg.noise_watt()
}

/// Mean SNR (linear) of a device at `dist_m` on the downlink.
pub fn mean_snr_dl(cfg: &CellConfig, dist_m: f64) -> f64 {
    dbm_to_watt(cfg.p_dl_dbm) * pathloss_gain(dist_m) / cfg.noise_watt()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pathloss_reference_values() {
        // at 1 km PL = 128.1 dB exactly; at 100 m PL = 128.1 - 37.6 = 90.5 dB
        assert!((pathloss_db(1000.0) - 128.1).abs() < 1e-9);
        assert!((pathloss_db(100.0) - 90.5).abs() < 1e-9);
    }

    #[test]
    fn pathloss_monotone() {
        let mut prev = 0.0;
        for d in [10.0, 50.0, 100.0, 150.0, 200.0] {
            let pl = pathloss_db(d);
            assert!(pl > prev);
            prev = pl;
        }
    }

    #[test]
    fn distances_within_cell() {
        let cfg = CellConfig::default();
        let mut rng = Pcg::seeded(1);
        for _ in 0..10_000 {
            let d = sample_distance(&cfg, &mut rng);
            assert!(d >= cfg.min_dist_m && d <= cfg.radius_m);
        }
    }

    #[test]
    fn distance_area_uniform() {
        // P(r <= R/2) should be ~1/4 for area-uniform placement.
        let cfg = CellConfig { min_dist_m: 0.0001, ..CellConfig::default() };
        let mut rng = Pcg::seeded(2);
        let n = 100_000;
        let inside = (0..n)
            .filter(|_| sample_distance(&cfg, &mut rng) <= cfg.radius_m / 2.0)
            .count();
        let frac = inside as f64 / n as f64;
        assert!((frac - 0.25).abs() < 0.01, "frac {frac}");
    }

    #[test]
    fn snr_decreases_with_distance() {
        let cfg = CellConfig::default();
        assert!(mean_snr_ul(&cfg, 50.0) > mean_snr_ul(&cfg, 150.0));
        assert!(mean_snr_dl(&cfg, 50.0) > mean_snr_dl(&cfg, 150.0));
    }

    #[test]
    fn snr_plausible_at_cell_edge() {
        // 28 dBm tx, ~139 dB PL at 200 m... sanity: SNR should be modest but
        // positive in dB terms at the edge with 10 MHz noise bandwidth.
        let cfg = CellConfig::default();
        let snr = mean_snr_ul(&cfg, 200.0);
        let snr_db = 10.0 * snr.log10();
        assert!(snr_db > -10.0 && snr_db < 40.0, "edge SNR {snr_db} dB");
    }

    #[test]
    fn split_bandwidth_budget() {
        let cfg = CellConfig::default();
        // one cell: the whole band, bitwise (the hier degenerate case)
        let one = cfg.split_bandwidth(1);
        assert_eq!(one.bandwidth_hz.to_bits(), cfg.bandwidth_hz.to_bits());
        // C cells: an even budget; powers and geometry untouched
        let c4 = cfg.split_bandwidth(4);
        assert_eq!(c4.bandwidth_hz, cfg.bandwidth_hz / 4.0);
        assert_eq!(c4.p_ul_dbm, cfg.p_ul_dbm);
        assert_eq!(c4.radius_m, cfg.radius_m);
        assert_eq!(c4.noise_dbm_per_hz, cfg.noise_dbm_per_hz);
        // noise power scales with the band (same density)
        assert!((c4.noise_watt() - cfg.noise_watt() / 4.0).abs() < 1e-25);
    }

    #[test]
    fn noise_power_value() {
        let cfg = CellConfig::default();
        // -174 dBm/Hz + 70 dB(10 MHz) = -104 dBm = 3.98e-14 W
        assert!((cfg.noise_watt() - 3.98e-14).abs() < 0.05e-14);
    }
}
