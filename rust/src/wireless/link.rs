//! Per-device link state: static path loss + per-period shadowing, yielding
//! the average uplink/downlink rates the optimizer consumes each period.

use crate::util::rng::Pcg;
use crate::wireless::fading::ShadowingProcess;
use crate::wireless::pathloss::{mean_snr_dl, mean_snr_ul, sample_distance, CellConfig};
use crate::wireless::rate::ergodic_rate;

/// Rates of one device for one training period.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct PeriodRates {
    /// average uplink rate, bit/s (eq. 5)
    pub ul_bps: f64,
    /// average downlink rate, bit/s (eq. 6)
    pub dl_bps: f64,
}

/// One device's wireless link.
#[derive(Clone, Debug)]
pub struct DeviceLink {
    pub dist_m: f64,
    shadow_ul: ShadowingProcess,
    shadow_dl: ShadowingProcess,
    cfg: CellConfig,
}

impl DeviceLink {
    /// Place a device uniformly in the cell.
    pub fn sample(cfg: CellConfig, shadow_sigma_db: f64, shadow_rho: f64, rng: &mut Pcg) -> Self {
        let dist_m = sample_distance(&cfg, rng);
        Self::at_distance(cfg, dist_m, shadow_sigma_db, shadow_rho, rng)
    }

    /// Place a device at a fixed distance (deterministic fleets in tests).
    pub fn at_distance(
        cfg: CellConfig,
        dist_m: f64,
        shadow_sigma_db: f64,
        shadow_rho: f64,
        rng: &mut Pcg,
    ) -> Self {
        DeviceLink {
            dist_m,
            shadow_ul: ShadowingProcess::new(shadow_sigma_db, shadow_rho, rng),
            shadow_dl: ShadowingProcess::new(shadow_sigma_db, shadow_rho, rng),
            cfg,
        }
    }

    /// Advance one training period and return this period's average rates.
    pub fn step(&mut self, rng: &mut Pcg) -> PeriodRates {
        let g_ul = self.shadow_ul.step(rng);
        let g_dl = self.shadow_dl.step(rng);
        self.rates_with_gains(g_ul, g_dl)
    }

    /// Rates at the current shadowing state (no advance).
    pub fn current(&self) -> PeriodRates {
        self.rates_with_gains(self.shadow_ul.gain(), self.shadow_dl.gain())
    }

    /// The (uplink, downlink) shadowing states in dB, for checkpoints.
    pub fn shadow_state(&self) -> (f64, f64) {
        (self.shadow_ul.state_db(), self.shadow_dl.state_db())
    }

    /// Restore checkpointed shadowing states verbatim.
    pub fn restore_shadow_state(&mut self, ul_db: f64, dl_db: f64) {
        self.shadow_ul.restore_state_db(ul_db);
        self.shadow_dl.restore_state_db(dl_db);
    }

    fn rates_with_gains(&self, g_ul: f64, g_dl: f64) -> PeriodRates {
        let w = self.cfg.bandwidth_hz;
        PeriodRates {
            ul_bps: ergodic_rate(w, mean_snr_ul(&self.cfg, self.dist_m) * g_ul),
            dl_bps: ergodic_rate(w, mean_snr_dl(&self.cfg, self.dist_m) * g_dl),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn closer_device_faster() {
        let cfg = CellConfig::default();
        let mut rng = Pcg::seeded(1);
        let near = DeviceLink::at_distance(cfg, 50.0, 0.0, 0.0, &mut rng).current();
        let far = DeviceLink::at_distance(cfg, 190.0, 0.0, 0.0, &mut rng).current();
        assert!(near.ul_bps > far.ul_bps);
        assert!(near.dl_bps > far.dl_bps);
    }

    #[test]
    fn no_shadowing_rates_constant() {
        let cfg = CellConfig::default();
        let mut rng = Pcg::seeded(2);
        let mut l = DeviceLink::at_distance(cfg, 100.0, 0.0, 0.0, &mut rng);
        let r0 = l.step(&mut rng);
        for _ in 0..10 {
            assert_eq!(l.step(&mut rng), r0);
        }
    }

    #[test]
    fn shadowing_varies_rates() {
        let cfg = CellConfig::default();
        let mut rng = Pcg::seeded(3);
        let mut l = DeviceLink::at_distance(cfg, 100.0, 8.0, 0.0, &mut rng);
        let rs: Vec<f64> = (0..50).map(|_| l.step(&mut rng).ul_bps).collect();
        let s = crate::util::stats::summarize(rs.iter().copied());
        assert!(s.std() > 0.01 * s.mean(), "rates did not vary");
    }

    #[test]
    fn rates_positive_and_bounded_by_capacity_at_huge_snr() {
        let cfg = CellConfig::default();
        let mut rng = Pcg::seeded(4);
        let l = DeviceLink::at_distance(cfg, 10.0, 0.0, 0.0, &mut rng).current();
        assert!(l.ul_bps > 0.0);
        // 10 MHz * ~30 b/s/Hz is an absurd upper bound; sanity only
        assert!(l.ul_bps < 10e6 * 30.0);
    }
}
