//! Configuration system: TOML-subset parser + experiment schema.

pub mod schema;
pub mod toml;

pub use schema::{parse_policy, parse_scheme, Experiment, SCHEME_NAMES};
pub use toml::{Config, Value};
