//! Configuration system: TOML-subset parser + experiment schema.

pub mod schema;
pub mod toml;

pub use schema::{
    parse_backends_spec, parse_cell_policies_spec, parse_policy, parse_scheme, Experiment,
    TierBackend, SCHEME_NAMES,
};
pub use toml::{Config, Value};
