//! Configuration system: TOML-subset parser + experiment schema.

pub mod schema;
pub mod toml;

pub use schema::{parse_scheme, Experiment};
pub use toml::{Config, Value};
