//! TOML-subset parser (serde/toml are unavailable offline).
//!
//! Supported grammar — everything the experiment configs need:
//!   * `[section]` and `[section.sub]` headers
//!   * `key = "string" | 123 | 4.5 | true | false | [value, ...]`
//!   * inline tables `{key = value, ...}` (used by `fleet.backends`)
//!   * `#` comments, blank lines
//!
//! Values land in a flat `section.key -> Value` map with typed accessors.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Arr(Vec<Value>),
    Table(BTreeMap<String, Value>),
}

impl Value {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Float(f) => Some(*f),
            Value::Int(i) => Some(*i as f64),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Value::Int(i) => Some(*i),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_i64().and_then(|i| usize::try_from(i).ok())
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Value]> {
        match self {
            Value::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_table(&self) -> Option<&BTreeMap<String, Value>> {
        match self {
            Value::Table(t) => Some(t),
            _ => None,
        }
    }
}

#[derive(Debug, Clone)]
pub struct TomlError {
    pub line: usize,
    pub msg: String,
}

impl fmt::Display for TomlError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "config line {}: {}", self.line, self.msg)
    }
}

impl std::error::Error for TomlError {}

/// Parsed config: flat dotted-key map.
#[derive(Clone, Debug, Default)]
pub struct Config {
    map: BTreeMap<String, Value>,
}

impl Config {
    pub fn parse(src: &str) -> Result<Config, TomlError> {
        let mut map = BTreeMap::new();
        let mut section = String::new();
        for (ln, raw) in src.lines().enumerate() {
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            let err = |msg: &str| TomlError { line: ln + 1, msg: msg.to_string() };
            if let Some(rest) = line.strip_prefix('[') {
                let name = rest.strip_suffix(']').ok_or_else(|| err("unclosed ["))?;
                if name.is_empty() || !name.chars().all(|c| c.is_ascii_alphanumeric() || c == '_' || c == '.') {
                    return Err(err("bad section name"));
                }
                section = name.to_string();
                continue;
            }
            let (key, val) = line.split_once('=').ok_or_else(|| err("expected key = value"))?;
            let key = key.trim();
            if key.is_empty() || !key.chars().all(|c| c.is_ascii_alphanumeric() || c == '_') {
                return Err(err("bad key"));
            }
            let full = if section.is_empty() { key.to_string() } else { format!("{section}.{key}") };
            let value = parse_value(val.trim()).map_err(|m| err(&m))?;
            if map.insert(full.clone(), value).is_some() {
                return Err(err(&format!("duplicate key {full}")));
            }
        }
        Ok(Config { map })
    }

    pub fn load(path: &std::path::Path) -> anyhow::Result<Config> {
        let text = std::fs::read_to_string(path)?;
        Ok(Config::parse(&text)?)
    }

    pub fn get(&self, key: &str) -> Option<&Value> {
        self.map.get(key)
    }

    pub fn str_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).and_then(|v| v.as_str()).unwrap_or(default)
    }

    pub fn f64_or(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|v| v.as_f64()).unwrap_or(default)
    }

    pub fn usize_or(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(|v| v.as_usize()).unwrap_or(default)
    }

    pub fn bool_or(&self, key: &str, default: bool) -> bool {
        self.get(key).and_then(|v| v.as_bool()).unwrap_or(default)
    }

    pub fn keys(&self) -> impl Iterator<Item = &String> {
        self.map.keys()
    }
}

fn strip_comment(line: &str) -> &str {
    // '#' outside quotes starts a comment
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> Result<Value, String> {
    if s.is_empty() {
        return Err("empty value".into());
    }
    if let Some(rest) = s.strip_prefix('"') {
        let inner = rest.strip_suffix('"').ok_or("unterminated string")?;
        return Ok(Value::Str(inner.to_string()));
    }
    if s == "true" {
        return Ok(Value::Bool(true));
    }
    if s == "false" {
        return Ok(Value::Bool(false));
    }
    if let Some(rest) = s.strip_prefix('[') {
        let inner = rest.strip_suffix(']').ok_or("unterminated array")?;
        let inner = inner.trim();
        if inner.is_empty() {
            return Ok(Value::Arr(Vec::new()));
        }
        return split_top_level(inner)?
            .into_iter()
            .map(|p| parse_value(p.trim()))
            .collect::<Result<Vec<_>, _>>()
            .map(Value::Arr);
    }
    if let Some(rest) = s.strip_prefix('{') {
        let inner = rest.strip_suffix('}').ok_or("unterminated inline table")?;
        let inner = inner.trim();
        let mut map = BTreeMap::new();
        if inner.is_empty() {
            return Ok(Value::Table(map));
        }
        for part in split_top_level(inner)? {
            let (key, val) = part
                .split_once('=')
                .ok_or_else(|| format!("inline table entry {part:?} wants key = value"))?;
            let key = key.trim();
            if key.is_empty() || !key.chars().all(|c| c.is_ascii_alphanumeric() || c == '_') {
                return Err(format!("bad inline table key {key:?}"));
            }
            if map.insert(key.to_string(), parse_value(val.trim())?).is_some() {
                return Err(format!("duplicate inline table key {key}"));
            }
        }
        return Ok(Value::Table(map));
    }
    if let Ok(i) = s.parse::<i64>() {
        return Ok(Value::Int(i));
    }
    if let Ok(f) = s.parse::<f64>() {
        return Ok(Value::Float(f));
    }
    Err(format!("cannot parse value {s:?}"))
}

/// Split on commas at bracket depth 0, outside strings — so array elements
/// that are themselves inline tables (or nested arrays) stay intact.
fn split_top_level(s: &str) -> Result<Vec<&str>, String> {
    let mut parts = Vec::new();
    let mut depth = 0i32;
    let mut in_str = false;
    let mut start = 0usize;
    for (i, c) in s.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '[' | '{' if !in_str => depth += 1,
            ']' | '}' if !in_str => {
                depth -= 1;
                if depth < 0 {
                    return Err(format!("unbalanced brackets in {s:?}"));
                }
            }
            ',' if !in_str && depth == 0 => {
                parts.push(&s[start..i]);
                start = i + 1;
            }
            _ => {}
        }
    }
    if depth != 0 || in_str {
        return Err(format!("unbalanced brackets or quotes in {s:?}"));
    }
    parts.push(&s[start..]);
    Ok(parts)
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
# experiment config
name = "table2"            # inline comment
[fleet]
k = 12
tiers = [0.7, 1.4, 2.1]
gpu = false
[train]
lr = 0.35
periods = 500
"#;

    #[test]
    fn parses_sample() {
        let c = Config::parse(SAMPLE).unwrap();
        assert_eq!(c.str_or("name", ""), "table2");
        assert_eq!(c.usize_or("fleet.k", 0), 12);
        assert!(!c.bool_or("fleet.gpu", true));
        assert_eq!(c.f64_or("train.lr", 0.0), 0.35);
        let tiers = c.get("fleet.tiers").unwrap();
        match tiers {
            Value::Arr(v) => assert_eq!(v.len(), 3),
            _ => panic!(),
        }
    }

    #[test]
    fn defaults_apply() {
        let c = Config::parse("").unwrap();
        assert_eq!(c.usize_or("missing", 7), 7);
        assert_eq!(c.str_or("missing", "x"), "x");
    }

    #[test]
    fn rejects_duplicates_and_garbage() {
        assert!(Config::parse("a = 1\na = 2").is_err());
        assert!(Config::parse("[unclosed").is_err());
        assert!(Config::parse("novalue =").is_err());
        assert!(Config::parse("= 3").is_err());
        assert!(Config::parse("a = \"unterminated").is_err());
    }

    #[test]
    fn int_vs_float() {
        let c = Config::parse("a = 3\nb = 3.5").unwrap();
        assert_eq!(c.get("a").unwrap().as_i64(), Some(3));
        assert_eq!(c.get("a").unwrap().as_f64(), Some(3.0));
        assert_eq!(c.get("b").unwrap().as_i64(), None);
        assert_eq!(c.get("b").unwrap().as_f64(), Some(3.5));
    }

    #[test]
    fn hash_inside_string_kept() {
        let c = Config::parse("a = \"x#y\"").unwrap();
        assert_eq!(c.str_or("a", ""), "x#y");
    }

    #[test]
    fn inline_table_arrays() {
        let src = r#"
[fleet]
backends = [{tier = 0, model = "mini_dense"}, {tier = 1, model = "mini_res", backend = "host"}]
"#;
        let c = Config::parse(src).unwrap();
        let arr = c.get("fleet.backends").unwrap().as_arr().unwrap();
        assert_eq!(arr.len(), 2);
        let t0 = arr[0].as_table().unwrap();
        assert_eq!(t0.get("tier").unwrap().as_usize(), Some(0));
        assert_eq!(t0.get("model").unwrap().as_str(), Some("mini_dense"));
        assert!(t0.get("backend").is_none());
        let t1 = arr[1].as_table().unwrap();
        assert_eq!(t1.get("backend").unwrap().as_str(), Some("host"));
        // empty table and empty array still parse
        let c = Config::parse("a = {}\nb = []").unwrap();
        assert!(c.get("a").unwrap().as_table().unwrap().is_empty());
        assert!(c.get("b").unwrap().as_arr().unwrap().is_empty());
    }

    #[test]
    fn inline_table_rejects_malformed() {
        assert!(Config::parse("a = {tier = 0").is_err());
        assert!(Config::parse("a = {tier}").is_err());
        assert!(Config::parse("a = {tier = 0, tier = 1}").is_err());
        assert!(Config::parse("a = {bad key = 0}").is_err());
        assert!(Config::parse("a = [{tier = 0}, {]").is_err());
        // commas inside strings do not split elements
        let c = Config::parse("a = [\"x,y\", \"z\"]").unwrap();
        let arr = c.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr.len(), 2);
        assert_eq!(arr[0].as_str(), Some("x,y"));
    }
}
