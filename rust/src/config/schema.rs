//! Experiment configuration schema: maps a parsed TOML-subset `Config`
//! onto the concrete simulation objects (fleet, data, trainer settings).

use anyhow::{bail, Result};

use super::toml::Config;
use crate::coordinator::{Scheme, TrainerConfig};
use crate::data::{Partition, SynthConfig};
use crate::device::{paper_cpu_fleet, paper_gpu_fleet, Device, GpuModule, StragglerModel};
use crate::opt::BatchPolicy;
use crate::sched::{RoundPolicy, POLICY_NAMES};
use crate::util::rng::Pcg;
use crate::wireless::CellConfig;

/// Accepted `--scheme` / `train.scheme` values (keep in sync with
/// [`parse_scheme`]; the CLI help and error paths print this).
pub const SCHEME_NAMES: &str =
    "proposed | gradient_fl | model_fl | individual | online | full_batch | random_batch";

/// Fully-resolved experiment description.
#[derive(Clone, Debug)]
pub struct Experiment {
    pub name: String,
    pub model: String,
    pub k: usize,
    pub partition: Partition,
    pub gpu: bool,
    pub periods: usize,
    pub train_n: usize,
    pub test_n: usize,
    pub synth: SynthConfig,
    pub cell: CellConfig,
    pub shadow_sigma_db: f64,
    pub shadow_rho: f64,
    pub cycles_per_sample: f64,
    pub cycles_per_update: f64,
    pub gpu_module: GpuModule,
    pub trainer: TrainerConfig,
}

impl Default for Experiment {
    fn default() -> Self {
        Experiment {
            name: "default".into(),
            model: "mini_res".into(),
            k: 6,
            partition: Partition::Iid,
            gpu: false,
            periods: 200,
            train_n: 6000,
            test_n: 1024,
            synth: SynthConfig::default(),
            cell: CellConfig::default(),
            shadow_sigma_db: 4.0,
            shadow_rho: 0.7,
            cycles_per_sample: 7e7,
            cycles_per_update: 1e8,
            gpu_module: GpuModule::new(0.110, 2.4e-3, 24.0, 2.0e9, 1.0e13),
            trainer: TrainerConfig::default(),
        }
    }
}

impl Experiment {
    /// Resolve from a parsed config file (missing keys keep defaults).
    pub fn from_config(c: &Config) -> Result<Experiment> {
        let mut e = Experiment::default();
        e.name = c.str_or("name", &e.name).to_string();
        e.model = c.str_or("model", &e.model).to_string();
        e.k = c.usize_or("fleet.k", e.k);
        if e.k == 0 {
            bail!("fleet.k must be >= 1");
        }
        e.partition = match c.str_or("data.partition", "iid") {
            s => Partition::parse(s).ok_or_else(|| anyhow::anyhow!("bad data.partition {s:?}"))?,
        };
        e.gpu = c.bool_or("fleet.gpu", e.gpu);
        e.periods = c.usize_or("train.periods", e.periods);
        e.train_n = c.usize_or("data.train_n", e.train_n);
        e.test_n = c.usize_or("data.test_n", e.test_n);
        e.synth.dim = c.usize_or("data.dim", e.synth.dim);
        e.synth.classes = c.usize_or("data.classes", e.synth.classes);
        e.shadow_sigma_db = c.f64_or("channel.shadow_sigma_db", e.shadow_sigma_db);
        e.shadow_rho = c.f64_or("channel.shadow_rho", e.shadow_rho);
        e.cell.radius_m = c.f64_or("channel.radius_m", e.cell.radius_m);
        e.cell.bandwidth_hz = c.f64_or("channel.bandwidth_hz", e.cell.bandwidth_hz);
        e.cycles_per_sample = c.f64_or("fleet.cycles_per_sample", e.cycles_per_sample);
        e.cycles_per_update = c.f64_or("fleet.cycles_per_update", e.cycles_per_update);

        let t = &mut e.trainer;
        t.b_max = c.usize_or("train.b_max", t.b_max);
        t.base_lr = c.f64_or("train.lr", t.base_lr);
        t.eval_every = c.usize_or("train.eval_every", t.eval_every);
        t.threads = c.usize_or("train.threads", t.threads);
        t.seed = c.usize_or("train.seed", t.seed as usize) as u64;
        t.wire_ratio = c.f64_or("compress.wire_ratio", t.wire_ratio);
        t.quant_bits = c.usize_or("compress.quant_bits", t.quant_bits as usize) as u32;
        if c.bool_or("compress.sbc", true) {
            t.sbc_keep = Some(c.f64_or("compress.keep_frac", 0.005));
        } else {
            t.sbc_keep = None;
        }
        t.scheme = parse_scheme(c.str_or("train.scheme", "proposed"), t.b_max)?;
        t.policy = parse_policy_config(c)?;
        t.straggler = StragglerModel::new(
            c.f64_or("fleet.jitter", t.straggler.jitter),
            c.f64_or("fleet.dropout", t.straggler.dropout),
        )?;
        Ok(e)
    }

    /// Build the device fleet this experiment describes.
    pub fn fleet(&self, rng: &mut Pcg) -> Vec<Device> {
        if self.gpu {
            paper_gpu_fleet(
                self.k,
                self.gpu_module,
                self.cell,
                self.shadow_sigma_db,
                self.shadow_rho,
                rng,
            )
        } else {
            paper_cpu_fleet(
                self.k,
                self.cycles_per_sample,
                self.cycles_per_update,
                self.cell,
                self.shadow_sigma_db,
                self.shadow_rho,
                rng,
            )
        }
    }
}

/// Parse a scheme name as used in configs and on the CLI.
pub fn parse_scheme(s: &str, b_max: usize) -> Result<Scheme> {
    Ok(match s {
        "proposed" => Scheme::Proposed,
        "gradient_fl" | "gradient" => Scheme::GradientFl,
        "model_fl" | "fedavg" => Scheme::ModelFl { local_batch: 32 },
        "individual" => Scheme::Individual { local_batch: b_max },
        "online" => Scheme::Fixed { policy: BatchPolicy::Online, optimal_slots: true },
        "full_batch" | "full" => Scheme::Fixed { policy: BatchPolicy::Full, optimal_slots: true },
        "random_batch" | "random" => {
            Scheme::Fixed { policy: BatchPolicy::Random, optimal_slots: true }
        }
        other => bail!("unknown scheme {other:?} (accepted: {SCHEME_NAMES})"),
    })
}

/// Parse a round-policy name as used in configs and on the CLI.
pub fn parse_policy(s: &str) -> Result<RoundPolicy> {
    RoundPolicy::parse(s)
        .ok_or_else(|| anyhow::anyhow!("unknown policy {s:?} (accepted: {POLICY_NAMES})"))
}

/// Resolve `train.policy` and its knobs (`train.deadline_factor`,
/// `train.async_alpha`, `train.async_beta`, `train.quorum`), validating
/// at parse time instead of deep inside the trainer.
fn parse_policy_config(c: &Config) -> Result<RoundPolicy> {
    let mut p = parse_policy(c.str_or("train.policy", "sync"))?;
    // a knob for a different policy is a mistake, not a no-op — silently
    // ignoring `train.quorum` under sync would run a different experiment
    // than the config describes (knob table: `RoundPolicy::ALL_KNOBS`)
    for knob in RoundPolicy::ALL_KNOBS {
        let key = format!("train.{knob}");
        if c.get(&key).is_some() && !p.knob_names().contains(knob) {
            bail!("config key {key} does not apply to train.policy = {:?}", p.name());
        }
    }
    match &mut p {
        RoundPolicy::Sync => {}
        RoundPolicy::Deadline { factor } => {
            *factor = c.f64_or("train.deadline_factor", *factor);
        }
        RoundPolicy::Async { alpha, beta, quorum } => {
            *alpha = c.f64_or("train.async_alpha", *alpha);
            *beta = c.f64_or("train.async_beta", *beta);
            *quorum = c.f64_or("train.quorum", *quorum);
        }
    }
    p.validate()?;
    Ok(p)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_roundtrip() {
        let c = Config::parse("").unwrap();
        let e = Experiment::from_config(&c).unwrap();
        assert_eq!(e.k, 6);
        assert_eq!(e.model, "mini_res");
        assert_eq!(e.partition, Partition::Iid);
    }

    #[test]
    fn full_config() {
        let src = r#"
name = "gpu_run"
model = "mini_dense"
[fleet]
k = 12
gpu = true
[data]
partition = "non-iid"
train_n = 2400
[train]
scheme = "online"
lr = 0.2
periods = 50
threads = 8
[compress]
sbc = false
"#;
        let e = Experiment::from_config(&Config::parse(src).unwrap()).unwrap();
        assert_eq!(e.k, 12);
        assert!(e.gpu);
        assert_eq!(e.partition, Partition::NonIid);
        assert_eq!(e.trainer.base_lr, 0.2);
        assert_eq!(e.trainer.threads, 8);
        assert!(e.trainer.sbc_keep.is_none());
        assert!(matches!(e.trainer.scheme, Scheme::Fixed { .. }));
    }

    #[test]
    fn rejects_bad_scheme_and_partition() {
        let c = Config::parse("[train]\nscheme = \"sgd\"").unwrap();
        let err = Experiment::from_config(&c).unwrap_err().to_string();
        assert!(err.contains("proposed") && err.contains("random_batch"), "{err}");
        let c = Config::parse("[data]\npartition = \"skewed\"").unwrap();
        assert!(Experiment::from_config(&c).is_err());
    }

    #[test]
    fn policy_and_straggler_keys() {
        // defaults: sync barrier, no perturbation
        let e = Experiment::from_config(&Config::parse("").unwrap()).unwrap();
        assert!(e.trainer.policy.is_sync());
        assert!(!e.trainer.straggler.is_active());
        // deadline with a custom factor + straggler knobs
        let src = r#"
[fleet]
jitter = 0.4
dropout = 0.1
[train]
policy = "deadline"
deadline_factor = 1.3
"#;
        let e = Experiment::from_config(&Config::parse(src).unwrap()).unwrap();
        assert_eq!(e.trainer.policy, RoundPolicy::Deadline { factor: 1.3 });
        assert_eq!(e.trainer.straggler, StragglerModel { jitter: 0.4, dropout: 0.1 });
        // async knobs
        let src = r#"
[train]
policy = "async"
async_alpha = 0.8
async_beta = 1.0
quorum = 0.25
"#;
        let e = Experiment::from_config(&Config::parse(src).unwrap()).unwrap();
        assert_eq!(
            e.trainer.policy,
            RoundPolicy::Async { alpha: 0.8, beta: 1.0, quorum: 0.25 }
        );
    }

    #[test]
    fn bad_policy_values_fail_at_parse_with_accepted_list() {
        let c = Config::parse("[train]\npolicy = \"fifo\"").unwrap();
        let err = Experiment::from_config(&c).unwrap_err().to_string();
        assert!(err.contains("sync | deadline | async"), "{err}");
        // knob validation happens at parse time, not deep in the trainer
        let c = Config::parse("[train]\npolicy = \"deadline\"\ndeadline_factor = 0.5").unwrap();
        assert!(Experiment::from_config(&c).is_err());
        let c = Config::parse("[train]\npolicy = \"async\"\nquorum = 2.0").unwrap();
        assert!(Experiment::from_config(&c).is_err());
        let c = Config::parse("[fleet]\ndropout = 1.5").unwrap();
        assert!(Experiment::from_config(&c).is_err());
        // a knob for a policy that is not active is an error, not a no-op
        let c = Config::parse("[train]\nquorum = 0.5").unwrap();
        let err = Experiment::from_config(&c).unwrap_err().to_string();
        assert!(err.contains("does not apply"), "{err}");
        let c = Config::parse("[train]\npolicy = \"deadline\"\nasync_alpha = 0.5").unwrap();
        assert!(Experiment::from_config(&c).is_err());
        let c = Config::parse("[train]\npolicy = \"async\"\ndeadline_factor = 1.5").unwrap();
        assert!(Experiment::from_config(&c).is_err());
    }

    #[test]
    fn fleet_construction_both_kinds() {
        let mut e = Experiment::default();
        let mut rng = Pcg::seeded(1);
        assert_eq!(e.fleet(&mut rng).len(), 6);
        e.gpu = true;
        assert_eq!(e.fleet(&mut rng).len(), 6);
    }
}
