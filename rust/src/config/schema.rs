//! Experiment configuration schema: maps a parsed TOML-subset `Config`
//! onto the concrete simulation objects (fleet, data, trainer settings).

use anyhow::{bail, Result};

use super::toml::{Config, Value};
use crate::coordinator::{Scheme, TrainerConfig};
use crate::data::{Partition, SynthConfig};
use crate::device::{
    paper_cpu_fleet, paper_gpu_fleet, Device, GpuModule, StragglerModel, CPU_TIER_COUNT,
};
use crate::fault::FaultPlan;
use crate::grad::{GradGuard, Quarantine, QUARANTINE_NAMES};
use crate::opt::BatchPolicy;
use crate::sched::{RoundPolicy, POLICY_NAMES};
use crate::util::rng::Pcg;
use crate::wireless::CellConfig;

/// Accepted `--scheme` / `train.scheme` values (keep in sync with
/// [`parse_scheme`]; the CLI help and error paths print this).
pub const SCHEME_NAMES: &str =
    "proposed | gradient_fl | model_fl | individual | online | full_batch | random_batch";

/// One per-tier backend rule: devices of CPU speed tier `tier` train
/// `model` on `backend` (`host` | `pjrt`; `None` = the run's `--backend`
/// kind). Configured as `fleet.backends = [{tier = 0, model =
/// "mini_dense", backend = "host"}, ...]` or the CLI shorthand
/// `--backends 0:mini_dense:host,1:mini_res`. Tiers without a rule fall
/// back to the experiment's default `model`.
#[derive(Clone, Debug, PartialEq)]
pub struct TierBackend {
    pub tier: usize,
    pub model: String,
    pub backend: Option<String>,
}

/// Fully-resolved experiment description.
#[derive(Clone, Debug)]
pub struct Experiment {
    pub name: String,
    pub model: String,
    pub k: usize,
    pub partition: Partition,
    pub gpu: bool,
    pub periods: usize,
    pub train_n: usize,
    pub test_n: usize,
    pub synth: SynthConfig,
    pub cell: CellConfig,
    pub shadow_sigma_db: f64,
    pub shadow_rho: f64,
    pub cycles_per_sample: f64,
    pub cycles_per_update: f64,
    pub gpu_module: GpuModule,
    pub trainer: TrainerConfig,
    /// per-tier backend rules (empty = homogeneous fleet on `model`)
    pub backends: Vec<TierBackend>,
    /// hierarchical topology: cells C (`topology.cells` / `--cells`;
    /// 1 = the flat single-cell trainer)
    pub cells: usize,
    /// cloud cadence tau: edge rounds per cloud merge (`topology.tau` /
    /// `--tau`)
    pub tau: usize,
    /// per-cell round-policy overrides (`topology.policies` /
    /// `--cell-policies`; empty = every cell uses `train.policy`). A name
    /// matching the base policy inherits its knobs; any other name gets
    /// that policy's defaults.
    pub cell_policies: Vec<RoundPolicy>,
    /// per-block cell sampling fraction (`topology.cell_frac` /
    /// `--cell-frac`; 1.0 = every cell runs every tau-block)
    pub cell_frac: f64,
}

impl Default for Experiment {
    fn default() -> Self {
        Experiment {
            name: "default".into(),
            model: "mini_res".into(),
            k: 6,
            partition: Partition::Iid,
            gpu: false,
            periods: 200,
            train_n: 6000,
            test_n: 1024,
            synth: SynthConfig::default(),
            cell: CellConfig::default(),
            shadow_sigma_db: 4.0,
            shadow_rho: 0.7,
            cycles_per_sample: 7e7,
            cycles_per_update: 1e8,
            gpu_module: GpuModule::new(0.110, 2.4e-3, 24.0, 2.0e9, 1.0e13),
            trainer: TrainerConfig::default(),
            backends: Vec::new(),
            cells: 1,
            tau: 1,
            cell_policies: Vec::new(),
            cell_frac: 1.0,
        }
    }
}

impl Experiment {
    /// Resolve from a parsed config file (missing keys keep defaults).
    pub fn from_config(c: &Config) -> Result<Experiment> {
        let mut e = Experiment::default();
        e.name = c.str_or("name", &e.name).to_string();
        e.model = c.str_or("model", &e.model).to_string();
        e.k = c.usize_or("fleet.k", e.k);
        if e.k == 0 {
            bail!("fleet.k must be >= 1");
        }
        e.partition = match c.str_or("data.partition", "iid") {
            s => Partition::parse(s).ok_or_else(|| anyhow::anyhow!("bad data.partition {s:?}"))?,
        };
        e.gpu = c.bool_or("fleet.gpu", e.gpu);
        e.periods = c.usize_or("train.periods", e.periods);
        e.train_n = c.usize_or("data.train_n", e.train_n);
        e.test_n = c.usize_or("data.test_n", e.test_n);
        e.synth.dim = c.usize_or("data.dim", e.synth.dim);
        e.synth.classes = c.usize_or("data.classes", e.synth.classes);
        e.shadow_sigma_db = c.f64_or("channel.shadow_sigma_db", e.shadow_sigma_db);
        e.shadow_rho = c.f64_or("channel.shadow_rho", e.shadow_rho);
        e.cell.radius_m = c.f64_or("channel.radius_m", e.cell.radius_m);
        e.cell.bandwidth_hz = c.f64_or("channel.bandwidth_hz", e.cell.bandwidth_hz);
        e.cycles_per_sample = c.f64_or("fleet.cycles_per_sample", e.cycles_per_sample);
        e.cycles_per_update = c.f64_or("fleet.cycles_per_update", e.cycles_per_update);

        let t = &mut e.trainer;
        t.b_max = c.usize_or("train.b_max", t.b_max);
        t.base_lr = c.f64_or("train.lr", t.base_lr);
        t.eval_every = c.usize_or("train.eval_every", t.eval_every);
        t.threads = c.usize_or("train.threads", t.threads);
        t.seed = c.usize_or("train.seed", t.seed as usize) as u64;
        t.wire_ratio = c.f64_or("compress.wire_ratio", t.wire_ratio);
        t.quant_bits = c.usize_or("compress.quant_bits", t.quant_bits as usize) as u32;
        if c.bool_or("compress.sbc", true) {
            t.sbc_keep = Some(c.f64_or("compress.keep_frac", 0.005));
        } else {
            t.sbc_keep = None;
        }
        t.scheme = parse_scheme(c.str_or("train.scheme", "proposed"), t.b_max)?;
        t.policy = parse_policy_config(c)?;
        t.straggler = StragglerModel::new(
            c.f64_or("fleet.jitter", t.straggler.jitter),
            c.f64_or("fleet.dropout", t.straggler.dropout),
        )?;
        t.sample_frac = c.f64_or("fleet.sample_frac", t.sample_frac);
        t.fault = parse_fault_config(c)?;
        t.guard = parse_guard_config(c)?;
        if let Some(v) = c.get("fleet.backends") {
            e.backends = parse_backend_rules(v)?;
            e.check_backend_tiers()?;
        }
        e.cells = c.usize_or("topology.cells", e.cells);
        e.tau = c.usize_or("topology.tau", e.tau);
        if let Some(v) = c.get("topology.policies") {
            e.cell_policies = parse_cell_policies(v)?;
        }
        e.cell_frac = c.f64_or("topology.cell_frac", e.cell_frac);
        e.check_topology()?;
        Ok(e)
    }

    /// Number of device tiers this experiment's fleet has: the paper's
    /// three CPU speed tiers, or one for the identical-GPU fleet.
    pub fn tier_count(&self) -> usize {
        if self.gpu {
            1
        } else {
            CPU_TIER_COUNT
        }
    }

    /// The tier device `id` belongs to (matches `paper_cpu_fleet`'s
    /// round-robin frequency assignment).
    pub fn tier_of(&self, id: usize) -> usize {
        id % self.tier_count()
    }

    /// Validate the per-tier backend rules against this experiment's
    /// fleet shape. Call after any mutation of `backends`, `gpu`, or `k`
    /// (the CLI does, after applying flag overrides). A rule for a tier
    /// no device occupies is an error, not a no-op — silently dropping it
    /// would run a different (homogeneous) experiment than the config
    /// describes.
    pub fn check_backend_tiers(&self) -> Result<()> {
        let tiers = self.tier_count();
        // round-robin assignment: tier t is occupied iff t < min(k, tiers)
        let occupied = tiers.min(self.k);
        for (i, r) in self.backends.iter().enumerate() {
            if r.tier >= tiers {
                bail!(
                    "fleet.backends tier {} out of range (this fleet has {} tiers)",
                    r.tier,
                    tiers
                );
            }
            if r.tier >= occupied {
                bail!(
                    "fleet.backends tier {} has no devices (fleet.k = {})",
                    r.tier,
                    self.k
                );
            }
            if self.backends[..i].iter().any(|o| o.tier == r.tier) {
                bail!("fleet.backends has two rules for tier {}", r.tier);
            }
        }
        Ok(())
    }

    /// Validate the hierarchical-topology knobs against the fleet shape.
    /// Call after any mutation of `cells`, `tau`, `cell_policies`, or `k`
    /// (the CLI does, after applying flag overrides). Per house style a
    /// knob that cannot take effect is an error, not a no-op: `tau` or
    /// per-cell policies on a single-cell run would silently describe a
    /// different experiment than the one that runs.
    pub fn check_topology(&self) -> Result<()> {
        if self.cells == 0 {
            bail!("topology.cells must be >= 1");
        }
        if self.tau == 0 {
            bail!("topology.tau must be >= 1");
        }
        if self.cells > self.k {
            bail!(
                "topology.cells = {} exceeds fleet.k = {}: every cell needs a device",
                self.cells,
                self.k
            );
        }
        if !(self.trainer.sample_frac > 0.0 && self.trainer.sample_frac <= 1.0) {
            bail!("fleet.sample_frac must be in (0, 1], got {}", self.trainer.sample_frac);
        }
        if !(self.cell_frac > 0.0 && self.cell_frac <= 1.0) {
            bail!("topology.cell_frac must be in (0, 1], got {}", self.cell_frac);
        }
        if self.cells == 1 {
            if self.tau != 1 {
                bail!("topology.tau applies to multi-cell runs (topology.cells > 1)");
            }
            if !self.cell_policies.is_empty() {
                bail!("topology.policies applies to multi-cell runs (topology.cells > 1)");
            }
            if self.cell_frac != 1.0 {
                bail!("topology.cell_frac applies to multi-cell runs (topology.cells > 1)");
            }
            if self.trainer.fault.outage_active() {
                bail!("fault.outage_rate applies to multi-cell runs (topology.cells > 1)");
            }
        }
        if !self.cell_policies.is_empty() && self.cell_policies.len() != self.cells {
            bail!(
                "topology.policies lists {} policies for {} cells (one per cell, or none)",
                self.cell_policies.len(),
                self.cells
            );
        }
        for p in &self.cell_policies {
            p.validate()?;
        }
        // per-cell tier coverage: each cell re-derives its tiers from its
        // own (smaller) device slice, so a backend rule that is valid for
        // the flat fleet can name a tier no device of the smallest cell
        // occupies — catch that here, with the cell split named, instead
        // of deep inside the per-cell backend resolution
        if self.cells > 1 && !self.backends.is_empty() {
            let smallest = self.k / self.cells;
            let occupied = self.tier_count().min(smallest);
            for r in &self.backends {
                if r.tier >= occupied {
                    bail!(
                        "fleet.backends tier {} has no devices once the fleet splits into {} \
                         cells (smallest cell: {} devices)",
                        r.tier,
                        self.cells,
                        smallest
                    );
                }
            }
        }
        Ok(())
    }

    /// The per-cell round policies a hierarchical run uses: the overrides
    /// with base-policy knob inheritance (a cell naming the base policy
    /// gets its configured knobs, not the parse defaults), or the base
    /// policy for every cell when no overrides are set.
    pub fn resolved_cell_policies(&self) -> Vec<RoundPolicy> {
        if self.cell_policies.is_empty() {
            return vec![self.trainer.policy; self.cells];
        }
        self.cell_policies
            .iter()
            .map(|p| {
                if p.name() == self.trainer.policy.name() {
                    self.trainer.policy
                } else {
                    *p
                }
            })
            .collect()
    }

    /// Build the device fleet this experiment describes.
    pub fn fleet(&self, rng: &mut Pcg) -> Vec<Device> {
        self.fleet_with(self.k, self.cell, rng)
    }

    /// Build a fleet of `k` devices under an explicit wireless `cell`
    /// config — the per-cell form `hier::CellTopology` drives with each
    /// cell's bandwidth budget. `fleet()` delegates here, so one RNG
    /// stream drawing cell after cell reproduces the flat fleet when the
    /// topology has a single cell.
    pub fn fleet_with(&self, k: usize, cell: CellConfig, rng: &mut Pcg) -> Vec<Device> {
        if self.gpu {
            paper_gpu_fleet(k, self.gpu_module, cell, self.shadow_sigma_db, self.shadow_rho, rng)
        } else {
            paper_cpu_fleet(
                k,
                self.cycles_per_sample,
                self.cycles_per_update,
                cell,
                self.shadow_sigma_db,
                self.shadow_rho,
                rng,
            )
        }
    }
}

/// Parse a scheme name as used in configs and on the CLI.
pub fn parse_scheme(s: &str, b_max: usize) -> Result<Scheme> {
    Ok(match s {
        "proposed" => Scheme::Proposed,
        "gradient_fl" | "gradient" => Scheme::GradientFl,
        "model_fl" | "fedavg" => Scheme::ModelFl { local_batch: 32 },
        "individual" => Scheme::Individual { local_batch: b_max },
        "online" => Scheme::Fixed { policy: BatchPolicy::Online, optimal_slots: true },
        "full_batch" | "full" => Scheme::Fixed { policy: BatchPolicy::Full, optimal_slots: true },
        "random_batch" | "random" => {
            Scheme::Fixed { policy: BatchPolicy::Random, optimal_slots: true }
        }
        other => bail!("unknown scheme {other:?} (accepted: {SCHEME_NAMES})"),
    })
}

/// Parse a round-policy name as used in configs and on the CLI.
pub fn parse_policy(s: &str) -> Result<RoundPolicy> {
    RoundPolicy::parse(s)
        .ok_or_else(|| anyhow::anyhow!("unknown policy {s:?} (accepted: {POLICY_NAMES})"))
}

/// Parse the `fleet.backends` config value: an array of inline tables
/// `{tier = N, model = "name", backend = "host"|"pjrt"}` (backend
/// optional — defaults to the run's `--backend` kind).
pub fn parse_backend_rules(v: &Value) -> Result<Vec<TierBackend>> {
    let Some(arr) = v.as_arr() else {
        bail!("fleet.backends wants an array of {{tier, model, backend}} tables");
    };
    let mut rules = Vec::with_capacity(arr.len());
    for item in arr {
        let Some(t) = item.as_table() else {
            bail!("fleet.backends entries want {{tier, model, backend}} tables");
        };
        for key in t.keys() {
            if !matches!(key.as_str(), "tier" | "model" | "backend") {
                bail!("fleet.backends entry has unknown key {key:?}");
            }
        }
        let Some(tier) = t.get("tier").and_then(|x| x.as_usize()) else {
            bail!("fleet.backends entry wants an integer tier");
        };
        let Some(model) = t.get("model").and_then(|x| x.as_str()) else {
            bail!("fleet.backends entry wants a string model");
        };
        let backend = match t.get("backend") {
            None => None,
            Some(b) => match b.as_str() {
                Some(s) => Some(s.to_string()),
                None => bail!("fleet.backends backend wants a string"),
            },
        };
        rules.push(TierBackend { tier, model: model.to_string(), backend });
    }
    Ok(rules)
}

/// Parse the CLI `--backends` shorthand: comma-separated
/// `tier:model[:backend]` rules, e.g. `0:mini_dense,1:mini_res:host`.
pub fn parse_backends_spec(spec: &str) -> Result<Vec<TierBackend>> {
    let mut rules = Vec::new();
    for part in spec.split(',') {
        let part = part.trim();
        if part.is_empty() {
            bail!("--backends has an empty rule (format: tier:model[:backend],...)");
        }
        let fields: Vec<&str> = part.split(':').collect();
        if !(2..=3).contains(&fields.len()) {
            bail!("--backends rule {part:?} wants tier:model[:backend]");
        }
        let tier: usize = fields[0]
            .parse()
            .map_err(|_| anyhow::anyhow!("--backends rule {part:?}: bad tier {:?}", fields[0]))?;
        if fields[1].is_empty() {
            bail!("--backends rule {part:?} wants a model name");
        }
        rules.push(TierBackend {
            tier,
            model: fields[1].to_string(),
            backend: fields.get(2).map(|s| s.to_string()),
        });
    }
    Ok(rules)
}

/// Parse the `topology.policies` config value: an array of round-policy
/// names, one per cell (e.g. `["sync", "deadline", "async"]`).
pub fn parse_cell_policies(v: &Value) -> Result<Vec<RoundPolicy>> {
    let Some(arr) = v.as_arr() else {
        bail!("topology.policies wants an array of policy names ({POLICY_NAMES})");
    };
    arr.iter()
        .map(|item| match item.as_str() {
            Some(s) => parse_policy(s),
            None => bail!("topology.policies entries want policy-name strings ({POLICY_NAMES})"),
        })
        .collect()
}

/// Parse the CLI `--cell-policies` shorthand: comma-separated policy
/// names, one per cell, e.g. `sync,deadline,async`.
pub fn parse_cell_policies_spec(spec: &str) -> Result<Vec<RoundPolicy>> {
    spec.split(',')
        .map(|part| {
            let part = part.trim();
            if part.is_empty() {
                bail!("--cell-policies has an empty entry (format: name,name,...)");
            }
            parse_policy(part)
        })
        .collect()
}

/// Resolve `train.policy` and its knobs (`train.deadline_factor`,
/// `train.async_alpha`, `train.async_beta`, `train.quorum`), validating
/// at parse time instead of deep inside the trainer.
fn parse_policy_config(c: &Config) -> Result<RoundPolicy> {
    let mut p = parse_policy(c.str_or("train.policy", "sync"))?;
    // a knob for a different policy is a mistake, not a no-op — silently
    // ignoring `train.quorum` under sync would run a different experiment
    // than the config describes (knob table: `RoundPolicy::ALL_KNOBS`)
    for knob in RoundPolicy::ALL_KNOBS {
        let key = format!("train.{knob}");
        if c.get(&key).is_some() && !p.knob_names().contains(knob) {
            bail!("config key {key} does not apply to train.policy = {:?}", p.name());
        }
    }
    match &mut p {
        RoundPolicy::Sync => {}
        RoundPolicy::Deadline { factor } => {
            *factor = c.f64_or("train.deadline_factor", *factor);
        }
        RoundPolicy::Async { alpha, beta, quorum } => {
            *alpha = c.f64_or("train.async_alpha", *alpha);
            *beta = c.f64_or("train.async_beta", *beta);
            *quorum = c.f64_or("train.quorum", *quorum);
        }
    }
    p.validate()?;
    Ok(p)
}

/// Resolve the `[fault]` table (`fault.crash_rate`, `fault.crash_len`,
/// `fault.corrupt_rate`, `fault.corrupt_noise`, `fault.outage_rate`),
/// validating at parse time instead of deep inside the trainer. A knob
/// for a fault class whose rate is zero is a mistake, not a no-op —
/// silently ignoring `fault.crash_len` with no crash rate would run a
/// different experiment than the config describes.
fn parse_fault_config(c: &Config) -> Result<FaultPlan> {
    let crash_rate = c.f64_or("fault.crash_rate", 0.0);
    if c.get("fault.crash_len").is_some() && crash_rate <= 0.0 {
        bail!("fault.crash_len needs fault.crash_rate > 0 to take effect");
    }
    let crash_len = c.usize_or("fault.crash_len", 1) as u64;
    let corrupt_rate = c.f64_or("fault.corrupt_rate", 0.0);
    if c.get("fault.corrupt_noise").is_some() && corrupt_rate <= 0.0 {
        bail!("fault.corrupt_noise needs fault.corrupt_rate > 0 to take effect");
    }
    let corrupt_noise = c.f64_or("fault.corrupt_noise", 0.0);
    let outage_rate = c.f64_or("fault.outage_rate", 0.0);
    FaultPlan::new(crash_rate, crash_len, corrupt_rate, corrupt_noise, outage_rate)
}

/// Resolve the gradient-quarantine knobs (`fault.quarantine`,
/// `fault.max_norm`). `max_norm` without a policy is deliberate
/// observability, not a dead knob: an `off` guard with a finite bound
/// counts norm outliers in the log without altering aggregation.
fn parse_guard_config(c: &Config) -> Result<GradGuard> {
    let policy = match c.get("fault.quarantine") {
        None => Quarantine::Off,
        Some(v) => {
            let Some(s) = v.as_str() else {
                bail!("fault.quarantine wants a policy-name string ({QUARANTINE_NAMES})");
            };
            Quarantine::parse(s).ok_or_else(|| {
                anyhow::anyhow!("unknown fault.quarantine {s:?} (accepted: {QUARANTINE_NAMES})")
            })?
        }
    };
    let max_norm = c.f64_or("fault.max_norm", f64::INFINITY);
    GradGuard::new(policy, max_norm)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_roundtrip() {
        let c = Config::parse("").unwrap();
        let e = Experiment::from_config(&c).unwrap();
        assert_eq!(e.k, 6);
        assert_eq!(e.model, "mini_res");
        assert_eq!(e.partition, Partition::Iid);
    }

    #[test]
    fn full_config() {
        let src = r#"
name = "gpu_run"
model = "mini_dense"
[fleet]
k = 12
gpu = true
[data]
partition = "non-iid"
train_n = 2400
[train]
scheme = "online"
lr = 0.2
periods = 50
threads = 8
[compress]
sbc = false
"#;
        let e = Experiment::from_config(&Config::parse(src).unwrap()).unwrap();
        assert_eq!(e.k, 12);
        assert!(e.gpu);
        assert_eq!(e.partition, Partition::NonIid);
        assert_eq!(e.trainer.base_lr, 0.2);
        assert_eq!(e.trainer.threads, 8);
        assert!(e.trainer.sbc_keep.is_none());
        assert!(matches!(e.trainer.scheme, Scheme::Fixed { .. }));
    }

    #[test]
    fn rejects_bad_scheme_and_partition() {
        let c = Config::parse("[train]\nscheme = \"sgd\"").unwrap();
        let err = Experiment::from_config(&c).unwrap_err().to_string();
        assert!(err.contains("proposed") && err.contains("random_batch"), "{err}");
        let c = Config::parse("[data]\npartition = \"skewed\"").unwrap();
        assert!(Experiment::from_config(&c).is_err());
    }

    #[test]
    fn policy_and_straggler_keys() {
        // defaults: sync barrier, no perturbation
        let e = Experiment::from_config(&Config::parse("").unwrap()).unwrap();
        assert!(e.trainer.policy.is_sync());
        assert!(!e.trainer.straggler.is_active());
        // deadline with a custom factor + straggler knobs
        let src = r#"
[fleet]
jitter = 0.4
dropout = 0.1
[train]
policy = "deadline"
deadline_factor = 1.3
"#;
        let e = Experiment::from_config(&Config::parse(src).unwrap()).unwrap();
        assert_eq!(e.trainer.policy, RoundPolicy::Deadline { factor: 1.3 });
        assert_eq!(e.trainer.straggler, StragglerModel { jitter: 0.4, dropout: 0.1 });
        // async knobs
        let src = r#"
[train]
policy = "async"
async_alpha = 0.8
async_beta = 1.0
quorum = 0.25
"#;
        let e = Experiment::from_config(&Config::parse(src).unwrap()).unwrap();
        assert_eq!(
            e.trainer.policy,
            RoundPolicy::Async { alpha: 0.8, beta: 1.0, quorum: 0.25 }
        );
    }

    #[test]
    fn bad_policy_values_fail_at_parse_with_accepted_list() {
        let c = Config::parse("[train]\npolicy = \"fifo\"").unwrap();
        let err = Experiment::from_config(&c).unwrap_err().to_string();
        assert!(err.contains("sync | deadline | async"), "{err}");
        // knob validation happens at parse time, not deep in the trainer
        let c = Config::parse("[train]\npolicy = \"deadline\"\ndeadline_factor = 0.5").unwrap();
        assert!(Experiment::from_config(&c).is_err());
        let c = Config::parse("[train]\npolicy = \"async\"\nquorum = 2.0").unwrap();
        assert!(Experiment::from_config(&c).is_err());
        let c = Config::parse("[fleet]\ndropout = 1.5").unwrap();
        assert!(Experiment::from_config(&c).is_err());
        // a knob for a policy that is not active is an error, not a no-op
        let c = Config::parse("[train]\nquorum = 0.5").unwrap();
        let err = Experiment::from_config(&c).unwrap_err().to_string();
        assert!(err.contains("does not apply"), "{err}");
        let c = Config::parse("[train]\npolicy = \"deadline\"\nasync_alpha = 0.5").unwrap();
        assert!(Experiment::from_config(&c).is_err());
        let c = Config::parse("[train]\npolicy = \"async\"\ndeadline_factor = 1.5").unwrap();
        assert!(Experiment::from_config(&c).is_err());
    }

    #[test]
    fn backend_rules_from_config_and_cli() {
        // defaults: no rules, homogeneous
        let e = Experiment::from_config(&Config::parse("").unwrap()).unwrap();
        assert!(e.backends.is_empty());
        let src = r#"
[fleet]
k = 6
backends = [{tier = 0, model = "mini_dense"}, {tier = 1, model = "mini_res", backend = "host"}]
"#;
        let e = Experiment::from_config(&Config::parse(src).unwrap()).unwrap();
        assert_eq!(e.backends.len(), 2);
        assert_eq!(
            e.backends[0],
            TierBackend { tier: 0, model: "mini_dense".into(), backend: None }
        );
        assert_eq!(
            e.backends[1],
            TierBackend { tier: 1, model: "mini_res".into(), backend: Some("host".into()) }
        );
        assert_eq!(e.tier_count(), 3);
        assert_eq!(e.tier_of(4), 1);
        // the CLI shorthand parses to the same rules
        let cli = parse_backends_spec("0:mini_dense,1:mini_res:host").unwrap();
        assert_eq!(cli, e.backends);
        // malformed shorthand rules are clean errors
        assert!(parse_backends_spec("").is_err());
        assert!(parse_backends_spec("0").is_err());
        assert!(parse_backends_spec("x:mini_dense").is_err());
        assert!(parse_backends_spec("0:").is_err());
        assert!(parse_backends_spec("0:m:host:extra").is_err());
    }

    #[test]
    fn backend_rules_validate_tiers() {
        // tier out of range for a CPU fleet (3 tiers)
        let src = "[fleet]\nbackends = [{tier = 3, model = \"mini_res\"}]";
        let err = Experiment::from_config(&Config::parse(src).unwrap())
            .unwrap_err()
            .to_string();
        assert!(err.contains("out of range"), "{err}");
        // duplicate tier rules
        let src = "[fleet]\nbackends = [{tier = 0, model = \"a\"}, {tier = 0, model = \"b\"}]";
        let err = Experiment::from_config(&Config::parse(src).unwrap())
            .unwrap_err()
            .to_string();
        assert!(err.contains("two rules"), "{err}");
        // gpu fleets have a single tier
        let src = "[fleet]\ngpu = true\nbackends = [{tier = 1, model = \"mini_res\"}]";
        assert!(Experiment::from_config(&Config::parse(src).unwrap()).is_err());
        // a rule for a tier no device occupies is an error, not a no-op
        let src = "[fleet]\nk = 2\nbackends = [{tier = 2, model = \"mini_dense\"}]";
        let err = Experiment::from_config(&Config::parse(src).unwrap())
            .unwrap_err()
            .to_string();
        assert!(err.contains("no devices"), "{err}");
        // ...but the same rule is fine once the fleet reaches the tier
        let src = "[fleet]\nk = 3\nbackends = [{tier = 2, model = \"mini_dense\"}]";
        assert!(Experiment::from_config(&Config::parse(src).unwrap()).is_ok());
        // malformed entries
        for bad in [
            "[fleet]\nbackends = [{model = \"m\"}]",
            "[fleet]\nbackends = [{tier = 0}]",
            "[fleet]\nbackends = [{tier = 0, model = \"m\", extra = 1}]",
            "[fleet]\nbackends = [7]",
            "[fleet]\nbackends = 7",
        ] {
            assert!(
                Experiment::from_config(&Config::parse(bad).unwrap()).is_err(),
                "{bad}"
            );
        }
    }

    #[test]
    fn fleet_construction_both_kinds() {
        let mut e = Experiment::default();
        let mut rng = Pcg::seeded(1);
        assert_eq!(e.fleet(&mut rng).len(), 6);
        e.gpu = true;
        assert_eq!(e.fleet(&mut rng).len(), 6);
    }

    #[test]
    fn fleet_with_matches_flat_fleet_for_one_cell() {
        // one RNG stream, one cell covering the fleet: identical devices
        let e = Experiment::default();
        let mut a = Pcg::seeded(4);
        let mut b = Pcg::seeded(4);
        let flat = e.fleet(&mut a);
        let cell = e.fleet_with(e.k, e.cell.split_bandwidth(1), &mut b);
        assert_eq!(flat.len(), cell.len());
        for (x, y) in flat.iter().zip(&cell) {
            assert_eq!(x.id, y.id);
            assert_eq!(x.compute, y.compute);
            assert_eq!(x.link.dist_m.to_bits(), y.link.dist_m.to_bits());
        }
    }

    #[test]
    fn dirichlet_partition_from_config_and_defaults() {
        let c = Config::parse("[data]\npartition = \"dirichlet:0.3\"").unwrap();
        let e = Experiment::from_config(&c).unwrap();
        assert_eq!(e.partition, Partition::Dirichlet { alpha: 0.3 });
        let c = Config::parse("[data]\npartition = \"dirichlet\"").unwrap();
        let e = Experiment::from_config(&c).unwrap();
        assert_eq!(e.partition, Partition::Dirichlet { alpha: 0.5 });
        let c = Config::parse("[data]\npartition = \"dirichlet:-2\"").unwrap();
        assert!(Experiment::from_config(&c).is_err());
    }

    #[test]
    fn topology_keys_parse_and_validate() {
        // defaults: flat single cell
        let e = Experiment::from_config(&Config::parse("").unwrap()).unwrap();
        assert_eq!((e.cells, e.tau), (1, 1));
        assert!(e.cell_policies.is_empty());
        let src = r#"
[fleet]
k = 12
[topology]
cells = 3
tau = 4
policies = ["sync", "deadline", "async"]
"#;
        let e = Experiment::from_config(&Config::parse(src).unwrap()).unwrap();
        assert_eq!((e.cells, e.tau), (3, 4));
        assert_eq!(e.cell_policies.len(), 3);
        assert_eq!(e.cell_policies[0], RoundPolicy::Sync);
        assert_eq!(e.cell_policies[1], RoundPolicy::Deadline { factor: 1.25 });
        assert!(matches!(e.cell_policies[2], RoundPolicy::Async { .. }));
        // resolution: a cell naming the base policy inherits its knobs
        let src = r#"
[fleet]
k = 6
[train]
policy = "deadline"
deadline_factor = 1.7
[topology]
cells = 2
policies = ["deadline", "sync"]
"#;
        let e = Experiment::from_config(&Config::parse(src).unwrap()).unwrap();
        let resolved = e.resolved_cell_policies();
        assert_eq!(resolved[0], RoundPolicy::Deadline { factor: 1.7 });
        assert_eq!(resolved[1], RoundPolicy::Sync);
        // no overrides: every cell runs the base policy
        let src = "[fleet]\nk = 6\n[topology]\ncells = 3";
        let e = Experiment::from_config(&Config::parse(src).unwrap()).unwrap();
        assert_eq!(e.resolved_cell_policies(), vec![RoundPolicy::Sync; 3]);
    }

    #[test]
    fn sampling_keys_parse_and_validate() {
        // defaults: full participation at both levels
        let e = Experiment::from_config(&Config::parse("").unwrap()).unwrap();
        assert_eq!(e.trainer.sample_frac, 1.0);
        assert_eq!(e.cell_frac, 1.0);
        let src = "[fleet]\nk = 12\nsample_frac = 0.25\n[topology]\ncells = 2\ncell_frac = 0.5";
        let e = Experiment::from_config(&Config::parse(src).unwrap()).unwrap();
        assert_eq!(e.trainer.sample_frac, 0.25);
        assert_eq!(e.cell_frac, 0.5);
        // out-of-range fractions fail at parse time
        assert!(topo_err("[fleet]\nsample_frac = 0.0").contains("sample_frac"));
        assert!(topo_err("[fleet]\nsample_frac = 1.5").contains("sample_frac"));
        let src = "[fleet]\nk = 6\n[topology]\ncells = 2\ncell_frac = 0.0";
        assert!(topo_err(src).contains("cell_frac"));
        let src = "[fleet]\nk = 6\n[topology]\ncells = 2\ncell_frac = 2.0";
        assert!(topo_err(src).contains("cell_frac"));
        // cell sampling on a flat run is an error, not a no-op
        let err = topo_err("[topology]\ncell_frac = 0.5");
        assert!(err.contains("multi-cell"), "{err}");
    }

    fn topo_err(src: &str) -> String {
        Experiment::from_config(&Config::parse(src).unwrap())
            .unwrap_err()
            .to_string()
    }

    #[test]
    fn fault_keys_parse_and_validate() {
        // defaults: no faults, quarantine off
        let e = Experiment::from_config(&Config::parse("").unwrap()).unwrap();
        assert_eq!(e.trainer.fault, FaultPlan::none());
        assert_eq!(e.trainer.guard, GradGuard::off());
        // the full table parses into the trainer config
        let src = r#"
[fleet]
k = 6
[fault]
crash_rate = 0.05
crash_len = 3
corrupt_rate = 0.1
corrupt_noise = 2.0
quarantine = "reject"
max_norm = 50.0
"#;
        let e = Experiment::from_config(&Config::parse(src).unwrap()).unwrap();
        assert_eq!(e.trainer.fault.crash_rate, 0.05);
        assert_eq!(e.trainer.fault.crash_len, 3);
        assert_eq!(e.trainer.fault.corrupt_rate, 0.1);
        assert_eq!(e.trainer.fault.corrupt_noise, 2.0);
        assert_eq!(e.trainer.guard.policy, Quarantine::Reject);
        assert_eq!(e.trainer.guard.max_norm, 50.0);
        // a knob for a fault class whose rate is zero is an error
        let err = topo_err("[fault]\ncrash_len = 3");
        assert!(err.contains("crash_rate > 0"), "{err}");
        let err = topo_err("[fault]\ncorrupt_noise = 2.0");
        assert!(err.contains("corrupt_rate > 0"), "{err}");
        // rates are range-checked at parse time
        assert!(topo_err("[fault]\ncrash_rate = 1.5").contains("[0, 1)"));
        assert!(topo_err("[fault]\ncorrupt_rate = -0.1").contains("[0, 1)"));
        assert!(topo_err("[fault]\ncrash_rate = 0.1\ncrash_len = 0").contains(">= 1"));
        // quarantine names are validated with the accepted list printed
        let err = topo_err("[fault]\nquarantine = \"fifo\"");
        assert!(err.contains("off | reject | clip | abort"), "{err}");
        let err = topo_err("[fault]\nquarantine = 7");
        assert!(err.contains("policy-name"), "{err}");
        assert!(topo_err("[fault]\nmax_norm = 0.0").contains("> 0"));
        // max_norm alone is detection-only observability, not an error
        let e = Experiment::from_config(&Config::parse("[fault]\nmax_norm = 9.0").unwrap());
        let e = e.unwrap();
        assert_eq!(e.trainer.guard.policy, Quarantine::Off);
        assert!(e.trainer.guard.checks_norm());
        // cell outage needs a multi-cell topology
        let err = topo_err("[fault]\noutage_rate = 0.2");
        assert!(err.contains("multi-cell"), "{err}");
        let src = "[fleet]\nk = 6\n[fault]\noutage_rate = 0.2\n[topology]\ncells = 2";
        let e = Experiment::from_config(&Config::parse(src).unwrap()).unwrap();
        assert_eq!(e.trainer.fault.outage_rate, 0.2);
    }

    #[test]
    fn topology_validation_rejects_bad_shapes() {
        assert!(topo_err("[topology]\ncells = 0").contains("cells must be >= 1"));
        assert!(topo_err("[topology]\ncells = 2\ntau = 0").contains("tau must be >= 1"));
        // more cells than devices
        let err = topo_err("[fleet]\nk = 2\n[topology]\ncells = 3");
        assert!(err.contains("every cell needs a device"), "{err}");
        // topology knobs without a multi-cell run are errors, not no-ops
        assert!(topo_err("[topology]\ntau = 4").contains("multi-cell"));
        assert!(topo_err("[topology]\npolicies = [\"sync\"]").contains("multi-cell"));
        // policy-list shape and contents
        let src = "[fleet]\nk = 6\n[topology]\ncells = 3\npolicies = [\"sync\", \"async\"]";
        assert!(topo_err(src).contains("one per cell"));
        let src = "[fleet]\nk = 6\n[topology]\ncells = 2\npolicies = [\"fifo\", \"sync\"]";
        assert!(topo_err(src).contains("fifo"));
        let src = "[fleet]\nk = 6\n[topology]\ncells = 2\npolicies = [7, 8]";
        assert!(topo_err(src).contains("policy-name"));
        let src = "[fleet]\nk = 6\n[topology]\ncells = 2\npolicies = \"sync\"";
        assert!(topo_err(src).contains("array"));
        // a backend rule valid for the flat fleet can starve once the
        // fleet splits into cells: k = 4 occupies tier 2 flat, but each
        // 2-device cell only occupies tiers 0-1
        let src = "[fleet]\nk = 4\nbackends = [{tier = 2, model = \"mini_dense\"}]\n\
                   [topology]\ncells = 2";
        let err = topo_err(src);
        assert!(err.contains("splits into 2"), "{err}");
        // ...and the same rule is fine once every cell reaches the tier
        let src = "[fleet]\nk = 6\nbackends = [{tier = 2, model = \"mini_dense\"}]\n\
                   [topology]\ncells = 2";
        assert!(Experiment::from_config(&Config::parse(src).unwrap()).is_ok());
        // the CLI shorthand parses to the same overrides
        let cli = parse_cell_policies_spec("sync,deadline,async").unwrap();
        assert_eq!(cli.len(), 3);
        assert_eq!(cli[0], RoundPolicy::Sync);
        assert!(parse_cell_policies_spec("").is_err());
        assert!(parse_cell_policies_spec("sync,,async").is_err());
        assert!(parse_cell_policies_spec("sync,fifo").is_err());
    }
}
