//! Experiment configuration schema: maps a parsed TOML-subset `Config`
//! onto the concrete simulation objects (fleet, data, trainer settings).

use anyhow::{bail, Result};

use super::toml::{Config, Value};
use crate::coordinator::{Scheme, TrainerConfig};
use crate::data::{Partition, SynthConfig};
use crate::device::{
    paper_cpu_fleet, paper_gpu_fleet, Device, GpuModule, StragglerModel, CPU_TIER_COUNT,
};
use crate::opt::BatchPolicy;
use crate::sched::{RoundPolicy, POLICY_NAMES};
use crate::util::rng::Pcg;
use crate::wireless::CellConfig;

/// Accepted `--scheme` / `train.scheme` values (keep in sync with
/// [`parse_scheme`]; the CLI help and error paths print this).
pub const SCHEME_NAMES: &str =
    "proposed | gradient_fl | model_fl | individual | online | full_batch | random_batch";

/// One per-tier backend rule: devices of CPU speed tier `tier` train
/// `model` on `backend` (`host` | `pjrt`; `None` = the run's `--backend`
/// kind). Configured as `fleet.backends = [{tier = 0, model =
/// "mini_dense", backend = "host"}, ...]` or the CLI shorthand
/// `--backends 0:mini_dense:host,1:mini_res`. Tiers without a rule fall
/// back to the experiment's default `model`.
#[derive(Clone, Debug, PartialEq)]
pub struct TierBackend {
    pub tier: usize,
    pub model: String,
    pub backend: Option<String>,
}

/// Fully-resolved experiment description.
#[derive(Clone, Debug)]
pub struct Experiment {
    pub name: String,
    pub model: String,
    pub k: usize,
    pub partition: Partition,
    pub gpu: bool,
    pub periods: usize,
    pub train_n: usize,
    pub test_n: usize,
    pub synth: SynthConfig,
    pub cell: CellConfig,
    pub shadow_sigma_db: f64,
    pub shadow_rho: f64,
    pub cycles_per_sample: f64,
    pub cycles_per_update: f64,
    pub gpu_module: GpuModule,
    pub trainer: TrainerConfig,
    /// per-tier backend rules (empty = homogeneous fleet on `model`)
    pub backends: Vec<TierBackend>,
}

impl Default for Experiment {
    fn default() -> Self {
        Experiment {
            name: "default".into(),
            model: "mini_res".into(),
            k: 6,
            partition: Partition::Iid,
            gpu: false,
            periods: 200,
            train_n: 6000,
            test_n: 1024,
            synth: SynthConfig::default(),
            cell: CellConfig::default(),
            shadow_sigma_db: 4.0,
            shadow_rho: 0.7,
            cycles_per_sample: 7e7,
            cycles_per_update: 1e8,
            gpu_module: GpuModule::new(0.110, 2.4e-3, 24.0, 2.0e9, 1.0e13),
            trainer: TrainerConfig::default(),
            backends: Vec::new(),
        }
    }
}

impl Experiment {
    /// Resolve from a parsed config file (missing keys keep defaults).
    pub fn from_config(c: &Config) -> Result<Experiment> {
        let mut e = Experiment::default();
        e.name = c.str_or("name", &e.name).to_string();
        e.model = c.str_or("model", &e.model).to_string();
        e.k = c.usize_or("fleet.k", e.k);
        if e.k == 0 {
            bail!("fleet.k must be >= 1");
        }
        e.partition = match c.str_or("data.partition", "iid") {
            s => Partition::parse(s).ok_or_else(|| anyhow::anyhow!("bad data.partition {s:?}"))?,
        };
        e.gpu = c.bool_or("fleet.gpu", e.gpu);
        e.periods = c.usize_or("train.periods", e.periods);
        e.train_n = c.usize_or("data.train_n", e.train_n);
        e.test_n = c.usize_or("data.test_n", e.test_n);
        e.synth.dim = c.usize_or("data.dim", e.synth.dim);
        e.synth.classes = c.usize_or("data.classes", e.synth.classes);
        e.shadow_sigma_db = c.f64_or("channel.shadow_sigma_db", e.shadow_sigma_db);
        e.shadow_rho = c.f64_or("channel.shadow_rho", e.shadow_rho);
        e.cell.radius_m = c.f64_or("channel.radius_m", e.cell.radius_m);
        e.cell.bandwidth_hz = c.f64_or("channel.bandwidth_hz", e.cell.bandwidth_hz);
        e.cycles_per_sample = c.f64_or("fleet.cycles_per_sample", e.cycles_per_sample);
        e.cycles_per_update = c.f64_or("fleet.cycles_per_update", e.cycles_per_update);

        let t = &mut e.trainer;
        t.b_max = c.usize_or("train.b_max", t.b_max);
        t.base_lr = c.f64_or("train.lr", t.base_lr);
        t.eval_every = c.usize_or("train.eval_every", t.eval_every);
        t.threads = c.usize_or("train.threads", t.threads);
        t.seed = c.usize_or("train.seed", t.seed as usize) as u64;
        t.wire_ratio = c.f64_or("compress.wire_ratio", t.wire_ratio);
        t.quant_bits = c.usize_or("compress.quant_bits", t.quant_bits as usize) as u32;
        if c.bool_or("compress.sbc", true) {
            t.sbc_keep = Some(c.f64_or("compress.keep_frac", 0.005));
        } else {
            t.sbc_keep = None;
        }
        t.scheme = parse_scheme(c.str_or("train.scheme", "proposed"), t.b_max)?;
        t.policy = parse_policy_config(c)?;
        t.straggler = StragglerModel::new(
            c.f64_or("fleet.jitter", t.straggler.jitter),
            c.f64_or("fleet.dropout", t.straggler.dropout),
        )?;
        if let Some(v) = c.get("fleet.backends") {
            e.backends = parse_backend_rules(v)?;
            e.check_backend_tiers()?;
        }
        Ok(e)
    }

    /// Number of device tiers this experiment's fleet has: the paper's
    /// three CPU speed tiers, or one for the identical-GPU fleet.
    pub fn tier_count(&self) -> usize {
        if self.gpu {
            1
        } else {
            CPU_TIER_COUNT
        }
    }

    /// The tier device `id` belongs to (matches `paper_cpu_fleet`'s
    /// round-robin frequency assignment).
    pub fn tier_of(&self, id: usize) -> usize {
        id % self.tier_count()
    }

    /// Validate the per-tier backend rules against this experiment's
    /// fleet shape. Call after any mutation of `backends`, `gpu`, or `k`
    /// (the CLI does, after applying flag overrides). A rule for a tier
    /// no device occupies is an error, not a no-op — silently dropping it
    /// would run a different (homogeneous) experiment than the config
    /// describes.
    pub fn check_backend_tiers(&self) -> Result<()> {
        let tiers = self.tier_count();
        // round-robin assignment: tier t is occupied iff t < min(k, tiers)
        let occupied = tiers.min(self.k);
        for (i, r) in self.backends.iter().enumerate() {
            if r.tier >= tiers {
                bail!(
                    "fleet.backends tier {} out of range (this fleet has {} tiers)",
                    r.tier,
                    tiers
                );
            }
            if r.tier >= occupied {
                bail!(
                    "fleet.backends tier {} has no devices (fleet.k = {})",
                    r.tier,
                    self.k
                );
            }
            if self.backends[..i].iter().any(|o| o.tier == r.tier) {
                bail!("fleet.backends has two rules for tier {}", r.tier);
            }
        }
        Ok(())
    }

    /// Build the device fleet this experiment describes.
    pub fn fleet(&self, rng: &mut Pcg) -> Vec<Device> {
        if self.gpu {
            paper_gpu_fleet(
                self.k,
                self.gpu_module,
                self.cell,
                self.shadow_sigma_db,
                self.shadow_rho,
                rng,
            )
        } else {
            paper_cpu_fleet(
                self.k,
                self.cycles_per_sample,
                self.cycles_per_update,
                self.cell,
                self.shadow_sigma_db,
                self.shadow_rho,
                rng,
            )
        }
    }
}

/// Parse a scheme name as used in configs and on the CLI.
pub fn parse_scheme(s: &str, b_max: usize) -> Result<Scheme> {
    Ok(match s {
        "proposed" => Scheme::Proposed,
        "gradient_fl" | "gradient" => Scheme::GradientFl,
        "model_fl" | "fedavg" => Scheme::ModelFl { local_batch: 32 },
        "individual" => Scheme::Individual { local_batch: b_max },
        "online" => Scheme::Fixed { policy: BatchPolicy::Online, optimal_slots: true },
        "full_batch" | "full" => Scheme::Fixed { policy: BatchPolicy::Full, optimal_slots: true },
        "random_batch" | "random" => {
            Scheme::Fixed { policy: BatchPolicy::Random, optimal_slots: true }
        }
        other => bail!("unknown scheme {other:?} (accepted: {SCHEME_NAMES})"),
    })
}

/// Parse a round-policy name as used in configs and on the CLI.
pub fn parse_policy(s: &str) -> Result<RoundPolicy> {
    RoundPolicy::parse(s)
        .ok_or_else(|| anyhow::anyhow!("unknown policy {s:?} (accepted: {POLICY_NAMES})"))
}

/// Parse the `fleet.backends` config value: an array of inline tables
/// `{tier = N, model = "name", backend = "host"|"pjrt"}` (backend
/// optional — defaults to the run's `--backend` kind).
pub fn parse_backend_rules(v: &Value) -> Result<Vec<TierBackend>> {
    let Some(arr) = v.as_arr() else {
        bail!("fleet.backends wants an array of {{tier, model, backend}} tables");
    };
    let mut rules = Vec::with_capacity(arr.len());
    for item in arr {
        let Some(t) = item.as_table() else {
            bail!("fleet.backends entries want {{tier, model, backend}} tables");
        };
        for key in t.keys() {
            if !matches!(key.as_str(), "tier" | "model" | "backend") {
                bail!("fleet.backends entry has unknown key {key:?}");
            }
        }
        let Some(tier) = t.get("tier").and_then(|x| x.as_usize()) else {
            bail!("fleet.backends entry wants an integer tier");
        };
        let Some(model) = t.get("model").and_then(|x| x.as_str()) else {
            bail!("fleet.backends entry wants a string model");
        };
        let backend = match t.get("backend") {
            None => None,
            Some(b) => match b.as_str() {
                Some(s) => Some(s.to_string()),
                None => bail!("fleet.backends backend wants a string"),
            },
        };
        rules.push(TierBackend { tier, model: model.to_string(), backend });
    }
    Ok(rules)
}

/// Parse the CLI `--backends` shorthand: comma-separated
/// `tier:model[:backend]` rules, e.g. `0:mini_dense,1:mini_res:host`.
pub fn parse_backends_spec(spec: &str) -> Result<Vec<TierBackend>> {
    let mut rules = Vec::new();
    for part in spec.split(',') {
        let part = part.trim();
        if part.is_empty() {
            bail!("--backends has an empty rule (format: tier:model[:backend],...)");
        }
        let fields: Vec<&str> = part.split(':').collect();
        if !(2..=3).contains(&fields.len()) {
            bail!("--backends rule {part:?} wants tier:model[:backend]");
        }
        let tier: usize = fields[0]
            .parse()
            .map_err(|_| anyhow::anyhow!("--backends rule {part:?}: bad tier {:?}", fields[0]))?;
        if fields[1].is_empty() {
            bail!("--backends rule {part:?} wants a model name");
        }
        rules.push(TierBackend {
            tier,
            model: fields[1].to_string(),
            backend: fields.get(2).map(|s| s.to_string()),
        });
    }
    Ok(rules)
}

/// Resolve `train.policy` and its knobs (`train.deadline_factor`,
/// `train.async_alpha`, `train.async_beta`, `train.quorum`), validating
/// at parse time instead of deep inside the trainer.
fn parse_policy_config(c: &Config) -> Result<RoundPolicy> {
    let mut p = parse_policy(c.str_or("train.policy", "sync"))?;
    // a knob for a different policy is a mistake, not a no-op — silently
    // ignoring `train.quorum` under sync would run a different experiment
    // than the config describes (knob table: `RoundPolicy::ALL_KNOBS`)
    for knob in RoundPolicy::ALL_KNOBS {
        let key = format!("train.{knob}");
        if c.get(&key).is_some() && !p.knob_names().contains(knob) {
            bail!("config key {key} does not apply to train.policy = {:?}", p.name());
        }
    }
    match &mut p {
        RoundPolicy::Sync => {}
        RoundPolicy::Deadline { factor } => {
            *factor = c.f64_or("train.deadline_factor", *factor);
        }
        RoundPolicy::Async { alpha, beta, quorum } => {
            *alpha = c.f64_or("train.async_alpha", *alpha);
            *beta = c.f64_or("train.async_beta", *beta);
            *quorum = c.f64_or("train.quorum", *quorum);
        }
    }
    p.validate()?;
    Ok(p)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_roundtrip() {
        let c = Config::parse("").unwrap();
        let e = Experiment::from_config(&c).unwrap();
        assert_eq!(e.k, 6);
        assert_eq!(e.model, "mini_res");
        assert_eq!(e.partition, Partition::Iid);
    }

    #[test]
    fn full_config() {
        let src = r#"
name = "gpu_run"
model = "mini_dense"
[fleet]
k = 12
gpu = true
[data]
partition = "non-iid"
train_n = 2400
[train]
scheme = "online"
lr = 0.2
periods = 50
threads = 8
[compress]
sbc = false
"#;
        let e = Experiment::from_config(&Config::parse(src).unwrap()).unwrap();
        assert_eq!(e.k, 12);
        assert!(e.gpu);
        assert_eq!(e.partition, Partition::NonIid);
        assert_eq!(e.trainer.base_lr, 0.2);
        assert_eq!(e.trainer.threads, 8);
        assert!(e.trainer.sbc_keep.is_none());
        assert!(matches!(e.trainer.scheme, Scheme::Fixed { .. }));
    }

    #[test]
    fn rejects_bad_scheme_and_partition() {
        let c = Config::parse("[train]\nscheme = \"sgd\"").unwrap();
        let err = Experiment::from_config(&c).unwrap_err().to_string();
        assert!(err.contains("proposed") && err.contains("random_batch"), "{err}");
        let c = Config::parse("[data]\npartition = \"skewed\"").unwrap();
        assert!(Experiment::from_config(&c).is_err());
    }

    #[test]
    fn policy_and_straggler_keys() {
        // defaults: sync barrier, no perturbation
        let e = Experiment::from_config(&Config::parse("").unwrap()).unwrap();
        assert!(e.trainer.policy.is_sync());
        assert!(!e.trainer.straggler.is_active());
        // deadline with a custom factor + straggler knobs
        let src = r#"
[fleet]
jitter = 0.4
dropout = 0.1
[train]
policy = "deadline"
deadline_factor = 1.3
"#;
        let e = Experiment::from_config(&Config::parse(src).unwrap()).unwrap();
        assert_eq!(e.trainer.policy, RoundPolicy::Deadline { factor: 1.3 });
        assert_eq!(e.trainer.straggler, StragglerModel { jitter: 0.4, dropout: 0.1 });
        // async knobs
        let src = r#"
[train]
policy = "async"
async_alpha = 0.8
async_beta = 1.0
quorum = 0.25
"#;
        let e = Experiment::from_config(&Config::parse(src).unwrap()).unwrap();
        assert_eq!(
            e.trainer.policy,
            RoundPolicy::Async { alpha: 0.8, beta: 1.0, quorum: 0.25 }
        );
    }

    #[test]
    fn bad_policy_values_fail_at_parse_with_accepted_list() {
        let c = Config::parse("[train]\npolicy = \"fifo\"").unwrap();
        let err = Experiment::from_config(&c).unwrap_err().to_string();
        assert!(err.contains("sync | deadline | async"), "{err}");
        // knob validation happens at parse time, not deep in the trainer
        let c = Config::parse("[train]\npolicy = \"deadline\"\ndeadline_factor = 0.5").unwrap();
        assert!(Experiment::from_config(&c).is_err());
        let c = Config::parse("[train]\npolicy = \"async\"\nquorum = 2.0").unwrap();
        assert!(Experiment::from_config(&c).is_err());
        let c = Config::parse("[fleet]\ndropout = 1.5").unwrap();
        assert!(Experiment::from_config(&c).is_err());
        // a knob for a policy that is not active is an error, not a no-op
        let c = Config::parse("[train]\nquorum = 0.5").unwrap();
        let err = Experiment::from_config(&c).unwrap_err().to_string();
        assert!(err.contains("does not apply"), "{err}");
        let c = Config::parse("[train]\npolicy = \"deadline\"\nasync_alpha = 0.5").unwrap();
        assert!(Experiment::from_config(&c).is_err());
        let c = Config::parse("[train]\npolicy = \"async\"\ndeadline_factor = 1.5").unwrap();
        assert!(Experiment::from_config(&c).is_err());
    }

    #[test]
    fn backend_rules_from_config_and_cli() {
        // defaults: no rules, homogeneous
        let e = Experiment::from_config(&Config::parse("").unwrap()).unwrap();
        assert!(e.backends.is_empty());
        let src = r#"
[fleet]
k = 6
backends = [{tier = 0, model = "mini_dense"}, {tier = 1, model = "mini_res", backend = "host"}]
"#;
        let e = Experiment::from_config(&Config::parse(src).unwrap()).unwrap();
        assert_eq!(e.backends.len(), 2);
        assert_eq!(
            e.backends[0],
            TierBackend { tier: 0, model: "mini_dense".into(), backend: None }
        );
        assert_eq!(
            e.backends[1],
            TierBackend { tier: 1, model: "mini_res".into(), backend: Some("host".into()) }
        );
        assert_eq!(e.tier_count(), 3);
        assert_eq!(e.tier_of(4), 1);
        // the CLI shorthand parses to the same rules
        let cli = parse_backends_spec("0:mini_dense,1:mini_res:host").unwrap();
        assert_eq!(cli, e.backends);
        // malformed shorthand rules are clean errors
        assert!(parse_backends_spec("").is_err());
        assert!(parse_backends_spec("0").is_err());
        assert!(parse_backends_spec("x:mini_dense").is_err());
        assert!(parse_backends_spec("0:").is_err());
        assert!(parse_backends_spec("0:m:host:extra").is_err());
    }

    #[test]
    fn backend_rules_validate_tiers() {
        // tier out of range for a CPU fleet (3 tiers)
        let src = "[fleet]\nbackends = [{tier = 3, model = \"mini_res\"}]";
        let err = Experiment::from_config(&Config::parse(src).unwrap())
            .unwrap_err()
            .to_string();
        assert!(err.contains("out of range"), "{err}");
        // duplicate tier rules
        let src = "[fleet]\nbackends = [{tier = 0, model = \"a\"}, {tier = 0, model = \"b\"}]";
        let err = Experiment::from_config(&Config::parse(src).unwrap())
            .unwrap_err()
            .to_string();
        assert!(err.contains("two rules"), "{err}");
        // gpu fleets have a single tier
        let src = "[fleet]\ngpu = true\nbackends = [{tier = 1, model = \"mini_res\"}]";
        assert!(Experiment::from_config(&Config::parse(src).unwrap()).is_err());
        // a rule for a tier no device occupies is an error, not a no-op
        let src = "[fleet]\nk = 2\nbackends = [{tier = 2, model = \"mini_dense\"}]";
        let err = Experiment::from_config(&Config::parse(src).unwrap())
            .unwrap_err()
            .to_string();
        assert!(err.contains("no devices"), "{err}");
        // ...but the same rule is fine once the fleet reaches the tier
        let src = "[fleet]\nk = 3\nbackends = [{tier = 2, model = \"mini_dense\"}]";
        assert!(Experiment::from_config(&Config::parse(src).unwrap()).is_ok());
        // malformed entries
        for bad in [
            "[fleet]\nbackends = [{model = \"m\"}]",
            "[fleet]\nbackends = [{tier = 0}]",
            "[fleet]\nbackends = [{tier = 0, model = \"m\", extra = 1}]",
            "[fleet]\nbackends = [7]",
            "[fleet]\nbackends = 7",
        ] {
            assert!(
                Experiment::from_config(&Config::parse(bad).unwrap()).is_err(),
                "{bad}"
            );
        }
    }

    #[test]
    fn fleet_construction_both_kinds() {
        let mut e = Experiment::default();
        let mut rng = Pcg::seeded(1);
        assert_eq!(e.fleet(&mut rng).len(), 6);
        e.gpu = true;
        assert_eq!(e.fleet(&mut rng).len(), 6);
    }
}
