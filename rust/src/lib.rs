//! `feel` — Federated Edge Learning acceleration library.
//!
//! Rust+JAX+Pallas reproduction of *"Accelerating DNN Training in Wireless
//! Federated Edge Learning Systems"* (Ren, Yu, Ding; 2019): joint training
//! batchsize selection and TDMA communication resource allocation that
//! maximizes the paper's learning-efficiency criterion `E = ΔL / T`.
//!
//! Architecture (DESIGN.md): this crate is layer 3 — the coordinator, the
//! wireless/device simulators, the paper's optimizer, and the PJRT runtime
//! that executes the AOT-compiled JAX/Pallas computations in `artifacts/`.
//! Python only runs at build time (`make artifacts`).

// Style decisions the codebase makes deliberately (index-loop GEMM kernels,
// config structs built by field assignment from Default) — kept out of
// clippy's way so CI can run with -D warnings.
#![allow(clippy::needless_range_loop)]
#![allow(clippy::field_reassign_with_default)]
#![allow(clippy::too_many_arguments)]

pub mod analysis;
pub mod benchkit;
pub mod cli;
pub mod compress;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod device;
pub mod exec;
pub mod exp;
pub mod fault;
pub mod grad;
pub mod hier;
pub mod metrics;
pub mod obs;
pub mod opt;
pub mod runtime;
pub mod sched;
pub mod testkit;
pub mod util;
pub mod wireless;
