//! `feel` — Federated Edge Learning acceleration library.
//!
//! Rust+JAX+Pallas reproduction of *"Accelerating DNN Training in Wireless
//! Federated Edge Learning Systems"* (Ren, Yu, Ding; 2019): joint training
//! batchsize selection and TDMA communication resource allocation that
//! maximizes the paper's learning-efficiency criterion `E = ΔL / T`.
//!
//! Architecture (DESIGN.md): this crate is layer 3 — the coordinator, the
//! wireless/device simulators, the paper's optimizer, and the PJRT runtime
//! that executes the AOT-compiled JAX/Pallas computations in `artifacts/`.
//! Python only runs at build time (`make artifacts`).

pub mod benchkit;
pub mod cli;
pub mod compress;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod device;
pub mod exp;
pub mod grad;
pub mod metrics;
pub mod opt;
pub mod runtime;
pub mod testkit;
pub mod util;
pub mod wireless;
