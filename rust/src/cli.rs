//! `feel` command-line interface (hand-rolled; clap is unavailable offline).
//!
//! Subcommands:
//!   train        — run a training experiment from a config file / flags
//!   optimize     — solve one period's allocation problem and print it
//!   channel      — dump channel-rate statistics for a sampled fleet
//!   fit-gpu      — profile + fit the GPU training function
//!   experiment   — regenerate a paper table/figure: fig2 fig3 table2 fig4 fig5
//!   report       — summarize a --metrics-out JSONL dump into a table
//!   audit        — summarize an --audit JSONL ledger: learning efficiency,
//!                  predicted-vs-realized regret, bandwidth utilization
//!   bench-merge  — fold per-bench BENCH_*.json files into BENCH_trajectory.json
//!                  and (optionally) gate on a committed baseline
//!   lint         — static-analysis pass for the determinism contracts R1–R6
//!
//! Common flags: --config <path>, --out <dir>, --backend host|pjrt,
//! --periods N, --k N, --scheme NAME, --partition iid|noniid, --seed N,
//! --threads N (worker threads for device fan-out + large GEMMs; 0 = all
//! cores; numerics are identical at any value), --policy NAME plus the
//! straggler knobs --jitter/--dropout and the per-policy knobs
//! --deadline-factor / --async-alpha / --async-beta / --quorum, and
//! --backends tier:model[:backend],... for heterogeneous fleets (see
//! `coordinator::fleet_backends`).

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::config::{
    parse_backends_spec, parse_cell_policies_spec, parse_policy, parse_scheme, Config, Experiment,
};
use crate::coordinator::Trainer;
use crate::device::{paper_profiles, StragglerModel};
use crate::fault::FaultPlan;
use crate::grad::{GradGuard, Quarantine, QUARANTINE_NAMES};
use crate::sched::RoundPolicy;
use crate::exp::common::{
    make_data, make_fleet_backends, run_hier_scheme_traced, BackendKind,
};
use crate::exp::{fig2, fig3, fig45, table2};
use crate::metrics::Recorder;
use crate::opt;
use crate::opt::types::Instance;
use crate::util::rng::Pcg;
use crate::util::stats::fit_piecewise;
use crate::wireless::PeriodRates;

/// Parsed command line: subcommand + flags + positionals.
#[derive(Debug, Default)]
pub struct Args {
    pub cmd: String,
    pub flags: BTreeMap<String, String>,
    pub positional: Vec<String>,
}

impl Args {
    pub fn parse(argv: &[String]) -> Result<Args> {
        let mut out = Args::default();
        let mut it = argv.iter().peekable();
        out.cmd = it.next().cloned().unwrap_or_else(|| "help".into());
        while let Some(a) = it.next() {
            if let Some(name) = a.strip_prefix("--") {
                let val = match it.next_if(|v| !v.starts_with("--")) {
                    Some(v) => v.clone(),
                    None => "true".to_string(),
                };
                out.flags.insert(name.to_string(), val);
            } else {
                out.positional.push(a.clone());
            }
        }
        Ok(out)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(|s| s.as_str())
    }

    pub fn usize_or(&self, name: &str, default: usize) -> Result<usize> {
        match self.get(name) {
            Some(v) => v.parse().with_context(|| format!("--{name} wants an integer")),
            None => Ok(default),
        }
    }

    pub fn f64_or(&self, name: &str, default: f64) -> Result<f64> {
        match self.get(name) {
            Some(v) => v.parse().with_context(|| format!("--{name} wants a number")),
            None => Ok(default),
        }
    }
}

const HELP: &str = "feel — wireless federated edge learning accelerator (paper reproduction)

USAGE: feel <command> [flags]

COMMANDS:
  train       run a FEEL training experiment
              --config <file>  --backend host|pjrt  --periods N
              --scheme proposed|gradient_fl|model_fl|individual|online|full_batch|random_batch
              --backends tier:model[:backend],...   heterogeneous fleet: route
                         each CPU speed tier (0|1|2; device tier = id mod 3) to
                         its own model family / backend, e.g.
                         0:mini_dense,1:mini_res — uncovered tiers use --model;
                         config form: fleet.backends = [{tier, model, backend}]
              --policy sync|deadline|async   how gradient rounds close:
                sync     barrier on the slowest device (paper default)
                deadline drop devices past --deadline-factor x the nominal
                         makespan (>= 1, default 1.25); re-plan them next period
                async    close at a --quorum fraction of arrivals (default 0.5);
                         stale gradients weighted alpha/(1+s)^beta via
                         --async-alpha (default 0.6) / --async-beta (default 0.5)
              --jitter F  --dropout F   straggler model: per-device latency
                         jitter amplitude and per-period failure probability
              --cells C  --tau N   hierarchical topology: C cells, each an
                         edge server on an even share of the band with its
                         own contiguous device slice, data shard, and
                         scheduler; a cloud aggregator FedAvg-merges the
                         edge models (sample-count weighted) every N edge
                         rounds. C=1 (default) is the flat trainer
              --cell-policies name,name,...   per-cell round policies
                         (one per cell; default: --policy everywhere)
              --sample-frac F   per-round client sampling: each gradient
                         round draws a Bernoulli(F) device subset from a
                         counter-derived stream and reweights by 1/F
                         (Horvitz-Thompson), so the sampled estimate is
                         unbiased for the full round. 1.0 (default) is
                         full participation, bitwise-identical to the
                         unsampled trainer. Gradient-exchange schemes only
              --cell-frac F   per-block cell sampling for hierarchical
                         runs: each tau-block runs a Bernoulli(F) subset
                         of cells; the cloud merge reweights by 1/F and
                         pushes the merged model to every cell
              --crash-rate F  --crash-len N   seeded fault injection:
                         each period each device crashes with prob F,
                         staying down 1..=N periods (uniform) and
                         rejoining cold (carry ledger wiped) or warm
              --corrupt-rate F  --corrupt-noise A   corrupt a device's
                         gradient upload with prob F per period: NaN/Inf
                         terms, or noise at amplitude A x payload RMS
                         when A > 0
              --outage-rate F   hierarchical cell outage: each tau-block
                         each cell goes dark with prob F — it neither
                         contributes to nor receives that cloud merge,
                         rejoining later with its stale edge model
              --quarantine off|reject|clip|abort   server-side screening
                         of non-finite / norm-outlier gradients; counts
                         land in the crashed/corrupt/quarantined CSV
                         columns. --max-norm F bounds the L2 norm
                         (detection-only when the policy is off)
              --checkpoint FILE  --checkpoint-every N   save the full
                         trainer state (versioned + checksummed) every N
                         periods (hier: every N tau-blocks) and at run
                         end
              --resume FILE   restore state from a checkpoint and keep
                         training — bitwise-identical continuation of
                         the interrupted run
              --trace FILE   write the run's event trace as Chrome
                         trace-event JSON (open in chrome://tracing or
                         https://ui.perfetto.dev): one process lane per
                         cell plus a cloud lane, one thread row per
                         device, spans for rounds and instants for
                         crashes/drops/deadline misses/quarantine
                         verdicts/cloud merges. Timestamps are simulated
                         seconds — traces are byte-identical across
                         thread counts and repeat runs
              --metrics-out FILE   write per-period counter/gauge/
                         histogram snapshots as JSONL; summarize with
                         `feel report <file>`
              --audit FILE   write the predicted-vs-realized audit
                         ledger as JSONL: per period and device, the
                         optimizer's predicted batchsize / compute /
                         TDMA slot share / finish time next to what the
                         round scheduler realized (arrival, outcome,
                         staleness, carry), plus per-period learning
                         efficiency. Simulated time only — identical
                         across thread counts. Summarize with
                         `feel audit <file>`
              --k N  --partition iid|noniid|dirichlet:alpha  --seed N
              --out results/
              --threads N (0 = all cores; results identical at any value)
  optimize    solve one period's joint batchsize + slot allocation
              --k N  --batch B  --gpu  --seed N
  channel     print sampled per-device average rates
              --k N  --seed N
  fit-gpu     profile the GPU training function and fit eq. 26
              --noise F  --seed N
  experiment  regenerate a paper table/figure: fig2 | fig3 | table2 | fig4 | fig5
              --k N  --periods N  --warm N  --backend host|pjrt
              --time-budget SECONDS  --train-n N  --out results/
  report      summarize a --metrics-out JSONL dump: counter totals, last
              gauges, p50/p95/max per histogram
              feel report <metrics.jsonl>   (or --in <file>)
  audit       summarize an --audit JSONL ledger: per-period learning
              efficiency (loss decrement / simulated second), predicted
              vs realized period time, straggler regret (realized /
              predicted finish), bandwidth utilization, outcome tallies
              feel audit <audit.jsonl>   (or --in <file>)
  bench-merge fold per-bench BENCH_*.json artifacts into one
              BENCH_trajectory.json keyed by headline metrics; with
              --baseline, exit nonzero when a headline metric regresses
              more than --tolerance (default 0.25) in its bad direction
              feel bench-merge BENCH_a.json ...  --run STAMP
                [--out BENCH_trajectory.json] [--baseline FILE]
                [--tolerance F]
  lint        check the determinism contracts (R1-R6): total_cmp-only float
              sorts, literal/nonzero/distinct RNG stream tags, no hash-order
              iteration in deterministic modules, wall clock on allowlist
              only, no unwrap/expect in library code, RNG construction in
              util::rng only. Exits nonzero if any finding survives its
              pragmas. See README \"Determinism contract\"
              feel lint [root] [--json]   (root: crate or repo root; default .)
  help        this text
";

/// CLI entry (called from main.rs).
pub fn main() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = Args::parse(&argv)?;
    run(args)
}

pub fn run(args: Args) -> Result<()> {
    match args.cmd.as_str() {
        "train" => cmd_train(&args),
        "optimize" => cmd_optimize(&args),
        "channel" => cmd_channel(&args),
        "fit-gpu" => cmd_fit_gpu(&args),
        "experiment" => cmd_experiment(&args),
        "report" => cmd_report(&args),
        "audit" => cmd_audit(&args),
        "bench-merge" => cmd_bench_merge(&args),
        "lint" => cmd_lint(&args),
        "help" | "--help" | "-h" => {
            println!("{HELP}");
            Ok(())
        }
        other => bail!("unknown command {other:?}\n{HELP}"),
    }
}

fn experiment_from_args(args: &Args) -> Result<Experiment> {
    let mut exp = match args.get("config") {
        Some(path) => Experiment::from_config(&Config::load(Path::new(path))?)?,
        None => Experiment::default(),
    };
    if let Some(k) = args.get("k") {
        exp.k = k.parse().context("--k")?;
    }
    if let Some(p) = args.get("partition") {
        exp.partition = crate::data::Partition::parse(p)
            .ok_or_else(|| anyhow::anyhow!("bad --partition {p:?}"))?;
    }
    if let Some(s) = args.get("seed") {
        exp.trainer.seed = s.parse().context("--seed")?;
    }
    if let Some(s) = args.get("scheme") {
        exp.trainer.scheme = parse_scheme(s, exp.trainer.b_max)?;
    }
    if args.get("gpu") == Some("true") {
        exp.gpu = true;
    }
    if let Some(m) = args.get("model") {
        exp.model = m.to_string();
    }
    if let Some(spec) = args.get("backends") {
        exp.backends = parse_backends_spec(spec)?;
    }
    // re-validate: --k/--gpu/--backends overrides can change the fleet's
    // tier shape after the config-file check ran
    exp.check_backend_tiers()?;
    if let Some(v) = args.get("cells") {
        exp.cells = v.parse().context("--cells")?;
    }
    if let Some(v) = args.get("tau") {
        exp.tau = v.parse().context("--tau")?;
    }
    if let Some(spec) = args.get("cell-policies") {
        exp.cell_policies = parse_cell_policies_spec(spec)?;
    }
    exp.trainer.sample_frac = args.f64_or("sample-frac", exp.trainer.sample_frac)?;
    exp.cell_frac = args.f64_or("cell-frac", exp.cell_frac)?;
    // fault-injection knobs: a knob for a fault class whose rate is zero
    // is a mistake, not a no-op (mirrors the config-file check)
    let crash_rate = args.f64_or("crash-rate", exp.trainer.fault.crash_rate)?;
    if args.get("crash-len").is_some() && crash_rate <= 0.0 {
        bail!("--crash-len needs --crash-rate > 0 to take effect");
    }
    let corrupt_rate = args.f64_or("corrupt-rate", exp.trainer.fault.corrupt_rate)?;
    if args.get("corrupt-noise").is_some() && corrupt_rate <= 0.0 {
        bail!("--corrupt-noise needs --corrupt-rate > 0 to take effect");
    }
    exp.trainer.fault = FaultPlan::new(
        crash_rate,
        args.usize_or("crash-len", exp.trainer.fault.crash_len as usize)? as u64,
        corrupt_rate,
        args.f64_or("corrupt-noise", exp.trainer.fault.corrupt_noise)?,
        args.f64_or("outage-rate", exp.trainer.fault.outage_rate)?,
    )?;
    let q_policy = match args.get("quarantine") {
        Some(q) => Quarantine::parse(q).ok_or_else(|| {
            anyhow::anyhow!("bad --quarantine {q:?} (accepted: {QUARANTINE_NAMES})")
        })?,
        None => exp.trainer.guard.policy,
    };
    exp.trainer.guard =
        GradGuard::new(q_policy, args.f64_or("max-norm", exp.trainer.guard.max_norm)?)?;
    // same re-validation story for the topology + sampling + fault knobs
    exp.check_topology()?;
    if let Some(t) = args.get("threads") {
        exp.trainer.threads = t.parse().context("--threads")?;
    }
    if let Some(p) = args.get("policy") {
        exp.trainer.policy = parse_policy(p)?;
    }
    reject_stray_policy_flags(args, exp.trainer.policy)?;
    match &mut exp.trainer.policy {
        RoundPolicy::Sync => {}
        RoundPolicy::Deadline { factor } => {
            *factor = args.f64_or("deadline-factor", *factor)?;
        }
        RoundPolicy::Async { alpha, beta, quorum } => {
            *alpha = args.f64_or("async-alpha", *alpha)?;
            *beta = args.f64_or("async-beta", *beta)?;
            *quorum = args.f64_or("quorum", *quorum)?;
        }
    }
    exp.trainer.policy.validate()?;
    exp.trainer.straggler = StragglerModel::new(
        args.f64_or("jitter", exp.trainer.straggler.jitter)?,
        args.f64_or("dropout", exp.trainer.straggler.dropout)?,
    )?;
    // the linalg row-blocked GEMM reads the crate-wide knob
    crate::util::threads::set_global_threads(exp.trainer.threads);
    Ok(exp)
}

/// A per-policy knob passed alongside a policy it does not apply to is a
/// mistake, not a no-op — silently ignoring `--quorum` under the sync
/// policy would run a different experiment than the user asked for. The
/// knob table lives on `RoundPolicy` so this and the config-file check
/// can never drift apart.
fn reject_stray_policy_flags(args: &Args, policy: RoundPolicy) -> Result<()> {
    for knob in RoundPolicy::ALL_KNOBS {
        let flag = knob.replace('_', "-");
        if args.get(&flag).is_some() && !policy.knob_names().contains(knob) {
            bail!("--{flag} does not apply to round policy {:?}", policy.name());
        }
    }
    Ok(())
}

fn backend_kind(args: &Args) -> Result<BackendKind> {
    let name = args.get("backend").unwrap_or("host");
    BackendKind::parse(name).ok_or_else(|| anyhow::anyhow!("bad --backend {name:?}"))
}

fn out_dir(args: &Args) -> PathBuf {
    PathBuf::from(args.get("out").unwrap_or("results"))
}

/// Resolve the checkpoint/resume flags shared by the flat and
/// hierarchical train paths: (save cadence, save path, resume path).
fn checkpoint_flags(args: &Args) -> Result<(usize, Option<PathBuf>, Option<PathBuf>)> {
    let every = args.usize_or("checkpoint-every", 0)?;
    let ckpt = args.get("checkpoint").map(PathBuf::from);
    if every > 0 && ckpt.is_none() {
        bail!("--checkpoint-every needs --checkpoint <file> to write to");
    }
    Ok((every, ckpt, args.get("resume").map(PathBuf::from)))
}

/// Resolve the observability flags shared by the flat and hierarchical
/// train paths: (trace path, metrics path, audit path). Any one of them
/// turns the observability sink on.
fn obs_flags(args: &Args) -> (Option<PathBuf>, Option<PathBuf>, Option<PathBuf>) {
    (
        args.get("trace").map(PathBuf::from),
        args.get("metrics-out").map(PathBuf::from),
        args.get("audit").map(PathBuf::from),
    )
}

/// Write an observability artifact (trace JSON / metrics JSONL) to disk.
fn write_obs_file(path: &Path, content: &str, what: &str) -> Result<()> {
    std::fs::write(path, content)
        .with_context(|| format!("writing {what} {}", path.display()))?;
    println!("{what} -> {}", path.display());
    Ok(())
}

fn cmd_train(args: &Args) -> Result<()> {
    let exp = experiment_from_args(args)?;
    let periods = args.usize_or("periods", exp.periods)?;
    let kind = backend_kind(args)?;
    let rec = Recorder::new(&out_dir(args), &format!("train_{}", exp.name))?;
    if exp.cells > 1 {
        return cmd_train_hier(args, &exp, periods, kind, &rec);
    }

    let backends = make_fleet_backends(&exp, kind)?;
    let set = backends.set();
    let (train, test) = make_data(&exp);
    let mut rng = Pcg::seeded(exp.trainer.seed ^ 0xf1ee7);
    let fleet = exp.fleet(&mut rng);
    let models = (0..set.family_count())
        .map(|f| format!("{} x{}", set.family_name(f), set.family_size(f)))
        .collect::<Vec<_>>()
        .join(" + ");
    println!(
        "training {models} on {:?}: K={}, scheme={}, policy={}, {:?}, {} periods, {} threads",
        kind,
        exp.k,
        exp.trainer.scheme.name(),
        exp.trainer.policy.name(),
        exp.partition,
        periods,
        crate::util::threads::resolve(exp.trainer.threads),
    );
    let mut tr = Trainer::with_backends(
        exp.trainer.clone(),
        fleet,
        &train,
        &test,
        exp.partition,
        set,
    )?;
    let (every, ckpt, resume) = checkpoint_flags(args)?;
    let (trace, metrics_out, audit) = obs_flags(args);
    if trace.is_some() || metrics_out.is_some() || audit.is_some() {
        tr.enable_obs();
    }
    let warm = args.usize_or("warm", 0)?;
    match &resume {
        // a resumed run's model state comes from the checkpoint — warm
        // starting again would train past it
        Some(path) => tr.resume_from(path)?,
        None if warm > 0 => tr.warm_start(warm, 64, 0.05)?,
        None => {}
    }
    match &ckpt {
        Some(path) => {
            tr.run_checkpointed(periods, every, path)?;
            // always leave a final snapshot so the run is resumable even
            // when periods is not a multiple of the cadence
            tr.save_checkpoint(path)?;
        }
        None => {
            tr.run(periods)?;
        }
    }
    if let Some(path) = &trace {
        write_obs_file(path, &tr.export_trace(), "trace")?;
    }
    if let Some(path) = &metrics_out {
        write_obs_file(path, &tr.export_metrics(), "metrics")?;
    }
    if let Some(path) = &audit {
        write_obs_file(path, &tr.export_audit(), "audit")?;
    }
    let log = &tr.log;
    rec.csv("train_log", &log.to_csv())?;
    println!(
        "done: {} periods, sim time {:.1}s, final loss {:.4}, final acc {} -> {}",
        log.records.len(),
        log.total_time(),
        log.final_loss().unwrap_or(f64::NAN),
        log.final_acc().map(|a| format!("{:.3}", a)).unwrap_or("n/a".into()),
        rec.dir().display()
    );
    Ok(())
}

/// The hierarchical form of `train`: C concurrent cells under a cloud
/// aggregator (`hier/`), driven through `exp::common::run_hier_scheme` —
/// the same path the benches take.
fn cmd_train_hier(
    args: &Args,
    exp: &Experiment,
    periods: usize,
    kind: BackendKind,
    rec: &Recorder,
) -> Result<()> {
    let policies = exp
        .resolved_cell_policies()
        .iter()
        .map(|p| p.name().to_string())
        .collect::<Vec<_>>()
        .join(",");
    println!(
        "training hierarchical on {:?}: K={} over {} cells, tau={}, scheme={}, \
         policies=[{}], {:?}, {} periods, {} threads",
        kind,
        exp.k,
        exp.cells,
        exp.tau,
        exp.trainer.scheme.name(),
        policies,
        exp.partition,
        periods,
        crate::util::threads::resolve(exp.trainer.threads),
    );
    let warm = args.usize_or("warm", 0)?;
    let (every, ckpt, resume) = checkpoint_flags(args)?;
    let (trace, metrics_out, audit) = obs_flags(args);
    let run = run_hier_scheme_traced(
        exp,
        exp.trainer.scheme,
        kind,
        periods,
        warm,
        every,
        ckpt.as_deref(),
        resume.as_deref(),
        trace.is_some() || metrics_out.is_some() || audit.is_some(),
    )?;
    if let (Some(path), Some(content)) = (&trace, &run.trace) {
        write_obs_file(path, content, "trace")?;
    }
    if let (Some(path), Some(content)) = (&metrics_out, &run.metrics) {
        write_obs_file(path, content, "metrics")?;
    }
    if let (Some(path), Some(content)) = (&audit, &run.audit) {
        write_obs_file(path, content, "audit")?;
    }
    rec.csv("train_log", &run.log.to_csv())?;
    println!(
        "done: {} cells x {} periods, {} cloud rounds, sim time {:.1}s, final loss {:.4} -> {}",
        run.cells,
        periods,
        run.cloud_rounds,
        run.sim_time,
        run.log.final_loss().unwrap_or(f64::NAN),
        rec.dir().display()
    );
    Ok(())
}

fn cmd_optimize(args: &Args) -> Result<()> {
    let exp = experiment_from_args(args)?;
    let mut rng = Pcg::seeded(exp.trainer.seed);
    let mut fleet = exp.fleet(&mut rng);
    let rates: Vec<PeriodRates> = fleet.iter_mut().map(|d| d.link.step(&mut rng)).collect();
    let s_bits = exp.trainer.wire_ratio * exp.trainer.quant_bits as f64 * 570_000.0;
    let inst = Instance::from_fleet(
        &fleet,
        &rates,
        exp.trainer.b_max as f64,
        s_bits,
        exp.trainer.frame_ul,
        exp.trainer.frame_dl,
        exp.trainer.xi_init,
    )?;
    let sol = match args.get("batch") {
        Some(b) => opt::solve_fixed_batch(&inst, b.parse().context("--batch")?, 1e-9)?,
        None => opt::solve(&inst, 1e-9)?,
    };
    println!(
        "optimal allocation (K={}, B*={:.1}, efficiency {:.5}, T={:.3}s = up {:.3} + down {:.3}):",
        exp.k,
        sol.solution.b_total,
        sol.efficiency,
        sol.solution.period_latency(),
        sol.solution.t_up,
        sol.solution.t_down
    );
    println!(
        "{:>4} {:>10} {:>10} {:>12} {:>12} {:>12}",
        "dev", "B_k", "V_k", "R_ul (Mbps)", "tau_ul (ms)", "tau_dl (ms)"
    );
    for (k, d) in inst.devices.iter().enumerate() {
        println!(
            "{k:>4} {:>10.1} {:>10.1} {:>12.2} {:>12.3} {:>12.3}",
            sol.solution.batches[k],
            d.speed,
            d.rate_ul / 1e6,
            sol.solution.tau_ul[k] * 1e3,
            sol.solution.tau_dl[k] * 1e3,
        );
    }
    Ok(())
}

fn cmd_channel(args: &Args) -> Result<()> {
    let exp = experiment_from_args(args)?;
    let mut rng = Pcg::seeded(exp.trainer.seed);
    let mut fleet = exp.fleet(&mut rng);
    println!("{:>4} {:>10} {:>14} {:>14}", "dev", "dist (m)", "R_ul (Mbps)", "R_dl (Mbps)");
    for d in fleet.iter_mut() {
        let r = d.link.step(&mut rng);
        println!(
            "{:>4} {:>10.1} {:>14.2} {:>14.2}",
            d.id,
            d.link.dist_m,
            r.ul_bps / 1e6,
            r.dl_bps / 1e6
        );
    }
    Ok(())
}

fn cmd_fit_gpu(args: &Args) -> Result<()> {
    let noise = args.f64_or("noise", 0.02)?;
    let seed = args.usize_or("seed", 42)? as u64;
    let mut rng = Pcg::seeded(seed);
    println!("GPU training-function fits (eq. 26), measurement noise {noise}:");
    for (name, gpu) in paper_profiles() {
        let bs: Vec<f64> = (1..=128).map(|b| b as f64).collect();
        let ts: Vec<f64> = bs.iter().map(|&b| gpu.measure(b, noise, &mut rng)).collect();
        let fit = fit_piecewise(&bs, &ts);
        println!(
            "  {name:<10} true(t_l={:.4}, c={:.5}, B_th={:>3.0})  fit(t_l={:.4}, c={:.5}, B_th={:>3.0})",
            gpu.t_flat, gpu.slope, gpu.b_th, fit.t_l, fit.c, fit.b_th
        );
    }
    Ok(())
}

fn cmd_experiment(args: &Args) -> Result<()> {
    let which = args
        .positional
        .first()
        .map(|s| s.as_str())
        .ok_or_else(|| anyhow::anyhow!("experiment wants: fig2|fig3|table2|fig4|fig5"))?;
    let kind = backend_kind(args)?;
    let rec = Recorder::new(&out_dir(args), which)?;
    let mut base = Experiment::default();
    base.train_n = args.usize_or("train-n", 3000)?;
    base.synth.dim = args.usize_or("dim", if kind == BackendKind::Pjrt { 768 } else { 192 })?;
    base.trainer.threads = args.usize_or("threads", 0)?;
    crate::util::threads::set_global_threads(base.trainer.threads);
    match which {
        "fig2" => fig2::drive(&rec),
        "fig3" => {
            let periods = args.usize_or("periods", 200)?;
            fig3::drive(&rec, &base, periods, kind)
        }
        "table2" => {
            let k = args.usize_or("k", 6)?;
            let periods = args.usize_or("periods", 150)?;
            let warm = args.usize_or("warm", 100)?;
            table2::drive(&rec, &base, k, periods, warm, kind)
        }
        "fig4" | "fig5" => {
            let fig = if which == "fig4" { 4 } else { 5 };
            let budget = args.f64_or("time-budget", 600.0)?;
            let periods = args.usize_or("periods", 2000)?;
            fig45::drive(&rec, &base, fig, budget, periods, kind)
        }
        other => bail!("unknown experiment {other:?}"),
    }
}

/// Summarize a `--metrics-out` JSONL dump into a per-run table (counter
/// totals, last gauges, p50/p95/max per histogram).
fn cmd_report(args: &Args) -> Result<()> {
    let path = args
        .get("in")
        .or_else(|| args.positional.first().map(|s| s.as_str()))
        .ok_or_else(|| anyhow::anyhow!("report wants a metrics JSONL path (or --in <file>)"))?;
    let src = std::fs::read_to_string(path).with_context(|| format!("reading {path}"))?;
    print!("{}", crate::obs::summarize_jsonl(&src)?);
    Ok(())
}

/// Summarize an `--audit` JSONL ledger: per-period learning efficiency,
/// predicted-vs-realized regret, bandwidth utilization, outcome tallies.
fn cmd_audit(args: &Args) -> Result<()> {
    let path = args
        .get("in")
        .or_else(|| args.positional.first().map(|s| s.as_str()))
        .ok_or_else(|| anyhow::anyhow!("audit wants an audit JSONL path (or --in <file>)"))?;
    let src = std::fs::read_to_string(path).with_context(|| format!("reading {path}"))?;
    print!("{}", crate::obs::summarize_audit_jsonl(&src)?);
    Ok(())
}

/// Fold per-bench `BENCH_*.json` artifacts into one `BENCH_trajectory.json`
/// and, when `--baseline` is given, gate on headline-metric regressions.
/// The run stamp comes from `--run` — never from the wall clock — so the
/// trajectory is a pure function of its inputs.
fn cmd_bench_merge(args: &Args) -> Result<()> {
    use crate::benchkit::{check_regressions, merge_bench_artifacts};
    use crate::util::json::Json;
    if args.positional.is_empty() {
        bail!("bench-merge wants one or more BENCH_*.json paths");
    }
    let mut parts = Vec::new();
    for path in &args.positional {
        let src = std::fs::read_to_string(path).with_context(|| format!("reading {path}"))?;
        let doc = Json::parse(&src)
            .map_err(|e| anyhow::anyhow!("parsing {path}: {e}"))?;
        parts.push(doc);
    }
    let run = args.get("run").unwrap_or("unstamped");
    let trajectory = merge_bench_artifacts(&parts, run);
    let out = PathBuf::from(args.get("out").unwrap_or("BENCH_trajectory.json"));
    std::fs::write(&out, format!("{trajectory}\n"))
        .with_context(|| format!("writing {}", out.display()))?;
    println!("trajectory ({} bench file(s), run {run:?}) -> {}", parts.len(), out.display());
    if let Some(base_path) = args.get("baseline") {
        let src = std::fs::read_to_string(base_path)
            .with_context(|| format!("reading baseline {base_path}"))?;
        let baseline = Json::parse(&src)
            .map_err(|e| anyhow::anyhow!("parsing baseline {base_path}: {e}"))?;
        let tolerance = args.f64_or("tolerance", 0.25)?;
        let rep = check_regressions(&baseline, &trajectory, tolerance);
        for note in &rep.notes {
            println!("{note}");
        }
        for failure in &rep.failures {
            println!("{failure}");
        }
        if !rep.failures.is_empty() {
            bail!(
                "bench-merge: {} headline metric(s) regressed past {:.0}% vs {base_path}",
                rep.failures.len(),
                tolerance * 100.0
            );
        }
        println!("bench-merge: no headline regression vs {base_path}");
    }
    Ok(())
}

/// Run the determinism-contract linter (`analysis`) over the tree and
/// exit nonzero on findings. Reads source files only — it can never touch
/// a training run.
fn cmd_lint(args: &Args) -> Result<()> {
    let arg = args.positional.first().map(PathBuf::from).unwrap_or_else(|| PathBuf::from("."));
    let root = crate::analysis::resolve_root(&arg)?;
    let findings = crate::analysis::lint_tree(&root)?;
    if args.get("json") == Some("true") {
        println!("{}", crate::analysis::render_json(&findings));
    } else {
        print!("{}", crate::analysis::render_text(&findings));
        println!("feel lint: {} finding(s) in {}", findings.len(), root.display());
    }
    if !findings.is_empty() {
        bail!("feel lint: {} contract violation(s)", findings.len());
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &str) -> Vec<String> {
        s.split_whitespace().map(|x| x.to_string()).collect()
    }

    #[test]
    fn parse_flags_and_positionals() {
        let a = Args::parse(&argv("experiment fig2 --k 12 --gpu --out /tmp/r")).unwrap();
        assert_eq!(a.cmd, "experiment");
        assert_eq!(a.positional, vec!["fig2"]);
        assert_eq!(a.get("k"), Some("12"));
        assert_eq!(a.get("gpu"), Some("true"));
        assert_eq!(a.get("out"), Some("/tmp/r"));
    }

    #[test]
    fn usize_parsing_errors() {
        let a = Args::parse(&argv("train --periods abc")).unwrap();
        assert!(a.usize_or("periods", 1).is_err());
        assert_eq!(a.usize_or("missing", 7).unwrap(), 7);
    }

    #[test]
    fn threads_flag_plumbs_into_trainer_config() {
        let a = Args::parse(&argv("train --threads 4")).unwrap();
        let exp = experiment_from_args(&a).unwrap();
        assert_eq!(exp.trainer.threads, 4);
        let a = Args::parse(&argv("train --threads nope")).unwrap();
        assert!(experiment_from_args(&a).is_err());
        // leave the global knob on auto for other tests
        crate::util::threads::set_global_threads(0);
    }

    #[test]
    fn policy_flags_plumb_into_trainer_config() {
        let a = Args::parse(&argv("train --policy deadline --deadline-factor 1.4")).unwrap();
        let exp = experiment_from_args(&a).unwrap();
        assert_eq!(exp.trainer.policy, RoundPolicy::Deadline { factor: 1.4 });
        let a = Args::parse(&argv(
            "train --policy async --async-alpha 0.9 --async-beta 1.0 --quorum 0.75 \
             --jitter 0.3 --dropout 0.05",
        ))
        .unwrap();
        let exp = experiment_from_args(&a).unwrap();
        assert_eq!(
            exp.trainer.policy,
            RoundPolicy::Async { alpha: 0.9, beta: 1.0, quorum: 0.75 }
        );
        assert_eq!(exp.trainer.straggler, StragglerModel { jitter: 0.3, dropout: 0.05 });
        crate::util::threads::set_global_threads(0);
    }

    #[test]
    fn bad_scheme_and_policy_errors_list_accepted_values() {
        let a = Args::parse(&argv("train --policy fifo")).unwrap();
        let err = experiment_from_args(&a).unwrap_err().to_string();
        assert!(err.contains("sync | deadline | async"), "{err}");
        let a = Args::parse(&argv("train --scheme sgd")).unwrap();
        let err = experiment_from_args(&a).unwrap_err().to_string();
        assert!(err.contains("proposed") && err.contains("individual"), "{err}");
        // knob validation fires at argument time too
        let a = Args::parse(&argv("train --policy deadline --deadline-factor 0.3")).unwrap();
        assert!(experiment_from_args(&a).is_err());
        let a = Args::parse(&argv("train --dropout 2.0")).unwrap();
        assert!(experiment_from_args(&a).is_err());
        // a knob for a policy that is not active is an error, not a no-op
        let a = Args::parse(&argv("train --quorum 0.25")).unwrap();
        let err = experiment_from_args(&a).unwrap_err().to_string();
        assert!(err.contains("does not apply"), "{err}");
        let a = Args::parse(&argv("train --policy deadline --quorum 0.25")).unwrap();
        assert!(experiment_from_args(&a).is_err());
        let a = Args::parse(&argv("train --policy async --deadline-factor 1.2")).unwrap();
        assert!(experiment_from_args(&a).is_err());
        crate::util::threads::set_global_threads(0);
        // the help text enumerates both flags' accepted values
        assert!(HELP.contains("--policy sync|deadline|async"));
        assert!(HELP.contains("--scheme proposed|gradient_fl|model_fl|individual"));
    }

    #[test]
    fn backends_flag_plumbs_into_experiment() {
        let a = Args::parse(&argv("train --backends 0:mini_dense,1:mini_res:host")).unwrap();
        let exp = experiment_from_args(&a).unwrap();
        assert_eq!(exp.backends.len(), 2);
        assert_eq!(exp.backends[0].tier, 0);
        assert_eq!(exp.backends[0].model, "mini_dense");
        assert_eq!(exp.backends[1].backend.as_deref(), Some("host"));
        // malformed specs and out-of-range tiers are clean errors
        let a = Args::parse(&argv("train --backends nope")).unwrap();
        assert!(experiment_from_args(&a).is_err());
        let a = Args::parse(&argv("train --backends 7:mini_res")).unwrap();
        let err = experiment_from_args(&a).unwrap_err().to_string();
        assert!(err.contains("out of range"), "{err}");
        // gpu fleets have one tier, so tier 1 is rejected there too
        let a = Args::parse(&argv("train --gpu --backends 1:mini_res")).unwrap();
        assert!(experiment_from_args(&a).is_err());
        crate::util::threads::set_global_threads(0);
        assert!(HELP.contains("--backends tier:model[:backend]"));
    }

    #[test]
    fn topology_flags_plumb_into_experiment() {
        let a = Args::parse(&argv("train --k 12 --cells 3 --tau 4")).unwrap();
        let exp = experiment_from_args(&a).unwrap();
        assert_eq!((exp.cells, exp.tau), (3, 4));
        let a = Args::parse(&argv(
            "train --k 12 --cells 3 --cell-policies sync,deadline,async",
        ))
        .unwrap();
        let exp = experiment_from_args(&a).unwrap();
        assert_eq!(exp.cell_policies.len(), 3);
        assert_eq!(exp.cell_policies[1], RoundPolicy::Deadline { factor: 1.25 });
        // validation fires on the CLI surface too
        let a = Args::parse(&argv("train --k 2 --cells 3")).unwrap();
        let err = experiment_from_args(&a).unwrap_err().to_string();
        assert!(err.contains("every cell needs a device"), "{err}");
        // topology knobs without a multi-cell run are errors, not no-ops
        let a = Args::parse(&argv("train --tau 4")).unwrap();
        assert!(experiment_from_args(&a).is_err());
        let a = Args::parse(&argv("train --cell-policies sync")).unwrap();
        assert!(experiment_from_args(&a).is_err());
        let a = Args::parse(&argv("train --k 12 --cells 3 --cell-policies sync,fifo,async"))
            .unwrap();
        assert!(experiment_from_args(&a).is_err());
        crate::util::threads::set_global_threads(0);
        assert!(HELP.contains("--cells C  --tau N"));
        assert!(HELP.contains("--cell-policies"));
    }

    #[test]
    fn sampling_flags_plumb_into_experiment() {
        let a = Args::parse(&argv("train --k 12 --sample-frac 0.25")).unwrap();
        let exp = experiment_from_args(&a).unwrap();
        assert_eq!(exp.trainer.sample_frac, 0.25);
        let a = Args::parse(&argv("train --k 12 --cells 2 --cell-frac 0.5")).unwrap();
        let exp = experiment_from_args(&a).unwrap();
        assert_eq!(exp.cell_frac, 0.5);
        // validation fires on the CLI surface too
        let a = Args::parse(&argv("train --sample-frac 0")).unwrap();
        assert!(experiment_from_args(&a).is_err());
        let a = Args::parse(&argv("train --sample-frac 1.5")).unwrap();
        assert!(experiment_from_args(&a).is_err());
        // cell sampling on a flat run is an error, not a no-op
        let a = Args::parse(&argv("train --cell-frac 0.5")).unwrap();
        let err = experiment_from_args(&a).unwrap_err().to_string();
        assert!(err.contains("multi-cell"), "{err}");
        crate::util::threads::set_global_threads(0);
        assert!(HELP.contains("--sample-frac"));
        assert!(HELP.contains("--cell-frac"));
    }

    #[test]
    fn fault_flags_plumb_into_experiment() {
        let a = Args::parse(&argv(
            "train --crash-rate 0.05 --crash-len 3 --corrupt-rate 0.1 --corrupt-noise 2.0 \
             --quarantine reject --max-norm 50.0",
        ))
        .unwrap();
        let exp = experiment_from_args(&a).unwrap();
        assert_eq!(exp.trainer.fault.crash_rate, 0.05);
        assert_eq!(exp.trainer.fault.crash_len, 3);
        assert_eq!(exp.trainer.fault.corrupt_rate, 0.1);
        assert_eq!(exp.trainer.fault.corrupt_noise, 2.0);
        assert_eq!(exp.trainer.guard.policy, Quarantine::Reject);
        assert_eq!(exp.trainer.guard.max_norm, 50.0);
        // a fault knob whose gate is off is an error, not a no-op
        let a = Args::parse(&argv("train --crash-len 3")).unwrap();
        let err = experiment_from_args(&a).unwrap_err().to_string();
        assert!(err.contains("--crash-rate > 0"), "{err}");
        let a = Args::parse(&argv("train --corrupt-noise 1.0")).unwrap();
        let err = experiment_from_args(&a).unwrap_err().to_string();
        assert!(err.contains("--corrupt-rate > 0"), "{err}");
        let a = Args::parse(&argv("train --quarantine firewall")).unwrap();
        let err = experiment_from_args(&a).unwrap_err().to_string();
        assert!(err.contains("off | reject | clip | abort"), "{err}");
        // cell outage needs a multi-cell topology
        let a = Args::parse(&argv("train --outage-rate 0.1")).unwrap();
        let err = experiment_from_args(&a).unwrap_err().to_string();
        assert!(err.contains("multi-cell"), "{err}");
        let a = Args::parse(&argv("train --k 12 --cells 2 --outage-rate 0.1")).unwrap();
        let exp = experiment_from_args(&a).unwrap();
        assert_eq!(exp.trainer.fault.outage_rate, 0.1);
        crate::util::threads::set_global_threads(0);
        assert!(HELP.contains("--crash-rate"));
        assert!(HELP.contains("--quarantine off|reject|clip|abort"));
    }

    #[test]
    fn checkpoint_flags_validate() {
        let a = Args::parse(&argv("train --checkpoint /tmp/c.ckpt")).unwrap();
        let (every, ckpt, resume) = checkpoint_flags(&a).unwrap();
        assert_eq!(every, 0);
        assert!(ckpt.is_some() && resume.is_none());
        let a = Args::parse(&argv("train --checkpoint-every 5")).unwrap();
        let err = checkpoint_flags(&a).unwrap_err().to_string();
        assert!(err.contains("--checkpoint"), "{err}");
        assert!(HELP.contains("--checkpoint FILE"));
        assert!(HELP.contains("--resume FILE"));
    }

    #[test]
    fn obs_flags_resolve_and_are_documented() {
        let a = Args::parse(&argv(
            "train --trace /tmp/t.json --metrics-out /tmp/m.jsonl --audit /tmp/a.jsonl",
        ))
        .unwrap();
        let (trace, metrics, audit) = obs_flags(&a);
        assert_eq!(trace.as_deref(), Some(Path::new("/tmp/t.json")));
        assert_eq!(metrics.as_deref(), Some(Path::new("/tmp/m.jsonl")));
        assert_eq!(audit.as_deref(), Some(Path::new("/tmp/a.jsonl")));
        let (trace, metrics, audit) = obs_flags(&Args::parse(&argv("train")).unwrap());
        assert!(trace.is_none() && metrics.is_none() && audit.is_none());
        assert!(HELP.contains("--trace FILE"));
        assert!(HELP.contains("--metrics-out FILE"));
        assert!(HELP.contains("--audit FILE"));
        assert!(HELP.contains("report"));
        assert!(HELP.contains("feel audit <audit.jsonl>"));
        assert!(HELP.contains("bench-merge"));
    }

    #[test]
    fn report_command_validates_input() {
        // no path at all
        let a = Args::parse(&argv("report")).unwrap();
        let err = run(a).unwrap_err().to_string();
        assert!(err.contains("metrics JSONL"), "{err}");
        // missing file
        let a = Args::parse(&argv("report /nonexistent/metrics.jsonl")).unwrap();
        assert!(run(a).is_err());
        // a real dump summarizes
        let mut m = crate::obs::MetricsRegistry::default();
        m.inc("round.applied", 3);
        m.observe("round.duration", 0.5);
        m.snapshot(1, 0);
        let path = std::env::temp_dir().join(format!("feel_report_{}.jsonl", std::process::id()));
        std::fs::write(&path, m.to_jsonl()).unwrap();
        let a = Args::parse(&argv(&format!("report {}", path.display()))).unwrap();
        run(a).unwrap();
        // --in form too
        let a = Args::parse(&argv(&format!("report --in {}", path.display()))).unwrap();
        run(a).unwrap();
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn audit_command_validates_input() {
        // no path at all
        let a = Args::parse(&argv("audit")).unwrap();
        let err = run(a).unwrap_err().to_string();
        assert!(err.contains("audit JSONL"), "{err}");
        // missing file
        let a = Args::parse(&argv("audit /nonexistent/audit.jsonl")).unwrap();
        assert!(run(a).is_err());
        // a real ledger summarizes (both positional and --in forms)
        let mut led = crate::obs::AuditLedger::new(0);
        let plan = crate::coordinator::scheme::Plan {
            batches: vec![16, 16],
            t_period: 1.2,
            t_up: 1.0,
            t_down: 0.2,
            finish: vec![0.9, 0.9],
            predicted: vec![
                crate::opt::types::PredictedTiming { compute: 0.5, comm: 0.4, slot_share: 0.5 };
                2
            ],
            predicted_efficiency: Some(0.05),
        };
        led.begin(1, 0.0, &plan);
        led.barrier_fill();
        led.end(1.2, 0.01, 32, 2);
        let path = std::env::temp_dir().join(format!("feel_audit_{}.jsonl", std::process::id()));
        std::fs::write(&path, led.to_jsonl()).unwrap();
        let a = Args::parse(&argv(&format!("audit {}", path.display()))).unwrap();
        run(a).unwrap();
        let a = Args::parse(&argv(&format!("audit --in {}", path.display()))).unwrap();
        run(a).unwrap();
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn bench_merge_command_merges_and_gates() {
        // no inputs is an error
        let a = Args::parse(&argv("bench-merge")).unwrap();
        let err = run(a).unwrap_err().to_string();
        assert!(err.contains("BENCH_"), "{err}");
        let dir = std::env::temp_dir();
        let pid = std::process::id();
        let bench = dir.join(format!("feel_bm_bench_{pid}.json"));
        let traj = dir.join(format!("feel_bm_traj_{pid}.json"));
        let base = dir.join(format!("feel_bm_base_{pid}.json"));
        std::fs::write(
            &bench,
            r#"{"bench":"gemm","speedup_256_vs_ref":4.0,"results":[{"packed_ms":2.0}]}"#,
        )
        .unwrap();
        // merge alone succeeds and stamps the run from the flag
        let a = Args::parse(&argv(&format!(
            "bench-merge {} --run abc123 --out {}",
            bench.display(),
            traj.display()
        )))
        .unwrap();
        run(a).unwrap();
        let traj_doc =
            crate::util::json::Json::parse(&std::fs::read_to_string(&traj).unwrap()).unwrap();
        assert_eq!(traj_doc.get("run").and_then(|v| v.as_str()), Some("abc123"));
        // a matching baseline passes the gate; a 2x-better baseline fails it
        std::fs::write(&base, std::fs::read_to_string(&traj).unwrap()).unwrap();
        let a = Args::parse(&argv(&format!(
            "bench-merge {} --run abc123 --out {} --baseline {}",
            bench.display(),
            traj.display(),
            base.display()
        )))
        .unwrap();
        run(a).unwrap();
        std::fs::write(
            &base,
            r#"{"headline":{"gemm.best.packed_ms":0.5,"gemm.speedup_256_vs_ref":16.0}}"#,
        )
        .unwrap();
        let a = Args::parse(&argv(&format!(
            "bench-merge {} --run abc123 --out {} --baseline {}",
            bench.display(),
            traj.display(),
            base.display()
        )))
        .unwrap();
        let err = run(a).unwrap_err().to_string();
        assert!(err.contains("regressed"), "{err}");
        for p in [&bench, &traj, &base] {
            std::fs::remove_file(p).ok();
        }
    }

    #[test]
    fn dirichlet_partition_flag() {
        let a = Args::parse(&argv("train --partition dirichlet:0.3")).unwrap();
        let exp = experiment_from_args(&a).unwrap();
        assert_eq!(exp.partition, crate::data::Partition::Dirichlet { alpha: 0.3 });
        let a = Args::parse(&argv("train --partition dirichlet:bad")).unwrap();
        assert!(experiment_from_args(&a).is_err());
        crate::util::threads::set_global_threads(0);
    }

    #[test]
    fn lint_command_is_wired() {
        let a = Args::parse(&argv("lint /nonexistent/path")).unwrap();
        let err = run(a).unwrap_err().to_string();
        assert!(err.contains("no src/"), "{err}");
        assert!(HELP.contains("feel lint [root] [--json]"));
    }

    #[test]
    fn unknown_command_rejected() {
        let a = Args::parse(&argv("frobnicate")).unwrap();
        assert!(run(a).is_err());
    }

    #[test]
    fn help_runs() {
        let a = Args::parse(&argv("help")).unwrap();
        run(a).unwrap();
    }

    #[test]
    fn fit_gpu_runs() {
        let a = Args::parse(&argv("fit-gpu --noise 0.01 --seed 3")).unwrap();
        run(a).unwrap();
    }

    #[test]
    fn channel_and_optimize_run() {
        run(Args::parse(&argv("channel --k 4 --seed 1")).unwrap()).unwrap();
        run(Args::parse(&argv("optimize --k 4 --seed 1")).unwrap()).unwrap();
        run(Args::parse(&argv("optimize --k 4 --batch 128 --gpu")).unwrap()).unwrap();
    }
}
