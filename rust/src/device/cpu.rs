//! CPU compute model (paper eq. 9 and 12).
//!
//! A CPU device trains serially: the local gradient calculation latency is
//! `t^L = B * C^L / f` where `f` is the CPU frequency (cycles/s) and `C^L`
//! the cycles per sample for one forward-backward pass; the model update
//! costs `t^M = M^C / f` cycles.

/// A CPU training module.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct CpuModule {
    /// CPU frequency, cycles/s (paper: 0.7 / 1.4 / 2.1 GHz tiers)
    pub freq_hz: f64,
    /// cycles per sample for forward-backward (C^L)
    pub cycles_per_sample: f64,
    /// cycles for one local model update (M^C)
    pub cycles_per_update: f64,
}

impl CpuModule {
    pub fn new(freq_hz: f64, cycles_per_sample: f64, cycles_per_update: f64) -> Self {
        assert!(freq_hz > 0.0 && cycles_per_sample > 0.0 && cycles_per_update >= 0.0);
        CpuModule { freq_hz, cycles_per_sample, cycles_per_update }
    }

    /// Local gradient calculation latency for batchsize `b` (eq. 9).
    pub fn grad_latency(&self, b: f64) -> f64 {
        b * self.cycles_per_sample / self.freq_hz
    }

    /// Local model update latency (eq. 12).
    pub fn update_latency(&self) -> f64 {
        self.cycles_per_update / self.freq_hz
    }

    /// Local training speed `V_k = f / C^L` (samples/s) — Theorem 1's V_k.
    pub fn training_speed(&self) -> f64 {
        self.freq_hz / self.cycles_per_sample
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn latency_linear_in_batch() {
        let c = CpuModule::new(1.4e9, 7e7, 1e8);
        let t1 = c.grad_latency(1.0);
        let t64 = c.grad_latency(64.0);
        assert!((t64 / t1 - 64.0).abs() < 1e-9);
    }

    #[test]
    fn faster_cpu_lower_latency() {
        let slow = CpuModule::new(0.7e9, 7e7, 1e8);
        let fast = CpuModule::new(2.1e9, 7e7, 1e8);
        assert!(fast.grad_latency(32.0) < slow.grad_latency(32.0));
        assert!(fast.update_latency() < slow.update_latency());
        assert!((fast.training_speed() / slow.training_speed() - 3.0).abs() < 1e-9);
    }

    #[test]
    fn paper_scale_sanity() {
        // 1.4 GHz, 7e7 cycles/sample -> 20 samples/s; B=128 -> 6.4 s
        let c = CpuModule::new(1.4e9, 7e7, 1e8);
        assert!((c.training_speed() - 20.0).abs() < 1e-9);
        assert!((c.grad_latency(128.0) - 6.4).abs() < 1e-9);
    }
}
