//! Device compute substrate (DESIGN.md S3–S4): CPU and GPU latency models
//! and fleet construction.

pub mod cpu;
pub mod fleet;
pub mod gpu;
pub mod sampling;
pub mod straggler;

pub use cpu::CpuModule;
pub use fleet::{paper_cpu_fleet, paper_gpu_fleet, Compute, Device, FleetSpec, CPU_TIER_COUNT};
pub use gpu::{paper_profiles, GpuModule};
pub use sampling::ClientSampler;
pub use straggler::{Perturbation, StragglerModel};
