//! Device fleet: the per-device combination of a compute module (CPU or
//! GPU) and a wireless link, plus the paper's standard fleet constructors.

use crate::device::cpu::CpuModule;
use crate::device::gpu::GpuModule;
use crate::util::rng::Pcg;
use crate::wireless::{CellConfig, DeviceLink};

/// Compute module of one device.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Compute {
    Cpu(CpuModule),
    Gpu(GpuModule),
}

impl Compute {
    /// Gradient-calculation latency at batchsize `b` (eq. 9 / eq. 26).
    pub fn grad_latency(&self, b: f64) -> f64 {
        match self {
            Compute::Cpu(c) => c.grad_latency(b),
            Compute::Gpu(g) => g.grad_latency(b),
        }
    }

    /// Model-update latency (eq. 12 / eq. 27).
    pub fn update_latency(&self) -> f64 {
        match self {
            Compute::Cpu(c) => c.update_latency(),
            Compute::Gpu(g) => g.update_latency(),
        }
    }

    /// Affine view of the latency on the feasible batch region:
    /// `t(B) ≈ B / speed + offset`. For CPUs offset = 0 and the form is
    /// exact; for GPUs this is the compute-bound branch (Lemma 2 restricts
    /// the optimum there).
    pub fn affine(&self) -> (f64, f64) {
        match self {
            Compute::Cpu(c) => (c.training_speed(), 0.0),
            Compute::Gpu(g) => (g.compute_bound_speed(), g.affine_offset()),
        }
    }

    /// Lower bound of the batch region the optimizer may use
    /// (1 for CPU; B_th for GPU per Lemma 2).
    pub fn batch_floor(&self) -> f64 {
        match self {
            Compute::Cpu(_) => 1.0,
            Compute::Gpu(g) => g.b_th,
        }
    }
}

/// One device: compute + link.
#[derive(Clone, Debug)]
pub struct Device {
    pub id: usize,
    pub compute: Compute,
    pub link: DeviceLink,
}

/// Number of CPU speed tiers in the paper's fleet (§VI-B). Device `id`
/// belongs to tier `id % CPU_TIER_COUNT` — the coordinate the per-tier
/// backend rules (`fleet.backends`, see `coordinator::fleet_backends`)
/// key on.
pub const CPU_TIER_COUNT: usize = 3;

/// The paper's CPU fleet (§VI-B): K devices in equal thirds of
/// 0.7 / 1.4 / 2.1 GHz, uniform positions. `cycles_per_sample` and
/// `cycles_per_update` are shared (same DNN on every device).
pub fn paper_cpu_fleet(
    k: usize,
    cycles_per_sample: f64,
    cycles_per_update: f64,
    cell: CellConfig,
    shadow_sigma_db: f64,
    shadow_rho: f64,
    rng: &mut Pcg,
) -> Vec<Device> {
    let tiers: [f64; CPU_TIER_COUNT] = [0.7e9, 1.4e9, 2.1e9];
    (0..k)
        .map(|id| Device {
            id,
            compute: Compute::Cpu(CpuModule::new(
                tiers[id % tiers.len()],
                cycles_per_sample,
                cycles_per_update,
            )),
            link: DeviceLink::sample(cell, shadow_sigma_db, shadow_rho, rng),
        })
        .collect()
}

/// Stream tag for [`FleetSpec`] materialization: keeps the lazy fleet's
/// per-device draws off every other counter-derived stream family.
const FLEET_SPEC_TAG: u64 = 0xf1ee_75ec_0000_00aa;

/// Compute layout of a lazy fleet: the paper's CPU tiers or one shared
/// GPU profile.
#[derive(Clone, Copy, Debug)]
enum FleetKind {
    Cpu { cycles_per_sample: f64, cycles_per_update: f64 },
    Gpu(GpuModule),
}

/// O(1)-memory columnar fleet description: tier layout + cell geometry +
/// shadowing parameters + a seed. Where [`paper_cpu_fleet`] eagerly builds
/// `Vec<Device>` (per-device position and shadowing state up front, from
/// one *sequential* RNG), a `FleetSpec` materializes a [`Device`] on
/// demand from a counter-derived per-device stream — so device `id` is a
/// pure function of `(spec, id)`, independent of which other ids were
/// materialized, in what order, or at what period. That makes a
/// million-device fleet representable in a few dozen bytes, with only the
/// round's *sampled* devices ever existing as state.
///
/// The two constructions are distinct RNG-stream families: an eager
/// fleet's sequential draws cannot be skipped to (a Box–Muller normal
/// consumes a variable number of raws), so `FleetSpec` does not reproduce
/// `paper_cpu_fleet` device-for-device — it reproduces *itself*, which is
/// the property the lazy path needs (`materialize(id)` is bitwise what
/// `materialize_all()[id]` builds).
#[derive(Clone, Copy, Debug)]
pub struct FleetSpec {
    k: usize,
    kind: FleetKind,
    cell: CellConfig,
    shadow_sigma_db: f64,
    shadow_rho: f64,
    seed: u64,
}

impl FleetSpec {
    /// The paper's CPU fleet layout (§VI-B tiers), lazily.
    pub fn cpu(
        k: usize,
        cycles_per_sample: f64,
        cycles_per_update: f64,
        cell: CellConfig,
        shadow_sigma_db: f64,
        shadow_rho: f64,
        seed: u64,
    ) -> FleetSpec {
        FleetSpec {
            k,
            kind: FleetKind::Cpu { cycles_per_sample, cycles_per_update },
            cell,
            shadow_sigma_db,
            shadow_rho,
            seed,
        }
    }

    /// The paper's GPU fleet layout (§VI-D, identical modules), lazily.
    pub fn gpu(
        k: usize,
        gpu: GpuModule,
        cell: CellConfig,
        shadow_sigma_db: f64,
        shadow_rho: f64,
        seed: u64,
    ) -> FleetSpec {
        FleetSpec { k, kind: FleetKind::Gpu(gpu), cell, shadow_sigma_db, shadow_rho, seed }
    }

    /// Fleet size this spec describes (no state of that size exists).
    pub fn k(&self) -> usize {
        self.k
    }

    /// Device `id`'s compute module — pure tier arithmetic, no RNG.
    pub fn compute_of(&self, id: usize) -> Compute {
        match self.kind {
            FleetKind::Cpu { cycles_per_sample, cycles_per_update } => {
                let tiers: [f64; CPU_TIER_COUNT] = [0.7e9, 1.4e9, 2.1e9];
                Compute::Cpu(CpuModule::new(
                    tiers[id % tiers.len()],
                    cycles_per_sample,
                    cycles_per_update,
                ))
            }
            FleetKind::Gpu(g) => Compute::Gpu(g),
        }
    }

    /// Materialize device `id` from its counter-derived stream. Bitwise
    /// identical no matter when or in what order ids are materialized.
    pub fn materialize(&self, id: usize) -> Device {
        assert!(id < self.k, "device {id} outside fleet of {}", self.k);
        let mut rng = Pcg::for_device(self.seed ^ FLEET_SPEC_TAG, 0, id as u64);
        Device {
            id,
            compute: self.compute_of(id),
            link: DeviceLink::sample(self.cell, self.shadow_sigma_db, self.shadow_rho, &mut rng),
        }
    }

    /// Eager twin: the whole fleet as `materialize` would build it id by
    /// id (the lazy-vs-eager equivalence test hinges on this being a plain
    /// map over `materialize`).
    pub fn materialize_all(&self) -> Vec<Device> {
        (0..self.k).map(|id| self.materialize(id)).collect()
    }
}

/// The paper's GPU fleet (§VI-D): K identical GTX-1080-Ti-like devices.
pub fn paper_gpu_fleet(
    k: usize,
    gpu: GpuModule,
    cell: CellConfig,
    shadow_sigma_db: f64,
    shadow_rho: f64,
    rng: &mut Pcg,
) -> Vec<Device> {
    (0..k)
        .map(|id| Device {
            id,
            compute: Compute::Gpu(gpu),
            link: DeviceLink::sample(cell, shadow_sigma_db, shadow_rho, rng),
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cpu_fleet_tiers() {
        let mut rng = Pcg::seeded(1);
        let fleet = paper_cpu_fleet(12, 7e7, 1e8, CellConfig::default(), 0.0, 0.0, &mut rng);
        assert_eq!(fleet.len(), 12);
        let count_07 = fleet
            .iter()
            .filter(|d| matches!(d.compute, Compute::Cpu(c) if (c.freq_hz - 0.7e9).abs() < 1.0))
            .count();
        assert_eq!(count_07, 4);
    }

    #[test]
    fn affine_cpu_exact() {
        let c = Compute::Cpu(CpuModule::new(1.4e9, 7e7, 1e8));
        let (v, off) = c.affine();
        for b in [1.0, 17.0, 128.0] {
            assert!((c.grad_latency(b) - (b / v + off)).abs() < 1e-12);
        }
        assert_eq!(c.batch_floor(), 1.0);
    }

    #[test]
    fn affine_gpu_compute_bound() {
        let g = Compute::Gpu(GpuModule::new(0.1, 0.002, 32.0, 1e9, 1e13));
        let (v, off) = g.affine();
        for b in [32.0, 64.0, 128.0] {
            assert!((g.grad_latency(b) - (b / v + off)).abs() < 1e-12, "b={b}");
        }
        assert_eq!(g.batch_floor(), 32.0);
    }

    #[test]
    fn lazy_materialization_matches_eager_bitwise_per_id() {
        let spec = FleetSpec::cpu(32, 7e7, 1e8, CellConfig::default(), 4.0, 0.5, 11);
        let eager = spec.materialize_all();
        assert_eq!(eager.len(), 32);
        // materialize out of order, repeatedly: every field of every
        // device must be bitwise what the eager pass built
        for &id in &[31usize, 0, 17, 17, 5] {
            let d = spec.materialize(id);
            let e = &eager[id];
            assert_eq!(d.id, e.id);
            assert_eq!(d.compute, e.compute);
            assert_eq!(d.link.dist_m.to_bits(), e.link.dist_m.to_bits(), "id {id}");
            let (a, b) = (d.link.current(), e.link.current());
            assert_eq!(a.ul_bps.to_bits(), b.ul_bps.to_bits(), "id {id}");
            assert_eq!(a.dl_bps.to_bits(), b.dl_bps.to_bits(), "id {id}");
        }
    }

    #[test]
    fn spec_is_o1_memory_and_keeps_tier_layout() {
        // the whole point: a million-device fleet is a value, not a Vec
        assert!(std::mem::size_of::<FleetSpec>() <= 160);
        let spec = FleetSpec::cpu(1_000_000, 7e7, 1e8, CellConfig::default(), 4.0, 0.5, 3);
        assert_eq!(spec.k(), 1_000_000);
        // tier arithmetic matches the eager constructor's `id % 3` layout
        for id in [0usize, 1, 2, 999_999] {
            let Compute::Cpu(c) = spec.compute_of(id) else { panic!("cpu spec") };
            let tiers = [0.7e9, 1.4e9, 2.1e9];
            assert_eq!(c.freq_hz, tiers[id % 3], "id {id}");
        }
        // distinct devices land at distinct positions
        let a = spec.materialize(12).link.dist_m;
        let b = spec.materialize(999_999).link.dist_m;
        assert!((a - b).abs() > 1e-9);
        // and distinct seeds decorrelate the same device
        let other = FleetSpec::cpu(1_000_000, 7e7, 1e8, CellConfig::default(), 4.0, 0.5, 4);
        let (x, y) = (spec.materialize(12), other.materialize(12));
        assert_ne!(x.link.dist_m.to_bits(), y.link.dist_m.to_bits());
    }

    #[test]
    fn gpu_spec_materializes_identical_modules() {
        let gpu = GpuModule::new(0.1, 0.002, 32.0, 1e9, 1e13);
        let spec = FleetSpec::gpu(6, gpu, CellConfig::default(), 0.0, 0.0, 9);
        for d in spec.materialize_all() {
            assert_eq!(d.compute, Compute::Gpu(gpu));
        }
    }

    #[test]
    fn gpu_fleet_identical_modules() {
        let mut rng = Pcg::seeded(2);
        let gpu = GpuModule::new(0.1, 0.002, 32.0, 1e9, 1e13);
        let fleet = paper_gpu_fleet(6, gpu, CellConfig::default(), 0.0, 0.0, &mut rng);
        for d in &fleet {
            assert_eq!(d.compute, Compute::Gpu(gpu));
        }
        // positions should differ
        let d0 = fleet[0].link.dist_m;
        assert!(fleet.iter().any(|d| (d.link.dist_m - d0).abs() > 1e-6));
    }
}
