//! Device fleet: the per-device combination of a compute module (CPU or
//! GPU) and a wireless link, plus the paper's standard fleet constructors.

use crate::device::cpu::CpuModule;
use crate::device::gpu::GpuModule;
use crate::util::rng::Pcg;
use crate::wireless::{CellConfig, DeviceLink};

/// Compute module of one device.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Compute {
    Cpu(CpuModule),
    Gpu(GpuModule),
}

impl Compute {
    /// Gradient-calculation latency at batchsize `b` (eq. 9 / eq. 26).
    pub fn grad_latency(&self, b: f64) -> f64 {
        match self {
            Compute::Cpu(c) => c.grad_latency(b),
            Compute::Gpu(g) => g.grad_latency(b),
        }
    }

    /// Model-update latency (eq. 12 / eq. 27).
    pub fn update_latency(&self) -> f64 {
        match self {
            Compute::Cpu(c) => c.update_latency(),
            Compute::Gpu(g) => g.update_latency(),
        }
    }

    /// Affine view of the latency on the feasible batch region:
    /// `t(B) ≈ B / speed + offset`. For CPUs offset = 0 and the form is
    /// exact; for GPUs this is the compute-bound branch (Lemma 2 restricts
    /// the optimum there).
    pub fn affine(&self) -> (f64, f64) {
        match self {
            Compute::Cpu(c) => (c.training_speed(), 0.0),
            Compute::Gpu(g) => (g.compute_bound_speed(), g.affine_offset()),
        }
    }

    /// Lower bound of the batch region the optimizer may use
    /// (1 for CPU; B_th for GPU per Lemma 2).
    pub fn batch_floor(&self) -> f64 {
        match self {
            Compute::Cpu(_) => 1.0,
            Compute::Gpu(g) => g.b_th,
        }
    }
}

/// One device: compute + link.
#[derive(Clone, Debug)]
pub struct Device {
    pub id: usize,
    pub compute: Compute,
    pub link: DeviceLink,
}

/// Number of CPU speed tiers in the paper's fleet (§VI-B). Device `id`
/// belongs to tier `id % CPU_TIER_COUNT` — the coordinate the per-tier
/// backend rules (`fleet.backends`, see `coordinator::fleet_backends`)
/// key on.
pub const CPU_TIER_COUNT: usize = 3;

/// The paper's CPU fleet (§VI-B): K devices in equal thirds of
/// 0.7 / 1.4 / 2.1 GHz, uniform positions. `cycles_per_sample` and
/// `cycles_per_update` are shared (same DNN on every device).
pub fn paper_cpu_fleet(
    k: usize,
    cycles_per_sample: f64,
    cycles_per_update: f64,
    cell: CellConfig,
    shadow_sigma_db: f64,
    shadow_rho: f64,
    rng: &mut Pcg,
) -> Vec<Device> {
    let tiers: [f64; CPU_TIER_COUNT] = [0.7e9, 1.4e9, 2.1e9];
    (0..k)
        .map(|id| Device {
            id,
            compute: Compute::Cpu(CpuModule::new(
                tiers[id % tiers.len()],
                cycles_per_sample,
                cycles_per_update,
            )),
            link: DeviceLink::sample(cell, shadow_sigma_db, shadow_rho, rng),
        })
        .collect()
}

/// The paper's GPU fleet (§VI-D): K identical GTX-1080-Ti-like devices.
pub fn paper_gpu_fleet(
    k: usize,
    gpu: GpuModule,
    cell: CellConfig,
    shadow_sigma_db: f64,
    shadow_rho: f64,
    rng: &mut Pcg,
) -> Vec<Device> {
    (0..k)
        .map(|id| Device {
            id,
            compute: Compute::Gpu(gpu),
            link: DeviceLink::sample(cell, shadow_sigma_db, shadow_rho, rng),
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cpu_fleet_tiers() {
        let mut rng = Pcg::seeded(1);
        let fleet = paper_cpu_fleet(12, 7e7, 1e8, CellConfig::default(), 0.0, 0.0, &mut rng);
        assert_eq!(fleet.len(), 12);
        let count_07 = fleet
            .iter()
            .filter(|d| matches!(d.compute, Compute::Cpu(c) if (c.freq_hz - 0.7e9).abs() < 1.0))
            .count();
        assert_eq!(count_07, 4);
    }

    #[test]
    fn affine_cpu_exact() {
        let c = Compute::Cpu(CpuModule::new(1.4e9, 7e7, 1e8));
        let (v, off) = c.affine();
        for b in [1.0, 17.0, 128.0] {
            assert!((c.grad_latency(b) - (b / v + off)).abs() < 1e-12);
        }
        assert_eq!(c.batch_floor(), 1.0);
    }

    #[test]
    fn affine_gpu_compute_bound() {
        let g = Compute::Gpu(GpuModule::new(0.1, 0.002, 32.0, 1e9, 1e13));
        let (v, off) = g.affine();
        for b in [32.0, 64.0, 128.0] {
            assert!((g.grad_latency(b) - (b / v + off)).abs() < 1e-12, "b={b}");
        }
        assert_eq!(g.batch_floor(), 32.0);
    }

    #[test]
    fn gpu_fleet_identical_modules() {
        let mut rng = Pcg::seeded(2);
        let gpu = GpuModule::new(0.1, 0.002, 32.0, 1e9, 1e13);
        let fleet = paper_gpu_fleet(6, gpu, CellConfig::default(), 0.0, 0.0, &mut rng);
        for d in &fleet {
            assert_eq!(d.compute, Compute::Gpu(gpu));
        }
        // positions should differ
        let d0 = fleet[0].link.dist_m;
        assert!(fleet.iter().any(|d| (d.link.dist_m - d0).abs() > 1e-6));
    }
}
