//! Straggler model: per-device latency jitter and dropout.
//!
//! Real edge fleets are long-tailed — background load, thermal throttling,
//! link outages — while the paper's latency model is deterministic given
//! the channel draws. This module injects that tail *on top of* the
//! analytic per-device finish times, so the scheduler policies in `sched/`
//! have something to schedule around.
//!
//! Determinism contract: perturbations are drawn from counter-derived
//! `Pcg::for_device` streams keyed by `(seed ^ STRAGGLER_TAG, period,
//! device)`, never from shared RNG state. Fault injection is therefore a
//! pure function of the run coordinates — independent of thread count,
//! execution order, and of *which* round policy consumes the draws — and
//! the tag keeps the streams disjoint from batch sampling, which uses the
//! untagged seed.

use anyhow::{bail, Result};

use crate::util::rng::Pcg;

/// Stream tag separating straggler draws from batch-sampling draws that
/// share the same `(seed, period, device)` coordinates.
const STRAGGLER_TAG: u64 = 0x57a6_6e1e_d15c_0de5;

/// Per-period, per-device perturbation.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Perturbation {
    /// multiplicative latency factor, >= 1 (1 = nominal speed)
    pub slowdown: f64,
    /// the device fails this period: its contribution never arrives
    pub dropped: bool,
}

impl Perturbation {
    /// The identity perturbation (nominal latency, no failure).
    pub fn none() -> Self {
        Perturbation { slowdown: 1.0, dropped: false }
    }
}

/// Fleet-wide straggler configuration.
///
/// `slowdown = 1 + jitter * Exp(1)` — exponential so the tail is heavy
/// (mean slowdown `1 + jitter`, but the max over K devices grows like
/// `1 + jitter * ln K`, which is exactly the barrier pathology the
/// Deadline/Async policies exist to cut). `dropout` is the per-period
/// probability a device fails outright.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct StragglerModel {
    /// jitter amplitude (0 = deterministic latency)
    pub jitter: f64,
    /// per-period per-device dropout probability in [0, 1)
    pub dropout: f64,
}

impl StragglerModel {
    pub fn new(jitter: f64, dropout: f64) -> Result<StragglerModel> {
        if !(jitter.is_finite() && jitter >= 0.0) {
            bail!("straggler jitter must be finite and >= 0, got {jitter}");
        }
        if !(dropout.is_finite() && (0.0..1.0).contains(&dropout)) {
            bail!("straggler dropout must be in [0, 1), got {dropout}");
        }
        Ok(StragglerModel { jitter, dropout })
    }

    /// No perturbation at all: the identity model.
    pub fn none() -> StragglerModel {
        StragglerModel { jitter: 0.0, dropout: 0.0 }
    }

    /// Whether any perturbation can occur. Inactive models skip RNG
    /// entirely, so a zero-jitter zero-dropout run is bitwise identical to
    /// one that never constructed a straggler model.
    pub fn is_active(&self) -> bool {
        self.jitter > 0.0 || self.dropout > 0.0
    }

    /// Draw device `device`'s perturbation for `period` of a run seeded
    /// with `seed`. The draw order is fixed (dropout uniform first, then
    /// the jitter exponential) so enabling one knob never shifts the
    /// other's stream.
    pub fn sample(&self, seed: u64, period: u64, device: u64) -> Perturbation {
        if !self.is_active() {
            return Perturbation::none();
        }
        let mut rng = Pcg::for_device(seed ^ STRAGGLER_TAG, period, device);
        let dropped = rng.f64() < self.dropout;
        let slowdown = 1.0 + self.jitter * rng.exponential();
        Perturbation { slowdown, dropped }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn inactive_is_identity_without_rng() {
        let m = StragglerModel::none();
        assert!(!m.is_active());
        for d in 0..8 {
            assert_eq!(m.sample(7, 3, d), Perturbation::none());
        }
    }

    #[test]
    fn validates_knobs() {
        assert!(StragglerModel::new(-0.1, 0.0).is_err());
        assert!(StragglerModel::new(f64::NAN, 0.0).is_err());
        assert!(StragglerModel::new(0.0, 1.0).is_err());
        assert!(StragglerModel::new(0.0, -0.2).is_err());
        assert!(StragglerModel::new(0.5, 0.3).is_ok());
    }

    #[test]
    fn draws_are_replayable_and_coordinate_separated() {
        let m = StragglerModel::new(0.5, 0.2).unwrap();
        let a = m.sample(11, 5, 3);
        assert_eq!(a, m.sample(11, 5, 3));
        // any coordinate change gives an independent draw stream: over many
        // devices the slowdowns cannot all coincide
        let same = (0..200)
            .filter(|&d| m.sample(11, 5, d).slowdown == m.sample(11, 6, d).slowdown)
            .count();
        assert!(same < 3, "{same} coincident draws across periods");
    }

    #[test]
    fn slowdown_at_least_one_and_dropout_rate_sane() {
        let m = StragglerModel::new(0.5, 0.25).unwrap();
        let n = 4000u64;
        let mut drops = 0usize;
        let mut mean = 0.0;
        for d in 0..n {
            let p = m.sample(1, 0, d);
            assert!(p.slowdown >= 1.0);
            drops += p.dropped as usize;
            mean += p.slowdown;
        }
        mean /= n as f64;
        let rate = drops as f64 / n as f64;
        assert!((rate - 0.25).abs() < 0.03, "dropout rate {rate}");
        // Exp(1) jitter: mean slowdown == 1 + jitter
        assert!((mean - 1.5).abs() < 0.05, "mean slowdown {mean}");
    }

    #[test]
    fn jitter_only_never_drops_and_dropout_only_never_slows() {
        let jitter_only = StragglerModel::new(0.4, 0.0).unwrap();
        let dropout_only = StragglerModel::new(0.0, 0.4).unwrap();
        for d in 0..500 {
            assert!(!jitter_only.sample(2, 1, d).dropped);
            assert_eq!(dropout_only.sample(2, 1, d).slowdown, 1.0);
        }
        // the dropout draw comes first, so the two knobs see the same
        // uniform: a device dropped by dropout_only is also dropped when
        // jitter is enabled on top
        let both = StragglerModel::new(0.4, 0.4).unwrap();
        for d in 0..200 {
            assert_eq!(dropout_only.sample(2, 1, d).dropped, both.sample(2, 1, d).dropped);
        }
    }
}
